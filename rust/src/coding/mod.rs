//! Spike coding schemes (DESIGN.md S2): the paper's dual-spike temporal
//! code plus the rate and TTFS baselines it is compared against in §II-B.

pub mod bitserial;
pub mod dualspike;
pub mod rate;
pub mod ttfs;

pub use bitserial::BitSerialPlan;
pub use dualspike::{DualSpikeCodec, SpikePair};
pub use rate::RateCodec;
pub use ttfs::TtfsCodec;

//! Dual-spike temporal coding (the paper's input/output representation).
//!
//! A digital value x is carried by a *pair* of spikes whose inter-spike
//! interval is T = x · T_bit (§III-B; Table I: T_bit = 0.2 ns). The first
//! spike opens the row's Event_flag window, the second closes it. On the
//! output side the OSG emits a pair whose interval encodes the MAC result
//! (Eq. 2); decoding divides by α·T_bit.

/// A dual-spike pair on one line: rise at `t0_ns`, fall at `t0_ns + dt_ns`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpikePair {
    /// Time of the first spike (ns).
    pub t0_ns: f64,
    /// Inter-spike interval (ns); carries the value.
    pub dt_ns: f64,
}

impl SpikePair {
    /// Time of the second spike.
    pub fn t1_ns(&self) -> f64 {
        self.t0_ns + self.dt_ns
    }
}

/// Encoder/decoder for dual-spike values.
#[derive(Debug, Clone, Copy)]
pub struct DualSpikeCodec {
    /// Interval LSB (ns).
    pub t_bit_ns: f64,
    /// Input precision in bits (saturation bound for encode).
    pub bits: u32,
}

impl DualSpikeCodec {
    pub fn new(t_bit_ns: f64, bits: u32) -> Self {
        assert!(t_bit_ns > 0.0 && bits >= 1 && bits <= 16);
        DualSpikeCodec { t_bit_ns, bits }
    }

    /// Max encodable digital value.
    pub fn max_value(&self) -> u32 {
        (1u32 << self.bits) - 1
    }

    /// Encode a digital value into a spike pair starting at `t0_ns`.
    /// Values saturate at `max_value()` (the SMU has a finite window).
    pub fn encode(&self, x: u32, t0_ns: f64) -> SpikePair {
        let v = x.min(self.max_value());
        SpikePair {
            t0_ns,
            dt_ns: v as f64 * self.t_bit_ns,
        }
    }

    /// Encode a whole input vector with aligned first spikes at t = 0
    /// (§III-A: inputs applied "across all 128 rows simultaneously").
    pub fn encode_vector(&self, xs: &[u32]) -> Vec<SpikePair> {
        xs.iter().map(|&x| self.encode(x, 0.0)).collect()
    }

    /// Exact interval → digital value (round to nearest LSB).
    pub fn decode(&self, dt_ns: f64) -> u32 {
        ((dt_ns / self.t_bit_ns).round().max(0.0)) as u32
    }

    /// Decode an OSG output interval into the *analog MAC value* in
    /// conductance units: Σ x_i·G_i = T_out / (α · T_bit)  (Eq. 2).
    pub fn decode_mac(&self, t_out_ns: f64, alpha: f64) -> f64 {
        t_out_ns / (alpha * self.t_bit_ns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codec() -> DualSpikeCodec {
        DualSpikeCodec::new(0.2, 8)
    }

    #[test]
    fn encode_is_linear_in_value() {
        let c = codec();
        assert_eq!(c.encode(0, 0.0).dt_ns, 0.0);
        assert!((c.encode(1, 0.0).dt_ns - 0.2).abs() < 1e-12);
        assert!((c.encode(255, 0.0).dt_ns - 51.0).abs() < 1e-12);
    }

    #[test]
    fn encode_saturates_at_max() {
        let c = codec();
        assert_eq!(c.encode(300, 0.0).dt_ns, c.encode(255, 0.0).dt_ns);
    }

    #[test]
    fn decode_inverts_encode_exactly() {
        let c = codec();
        for x in 0..=255u32 {
            let p = c.encode(x, 0.0);
            assert_eq!(c.decode(p.dt_ns), x);
        }
    }

    #[test]
    fn decode_rounds_to_nearest_lsb() {
        let c = codec();
        assert_eq!(c.decode(0.29), 1); // 0.29/0.2 = 1.45 → 1
        assert_eq!(c.decode(0.31), 2); // 1.55 → 2
        assert_eq!(c.decode(-0.5), 0); // clamped
    }

    #[test]
    fn decode_mac_applies_alpha() {
        let c = codec();
        // T_out = α·Σ(T_in·G) ⇒ MAC = Σ(x·G) = T_out/(α·T_bit).
        let mac = 1234.5; // x·µS units
        let t_out = 0.05 * mac * 0.2;
        assert!((c.decode_mac(t_out, 0.05) - mac).abs() < 1e-9);
    }

    #[test]
    fn vector_encode_aligns_first_spikes() {
        let c = codec();
        let ps = c.encode_vector(&[1, 2, 3]);
        assert!(ps.iter().all(|p| p.t0_ns == 0.0));
        assert!((ps[2].t1_ns() - 0.6).abs() < 1e-12);
    }
}

//! Time-to-first-spike (TTFS) coding baseline (§II-B).
//!
//! A value is the *latency* of a single spike relative to a global
//! reference edge: larger value → earlier spike. One spike per value
//! (good energy), but it needs a synchronized global clock to define
//! t = 0 — exactly the dependency the paper's dual-spike scheme removes,
//! since a pair is self-referential.

/// TTFS codec: x ∈ [0, 2^bits) ↦ spike at t = (max − x)·t_bit.
#[derive(Debug, Clone, Copy)]
pub struct TtfsCodec {
    pub t_bit_ns: f64,
    pub bits: u32,
}

impl TtfsCodec {
    pub fn new(t_bit_ns: f64, bits: u32) -> Self {
        assert!(t_bit_ns > 0.0 && (1..=16).contains(&bits));
        TtfsCodec { t_bit_ns, bits }
    }

    pub fn max_value(&self) -> u32 {
        (1u32 << self.bits) - 1
    }

    /// Spike time for value `x` (earlier = larger).
    pub fn encode(&self, x: u32) -> f64 {
        (self.max_value() - x.min(self.max_value())) as f64 * self.t_bit_ns
    }

    /// Value from spike time (requires the shared global reference!).
    pub fn decode(&self, t_ns: f64) -> u32 {
        let q = (t_ns / self.t_bit_ns).round().max(0.0) as u32;
        self.max_value() - q.min(self.max_value())
    }

    /// Decoding error caused by a clock-skew of `skew_ns` between encoder
    /// and decoder — the synchronization sensitivity dual-spike avoids.
    pub fn skew_error(&self, x: u32, skew_ns: f64) -> i64 {
        let t = self.encode(x) + skew_ns;
        self.decode(t) as i64 - x as i64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_values() {
        let c = TtfsCodec::new(0.2, 8);
        for x in 0..=255u32 {
            assert_eq!(c.decode(c.encode(x)), x);
        }
    }

    #[test]
    fn larger_values_spike_earlier() {
        let c = TtfsCodec::new(0.2, 8);
        assert!(c.encode(255) < c.encode(1));
        assert_eq!(c.encode(255), 0.0);
    }

    #[test]
    fn clock_skew_corrupts_value() {
        let c = TtfsCodec::new(0.2, 8);
        // 1 ns of skew = 5 LSB of error — the §II-B failure mode.
        assert_eq!(c.skew_error(100, 1.0), -5);
        assert_eq!(c.skew_error(100, 0.0), 0);
    }

    #[test]
    fn skew_error_saturates_at_zero_value() {
        let c = TtfsCodec::new(0.2, 8);
        let e = c.skew_error(0, 10.0);
        assert_eq!(e, 0); // already latest possible spike
    }

    #[test]
    fn unit_lsb_spike_times_are_integer_frame_slots() {
        // The stream frame adapter (DESIGN.md S18) runs this codec at a
        // 1-frame LSB so a value's spike time IS its timestep index:
        // integer, inside the T-frame window, strictly earlier for
        // larger values, and exactly invertible.
        let c = TtfsCodec::new(1.0, 4);
        let mut last = f64::INFINITY;
        for q in 1..=15u32 {
            let t = c.encode(q);
            assert_eq!(t.fract(), 0.0, "integer frame slot");
            assert!((0.0..16.0).contains(&t));
            assert!(t < last, "larger value spikes strictly earlier");
            last = t;
            assert_eq!(c.decode(t), q);
        }
    }
}

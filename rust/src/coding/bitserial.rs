//! Bit-serial input decomposition (§IV-B extension).
//!
//! The paper notes that "high bit data precision ... requires longer
//! charging periods" — an 8-bit dual-spike window is up to 51 ns and the
//! charge on C_rt approaches VDD. The standard alternative is to split
//! the input into `chunks` lower-precision passes and recombine digitally
//! with shift-add:
//!
//!   x = Σ_p chunk_p · 2^(p·bits_per_pass)
//!   MAC(x) = Σ_p 2^(p·bits_per_pass) · MAC(chunk_p)
//!
//! Each pass has a ≤(2^bits_per_pass−1)·T_bit window — shorter charging,
//! lower V_charge ceiling (more headroom for bigger arrays), at the cost
//! of `chunks`× more conversions. The trade-off is quantified in
//! `benches/fig6_energy.rs` and the ablation runner.

/// A bit-serial decomposition plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BitSerialPlan {
    /// Total input precision (e.g. 8).
    pub total_bits: u32,
    /// Bits handled per analog pass (e.g. 4 → two passes).
    pub bits_per_pass: u32,
}

impl BitSerialPlan {
    pub fn new(total_bits: u32, bits_per_pass: u32) -> Self {
        assert!(total_bits >= 1 && bits_per_pass >= 1);
        assert!(
            bits_per_pass <= total_bits,
            "pass width exceeds total precision"
        );
        BitSerialPlan {
            total_bits,
            bits_per_pass,
        }
    }

    /// Number of analog passes.
    pub fn passes(&self) -> u32 {
        self.total_bits.div_ceil(self.bits_per_pass)
    }

    /// Mask selecting one pass's chunk.
    fn mask(&self) -> u32 {
        (1u32 << self.bits_per_pass) - 1
    }

    /// Split a value into per-pass chunks, LSB chunk first.
    pub fn split(&self, x: u32) -> Vec<u32> {
        assert!(x < (1u64 << self.total_bits) as u32 + 1);
        (0..self.passes())
            .map(|p| (x >> (p * self.bits_per_pass)) & self.mask())
            .collect()
    }

    /// Split a whole input vector: `out[p][i]` = pass-p chunk of x[i].
    pub fn split_vector(&self, xs: &[u32]) -> Vec<Vec<u32>> {
        let mut out = vec![Vec::with_capacity(xs.len()); self.passes() as usize];
        for &x in xs {
            for (p, chunk) in self.split(x).into_iter().enumerate() {
                out[p].push(chunk);
            }
        }
        out
    }

    /// Recombine per-pass MAC results with shift-add.
    pub fn combine(&self, pass_macs: &[Vec<f64>]) -> Vec<f64> {
        assert_eq!(pass_macs.len(), self.passes() as usize);
        let n = pass_macs[0].len();
        let mut out = vec![0.0f64; n];
        for (p, macs) in pass_macs.iter().enumerate() {
            assert_eq!(macs.len(), n);
            let w = (1u64 << (p as u32 * self.bits_per_pass)) as f64;
            for (o, &m) in out.iter_mut().zip(macs) {
                *o += w * m;
            }
        }
        out
    }

    /// Worst-case charge-phase window per pass, in T_bit units.
    pub fn window_lsbs(&self) -> u32 {
        self.mask()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_combine_roundtrip_scalar() {
        let plan = BitSerialPlan::new(8, 4);
        assert_eq!(plan.passes(), 2);
        for x in [0u32, 1, 15, 16, 200, 255] {
            let chunks = plan.split(x);
            let back: u32 = chunks
                .iter()
                .enumerate()
                .map(|(p, &c)| c << (p as u32 * 4))
                .sum();
            assert_eq!(back, x);
        }
    }

    #[test]
    fn ragged_split_covers_all_bits() {
        let plan = BitSerialPlan::new(8, 3); // 3+3+2 bits
        assert_eq!(plan.passes(), 3);
        let chunks = plan.split(0b1011_0110);
        assert_eq!(chunks, vec![0b110, 0b110, 0b10]);
    }

    #[test]
    fn combine_is_linear_shift_add() {
        let plan = BitSerialPlan::new(8, 4);
        // MAC is linear, so combining per-chunk MACs of a known G gives
        // the full-precision MAC exactly.
        let g = [0.25f64, 1.0 / 3.0];
        let xs = [200u32, 45];
        let split = plan.split_vector(&xs);
        let mac_of = |chunk: &[u32]| -> Vec<f64> {
            vec![chunk.iter().zip(&g).map(|(&c, gg)| c as f64 * gg).sum()]
        };
        let pass_macs: Vec<Vec<f64>> =
            split.iter().map(|c| mac_of(c)).collect();
        let combined = plan.combine(&pass_macs);
        let want: f64 = xs.iter().zip(&g).map(|(&x, gg)| x as f64 * gg).sum();
        assert!((combined[0] - want).abs() < 1e-9);
    }

    #[test]
    fn window_shrinks_with_pass_width() {
        assert_eq!(BitSerialPlan::new(8, 8).window_lsbs(), 255);
        assert_eq!(BitSerialPlan::new(8, 4).window_lsbs(), 15);
        assert_eq!(BitSerialPlan::new(8, 2).window_lsbs(), 3);
    }

    #[test]
    #[should_panic(expected = "pass width")]
    fn rejects_pass_wider_than_total() {
        BitSerialPlan::new(4, 8);
    }
}

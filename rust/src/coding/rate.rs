//! Rate coding baseline (§II-B; VLSI'19 [18] style).
//!
//! Information is the *number* of spikes in a fixed window. Simple, but
//! needs many spikes per value (energy ∝ value) and quantizes coarsely —
//! this module exists so the comparison benches can demonstrate exactly
//! that trade-off against dual-spike coding.

/// Rate encoder over a fixed observation window.
#[derive(Debug, Clone, Copy)]
pub struct RateCodec {
    /// Observation window (ns).
    pub window_ns: f64,
    /// Max spikes in a window (= max representable value).
    pub max_spikes: u32,
}

impl RateCodec {
    pub fn new(window_ns: f64, max_spikes: u32) -> Self {
        assert!(window_ns > 0.0 && max_spikes >= 1);
        RateCodec { window_ns, max_spikes }
    }

    /// Encode `x` (saturating) as evenly spaced spike times in the window.
    pub fn encode(&self, x: u32) -> Vec<f64> {
        let n = x.min(self.max_spikes);
        let period = self.window_ns / self.max_spikes as f64;
        (0..n).map(|i| i as f64 * period).collect()
    }

    /// Decode = count spikes.
    pub fn decode(&self, spikes: &[f64]) -> u32 {
        spikes.len() as u32
    }

    /// Number of spike events needed to carry `x` (energy proxy).
    pub fn events_for(&self, x: u32) -> u32 {
        x.min(self.max_spikes)
    }

    /// Quantization step when representing `bits`-bit data in this window:
    /// values above `max_spikes` alias (precision loss of rate coding).
    pub fn effective_bits(&self) -> u32 {
        32 - self.max_spikes.leading_zeros()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_within_capacity() {
        let c = RateCodec::new(100.0, 64);
        for x in [0u32, 1, 17, 64] {
            assert_eq!(c.decode(&c.encode(x)), x);
        }
    }

    #[test]
    fn saturates_above_capacity() {
        let c = RateCodec::new(100.0, 64);
        assert_eq!(c.decode(&c.encode(200)), 64);
    }

    #[test]
    fn spikes_fit_in_window() {
        let c = RateCodec::new(100.0, 64);
        let s = c.encode(64);
        assert!(s.iter().all(|&t| t >= 0.0 && t < 100.0));
        assert_eq!(s.len(), 64);
    }

    #[test]
    fn event_count_linear_in_value_unlike_dualspike() {
        // The energy story of §II-B: rate coding needs x events, dual-spike
        // always needs 2.
        let c = RateCodec::new(100.0, 255);
        assert_eq!(c.events_for(200), 200);
        assert_eq!(c.events_for(3), 3);
    }

    #[test]
    fn effective_bits() {
        assert_eq!(RateCodec::new(10.0, 255).effective_bits(), 8);
        assert_eq!(RateCodec::new(10.0, 15).effective_bits(), 4);
    }

    #[test]
    fn encode_times_bin_into_distinct_unit_frames() {
        // The stream frame adapter (DESIGN.md S18) bins these times
        // into T unit-width timestep frames: encode(n) must land
        // exactly one spike in each of the FIRST n bins — the property
        // that makes the frame round trip a pure count.
        let c = RateCodec::new(8.0, 8);
        let period = c.window_ns / c.max_spikes as f64;
        for n in [0u32, 1, 5, 8] {
            let frames: Vec<usize> = c
                .encode(n)
                .iter()
                .map(|&t| (t / period) as usize)
                .collect();
            assert_eq!(frames, (0..n as usize).collect::<Vec<_>>());
            // Counting the binned spikes IS the decode.
            assert_eq!(c.decode(&c.encode(n)) as usize, frames.len());
        }
    }
}

//! SOT write dynamics (paper §III-A: "during write operations, all
//! transistors are activated, allowing currents to pass through the
//! heavy-metal layer ... and switch the magnetization state").
//!
//! Thermally-activated macrospin model: a pulse of amplitude `i_ua` and
//! duration `t_ns` switches the free layer with probability
//!
//!   P_sw = 1 − exp(−t/τ(i)),   τ(i) = τ0 · exp(Δ·(1 − i/I_c0))  for i<~I_c0
//!
//! above the critical current the precessional regime makes switching
//! quasi-deterministic for ns pulses. Parameters are typical published
//! SOT values (I_c0 ≈ 60 µA for a 1 MΩ-class junction, Δ ≈ 40).

use crate::util::rng::Rng;

use super::mtj::{Mtj, MtjState};

/// SOT write-path parameters.
#[derive(Debug, Clone, Copy)]
pub struct SotWriteParams {
    /// Critical switching current (µA).
    pub i_c0_ua: f64,
    /// Thermal stability factor Δ = E_b/kT.
    pub delta: f64,
    /// Attempt time τ0 (ns).
    pub tau0_ns: f64,
    /// Heavy-metal write-path resistance (kΩ) for energy accounting.
    pub r_write_kohm: f64,
}

impl Default for SotWriteParams {
    fn default() -> Self {
        SotWriteParams {
            i_c0_ua: 60.0,
            delta: 40.0,
            tau0_ns: 1.0,
            r_write_kohm: 1.0,
        }
    }
}

/// A write pulse applied to the heavy-metal line.
#[derive(Debug, Clone, Copy)]
pub struct WritePulse {
    /// Pulse amplitude (µA). Sign selects target state: >0 → AP, <0 → P.
    pub i_ua: f64,
    /// Pulse duration (ns).
    pub t_ns: f64,
}

impl WritePulse {
    pub fn target(&self) -> MtjState {
        if self.i_ua > 0.0 {
            MtjState::AntiParallel
        } else {
            MtjState::Parallel
        }
    }
}

/// Probability that `pulse` switches a junction with parameters `p`.
pub fn switch_probability(p: &SotWriteParams, pulse: &WritePulse) -> f64 {
    let i = pulse.i_ua.abs();
    if i <= 0.0 || pulse.t_ns <= 0.0 {
        return 0.0;
    }
    let ratio = i / p.i_c0_ua;
    if ratio >= 1.2 {
        // Precessional regime: deterministic for ns-scale pulses.
        return 1.0;
    }
    // Thermally-activated: τ(i) = τ0 · exp(Δ(1 − i/I_c0)).
    let tau = p.tau0_ns * (p.delta * (1.0 - ratio)).exp();
    1.0 - (-pulse.t_ns / tau).exp()
}

/// Energy dissipated in the write path (fJ): I²·R·t.
/// (µA² · kΩ · ns = 1e-12·1e3·1e-9 W·s = fJ.)
pub fn write_energy_fj(p: &SotWriteParams, pulse: &WritePulse) -> f64 {
    pulse.i_ua * pulse.i_ua * p.r_write_kohm * pulse.t_ns
}

/// Apply a stochastic write; returns true if the junction ends in the
/// target state (either it switched or it was already there).
pub fn apply_write(
    mtj: &mut Mtj,
    p: &SotWriteParams,
    pulse: &WritePulse,
    rng: &mut Rng,
) -> bool {
    let target = pulse.target();
    if mtj.state == target {
        mtj.writes += 1; // pulse still applied & counted
        return true;
    }
    if rng.f64() < switch_probability(p, pulse) {
        mtj.set_state(target);
        true
    } else {
        mtj.writes += 1;
        false
    }
}

/// Deterministic "verified write": retry up to `max_tries` pulses,
/// mirroring a write-verify loop in the macro's write driver.
pub fn write_verify(
    mtj: &mut Mtj,
    p: &SotWriteParams,
    pulse: &WritePulse,
    rng: &mut Rng,
    max_tries: u32,
) -> (bool, u32, f64) {
    let mut energy = 0.0;
    for attempt in 1..=max_tries {
        energy += write_energy_fj(p, pulse);
        if apply_write(mtj, p, pulse, rng) {
            return (true, attempt, energy);
        }
    }
    (false, max_tries, energy)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> SotWriteParams {
        SotWriteParams::default()
    }

    #[test]
    fn overdrive_switches_deterministically() {
        let p = params();
        let pulse = WritePulse { i_ua: 80.0, t_ns: 2.0 };
        assert_eq!(switch_probability(&p, &pulse), 1.0);
    }

    #[test]
    fn subcritical_probability_increases_with_current_and_time() {
        let p = params();
        let lo_i = switch_probability(&p, &WritePulse { i_ua: 40.0, t_ns: 5.0 });
        let hi_i = switch_probability(&p, &WritePulse { i_ua: 55.0, t_ns: 5.0 });
        assert!(hi_i > lo_i);
        let lo_t = switch_probability(&p, &WritePulse { i_ua: 55.0, t_ns: 1.0 });
        let hi_t = switch_probability(&p, &WritePulse { i_ua: 55.0, t_ns: 10.0 });
        assert!(hi_t > lo_t);
    }

    #[test]
    fn zero_pulse_never_switches() {
        let p = params();
        assert_eq!(
            switch_probability(&p, &WritePulse { i_ua: 0.0, t_ns: 5.0 }),
            0.0
        );
        assert_eq!(
            switch_probability(&p, &WritePulse { i_ua: 50.0, t_ns: 0.0 }),
            0.0
        );
    }

    #[test]
    fn energy_quadratic_in_current() {
        let p = params();
        let e1 = write_energy_fj(&p, &WritePulse { i_ua: 30.0, t_ns: 2.0 });
        let e2 = write_energy_fj(&p, &WritePulse { i_ua: 60.0, t_ns: 2.0 });
        assert!((e2 / e1 - 4.0).abs() < 1e-12);
    }

    #[test]
    fn write_verify_reaches_target_with_overdrive() {
        let p = params();
        let mut mtj = Mtj::new(1.0, 1.0);
        let mut rng = Rng::new(1);
        let pulse = WritePulse { i_ua: 90.0, t_ns: 2.0 };
        let (ok, tries, energy) = write_verify(&mut mtj, &p, &pulse, &mut rng, 4);
        assert!(ok);
        assert_eq!(tries, 1);
        assert!(energy > 0.0);
        assert_eq!(mtj.state, MtjState::AntiParallel);
    }

    #[test]
    fn marginal_writes_eventually_succeed_statistically() {
        let p = params();
        let mut rng = Rng::new(7);
        let pulse = WritePulse { i_ua: -58.0, t_ns: 5.0 };
        let mut success = 0;
        let n = 200;
        for _ in 0..n {
            let mut mtj = Mtj::new(1.0, 1.0);
            mtj.set_state(MtjState::AntiParallel);
            let (ok, _, _) = write_verify(&mut mtj, &p, &pulse, &mut rng, 8);
            success += ok as u32;
        }
        // With 8 retries at a non-trivial per-pulse probability,
        // the overwhelming majority of verified writes succeed.
        assert!(success > n * 9 / 10, "only {success}/{n} succeeded");
    }

    #[test]
    fn already_in_target_state_is_success() {
        let p = params();
        let mut mtj = Mtj::new(1.0, 1.0); // starts Parallel
        let mut rng = Rng::new(3);
        let pulse = WritePulse { i_ua: -10.0, t_ns: 0.1 }; // weak pulse
        assert!(apply_write(&mut mtj, &p, &pulse, &mut rng));
    }
}

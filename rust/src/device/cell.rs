//! 3T-2MTJ memory cell (paper §III-A, Fig 1b).
//!
//! Two SOT-MRAM devices in series per cell; J2 is sized with twice the
//! resistance of J1, so the four (J1, J2) state combinations give four
//! distinct series resistances encoding a 2-bit weight:
//!
//!   code 0 : J1=AP, J2=AP → R = 2·R + 4·R = 6·R_LRS   (G min)
//!   code 1 : J1=P , J2=AP → R = 1·R + 4·R = 5·R_LRS
//!   code 2 : J1=AP, J2=P  → R = 2·R + 2·R = 4·R_LRS
//!   code 3 : J1=P , J2=P  → R = 1·R + 2·R = 3·R_LRS   (G max)
//!
//! During reads all three transistors are off and the cell is purely the
//! series MTJ stack between RBL[0] (input) and RBL[1] (readout clamp).

use super::mtj::{Mtj, MtjState};

/// One 3T-2MTJ cell.
#[derive(Debug, Clone)]
pub struct Cell3T2J {
    /// J1: nominal R_P = R_LRS.
    pub j1: Mtj,
    /// J2: nominal R_P = 2·R_LRS.
    pub j2: Mtj,
}

impl Cell3T2J {
    /// Nominal cell: both junctions parallel (code 3, G max).
    pub fn new(r_lrs_mohm: f64, tmr: f64) -> Self {
        Cell3T2J {
            j1: Mtj::new(r_lrs_mohm, tmr),
            j2: Mtj::new(2.0 * r_lrs_mohm, tmr),
        }
    }

    /// Cell with frozen device-to-device variation factors per junction.
    pub fn with_variation(
        r_lrs_mohm: f64,
        tmr: f64,
        d2d_j1: f64,
        d2d_j2: f64,
    ) -> Self {
        Cell3T2J {
            j1: Mtj::with_variation(r_lrs_mohm, tmr, d2d_j1),
            j2: Mtj::with_variation(2.0 * r_lrs_mohm, tmr, d2d_j2),
        }
    }

    /// Program a 2-bit code (write both junctions; §III-A write op).
    ///
    /// Code bit 0 ↔ J1 state, bit 1 ↔ J2 state, chosen so conductance is
    /// strictly increasing in code (see module docs).
    pub fn program(&mut self, code: u8) {
        assert!(code < 4, "2-bit code, got {code}");
        self.j1.set_state(MtjState::from_bit(code & 1 == 0));
        self.j2.set_state(MtjState::from_bit(code & 2 == 0));
    }

    /// Read back the stored 2-bit code from the junction states.
    pub fn code(&self) -> u8 {
        let b0 = !self.j1.state.to_bit() as u8;
        let b1 = !self.j2.state.to_bit() as u8;
        b0 | (b1 << 1)
    }

    /// Series resistance of the stack (MΩ).
    pub fn resistance_mohm(&self) -> f64 {
        self.j1.resistance_mohm() + self.j2.resistance_mohm()
    }

    /// Series conductance (µS).
    pub fn conductance_us(&self) -> f64 {
        1.0 / self.resistance_mohm()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_distinct_states_match_design_table() {
        let mut c = Cell3T2J::new(1.0, 1.0);
        let want_r = [6.0, 5.0, 4.0, 3.0];
        for code in 0..4u8 {
            c.program(code);
            assert_eq!(c.code(), code);
            assert!(
                (c.resistance_mohm() - want_r[code as usize]).abs() < 1e-12,
                "code {code}: R = {}",
                c.resistance_mohm()
            );
        }
    }

    #[test]
    fn conductance_strictly_increasing_in_code() {
        let mut c = Cell3T2J::new(1.0, 1.0);
        let mut prev = 0.0;
        for code in 0..4u8 {
            c.program(code);
            let g = c.conductance_us();
            assert!(g > prev);
            prev = g;
        }
    }

    #[test]
    fn levels_match_config_level_map() {
        use crate::config::LevelMap;
        let levels = LevelMap::DeviceTrue.levels();
        let mut c = Cell3T2J::new(1.0, 1.0);
        for code in 0..4u8 {
            c.program(code);
            assert!(
                (c.conductance_us() - levels[code as usize]).abs() < 1e-12
            );
        }
    }

    #[test]
    fn j2_is_twice_j1() {
        let c = Cell3T2J::new(1.0, 1.0);
        assert!(
            (c.j2.r_p_mohm - 2.0 * c.j1.r_p_mohm).abs() < 1e-12
        );
    }

    #[test]
    fn reprogram_updates_write_counters() {
        let mut c = Cell3T2J::new(1.0, 1.0);
        c.program(0);
        c.program(3);
        assert_eq!(c.j1.writes, 2);
        assert_eq!(c.j2.writes, 2);
    }

    #[test]
    fn variation_shifts_levels_but_keeps_order() {
        let mut c = Cell3T2J::with_variation(1.0, 1.0, 1.08, 0.94);
        let mut prev = 0.0;
        for code in 0..4u8 {
            c.program(code);
            assert!(c.conductance_us() > prev);
            prev = c.conductance_us();
        }
    }

    #[test]
    #[should_panic]
    fn program_rejects_out_of_range_code() {
        Cell3T2J::new(1.0, 1.0).program(4);
    }
}

//! SOT-MRAM magnetic tunnel junction (MTJ) device model.
//!
//! The macro only reads MTJs through their resistance, so the model is
//! resistive: a free-layer state (P/AP), a nominal parallel resistance,
//! and a TMR ratio giving R_AP = R_P · (1 + TMR). Device-to-device
//! variation is frozen at fabrication time; cycle-to-cycle read noise is
//! sampled per read by the array layer.
//!
//! Writes go through the heavy-metal layer (SOT): the thermally-activated
//! switching model in [`crate::device::write`] decides whether a given
//! current pulse flips the free layer.

/// Magnetization state of the free layer relative to the pinned layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MtjState {
    /// Parallel — low resistance (R_P = R_LRS).
    Parallel,
    /// Anti-parallel — high resistance (R_AP = R_P·(1+TMR)).
    AntiParallel,
}

impl MtjState {
    /// The state encoding one bit: 0 → P, 1 → AP.
    pub fn from_bit(bit: bool) -> Self {
        if bit {
            MtjState::AntiParallel
        } else {
            MtjState::Parallel
        }
    }

    pub fn to_bit(self) -> bool {
        self == MtjState::AntiParallel
    }
}

/// One magnetic tunnel junction.
#[derive(Debug, Clone)]
pub struct Mtj {
    /// Nominal parallel-state resistance (MΩ) *including* the frozen
    /// device-to-device variation factor.
    pub r_p_mohm: f64,
    /// Tunnel magnetoresistance ratio (1.0 = 100 %).
    pub tmr: f64,
    /// Current free-layer state.
    pub state: MtjState,
    /// Lifetime write count (endurance accounting).
    pub writes: u64,
}

impl Mtj {
    /// A nominal device: parallel state, no variation applied.
    pub fn new(r_p_mohm: f64, tmr: f64) -> Self {
        assert!(r_p_mohm > 0.0 && tmr >= 0.0);
        Mtj {
            r_p_mohm,
            tmr,
            state: MtjState::Parallel,
            writes: 0,
        }
    }

    /// Same, with a multiplicative device-to-device factor (e.g. 1.02).
    pub fn with_variation(r_p_mohm: f64, tmr: f64, d2d_factor: f64) -> Self {
        assert!(d2d_factor > 0.0);
        Mtj::new(r_p_mohm * d2d_factor, tmr)
    }

    /// Present resistance (MΩ).
    pub fn resistance_mohm(&self) -> f64 {
        match self.state {
            MtjState::Parallel => self.r_p_mohm,
            MtjState::AntiParallel => self.r_p_mohm * (1.0 + self.tmr),
        }
    }

    /// Present conductance (µS).
    pub fn conductance_us(&self) -> f64 {
        1.0 / self.resistance_mohm()
    }

    /// Force the free layer to `state`, counting the write.
    pub fn set_state(&mut self, state: MtjState) {
        if self.state != state {
            self.state = state;
        }
        self.writes += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tmr_doubles_resistance_at_100pct() {
        let mut m = Mtj::new(1.0, 1.0); // Table I: R_LRS = 1 MΩ, TMR 100 %
        assert!((m.resistance_mohm() - 1.0).abs() < 1e-12);
        m.set_state(MtjState::AntiParallel);
        assert!((m.resistance_mohm() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn conductance_is_reciprocal() {
        let m = Mtj::new(2.0, 1.0);
        assert!((m.conductance_us() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn variation_scales_both_states() {
        let mut m = Mtj::with_variation(1.0, 1.0, 1.05);
        let rp = m.resistance_mohm();
        m.set_state(MtjState::AntiParallel);
        let rap = m.resistance_mohm();
        assert!((rp - 1.05).abs() < 1e-12);
        assert!((rap / rp - 2.0).abs() < 1e-12); // TMR ratio preserved
    }

    #[test]
    fn write_counter_increments() {
        let mut m = Mtj::new(1.0, 1.0);
        m.set_state(MtjState::AntiParallel);
        m.set_state(MtjState::AntiParallel); // redundant write still counted
        assert_eq!(m.writes, 2);
    }

    #[test]
    fn bit_roundtrip() {
        assert_eq!(MtjState::from_bit(true).to_bit(), true);
        assert_eq!(MtjState::from_bit(false).to_bit(), false);
    }
}

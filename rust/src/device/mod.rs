//! SOT-MRAM device substrate (DESIGN.md S1): MTJ resistance model, the
//! paper's 3T-2MTJ series cell, SOT write-switching dynamics, and the
//! seeded fault-injection runtime built on them (DESIGN.md S19).

pub mod cell;
pub mod faults;
pub mod mtj;
pub mod retention;
pub mod write;

pub use cell::Cell3T2J;
pub use faults::{FaultPlan, FaultState, ScrubOutcome};
pub use mtj::{Mtj, MtjState};
pub use retention::{EnduranceParams, RetentionParams};
pub use write::{SotWriteParams, WritePulse};

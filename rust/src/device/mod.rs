//! SOT-MRAM device substrate (DESIGN.md S1): MTJ resistance model, the
//! paper's 3T-2MTJ series cell, and SOT write-switching dynamics.

pub mod cell;
pub mod mtj;
pub mod retention;
pub mod write;

pub use cell::Cell3T2J;
pub use mtj::{Mtj, MtjState};
pub use retention::{EnduranceParams, RetentionParams};
pub use write::{SotWriteParams, WritePulse};

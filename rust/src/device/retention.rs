//! Retention & endurance model (DESIGN.md §7 extension).
//!
//! MTJ free layers are thermally stable but not immortal: the retention
//! time follows the Néel–Arrhenius law  τ_ret = τ0 · e^Δ, and the two
//! free-layer orientations relax toward thermal equilibrium (both wells
//! equally likely), so a stored bit reads back flipped after time t
//! with probability ½·(1 − exp(−2t/τ_ret)) — monotone in t, ≈ t/τ_ret
//! for t ≪ τ_ret, saturating at ½. For a weight-stationary CIM macro
//! this sets the *scrub interval* — how often the coordinator must
//! re-verify/refresh the programmed codes — and the resulting energy
//! tax, which the ablation runner quantifies against the paper's energy
//! budget. The reliability runtime (DESIGN.md S19) drives this model
//! against live arrays through `device::faults`.

use crate::util::rng::Rng;

/// Retention parameters for one MTJ technology corner.
#[derive(Debug, Clone, Copy)]
pub struct RetentionParams {
    /// Thermal stability factor Δ = E_b/kT at operating temperature.
    pub delta: f64,
    /// Attempt time τ0 (ns); physical value ≈ 1 ns.
    pub tau0_ns: f64,
}

impl RetentionParams {
    /// Typical embedded-MRAM target: Δ ≈ 60 at 85 °C (10-year retention).
    pub fn standard() -> Self {
        RetentionParams {
            delta: 60.0,
            tau0_ns: 1.0,
        }
    }

    /// Scaled-down device / high temperature: Δ ≈ 35 (τ ≈ 18 days —
    /// the regime where the coordinator's scrub policy matters).
    pub fn weak() -> Self {
        RetentionParams {
            delta: 35.0,
            tau0_ns: 1.0,
        }
    }

    /// Accelerated-aging stress corner: Δ ≈ 16 (τ ≈ 8.9 ms), the knob
    /// EX4 (`repro::reliability`) uses so drift is *measurable* within
    /// a simulated uptime of ~10⁶–10⁷ ns instead of days.
    pub fn stress() -> Self {
        RetentionParams {
            delta: 16.0,
            tau0_ns: 1.0,
        }
    }

    /// Retention so deep it is *exactly* zero in f64: Δ = 200 puts
    /// 2t/τ below the underflow knee of `exp` for any uptime shorter
    /// than the age of the universe, so `flip_probability` returns
    /// 0.0 — not merely tiny — and `corrupt_codes` takes its strict
    /// no-op branch (no draws). The gain-drift differential test
    /// (DESIGN.md S22) leans on this corner to isolate analog gain
    /// wander from retention flips with certainty, not probability.
    pub fn frozen() -> Self {
        RetentionParams {
            delta: 200.0,
            tau0_ns: 1.0,
        }
    }

    /// Mean retention time (ns).
    pub fn tau_ret_ns(&self) -> f64 {
        self.tau0_ns * self.delta.exp()
    }

    /// Probability a stored bit reads back flipped after `t_ns`: the
    /// two-state relaxation solution ½·(1 − e^(−2t/τ_ret)). Bounded in
    /// [0, ½] and monotone in t (pinned by
    /// `rust/tests/reliability_props.rs`).
    pub fn flip_probability(&self, t_ns: f64) -> f64 {
        if t_ns <= 0.0 {
            return 0.0;
        }
        0.5 * (1.0 - (-2.0 * t_ns / self.tau_ret_ns()).exp())
    }

    /// Longest scrub interval (ns) keeping per-bit flip probability at
    /// or below `p_target` — the exact inverse of
    /// [`flip_probability`](Self::flip_probability), so the target must
    /// lie strictly inside the reachable band (0, ½).
    pub fn scrub_interval_ns(&self, p_target: f64) -> f64 {
        assert!(
            p_target > 0.0 && p_target < 0.5,
            "p_target must be in (0, 0.5), got {p_target}"
        );
        -0.5 * self.tau_ret_ns() * (1.0 - 2.0 * p_target).ln()
    }
}

/// Endurance model: SOT writes are effectively unlimited (>1e12 in
/// literature), but we still track wear to expose the write-budget the
/// scheduler's reprogramming policy consumes.
#[derive(Debug, Clone, Copy)]
pub struct EnduranceParams {
    /// Rated write cycles per junction.
    pub rated_cycles: u64,
}

impl Default for EnduranceParams {
    fn default() -> Self {
        EnduranceParams {
            rated_cycles: 1_000_000_000_000, // 1e12, typical SOT rating
        }
    }
}

impl EnduranceParams {
    /// Fraction of rated life consumed by `writes` cycles, saturating
    /// at 1.0 — a die past its rating is fully worn, not 110 % worn
    /// (monotonicity + saturation pinned by
    /// `rust/tests/reliability_props.rs`).
    pub fn wear(&self, writes: u64) -> f64 {
        (writes as f64 / self.rated_cycles as f64).min(1.0)
    }
}

/// Simulate retention-induced code corruption over an idle period:
/// each junction flips independently with the Arrhenius relaxation
/// probability. Deterministic for a fixed `rng` seed (exactly two draws
/// per cell whenever p > 0) and a strict no-op at p = 0 — both pinned
/// by `rust/tests/reliability_props.rs`. Returns the number of *cells*
/// whose stored code changed.
pub fn corrupt_codes(
    codes: &mut [u8],
    idle_ns: f64,
    params: &RetentionParams,
    rng: &mut Rng,
) -> usize {
    let p = params.flip_probability(idle_ns);
    if p <= 0.0 {
        return 0;
    }
    let mut corrupted = 0;
    for code in codes.iter_mut() {
        let mut c = *code;
        // Two junctions per cell: bit0 ↔ J1, bit1 ↔ J2 (cell.rs layout).
        if rng.f64() < p {
            c ^= 1;
        }
        if rng.f64() < p {
            c ^= 2;
        }
        if c != *code {
            *code = c;
            corrupted += 1;
        }
    }
    corrupted
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_retention_is_years() {
        let p = RetentionParams::standard();
        let year_ns = 3.15e16;
        // Δ=60 → τ ≈ e^60 ns ≈ 1.1e26 ns ≫ 10 years.
        assert!(p.tau_ret_ns() > 1000.0 * year_ns);
        assert!(p.flip_probability(year_ns) < 1e-8);
    }

    #[test]
    fn weak_devices_need_scrubbing() {
        let p = RetentionParams::weak();
        // Δ=35 → τ ≈ 1.6e15 ns ≈ 18 days: monthly idle loses data.
        let day_ns = 8.64e13;
        assert!(p.flip_probability(30.0 * day_ns) > 0.1);
        let scrub = p.scrub_interval_ns(1e-6);
        assert!(scrub > 0.0 && scrub < day_ns);
    }

    #[test]
    fn scrub_interval_bounds_flip_probability() {
        let p = RetentionParams::weak();
        for target in [1e-9, 1e-6, 1e-3] {
            let t = p.scrub_interval_ns(target);
            let got = p.flip_probability(t);
            assert!((got - target).abs() / target < 1e-6, "{got} vs {target}");
        }
    }

    #[test]
    fn corruption_rate_matches_probability() {
        let p = RetentionParams { delta: 10.0, tau0_ns: 1.0 }; // fast decay
        let t = p.tau_ret_ns(); // P(flip) = ½(1 − e^−2) ≈ 0.432 per junction
        let mut rng = Rng::new(404);
        let mut codes = vec![0u8; 20_000];
        let corrupted = corrupt_codes(&mut codes, t, &p, &mut rng);
        // P(cell changed) = 1 − (1−p)² ≈ 0.678.
        let frac = corrupted as f64 / codes.len() as f64;
        assert!((frac - 0.678).abs() < 0.02, "{frac}");
    }

    #[test]
    fn flip_probability_saturates_at_equilibrium() {
        // Long after τ_ret both orientations are equally likely: the
        // read-back flip probability tends to ½, never beyond.
        let p = RetentionParams::stress();
        let tau = p.tau_ret_ns();
        assert!((p.flip_probability(1e3 * tau) - 0.5).abs() < 1e-12);
        assert!(p.flip_probability(f64::MAX) <= 0.5);
        // Small-t limit: p ≈ t/τ (first-order identical to the old
        // pure-decay model, so scrub-policy sizing is unchanged).
        let t = 1e-6 * tau;
        let lin = t / tau;
        assert!((p.flip_probability(t) - lin).abs() / lin < 1e-5);
    }

    #[test]
    fn no_time_no_corruption() {
        let mut rng = Rng::new(1);
        let mut codes = vec![3u8; 100];
        assert_eq!(
            corrupt_codes(&mut codes, 0.0, &RetentionParams::standard(), &mut rng),
            0
        );
        assert!(codes.iter().all(|&c| c == 3));
    }

    #[test]
    fn endurance_wear_fraction() {
        let e = EnduranceParams::default();
        assert!(e.wear(1_000_000) < 1e-5);
        assert!((e.wear(e.rated_cycles) - 1.0).abs() < 1e-12);
        // Saturation: past the rating the die is 100 % worn, not more.
        assert_eq!(e.wear(e.rated_cycles * 3), 1.0);
        assert_eq!(e.wear(u64::MAX), 1.0);
    }

    #[test]
    fn frozen_corner_flip_probability_is_exactly_zero() {
        let p = RetentionParams::frozen();
        // A century of uptime: 2t/τ underflows exp to exactly 1.0,
        // so the probability is exactly 0.0 — the certainty the
        // gain-drift differential test requires.
        let century_ns = 3.15e18;
        assert_eq!(p.flip_probability(century_ns), 0.0);
        // And corrupt_codes is a strict no-op (no RNG draws).
        let mut rng = Rng::new(5);
        let mut codes = vec![2u8; 256];
        assert_eq!(corrupt_codes(&mut codes, century_ns, &p, &mut rng), 0);
        assert_eq!(rng.f64(), Rng::new(5).f64());
    }
}

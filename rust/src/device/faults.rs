//! Seeded fault-injection plans for deployed arrays (DESIGN.md S19).
//!
//! The retention/write/endurance models (this directory) describe what
//! *can* go wrong; this module is the runtime that makes it happen to a
//! live [`Crossbar`] on a simulated wall-clock. Three fault classes,
//! matching the wafer-scale SOT-MRAM characterization literature:
//!
//! * **Retention drift** — junction states relax toward thermal
//!   equilibrium per [`RetentionParams::flip_probability`]. Drift flips
//!   *states*, not device geometry: conductances stay on their level
//!   targets, so a drifted array still passes `uniform_levels()` and
//!   the quantized engine remains eligible (the codes are wrong, not
//!   non-uniform).
//! * **Stuck-at cells** — a seeded fraction of cells pinned at an
//!   extreme code (half G_AP = code 0, half G_P = code 3) at deploy
//!   time. Pins survive drift *and* scrubbing: every mutation re-pins.
//! * **Die-to-die variation** — a one-shot lognormal-ish scale on every
//!   junction's R_P at deploy. This is the class that moves
//!   conductances off their level targets and forces `MvmEngine::Auto`
//!   away from the quantized level-plane engine.
//! * **Gain drift** (DESIGN.md S22) — a slow die-level multiplicative
//!   random walk on the whole array's conductance gain (thermal /
//!   read-disturb aging of the analog path). The stored codes stay
//!   *correct*, so a verify-and-rewrite scrub is a bitwise no-op
//!   against it; only per-layer λ recalibration
//!   (`SpikingMlp::recalibrate`) restores accuracy. Die-level rather
//!   than per-cell by design: a uniform gain factor is exactly what a
//!   per-layer threshold reset corrects.
//!
//! Everything is deterministic under `FaultPlan::seed`: each macro gets
//! a [`FaultState`] with two decoupled RNG streams — one for drift, one
//! for scrub-write stochasticity — so arms of an experiment that share
//! a plan see *identical* flip sequences whether or not they scrub.

use crate::device::retention::RetentionParams;
use crate::device::write::SotWriteParams;
use crate::util::rng::Rng;
use crate::xbar::Crossbar;

/// What goes wrong, and how fast. `Copy` so configs can embed it.
#[derive(Debug, Clone, Copy)]
pub struct FaultPlan {
    /// Master seed; per-macro streams are forked from it.
    pub seed: u64,
    /// Retention corner driving the drift schedule.
    pub retention: RetentionParams,
    /// Fraction of cells stuck at an extreme code from deploy time.
    pub stuck_frac: f64,
    /// Extra die-to-die sigma on junction R_P frozen in at deploy
    /// (breaks `uniform_levels`, disqualifying the quantized engine).
    pub d2d_sigma: f64,
    /// Gain-drift volatility (DESIGN.md S22): per-√hour sigma of the
    /// die-level multiplicative conductance-gain random walk applied
    /// by [`FaultState::advance`]. 0 disables the walk *and* its RNG
    /// draws, so plans predating the gain mode keep bit-identical
    /// drift streams.
    pub gain_sigma: f64,
}

impl FaultPlan {
    /// Healthy silicon: standard retention, no stuck cells, no extra
    /// variation. Drift at Δ = 60 is negligible over any sane uptime.
    pub fn none(seed: u64) -> Self {
        FaultPlan {
            seed,
            retention: RetentionParams::standard(),
            stuck_frac: 0.0,
            d2d_sigma: 0.0,
            gain_sigma: 0.0,
        }
    }

    /// Pure retention drift at the given corner — the scrubbable fault
    /// class (EX4's subject).
    pub fn drift_only(retention: RetentionParams, seed: u64) -> Self {
        FaultPlan {
            seed,
            retention,
            stuck_frac: 0.0,
            d2d_sigma: 0.0,
            gain_sigma: 0.0,
        }
    }

    /// Pure gain drift on a retention-frozen array (DESIGN.md S22):
    /// codes never flip (Δ = 200 ⇒ flip probability exactly 0), only
    /// the analog gain wanders. Scrub is provably a no-op here —
    /// recalibration is the only corrective tool that works.
    pub fn gain_only(gain_sigma: f64, seed: u64) -> Self {
        FaultPlan {
            seed,
            retention: RetentionParams::frozen(),
            stuck_frac: 0.0,
            d2d_sigma: 0.0,
            gain_sigma,
        }
    }

    /// Mission profile (EX6): retention drift at the given corner
    /// *plus* gain wander — the regime where scrub and recalibration
    /// each fix a fault class the other cannot.
    pub fn mission(
        retention: RetentionParams,
        gain_sigma: f64,
        seed: u64,
    ) -> Self {
        FaultPlan {
            seed,
            retention,
            stuck_frac: 0.0,
            d2d_sigma: 0.0,
            gain_sigma,
        }
    }

    /// Everything at once: stress-corner drift, 0.2 % stuck cells, 3 %
    /// die-to-die R_P spread. The differential engine tests run here.
    pub fn harsh(seed: u64) -> Self {
        FaultPlan {
            seed,
            retention: RetentionParams::stress(),
            stuck_frac: 0.002,
            d2d_sigma: 0.03,
            gain_sigma: 0.0,
        }
    }
}

/// Tally of one scrub pass over an array (or the sum over many).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ScrubOutcome {
    /// Cells compared against the golden snapshot.
    pub checked: usize,
    /// Cells whose stored code disagreed with golden (flips detected).
    pub mismatched: usize,
    /// Cells whose code matches golden after rewriting (stuck cells
    /// stay mismatched: detected but not repairable).
    pub repaired: usize,
    /// SOT write pulses issued (wear, via `Mtj::writes`).
    pub junction_pulses: u64,
    /// Write energy dissipated (fJ), I²·R·t per pulse.
    pub energy_fj: f64,
}

impl ScrubOutcome {
    /// Fold another pass into this tally (multi-macro aggregation).
    pub fn absorb(&mut self, other: &ScrubOutcome) {
        self.checked += other.checked;
        self.mismatched += other.mismatched;
        self.repaired += other.repaired;
        self.junction_pulses += other.junction_pulses;
        self.energy_fj += other.energy_fj;
    }
}

/// Per-macro fault-injection state: the plan, this macro's RNG streams,
/// its stuck-cell pin list, and the simulated clock.
#[derive(Debug, Clone)]
pub struct FaultState {
    plan: FaultPlan,
    /// Drift stream — advanced only by [`advance`](Self::advance), so
    /// scrubbing never perturbs the flip sequence.
    drift_rng: Rng,
    /// Write stream for scrub pulses (overdrive writes are
    /// deterministic anyway, but `apply_write` still draws).
    scrub_rng: Rng,
    /// Linear cell index → pinned code.
    stuck: Vec<(usize, u8)>,
    /// Simulated uptime accumulated through `advance` (ns).
    pub now_ns: f64,
    /// Cells changed by drift so far (re-flips counted each time).
    pub flips_injected: u64,
    /// Cumulative die-level gain factor applied so far (1.0 = nominal;
    /// only moves when `plan.gain_sigma > 0`).
    pub gain: f64,
}

impl FaultState {
    /// Deterministic state for macro number `index` under `plan`.
    pub fn new(plan: FaultPlan, index: u64) -> Self {
        let mut root =
            Rng::new(plan.seed ^ 0x9e37_79b9_7f4a_7c15u64.wrapping_mul(index.wrapping_add(1)));
        let drift_rng = root.fork();
        let scrub_rng = root.fork();
        FaultState {
            plan,
            drift_rng,
            scrub_rng,
            stuck: Vec::new(),
            now_ns: 0.0,
            flips_injected: 0,
            gain: 1.0,
        }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Number of pinned (stuck-at) cells after deploy.
    pub fn stuck_cells(&self) -> usize {
        self.stuck.len()
    }

    /// Apply deploy-time faults to `xbar`: freeze die-to-die variation
    /// into the junction resistances and sample + pin the stuck-at set.
    /// Returns the number of stuck cells.
    pub fn deploy(&mut self, xbar: &mut Crossbar) -> usize {
        if self.plan.d2d_sigma > 0.0 {
            xbar.inject_gain_variation(self.plan.d2d_sigma, &mut self.drift_rng);
        }
        self.stuck.clear();
        if self.plan.stuck_frac > 0.0 {
            for i in 0..xbar.rows * xbar.cols {
                if self.drift_rng.f64() < self.plan.stuck_frac {
                    let code = if self.drift_rng.f64() < 0.5 { 0 } else { 3 };
                    self.stuck.push((i, code));
                }
            }
            xbar.force_codes(&self.stuck);
        }
        self.stuck.len()
    }

    /// Advance the simulated clock by `dt_ns`: retention flips land on
    /// `xbar` (no wear — Néel relaxation is not a write), stuck cells
    /// are re-pinned, and — when the plan has a gain mode — the
    /// die-level conductance gain takes one √dt-scaled random-walk
    /// step. The walk draws from the drift stream only when
    /// `gain_sigma > 0`, so gainless plans stay bit-identical to
    /// pre-S22 runs. Returns cells whose code changed.
    pub fn advance(&mut self, xbar: &mut Crossbar, dt_ns: f64) -> usize {
        self.now_ns += dt_ns;
        let flipped =
            xbar.corrupt_retention(dt_ns, &self.plan.retention, &mut self.drift_rng);
        if !self.stuck.is_empty() {
            xbar.force_codes(&self.stuck);
        }
        if self.plan.gain_sigma > 0.0 && dt_ns > 0.0 {
            // Brownian gain wander: step sigma scales with √(dt in
            // hours), clamped so one pathological draw cannot zero or
            // explode the array.
            let hours = dt_ns / 3.6e12;
            let step = self.plan.gain_sigma * hours.sqrt();
            let factor =
                (1.0 + self.drift_rng.normal_ms(0.0, step)).clamp(0.25, 4.0);
            // Gain up ⇒ resistance down: scale_gain takes an R scale.
            xbar.scale_gain(1.0 / factor);
            self.gain *= factor;
        }
        self.flips_injected += flipped as u64;
        flipped
    }

    /// Verify-and-rewrite `xbar` against a golden code snapshot, then
    /// re-pin stuck cells (their rewrites do not stick, and they are
    /// subtracted back out of `repaired`).
    pub fn scrub(
        &mut self,
        xbar: &mut Crossbar,
        golden: &[u8],
        wp: &SotWriteParams,
    ) -> ScrubOutcome {
        let mut out = xbar.scrub_to(golden, wp, &mut self.scrub_rng);
        if !self.stuck.is_empty() {
            let repinned = xbar.force_codes(&self.stuck);
            out.repaired = out.repaired.saturating_sub(repinned);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MacroConfig;

    fn small() -> MacroConfig {
        MacroConfig {
            rows: 16,
            cols: 16,
            ..MacroConfig::default()
        }
    }

    fn programmed(cfg: &MacroConfig) -> Crossbar {
        let mut xb = Crossbar::new(cfg);
        let codes: Vec<u8> =
            (0..cfg.rows * cfg.cols).map(|i| (i % 4) as u8).collect();
        xb.program_codes(&codes);
        xb
    }

    #[test]
    fn drift_is_deterministic_per_seed_and_index() {
        let cfg = small();
        let plan = FaultPlan::drift_only(RetentionParams::stress(), 42);
        let (mut a, mut b) = (programmed(&cfg), programmed(&cfg));
        let mut fa = FaultState::new(plan, 3);
        let mut fb = FaultState::new(plan, 3);
        let tau = plan.retention.tau_ret_ns();
        assert_eq!(fa.advance(&mut a, tau), fb.advance(&mut b, tau));
        assert_eq!(a.codes(), b.codes());
        // A different macro index draws a different flip pattern.
        let mut c = programmed(&cfg);
        let mut fc = FaultState::new(plan, 4);
        fc.advance(&mut c, tau);
        assert_ne!(a.codes(), c.codes());
    }

    #[test]
    fn drift_keeps_levels_uniform_but_d2d_breaks_them() {
        let cfg = small();
        let mut drifted = programmed(&cfg);
        let plan = FaultPlan::drift_only(RetentionParams::stress(), 7);
        let mut fs = FaultState::new(plan, 0);
        let flips = fs.advance(&mut drifted, plan.retention.tau_ret_ns());
        assert!(flips > 0, "stress corner at t=τ must flip something");
        assert!(drifted.uniform_levels(), "drift moves codes, not levels");

        let mut varied = programmed(&cfg);
        let mut fv = FaultState::new(FaultPlan::harsh(7), 0);
        fv.deploy(&mut varied);
        assert!(!varied.uniform_levels(), "d2d variation must break levels");
    }

    #[test]
    fn stuck_cells_survive_drift_and_scrub() {
        let cfg = small();
        let mut xb = programmed(&cfg);
        let golden = xb.read_codes();
        let plan = FaultPlan {
            stuck_frac: 0.1,
            d2d_sigma: 0.0,
            ..FaultPlan::harsh(9)
        };
        let mut fs = FaultState::new(plan, 1);
        let stuck = fs.deploy(&mut xb);
        assert!(stuck > 0, "10 % of 256 cells must pin at least one");
        fs.advance(&mut xb, plan.retention.tau_ret_ns());
        let out = fs.scrub(&mut xb, &golden, &SotWriteParams::default());
        assert_eq!(out.checked, 256);
        assert!(out.mismatched > 0);
        // Every non-stuck cell is back on golden; stuck pins remain.
        let now = xb.read_codes();
        let stuck_set: Vec<usize> =
            (0..256).filter(|i| now[*i] != golden[*i]).collect();
        assert!(stuck_set.len() <= stuck);
        assert!(out.repaired >= out.mismatched.saturating_sub(stuck));
    }

    #[test]
    fn scrub_does_not_perturb_the_drift_stream() {
        // Two arms, same plan: one scrubs between drift steps, one
        // does not. The *drift* flip sequences must stay identical.
        let cfg = small();
        let plan = FaultPlan::drift_only(RetentionParams::stress(), 17);
        let wp = SotWriteParams::default();
        let (mut a, mut b) = (programmed(&cfg), programmed(&cfg));
        let golden = a.read_codes();
        let mut fa = FaultState::new(plan, 0);
        let mut fb = FaultState::new(plan, 0);
        let dt = plan.retention.tau_ret_ns() * 0.3;
        for _ in 0..3 {
            let na = fa.advance(&mut a, dt);
            let nb = fb.advance(&mut b, dt);
            assert_eq!(na, nb, "scrubbing must not desync drift");
            fb.scrub(&mut b, &golden, &wp);
        }
        assert_eq!(fa.flips_injected, fb.flips_injected);
        assert_eq!(b.read_codes(), golden, "arm b ends fully scrubbed");
    }

    #[test]
    fn gain_drift_moves_levels_not_codes() {
        let cfg = small();
        let mut xb = programmed(&cfg);
        let golden = xb.read_codes();
        let g_before = xb.conductances().to_vec();
        let plan = FaultPlan::gain_only(0.05, 21);
        let mut fs = FaultState::new(plan, 0);
        // One simulated hour per tick: the frozen retention corner
        // guarantees zero flips, only the gain walks.
        for _ in 0..4 {
            assert_eq!(fs.advance(&mut xb, 3.6e12), 0, "frozen corner");
        }
        assert_eq!(xb.read_codes(), golden, "codes untouched");
        assert_ne!(fs.gain, 1.0, "the walk must have moved");
        assert!(!xb.uniform_levels(), "analog levels left their targets");
        let drift: f64 = xb
            .conductances()
            .iter()
            .zip(&g_before)
            .map(|(a, b)| (a / b - fs.gain).abs())
            .fold(0.0, f64::max);
        assert!(drift < 1e-9, "uniform die-level factor, off by {drift}");
    }

    #[test]
    fn gain_drift_is_deterministic_and_gainless_plans_draw_nothing() {
        let cfg = small();
        let plan = FaultPlan::gain_only(0.08, 33);
        let (mut a, mut b) = (programmed(&cfg), programmed(&cfg));
        let mut fa = FaultState::new(plan, 2);
        let mut fb = FaultState::new(plan, 2);
        for _ in 0..3 {
            fa.advance(&mut a, 1.8e12);
            fb.advance(&mut b, 1.8e12);
        }
        assert_eq!(fa.gain, fb.gain, "same plan + index → same walk");
        assert_eq!(a.conductances(), b.conductances());

        // gain_sigma = 0 must not consume the drift stream: a stress
        // drift run is bit-identical whether or not the field exists.
        let p0 = FaultPlan::drift_only(RetentionParams::stress(), 17);
        let (mut c, mut d) = (programmed(&cfg), programmed(&cfg));
        let mut fc = FaultState::new(p0, 0);
        let mut fd = FaultState::new(p0, 0);
        let dt = p0.retention.tau_ret_ns() * 0.2;
        assert_eq!(fc.advance(&mut c, dt), fd.advance(&mut d, dt));
        assert_eq!(fc.gain, 1.0);
        assert_eq!(c.codes(), d.codes());
    }

    #[test]
    fn scrub_is_a_bitwise_noop_under_pure_gain_drift() {
        let cfg = small();
        let mut xb = programmed(&cfg);
        let golden = xb.read_codes();
        let wear_before = xb.write_pulses;
        let plan = FaultPlan::gain_only(0.1, 55);
        let mut fs = FaultState::new(plan, 0);
        fs.advance(&mut xb, 7.2e12);
        let out = fs.scrub(&mut xb, &golden, &SotWriteParams::default());
        // The codes were never wrong: nothing to detect, nothing to
        // rewrite, zero wear, zero energy — and the gain error is
        // still there afterwards.
        assert_eq!(out.mismatched, 0);
        assert_eq!(out.junction_pulses, 0);
        assert_eq!(out.energy_fj, 0.0);
        assert_eq!(xb.write_pulses, wear_before);
        assert!(!xb.uniform_levels(), "scrub cannot fix analog gain");
    }
}

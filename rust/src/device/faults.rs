//! Seeded fault-injection plans for deployed arrays (DESIGN.md S19).
//!
//! The retention/write/endurance models (this directory) describe what
//! *can* go wrong; this module is the runtime that makes it happen to a
//! live [`Crossbar`] on a simulated wall-clock. Three fault classes,
//! matching the wafer-scale SOT-MRAM characterization literature:
//!
//! * **Retention drift** — junction states relax toward thermal
//!   equilibrium per [`RetentionParams::flip_probability`]. Drift flips
//!   *states*, not device geometry: conductances stay on their level
//!   targets, so a drifted array still passes `uniform_levels()` and
//!   the quantized engine remains eligible (the codes are wrong, not
//!   non-uniform).
//! * **Stuck-at cells** — a seeded fraction of cells pinned at an
//!   extreme code (half G_AP = code 0, half G_P = code 3) at deploy
//!   time. Pins survive drift *and* scrubbing: every mutation re-pins.
//! * **Die-to-die variation** — a one-shot lognormal-ish scale on every
//!   junction's R_P at deploy. This is the class that moves
//!   conductances off their level targets and forces `MvmEngine::Auto`
//!   away from the quantized level-plane engine.
//!
//! Everything is deterministic under `FaultPlan::seed`: each macro gets
//! a [`FaultState`] with two decoupled RNG streams — one for drift, one
//! for scrub-write stochasticity — so arms of an experiment that share
//! a plan see *identical* flip sequences whether or not they scrub.

use crate::device::retention::RetentionParams;
use crate::device::write::SotWriteParams;
use crate::util::rng::Rng;
use crate::xbar::Crossbar;

/// What goes wrong, and how fast. `Copy` so configs can embed it.
#[derive(Debug, Clone, Copy)]
pub struct FaultPlan {
    /// Master seed; per-macro streams are forked from it.
    pub seed: u64,
    /// Retention corner driving the drift schedule.
    pub retention: RetentionParams,
    /// Fraction of cells stuck at an extreme code from deploy time.
    pub stuck_frac: f64,
    /// Extra die-to-die sigma on junction R_P frozen in at deploy
    /// (breaks `uniform_levels`, disqualifying the quantized engine).
    pub d2d_sigma: f64,
}

impl FaultPlan {
    /// Healthy silicon: standard retention, no stuck cells, no extra
    /// variation. Drift at Δ = 60 is negligible over any sane uptime.
    pub fn none(seed: u64) -> Self {
        FaultPlan {
            seed,
            retention: RetentionParams::standard(),
            stuck_frac: 0.0,
            d2d_sigma: 0.0,
        }
    }

    /// Pure retention drift at the given corner — the scrubbable fault
    /// class (EX4's subject).
    pub fn drift_only(retention: RetentionParams, seed: u64) -> Self {
        FaultPlan {
            seed,
            retention,
            stuck_frac: 0.0,
            d2d_sigma: 0.0,
        }
    }

    /// Everything at once: stress-corner drift, 0.2 % stuck cells, 3 %
    /// die-to-die R_P spread. The differential engine tests run here.
    pub fn harsh(seed: u64) -> Self {
        FaultPlan {
            seed,
            retention: RetentionParams::stress(),
            stuck_frac: 0.002,
            d2d_sigma: 0.03,
        }
    }
}

/// Tally of one scrub pass over an array (or the sum over many).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ScrubOutcome {
    /// Cells compared against the golden snapshot.
    pub checked: usize,
    /// Cells whose stored code disagreed with golden (flips detected).
    pub mismatched: usize,
    /// Cells whose code matches golden after rewriting (stuck cells
    /// stay mismatched: detected but not repairable).
    pub repaired: usize,
    /// SOT write pulses issued (wear, via `Mtj::writes`).
    pub junction_pulses: u64,
    /// Write energy dissipated (fJ), I²·R·t per pulse.
    pub energy_fj: f64,
}

impl ScrubOutcome {
    /// Fold another pass into this tally (multi-macro aggregation).
    pub fn absorb(&mut self, other: &ScrubOutcome) {
        self.checked += other.checked;
        self.mismatched += other.mismatched;
        self.repaired += other.repaired;
        self.junction_pulses += other.junction_pulses;
        self.energy_fj += other.energy_fj;
    }
}

/// Per-macro fault-injection state: the plan, this macro's RNG streams,
/// its stuck-cell pin list, and the simulated clock.
#[derive(Debug, Clone)]
pub struct FaultState {
    plan: FaultPlan,
    /// Drift stream — advanced only by [`advance`](Self::advance), so
    /// scrubbing never perturbs the flip sequence.
    drift_rng: Rng,
    /// Write stream for scrub pulses (overdrive writes are
    /// deterministic anyway, but `apply_write` still draws).
    scrub_rng: Rng,
    /// Linear cell index → pinned code.
    stuck: Vec<(usize, u8)>,
    /// Simulated uptime accumulated through `advance` (ns).
    pub now_ns: f64,
    /// Cells changed by drift so far (re-flips counted each time).
    pub flips_injected: u64,
}

impl FaultState {
    /// Deterministic state for macro number `index` under `plan`.
    pub fn new(plan: FaultPlan, index: u64) -> Self {
        let mut root =
            Rng::new(plan.seed ^ 0x9e37_79b9_7f4a_7c15u64.wrapping_mul(index.wrapping_add(1)));
        let drift_rng = root.fork();
        let scrub_rng = root.fork();
        FaultState {
            plan,
            drift_rng,
            scrub_rng,
            stuck: Vec::new(),
            now_ns: 0.0,
            flips_injected: 0,
        }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Number of pinned (stuck-at) cells after deploy.
    pub fn stuck_cells(&self) -> usize {
        self.stuck.len()
    }

    /// Apply deploy-time faults to `xbar`: freeze die-to-die variation
    /// into the junction resistances and sample + pin the stuck-at set.
    /// Returns the number of stuck cells.
    pub fn deploy(&mut self, xbar: &mut Crossbar) -> usize {
        if self.plan.d2d_sigma > 0.0 {
            xbar.inject_gain_variation(self.plan.d2d_sigma, &mut self.drift_rng);
        }
        self.stuck.clear();
        if self.plan.stuck_frac > 0.0 {
            for i in 0..xbar.rows * xbar.cols {
                if self.drift_rng.f64() < self.plan.stuck_frac {
                    let code = if self.drift_rng.f64() < 0.5 { 0 } else { 3 };
                    self.stuck.push((i, code));
                }
            }
            xbar.force_codes(&self.stuck);
        }
        self.stuck.len()
    }

    /// Advance the simulated clock by `dt_ns`: retention flips land on
    /// `xbar` (no wear — Néel relaxation is not a write) and stuck
    /// cells are re-pinned. Returns cells whose code changed.
    pub fn advance(&mut self, xbar: &mut Crossbar, dt_ns: f64) -> usize {
        self.now_ns += dt_ns;
        let flipped =
            xbar.corrupt_retention(dt_ns, &self.plan.retention, &mut self.drift_rng);
        if !self.stuck.is_empty() {
            xbar.force_codes(&self.stuck);
        }
        self.flips_injected += flipped as u64;
        flipped
    }

    /// Verify-and-rewrite `xbar` against a golden code snapshot, then
    /// re-pin stuck cells (their rewrites do not stick, and they are
    /// subtracted back out of `repaired`).
    pub fn scrub(
        &mut self,
        xbar: &mut Crossbar,
        golden: &[u8],
        wp: &SotWriteParams,
    ) -> ScrubOutcome {
        let mut out = xbar.scrub_to(golden, wp, &mut self.scrub_rng);
        if !self.stuck.is_empty() {
            let repinned = xbar.force_codes(&self.stuck);
            out.repaired = out.repaired.saturating_sub(repinned);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MacroConfig;

    fn small() -> MacroConfig {
        MacroConfig {
            rows: 16,
            cols: 16,
            ..MacroConfig::default()
        }
    }

    fn programmed(cfg: &MacroConfig) -> Crossbar {
        let mut xb = Crossbar::new(cfg);
        let codes: Vec<u8> =
            (0..cfg.rows * cfg.cols).map(|i| (i % 4) as u8).collect();
        xb.program_codes(&codes);
        xb
    }

    #[test]
    fn drift_is_deterministic_per_seed_and_index() {
        let cfg = small();
        let plan = FaultPlan::drift_only(RetentionParams::stress(), 42);
        let (mut a, mut b) = (programmed(&cfg), programmed(&cfg));
        let mut fa = FaultState::new(plan, 3);
        let mut fb = FaultState::new(plan, 3);
        let tau = plan.retention.tau_ret_ns();
        assert_eq!(fa.advance(&mut a, tau), fb.advance(&mut b, tau));
        assert_eq!(a.codes(), b.codes());
        // A different macro index draws a different flip pattern.
        let mut c = programmed(&cfg);
        let mut fc = FaultState::new(plan, 4);
        fc.advance(&mut c, tau);
        assert_ne!(a.codes(), c.codes());
    }

    #[test]
    fn drift_keeps_levels_uniform_but_d2d_breaks_them() {
        let cfg = small();
        let mut drifted = programmed(&cfg);
        let plan = FaultPlan::drift_only(RetentionParams::stress(), 7);
        let mut fs = FaultState::new(plan, 0);
        let flips = fs.advance(&mut drifted, plan.retention.tau_ret_ns());
        assert!(flips > 0, "stress corner at t=τ must flip something");
        assert!(drifted.uniform_levels(), "drift moves codes, not levels");

        let mut varied = programmed(&cfg);
        let mut fv = FaultState::new(FaultPlan::harsh(7), 0);
        fv.deploy(&mut varied);
        assert!(!varied.uniform_levels(), "d2d variation must break levels");
    }

    #[test]
    fn stuck_cells_survive_drift_and_scrub() {
        let cfg = small();
        let mut xb = programmed(&cfg);
        let golden = xb.read_codes();
        let plan = FaultPlan {
            stuck_frac: 0.1,
            d2d_sigma: 0.0,
            ..FaultPlan::harsh(9)
        };
        let mut fs = FaultState::new(plan, 1);
        let stuck = fs.deploy(&mut xb);
        assert!(stuck > 0, "10 % of 256 cells must pin at least one");
        fs.advance(&mut xb, plan.retention.tau_ret_ns());
        let out = fs.scrub(&mut xb, &golden, &SotWriteParams::default());
        assert_eq!(out.checked, 256);
        assert!(out.mismatched > 0);
        // Every non-stuck cell is back on golden; stuck pins remain.
        let now = xb.read_codes();
        let stuck_set: Vec<usize> =
            (0..256).filter(|i| now[*i] != golden[*i]).collect();
        assert!(stuck_set.len() <= stuck);
        assert!(out.repaired >= out.mismatched.saturating_sub(stuck));
    }

    #[test]
    fn scrub_does_not_perturb_the_drift_stream() {
        // Two arms, same plan: one scrubs between drift steps, one
        // does not. The *drift* flip sequences must stay identical.
        let cfg = small();
        let plan = FaultPlan::drift_only(RetentionParams::stress(), 17);
        let wp = SotWriteParams::default();
        let (mut a, mut b) = (programmed(&cfg), programmed(&cfg));
        let golden = a.read_codes();
        let mut fa = FaultState::new(plan, 0);
        let mut fb = FaultState::new(plan, 0);
        let dt = plan.retention.tau_ret_ns() * 0.3;
        for _ in 0..3 {
            let na = fa.advance(&mut a, dt);
            let nb = fb.advance(&mut b, dt);
            assert_eq!(na, nb, "scrubbing must not desync drift");
            fb.scrub(&mut b, &golden, &wp);
        }
        assert_eq!(fa.flips_injected, fb.flips_injected);
        assert_eq!(b.read_codes(), golden, "arm b ends fully scrubbed");
    }
}

//! # spikemram — event-driven spiking CIM macro on SOT-MRAM
//!
//! Full-stack reproduction of *"An Event-Driven Spiking Compute-In-Memory
//! Macro based on SOT-MRAM"* (Yu et al., 2025): a behavioral 28 nm macro
//! simulator (devices → circuits → macro), an event-driven coordinator
//! that tiles DNN workloads onto macros, an energy model calibrated to the
//! paper's aggregates, baseline readout schemes for the comparison tables,
//! and a PJRT runtime executing the AOT-compiled JAX/Pallas functional
//! model (HLO text artifacts, python never on the request path).
//!
//! Layer map (DESIGN.md §3):
//! * L3 (this crate): [`coordinator`], [`fabric`], [`macro_model`],
//!   substrates.
//! * L2/L1 (build time): `python/compile/{model.py,kernels/}` → `artifacts/`.
//! * Bridge: [`runtime`] executes the HLO artifacts — via the `xla` crate
//!   when built with the `pjrt` cargo feature, or through the hermetic
//!   pure-Rust [`runtime::interp`] backend by default (DESIGN.md S12).

// The numeric substrate intentionally walks parallel arrays by index (the
// event loop updates several column vectors in lockstep) and mirrors
// serde_json's `to_string` naming in the offline JSON substrate; silencing
// the corresponding style lints beats contorting the hot paths.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::inherent_to_string)]
#![allow(clippy::type_complexity)]
#![allow(clippy::needless_lifetimes)]
#![allow(clippy::derivable_impls)]

pub mod baselines;
pub mod benchlib;
pub mod circuit;
pub mod coding;
pub mod config;
pub mod coordinator;
pub mod device;
pub mod energy;
pub mod event;
pub mod fabric;
pub mod macro_model;
pub mod net;
pub mod obs;
pub mod repro;
pub mod runtime;
pub mod snn;
pub mod stream;
pub mod testkit;
pub mod util;
pub mod xbar;

//! # spikemram — event-driven spiking CIM macro on SOT-MRAM
//!
//! Full-stack reproduction of *"An Event-Driven Spiking Compute-In-Memory
//! Macro based on SOT-MRAM"* (Yu et al., 2025): a behavioral 28 nm macro
//! simulator (devices → circuits → macro), an event-driven coordinator
//! that tiles DNN workloads onto macros, an energy model calibrated to the
//! paper's aggregates, baseline readout schemes for the comparison tables,
//! and a PJRT runtime executing the AOT-compiled JAX/Pallas functional
//! model (HLO text artifacts, python never on the request path).
//!
//! Layer map (DESIGN.md §3):
//! * L3 (this crate): [`coordinator`], [`macro_model`], substrates.
//! * L2/L1 (build time): `python/compile/{model.py,kernels/}` → `artifacts/`.
//! * Bridge: [`runtime`] loads the HLO artifacts via the `xla` crate.

pub mod baselines;
pub mod benchlib;
pub mod circuit;
pub mod coding;
pub mod config;
pub mod coordinator;
pub mod device;
pub mod energy;
pub mod event;
pub mod macro_model;
pub mod repro;
pub mod runtime;
pub mod snn;
pub mod testkit;
pub mod util;
pub mod xbar;

//! Chrome/Perfetto `trace_event` exporter (DESIGN.md S20): turns a
//! drained [`TraceReport`] into the JSON object format
//! (`{"traceEvents": [...]}`) that `chrome://tracing` and
//! <https://ui.perfetto.dev> load directly. Written with the vendored
//! [`util::json`](crate::util::json) writer and round-trip-validated
//! with its parser before it ever lands on disk.
//!
//! Mapping: pid 1 = the chip, tid = recording worker (named via
//! `thread_name` metadata), span kinds become complete (`ph:"X"`)
//! events with `ts`/`dur` in µs, counter kinds become `ph:"C"` series.

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use super::{TraceEvent, TraceReport};
use crate::util::json::{self, Json};

/// Perfetto process id for the (single) simulated chip.
const PID: f64 = 1.0;

/// Build the full Chrome `trace_event` JSON object for a report.
pub fn chrome_trace(report: &TraceReport) -> Json {
    let mut evs: Vec<Json> =
        Vec::with_capacity(report.events.len() + report.threads.len() + 1);
    evs.push(json::obj(vec![
        ("ph", Json::Str("M".into())),
        ("name", Json::Str("process_name".into())),
        ("pid", Json::Num(PID)),
        (
            "args",
            json::obj(vec![("name", Json::Str("spikemram-chip".into()))]),
        ),
    ]));
    for (tid, name) in report.threads.iter().enumerate() {
        evs.push(json::obj(vec![
            ("ph", Json::Str("M".into())),
            ("name", Json::Str("thread_name".into())),
            ("pid", Json::Num(PID)),
            ("tid", Json::Num(tid as f64)),
            ("args", json::obj(vec![("name", Json::Str(name.clone()))])),
        ]));
    }
    evs.extend(report.events.iter().map(event_json));
    json::obj(vec![
        ("traceEvents", Json::Arr(evs)),
        ("displayTimeUnit", Json::Str("ms".into())),
        (
            "otherData",
            json::obj(vec![
                ("producer", Json::Str("spikemram obs".into())),
                ("dropped", Json::Num(report.dropped as f64)),
            ]),
        ),
    ])
}

fn event_json(e: &TraceEvent) -> Json {
    let ts_us = e.ts_ns as f64 / 1e3;
    if e.kind.is_counter() {
        return json::obj(vec![
            ("ph", Json::Str("C".into())),
            ("name", Json::Str(e.kind.name().into())),
            ("pid", Json::Num(PID)),
            ("tid", Json::Num(f64::from(e.worker))),
            ("ts", Json::Num(ts_us)),
            (
                "args",
                json::obj(vec![("value", Json::Num(e.payload[0]))]),
            ),
        ]);
    }
    let (p0, p1) = e.kind.payload_names();
    json::obj(vec![
        ("ph", Json::Str("X".into())),
        ("name", Json::Str(e.kind.name().into())),
        ("cat", Json::Str(e.kind.name().into())),
        ("pid", Json::Num(PID)),
        ("tid", Json::Num(f64::from(e.worker))),
        ("ts", Json::Num(ts_us)),
        ("dur", Json::Num(e.dur_ns as f64 / 1e3)),
        (
            "args",
            json::obj(vec![
                ("stage", Json::Num(f64::from(e.stage))),
                (p0, Json::Num(e.payload[0])),
                (p1, Json::Num(e.payload[1])),
            ]),
        ),
    ])
}

/// Serialize `report` to `path` (parent directories created), gated by
/// a parse round-trip of the exact bytes written — a trace that the
/// vendored reader cannot load back is a hard error, never a silent
/// artifact (ci.sh smoke + ISSUE 7 acceptance bar).
pub fn write_chrome_trace(
    path: &Path,
    report: &TraceReport,
) -> Result<PathBuf> {
    let text = chrome_trace(report).to_string();
    json::parse(&text)
        .map_err(|e| anyhow!("trace JSON failed round-trip parse: {e}"))?;
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            fs::create_dir_all(dir)
                .with_context(|| format!("create {}", dir.display()))?;
        }
    }
    fs::write(path, &text)
        .with_context(|| format!("write {}", path.display()))?;
    Ok(path.to_path_buf())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::TraceKind;

    fn sample_report() -> TraceReport {
        TraceReport {
            events: vec![
                TraceEvent {
                    ts_ns: 1_000,
                    dur_ns: 2_500,
                    kind: TraceKind::MacroMvm,
                    stage: 0,
                    worker: 0,
                    payload: [17.0, 2.0],
                },
                TraceEvent {
                    ts_ns: 4_000,
                    dur_ns: 0,
                    kind: TraceKind::QueueDepth,
                    stage: 0,
                    worker: 1,
                    payload: [3.0, 0.0],
                },
            ],
            dropped: 5,
            threads: vec!["main".into(), "spikemram-pool-0".into()],
        }
    }

    #[test]
    fn chrome_trace_round_trips_through_vendored_parser() {
        let j = chrome_trace(&sample_report());
        let back = json::parse(&j.to_string()).expect("round trip");
        let evs = back
            .get("traceEvents")
            .and_then(Json::as_arr)
            .expect("traceEvents array");
        // 1 process_name + 2 thread_name + 2 events.
        assert_eq!(evs.len(), 5);
        let span = &evs[3];
        assert_eq!(
            span.get("ph").and_then(Json::as_str),
            Some("X"),
            "{span:?}"
        );
        assert_eq!(
            span.get("name").and_then(Json::as_str),
            Some("macro.mvm")
        );
        assert_eq!(span.get("ts").and_then(Json::as_f64), Some(1.0));
        assert_eq!(span.get("dur").and_then(Json::as_f64), Some(2.5));
        let args = span.get("args").expect("args");
        assert_eq!(
            args.get("active_rows").and_then(Json::as_f64),
            Some(17.0)
        );
        assert_eq!(args.get("engine").and_then(Json::as_f64), Some(2.0));
        let ctr = &evs[4];
        assert_eq!(ctr.get("ph").and_then(Json::as_str), Some("C"));
        assert_eq!(
            ctr.get("args").and_then(|a| a.get("value")).and_then(Json::as_f64),
            Some(3.0)
        );
        assert_eq!(
            back.get("otherData")
                .and_then(|o| o.get("dropped"))
                .and_then(Json::as_f64),
            Some(5.0)
        );
    }

    #[test]
    fn write_chrome_trace_lands_parseable_file() {
        let dir = std::env::temp_dir().join("spikemram_obs_export_test");
        let path = dir.join("trace_unit.json");
        let p = write_chrome_trace(&path, &sample_report()).expect("write");
        let text = std::fs::read_to_string(&p).expect("read back");
        json::parse(&text).expect("file parses");
        let _ = std::fs::remove_file(&p);
    }
}

//! Unified tracing & telemetry (DESIGN.md S20): per-stage spans recorded
//! into per-worker ring buffers, drained into Chrome/Perfetto
//! `trace_event` JSON by [`export`].
//!
//! Design contract (the "overhead contract", DESIGN.md §S20):
//!
//! * **Never block serving.** Each thread records into its own
//!   fixed-capacity ring behind its own `Mutex` — the lock is only ever
//!   contended by the exporter's brief drain, never by another worker.
//!   A full ring drops its *oldest* event and bumps a cumulative
//!   `dropped` counter; recording never waits for a consumer.
//! * **Near-zero cost when off.** Every record site first checks one
//!   relaxed atomic load of the enabled-kind bitmask
//!   ([`enabled`]); a disabled [`Span`] takes no timestamp, holds no
//!   payload, and its `Drop` is a no-op. The `benches/obs.rs` smoke
//!   target asserts the band (EXPERIMENTS.md §Perf).
//! * **Purely observational.** Tracing reads timestamps and counters
//!   only — it never touches RNG streams or results, so the bit-identity
//!   contracts of DESIGN.md S16–S18 hold with tracing on or off.
//!
//! Span taxonomy: one [`TraceKind`] per instrumented site — pool job
//! execute + queue-wait ([`util::pool`](crate::util::pool)), macro MVM
//! engine dispatch ([`CimMacro`](crate::macro_model::CimMacro)), NoC
//! route + 5-phase layer forward
//! ([`FabricChip`](crate::fabric::FabricChip)), per-stage stream frame
//! processing ([`stream`](crate::stream)), stream-server frame jobs,
//! and [`Scrubber`](crate::coordinator::Scrubber) passes — plus counter
//! kinds for pool queue depth, row occupancy, and modeled energy.
//!
//! Enable via [`install`] with a [`TraceConfig`]; drain with [`drain`];
//! export with [`write_chrome_trace`].

mod export;

pub use export::{chrome_trace, write_chrome_trace};

use std::cell::RefCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU32, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::config::TraceConfig;

/// One instrumented site (span kinds) or telemetry series (counter
/// kinds). The discriminant is the bit position in
/// [`TraceConfig::kinds`].
#[repr(u32)]
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum TraceKind {
    /// One pool job body (`util::pool` scope ticket or detached spawn).
    PoolExec = 0,
    /// Channel residency of a pool task: send → first poll.
    PoolWait = 1,
    /// One `CimMacro` batch through the resolved engine.
    MacroMvm = 2,
    /// One `route_flags` NoC pricing pass (ingress→egress phases).
    NocRoute = 3,
    /// One `FabricChip` layer forward (any entry point).
    LayerForward = 4,
    /// One spiking stage's timestep (`SpikingStage::step`).
    StreamStage = 5,
    /// One stream-server frame job (dequeue → reply).
    ServeFrame = 6,
    /// One scrub pass (background tick or in-worker scrub job).
    ScrubPass = 7,
    /// Counter: pool channel depth after each enqueue.
    QueueDepth = 8,
    /// Counter: per-frame active-row occupancy (0..=1).
    Occupancy = 9,
    /// Counter: per-frame modeled energy (fJ).
    EnergyFj = 10,
    /// One stream-worker restart (backoff sleep → rebuild → redeploy).
    WorkerRestart = 11,
    /// Counter: admission-control shed (queue full at enqueue).
    AdmissionShed = 12,
    /// Counter: per-worker die wear fraction of rated write cycles
    /// (0..=1), published whenever a worker's wear ledger changes
    /// (S22 endurance runtime).
    WearFraction = 13,
}

/// Number of [`TraceKind`] variants (bitmask width).
pub const KIND_COUNT: usize = 14;

impl TraceKind {
    /// Every kind, in discriminant order.
    pub const ALL: [TraceKind; KIND_COUNT] = [
        TraceKind::PoolExec,
        TraceKind::PoolWait,
        TraceKind::MacroMvm,
        TraceKind::NocRoute,
        TraceKind::LayerForward,
        TraceKind::StreamStage,
        TraceKind::ServeFrame,
        TraceKind::ScrubPass,
        TraceKind::QueueDepth,
        TraceKind::Occupancy,
        TraceKind::EnergyFj,
        TraceKind::WorkerRestart,
        TraceKind::AdmissionShed,
        TraceKind::WearFraction,
    ];

    /// This kind's bit in [`TraceConfig::kinds`].
    #[inline]
    pub fn bit(self) -> u32 {
        1 << (self as u32)
    }

    /// Dotted site name (Perfetto event/counter name and `cat`).
    pub fn name(self) -> &'static str {
        match self {
            TraceKind::PoolExec => "pool.exec",
            TraceKind::PoolWait => "pool.wait",
            TraceKind::MacroMvm => "macro.mvm",
            TraceKind::NocRoute => "noc.route",
            TraceKind::LayerForward => "fabric.layer",
            TraceKind::StreamStage => "stream.stage",
            TraceKind::ServeFrame => "serve.frame",
            TraceKind::ScrubPass => "scrub.pass",
            TraceKind::QueueDepth => "pool.queue_depth",
            TraceKind::Occupancy => "serve.occupancy",
            TraceKind::EnergyFj => "serve.energy_fj",
            TraceKind::WorkerRestart => "serve.restart",
            TraceKind::AdmissionShed => "serve.shed",
            TraceKind::WearFraction => "serve.wear",
        }
    }

    /// Counter kinds export as Perfetto `ph:"C"` series; the rest are
    /// complete (`ph:"X"`) spans.
    pub fn is_counter(self) -> bool {
        matches!(
            self,
            TraceKind::QueueDepth
                | TraceKind::Occupancy
                | TraceKind::EnergyFj
                | TraceKind::AdmissionShed
                | TraceKind::WearFraction
        )
    }

    /// Names for the two payload slots (Perfetto `args` keys).
    pub fn payload_names(self) -> (&'static str, &'static str) {
        match self {
            TraceKind::PoolExec => ("job", "jobs"),
            TraceKind::PoolWait => ("wait_us", "p1"),
            TraceKind::MacroMvm => ("active_rows", "engine"),
            TraceKind::NocRoute => ("packets", "hops"),
            TraceKind::LayerForward => ("items", "active_rows"),
            TraceKind::StreamStage => ("events_in", "spikes_out"),
            TraceKind::ServeFrame => ("queue_wait_us", "active_rows"),
            TraceKind::ScrubPass => ("round", "repaired"),
            TraceKind::WorkerRestart => ("attempt", "backoff_ms"),
            TraceKind::AdmissionShed => ("queue_depth", "p1"),
            TraceKind::WearFraction => ("wear", "p1"),
            _ => ("value", "p1"),
        }
    }
}

/// One recorded trace event. `ts_ns` is relative to the process trace
/// epoch (first [`install`]); `worker` is the recording thread's
/// registration index (the Perfetto `tid`); `stage` disambiguates
/// multi-instance sites (layer index, scrub source); `payload` carries
/// two site-specific numbers named by
/// [`TraceKind::payload_names`].
#[derive(Debug, Clone, Copy)]
pub struct TraceEvent {
    pub ts_ns: u64,
    pub dur_ns: u64,
    pub kind: TraceKind,
    pub stage: u16,
    pub worker: u32,
    pub payload: [f64; 2],
}

/// Default per-thread ring capacity (events).
pub const DEFAULT_CAPACITY: usize = 65_536;

struct Ring {
    events: VecDeque<TraceEvent>,
    capacity: usize,
    /// Cumulative drop-oldest count since the last drain.
    dropped: u64,
}

impl Ring {
    fn new(capacity: usize) -> Ring {
        Ring {
            events: VecDeque::with_capacity(capacity.min(1024)),
            capacity,
            dropped: 0,
        }
    }

    fn push(&mut self, ev: TraceEvent) {
        if self.capacity == 0 {
            self.dropped += 1;
            return;
        }
        if self.events.len() >= self.capacity {
            self.events.pop_front();
            self.dropped += 1;
        }
        self.events.push_back(ev);
    }
}

struct RegEntry {
    /// Recording thread's name at registration (Perfetto thread_name).
    name: String,
    ring: Arc<Mutex<Ring>>,
}

/// Enabled-kind bitmask — the ONE load every record site pays when
/// tracing is off.
static KINDS: AtomicU32 = AtomicU32::new(0);
static CAPACITY: AtomicUsize = AtomicUsize::new(DEFAULT_CAPACITY);
static REGISTRY: Mutex<Vec<RegEntry>> = Mutex::new(Vec::new());

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

struct LocalRing {
    worker: u32,
    ring: Arc<Mutex<Ring>>,
}

thread_local! {
    static LOCAL: RefCell<Option<LocalRing>> = const { RefCell::new(None) };
}

fn register_thread() -> LocalRing {
    let name = std::thread::current()
        .name()
        .map(str::to_string)
        .unwrap_or_else(|| "unnamed".to_string());
    let ring =
        Arc::new(Mutex::new(Ring::new(CAPACITY.load(Ordering::Relaxed))));
    let mut reg = REGISTRY.lock().expect("obs registry");
    let worker = reg.len() as u32;
    reg.push(RegEntry {
        name,
        ring: Arc::clone(&ring),
    });
    LocalRing { worker, ring }
}

/// Record into the calling thread's ring (registering it on first use).
/// Lock order: only the thread's own ring — never the registry — so a
/// concurrent [`drain`] (registry → ring) cannot deadlock with writers.
fn local_push(mut ev: TraceEvent) {
    LOCAL.with(|slot| {
        let mut slot = slot.borrow_mut();
        let local = slot.get_or_insert_with(register_thread);
        ev.worker = local.worker;
        local.ring.lock().expect("obs ring").push(ev);
    });
}

/// Install a trace configuration process-wide: sets the enabled-kind
/// mask and ring capacity, pins the trace epoch, and re-fits
/// already-registered rings to the new capacity (trimming oldest
/// first). Call before serving; [`TraceConfig::off`] disables all
/// recording again.
pub fn install(cfg: &TraceConfig) {
    CAPACITY.store(cfg.capacity, Ordering::Relaxed);
    KINDS.store(cfg.kinds, Ordering::Relaxed);
    let _ = epoch();
    for e in REGISTRY.lock().expect("obs registry").iter() {
        let mut r = e.ring.lock().expect("obs ring");
        r.capacity = cfg.capacity;
        while r.events.len() > r.capacity {
            r.events.pop_front();
            r.dropped += 1;
        }
    }
}

/// Is this kind currently recorded? One relaxed atomic load.
#[inline]
pub fn enabled(kind: TraceKind) -> bool {
    KINDS.load(Ordering::Relaxed) & kind.bit() != 0
}

/// RAII span guard: construction takes the timestamp, `Drop` records
/// the complete event. When the kind is disabled the guard is inert
/// (no timestamp, no-op `Drop`).
pub struct Span {
    kind: TraceKind,
    stage: u16,
    start: Option<Instant>,
    payload: [f64; 2],
}

impl Span {
    #[inline]
    pub fn begin(kind: TraceKind, stage: u16) -> Span {
        let start = enabled(kind).then(Instant::now);
        Span {
            kind,
            stage,
            start,
            payload: [0.0; 2],
        }
    }

    /// Attach the two payload numbers (see
    /// [`TraceKind::payload_names`]). No-op when inert.
    #[inline]
    pub fn note(&mut self, a: f64, b: f64) {
        if self.start.is_some() {
            self.payload = [a, b];
        }
    }

    /// Is this span actually recording?
    pub fn active(&self) -> bool {
        self.start.is_some()
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(t0) = self.start else { return };
        let dur_ns = t0.elapsed().as_nanos() as u64;
        let ts_ns = t0.saturating_duration_since(epoch()).as_nanos() as u64;
        local_push(TraceEvent {
            ts_ns,
            dur_ns,
            kind: self.kind,
            stage: self.stage,
            worker: 0,
            payload: self.payload,
        });
    }
}

/// Record a counter sample (`payload[0] = value`).
pub fn counter(kind: TraceKind, stage: u16, value: f64) {
    if !enabled(kind) {
        return;
    }
    local_push(TraceEvent {
        ts_ns: Instant::now()
            .saturating_duration_since(epoch())
            .as_nanos() as u64,
        dur_ns: 0,
        kind,
        stage,
        worker: 0,
        payload: [value, 0.0],
    });
}

/// Record a wait interval that *started* at `since` and ends now —
/// used for pool queue-wait where the enqueue and the dequeue happen
/// on different threads (the event lands in the dequeuing thread's
/// ring). `payload[0]` is the wait in µs.
pub fn wait_since(kind: TraceKind, stage: u16, since: Instant) {
    if !enabled(kind) {
        return;
    }
    let dur_ns = since.elapsed().as_nanos() as u64;
    let ts_ns = since.saturating_duration_since(epoch()).as_nanos() as u64;
    local_push(TraceEvent {
        ts_ns,
        dur_ns,
        kind,
        stage,
        worker: 0,
        payload: [dur_ns as f64 / 1e3, 0.0],
    });
}

/// Everything [`drain`] pulled out of the rings: events merged and
/// sorted by timestamp, the cumulative drop count since the previous
/// drain, and the per-worker thread names (indexed by
/// [`TraceEvent::worker`]).
#[derive(Debug, Clone, Default)]
pub struct TraceReport {
    pub events: Vec<TraceEvent>,
    pub dropped: u64,
    pub threads: Vec<String>,
}

impl TraceReport {
    /// Events of one kind.
    pub fn count(&self, kind: TraceKind) -> usize {
        self.events.iter().filter(|e| e.kind == kind).count()
    }

    /// Distinct *span* kinds present (counter kinds excluded), in
    /// discriminant order — the acceptance bar counts these.
    pub fn span_kinds(&self) -> Vec<TraceKind> {
        TraceKind::ALL
            .iter()
            .copied()
            .filter(|k| !k.is_counter() && self.count(*k) > 0)
            .collect()
    }

    /// Any counter samples present?
    pub fn has_counters(&self) -> bool {
        self.events.iter().any(|e| e.kind.is_counter())
    }
}

/// Drain every registered ring: moves the buffered events out (rings
/// keep recording), resets the drop counters, and returns the merged
/// timeline. Holds the registry lock for the duration and each ring
/// lock briefly; writers only ever take their own ring lock, so this
/// cannot deadlock with the worker pool (asserted by
/// `rust/tests/obs_trace.rs`).
pub fn drain() -> TraceReport {
    let reg = REGISTRY.lock().expect("obs registry");
    let mut events = Vec::new();
    let mut dropped = 0u64;
    let mut threads = Vec::with_capacity(reg.len());
    for e in reg.iter() {
        threads.push(e.name.clone());
        let mut r = e.ring.lock().expect("obs ring");
        dropped += std::mem::take(&mut r.dropped);
        events.extend(r.events.drain(..));
    }
    drop(reg);
    events.sort_by(|a, b| {
        a.ts_ns.cmp(&b.ts_ns).then(a.worker.cmp(&b.worker))
    });
    TraceReport {
        events,
        dropped,
        threads,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// obs state is process-global; serialize the unit tests that
    /// mutate it (other suites never drain, so they are unaffected
    /// beyond a little recording overhead while these run).
    fn lock() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Stage markers keep these assertions immune to events other
    /// concurrently-running lib tests may record while tracing is on.
    const MARK: u16 = 7_771;

    fn count_marked(r: &TraceReport, kind: TraceKind, stage: u16) -> usize {
        r.events
            .iter()
            .filter(|e| e.kind == kind && e.stage == stage)
            .count()
    }

    #[test]
    fn disabled_span_is_inert_and_records_nothing() {
        let _g = lock();
        install(&TraceConfig::off());
        let mut sp = Span::begin(TraceKind::MacroMvm, MARK);
        assert!(!sp.active());
        sp.note(1.0, 2.0);
        drop(sp);
        counter(TraceKind::EnergyFj, MARK, 9.0);
        let r = drain();
        assert_eq!(count_marked(&r, TraceKind::MacroMvm, MARK), 0);
        assert_eq!(count_marked(&r, TraceKind::EnergyFj, MARK), 0);
    }

    #[test]
    fn span_records_payload_and_monotonic_timestamps() {
        let _g = lock();
        install(&TraceConfig::all());
        {
            let mut sp = Span::begin(TraceKind::NocRoute, MARK + 1);
            assert!(sp.active());
            sp.note(3.0, 45.0);
        }
        counter(TraceKind::QueueDepth, MARK + 1, 2.0);
        let r = drain();
        let spans: Vec<&TraceEvent> = r
            .events
            .iter()
            .filter(|e| {
                e.kind == TraceKind::NocRoute && e.stage == MARK + 1
            })
            .collect();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].payload, [3.0, 45.0]);
        assert_eq!(count_marked(&r, TraceKind::QueueDepth, MARK + 1), 1);
        assert!(r.events.windows(2).all(|w| w[0].ts_ns <= w[1].ts_ns));
        install(&TraceConfig::off());
    }

    #[test]
    fn ring_drops_oldest_and_counts_drops() {
        let _g = lock();
        install(&TraceConfig {
            capacity: 4,
            ..TraceConfig::all()
        });
        for i in 0..100 {
            counter(TraceKind::Occupancy, MARK + 2, i as f64);
        }
        let r = drain();
        let mine: Vec<f64> = r
            .events
            .iter()
            .filter(|e| {
                e.kind == TraceKind::Occupancy && e.stage == MARK + 2
            })
            .map(|e| e.payload[0])
            .collect();
        // This thread's ring kept only the newest `capacity` events.
        assert!(mine.len() <= 4, "kept {}", mine.len());
        assert!(mine.contains(&99.0), "newest survives: {mine:?}");
        assert!(r.dropped >= 96, "dropped {}", r.dropped);
        // A drain empties the rings: the marked events are gone.
        let again = drain();
        assert_eq!(count_marked(&again, TraceKind::Occupancy, MARK + 2), 0);
        install(&TraceConfig::off());
    }

    #[test]
    fn kind_bits_are_distinct_and_all_is_complete() {
        let mut mask = 0u32;
        for k in TraceKind::ALL {
            assert_eq!(mask & k.bit(), 0, "{k:?} bit collides");
            mask |= k.bit();
            assert!(!k.name().is_empty());
        }
        assert_eq!(mask.count_ones() as usize, KIND_COUNT);
        assert_eq!(TraceConfig::all().kinds & mask, mask);
    }
}

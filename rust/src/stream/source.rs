//! Event-stream sources (DESIGN.md S18): where timestep frames come
//! from. Two producers behind one trait:
//!
//! * [`PoissonStream`] — synthetic DVS-style traffic: every row fires
//!   independently per frame with its own rate (a discrete-time Poisson
//!   process), deterministic in the seed. This is the serving/bench
//!   workload knob: mean frame density ≈ rate.
//! * [`EncodedStream`] — a static input re-encoded into T frames by a
//!   [`FrameEncoder`] (rate or TTFS), the ANN→SNN conversion path.
//!
//! A frame is a sorted active-row event list — the exact shape
//! `CimMacro::mvm_events` and `LayerStage::run_events` consume, so a
//! source plugs straight into the runtime with no re-encoding.

use crate::util::rng::Rng;

use super::encode::FrameEncoder;

/// A finite sequence of binary timestep frames.
pub trait EventStream {
    /// Input rows each frame spans.
    fn rows(&self) -> usize;

    /// Write the next frame's sorted active-row list into `out`;
    /// returns `false` (leaving `out` empty) when the stream is done.
    fn next_frame(&mut self, out: &mut Vec<u32>) -> bool;
}

/// Drain a stream into owned frames (tests, sweeps, benches).
pub fn collect_frames(stream: &mut dyn EventStream) -> Vec<Vec<u32>> {
    let mut frames = Vec::new();
    let mut frame = Vec::new();
    while stream.next_frame(&mut frame) {
        frames.push(frame.clone());
    }
    frames
}

/// Synthetic DVS-style source: independent per-row Bernoulli firing per
/// frame, deterministic in the seed.
#[derive(Debug, Clone)]
pub struct PoissonStream {
    rates: Vec<f64>,
    frames_left: usize,
    rng: Rng,
}

impl PoissonStream {
    /// Every row fires with probability `density` per frame.
    pub fn uniform(
        rows: usize,
        frames: usize,
        density: f64,
        seed: u64,
    ) -> PoissonStream {
        assert!((0.0..=1.0).contains(&density), "density in [0, 1]");
        PoissonStream {
            rates: vec![density; rows],
            frames_left: frames,
            rng: Rng::new(seed),
        }
    }

    /// Per-row firing rates (a DVS scene with hot and cold pixels).
    pub fn with_rates(rates: Vec<f64>, frames: usize, seed: u64) -> PoissonStream {
        assert!(rates.iter().all(|r| (0.0..=1.0).contains(r)));
        PoissonStream {
            rates,
            frames_left: frames,
            rng: Rng::new(seed),
        }
    }
}

impl EventStream for PoissonStream {
    fn rows(&self) -> usize {
        self.rates.len()
    }

    fn next_frame(&mut self, out: &mut Vec<u32>) -> bool {
        out.clear();
        if self.frames_left == 0 {
            return false;
        }
        self.frames_left -= 1;
        for (r, &rate) in self.rates.iter().enumerate() {
            if self.rng.f64() < rate {
                out.push(r as u32);
            }
        }
        true
    }
}

/// A static input unrolled into T frames by a [`FrameEncoder`].
#[derive(Debug, Clone)]
pub struct EncodedStream {
    frames: Vec<Vec<u32>>,
    next: usize,
    rows: usize,
}

impl EncodedStream {
    pub fn new(enc: &FrameEncoder, x: &[u32]) -> EncodedStream {
        EncodedStream {
            frames: enc.encode_frames(x),
            next: 0,
            rows: x.len(),
        }
    }
}

impl EventStream for EncodedStream {
    fn rows(&self) -> usize {
        self.rows
    }

    fn next_frame(&mut self, out: &mut Vec<u32>) -> bool {
        out.clear();
        if self.next >= self.frames.len() {
            return false;
        }
        out.extend_from_slice(&self.frames[self.next]);
        self.next += 1;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::encode::TemporalCode;

    #[test]
    fn poisson_stream_is_deterministic_and_bounded() {
        let mut a = PoissonStream::uniform(128, 10, 0.2, 9);
        let mut b = PoissonStream::uniform(128, 10, 0.2, 9);
        let fa = collect_frames(&mut a);
        let fb = collect_frames(&mut b);
        assert_eq!(fa, fb);
        assert_eq!(fa.len(), 10);
        for f in &fa {
            assert!(f.windows(2).all(|w| w[0] < w[1]), "sorted");
            assert!(f.iter().all(|&r| r < 128));
        }
        // Mean density over 10×128 draws lands near the rate.
        let total: usize = fa.iter().map(|f| f.len()).sum();
        let density = total as f64 / (10.0 * 128.0);
        assert!((0.08..0.35).contains(&density), "{density}");
    }

    #[test]
    fn poisson_rate_extremes() {
        let mut silent = PoissonStream::uniform(64, 3, 0.0, 1);
        assert!(collect_frames(&mut silent).iter().all(|f| f.is_empty()));
        let mut dense = PoissonStream::uniform(64, 3, 1.0, 1);
        assert!(collect_frames(&mut dense)
            .iter()
            .all(|f| f.len() == 64));
    }

    #[test]
    fn per_row_rates_shape_the_traffic() {
        let mut rates = vec![0.0; 32];
        rates[7] = 1.0;
        let mut s = PoissonStream::with_rates(rates, 5, 3);
        for f in collect_frames(&mut s) {
            assert_eq!(f, vec![7]);
        }
    }

    #[test]
    fn encoded_stream_replays_the_frame_encoder() {
        let enc = FrameEncoder::new(TemporalCode::Rate, 4, 255);
        let x = vec![255u32, 0, 128, 64];
        let mut s = EncodedStream::new(&enc, &x);
        assert_eq!(s.rows(), 4);
        let frames = collect_frames(&mut s);
        assert_eq!(frames, enc.encode_frames(&x));
        // Exhausted stream stays exhausted.
        let mut out = vec![9u32];
        assert!(!s.next_frame(&mut out));
        assert!(out.is_empty());
    }
}

//! Streaming session server (DESIGN.md S18, supervised since S21):
//! `serve --backend stream`.
//!
//! Serving a temporal SNN differs from the one-shot `MacroServer` in
//! one essential way: a request is not a vector, it is a *session* — an
//! ordered frame stream whose state (per-stage LIF membranes) must
//! survive between frames. The server keeps the weights stationary and
//! the state mobile:
//!
//! * every worker owns one deployed [`SpikingMlp`] (weights programmed
//!   once, like `MacroServer`'s per-worker macro);
//! * a session is pinned to `worker = id % workers`, so its frames are
//!   processed in submission order (worker channels are FIFO) — the
//!   temporal analogue of the scheduler's weight-stationary affinity;
//! * per-session membrane snapshots are swapped into the worker's
//!   model around each frame ([`SpikingMlp::swap_state`]) — membranes
//!   are a few hundred f64s, the macros are the expensive part.
//!
//! Per-frame serving metrics flow into the shared [`Metrics`]:
//! latency (`record_request`), energy (`record_energy`), occupancy
//! (`record_activity` with macro row slots across all stages), and
//! MACs. Session replies carry the running readout membranes, so a
//! client can take the argmax at any timestep (anytime inference).
//!
//! Reliability (DESIGN.md S19): when the config carries a
//! [`FaultPlan`], each worker owns a per-shard fault state alongside
//! its model and a golden code snapshot taken at deployment. Simulated
//! retention drift ([`StreamServer::drift`]) and verify-and-rewrite
//! scrubs ([`StreamServer::scrub_now`], or a background
//! [`Scrubber`] via [`StreamServer::start_scrubber`]) travel through
//! the same per-worker FIFOs as frames, so scrub work interleaves with
//! serving at session granularity — it can never race a frame on the
//! worker's model, which is what makes the scrub-vs-serve bit-identity
//! assertion in `rust/tests/stream_e2e.rs` possible.
//!
//! Supervision & overload control (DESIGN.md S21): the server is a
//! *supervised* control plane over the blocking compute plane:
//!
//! * **Admission** — [`StreamServer::try_submit_frame`] claims a slot
//!   in the session's per-worker bounded queue and returns
//!   [`Admission::Shed`] (with a `retry_after` hint from the measured
//!   service-time EWMA) when the queue is full, instead of growing an
//!   unbounded backlog. Per-frame deadlines are checked at *dequeue*:
//!   a stale frame is dropped-not-computed and its client gets
//!   [`FrameOutcome::Shed`].
//! * **Panic isolation** — each frame attempt runs under
//!   `catch_unwind`. A panicking worker restores the session's
//!   pre-frame membrane snapshot, reports to the [`Supervisor`], and —
//!   while the restart budget lasts — rebuilds its replica from the
//!   spec (fresh die + fault-state reseed, golden codes recaptured)
//!   after an exponential backoff, then retries the frame once. Past
//!   the budget the worker *degrades*: it sheds frames
//!   ([`ShedReason::RestartBudget`]) but still drains session state.
//! * **Graceful drain** — [`StreamServer::shutdown_within`] stops
//!   admissions, lets queued frames finish until the deadline, sheds
//!   the rest ([`ShedReason::Draining`]), quiesces the scrubber, and
//!   returns a [`DrainReport`]. Every admitted frame gets exactly one
//!   outcome — served or shed, never silently lost.
//!
//! Mission-clock endurance (DESIGN.md S22): a [`MissionClock`] started
//! via [`StreamServer::start_mission`] compresses days of simulated
//! uptime into seconds of wall time. Each tick broadcasts a `Drift`
//! job (fixed `sim_dt_ns` of virtual uptime) through the same
//! per-worker FIFOs as frames, then runs the configured maintenance
//! arm ([`MissionMode`]): scrub on a wear-stretched schedule,
//! recalibrate λ online ([`SpikingMlp::recalibrate`]), or choose
//! between them adaptively from [`ScrubOutcome`] evidence. Write
//! pulses are a *wear ledger*: every worker tracks its die's
//! cumulative pulses (surviving restarts — the rebuilt replica
//! reprograms the *same* physical die) against an
//! [`EndurancePolicy`]; as the wear budget depletes, scrubbing is
//! throttled, and past the configured ceiling the worker reports
//! `wear_out` to the [`Supervisor`] and degrades through the S21 path
//! instead of continuing to burn pulses.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::{FabricConfig, LevelMap, MacroConfig, StreamConfig};
use crate::coordinator::{
    Admission, ChaosPlan, EndurancePolicy, Metrics, MissionClock,
    RestartPolicy, ScrubPolicy, Scrubber, ShedReason, StatusMsg, Supervisor,
    Verdict,
};
use crate::device::{FaultPlan, FaultState, ScrubOutcome, SotWriteParams};
use crate::obs::{self, TraceKind};
use crate::snn::dataset::Dataset;
use crate::snn::mlp::Mlp;
use crate::util::rng::Rng;

use super::encode::{FrameEncoder, TemporalCode};
use super::snn::SpikingMlp;

/// Everything needed to deploy one [`SpikingMlp`] per worker.
#[derive(Clone)]
pub struct StreamSpec {
    pub model: Mlp,
    pub calib: Dataset,
    pub mcfg: MacroConfig,
    pub fabric: FabricConfig,
    pub level_map: LevelMap,
    pub stream: StreamConfig,
}

impl StreamSpec {
    /// Deploy the spec (quantize, calibrate, place on the mesh).
    pub fn build(&self) -> Result<SpikingMlp> {
        SpikingMlp::from_float(
            &self.model,
            &self.calib,
            &self.mcfg,
            self.fabric.clone(),
            self.level_map,
            &self.stream,
        )
    }
}

/// One session reply: the state of the readout after a frame.
#[derive(Debug, Clone)]
pub struct StreamReply {
    pub session: u64,
    /// Timesteps this session has processed (after this frame).
    pub t: usize,
    /// Readout membranes (running evidence).
    pub out_v: Vec<f64>,
    /// Argmax of the digit classes at this timestep.
    pub label: usize,
}

/// What became of one *admitted* frame. Exactly one of these arrives on
/// the receiver returned by [`StreamServer::try_submit_frame`].
#[derive(Debug, Clone)]
pub enum FrameOutcome {
    /// The frame was computed; the session advanced one timestep.
    Served(StreamReply),
    /// The frame was dropped-not-computed; the session did NOT advance.
    Shed { session: u64, reason: ShedReason },
}

impl FrameOutcome {
    /// The reply, if served.
    pub fn served(self) -> Option<StreamReply> {
        match self {
            FrameOutcome::Served(r) => Some(r),
            FrameOutcome::Shed { .. } => None,
        }
    }

    /// Was the frame shed after admission?
    pub fn is_shed(&self) -> bool {
        matches!(self, FrameOutcome::Shed { .. })
    }

    /// Unwrap the served reply; panics if the frame was shed. For
    /// callers (tests, sweeps below capacity) that treat shedding as a
    /// bug rather than a load condition.
    pub fn expect_served(self) -> StreamReply {
        match self {
            FrameOutcome::Served(r) => r,
            FrameOutcome::Shed { session, reason } => panic!(
                "frame for session {session} was shed ({reason:?}) — \
                 handle FrameOutcome::Shed when serving near capacity"
            ),
        }
    }
}

/// What a graceful drain accomplished (see
/// [`StreamServer::shutdown_within`]).
#[derive(Debug, Clone, Copy)]
pub struct DrainReport {
    /// Wall time the full shutdown took (drain + join), ms.
    pub drain_ms: f64,
    /// Frames shed while draining (drain + deadline sheds).
    pub shed: u64,
    /// True when every admitted frame was served (nothing shed).
    pub clean: bool,
}

enum StreamJob {
    Frame {
        session: u64,
        events: Vec<u32>,
        submitted: Instant,
        /// Latest instant at which computing this frame is still
        /// useful; checked at dequeue (dropped-not-computed).
        deadline: Option<Instant>,
        reply: mpsc::Sender<FrameOutcome>,
    },
    Finish {
        session: u64,
        reply: mpsc::Sender<StreamReply>,
    },
    /// Advance the worker's simulated clock: retention flips land on
    /// its arrays. Replies with the number of cells changed.
    Drift {
        dt_ns: f64,
        reply: mpsc::Sender<u64>,
    },
    /// Verify-and-rewrite every shard against the worker's golden
    /// snapshot. The reply sender may already be gone (background
    /// scrubber ticks fire and forget).
    Scrub {
        reply: mpsc::Sender<ScrubOutcome>,
    },
    /// Re-derive the per-layer normalization thresholds λ on the
    /// worker's *drifted* replica (DESIGN.md S22): gain drift moves
    /// every conductance multiplicatively, which scrub cannot see
    /// (codes still match golden) — only re-running calibration
    /// restores the operating point. Write-pulse free. Replies with
    /// the largest relative λ shift, the adaptive controller's
    /// evidence that gain is (still) wandering.
    Recalibrate {
        reply: mpsc::Sender<f64>,
    },
}

/// Stream server configuration.
#[derive(Debug, Clone)]
pub struct StreamServerConfig {
    pub workers: usize,
    /// Fault-injection plan (DESIGN.md S19). `None` serves a pristine
    /// fabric; drift/scrub jobs are then no-ops.
    pub faults: Option<FaultPlan>,
    /// Per-worker ingress queue capacity (frames). Admission beyond it
    /// returns [`Admission::Shed`].
    pub queue_cap: usize,
    /// Per-frame service deadline, measured from admission. `None`
    /// serves every admitted frame regardless of queueing delay.
    pub deadline: Option<Duration>,
    /// Restart budget and backoff for panicking workers.
    pub restart: RestartPolicy,
    /// Deterministic fault injection for the chaos tests: make workers
    /// panic mid-frame. `None` in production.
    pub chaos: Option<ChaosPlan>,
    /// Worker `recv_timeout` tick: bounds how stale the windowed
    /// metrics report and the drain-deadline check can get when a
    /// session goes quiet.
    pub idle_tick: Duration,
    /// When set, worker 0 publishes a windowed [`Metrics`] delta
    /// (readable via [`Metrics::last_window`]) roughly this often.
    pub report_period: Option<Duration>,
    /// Scrub knobs, including the queue-depth threshold that gates
    /// background scrub ticks (idle stealing).
    pub scrub: ScrubPolicy,
    /// Wear-budget SLO (DESIGN.md S22): rated write cycles, scrub
    /// throttling knee, and the degrade ceiling. The default rating
    /// (1e12 cycles) keeps wear negligible for ordinary serving.
    pub endurance: EndurancePolicy,
}

impl Default for StreamServerConfig {
    fn default() -> Self {
        StreamServerConfig {
            workers: 2,
            faults: None,
            queue_cap: 1024,
            deadline: None,
            restart: RestartPolicy::standard(),
            chaos: None,
            idle_tick: Duration::from_millis(50),
            report_period: None,
            scrub: ScrubPolicy::standard(),
            endurance: EndurancePolicy::standard(),
        }
    }
}

/// Maintenance arm the mission clock runs each tick (the three EX6
/// endurance arms — DESIGN.md S22).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MissionMode {
    /// Scrub every tick (worker-side wear throttle still applies);
    /// never recalibrate. Fixes retention flips, blind to gain drift.
    ScrubOnly,
    /// Recalibrate every tick; never scrub. Wear-free, tracks gain
    /// drift, but retention flips accumulate unrepaired.
    RecalOnly,
    /// Scrub on the wear-stretched schedule, and recalibrate when the
    /// evidence says scrubbing cannot help: the last scrub found
    /// nothing to repair (pure gain-drift signature) or the previous
    /// recalibration still moved some λ by at least
    /// [`MissionConfig::shift_eps`] (gain still wandering).
    Adaptive,
}

/// Mission-clock schedule: how much simulated uptime each wall-clock
/// tick represents, for how many ticks, and which maintenance arm to
/// run (DESIGN.md S22).
#[derive(Debug, Clone, Copy)]
pub struct MissionConfig {
    /// Wall period between virtual-uptime ticks.
    pub period: Duration,
    /// Simulated uptime per tick, ns (wall period × compression
    /// factor). Total simulated uptime is exactly
    /// `horizon × sim_dt_ns`, independent of wall-clock jitter.
    pub sim_dt_ns: f64,
    /// Tick budget; 0 runs until [`StreamServer::stop_mission`].
    pub horizon: u64,
    /// Maintenance arm.
    pub mode: MissionMode,
    /// λ-shift hysteresis for [`MissionMode::Adaptive`]: keep
    /// recalibrating while the last recalibration moved some λ by at
    /// least this fraction.
    pub shift_eps: f64,
}

/// Adaptive-arm probe interval: after this many ticks without a
/// recalibration the hysteresis re-arms and one fires anyway. Bounds
/// the λ staleness a quiet-then-wandering gain walk can accumulate to
/// a few ticks, while pure retention drift still settles to ~1/4 the
/// recalibration rate of [`MissionMode::RecalOnly`].
const RECAL_PROBE_TICKS: u64 = 4;

impl MissionConfig {
    /// Compress `sim_hours` of uptime into wall time at `factor`
    /// (simulated ns per wall ns): each `period` tick carries
    /// `period × factor` of simulated uptime, and the horizon is
    /// however many ticks cover `sim_hours`.
    pub fn compressed(
        factor: f64,
        sim_hours: f64,
        period: Duration,
        mode: MissionMode,
    ) -> Self {
        assert!(factor > 0.0, "uptime compression factor must be positive");
        assert!(sim_hours > 0.0, "simulated mission must have a duration");
        let sim_dt_ns = period.as_nanos() as f64 * factor;
        assert!(sim_dt_ns > 0.0, "tick period too short for the factor");
        let horizon = ((sim_hours * 3.6e12) / sim_dt_ns).ceil().max(1.0);
        MissionConfig {
            period,
            sim_dt_ns,
            horizon: horizon as u64,
            mode,
            shift_eps: 0.01,
        }
    }
}

/// One worker's reliability state: the golden snapshot it scrubs
/// toward, per-shard fault RNG streams, and the write/scrub knobs.
struct ReliabilityCtx {
    golden: Vec<Vec<Vec<u8>>>,
    states: Vec<Vec<FaultState>>,
    wp: SotWriteParams,
    policy: ScrubPolicy,
    /// Deployed shard macros (scrub busy-time = macros × tile time).
    n_macros: u64,
}

struct SessionState {
    /// Per-stage membrane snapshot.
    state: Vec<Vec<f64>>,
    /// Timesteps processed so far.
    t: usize,
}

/// Control-plane state shared between the caller-side admission path
/// and the worker loops.
struct ServeShared {
    /// Admitted-but-not-yet-dequeued frames, per worker. Incremented
    /// at admission, decremented at dequeue — the queue-depth counter
    /// that drives load shedding and the scrub gate.
    depth: Vec<AtomicUsize>,
    /// Cleared when a drain begins: new frames are refused upfront.
    accepting: AtomicBool,
    /// Wall deadline of an in-progress drain; frames dequeued after it
    /// are shed ([`ShedReason::Draining`]).
    drain_deadline: Mutex<Option<Instant>>,
    /// EWMA of per-frame service time (f64 nanoseconds, stored as
    /// bits) — the basis of the `retry_after` hint.
    svc_ns: AtomicU64,
}

impl ServeShared {
    fn total_depth(&self) -> usize {
        self.depth.iter().map(|d| d.load(Ordering::Acquire)).sum()
    }

    /// Fold one measured frame-service time into the EWMA.
    fn note_service(&self, ns: f64) {
        let prev = f64::from_bits(self.svc_ns.load(Ordering::Relaxed));
        let next = if prev == 0.0 { ns } else { prev * 0.9 + ns * 0.1 };
        self.svc_ns.store(next.to_bits(), Ordering::Relaxed);
    }
}

/// Deploy one worker replica: fresh die from the spec, then (when a
/// fault plan is active) golden snapshot + per-worker reseeded fault
/// states. Worker *re*starts go through this same path — never through
/// re-deploying faults onto the old die, whose gain variation is
/// already applied (it would compound).
fn deploy_worker(
    spec: &StreamSpec,
    faults: Option<FaultPlan>,
    policy: ScrubPolicy,
    w: usize,
) -> Result<(SpikingMlp, Option<ReliabilityCtx>)> {
    let mut mlp = spec.build()?;
    let rel = faults.map(|plan| {
        // Golden = intended codes, captured before any fault
        // touches the arrays: scrub restores toward *this*.
        let golden = mlp.snapshot_codes();
        // Distinct per-worker seed: each replica is its own
        // die and drifts independently.
        let wplan = FaultPlan {
            seed: plan.seed.wrapping_add(1 + w as u64),
            ..plan
        };
        let mut states = mlp.fault_states(wplan);
        mlp.deploy_faults(&mut states);
        let n_macros = golden.iter().map(|s| s.len() as u64).sum::<u64>();
        ReliabilityCtx {
            golden,
            states,
            wp: SotWriteParams::default(),
            policy,
            n_macros,
        }
    });
    Ok((mlp, rel))
}

/// A running streaming-SNN service.
pub struct StreamServer {
    txs: Vec<mpsc::Sender<StreamJob>>,
    pub metrics: Arc<Metrics>,
    handles: Vec<JoinHandle<()>>,
    next_session: AtomicU64,
    in_dim: usize,
    shared: Arc<ServeShared>,
    supervisor: Option<Supervisor>,
    scrubber: Mutex<Option<Scrubber>>,
    mission: Mutex<Option<MissionClock>>,
    queue_cap: usize,
    deadline: Option<Duration>,
    scrub_policy: ScrubPolicy,
}

impl StreamServer {
    /// Deploy one model per worker and start the session loops. Fails
    /// fast (on the caller's thread) when the spec cannot deploy, e.g.
    /// the mesh is too small for the layer shards.
    pub fn start(
        spec: StreamSpec,
        scfg: StreamServerConfig,
    ) -> Result<StreamServer> {
        assert!(scfg.workers >= 1, "at least one worker");
        let metrics = Arc::new(Metrics::new());
        let shared = Arc::new(ServeShared {
            depth: (0..scfg.workers).map(|_| AtomicUsize::new(0)).collect(),
            accepting: AtomicBool::new(true),
            drain_deadline: Mutex::new(None),
            svc_ns: AtomicU64::new(0),
        });
        let (supervisor, status) =
            Supervisor::start(scfg.workers, scfg.restart, metrics.clone());
        let mut txs = Vec::with_capacity(scfg.workers);
        let mut handles = Vec::with_capacity(scfg.workers);
        let mut in_dim = 0;
        for w in 0..scfg.workers {
            let (mlp, rel) = deploy_worker(&spec, scfg.faults, scfg.scrub, w)?;
            in_dim = mlp.in_dim();
            let (tx, rx) = mpsc::channel::<StreamJob>();
            let wk = Worker {
                w,
                mlp,
                rel,
                sessions: HashMap::new(),
                degraded: false,
                attempts_seen: 0,
                chaos: scfg.chaos,
                chaos_rng: scfg.chaos.map(|c| c.rng_for(w)),
                spec: spec.clone(),
                faults: scfg.faults,
                scrub_policy: scfg.scrub,
                endurance: scfg.endurance,
                wear_carry: 0,
                scrub_round: 0,
                calib_frames: None,
                shared: shared.clone(),
                metrics: metrics.clone(),
                status: status.clone(),
            };
            let (idle_tick, report_period) =
                (scfg.idle_tick, scfg.report_period);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("spikemram-stream-{w}"))
                    .spawn(move || {
                        worker_loop(wk, rx, idle_tick, report_period)
                    })
                    .expect("spawn stream worker"),
            );
            txs.push(tx);
        }
        drop(status); // workers hold the only status senders now
        Ok(StreamServer {
            txs,
            metrics,
            handles,
            next_session: AtomicU64::new(0),
            in_dim,
            shared,
            supervisor: Some(supervisor),
            scrubber: Mutex::new(None),
            mission: Mutex::new(None),
            queue_cap: scfg.queue_cap,
            deadline: scfg.deadline,
            scrub_policy: scfg.scrub,
        })
    }

    /// Open a new session (fresh membranes on first frame). Sessions
    /// are sticky to one worker, so frames submitted in order are
    /// processed in order.
    pub fn open_session(&self) -> u64 {
        self.next_session.fetch_add(1, Ordering::Relaxed)
    }

    /// Model input dimension — the exclusive upper bound on event-row
    /// indices. The wire front end (DESIGN.md S23) validates remote
    /// frames against it *before* submission, so a malformed frame
    /// fails its own connection instead of tripping the in-process
    /// caller-bug assertions in [`try_submit_frame`](Self::try_submit_frame).
    pub fn in_dim(&self) -> usize {
        self.in_dim
    }

    /// Public form of the shed backoff hint: roughly one service time
    /// at the measured EWMA rate (1 ms before any frame has been
    /// measured). The wire front end attaches it to dequeue-side shed
    /// responses, which — unlike [`Admission::Shed`] — don't carry
    /// their own hint.
    pub fn retry_hint(&self) -> Duration {
        self.retry_after(1)
    }

    fn worker_for(&self, session: u64) -> usize {
        (session as usize) % self.txs.len()
    }

    /// `retry_after` hint when shedding: roughly how long until
    /// `queued` frames have drained at the measured service rate
    /// (1 ms before any frame has been measured).
    fn retry_after(&self, queued: usize) -> Duration {
        let svc = f64::from_bits(self.shared.svc_ns.load(Ordering::Relaxed));
        if svc > 0.0 {
            Duration::from_nanos((svc * queued as f64).max(1_000.0) as u64)
        } else {
            Duration::from_millis(1)
        }
    }

    /// Submit one timestep frame (sorted active-row event list) under
    /// admission control.
    ///
    /// The frame is validated here, on the *caller's* thread — a
    /// malformed list must fail the offending caller, not panic a
    /// shared worker and take every session pinned to it down with
    /// opaque disconnect errors. Validation happens before admission:
    /// a malformed frame is a caller bug, not an overload signal.
    ///
    /// On [`Admission::Accepted`] the receiver yields exactly one
    /// [`FrameOutcome`]; on [`Admission::Shed`] nothing was enqueued
    /// and the session did not advance.
    pub fn try_submit_frame(
        &self,
        session: u64,
        events: Vec<u32>,
    ) -> Admission<mpsc::Receiver<FrameOutcome>> {
        let mut prev: i64 = -1;
        for &r in &events {
            assert!(
                (r as usize) < self.in_dim,
                "event row {r} of {}",
                self.in_dim
            );
            assert!(
                i64::from(r) > prev,
                "event list must be sorted ascending without duplicates"
            );
            prev = i64::from(r);
        }
        let w = self.worker_for(session);
        if !self.shared.accepting.load(Ordering::Acquire) {
            self.metrics.record_shed(ShedReason::Draining);
            return Admission::Shed {
                retry_after: self.retry_after(1),
            };
        }
        // Optimistic slot claim, undone on overflow; the worker
        // decrements at dequeue.
        let depth = self.shared.depth[w].fetch_add(1, Ordering::AcqRel);
        if depth >= self.queue_cap {
            self.shared.depth[w].fetch_sub(1, Ordering::AcqRel);
            self.metrics.record_shed_queue();
            obs::counter(TraceKind::AdmissionShed, w as u16, depth as f64);
            return Admission::Shed {
                retry_after: self.retry_after(depth + 1),
            };
        }
        let deadline = self.deadline.map(|d| Instant::now() + d);
        let (rtx, rrx) = mpsc::channel();
        self.txs[w]
            .send(StreamJob::Frame {
                session,
                events,
                submitted: Instant::now(),
                deadline,
                reply: rtx,
            })
            .expect("workers alive");
        Admission::Accepted(rrx)
    }

    /// Submit one frame, treating admission shedding as a caller bug
    /// (panics on [`Admission::Shed`] — use
    /// [`try_submit_frame`](Self::try_submit_frame) near capacity).
    pub fn submit_frame(
        &self,
        session: u64,
        events: Vec<u32>,
    ) -> mpsc::Receiver<FrameOutcome> {
        self.try_submit_frame(session, events).expect_accepted()
    }

    /// Submit and wait; panics if the frame is shed at admission or
    /// after dequeue.
    pub fn frame(&self, session: u64, events: Vec<u32>) -> StreamReply {
        self.submit_frame(session, events)
            .recv()
            .expect("reply")
            .expect_served()
    }

    /// Close a session: returns its final reply and drops its state.
    /// Works on degraded workers too (drain-only mode).
    pub fn finish(&self, session: u64) -> StreamReply {
        let (rtx, rrx) = mpsc::channel();
        self.txs[self.worker_for(session)]
            .send(StreamJob::Finish {
                session,
                reply: rtx,
            })
            .expect("workers alive");
        rrx.recv().expect("reply")
    }

    /// Advance every worker's simulated clock by `dt_ns` (retention
    /// drift lands in place, interleaved with any in-flight frames).
    /// Returns the total cells flipped across all workers; 0 when the
    /// server runs without a fault plan.
    pub fn drift(&self, dt_ns: f64) -> u64 {
        let rxs: Vec<_> = self
            .txs
            .iter()
            .map(|tx| {
                let (rtx, rrx) = mpsc::channel();
                tx.send(StreamJob::Drift { dt_ns, reply: rtx })
                    .expect("workers alive");
                rrx
            })
            .collect();
        rxs.into_iter().map(|rx| rx.recv().expect("reply")).sum()
    }

    /// Scrub every worker's fabric against its golden snapshot and
    /// wait for completion (the synchronous path; the background
    /// [`Scrubber`] uses the same job type, fire-and-forget).
    pub fn scrub_now(&self) -> ScrubOutcome {
        let rxs: Vec<_> = self
            .txs
            .iter()
            .map(|tx| {
                let (rtx, rrx) = mpsc::channel();
                tx.send(StreamJob::Scrub { reply: rtx })
                    .expect("workers alive");
                rrx
            })
            .collect();
        let mut out = ScrubOutcome::default();
        for rx in rxs {
            out.absorb(&rx.recv().expect("reply"));
        }
        out
    }

    /// Start the background scrubber ticking every `period` of wall
    /// time, owned by the server ([`shutdown`](Self::shutdown)
    /// quiesces it). Each tick enqueues one scrub job per worker —
    /// unless ingress queues are deeper than the policy's
    /// `queue_depth_threshold`, in which case the tick is *skipped*
    /// (idle stealing: scrub work yields to queued frames) and
    /// counted via `Metrics::record_scrub_skip`.
    pub fn start_scrubber(&self, period: Duration) {
        let txs = self.txs.clone();
        let shared = self.shared.clone();
        let metrics = self.metrics.clone();
        let policy = self.scrub_policy;
        let s = Scrubber::start(period, move |_round| {
            if policy.should_skip(shared.total_depth()) {
                metrics.record_scrub_skip();
                return;
            }
            for tx in &txs {
                let (rtx, _rrx) = mpsc::channel();
                // Tolerate shutdown racing a tick: a closed channel
                // just means there is nothing left to scrub.
                let _ = tx.send(StreamJob::Scrub { reply: rtx });
            }
        });
        if let Some(old) = self.scrubber.lock().expect("scrubber").replace(s)
        {
            old.stop();
        }
    }

    /// Quiesce the background scrubber (no-op when none is running).
    /// Returns only after any in-flight tick has completed.
    pub fn stop_scrubber(&self) {
        if let Some(s) = self.scrubber.lock().expect("scrubber").take() {
            s.stop();
        }
    }

    /// Recalibrate every worker's λ thresholds against its own drifted
    /// replica and wait (the synchronous path; the mission clock uses
    /// the same job type). Returns the largest relative λ shift seen
    /// across workers — 0.0 when nothing moved.
    pub fn recalibrate_now(&self) -> f64 {
        let rxs: Vec<_> = self
            .txs
            .iter()
            .map(|tx| {
                let (rtx, rrx) = mpsc::channel();
                tx.send(StreamJob::Recalibrate { reply: rtx })
                    .expect("workers alive");
                rrx
            })
            .collect();
        rxs.into_iter()
            .map(|rx| rx.recv().expect("reply"))
            .fold(0.0, f64::max)
    }

    /// Start the mission clock (DESIGN.md S22): every `mcfg.period` of
    /// wall time one tick of `mcfg.sim_dt_ns` simulated uptime lands —
    /// a `Drift` job broadcast through the per-worker FIFOs (so drift
    /// interleaves with serving exactly like frames do), followed by
    /// the maintenance arm for `mcfg.mode`. Each tick completes
    /// synchronously on the clock thread, so the end state after
    /// `horizon` ticks is deterministic regardless of wall jitter.
    /// A bounded mission (`horizon > 0`) stops itself; use
    /// [`mission_wait`](Self::mission_wait) to block until it does.
    pub fn start_mission(&self, mcfg: MissionConfig) {
        let txs = self.txs.clone();
        // The adaptive arm's hysteresis: ∞ forces a first-tick
        // recalibration, which seeds the λ-shift evidence.
        let mut last_shift = f64::INFINITY;
        let mut ticks_since_recal = 0u64;
        let clock = MissionClock::start(
            mcfg.period,
            mcfg.sim_dt_ns,
            mcfg.horizon,
            move |_round, dt_ns| {
                // 1. Virtual uptime advances on every replica. Channel
                // sends/recvs tolerate shutdown racing a tick.
                let drifts: Vec<_> = txs
                    .iter()
                    .map(|tx| {
                        let (rtx, rrx) = mpsc::channel();
                        let _ =
                            tx.send(StreamJob::Drift { dt_ns, reply: rtx });
                        rrx
                    })
                    .collect();
                for rx in drifts {
                    let _ = rx.recv();
                }
                // 2. Maintenance arm.
                let mut mismatched = 0u64;
                if matches!(
                    mcfg.mode,
                    MissionMode::ScrubOnly | MissionMode::Adaptive
                ) {
                    let rxs: Vec<_> = txs
                        .iter()
                        .map(|tx| {
                            let (rtx, rrx) = mpsc::channel();
                            let _ =
                                tx.send(StreamJob::Scrub { reply: rtx });
                            rrx
                        })
                        .collect();
                    for rx in rxs {
                        if let Ok(o) = rx.recv() {
                            mismatched += o.mismatched as u64;
                        }
                    }
                }
                let recal = match mcfg.mode {
                    MissionMode::ScrubOnly => false,
                    MissionMode::RecalOnly => true,
                    // ScrubOutcome evidence: a scrub pass that found
                    // nothing to repair proves the residual drift is
                    // gain-type (codes all match golden, yet time
                    // passed); and while the previous recalibration
                    // still moved λ, gain is still wandering. The
                    // periodic probe re-arms the hysteresis after a
                    // quiet interval — a single sub-ε gain step must
                    // not disable recalibration for the rest of the
                    // mission while the walk keeps wandering.
                    MissionMode::Adaptive => {
                        mismatched == 0
                            || last_shift >= mcfg.shift_eps
                            || ticks_since_recal >= RECAL_PROBE_TICKS
                    }
                };
                if recal {
                    let rxs: Vec<_> = txs
                        .iter()
                        .map(|tx| {
                            let (rtx, rrx) = mpsc::channel();
                            let _ = tx
                                .send(StreamJob::Recalibrate { reply: rtx });
                            rrx
                        })
                        .collect();
                    let mut shift = 0.0f64;
                    for rx in rxs {
                        if let Ok(s) = rx.recv() {
                            shift = shift.max(s);
                        }
                    }
                    last_shift = shift;
                    ticks_since_recal = 0;
                } else {
                    ticks_since_recal += 1;
                }
            },
        );
        if let Some(old) = self.mission.lock().expect("mission").replace(clock)
        {
            old.stop();
        }
    }

    /// Block until a bounded mission reaches its horizon (immediately
    /// returns when no mission is running). Returns the simulated
    /// uptime the mission has accumulated, ns.
    pub fn mission_wait(&self) -> f64 {
        let guard = self.mission.lock().expect("mission");
        match guard.as_ref() {
            Some(c) => {
                c.wait_done();
                c.sim_elapsed_ns()
            }
            None => 0.0,
        }
    }

    /// Stop the mission clock and quiesce its in-flight tick (no-op
    /// when none is running).
    pub fn stop_mission(&self) {
        if let Some(c) = self.mission.lock().expect("mission").take() {
            c.stop();
        }
    }

    /// Graceful drain: stop admissions immediately, let queued frames
    /// finish until `deadline` of wall time has passed, shed whatever
    /// remains ([`ShedReason::Draining`] — every admitted frame still
    /// gets its outcome), quiesce the scrubber and supervisor, and
    /// join the workers.
    pub fn shutdown_within(mut self, deadline: Duration) -> DrainReport {
        let t0 = Instant::now();
        let before = self.metrics.snapshot();
        self.shared.accepting.store(false, Ordering::Release);
        *self.shared.drain_deadline.lock().expect("drain deadline") =
            Some(t0 + deadline);
        self.stop_mission();
        self.stop_scrubber();
        while self.shared.total_depth() > 0 && t0.elapsed() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        // Closing the channels ends the worker loops once the queues
        // are drained; frames dequeued past the drain deadline are
        // shed, not computed.
        self.txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        // Workers held the only status senders; the supervisor loop
        // has therefore exited and this join cannot block.
        if let Some(s) = self.supervisor.take() {
            s.join();
        }
        let after = self.metrics.snapshot();
        let shed = (after.sheds_drain - before.sheds_drain)
            + (after.sheds_deadline - before.sheds_deadline);
        DrainReport {
            drain_ms: t0.elapsed().as_secs_f64() * 1e3,
            shed,
            clean: shed == 0,
        }
    }

    /// Drain with a generous deadline (the old hard-stop API; existing
    /// callers may ignore the report).
    pub fn shutdown(self) -> DrainReport {
        self.shutdown_within(Duration::from_secs(60))
    }
}

/// One worker's whole world: its replica, sessions, chaos state, and
/// the handles it needs to rebuild itself.
struct Worker {
    w: usize,
    mlp: SpikingMlp,
    rel: Option<ReliabilityCtx>,
    sessions: HashMap<u64, SessionState>,
    /// Restart budget exhausted: shed frames, keep draining state.
    degraded: bool,
    /// Frame *attempts* (retries included) — the chaos clock.
    attempts_seen: u64,
    chaos: Option<ChaosPlan>,
    chaos_rng: Option<Rng>,
    spec: StreamSpec,
    faults: Option<FaultPlan>,
    scrub_policy: ScrubPolicy,
    /// Wear-budget SLO knobs (DESIGN.md S22).
    endurance: EndurancePolicy,
    /// Write pulses accumulated by *previous* replicas on this die.
    /// A restart rebuilds the model but reprograms the same physical
    /// array, so the ledger carries across — wear never resets.
    wear_carry: u64,
    /// Scrub requests seen (fired or throttled) — the phase of the
    /// wear-stretched scrub schedule.
    scrub_round: u64,
    /// Encoded calibration frame sets, built lazily on the first
    /// `Recalibrate` job (sized like EX4's recalibration arm).
    calib_frames: Option<Vec<Vec<Vec<u32>>>>,
    shared: Arc<ServeShared>,
    metrics: Arc<Metrics>,
    status: mpsc::Sender<StatusMsg>,
}

fn worker_loop(
    mut wk: Worker,
    rx: mpsc::Receiver<StreamJob>,
    idle_tick: Duration,
    report_period: Option<Duration>,
) {
    let mut window_prev = wk.metrics.snapshot();
    let mut window_at = Instant::now();
    // Initial programming pulses are already on the wear ledger.
    wk.publish_wear();
    loop {
        match rx.recv_timeout(idle_tick) {
            Ok(job) => wk.handle(job),
            // The idle tick exists so the periodic work below runs
            // even when every session goes quiet.
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
        if wk.w == 0 {
            if let Some(period) = report_period {
                if window_at.elapsed() >= period {
                    let cur = wk.metrics.snapshot();
                    wk.metrics.store_window(cur.delta_since(&window_prev));
                    window_prev = cur;
                    window_at = Instant::now();
                }
            }
        }
    }
}

impl Worker {
    fn handle(&mut self, job: StreamJob) {
        match job {
            StreamJob::Frame {
                session,
                events,
                submitted,
                deadline,
                reply,
            } => self.handle_frame(session, events, submitted, deadline, reply),
            StreamJob::Finish { session, reply } => {
                self.handle_finish(session, reply)
            }
            StreamJob::Drift { dt_ns, reply } => {
                let flips = match self.rel.as_mut() {
                    Some(ctx) => self.mlp.drift(&mut ctx.states, dt_ns),
                    None => 0,
                };
                self.metrics.record_fault_injection(flips, dt_ns);
                let _ = reply.send(flips);
            }
            StreamJob::Scrub { reply } => self.handle_scrub(reply),
            StreamJob::Recalibrate { reply } => self.handle_recalibrate(reply),
        }
    }

    /// The die's cumulative write-pulse ledger: every pulse issued by
    /// this replica plus everything carried over from replicas the
    /// supervisor has since rebuilt (same physical array).
    fn die_pulses(&self) -> u64 {
        self.wear_carry + self.mlp.write_pulses()
    }

    /// Publish the wear ledger to [`Metrics`] and the S20 trace ring.
    fn publish_wear(&self) {
        let pulses = self.die_pulses();
        let wear = self.endurance.wear(pulses);
        self.metrics.set_worker_wear(self.w, pulses, wear);
        obs::counter(TraceKind::WearFraction, self.w as u16, wear);
    }

    /// One scrub request under the wear-budget SLO (DESIGN.md S22):
    /// past the ceiling the worker degrades instead of scrubbing; in
    /// the throttle band only every `stretch`-th round fires.
    fn handle_scrub(&mut self, reply: mpsc::Sender<ScrubOutcome>) {
        let round = self.scrub_round;
        self.scrub_round += 1;
        if self.rel.is_none() {
            let _ = reply.send(ScrubOutcome::default());
            return;
        }
        let wear = self.endurance.wear(self.die_pulses());
        if self.endurance.should_degrade(wear) {
            // The die is spent: restarting cannot help (same physical
            // array), so report wear_out and take the S21 Degrade
            // path — shed frames, keep draining session state, and
            // stop burning write pulses.
            if !self.degraded {
                let (vtx, vrx) = mpsc::channel();
                if self
                    .status
                    .send(StatusMsg {
                        worker: self.w,
                        wear_out: true,
                        reply: vtx,
                    })
                    .is_ok()
                {
                    let _ = vrx.recv();
                }
                self.degraded = true;
            }
            self.publish_wear();
            let _ = reply.send(ScrubOutcome::default());
            return;
        }
        if !self.endurance.scrub_this_round(wear, round) {
            // Budget throttle: the scrub interval stretches as the
            // wear budget depletes; a skipped round costs no pulses.
            self.metrics.record_scrub_skip();
            self.publish_wear();
            let _ = reply.send(ScrubOutcome::default());
            return;
        }
        // S20 span (stage 0 = in-worker scrub execution; the
        // background tick records stage 1).
        let mut span = obs::Span::begin(TraceKind::ScrubPass, 0);
        let out = {
            let ctx = self.rel.as_mut().expect("fault plan checked above");
            let o = self.mlp.scrub(&mut ctx.states, &ctx.golden, &ctx.wp);
            let busy = ctx.policy.scrub_duration_ns * ctx.n_macros as f64;
            self.metrics.record_scrub(
                o.mismatched as u64,
                o.repaired as u64,
                o.energy_fj,
                busy,
            );
            o
        };
        span.note(0.0, out.repaired as f64);
        self.publish_wear();
        let _ = reply.send(out); // background ticks don't wait
    }

    /// One online recalibration (DESIGN.md S22): stream the spec's
    /// calibration set through the *drifted* replica, re-derive λ per
    /// hidden layer, and reply with the largest relative λ shift. No
    /// write pulses — λ lives in the digital periphery, not the array.
    fn handle_recalibrate(&mut self, reply: mpsc::Sender<f64>) {
        if self.calib_frames.is_none() {
            let enc = FrameEncoder::new(
                TemporalCode::Rate,
                self.spec.stream.t_steps,
                255,
            );
            let n = self.spec.calib.len().min(8);
            self.calib_frames = Some(
                (0..n)
                    .map(|i| enc.encode_frames(&self.spec.calib.features_u8(i)))
                    .collect(),
            );
        }
        let old = self.mlp.lambdas();
        let sets = self.calib_frames.as_ref().expect("built above");
        let new = self.mlp.recalibrate(sets, self.spec.stream.theta_pct);
        let shift = old
            .iter()
            .zip(&new)
            .map(|(&o, &n)| {
                if o.abs() > 1e-12 {
                    ((n - o) / o).abs()
                } else {
                    0.0
                }
            })
            .fold(0.0f64, f64::max);
        self.metrics.record_recalibration(shift);
        let _ = reply.send(shift);
    }

    fn shed(
        &self,
        session: u64,
        reason: ShedReason,
        reply: &mpsc::Sender<FrameOutcome>,
    ) {
        self.metrics.record_shed(reason);
        let _ = reply.send(FrameOutcome::Shed { session, reason });
    }

    fn handle_frame(
        &mut self,
        session: u64,
        events: Vec<u32>,
        submitted: Instant,
        deadline: Option<Instant>,
        reply: mpsc::Sender<FrameOutcome>,
    ) {
        self.shared.depth[self.w].fetch_sub(1, Ordering::AcqRel);
        // Dropped-not-computed gates, checked at dequeue: a frame that
        // cannot be useful anymore must not burn array energy.
        if deadline.is_some_and(|d| Instant::now() >= d) {
            self.shed(session, ShedReason::DeadlineExpired, &reply);
            return;
        }
        let draining = self
            .shared
            .drain_deadline
            .lock()
            .expect("drain deadline")
            .is_some_and(|d| Instant::now() >= d);
        if draining {
            self.shed(session, ShedReason::Draining, &reply);
            return;
        }
        if self.degraded {
            self.shed(session, ShedReason::RestartBudget, &reply);
            return;
        }

        // S20 span: dequeue → reply, payload = channel wait (µs) +
        // this frame's macro row activations.
        let mut span = obs::Span::begin(TraceKind::ServeFrame, 0);
        let queue_wait_us = if span.active() {
            submitted.elapsed().as_secs_f64() * 1e6
        } else {
            0.0
        };
        // The session is taken OUT of the map for the duration: on a
        // panic its membranes are stuck inside the poisoned model, so
        // recovery re-seeds from this pre-frame snapshot.
        let mut sess =
            self.sessions.remove(&session).unwrap_or_else(|| SessionState {
                state: self.mlp.fresh_state(),
                t: 0,
            });
        let pre = sess.state.clone();
        let mut tries = 0u32;
        let mut t_attempt;
        let served = loop {
            tries += 1;
            self.attempts_seen += 1;
            let fire = match (self.chaos.as_ref(), self.chaos_rng.as_mut()) {
                (Some(c), Some(rng)) => c.fires(self.attempts_seen, rng),
                _ => false,
            };
            self.mlp.swap_state(&mut sess.state);
            t_attempt = Instant::now();
            let res = catch_unwind(AssertUnwindSafe(|| {
                if fire {
                    panic!("chaos: injected worker fault");
                }
                self.mlp.step_frame(&events)
            }));
            match res {
                Ok(step) => break Some(step),
                Err(_) => {
                    // Panic isolation (DESIGN.md S21): restore the
                    // session from its pre-frame snapshot, report, and
                    // follow the supervisor's verdict.
                    sess.state = pre.clone();
                    self.metrics.record_worker_panic();
                    let (vtx, vrx) = mpsc::channel();
                    let verdict = self
                        .status
                        .send(StatusMsg {
                            worker: self.w,
                            wear_out: false,
                            reply: vtx,
                        })
                        .ok()
                        .and_then(|()| vrx.recv().ok());
                    match verdict {
                        Some(Verdict::Restart { attempt, backoff }) => {
                            std::thread::sleep(backoff);
                            match deploy_worker(
                                &self.spec,
                                self.faults,
                                self.scrub_policy,
                                self.w,
                            ) {
                                Ok((m, r)) => {
                                    // Wear ledger (DESIGN.md S22): the
                                    // rebuilt replica reprograms the
                                    // SAME physical die, so the old
                                    // replica's pulses carry over
                                    // before the model is replaced.
                                    self.wear_carry +=
                                        self.mlp.write_pulses();
                                    self.mlp = m;
                                    self.rel = r;
                                    self.publish_wear();
                                    self.metrics.record_restart();
                                    let mut sp = obs::Span::begin(
                                        TraceKind::WorkerRestart,
                                        self.w as u16,
                                    );
                                    sp.note(
                                        attempt as f64,
                                        backoff.as_secs_f64() * 1e3,
                                    );
                                    if tries >= 2 {
                                        // Already retried once: the
                                        // replica is healthy again but
                                        // this frame is shed, not
                                        // looped on forever.
                                        break None;
                                    }
                                    // retry the frame on the fresh
                                    // replica
                                }
                                Err(_) => {
                                    // Cannot rebuild: degrade in place.
                                    self.degraded = true;
                                    break None;
                                }
                            }
                        }
                        Some(Verdict::Degrade) | None => {
                            self.degraded = true;
                            break None;
                        }
                    }
                }
            }
        };
        match served {
            Some(step) => {
                sess.t += 1;
                let out = StreamReply {
                    session,
                    t: sess.t,
                    out_v: self.mlp.out_membranes().to_vec(),
                    label: self.mlp.label(),
                };
                self.mlp.swap_state(&mut sess.state);
                self.shared
                    .note_service(t_attempt.elapsed().as_nanos() as f64);
                self.metrics.record_batch(1, step.macs);
                self.metrics
                    .record_activity(step.active_rows, step.row_slots);
                self.metrics.record_energy(step.energy.total_fj());
                self.metrics.record_noc(step.noc_packets, step.noc_hops);
                self.metrics
                    .record_request(submitted.elapsed().as_secs_f64() * 1e6);
                span.note(queue_wait_us, step.active_rows as f64);
                // Per-frame telemetry series (each gated on its own
                // kind inside `counter`).
                if span.active() {
                    let occ = if step.row_slots == 0 {
                        0.0
                    } else {
                        step.active_rows as f64 / step.row_slots as f64
                    };
                    obs::counter(TraceKind::Occupancy, 0, occ);
                    obs::counter(
                        TraceKind::EnergyFj,
                        0,
                        step.energy.total_fj(),
                    );
                }
                let _ = reply.send(FrameOutcome::Served(out));
            }
            None => {
                // The session did not advance; the pre-frame snapshot
                // is back in `sess`.
                self.shed(session, ShedReason::RestartBudget, &reply);
            }
        }
        self.sessions.insert(session, sess);
    }

    fn handle_finish(&mut self, session: u64, reply: mpsc::Sender<StreamReply>) {
        let out = match self.sessions.remove(&session) {
            Some(mut sess) => {
                self.mlp.swap_state(&mut sess.state);
                let r = StreamReply {
                    session,
                    t: sess.t,
                    out_v: self.mlp.out_membranes().to_vec(),
                    label: self.mlp.label(),
                };
                self.mlp.swap_state(&mut sess.state);
                r
            }
            None => StreamReply {
                session,
                t: 0,
                out_v: vec![0.0; self.mlp.out_dim()],
                label: 0,
            },
        };
        let _ = reply.send(out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::encode::{FrameEncoder, TemporalCode};

    fn spec(seed: u64) -> StreamSpec {
        StreamSpec {
            model: Mlp::new(seed),
            calib: Dataset::generate(24, seed ^ 0x9),
            mcfg: MacroConfig::default(),
            fabric: FabricConfig::square(2),
            level_map: LevelMap::DeviceTrue,
            stream: StreamConfig::default(),
        }
    }

    #[test]
    fn interleaved_sessions_match_serial_runs_bitwise() {
        let sp = spec(61);
        let mut serial = sp.build().unwrap();
        let enc = FrameEncoder::new(TemporalCode::Rate, 5, 255);
        let data = Dataset::generate(6, 77);
        let server = StreamServer::start(
            sp,
            StreamServerConfig {
                workers: 2,
                ..StreamServerConfig::default()
            },
        )
        .unwrap();

        // Three concurrent sessions, frames interleaved round-robin.
        let frames: Vec<Vec<Vec<u32>>> = (0..3)
            .map(|i| enc.encode_frames(&data.features_u8(i)))
            .collect();
        let ids: Vec<u64> = (0..3).map(|_| server.open_session()).collect();
        for t in 0..5 {
            for (s, id) in ids.iter().enumerate() {
                let r = server.frame(*id, frames[s][t].clone());
                assert_eq!(r.t, t + 1);
            }
        }
        for (s, id) in ids.iter().enumerate() {
            let want = serial.run(&frames[s]);
            let got = server.finish(*id);
            assert_eq!(got.t, 5);
            assert_eq!(got.out_v, want.out_v, "session {s} membranes");
            assert_eq!(got.label, want.label);
        }

        let snap = server.metrics.snapshot();
        assert_eq!(snap.requests, 15, "one request per frame");
        assert_eq!(snap.batches, 15);
        assert!(snap.energy_fj > 0.0, "per-timestep energy recorded");
        assert!(snap.row_slots > 0);
        let d = snap.input_density();
        assert!(d > 0.0 && d < 1.0, "occupancy {d}");
        server.shutdown();
    }

    #[test]
    fn finishing_an_unknown_session_is_benign() {
        let server =
            StreamServer::start(spec(63), StreamServerConfig::default())
                .unwrap();
        let r = server.finish(1234);
        assert_eq!(r.t, 0);
        assert!(r.out_v.iter().all(|&v| v == 0.0));
        server.shutdown();
    }

    #[test]
    #[should_panic(expected = "sorted ascending")]
    fn malformed_frame_fails_the_caller_not_the_worker() {
        let server =
            StreamServer::start(spec(67), StreamServerConfig::default())
                .unwrap();
        let id = server.open_session();
        let _ = server.submit_frame(id, vec![5, 3]);
    }

    #[test]
    fn faultless_server_treats_drift_and_scrub_as_noops() {
        let server =
            StreamServer::start(spec(71), StreamServerConfig::default())
                .unwrap();
        assert_eq!(server.drift(1e9), 0);
        assert_eq!(server.scrub_now(), ScrubOutcome::default());
        let snap = server.metrics.snapshot();
        assert_eq!(snap.flips_injected, 0);
        assert_eq!(snap.flips_repaired, 0);
        server.shutdown();
    }

    #[test]
    fn drift_then_scrub_restores_serving_bitwise() {
        use crate::device::RetentionParams;
        let sp = spec(73);
        let mut serial = sp.build().unwrap();
        let enc = FrameEncoder::new(TemporalCode::Rate, 4, 255);
        let data = Dataset::generate(4, 79);
        let frames = enc.encode_frames(&data.features_u8(0));
        let want = serial.run(&frames);

        let plan = FaultPlan::drift_only(RetentionParams::stress(), 81);
        let server = StreamServer::start(
            sp,
            StreamServerConfig {
                workers: 2,
                faults: Some(plan),
                ..StreamServerConfig::default()
            },
        )
        .unwrap();
        let flips = server.drift(plan.retention.tau_ret_ns());
        assert!(flips > 0, "stress drift at t=τ must flip cells");
        let out = server.scrub_now();
        assert_eq!(out.repaired, flips as usize, "full repair");
        assert!(out.energy_fj > 0.0);

        // Post-scrub, every worker replica serves the pristine answer.
        for _ in 0..2 {
            let id = server.open_session();
            for f in &frames {
                server.frame(id, f.clone());
            }
            let got = server.finish(id);
            assert_eq!(got.out_v, want.out_v);
            assert_eq!(got.label, want.label);
        }

        let snap = server.metrics.snapshot();
        assert_eq!(snap.flips_injected, flips);
        assert_eq!(snap.flips_detected, flips);
        assert_eq!(snap.flips_repaired, flips);
        assert_eq!(snap.scrubs, 2, "one scrub per worker");
        assert!(snap.scrub_energy_fj > 0.0);
        assert!(snap.scrub_duty_cycle() > 0.0);
        server.shutdown();
    }

    #[test]
    fn too_small_mesh_fails_at_start() {
        let sp = StreamSpec {
            fabric: FabricConfig::square(1),
            ..spec(65)
        };
        let err = StreamServer::start(sp, StreamServerConfig::default())
            .err()
            .expect("placement must fail");
        assert!(err.to_string().contains("exceed"), "{err}");
    }

    #[test]
    fn admission_control_sheds_when_the_queue_is_full() {
        // queue_cap 0: the bounded queue can hold nothing, so every
        // submission is deterministically shed at admission.
        let server = StreamServer::start(
            spec(83),
            StreamServerConfig {
                workers: 1,
                queue_cap: 0,
                ..StreamServerConfig::default()
            },
        )
        .unwrap();
        let id = server.open_session();
        for _ in 0..4 {
            match server.try_submit_frame(id, vec![0, 3]) {
                Admission::Shed { retry_after } => {
                    assert!(retry_after > Duration::ZERO);
                }
                Admission::Accepted(_) => panic!("cap-0 queue accepted"),
            }
        }
        let snap = server.metrics.snapshot();
        assert_eq!(snap.sheds_queue, 4);
        assert_eq!(snap.requests, 0, "nothing was computed");
        assert_eq!(snap.sheds_total(), 4);
        assert!((snap.shed_rate() - 1.0).abs() < 1e-12);
        server.shutdown();
    }

    #[test]
    fn expired_deadlines_drop_frames_without_computing() {
        // A zero deadline has always expired by dequeue time: every
        // admitted frame is shed DeadlineExpired and no array energy
        // is spent.
        let server = StreamServer::start(
            spec(85),
            StreamServerConfig {
                workers: 1,
                deadline: Some(Duration::ZERO),
                ..StreamServerConfig::default()
            },
        )
        .unwrap();
        let id = server.open_session();
        for _ in 0..3 {
            let rx = server.submit_frame(id, vec![1, 2]);
            match rx.recv().expect("outcome") {
                FrameOutcome::Shed { session, reason } => {
                    assert_eq!(session, id);
                    assert_eq!(reason, ShedReason::DeadlineExpired);
                }
                FrameOutcome::Served(_) => {
                    panic!("zero-deadline frame computed")
                }
            }
        }
        let snap = server.metrics.snapshot();
        assert_eq!(snap.sheds_deadline, 3);
        assert_eq!(snap.requests, 0, "dropped-not-computed");
        assert_eq!(snap.batches, 0);
        server.shutdown();
    }

    #[test]
    fn injected_panic_restarts_the_worker_and_stays_bitwise() {
        let sp = spec(91);
        let mut serial = sp.build().unwrap();
        let enc = FrameEncoder::new(TemporalCode::Rate, 4, 255);
        let data = Dataset::generate(4, 93);
        let frames = enc.encode_frames(&data.features_u8(0));
        let want = serial.run(&frames);

        let server = StreamServer::start(
            sp,
            StreamServerConfig {
                workers: 1,
                chaos: Some(ChaosPlan::every(3)),
                restart: RestartPolicy {
                    max_restarts: 100,
                    backoff: Duration::from_millis(1),
                    backoff_max: Duration::from_millis(2),
                },
                ..StreamServerConfig::default()
            },
        )
        .unwrap();
        let id = server.open_session();
        for f in &frames {
            // every-mode retries converge: every frame is served.
            server.frame(id, f.clone());
        }
        let got = server.finish(id);
        assert_eq!(got.out_v, want.out_v, "recovered replica must be exact");
        assert_eq!(got.label, want.label);
        let snap = server.metrics.snapshot();
        assert!(snap.worker_panics >= 1, "chaos must have fired");
        assert_eq!(
            snap.worker_panics, snap.restarts,
            "every panic earned a restart within budget"
        );
        assert_eq!(snap.requests, frames.len() as u64);
        server.shutdown();
    }

    #[test]
    fn idle_ticks_publish_windowed_reports() {
        let server = StreamServer::start(
            spec(95),
            StreamServerConfig {
                workers: 1,
                idle_tick: Duration::from_millis(2),
                report_period: Some(Duration::from_millis(5)),
                ..StreamServerConfig::default()
            },
        )
        .unwrap();
        let id = server.open_session();
        server.frame(id, vec![0, 1]);
        // No further traffic: only the recv_timeout idle tick can give
        // the worker a chance to publish the window.
        let deadline = Instant::now() + Duration::from_secs(10);
        while server.metrics.last_window().is_none() {
            assert!(Instant::now() < deadline, "window never published");
            std::thread::sleep(Duration::from_millis(2));
        }
        server.shutdown();
    }

    #[test]
    fn mission_clock_drives_drift_with_no_explicit_drift_calls() {
        use crate::device::RetentionParams;
        let plan = FaultPlan::drift_only(RetentionParams::stress(), 41);
        let tau = plan.retention.tau_ret_ns();
        let server = StreamServer::start(
            spec(43),
            StreamServerConfig {
                workers: 1,
                faults: Some(plan),
                ..StreamServerConfig::default()
            },
        )
        .unwrap();
        server.start_mission(MissionConfig {
            period: Duration::from_millis(1),
            sim_dt_ns: tau,
            horizon: 4,
            mode: MissionMode::ScrubOnly,
            shift_eps: 0.01,
        });
        let sim_ns = server.mission_wait();
        assert!(
            (sim_ns - 4.0 * tau).abs() < 1e-3,
            "uptime = horizon × dt exactly, got {sim_ns}"
        );
        let snap = server.metrics.snapshot();
        assert!(
            (snap.sim_time_ns - 4.0 * tau).abs() < 1e-3,
            "every tick's drift landed on the worker"
        );
        assert!(snap.flips_injected > 0, "stress drift at t=τ must flip");
        assert_eq!(snap.scrubs, 4, "scrub-only arm scrubs every tick");
        assert_eq!(snap.flips_repaired, snap.flips_detected);
        assert!(snap.wear_pulses.first().copied().unwrap_or(0) > 0);
        server.shutdown();
    }

    #[test]
    fn adaptive_mission_recalibrates_under_pure_gain_drift() {
        // Frozen retention + strong gain walk: scrub passes find
        // nothing (codes match golden), so the adaptive controller
        // must escalate to recalibration on ScrubOutcome evidence.
        let plan = FaultPlan::gain_only(0.5, 47);
        let server = StreamServer::start(
            spec(53),
            StreamServerConfig {
                workers: 1,
                faults: Some(plan),
                ..StreamServerConfig::default()
            },
        )
        .unwrap();
        server.start_mission(MissionConfig {
            period: Duration::from_millis(1),
            sim_dt_ns: 3.6e12, // one simulated hour per tick
            horizon: 3,
            mode: MissionMode::Adaptive,
            shift_eps: 0.01,
        });
        server.mission_wait();
        let snap = server.metrics.snapshot();
        assert_eq!(snap.flips_injected, 0, "frozen corner cannot flip");
        assert_eq!(snap.flips_repaired, 0, "scrub is a no-op under gain");
        assert!(snap.scrubs >= 1, "adaptive arm still probes via scrub");
        assert!(
            snap.recalibrations >= 1,
            "zero-mismatch scrub evidence must trigger recalibration"
        );
        server.shutdown();
    }

    #[test]
    fn wear_ledger_survives_a_worker_restart() {
        let sp = spec(59);
        let fresh_pulses = sp.build().unwrap().write_pulses();
        assert!(fresh_pulses > 0, "deploy programs the arrays");
        let server = StreamServer::start(
            sp,
            StreamServerConfig {
                workers: 1,
                chaos: Some(ChaosPlan::every(2)),
                restart: RestartPolicy {
                    max_restarts: 100,
                    backoff: Duration::from_millis(1),
                    backoff_max: Duration::from_millis(2),
                },
                ..StreamServerConfig::default()
            },
        )
        .unwrap();
        let id = server.open_session();
        server.frame(id, vec![0, 1]); // attempt 1: clean
        server.frame(id, vec![0, 1]); // attempt 2: panic → restart → retry
        let snap = server.metrics.snapshot();
        assert_eq!(snap.restarts, 1, "chaos every-2 earns one restart");
        // The rebuilt replica reprogrammed the same die: the ledger
        // holds the old replica's pulses PLUS the reprogramming.
        assert_eq!(
            snap.wear_pulses.first().copied(),
            Some(2 * fresh_pulses),
            "restart must not reset the die's accumulated write pulses"
        );
        assert!(snap.wear_fraction.first().copied().unwrap_or(0.0) > 0.0);
        server.shutdown();
    }

    #[test]
    fn wear_ceiling_degrades_the_worker_instead_of_scrubbing() {
        use crate::device::{EnduranceParams, RetentionParams};
        let plan = FaultPlan::drift_only(RetentionParams::standard(), 7);
        let server = StreamServer::start(
            spec(89),
            StreamServerConfig {
                workers: 1,
                faults: Some(plan),
                // A 10-cycle rating: initial programming alone blows
                // through the 0.9 ceiling.
                endurance: EndurancePolicy {
                    endurance: EnduranceParams { rated_cycles: 10 },
                    ..EndurancePolicy::standard()
                },
                ..StreamServerConfig::default()
            },
        )
        .unwrap();
        let out = server.scrub_now();
        assert_eq!(out, ScrubOutcome::default(), "no scrub past the ceiling");
        let snap = server.metrics.snapshot();
        assert_eq!(snap.scrubs, 0, "a spent die is never scrubbed");
        assert_eq!(
            snap.degraded_workers, 1,
            "wear-out must degrade via the S21 supervisor path"
        );
        assert_eq!(snap.wear_fraction.first().copied(), Some(1.0));
        // Degraded worker sheds frames but still drains state.
        let id = server.open_session();
        let rx = server.submit_frame(id, vec![0, 2]);
        match rx.recv().expect("outcome") {
            FrameOutcome::Shed { reason, .. } => {
                assert_eq!(reason, ShedReason::RestartBudget)
            }
            FrameOutcome::Served(_) => panic!("degraded worker served"),
        }
        let fin = server.finish(id);
        assert_eq!(fin.t, 0);
        server.shutdown();
    }

    #[test]
    fn drain_is_clean_when_queues_are_empty() {
        let server = StreamServer::start(
            spec(97),
            StreamServerConfig {
                workers: 2,
                ..StreamServerConfig::default()
            },
        )
        .unwrap();
        let id = server.open_session();
        for _ in 0..3 {
            server.frame(id, vec![2, 5]);
        }
        let rep = server.shutdown_within(Duration::from_secs(5));
        assert!(rep.clean, "no queued work, drain must be clean");
        assert_eq!(rep.shed, 0);
        assert!(rep.drain_ms >= 0.0);
    }

    #[test]
    fn drain_accounts_every_admitted_frame() {
        let server = StreamServer::start(
            spec(99),
            StreamServerConfig {
                workers: 1,
                ..StreamServerConfig::default()
            },
        )
        .unwrap();
        let id = server.open_session();
        let rxs: Vec<_> = (0..16)
            .map(|_| server.submit_frame(id, vec![0, 7]))
            .collect();
        // Zero-deadline drain: whatever is still queued is shed, but
        // every admitted frame must still get exactly one outcome.
        let rep = server.shutdown_within(Duration::ZERO);
        let mut served = 0u64;
        let mut shed = 0u64;
        for rx in rxs {
            match rx.recv().expect("every admitted frame answers") {
                FrameOutcome::Served(_) => served += 1,
                FrameOutcome::Shed { reason, .. } => {
                    assert_eq!(reason, ShedReason::Draining);
                    shed += 1;
                }
            }
        }
        assert_eq!(served + shed, 16, "no frame lost, none double-counted");
        assert_eq!(rep.shed, shed, "drain report matches client view");
        assert_eq!(rep.clean, shed == 0);
        let snap = server.metrics.snapshot();
        assert_eq!(snap.requests, served);
        assert_eq!(snap.sheds_drain, shed);
    }
}

//! Streaming session server (DESIGN.md S18): `serve --backend stream`.
//!
//! Serving a temporal SNN differs from the one-shot `MacroServer` in
//! one essential way: a request is not a vector, it is a *session* — an
//! ordered frame stream whose state (per-stage LIF membranes) must
//! survive between frames. The server keeps the weights stationary and
//! the state mobile:
//!
//! * every worker owns one deployed [`SpikingMlp`] (weights programmed
//!   once, like `MacroServer`'s per-worker macro);
//! * a session is pinned to `worker = id % workers`, so its frames are
//!   processed in submission order (worker channels are FIFO) — the
//!   temporal analogue of the scheduler's weight-stationary affinity;
//! * per-session membrane snapshots are swapped into the worker's
//!   model around each frame ([`SpikingMlp::swap_state`]) — membranes
//!   are a few hundred f64s, the macros are the expensive part.
//!
//! Per-frame serving metrics flow into the shared [`Metrics`]:
//! latency (`record_request`), energy (`record_energy`), occupancy
//! (`record_activity` with macro row slots across all stages), and
//! MACs. Session replies carry the running readout membranes, so a
//! client can take the argmax at any timestep (anytime inference).
//!
//! Reliability (DESIGN.md S19): when the config carries a
//! [`FaultPlan`], each worker owns a per-shard fault state alongside
//! its model and a golden code snapshot taken at deployment. Simulated
//! retention drift ([`StreamServer::drift`]) and verify-and-rewrite
//! scrubs ([`StreamServer::scrub_now`], or a background
//! [`Scrubber`] via [`StreamServer::start_scrubber`]) travel through
//! the same per-worker FIFOs as frames, so scrub work interleaves with
//! serving at session granularity — it can never race a frame on the
//! worker's model, which is what makes the scrub-vs-serve bit-identity
//! assertion in `rust/tests/stream_e2e.rs` possible.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::{FabricConfig, LevelMap, MacroConfig, StreamConfig};
use crate::coordinator::{Metrics, ScrubPolicy, Scrubber};
use crate::device::{FaultPlan, FaultState, ScrubOutcome, SotWriteParams};
use crate::obs::{self, TraceKind};
use crate::snn::dataset::Dataset;
use crate::snn::mlp::Mlp;

use super::snn::SpikingMlp;

/// Everything needed to deploy one [`SpikingMlp`] per worker.
#[derive(Clone)]
pub struct StreamSpec {
    pub model: Mlp,
    pub calib: Dataset,
    pub mcfg: MacroConfig,
    pub fabric: FabricConfig,
    pub level_map: LevelMap,
    pub stream: StreamConfig,
}

impl StreamSpec {
    /// Deploy the spec (quantize, calibrate, place on the mesh).
    pub fn build(&self) -> Result<SpikingMlp> {
        SpikingMlp::from_float(
            &self.model,
            &self.calib,
            &self.mcfg,
            self.fabric.clone(),
            self.level_map,
            &self.stream,
        )
    }
}

/// One session reply: the state of the readout after a frame.
#[derive(Debug, Clone)]
pub struct StreamReply {
    pub session: u64,
    /// Timesteps this session has processed (after this frame).
    pub t: usize,
    /// Readout membranes (running evidence).
    pub out_v: Vec<f64>,
    /// Argmax of the digit classes at this timestep.
    pub label: usize,
}

enum StreamJob {
    Frame {
        session: u64,
        events: Vec<u32>,
        submitted: Instant,
        reply: mpsc::Sender<StreamReply>,
    },
    Finish {
        session: u64,
        reply: mpsc::Sender<StreamReply>,
    },
    /// Advance the worker's simulated clock: retention flips land on
    /// its arrays. Replies with the number of cells changed.
    Drift {
        dt_ns: f64,
        reply: mpsc::Sender<u64>,
    },
    /// Verify-and-rewrite every shard against the worker's golden
    /// snapshot. The reply sender may already be gone (background
    /// scrubber ticks fire and forget).
    Scrub {
        reply: mpsc::Sender<ScrubOutcome>,
    },
}

/// Stream server configuration.
#[derive(Debug, Clone)]
pub struct StreamServerConfig {
    pub workers: usize,
    /// Fault-injection plan (DESIGN.md S19). `None` serves a pristine
    /// fabric; drift/scrub jobs are then no-ops.
    pub faults: Option<FaultPlan>,
}

impl Default for StreamServerConfig {
    fn default() -> Self {
        StreamServerConfig {
            workers: 2,
            faults: None,
        }
    }
}

/// One worker's reliability state: the golden snapshot it scrubs
/// toward, per-shard fault RNG streams, and the write/scrub knobs.
struct ReliabilityCtx {
    golden: Vec<Vec<Vec<u8>>>,
    states: Vec<Vec<FaultState>>,
    wp: SotWriteParams,
    policy: ScrubPolicy,
    /// Deployed shard macros (scrub busy-time = macros × tile time).
    n_macros: u64,
}

struct SessionState {
    /// Per-stage membrane snapshot.
    state: Vec<Vec<f64>>,
    /// Timesteps processed so far.
    t: usize,
}

/// A running streaming-SNN service.
pub struct StreamServer {
    txs: Vec<mpsc::Sender<StreamJob>>,
    pub metrics: Arc<Metrics>,
    handles: Vec<JoinHandle<()>>,
    next_session: AtomicU64,
    in_dim: usize,
}

impl StreamServer {
    /// Deploy one model per worker and start the session loops. Fails
    /// fast (on the caller's thread) when the spec cannot deploy, e.g.
    /// the mesh is too small for the layer shards.
    pub fn start(
        spec: StreamSpec,
        scfg: StreamServerConfig,
    ) -> Result<StreamServer> {
        assert!(scfg.workers >= 1, "at least one worker");
        let metrics = Arc::new(Metrics::new());
        let mut txs = Vec::with_capacity(scfg.workers);
        let mut handles = Vec::with_capacity(scfg.workers);
        let mut in_dim = 0;
        for w in 0..scfg.workers {
            let mut mlp = spec.build()?;
            in_dim = mlp.in_dim();
            let rel = scfg.faults.map(|plan| {
                // Golden = intended codes, captured before any fault
                // touches the arrays: scrub restores toward *this*.
                let golden = mlp.snapshot_codes();
                // Distinct per-worker seed: each replica is its own
                // die and drifts independently.
                let wplan = FaultPlan {
                    seed: plan.seed.wrapping_add(1 + w as u64),
                    ..plan
                };
                let mut states = mlp.fault_states(wplan);
                mlp.deploy_faults(&mut states);
                let n_macros =
                    golden.iter().map(|s| s.len() as u64).sum::<u64>();
                ReliabilityCtx {
                    golden,
                    states,
                    wp: SotWriteParams::default(),
                    policy: ScrubPolicy::standard(),
                    n_macros,
                }
            });
            let (tx, rx) = mpsc::channel::<StreamJob>();
            let m = metrics.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("spikemram-stream-{w}"))
                    .spawn(move || worker_loop(mlp, rx, m, rel))
                    .expect("spawn stream worker"),
            );
            txs.push(tx);
        }
        Ok(StreamServer {
            txs,
            metrics,
            handles,
            next_session: AtomicU64::new(0),
            in_dim,
        })
    }

    /// Open a new session (fresh membranes on first frame). Sessions
    /// are sticky to one worker, so frames submitted in order are
    /// processed in order.
    pub fn open_session(&self) -> u64 {
        self.next_session.fetch_add(1, Ordering::Relaxed)
    }

    fn tx_for(&self, session: u64) -> &mpsc::Sender<StreamJob> {
        &self.txs[(session as usize) % self.txs.len()]
    }

    /// Submit one timestep frame (sorted active-row event list).
    ///
    /// The frame is validated here, on the *caller's* thread — a
    /// malformed list must fail the offending caller, not panic a
    /// shared worker and take every session pinned to it down with
    /// opaque disconnect errors.
    pub fn submit_frame(
        &self,
        session: u64,
        events: Vec<u32>,
    ) -> mpsc::Receiver<StreamReply> {
        let mut prev: i64 = -1;
        for &r in &events {
            assert!(
                (r as usize) < self.in_dim,
                "event row {r} of {}",
                self.in_dim
            );
            assert!(
                i64::from(r) > prev,
                "event list must be sorted ascending without duplicates"
            );
            prev = i64::from(r);
        }
        let (rtx, rrx) = mpsc::channel();
        self.tx_for(session)
            .send(StreamJob::Frame {
                session,
                events,
                submitted: Instant::now(),
                reply: rtx,
            })
            .expect("workers alive");
        rrx
    }

    /// Submit and wait.
    pub fn frame(&self, session: u64, events: Vec<u32>) -> StreamReply {
        self.submit_frame(session, events).recv().expect("reply")
    }

    /// Close a session: returns its final reply and drops its state.
    pub fn finish(&self, session: u64) -> StreamReply {
        let (rtx, rrx) = mpsc::channel();
        self.tx_for(session)
            .send(StreamJob::Finish {
                session,
                reply: rtx,
            })
            .expect("workers alive");
        rrx.recv().expect("reply")
    }

    /// Advance every worker's simulated clock by `dt_ns` (retention
    /// drift lands in place, interleaved with any in-flight frames).
    /// Returns the total cells flipped across all workers; 0 when the
    /// server runs without a fault plan.
    pub fn drift(&self, dt_ns: f64) -> u64 {
        let rxs: Vec<_> = self
            .txs
            .iter()
            .map(|tx| {
                let (rtx, rrx) = mpsc::channel();
                tx.send(StreamJob::Drift { dt_ns, reply: rtx })
                    .expect("workers alive");
                rrx
            })
            .collect();
        rxs.into_iter().map(|rx| rx.recv().expect("reply")).sum()
    }

    /// Scrub every worker's fabric against its golden snapshot and
    /// wait for completion (the synchronous path; the background
    /// [`Scrubber`] uses the same job type, fire-and-forget).
    pub fn scrub_now(&self) -> ScrubOutcome {
        let rxs: Vec<_> = self
            .txs
            .iter()
            .map(|tx| {
                let (rtx, rrx) = mpsc::channel();
                tx.send(StreamJob::Scrub { reply: rtx })
                    .expect("workers alive");
                rrx
            })
            .collect();
        let mut out = ScrubOutcome::default();
        for rx in rxs {
            out.absorb(&rx.recv().expect("reply"));
        }
        out
    }

    /// Start a background scrubber ticking every `period` of wall
    /// time. Each tick enqueues one scrub job per worker; the jobs
    /// drain through the same FIFOs as frames, so they interleave with
    /// serving instead of racing it. Call [`Scrubber::stop`] before
    /// [`shutdown`](StreamServer::shutdown).
    pub fn start_scrubber(&self, period: Duration) -> Scrubber {
        let txs = self.txs.clone();
        Scrubber::start(period, move |_round| {
            for tx in &txs {
                let (rtx, _rrx) = mpsc::channel();
                // Tolerate shutdown racing a tick: a closed channel
                // just means there is nothing left to scrub.
                let _ = tx.send(StreamJob::Scrub { reply: rtx });
            }
        })
    }

    /// Stop accepting work and join the workers.
    pub fn shutdown(mut self) {
        self.txs.clear(); // closes every channel; workers drain & exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(
    mut mlp: SpikingMlp,
    rx: mpsc::Receiver<StreamJob>,
    metrics: Arc<Metrics>,
    mut rel: Option<ReliabilityCtx>,
) {
    let mut sessions: HashMap<u64, SessionState> = HashMap::new();
    while let Ok(job) = rx.recv() {
        match job {
            StreamJob::Frame {
                session,
                events,
                submitted,
                reply,
            } => {
                // S20 span: dequeue → reply, payload = channel wait
                // (µs) + this frame's macro row activations.
                let mut span = obs::Span::begin(TraceKind::ServeFrame, 0);
                let queue_wait_us = if span.active() {
                    submitted.elapsed().as_secs_f64() * 1e6
                } else {
                    0.0
                };
                let sess = sessions.entry(session).or_insert_with(|| {
                    SessionState {
                        state: mlp.fresh_state(),
                        t: 0,
                    }
                });
                mlp.swap_state(&mut sess.state);
                let step = mlp.step_frame(&events);
                sess.t += 1;
                let out = StreamReply {
                    session,
                    t: sess.t,
                    out_v: mlp.out_membranes().to_vec(),
                    label: mlp.label(),
                };
                mlp.swap_state(&mut sess.state);
                metrics.record_batch(1, step.macs);
                metrics.record_activity(step.active_rows, step.row_slots);
                metrics.record_energy(step.energy.total_fj());
                metrics.record_noc(step.noc_packets, step.noc_hops);
                metrics
                    .record_request(submitted.elapsed().as_secs_f64() * 1e6);
                span.note(queue_wait_us, step.active_rows as f64);
                // Per-frame telemetry series (each gated on its own
                // kind inside `counter`).
                if span.active() {
                    let occ = if step.row_slots == 0 {
                        0.0
                    } else {
                        step.active_rows as f64 / step.row_slots as f64
                    };
                    obs::counter(TraceKind::Occupancy, 0, occ);
                    obs::counter(
                        TraceKind::EnergyFj,
                        0,
                        step.energy.total_fj(),
                    );
                }
                let _ = reply.send(out); // receiver may have gone away
            }
            StreamJob::Finish { session, reply } => {
                let out = match sessions.remove(&session) {
                    Some(mut sess) => {
                        mlp.swap_state(&mut sess.state);
                        let r = StreamReply {
                            session,
                            t: sess.t,
                            out_v: mlp.out_membranes().to_vec(),
                            label: mlp.label(),
                        };
                        mlp.swap_state(&mut sess.state);
                        r
                    }
                    None => StreamReply {
                        session,
                        t: 0,
                        out_v: vec![0.0; mlp.out_dim()],
                        label: 0,
                    },
                };
                let _ = reply.send(out);
            }
            StreamJob::Drift { dt_ns, reply } => {
                let flips = match rel.as_mut() {
                    Some(ctx) => mlp.drift(&mut ctx.states, dt_ns),
                    None => 0,
                };
                metrics.record_fault_injection(flips, dt_ns);
                let _ = reply.send(flips);
            }
            StreamJob::Scrub { reply } => {
                // S20 span (stage 0 = in-worker scrub execution; the
                // background tick records stage 1).
                let mut span = obs::Span::begin(TraceKind::ScrubPass, 0);
                let out = match rel.as_mut() {
                    Some(ctx) => {
                        let o =
                            mlp.scrub(&mut ctx.states, &ctx.golden, &ctx.wp);
                        let busy = ctx.policy.scrub_duration_ns
                            * ctx.n_macros as f64;
                        metrics.record_scrub(
                            o.mismatched as u64,
                            o.repaired as u64,
                            o.energy_fj,
                            busy,
                        );
                        o
                    }
                    None => ScrubOutcome::default(),
                };
                span.note(0.0, out.repaired as f64);
                let _ = reply.send(out); // background ticks don't wait
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::encode::{FrameEncoder, TemporalCode};

    fn spec(seed: u64) -> StreamSpec {
        StreamSpec {
            model: Mlp::new(seed),
            calib: Dataset::generate(24, seed ^ 0x9),
            mcfg: MacroConfig::default(),
            fabric: FabricConfig::square(2),
            level_map: LevelMap::DeviceTrue,
            stream: StreamConfig::default(),
        }
    }

    #[test]
    fn interleaved_sessions_match_serial_runs_bitwise() {
        let sp = spec(61);
        let mut serial = sp.build().unwrap();
        let enc = FrameEncoder::new(TemporalCode::Rate, 5, 255);
        let data = Dataset::generate(6, 77);
        let server = StreamServer::start(
            sp,
            StreamServerConfig {
                workers: 2,
                ..StreamServerConfig::default()
            },
        )
        .unwrap();

        // Three concurrent sessions, frames interleaved round-robin.
        let frames: Vec<Vec<Vec<u32>>> = (0..3)
            .map(|i| enc.encode_frames(&data.features_u8(i)))
            .collect();
        let ids: Vec<u64> = (0..3).map(|_| server.open_session()).collect();
        for t in 0..5 {
            for (s, id) in ids.iter().enumerate() {
                let r = server.frame(*id, frames[s][t].clone());
                assert_eq!(r.t, t + 1);
            }
        }
        for (s, id) in ids.iter().enumerate() {
            let want = serial.run(&frames[s]);
            let got = server.finish(*id);
            assert_eq!(got.t, 5);
            assert_eq!(got.out_v, want.out_v, "session {s} membranes");
            assert_eq!(got.label, want.label);
        }

        let snap = server.metrics.snapshot();
        assert_eq!(snap.requests, 15, "one request per frame");
        assert_eq!(snap.batches, 15);
        assert!(snap.energy_fj > 0.0, "per-timestep energy recorded");
        assert!(snap.row_slots > 0);
        let d = snap.input_density();
        assert!(d > 0.0 && d < 1.0, "occupancy {d}");
        server.shutdown();
    }

    #[test]
    fn finishing_an_unknown_session_is_benign() {
        let server =
            StreamServer::start(spec(63), StreamServerConfig::default())
                .unwrap();
        let r = server.finish(1234);
        assert_eq!(r.t, 0);
        assert!(r.out_v.iter().all(|&v| v == 0.0));
        server.shutdown();
    }

    #[test]
    #[should_panic(expected = "sorted ascending")]
    fn malformed_frame_fails_the_caller_not_the_worker() {
        let server =
            StreamServer::start(spec(67), StreamServerConfig::default())
                .unwrap();
        let id = server.open_session();
        let _ = server.submit_frame(id, vec![5, 3]);
    }

    #[test]
    fn faultless_server_treats_drift_and_scrub_as_noops() {
        let server =
            StreamServer::start(spec(71), StreamServerConfig::default())
                .unwrap();
        assert_eq!(server.drift(1e9), 0);
        assert_eq!(server.scrub_now(), ScrubOutcome::default());
        let snap = server.metrics.snapshot();
        assert_eq!(snap.flips_injected, 0);
        assert_eq!(snap.flips_repaired, 0);
        server.shutdown();
    }

    #[test]
    fn drift_then_scrub_restores_serving_bitwise() {
        use crate::device::RetentionParams;
        let sp = spec(73);
        let mut serial = sp.build().unwrap();
        let enc = FrameEncoder::new(TemporalCode::Rate, 4, 255);
        let data = Dataset::generate(4, 79);
        let frames = enc.encode_frames(&data.features_u8(0));
        let want = serial.run(&frames);

        let plan = FaultPlan::drift_only(RetentionParams::stress(), 81);
        let server = StreamServer::start(
            sp,
            StreamServerConfig {
                workers: 2,
                faults: Some(plan),
            },
        )
        .unwrap();
        let flips = server.drift(plan.retention.tau_ret_ns());
        assert!(flips > 0, "stress drift at t=τ must flip cells");
        let out = server.scrub_now();
        assert_eq!(out.repaired, flips as usize, "full repair");
        assert!(out.energy_fj > 0.0);

        // Post-scrub, every worker replica serves the pristine answer.
        for _ in 0..2 {
            let id = server.open_session();
            for f in &frames {
                server.frame(id, f.clone());
            }
            let got = server.finish(id);
            assert_eq!(got.out_v, want.out_v);
            assert_eq!(got.label, want.label);
        }

        let snap = server.metrics.snapshot();
        assert_eq!(snap.flips_injected, flips);
        assert_eq!(snap.flips_detected, flips);
        assert_eq!(snap.flips_repaired, flips);
        assert_eq!(snap.scrubs, 2, "one scrub per worker");
        assert!(snap.scrub_energy_fj > 0.0);
        assert!(snap.scrub_duty_cycle() > 0.0);
        server.shutdown();
    }

    #[test]
    fn too_small_mesh_fails_at_start() {
        let sp = StreamSpec {
            fabric: FabricConfig::square(1),
            ..spec(65)
        };
        let err = StreamServer::start(sp, StreamServerConfig::default())
            .err()
            .expect("placement must fail");
        assert!(err.to_string().contains("exceed"), "{err}");
    }
}

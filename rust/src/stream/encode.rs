//! Static-input → temporal-frame re-encoding (DESIGN.md S18): unrolls a
//! static 8-bit input vector into T binary timestep frames through the
//! *existing* §II-B codecs — [`RateCodec`] (spike count over the window)
//! or [`TtfsCodec`] (single spike, earlier = larger) — so the streaming
//! runtime consumes exactly the codes the paper compares against.
//!
//! A frame is a sorted list of active row indices: precisely the event
//! list `CimMacro::mvm_events` takes. Zero values emit nothing in
//! either code (the event-driven convention; note this deviates from a
//! raw TTFS decoder, which would reserve the *latest* slot for zero —
//! here that slot is simply never used, and an absent spike decodes to
//! zero).
//!
//! Accumulated decode (`decode_accumulated`) reconstructs the static
//! value from the frames to within [`quant_tolerance`] — the round-trip
//! contract the encoder tests pin down, including all-zero and
//! saturating inputs.
//!
//! [`quant_tolerance`]: FrameEncoder::quant_tolerance

use crate::coding::{RateCodec, TtfsCodec};

/// Which temporal code unrolls static values into frames.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TemporalCode {
    /// Value → spike *count*: n = round(x·T/x_max) spikes in the first
    /// n frames (the codec's evenly spaced times land one per frame).
    Rate,
    /// Value → spike *position*: one spike at frame T−1−q with
    /// q = round(x·(T−1)/x_max); requires T a power of two (the codec's
    /// bit-width constraint). Far sparser than rate coding — at most
    /// one spike per row over the whole stream.
    Ttfs,
}

/// Re-encoder from static values to T binary frames and back.
#[derive(Debug, Clone)]
pub struct FrameEncoder {
    pub code: TemporalCode,
    /// Timesteps per inference (T ≥ 1).
    pub t_steps: usize,
    /// Static full scale (255 for 8-bit pixels); inputs saturate here.
    pub max_in: u32,
    /// Rate codec over a T-frame window (1 ns frames, max T spikes).
    rate: RateCodec,
    /// TTFS codec with a 1-frame LSB; `None` for `Rate` or T = 1.
    ttfs: Option<TtfsCodec>,
}

impl FrameEncoder {
    pub fn new(code: TemporalCode, t_steps: usize, max_in: u32) -> Self {
        assert!(t_steps >= 1, "at least one timestep");
        assert!(max_in >= 1, "full scale");
        let ttfs = match code {
            TemporalCode::Ttfs if t_steps > 1 => {
                assert!(
                    t_steps.is_power_of_two() && t_steps <= 1 << 16,
                    "TTFS frames must be a power of two (codec bit-width)"
                );
                Some(TtfsCodec::new(1.0, t_steps.trailing_zeros()))
            }
            _ => None,
        };
        FrameEncoder {
            code,
            t_steps,
            max_in,
            rate: RateCodec::new(t_steps as f64, t_steps as u32),
            ttfs,
        }
    }

    /// Quantize a static value onto this code's temporal alphabet:
    /// spike count for `Rate` (0..=T), level for `Ttfs` (0..=T−1).
    pub fn quantize(&self, x: u32) -> u32 {
        let x = x.min(self.max_in) as f64;
        let levels = match self.code {
            TemporalCode::Rate => self.t_steps,
            TemporalCode::Ttfs => self.t_steps - 1,
        }
        .max(1) as f64;
        (x * levels / self.max_in as f64).round() as u32
    }

    /// Reconstruct the static value from its temporal alphabet symbol.
    pub fn dequantize(&self, q: u32) -> u32 {
        let levels = match self.code {
            TemporalCode::Rate => self.t_steps,
            TemporalCode::Ttfs => self.t_steps - 1,
        }
        .max(1) as f64;
        (q.min(levels as u32) as f64 * self.max_in as f64 / levels).round()
            as u32
    }

    /// Encode a static vector into T frames of sorted active-row lists.
    pub fn encode_frames(&self, x: &[u32]) -> Vec<Vec<u32>> {
        let mut frames: Vec<Vec<u32>> = vec![Vec::new(); self.t_steps];
        for (r, &xv) in x.iter().enumerate() {
            match self.code {
                TemporalCode::Rate => {
                    // The codec's spike times are i·(window/T) for
                    // i < n — exactly one per unit-width frame bin.
                    let period = self.rate.window_ns
                        / self.rate.max_spikes as f64;
                    for t_ns in self.rate.encode(self.quantize(xv)) {
                        frames[(t_ns / period) as usize].push(r as u32);
                    }
                }
                TemporalCode::Ttfs => {
                    let q = self.quantize(xv);
                    if q == 0 {
                        continue; // zero emits nothing (event-driven)
                    }
                    let f = match &self.ttfs {
                        // 1-frame LSB: the codec's spike time IS the
                        // frame index (earlier = larger value).
                        Some(c) => c.encode(q).round() as usize,
                        None => 0, // T = 1: the only frame
                    };
                    frames[f].push(r as u32);
                }
            }
        }
        frames
    }

    /// Accumulate T frames back into static values — the inverse of
    /// [`encode_frames`](Self::encode_frames) up to
    /// [`quant_tolerance`](Self::quant_tolerance).
    pub fn decode_accumulated(
        &self,
        frames: &[Vec<u32>],
        rows: usize,
    ) -> Vec<u32> {
        assert_eq!(frames.len(), self.t_steps, "frame count");
        match self.code {
            TemporalCode::Rate => {
                // Count spikes per row (what RateCodec::decode does to
                // a spike train) and map the count back to the value.
                let mut counts = vec![0u32; rows];
                for frame in frames {
                    for &r in frame {
                        counts[r as usize] += 1;
                    }
                }
                counts.into_iter().map(|c| self.dequantize(c)).collect()
            }
            TemporalCode::Ttfs => {
                // First (only) spike position per row → level → value.
                let mut out = vec![0u32; rows];
                for (f, frame) in frames.iter().enumerate() {
                    for &r in frame {
                        if out[r as usize] == 0 {
                            let q = match &self.ttfs {
                                Some(c) => c.decode(f as f64),
                                None => 1, // T = 1: any spike is full scale
                            };
                            out[r as usize] = self.dequantize(q);
                        }
                    }
                }
                out
            }
        }
    }

    /// Worst-case |decode − encode input| of the round trip: half a
    /// temporal quantization step (the whole scale for T = 1).
    pub fn quant_tolerance(&self) -> u32 {
        let levels = match self.code {
            TemporalCode::Rate => self.t_steps,
            TemporalCode::Ttfs => self.t_steps - 1,
        }
        .max(1) as u32;
        self.max_in.div_ceil(2 * levels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn probe_values() -> Vec<u32> {
        let mut v = vec![0u32, 1, 64, 127, 128, 200, 254, 255, 400];
        let mut rng = Rng::new(71);
        v.extend((0..32).map(|_| rng.below(256) as u32));
        v
    }

    #[test]
    fn rate_roundtrip_within_quantization_tolerance() {
        // The satellite contract: encode → temporal frames →
        // accumulated decode stays within the T-step quantization of
        // the static window encoding, for every T.
        for t in [1usize, 2, 4, 8, 16] {
            let enc = FrameEncoder::new(TemporalCode::Rate, t, 255);
            let x = probe_values();
            let frames = enc.encode_frames(&x);
            assert_eq!(frames.len(), t);
            let got = enc.decode_accumulated(&frames, x.len());
            let tol = enc.quant_tolerance();
            for (r, (&xv, &g)) in x.iter().zip(&got).enumerate() {
                let want = xv.min(255);
                assert!(
                    (g as i64 - want as i64).unsigned_abs() <= tol as u64,
                    "T={t} row {r}: {want} -> {g} (tol {tol})"
                );
            }
        }
    }

    #[test]
    fn ttfs_roundtrip_within_quantization_tolerance() {
        for t in [1usize, 2, 4, 8, 16] {
            let enc = FrameEncoder::new(TemporalCode::Ttfs, t, 255);
            let x = probe_values();
            let frames = enc.encode_frames(&x);
            let got = enc.decode_accumulated(&frames, x.len());
            let tol = enc.quant_tolerance();
            for (r, (&xv, &g)) in x.iter().zip(&got).enumerate() {
                let want = xv.min(255);
                assert!(
                    (g as i64 - want as i64).unsigned_abs() <= tol as u64,
                    "T={t} row {r}: {want} -> {g} (tol {tol})"
                );
            }
            // TTFS sends at most one spike per row over the stream.
            let mut seen = vec![0u32; x.len()];
            for frame in &frames {
                for &r in frame {
                    seen[r as usize] += 1;
                }
            }
            assert!(seen.iter().all(|&c| c <= 1));
        }
    }

    #[test]
    fn zero_and_saturating_inputs_are_exact() {
        for code in [TemporalCode::Rate, TemporalCode::Ttfs] {
            for t in [1usize, 4, 16] {
                let enc = FrameEncoder::new(code, t, 255);
                let frames = enc.encode_frames(&[0, 255, 0, 300]);
                // All-zero rows never appear in any frame.
                for frame in &frames {
                    assert!(!frame.contains(&0));
                    assert!(!frame.contains(&2));
                }
                let got = enc.decode_accumulated(&frames, 4);
                assert_eq!(got[0], 0, "{code:?} T={t}");
                assert_eq!(got[1], 255, "saturating input decodes exactly");
                assert_eq!(got[3], 255, "above-scale input saturates");
            }
        }
        // An all-zero vector produces T empty frames.
        let enc = FrameEncoder::new(TemporalCode::Rate, 8, 255);
        assert!(enc
            .encode_frames(&[0u32; 32])
            .iter()
            .all(|f| f.is_empty()));
    }

    #[test]
    fn frames_are_sorted_event_lists() {
        let mut rng = Rng::new(73);
        let x: Vec<u32> =
            (0..200).map(|_| rng.below(256) as u32).collect();
        for code in [TemporalCode::Rate, TemporalCode::Ttfs] {
            let enc = FrameEncoder::new(code, 8, 255);
            for frame in enc.encode_frames(&x) {
                assert!(frame.windows(2).all(|w| w[0] < w[1]), "{code:?}");
            }
        }
    }

    #[test]
    fn rate_frames_agree_with_raw_codec() {
        // The adapter is a *binning* of RateCodec, not a reimplementation:
        // row r's spike count across frames equals the codec's count.
        let enc = FrameEncoder::new(TemporalCode::Rate, 8, 255);
        let codec = RateCodec::new(8.0, 8);
        for x in [0u32, 31, 128, 255] {
            let frames = enc.encode_frames(&[x]);
            let count: usize =
                frames.iter().map(|f| f.len()).sum();
            assert_eq!(count as u32, codec.decode(&codec.encode(enc.quantize(x))));
        }
    }

    #[test]
    fn ttfs_frames_agree_with_raw_codec() {
        // Larger values spike earlier, exactly at the codec's slot.
        let enc = FrameEncoder::new(TemporalCode::Ttfs, 16, 255);
        let codec = TtfsCodec::new(1.0, 4);
        for x in [17u32, 100, 255] {
            let frames = enc.encode_frames(&[x]);
            let f = frames
                .iter()
                .position(|fr| !fr.is_empty())
                .expect("nonzero value spikes");
            assert_eq!(f, codec.encode(enc.quantize(x)).round() as usize);
        }
        let lo = enc.encode_frames(&[40]);
        let hi = enc.encode_frames(&[240]);
        let pos = |fs: &[Vec<u32>]| fs.iter().position(|f| !f.is_empty());
        assert!(pos(&hi) < pos(&lo), "larger value spikes earlier");
    }
}

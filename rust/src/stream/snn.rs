//! The time-stepped spiking MLP (DESIGN.md S18): the quantized digit
//! model deployed as a *temporal* network — per-stage LIF membranes
//! carried across timesteps, every timestep's binary spike vector fed
//! straight into the macro fabric as an active-row event list
//! (`LayerStage::run_events` → `CimMacro::mvm_events`; no window matrix
//! is ever built).
//!
//! Rate-domain semantics (data-based normalization, the standard
//! ANN→SNN conversion): spikes entering stage l each carry the float
//! value λ_{l−1} (λ_0 = 1 — pixels arrive as x/255 rates), the stage's
//! per-step drive is `scale·(mac − G_mid·n_active)·λ_{l−1} + bias`, and
//! its LIF threshold is λ_l (the calibrated activation ceiling). A
//! neuron's firing rate then tracks `h_l/λ_l`, so accumulated output
//! membranes approach `T · logits` as T grows — the accuracy-vs-T knob
//! `repro::stream` sweeps. The readout stage never fires; it integrates
//! (λ_leak = 0) and the label is the argmax of its membranes.
//!
//! Bit-identity rule: a timestep is processed stage by stage in fixed
//! neuron order with f64 state, and per-run statistics are folded
//! per-stage first, then across stages in stage order — the pipelined
//! executor (`stream::exec`) reproduces both orders exactly, so serial
//! and pipelined runs agree *bitwise* (membranes, spike trains, energy
//! tallies; asserted in `rust/tests/stream_e2e.rs`).

use anyhow::Result;

use crate::baselines::DiscreteLif;
use crate::config::{FabricConfig, LevelMap, MacroConfig, StreamConfig};
use crate::coordinator::TiledMatrix;
use crate::device::faults::{FaultPlan, FaultState, ScrubOutcome};
use crate::device::SotWriteParams;
use crate::energy::EnergyBreakdown;
use crate::fabric::{FabricChip, LayerResult, LayerStage};
use crate::obs::{self, TraceKind};
use crate::snn::collect_activations;
use crate::snn::dataset::Dataset;
use crate::snn::mlp::Mlp;
use crate::snn::quant::{quantize_layer, ActQuant};

/// Argmax over f64 membranes (ties break to the lower index).
fn argmax64(xs: &[f64]) -> usize {
    let mut best = 0usize;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

/// One deployed layer: its fabric stage (weight-stationary shard
/// macros + NoC endpoints) plus the temporal state and the dequant
/// constants that turn a binary-spike MAC into membrane drive.
pub(crate) struct SpikingStage {
    pub(crate) stage: LayerStage,
    /// Stage index in the deployed network (the S20 span `stage` tag).
    idx: u16,
    /// Weight scale s of the quantized layer.
    scale: f64,
    /// Conductance offset G_mid (signed-weight scheme).
    g_mid: f64,
    /// Digital bias, added to the drive every timestep.
    bias: Vec<f32>,
    /// Float value one incoming spike carries (λ_{l−1}; 1.0 for the
    /// pixel-rate input layer).
    in_unit: f64,
    /// Membrane state, resident across timesteps.
    pub(crate) lif: DiscreteLif,
    /// Readout stages integrate and never fire.
    readout: bool,
    /// Dense MAC count of one timestep (k·n, the serving convention).
    macs_per_step: u64,
    /// Macro row slots offered per timestep (shards × tile rows).
    slots_per_step: u64,
    /// Reusable per-step drive buffer (no per-timestep allocation on
    /// the streaming hot path).
    cur: Vec<f64>,
}

impl SpikingStage {
    /// One timestep: binary input event list → (output event list,
    /// macro-level result). The output list of a readout stage is
    /// always empty; read its membranes instead.
    pub(crate) fn step(&mut self, events: &[u32]) -> (Vec<u32>, LayerResult) {
        // S20 span: one stage-timestep; payload = spikes in / spikes out.
        let mut span = obs::Span::begin(TraceKind::StreamStage, self.idx);
        let r = self.stage.run_events(events);
        let mac = self.stage.tiled.accumulate(&r.partials);
        let n_active = events.len() as f64;
        let (scale, g_mid, in_unit) = (self.scale, self.g_mid, self.in_unit);
        let bias = &self.bias;
        self.cur.clear();
        self.cur.extend(mac.iter().enumerate().map(|(o, &m)| {
            scale * (m - g_mid * n_active) * in_unit
                + bias.get(o).copied().unwrap_or(0.0) as f64
        }));
        // `out` is owned per step by design: it leaves the stage (next
        // stage's input / pipeline message / spike-train record).
        let mut out = Vec::new();
        if self.readout {
            self.lif.integrate(&self.cur);
        } else {
            self.lif.step(&self.cur, &mut out);
        }
        span.note(events.len() as f64, out.len() as f64);
        (out, r)
    }

    /// Fold one timestep's result into this stage's running tally —
    /// the single accumulation order both the serial loop and the
    /// pipelined executor use (bit-identity rule above).
    pub(crate) fn tally_into(
        &self,
        t: &mut StageTally,
        r: &LayerResult,
        out: &[u32],
    ) {
        t.energy.add(&r.energy);
        t.latency_ns += r.latency_ns;
        t.active_rows += r.active_rows;
        t.row_slots += self.slots_per_step;
        t.macs += self.macs_per_step;
        t.packets += r.packets;
        t.hops += r.hops;
        t.spikes += out.len() as u64;
    }
}

/// One stage's running statistics over a stream.
#[derive(Debug, Clone, Default)]
pub(crate) struct StageTally {
    pub energy: EnergyBreakdown,
    pub latency_ns: f64,
    pub active_rows: u64,
    pub row_slots: u64,
    pub macs: u64,
    pub packets: u64,
    pub hops: u64,
    pub spikes: u64,
}

/// Aggregate statistics of one streamed inference.
#[derive(Debug, Clone, Default)]
pub struct StreamStats {
    /// Timesteps processed.
    pub timesteps: usize,
    pub energy: EnergyBreakdown,
    /// Σ per-timestep per-stage modeled latency (model time; the
    /// pipelined executor buys wall-clock, not model time).
    pub latency_ns: f64,
    /// Dense MAC count (k·n per stage per step, the Table II
    /// convention).
    pub macs: u64,
    /// Macro row activations across all stages and steps.
    pub active_rows: u64,
    /// Macro row slots offered (stages × shards × tile × steps).
    pub row_slots: u64,
    pub noc_packets: u64,
    pub noc_hops: u64,
    /// Input spikes consumed (Σ frame lengths).
    pub in_spikes: u64,
    /// Spikes emitted per stage (readout entry is always 0).
    pub layer_spikes: Vec<u64>,
}

impl StreamStats {
    /// Fraction of offered row slots that carried a spike (0 before
    /// any traffic — never NaN).
    pub fn occupancy(&self) -> f64 {
        if self.row_slots == 0 {
            0.0
        } else {
            self.active_rows as f64 / self.row_slots as f64
        }
    }

    /// All spikes moved this run (input + every stage's output).
    pub fn spikes_total(&self) -> u64 {
        self.in_spikes + self.layer_spikes.iter().sum::<u64>()
    }
}

/// One streamed inference's outcome.
#[derive(Debug, Clone)]
pub struct StreamRun {
    /// Argmax of the readout membranes over the digit classes.
    pub label: usize,
    /// Final readout membranes (all 16 padded columns).
    pub out_v: Vec<f64>,
    /// Spike trains: `trains[stage][t]` is the event list stage `stage`
    /// emitted at timestep `t` (readout rows are empty).
    pub trains: Vec<Vec<Vec<u32>>>,
    pub stats: StreamStats,
}

/// One timestep's aggregate across all stages (the serving path).
#[derive(Debug, Clone, Default)]
pub struct FrameStep {
    pub energy: EnergyBreakdown,
    pub latency_ns: f64,
    pub active_rows: u64,
    pub row_slots: u64,
    pub macs: u64,
    pub noc_packets: u64,
    pub noc_hops: u64,
    /// Spikes emitted per stage this step.
    pub spikes: Vec<u64>,
}

/// The quantized MLP deployed as a streaming SNN on a fabric chip.
pub struct SpikingMlp {
    pub(crate) stages: Vec<SpikingStage>,
    /// Digit classes scored by the readout (first `classes` membranes).
    pub classes: usize,
}

impl SpikingMlp {
    /// Quantize a trained float model, calibrate the per-layer
    /// normalization thresholds λ on `calib`, and deploy every layer's
    /// weight shards onto a fabric mesh (fails when the mesh cannot
    /// hold them — the 3-layer digit MLP needs 4 tiles).
    pub fn from_float(
        model: &Mlp,
        calib: &Dataset,
        mcfg: &MacroConfig,
        fabric: FabricConfig,
        level_map: LevelMap,
        scfg: &StreamConfig,
    ) -> Result<SpikingMlp> {
        let qs = [
            quantize_layer(
                &model.l1.w,
                &model.l1.b,
                model.l1.in_dim,
                model.l1.out_dim,
                level_map,
            ),
            quantize_layer(
                &model.l2.w,
                &model.l2.b,
                model.l2.in_dim,
                model.l2.out_dim,
                level_map,
            ),
            quantize_layer(
                &model.l3.w,
                &model.l3.b,
                model.l3.in_dim,
                model.l3.out_dim,
                level_map,
            ),
        ];
        let (h1, h2) = collect_activations(model, calib, 64);
        let lam1 = ActQuant::calibrate(&h1, scfg.theta_pct).a_max() as f64;
        let lam2 = ActQuant::calibrate(&h2, scfg.theta_pct).a_max() as f64;

        let tiled: Vec<TiledMatrix> = qs
            .iter()
            .map(|q| TiledMatrix::new(&q.codes, q.in_dim, q.out_dim, mcfg.rows))
            .collect();
        let chip = FabricChip::new(mcfg, fabric, tiled)?;
        let raw = chip.into_stages();

        // Stage l: incoming spikes carry λ_{l−1}, threshold λ_l; the
        // last stage is the integrating readout.
        let in_units = [1.0, lam1, lam2];
        let thresholds = [lam1, lam2, f64::INFINITY];
        let n_stages = raw.len();
        let stages: Vec<SpikingStage> = raw
            .into_iter()
            .zip(qs)
            .enumerate()
            .map(|(l, (stage, q))| {
                let readout = l + 1 == n_stages;
                SpikingStage {
                    idx: l as u16,
                    macs_per_step: (q.in_dim * q.out_dim) as u64,
                    slots_per_step: (stage.tiled.row_tiles
                        * stage.tiled.col_tiles
                        * stage.tiled.tile)
                        as u64,
                    scale: q.scale,
                    g_mid: q.g_mid,
                    bias: q.bias,
                    in_unit: in_units[l],
                    lif: DiscreteLif::new(
                        q.out_dim,
                        thresholds[l],
                        if readout { 0.0 } else { scfg.leak },
                    ),
                    readout,
                    cur: Vec::new(),
                    stage,
                }
            })
            .collect();
        Ok(SpikingMlp {
            stages,
            classes: 10,
        })
    }

    /// Input rows a frame spans (the first layer's width).
    pub fn in_dim(&self) -> usize {
        self.stages[0].stage.tiled.k
    }

    /// Readout width (padded output columns).
    pub fn out_dim(&self) -> usize {
        self.stages.last().expect("stages").lif.v.len()
    }

    /// Zero every stage's membranes (start of a new stream).
    pub fn reset(&mut self) {
        for s in &mut self.stages {
            s.lif.reset();
        }
    }

    /// The readout membranes as they stand.
    pub fn out_membranes(&self) -> &[f64] {
        &self.stages.last().expect("stages").lif.v
    }

    /// Current prediction: argmax of the readout membranes over the
    /// digit classes.
    pub fn label(&self) -> usize {
        argmax64(&self.out_membranes()[..self.classes])
    }

    /// A zeroed membrane snapshot, one vector per stage — the
    /// per-session state the stream server keeps (DESIGN.md S18).
    pub fn fresh_state(&self) -> Vec<Vec<f64>> {
        self.stages.iter().map(|s| vec![0.0; s.lif.v.len()]).collect()
    }

    /// Exchange the resident membranes with `state` (shape-checked):
    /// swap a session in, step frames, swap it back out. The macros
    /// themselves are weight-stationary and stateless across ideal
    /// ops, so one deployed model serves many sessions.
    pub fn swap_state(&mut self, state: &mut [Vec<f64>]) {
        assert_eq!(state.len(), self.stages.len(), "one vector per stage");
        for (s, st) in self.stages.iter_mut().zip(state) {
            assert_eq!(st.len(), s.lif.v.len(), "membrane count");
            std::mem::swap(&mut s.lif.v, st);
        }
    }

    /// Process one timestep through every stage in order, mutating the
    /// resident membranes; returns the step's aggregate tallies (the
    /// serving hot path — per-stage folding is irrelevant for state,
    /// which only depends on the stage-by-stage math).
    pub fn step_frame(&mut self, events: &[u32]) -> FrameStep {
        let mut out = FrameStep::default();
        let mut cur: Vec<u32> = Vec::new();
        for (s, stage) in self.stages.iter_mut().enumerate() {
            let input: &[u32] = if s == 0 { events } else { &cur };
            let (next, r) = stage.step(input);
            out.energy.add(&r.energy);
            out.latency_ns += r.latency_ns;
            out.active_rows += r.active_rows;
            out.row_slots += stage.slots_per_step;
            out.macs += stage.macs_per_step;
            out.noc_packets += r.packets;
            out.noc_hops += r.hops;
            out.spikes.push(next.len() as u64);
            cur = next;
        }
        out
    }

    /// Run a whole frame stream serially (reset → T timesteps stage by
    /// stage). The reference order the pipelined executor is asserted
    /// bitwise against.
    pub fn run(&mut self, frames: &[Vec<u32>]) -> StreamRun {
        self.reset();
        let ns = self.stages.len();
        let mut tallies = vec![StageTally::default(); ns];
        let mut trains: Vec<Vec<Vec<u32>>> = (0..ns)
            .map(|_| Vec::with_capacity(frames.len()))
            .collect();
        let mut in_spikes = 0u64;
        for f in frames {
            in_spikes += f.len() as u64;
            let mut cur: Vec<u32> = Vec::new();
            for (s, stage) in self.stages.iter_mut().enumerate() {
                let input: &[u32] = if s == 0 { f } else { &cur };
                let (next, r) = stage.step(input);
                stage.tally_into(&mut tallies[s], &r, &next);
                trains[s].push(next.clone());
                cur = next;
            }
        }
        self.assemble_run(frames.len(), in_spikes, tallies, trains)
    }

    /// Fold per-stage tallies (in stage order — the shared fold both
    /// execution modes use) and snapshot the readout.
    pub(crate) fn assemble_run(
        &self,
        timesteps: usize,
        in_spikes: u64,
        tallies: Vec<StageTally>,
        trains: Vec<Vec<Vec<u32>>>,
    ) -> StreamRun {
        let mut stats = StreamStats {
            timesteps,
            in_spikes,
            ..StreamStats::default()
        };
        for t in &tallies {
            stats.energy.add(&t.energy);
            stats.latency_ns += t.latency_ns;
            stats.active_rows += t.active_rows;
            stats.row_slots += t.row_slots;
            stats.macs += t.macs;
            stats.noc_packets += t.packets;
            stats.noc_hops += t.hops;
            stats.layer_spikes.push(t.spikes);
        }
        StreamRun {
            label: self.label(),
            out_v: self.out_membranes().to_vec(),
            trains,
            stats,
        }
    }

    // --- reliability runtime (DESIGN.md S19) -------------------------

    /// Golden code snapshot of every deployed shard:
    /// `codes[stage][shard]` is that macro's row-major code matrix —
    /// the scrubber's reference copy. Take it right after deployment,
    /// before any fault plan touches the arrays.
    pub fn snapshot_codes(&self) -> Vec<Vec<Vec<u8>>> {
        self.stages
            .iter()
            .map(|s| {
                s.stage
                    .macros()
                    .iter()
                    .map(|m| m.golden_codes())
                    .collect()
            })
            .collect()
    }

    /// Total SOT write pulses issued across every deployed shard array
    /// (DESIGN.md S22): programming at deploy plus every scrub rewrite
    /// since — the die's endurance ledger, fed to
    /// [`EnduranceParams::wear`](crate::device::EnduranceParams::wear).
    pub fn write_pulses(&self) -> u64 {
        self.stages
            .iter()
            .flat_map(|s| s.stage.macros())
            .map(|m| m.xbar.write_pulses)
            .sum()
    }

    /// Current per-layer normalization thresholds λ_l (hidden stages
    /// only — the values [`recalibrate`](Self::recalibrate) re-derives).
    /// The adaptive endurance controller compares successive snapshots
    /// to decide whether gain drift is still moving the operating point.
    pub fn lambdas(&self) -> Vec<f64> {
        let ns = self.stages.len();
        self.stages[..ns - 1].iter().map(|s| s.lif.v_th).collect()
    }

    /// One [`FaultState`] per deployed shard macro (stage-major), each
    /// with a deterministic per-macro RNG stream forked from the plan's
    /// seed — two models built from the same spec and plan see
    /// identical fault sequences.
    pub fn fault_states(&self, plan: FaultPlan) -> Vec<Vec<FaultState>> {
        let mut idx = 0u64;
        self.stages
            .iter()
            .map(|s| {
                s.stage
                    .macros()
                    .iter()
                    .map(|_| {
                        idx += 1;
                        FaultState::new(plan, idx)
                    })
                    .collect()
            })
            .collect()
    }

    /// Apply deploy-time faults (stuck cells, die-to-die variation) to
    /// every shard. Returns the total number of stuck cells pinned.
    pub fn deploy_faults(&mut self, states: &mut [Vec<FaultState>]) -> u64 {
        let mut stuck = 0u64;
        for (s, row) in self.stages.iter_mut().zip(states.iter_mut()) {
            for (m, fs) in s.stage.macros_mut().iter_mut().zip(row.iter_mut()) {
                stuck += fs.deploy(&mut m.xbar) as u64;
            }
        }
        stuck
    }

    /// Advance the simulated clock by `dt_ns` on every shard: retention
    /// flips land in place. Returns the total cells changed.
    pub fn drift(&mut self, states: &mut [Vec<FaultState>], dt_ns: f64) -> u64 {
        let mut flips = 0u64;
        for (s, row) in self.stages.iter_mut().zip(states.iter_mut()) {
            for (m, fs) in s.stage.macros_mut().iter_mut().zip(row.iter_mut()) {
                flips += fs.advance(&mut m.xbar, dt_ns) as u64;
            }
        }
        flips
    }

    /// Verify-and-rewrite every shard against the golden snapshot,
    /// charging SOT write energy and wear. Because drift moves states
    /// and never R_P, a completed scrub of a drift-only plan restores
    /// the deployment bit-for-bit (asserted in
    /// `rust/tests/reliability_diff.rs`).
    pub fn scrub(
        &mut self,
        states: &mut [Vec<FaultState>],
        golden: &[Vec<Vec<u8>>],
        wp: &SotWriteParams,
    ) -> ScrubOutcome {
        let mut out = ScrubOutcome::default();
        for ((s, row), gold) in
            self.stages.iter_mut().zip(states.iter_mut()).zip(golden)
        {
            for ((m, fs), g) in s
                .stage
                .macros_mut()
                .iter_mut()
                .zip(row.iter_mut())
                .zip(gold)
            {
                out.absorb(&fs.scrub(&mut m.xbar, g, wp));
            }
        }
        out
    }

    /// Online recalibration (DESIGN.md S19): stream `frame_sets`
    /// through the deployed — possibly drifted — fabric under the
    /// *current* thresholds, record every hidden stage's per-step
    /// drive, then jointly reset each hidden λ (its LIF threshold and
    /// the downstream stage's per-spike unit) to the `theta_pct`
    /// percentile of what the arrays actually produce, exactly as
    /// `from_float` did against float activations at deploy time.
    /// Weights and codes are untouched; membranes are reset. Returns
    /// the new per-hidden-stage λ values.
    pub fn recalibrate(
        &mut self,
        frame_sets: &[Vec<Vec<u32>>],
        theta_pct: f64,
    ) -> Vec<f64> {
        let ns = self.stages.len();
        let mut drives: Vec<Vec<f32>> = vec![Vec::new(); ns - 1];
        for frames in frame_sets {
            self.reset();
            for f in frames {
                let mut cur: Vec<u32> = Vec::new();
                for (s, stage) in self.stages.iter_mut().enumerate() {
                    let input: &[u32] = if s == 0 { f } else { &cur };
                    let (next, _r) = stage.step(input);
                    if s < ns - 1 {
                        drives[s].extend(stage.cur.iter().map(|&v| v as f32));
                    }
                    cur = next;
                }
            }
        }
        let lambdas: Vec<f64> = drives
            .iter()
            .map(|d| ActQuant::calibrate(d, theta_pct).a_max() as f64)
            .collect();
        for (l, &lam) in lambdas.iter().enumerate() {
            self.stages[l].lif.v_th = lam;
            self.stages[l + 1].in_unit = lam;
        }
        self.reset();
        lambdas
    }
}

/// Shared test fixture (also used by `stream::exec` tests): an
/// untrained model deployed on a 2×2 mesh — bit-identity proofs need
/// determinism, not accuracy.
#[cfg(test)]
pub(crate) fn tiny_mlp(seed: u64) -> (SpikingMlp, Dataset) {
    let calib = Dataset::generate(32, seed);
    let model = Mlp::new(seed ^ 0x5);
    let mlp = SpikingMlp::from_float(
        &model,
        &calib,
        &MacroConfig::default(),
        FabricConfig::square(2),
        LevelMap::DeviceTrue,
        &StreamConfig::default(),
    )
    .unwrap();
    (mlp, calib)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::encode::{FrameEncoder, TemporalCode};

    #[test]
    fn stream_run_shapes_and_counters() {
        let (mut mlp, data) = tiny_mlp(11);
        assert_eq!(mlp.in_dim(), 256);
        assert_eq!(mlp.out_dim(), 16);
        let enc = FrameEncoder::new(TemporalCode::Rate, 4, 255);
        let frames = enc.encode_frames(&data.features_u8(0));
        let run = mlp.run(&frames);
        assert!(run.label < 10);
        assert_eq!(run.out_v.len(), 16);
        assert_eq!(run.trains.len(), 3);
        assert!(run.trains.iter().all(|t| t.len() == 4));
        assert!(run.trains[2].iter().all(|f| f.is_empty()), "readout");
        let s = &run.stats;
        assert_eq!(s.timesteps, 4);
        // Shards: 2 + 1 + 1, each offering 128 rows per step.
        assert_eq!(s.row_slots, 4 * (2 + 1 + 1) * 128);
        assert!(s.active_rows > 0 && s.active_rows <= s.row_slots);
        assert!(s.occupancy() > 0.0 && s.occupancy() <= 1.0);
        assert_eq!(s.macs, 4 * (256 * 128 + 128 * 128 + 128 * 16) as u64);
        assert!(s.energy.total_fj() > 0.0);
        assert!(s.noc_packets > 0, "multi-tile layer 0 must route");
        assert_eq!(s.in_spikes, frames.iter().map(|f| f.len() as u64).sum());
        assert_eq!(s.layer_spikes.len(), 3);
        assert_eq!(s.layer_spikes[2], 0, "readout never fires");
    }

    #[test]
    fn membranes_accumulate_evidence_and_reset_clears_them() {
        let (mut mlp, data) = tiny_mlp(13);
        let enc = FrameEncoder::new(TemporalCode::Rate, 8, 255);
        let frames = enc.encode_frames(&data.features_u8(1));
        let a = mlp.run(&frames);
        let b = mlp.run(&frames);
        // run() resets: identical streams give identical outcomes.
        assert_eq!(a.out_v, b.out_v);
        assert_eq!(a.label, b.label);
        assert_eq!(a.trains, b.trains);
        assert_eq!(a.stats.energy, b.stats.energy);
        mlp.reset();
        assert!(mlp.out_membranes().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn more_timesteps_accumulate_more_energy_and_spikes() {
        let (mut mlp, data) = tiny_mlp(17);
        let x = data.features_u8(2);
        let mut prev_energy = 0.0f64;
        let mut prev_spikes = 0u64;
        for t in [1usize, 4, 16] {
            let enc = FrameEncoder::new(TemporalCode::Rate, t, 255);
            let run = mlp.run(&enc.encode_frames(&x));
            let e = run.stats.energy.total_fj();
            assert!(e >= prev_energy, "T={t}: {e} < {prev_energy}");
            assert!(run.stats.spikes_total() >= prev_spikes);
            prev_energy = e;
            prev_spikes = run.stats.spikes_total();
        }
    }

    #[test]
    fn swapped_session_state_matches_uninterrupted_run() {
        // The server path: membranes swapped out between every frame
        // must land exactly where the uninterrupted serial run does.
        let (mut mlp, data) = tiny_mlp(19);
        let enc = FrameEncoder::new(TemporalCode::Rate, 6, 255);
        let frames = enc.encode_frames(&data.features_u8(3));
        let want = mlp.run(&frames);

        let mut session = mlp.fresh_state();
        // Dirty the resident membranes to prove isolation.
        let noise = enc.encode_frames(&data.features_u8(4));
        mlp.reset();
        mlp.step_frame(&noise[0]);
        for f in &frames {
            mlp.swap_state(&mut session);
            mlp.step_frame(f);
            mlp.swap_state(&mut session);
        }
        assert_eq!(session.last().unwrap(), &want.out_v);
    }

    #[test]
    fn fault_states_cover_every_shard_and_snapshot_matches() {
        use crate::device::faults::FaultPlan;
        let (mlp, _) = tiny_mlp(29);
        let golden = mlp.snapshot_codes();
        // 2 + 1 + 1 shard macros on the 2×2 mesh.
        assert_eq!(golden.iter().map(|s| s.len()).sum::<usize>(), 4);
        assert!(golden
            .iter()
            .flatten()
            .all(|codes| codes.len() == 128 * 128));
        let states = mlp.fault_states(FaultPlan::none(1));
        assert_eq!(
            states.iter().map(|s| s.len()).collect::<Vec<_>>(),
            golden.iter().map(|s| s.len()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn drift_scrub_roundtrip_restores_the_run_bitwise() {
        use crate::device::faults::FaultPlan;
        use crate::device::{RetentionParams, SotWriteParams};
        let (mut mlp, data) = tiny_mlp(31);
        let enc = FrameEncoder::new(TemporalCode::Rate, 4, 255);
        let frames = enc.encode_frames(&data.features_u8(0));
        let golden = mlp.snapshot_codes();
        let want = mlp.run(&frames);

        let plan = FaultPlan::drift_only(RetentionParams::stress(), 37);
        let mut states = mlp.fault_states(plan);
        let flips = mlp.drift(&mut states, plan.retention.tau_ret_ns());
        assert!(flips > 0, "stress drift at t=τ must flip cells");

        let out =
            mlp.scrub(&mut states, &golden, &SotWriteParams::default());
        assert_eq!(out.checked, 4 * 128 * 128);
        assert_eq!(out.mismatched, flips as usize);
        assert_eq!(out.repaired, flips as usize);
        assert!(out.energy_fj > 0.0);
        assert_eq!(mlp.snapshot_codes(), golden);
        let got = mlp.run(&frames);
        assert_eq!(got.out_v, want.out_v, "scrubbed run must match pristine");
        assert_eq!(got.trains, want.trains);
        assert_eq!(got.stats.energy, want.stats.energy);
    }

    #[test]
    fn recalibration_is_deterministic_and_resets_thresholds() {
        use crate::device::faults::FaultPlan;
        use crate::device::RetentionParams;
        let mk = || tiny_mlp(41).0;
        let (_, data) = tiny_mlp(41);
        let enc = FrameEncoder::new(TemporalCode::Rate, 4, 255);
        let frame_sets: Vec<Vec<Vec<u32>>> = (0..4)
            .map(|i| enc.encode_frames(&data.features_u8(i)))
            .collect();

        let plan = FaultPlan::drift_only(RetentionParams::stress(), 43);
        let drift = |mlp: &mut SpikingMlp| {
            let mut st = mlp.fault_states(plan);
            mlp.drift(&mut st, plan.retention.tau_ret_ns())
        };
        let (mut a, mut b) = (mk(), mk());
        assert_eq!(drift(&mut a), drift(&mut b), "same plan, same flips");
        let la = a.recalibrate(&frame_sets, 99.7);
        let lb = b.recalibrate(&frame_sets, 99.7);
        assert_eq!(la, lb, "recalibration must be deterministic");
        assert_eq!(la.len(), 2);
        assert!(la.iter().all(|&l| l.is_finite() && l > 0.0));
        // The new λ lands in both the stage threshold and the
        // downstream per-spike unit.
        assert_eq!(a.stages[0].lif.v_th, la[0]);
        assert_eq!(a.stages[1].in_unit, la[0]);
        assert_eq!(a.stages[1].lif.v_th, la[1]);
        assert_eq!(a.stages[2].in_unit, la[1]);
        // And the recalibrated models still agree bitwise on a run.
        let ra = a.run(&frame_sets[0]);
        let rb = b.run(&frame_sets[0]);
        assert_eq!(ra.out_v, rb.out_v);
        assert!(ra.label < 10);
    }

    #[test]
    fn leak_changes_the_dynamics() {
        let calib = Dataset::generate(32, 23);
        let model = Mlp::new(24);
        let mk = |leak: f64| {
            SpikingMlp::from_float(
                &model,
                &calib,
                &MacroConfig::default(),
                FabricConfig::square(2),
                LevelMap::DeviceTrue,
                &StreamConfig {
                    leak,
                    ..StreamConfig::default()
                },
            )
            .unwrap()
        };
        let enc = FrameEncoder::new(TemporalCode::Rate, 8, 255);
        let frames = enc.encode_frames(&calib.features_u8(0));
        let mut if_net = mk(0.0);
        let mut lif_net = mk(0.3);
        // The leak is plumbed into every hidden stage's membrane.
        assert_eq!(if_net.stages[0].lif.leak, 0.0);
        assert_eq!(lif_net.stages[0].lif.leak, 0.3);
        assert_eq!(lif_net.stages[1].lif.leak, 0.3);
        assert_eq!(lif_net.stages[2].lif.leak, 0.0, "readout integrates");
        let if_run = if_net.run(&frames);
        let lif_run = lif_net.run(&frames);
        assert!(if_run.label < 10 && lif_run.label < 10);
        assert_eq!(if_run.stats.timesteps, lif_run.stats.timesteps);
    }
}

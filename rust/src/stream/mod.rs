//! Temporal streaming SNN runtime (DESIGN.md S18): time-stepped LIF
//! inference over event streams, end-to-end on the event-list engine.
//!
//! The macro is event-driven *in space* (silent rows cost nothing —
//! S17); this subsystem makes it event-driven *in time*: inputs arrive
//! as T binary frames (DVS-style [`PoissonStream`] traffic, or a static
//! input unrolled by [`FrameEncoder`] through the §II-B rate/TTFS
//! codecs), each frame is an active-row event list fed straight into
//! `CimMacro::mvm_events` — binary spikes skip window computation
//! entirely — and per-stage LIF membranes ([`baselines::DiscreteLif`])
//! carry the state between timesteps.
//!
//! Pieces:
//! * [`source`] — event-stream sources (Poisson/DVS, encoded-static);
//! * [`encode`] — static → T-frame re-encoding + accumulated decode;
//! * [`snn`] — [`SpikingMlp`]: the quantized digit MLP as a temporal
//!   network on a fabric chip, serial reference loop;
//! * [`exec`] — the pipelined executor on `util::pool` (bitwise equal
//!   to serial);
//! * [`serve`] — [`StreamServer`]: per-session membrane state behind
//!   the serving metrics.
//!
//! The sweep lives in `repro::stream` (`spikemram stream`), the perf
//! rows in `benches/stream.rs`, and the cross-level bit-identity proofs
//! in `rust/tests/stream_e2e.rs`.
//!
//! [`baselines::DiscreteLif`]: crate::baselines::DiscreteLif

pub mod encode;
pub mod exec;
pub mod serve;
pub mod snn;
pub mod source;

pub use encode::{FrameEncoder, TemporalCode};
pub use serve::{
    DrainReport, FrameOutcome, MissionConfig, MissionMode, StreamReply,
    StreamServer, StreamServerConfig, StreamSpec,
};
pub use snn::{FrameStep, SpikingMlp, StreamRun, StreamStats};
pub use source::{collect_frames, EncodedStream, EventStream, PoissonStream};

//! Pipelined streaming executor (DESIGN.md S18): timestep t at stage l
//! overlaps timestep t−1 at stage l+1, with every stage's LIF membranes
//! resident on that stage — the temporal analogue of the fabric
//! dataflow executor, built on the same persistent worker pool.
//!
//! Shape: one `scope_map` job per stage. Stage 0 walks the caller's
//! frame slice directly; stage l > 0 drains an mpsc channel fed by
//! stage l−1 and stops when the upstream sender drops. Jobs therefore
//! *block* on their inboxes (unlike the fabric executor's non-blocking
//! stage turns) — safe here because:
//!
//! * stage 0's input is fully materialized before the run starts, so
//!   the most-upstream unfinished stage can always make progress;
//! * `scope_map` claims jobs in index = stage order, so the claimed
//!   set is always a prefix that contains that stage; and
//! * the caller claims jobs too, so even a single-worker pool (or a
//!   pool saturated by other scopes) drives the chain to completion.
//!   Nested fan-outs inside a stage (the shard `mvm_events_parallel`)
//!   are caller-claiming for the same reason.
//!
//! Bit-identity: each stage processes timesteps in channel FIFO = time
//! order against its own membranes, folds its tally in the same
//! per-stage order as the serial loop, and the final fold is the shared
//! `SpikingMlp::assemble_run` — so membranes, spike trains, and every
//! energy tally come out *bitwise* equal to [`SpikingMlp::run`]
//! (asserted here and in `rust/tests/stream_e2e.rs`). The pipelining
//! buys wall-clock only.

use std::sync::mpsc;

use crate::util::pool;

use super::snn::{SpikingMlp, SpikingStage, StageTally, StreamRun};

/// Where a stage's frames come from.
enum Feed<'a> {
    /// Stage 0: the caller's frame stream.
    Source(&'a [Vec<u32>]),
    /// Stage l > 0: the upstream stage's output events.
    Upstream(mpsc::Receiver<Vec<u32>>),
}

/// One stage job: the stage (with resident membranes), its feed, and
/// the downstream sender (`None` for the readout stage).
struct StageJob<'a> {
    stage: &'a mut SpikingStage,
    feed: Feed<'a>,
    down: Option<mpsc::Sender<Vec<u32>>>,
}

/// Run one stage to completion: step every inbound frame in order,
/// forward the emitted events downstream, tally locally.
fn stage_task(job: StageJob<'_>) -> (Vec<Vec<u32>>, StageTally) {
    let StageJob { stage, feed, down } = job;
    let mut tally = StageTally::default();
    let mut trains: Vec<Vec<u32>> = Vec::new();
    let mut handle = |stage: &mut SpikingStage, events: &[u32]| {
        let (next, r) = stage.step(events);
        stage.tally_into(&mut tally, &r, &next);
        if let Some(tx) = &down {
            // A dropped downstream only happens on a sibling panic,
            // which scope_map re-raises on the caller anyway.
            let _ = tx.send(next.clone());
        }
        trains.push(next);
    };
    match feed {
        Feed::Source(frames) => {
            for f in frames {
                handle(stage, f);
            }
        }
        Feed::Upstream(rx) => {
            while let Ok(events) = rx.recv() {
                handle(stage, &events);
            }
        }
    }
    // `handle`'s borrows end here; `down` drops with the job, closing
    // the downstream inbox.
    (trains, tally)
}

impl SpikingMlp {
    /// [`run`](Self::run), pipelined across the worker pool: distinct
    /// stages overlap on distinct workers while each stage's membranes
    /// stay resident with it. Bitwise identical to the serial loop —
    /// membranes, spike trains, tallies (see module docs).
    pub fn run_pipelined(&mut self, frames: &[Vec<u32>]) -> StreamRun {
        self.reset();
        let ns = self.stages.len();
        let in_spikes: u64 = frames.iter().map(|f| f.len() as u64).sum();

        let mut feeds: Vec<Feed> = Vec::with_capacity(ns);
        let mut downs: Vec<Option<mpsc::Sender<Vec<u32>>>> =
            Vec::with_capacity(ns);
        feeds.push(Feed::Source(frames));
        for _ in 1..ns {
            let (tx, rx) = mpsc::channel();
            downs.push(Some(tx));
            feeds.push(Feed::Upstream(rx));
        }
        downs.push(None);

        let jobs: Vec<StageJob> = self
            .stages
            .iter_mut()
            .zip(feeds.into_iter().zip(downs))
            .map(|(stage, (feed, down))| StageJob { stage, feed, down })
            .collect();
        let results = pool::scope_map(jobs, stage_task);

        let mut trains = Vec::with_capacity(ns);
        let mut tallies = Vec::with_capacity(ns);
        for (t, tally) in results {
            trains.push(t);
            tallies.push(tally);
        }
        self.assemble_run(frames.len(), in_spikes, tallies, trains)
    }
}

#[cfg(test)]
mod tests {
    use super::super::encode::{FrameEncoder, TemporalCode};
    use super::super::snn::tiny_mlp;
    use super::super::source::{collect_frames, PoissonStream};

    #[test]
    fn pipelined_run_bitwise_equals_serial() {
        let (mut mlp, data) = tiny_mlp(41);
        let enc = FrameEncoder::new(TemporalCode::Rate, 8, 255);
        for i in 0..3 {
            let frames = enc.encode_frames(&data.features_u8(i));
            let serial = mlp.run(&frames);
            let piped = mlp.run_pipelined(&frames);
            assert_eq!(piped.label, serial.label, "item {i}");
            assert_eq!(piped.out_v, serial.out_v, "membranes item {i}");
            assert_eq!(piped.trains, serial.trains, "spike trains item {i}");
            let (s, p) = (&serial.stats, &piped.stats);
            assert_eq!(p.energy, s.energy, "energy tallies item {i}");
            assert_eq!(p.latency_ns, s.latency_ns);
            assert_eq!(p.active_rows, s.active_rows);
            assert_eq!(p.row_slots, s.row_slots);
            assert_eq!(p.macs, s.macs);
            assert_eq!((p.noc_packets, p.noc_hops), (s.noc_packets, s.noc_hops));
            assert_eq!(p.in_spikes, s.in_spikes);
            assert_eq!(p.layer_spikes, s.layer_spikes);
        }
    }

    #[test]
    fn pipelined_handles_dvs_streams_and_empty_input() {
        let (mut mlp, _) = tiny_mlp(43);
        let mut src = PoissonStream::uniform(256, 12, 0.15, 44);
        let frames = collect_frames(&mut src);
        let serial = mlp.run(&frames);
        let piped = mlp.run_pipelined(&frames);
        assert_eq!(piped.out_v, serial.out_v);
        assert_eq!(piped.stats.energy, serial.stats.energy);

        // Zero timesteps: a clean no-op with zeroed membranes.
        let empty = mlp.run_pipelined(&[]);
        assert_eq!(empty.stats.timesteps, 0);
        assert!(empty.out_v.iter().all(|&v| v == 0.0));
        assert_eq!(empty.stats.energy.total_fj(), 0.0);
    }
}

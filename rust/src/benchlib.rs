//! Criterion-style benchmark harness (DESIGN.md S0; substrate — the
//! `criterion` crate is unavailable offline, so every `benches/*.rs`
//! target is declared with `harness = false` in Cargo.toml).
//!
//! Provides warmup, timed sampling, and robust summary statistics
//! (median / mean / p95, MAD-based spread) with the familiar
//! `bench_function(name, |b| b.iter(...))` shape, plus a results table
//! printer used by every `benches/*.rs` target (`harness = false`).

use std::hint::black_box as bb;
use std::time::{Duration, Instant};

pub use std::hint::black_box;

/// One benchmark's collected samples and derived stats.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples_ns: Vec<f64>,
    pub iters_per_sample: u64,
}

impl BenchResult {
    pub fn median_ns(&self) -> f64 {
        crate::util::stats::percentile(&self.samples_ns, 50.0)
    }

    pub fn mean_ns(&self) -> f64 {
        crate::util::stats::mean(&self.samples_ns)
    }

    pub fn p95_ns(&self) -> f64 {
        crate::util::stats::percentile(&self.samples_ns, 95.0)
    }

    /// Median absolute deviation (robust spread), ns.
    pub fn mad_ns(&self) -> f64 {
        let med = self.median_ns();
        let devs: Vec<f64> =
            self.samples_ns.iter().map(|&x| (x - med).abs()).collect();
        crate::util::stats::percentile(&devs, 50.0)
    }

    pub fn summary_line(&self) -> String {
        format!(
            "{:<44} {:>12} {:>12} {:>12} {:>8}",
            self.name,
            fmt_ns(self.median_ns()),
            fmt_ns(self.mean_ns()),
            fmt_ns(self.p95_ns()),
            format!("±{:.1}%", 100.0 * self.mad_ns() / self.median_ns().max(1e-12)),
        )
    }
}

/// Human-friendly duration.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Per-iteration timer handle passed to the closure.
pub struct Bencher {
    target_sample: Duration,
    result_ns: Vec<f64>,
    iters_per_sample: u64,
    samples: usize,
}

impl Bencher {
    /// Time `f`, auto-scaling iterations so each sample lasts about
    /// `target_sample`.
    pub fn iter<T, F: FnMut() -> T>(&mut self, mut f: F) {
        // Calibrate: how many iters fit the target sample time?
        let mut iters: u64 = 1;
        loop {
            let t0 = Instant::now();
            for _ in 0..iters {
                bb(f());
            }
            let dt = t0.elapsed();
            if dt >= self.target_sample / 4 || iters >= 1 << 24 {
                let scale = (self.target_sample.as_secs_f64()
                    / dt.as_secs_f64().max(1e-9))
                .clamp(0.25, 1024.0);
                iters = ((iters as f64 * scale) as u64).max(1);
                break;
            }
            iters *= 4;
        }
        // Warmup once at full count, then sample.
        let t0 = Instant::now();
        for _ in 0..iters {
            bb(f());
        }
        bb(t0.elapsed());
        self.result_ns.clear();
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                bb(f());
            }
            self.result_ns
                .push(t0.elapsed().as_secs_f64() * 1e9 / iters as f64);
        }
        self.iters_per_sample = iters;
    }
}

/// A named group of benchmarks printing a results table.
pub struct Harness {
    pub group: String,
    results: Vec<BenchResult>,
    samples: usize,
    target_sample: Duration,
}

impl Harness {
    pub fn new(group: &str) -> Harness {
        // Honor a quick mode for CI: SPIKEMRAM_BENCH_FAST=1.
        let fast = std::env::var("SPIKEMRAM_BENCH_FAST").is_ok();
        println!("\n=== bench group: {group} ===");
        println!(
            "{:<44} {:>12} {:>12} {:>12} {:>8}",
            "benchmark", "median", "mean", "p95", "spread"
        );
        Harness {
            group: group.to_string(),
            results: Vec::new(),
            samples: if fast { 5 } else { 15 },
            target_sample: if fast {
                Duration::from_millis(20)
            } else {
                Duration::from_millis(80)
            },
        }
    }

    /// Run one benchmark and print its row. Returns a copy of the result
    /// so callers can keep using the harness (`note`, more benches).
    pub fn bench_function<F: FnMut(&mut Bencher)>(
        &mut self,
        name: &str,
        mut f: F,
    ) -> BenchResult {
        let mut b = Bencher {
            target_sample: self.target_sample,
            result_ns: Vec::new(),
            iters_per_sample: 0,
            samples: self.samples,
        };
        f(&mut b);
        let r = BenchResult {
            name: name.to_string(),
            samples_ns: b.result_ns,
            iters_per_sample: b.iters_per_sample,
        };
        println!("{}", r.summary_line());
        self.results.push(r.clone());
        r
    }

    /// Print a throughput line derived from the last result.
    pub fn note(&self, text: &str) {
        println!("    ↳ {text}");
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bencher_measures_something() {
        std::env::set_var("SPIKEMRAM_BENCH_FAST", "1");
        let mut h = Harness::new("selftest");
        let r = h.bench_function("sum_1k", |b| {
            b.iter(|| (0..1000u64).sum::<u64>())
        });
        assert!(r.median_ns() > 0.0);
        assert!(r.iters_per_sample >= 1);
        assert_eq!(r.samples_ns.len(), 5);
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(5.0).ends_with("ns"));
        assert!(fmt_ns(5.0e3).ends_with("µs"));
        assert!(fmt_ns(5.0e6).ends_with("ms"));
        assert!(fmt_ns(5.0e9).ends_with("s"));
    }

    #[test]
    fn slower_code_measures_slower() {
        std::env::set_var("SPIKEMRAM_BENCH_FAST", "1");
        let mut h = Harness::new("selftest2");
        let fast = h
            .bench_function("fast", |b| b.iter(|| (0..100u64).sum::<u64>()))
            .median_ns();
        let slow = h
            .bench_function("slow", |b| b.iter(|| (0..100_000u64).sum::<u64>()))
            .median_ns();
        assert!(slow > 10.0 * fast, "slow {slow} vs fast {fast}");
    }
}

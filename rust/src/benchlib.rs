//! Criterion-style benchmark harness (DESIGN.md S0; substrate — the
//! `criterion` crate is unavailable offline, so every `benches/*.rs`
//! target is declared with `harness = false` in Cargo.toml).
//!
//! Provides warmup, timed sampling, and robust summary statistics
//! (median / mean / p95, MAD-based spread) with the familiar
//! `bench_function(name, |b| b.iter(...))` shape, plus a results table
//! printer used by every `benches/*.rs` target (`harness = false`).

use std::hint::black_box as bb;
use std::time::{Duration, Instant};

pub use std::hint::black_box;

/// One benchmark's collected samples and derived stats.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples_ns: Vec<f64>,
    pub iters_per_sample: u64,
    /// Logical operations per iteration (batch size for batched benches);
    /// `per_op_median_ns` divides by this so batched and serial rows
    /// compare per-op directly.
    pub ops_per_iter: u64,
}

impl BenchResult {
    pub fn median_ns(&self) -> f64 {
        crate::util::stats::percentile(&self.samples_ns, 50.0)
    }

    /// Median per logical op (= `median_ns` for unbatched benches).
    pub fn per_op_median_ns(&self) -> f64 {
        self.median_ns() / self.ops_per_iter.max(1) as f64
    }

    pub fn mean_ns(&self) -> f64 {
        crate::util::stats::mean(&self.samples_ns)
    }

    pub fn p95_ns(&self) -> f64 {
        crate::util::stats::percentile(&self.samples_ns, 95.0)
    }

    /// Median absolute deviation (robust spread), ns.
    pub fn mad_ns(&self) -> f64 {
        let med = self.median_ns();
        let devs: Vec<f64> =
            self.samples_ns.iter().map(|&x| (x - med).abs()).collect();
        crate::util::stats::percentile(&devs, 50.0)
    }

    pub fn summary_line(&self) -> String {
        format!(
            "{:<44} {:>12} {:>12} {:>12} {:>8}",
            self.name,
            fmt_ns(self.median_ns()),
            fmt_ns(self.mean_ns()),
            fmt_ns(self.p95_ns()),
            format!("±{:.1}%", 100.0 * self.mad_ns() / self.median_ns().max(1e-12)),
        )
    }
}

/// Human-friendly duration.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Per-iteration timer handle passed to the closure.
pub struct Bencher {
    target_sample: Duration,
    result_ns: Vec<f64>,
    iters_per_sample: u64,
    samples: usize,
}

impl Bencher {
    /// Time `f`, auto-scaling iterations so each sample lasts about
    /// `target_sample`.
    pub fn iter<T, F: FnMut() -> T>(&mut self, mut f: F) {
        // Calibrate: how many iters fit the target sample time?
        let mut iters: u64 = 1;
        loop {
            let t0 = Instant::now();
            for _ in 0..iters {
                bb(f());
            }
            let dt = t0.elapsed();
            if dt >= self.target_sample / 4 || iters >= 1 << 24 {
                let scale = (self.target_sample.as_secs_f64()
                    / dt.as_secs_f64().max(1e-9))
                .clamp(0.25, 1024.0);
                iters = ((iters as f64 * scale) as u64).max(1);
                break;
            }
            iters *= 4;
        }
        // Warmup once at full count, then sample.
        let t0 = Instant::now();
        for _ in 0..iters {
            bb(f());
        }
        bb(t0.elapsed());
        self.result_ns.clear();
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                bb(f());
            }
            self.result_ns
                .push(t0.elapsed().as_secs_f64() * 1e9 / iters as f64);
        }
        self.iters_per_sample = iters;
    }
}

/// A named group of benchmarks printing a results table.
pub struct Harness {
    pub group: String,
    results: Vec<BenchResult>,
    samples: usize,
    target_sample: Duration,
}

impl Harness {
    pub fn new(group: &str) -> Harness {
        // Honor a quick mode for CI: SPIKEMRAM_BENCH_FAST=1.
        let fast = std::env::var("SPIKEMRAM_BENCH_FAST").is_ok();
        println!("\n=== bench group: {group} ===");
        println!(
            "{:<44} {:>12} {:>12} {:>12} {:>8}",
            "benchmark", "median", "mean", "p95", "spread"
        );
        Harness {
            group: group.to_string(),
            results: Vec::new(),
            samples: if fast { 5 } else { 15 },
            target_sample: if fast {
                Duration::from_millis(20)
            } else {
                Duration::from_millis(80)
            },
        }
    }

    /// Run one benchmark and print its row. Returns a copy of the result
    /// so callers can keep using the harness (`note`, more benches).
    pub fn bench_function<F: FnMut(&mut Bencher)>(
        &mut self,
        name: &str,
        f: F,
    ) -> BenchResult {
        self.bench_function_n(name, 1, f)
    }

    /// [`bench_function`](Self::bench_function) for a closure doing
    /// `ops` logical operations per iteration (e.g. one B-item
    /// `mvm_batch` call): the JSON record carries a per-op median.
    pub fn bench_function_n<F: FnMut(&mut Bencher)>(
        &mut self,
        name: &str,
        ops: u64,
        mut f: F,
    ) -> BenchResult {
        let mut b = Bencher {
            target_sample: self.target_sample,
            result_ns: Vec::new(),
            iters_per_sample: 0,
            samples: self.samples,
        };
        f(&mut b);
        let r = BenchResult {
            name: name.to_string(),
            samples_ns: b.result_ns,
            iters_per_sample: b.iters_per_sample,
            ops_per_iter: ops.max(1),
        };
        println!("{}", r.summary_line());
        if r.ops_per_iter > 1 {
            println!(
                "    ↳ {} per op ({} ops/iter)",
                fmt_ns(r.per_op_median_ns()),
                r.ops_per_iter
            );
        }
        self.results.push(r.clone());
        r
    }

    /// Print a throughput line derived from the last result.
    pub fn note(&self, text: &str) {
        println!("    ↳ {text}");
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Write the group's results as machine-readable
    /// `BENCH_<group>.json` (into `SPIKEMRAM_BENCH_DIR`, default the
    /// working directory) so the perf trajectory is tracked across PRs.
    /// Returns the path written.
    pub fn finish(&self) -> std::path::PathBuf {
        let dir = std::env::var("SPIKEMRAM_BENCH_DIR")
            .unwrap_or_else(|_| ".".to_string());
        self.finish_to(std::path::Path::new(&dir))
    }

    /// [`finish`](Self::finish) into an explicit directory (tests use
    /// this to avoid mutating process-global env vars).
    pub fn finish_to(&self, dir: &std::path::Path) -> std::path::PathBuf {
        use crate::util::json::{self, Json};
        let benches: std::collections::BTreeMap<String, Json> = self
            .results
            .iter()
            .map(|r| {
                (
                    r.name.clone(),
                    json::obj(vec![
                        ("median_ns", Json::Num(r.median_ns())),
                        ("mean_ns", Json::Num(r.mean_ns())),
                        ("p95_ns", Json::Num(r.p95_ns())),
                        ("mad_ns", Json::Num(r.mad_ns())),
                        (
                            "ops_per_iter",
                            Json::Num(r.ops_per_iter as f64),
                        ),
                        (
                            "per_op_median_ns",
                            Json::Num(r.per_op_median_ns()),
                        ),
                        (
                            "iters_per_sample",
                            Json::Num(r.iters_per_sample as f64),
                        ),
                    ]),
                )
            })
            .collect();
        let doc = json::obj(vec![
            ("group", Json::Str(self.group.clone())),
            (
                "profile",
                Json::Str(
                    if cfg!(debug_assertions) { "debug" } else { "release" }
                        .to_string(),
                ),
            ),
            (
                "fast_mode",
                Json::Bool(std::env::var("SPIKEMRAM_BENCH_FAST").is_ok()),
            ),
            ("samples_per_bench", Json::Num(self.samples as f64)),
            ("benches", Json::Obj(benches)),
        ]);
        let path = dir.join(format!("BENCH_{}.json", self.group));
        std::fs::write(&path, doc.to_pretty())
            .unwrap_or_else(|e| panic!("write {}: {e}", path.display()));
        println!("wrote {}", path.display());
        path
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bencher_measures_something() {
        std::env::set_var("SPIKEMRAM_BENCH_FAST", "1");
        let mut h = Harness::new("selftest");
        let r = h.bench_function("sum_1k", |b| {
            b.iter(|| (0..1000u64).sum::<u64>())
        });
        assert!(r.median_ns() > 0.0);
        assert!(r.iters_per_sample >= 1);
        assert_eq!(r.samples_ns.len(), 5);
    }

    #[test]
    fn finish_writes_machine_readable_json() {
        std::env::set_var("SPIKEMRAM_BENCH_FAST", "1");
        let dir = std::env::temp_dir().join("spikemram_bench_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut h = Harness::new("selftest_json");
        h.bench_function("sum_1k", |b| b.iter(|| (0..1000u64).sum::<u64>()));
        h.bench_function_n("sum_1k_x8", 8, |b| {
            b.iter(|| (0..8).map(|_| (0..1000u64).sum::<u64>()).sum::<u64>())
        });
        let path = h.finish_to(&dir);
        let doc = crate::util::json::parse(
            &std::fs::read_to_string(&path).unwrap(),
        )
        .unwrap();
        assert_eq!(doc.get("group").unwrap().as_str(), Some("selftest_json"));
        assert!(doc.get("profile").unwrap().as_str().is_some());
        let b8 = doc.get("benches").unwrap().get("sum_1k_x8").unwrap();
        assert_eq!(b8.get("ops_per_iter").unwrap().as_f64(), Some(8.0));
        let per_op = b8.get("per_op_median_ns").unwrap().as_f64().unwrap();
        let med = b8.get("median_ns").unwrap().as_f64().unwrap();
        assert!(per_op > 0.0 && (per_op - med / 8.0).abs() < 1e-9);
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(5.0).ends_with("ns"));
        assert!(fmt_ns(5.0e3).ends_with("µs"));
        assert!(fmt_ns(5.0e6).ends_with("ms"));
        assert!(fmt_ns(5.0e9).ends_with("s"));
    }

    #[test]
    fn slower_code_measures_slower() {
        std::env::set_var("SPIKEMRAM_BENCH_FAST", "1");
        let mut h = Harness::new("selftest2");
        let fast = h
            .bench_function("fast", |b| b.iter(|| (0..100u64).sum::<u64>()))
            .median_ns();
        let slow = h
            .bench_function("slow", |b| b.iter(|| (0..100_000u64).sum::<u64>()))
            .median_ns();
        assert!(slow > 10.0 * fast, "slow {slow} vs fast {fast}");
    }
}

//! Consolidated ablation runner (DESIGN.md §7): every design-choice knob
//! the paper's architecture embeds, measured on the same stimulus —
//! level map, clamp+current-mirror, bit-serial decomposition, scrub
//! policy under weak retention, and process corners. Each row reports
//! accuracy-of-MAC, energy, and latency deltas against the baseline
//! configuration, saved to `results/ablations.csv`.

use crate::circuit::montecarlo::{run_corner, Corner};
use crate::coding::BitSerialPlan;
use crate::config::{LevelMap, MacroConfig, NonIdeality};
use crate::device::retention::{corrupt_codes, RetentionParams};
use crate::macro_model::CimMacro;
use crate::util::rng::Rng;

use super::report::{self, Table};

/// One ablation row.
#[derive(Debug, Clone)]
pub struct AblationRow {
    pub name: String,
    /// Mean relative MAC error vs the digital oracle.
    pub mac_rel_err: f64,
    /// Energy per MVM (pJ).
    pub energy_pj: f64,
    /// Latency per MVM (ns).
    pub latency_ns: f64,
}

fn measure(
    cfg: &MacroConfig,
    seed: u64,
    mvms: usize,
    bitserial: Option<BitSerialPlan>,
    idle_before_ns: f64,
    retention: Option<RetentionParams>,
) -> AblationRow {
    let mut m = if cfg.nonideal.sigma_r_d2d > 0.0 {
        CimMacro::with_nonidealities(cfg.clone(), seed)
    } else {
        CimMacro::new(cfg.clone())
    };
    let mut rng = Rng::new(seed ^ 0xab1a);
    let mut codes: Vec<u8> = (0..cfg.rows * cfg.cols)
        .map(|_| rng.below(4) as u8)
        .collect();
    let golden = codes.clone();
    if let (Some(ret), true) = (retention, idle_before_ns > 0.0) {
        corrupt_codes(&mut codes, idle_before_ns, &ret, &mut rng);
    }
    m.program(&codes);

    // The oracle uses the *intended* (golden) weights — retention errors
    // therefore show up as MAC error, as they would in deployment.
    let mut oracle = CimMacro::new(MacroConfig {
        nonideal: NonIdeality::ideal(),
        ..cfg.clone()
    });
    oracle.program(&golden);

    let mut err = 0.0;
    let mut energy = 0.0;
    let mut latency = 0.0;
    for _ in 0..mvms {
        let x: Vec<u32> = (0..cfg.rows).map(|_| rng.below(256) as u32).collect();
        let want = oracle.ideal_mvm(&x);
        let (y, r) = match bitserial {
            Some(plan) => m.mvm_bitserial(&x, plan),
            None => {
                let r = m.mvm(&x);
                (r.y_mac.clone(), r)
            }
        };
        energy += r.energy.total_pj();
        latency += r.latency_ns;
        for c in 0..cfg.cols {
            err += (y[c] - want[c]).abs() / want[c].max(1.0);
        }
    }
    let n = (mvms * cfg.cols) as f64;
    AblationRow {
        name: String::new(),
        mac_rel_err: err / n,
        energy_pj: energy / mvms as f64,
        latency_ns: latency / mvms as f64,
    }
}

/// Run the full ablation suite.
pub fn run(seed: u64, mvms: usize) -> Vec<AblationRow> {
    let base = MacroConfig::default();
    let mut rows = Vec::new();
    let mut push = |name: &str, mut r: AblationRow| {
        r.name = name.to_string();
        rows.push(r);
    };

    push("baseline (device-true, ideal)", measure(&base, seed, mvms, None, 0.0, None));
    push(
        "ideal-linear level map",
        measure(
            &MacroConfig {
                level_map: LevelMap::IdealLinear,
                ..base.clone()
            },
            seed,
            mvms,
            None,
            0.0,
            None,
        ),
    );
    push(
        "no clamp+current-mirror (Fig 7b)",
        measure(
            &MacroConfig {
                nonideal: NonIdeality {
                    clamp_current_mirror: false,
                    ..NonIdeality::ideal()
                },
                ..base.clone()
            },
            seed,
            mvms,
            None,
            0.0,
            None,
        ),
    );
    push(
        "realistic non-idealities",
        measure(
            &MacroConfig {
                nonideal: NonIdeality::realistic(),
                ..base.clone()
            },
            seed,
            mvms,
            None,
            0.0,
            None,
        ),
    );
    push(
        "bit-serial 2×4-bit",
        measure(&base, seed, mvms, Some(BitSerialPlan::new(8, 4)), 0.0, None),
    );
    push(
        "bit-serial 4×2-bit",
        measure(&base, seed, mvms, Some(BitSerialPlan::new(8, 2)), 0.0, None),
    );
    push(
        "weak retention, 1 day idle, no scrub",
        measure(
            &base,
            seed,
            mvms,
            None,
            8.64e13,
            Some(RetentionParams::weak()),
        ),
    );
    rows
}

pub fn render(rows: &[AblationRow]) -> String {
    let mut t = Table::new(
        "Ablations — design-choice knobs (uniform-random stimulus)",
        &["Configuration", "MAC rel. err", "pJ/MVM", "ns/MVM"],
    );
    for r in rows {
        t.row(&[
            r.name.clone(),
            format!("{:.3e}", r.mac_rel_err),
            format!("{:.1}", r.energy_pj),
            format!("{:.1}", r.latency_ns),
        ]);
    }
    t.render()
}

/// Process-corner MC summary table (E6 robustness companion).
pub fn render_corners(seed: u64) -> String {
    let base = MacroConfig::default();
    let mut t = Table::new(
        "Monte-Carlo corners (8 dies × 2 MVMs each)",
        &["Corner", "R² (mean)", "R² (p5)", "MAC err (mean±sd)", "pJ/MVM"],
    );
    for corner in [Corner::FF, Corner::TT, Corner::SS] {
        let s = run_corner(&base, corner, 8, 2, seed);
        t.row(&[
            format!("{corner:?}"),
            format!("{:.9}", s.r2_mean),
            format!("{:.9}", s.r2_p5),
            format!("{:.2e}±{:.1e}", s.mac_err_mean, s.mac_err_sd),
            format!("{:.1}", s.energy_pj_mean),
        ]);
    }
    t.render()
}

/// Run + save everything.
pub fn run_and_save(seed: u64, mvms: usize) -> String {
    let rows = run(seed, mvms);
    let mut out = render(&rows);
    out.push('\n');
    out.push_str(&render_corners(seed));
    let csv: String = std::iter::once(
        "name,mac_rel_err,energy_pj,latency_ns".to_string(),
    )
    .chain(rows.iter().map(|r| {
        format!(
            "{},{:.6e},{:.3},{:.3}",
            r.name.replace(',', ";"),
            r.mac_rel_err,
            r.energy_pj,
            r.latency_ns
        )
    }))
    .collect::<Vec<_>>()
    .join("\n");
    report::save("ablations.csv", &csv);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_is_exact_and_others_rank_sensibly() {
        let rows = run(4242, 2);
        let by = |name: &str| {
            rows.iter()
                .find(|r| r.name.starts_with(name))
                .unwrap_or_else(|| panic!("{name}"))
        };
        // Baseline: numerically exact.
        assert!(by("baseline").mac_rel_err < 1e-9);
        // Bit-serial stays exact under ideal circuits (linearity), and in
        // this energy model trades bias energy down for 2× control energy
        // (DESIGN.md §7 — error amplification appears once offsets are
        // enabled, tested in macro_model).
        assert!(by("bit-serial 2×4-bit").mac_rel_err < 1e-9);
        assert!(by("bit-serial 2×4-bit").energy_pj < by("baseline").energy_pj);
        // Droop mode is catastrophically wrong (the §IV-B argument).
        assert!(by("no clamp").mac_rel_err > 0.05);
        // Retention corruption hurts more than realistic analog noise.
        assert!(
            by("weak retention").mac_rel_err
                > by("realistic").mac_rel_err
        );
    }

    #[test]
    fn render_produces_tables() {
        std::env::set_var("SPIKEMRAM_RESULTS", "/tmp/spikemram_test_results");
        let s = run_and_save(11, 1);
        assert!(s.contains("Ablations"));
        assert!(s.contains("Monte-Carlo corners"));
        assert!(report::exists("ablations.csv"));
    }
}

//! Experiment E8 — Table II: comparison with other CIM designs.
//!
//! Competitors' numbers are quoted from their publications (that is what
//! the paper's table does too); *our* row is measured live from the energy
//! model via the Monte-Carlo Fig 6(a) run, so any recalibration of the
//! energy parameters flows into this table automatically.

use crate::config::MacroConfig;

use super::fig6;
use super::report::Table;

/// One comparison row.
#[derive(Debug, Clone)]
pub struct CompareRow {
    pub work: &'static str,
    pub memory: &'static str,
    pub node: &'static str,
    pub cell: &'static str,
    pub array: &'static str,
    pub readout: &'static str,
    /// Published efficiency (TOPS/W); None = ours (measured).
    pub tops_w: Option<f64>,
}

/// The published comparison set of Table II.
pub fn published_rows() -> Vec<CompareRow> {
    vec![
        CompareRow {
            work: "VLSI'19 [18]",
            memory: "ReRAM",
            node: "150nm",
            cell: "1T-1R",
            array: "256×256",
            readout: "CA+IFC (rate)",
            tops_w: Some(16.9),
        },
        CompareRow {
            work: "DAC'20 [14]",
            memory: "ReRAM",
            node: "65nm",
            cell: "1T-1R",
            array: "32×32",
            readout: "COG (single-spike)",
            tops_w: Some(40.8),
        },
        CompareRow {
            work: "TCAS-I'22 [24]",
            memory: "ReRAM",
            node: "65nm",
            cell: "1T-1J",
            array: "128×128",
            readout: "LIF",
            tops_w: Some(46.6),
        },
        CompareRow {
            work: "ESSCIRC'21 [13]",
            memory: "MRAM",
            node: "22nm",
            cell: "2T-2J",
            array: "128×128",
            readout: "ADC",
            tops_w: Some(5.1),
        },
        CompareRow {
            work: "DAC'24 [16]",
            memory: "MRAM",
            node: "28nm",
            cell: "6T-4J",
            array: "64×128",
            readout: "ADC",
            tops_w: Some(26.6), // midpoint of the published 23.7–29.4
        },
        CompareRow {
            work: "This Work",
            memory: "MRAM",
            node: "28nm",
            cell: "3T-2J",
            array: "128×128",
            readout: "OSG (event-driven)",
            tops_w: None,
        },
    ]
}

#[derive(Debug, Clone)]
pub struct Table2 {
    pub rows: Vec<(CompareRow, f64)>,
    pub ours_tops_w: f64,
}

pub fn run(cfg: &MacroConfig, mvms: usize, seed: u64) -> Table2 {
    let ours = fig6::run_fig6a(cfg, mvms, seed).tops_per_watt;
    let rows = published_rows()
        .into_iter()
        .map(|r| {
            let v = r.tops_w.unwrap_or(ours);
            (r, v)
        })
        .collect();
    Table2 {
        rows,
        ours_tops_w: ours,
    }
}

pub fn render(t2: &Table2) -> String {
    let mut t = Table::new(
        "Table II — comparison with other CIM designs",
        &[
            "Work", "Memory", "Node", "Cell", "Array", "Readout",
            "TOPS/W",
        ],
    );
    for (r, v) in &t2.rows {
        let eff = if r.tops_w.is_some() {
            format!("{v:.1} (published)")
        } else {
            format!("{v:.1} (measured; paper 243.6)")
        };
        t.row(&[
            r.work.into(),
            r.memory.into(),
            r.node.into(),
            r.cell.into(),
            r.array.into(),
            r.readout.into(),
            eff,
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ours_beats_every_published_baseline_by_5x() {
        let t2 = run(&MacroConfig::default(), 10, 81);
        for (r, v) in &t2.rows {
            if r.tops_w.is_some() {
                assert!(
                    t2.ours_tops_w > 5.0 * v,
                    "{}: {} vs ours {}",
                    r.work,
                    v,
                    t2.ours_tops_w
                );
            }
        }
    }

    #[test]
    fn ours_matches_papers_headline() {
        let t2 = run(&MacroConfig::default(), 10, 82);
        assert!(
            (t2.ours_tops_w - 243.6).abs() / 243.6 < 0.05,
            "{}",
            t2.ours_tops_w
        );
    }

    #[test]
    fn table_has_six_rows_and_renders() {
        let t2 = run(&MacroConfig::default(), 5, 83);
        assert_eq!(t2.rows.len(), 6);
        let s = render(&t2);
        assert!(s.contains("This Work"));
        assert!(s.contains("ESSCIRC'21"));
    }
}

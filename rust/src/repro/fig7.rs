//! Experiments E6/E7 — Fig 7(a) computing linearity and Fig 7(b) V_charge
//! droop without the clamp + current mirror.
//!
//! 7(a): uniform random (8-bit input × 2-bit weight) stimulus over many
//! columns; plot T_out against Σ T_in·G and fit a line — the paper shows
//! "excellent linearity"; we report R² and max deviation.
//!
//! 7(b): charge one column with and without the Clamping&CM circuit and
//! measure the droop at 5 ns and 10 ns (paper: 19.3 % and 39.6 %).

use crate::circuit::osg::{self, OsgParams};
use crate::config::{MacroConfig, NonIdeality};
use crate::macro_model::CimMacro;
use crate::util::rng::Rng;
use crate::util::stats::{line_fit, LineFit};

use super::report;

/// Fig 7(a) result.
#[derive(Debug, Clone)]
pub struct Fig7a {
    pub points: usize,
    pub fit: LineFit,
    /// Expected slope = α (Eq. 2).
    pub alpha: f64,
    pub csv_path: String,
}

pub fn run_fig7a(cfg: &MacroConfig, n_points: usize, seed: u64) -> Fig7a {
    let mut m = CimMacro::new(cfg.clone());
    let mut rng = Rng::new(seed);
    let mut xs = Vec::with_capacity(n_points);
    let mut ys = Vec::with_capacity(n_points);
    while xs.len() < n_points {
        // Fresh random weights periodically to cover the weight space.
        let codes: Vec<u8> = (0..cfg.rows * cfg.cols)
            .map(|_| rng.below(4) as u8)
            .collect();
        m.program(&codes);
        let x: Vec<u32> = (0..cfg.rows).map(|_| rng.below(256) as u32).collect();
        let r = m.mvm(&x);
        let ideal = m.ideal_mvm(&x);
        for c in 0..cfg.cols {
            if xs.len() >= n_points {
                break;
            }
            xs.push(ideal[c] * cfg.t_bit_ns); // Σ T_in·G  (ns·µS)
            ys.push(r.t_out_ns[c]);
        }
    }
    let fit = line_fit(&xs, &ys);
    let csv = report::xy_csv(&[("sum_tin_g_nsus", &xs), ("t_out_ns", &ys)]);
    let path = report::save("fig7a_linearity.csv", &csv);
    Fig7a {
        points: n_points,
        fit,
        alpha: cfg.alpha(),
        csv_path: path.display().to_string(),
    }
}

pub fn render_fig7a(f: &Fig7a) -> String {
    format!(
        "Fig 7(a) — T_out vs Σ T_in·G ({} points)\n\
         slope: {:.6} ns/(µS·ns)  (α = {:.6})\n\
         intercept: {:.3e} ns\n\
         R² = {:.9}   rmse = {:.3e} ns   max|err| = {:.3e} ns\n\
         points: {}\n",
        f.points, f.fit.b, f.alpha, f.fit.a, f.fit.r2, f.fit.rmse,
        f.fit.max_abs_err, f.csv_path
    )
}

/// Fig 7(b) result.
#[derive(Debug, Clone)]
pub struct Fig7b {
    pub active_rows: usize,
    pub droop_5ns: f64,
    pub droop_10ns: f64,
    pub csv_path: String,
}

/// Stress column: `active_rows` rows at max conductance held open ≥10 ns.
pub fn run_fig7b(cfg: &MacroConfig, active_rows: usize) -> Fig7b {
    let g_max = cfg.level_map.levels()[3];
    let windows: Vec<(f64, f64)> =
        (0..active_rows).map(|_| (12.0, g_max)).collect();
    let ideal = OsgParams::ideal(
        cfg.v_read(),
        cfg.c_rt_ff,
        cfg.c_com_ff,
        cfg.i_com_ua,
    );
    let mut droop = ideal;
    droop.clamp_cm_enabled = false;

    let dt = 0.002;
    let wf_i = osg::waveforms(&ideal, &windows, 12.0, dt);
    let wf_d = osg::waveforms(&droop, &windows, 12.0, dt);
    let vi = wf_i.get("v_charge").unwrap();
    let vd = wf_d.get("v_charge").unwrap();
    let droop_at = |t: f64| 1.0 - vd.at(t) / vi.at(t);

    // Merge both runs into one CSV (t, with mirror, without).
    let ts: Vec<f64> = (0..=(12.0 / 0.05) as usize)
        .map(|i| i as f64 * 0.05)
        .collect();
    let with: Vec<f64> = ts.iter().map(|&t| vi.at(t)).collect();
    let without: Vec<f64> = ts.iter().map(|&t| vd.at(t)).collect();
    let csv = report::xy_csv(&[
        ("t_ns", &ts),
        ("v_charge_with_cm", &with),
        ("v_charge_without_cm", &without),
    ]);
    let path = report::save("fig7b_vcharge_droop.csv", &csv);

    Fig7b {
        active_rows,
        droop_5ns: droop_at(5.0),
        droop_10ns: droop_at(10.0),
        csv_path: path.display().to_string(),
    }
}

/// Paper-matched stress level (DESIGN.md §5 E7): the droop magnitude
/// depends on the column load G_tot·t/C_rt; 60 max-G rows lands in the
/// paper's regime (≈20 %@5 ns, ≈37 %@10 ns vs paper's 19.3 %/39.6 %).
pub const FIG7B_ACTIVE_ROWS: usize = 60;

pub fn render_fig7b(f: &Fig7b) -> String {
    format!(
        "Fig 7(b) — V_charge droop without Clamping&CM ({} rows @ G_max)\n\
         droop @ 5 ns:  {:.1} %   (paper: 19.3 %)\n\
         droop @ 10 ns: {:.1} %   (paper: 39.6 %)\n\
         curves: {}\n",
        f.active_rows,
        f.droop_5ns * 100.0,
        f.droop_10ns * 100.0,
        f.csv_path
    )
}

/// Ablation: end-to-end MAC error caused by running the macro in droop
/// mode (quantifies why the mirror matters for accuracy, §IV-B).
pub fn droop_mac_error(cfg: &MacroConfig, seed: u64) -> f64 {
    let droop_cfg = MacroConfig {
        nonideal: NonIdeality {
            clamp_current_mirror: false,
            ..NonIdeality::ideal()
        },
        ..cfg.clone()
    };
    let mut m = CimMacro::new(droop_cfg);
    let mut rng = Rng::new(seed);
    let codes: Vec<u8> = (0..cfg.rows * cfg.cols)
        .map(|_| rng.below(4) as u8)
        .collect();
    m.program(&codes);
    let x: Vec<u32> = (0..cfg.rows).map(|_| rng.below(256) as u32).collect();
    let r = m.mvm(&x);
    let ideal = m.ideal_mvm(&x);
    let mut rel = 0.0f64;
    for (g, w) in r.y_mac.iter().zip(&ideal) {
        rel += (g - w).abs() / w.max(1.0);
    }
    rel / cfg.cols as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7a_linearity_is_excellent() {
        std::env::set_var("SPIKEMRAM_RESULTS", "/tmp/spikemram_test_results");
        let cfg = MacroConfig::default();
        let f = run_fig7a(&cfg, 512, 71);
        assert!(f.fit.r2 > 0.999999, "R² {}", f.fit.r2);
        assert!((f.fit.b - f.alpha).abs() / f.alpha < 1e-6);
        assert!(f.fit.a.abs() < 1e-6);
    }

    #[test]
    fn fig7b_droop_matches_paper_regime() {
        std::env::set_var("SPIKEMRAM_RESULTS", "/tmp/spikemram_test_results");
        let f = run_fig7b(&MacroConfig::default(), FIG7B_ACTIVE_ROWS);
        // Paper: 19.3 % @5 ns, 39.6 % @10 ns. A single-RC behavioral model
        // reproduces the shape (concave, roughly doubling): accept ±6 pts.
        assert!(
            (f.droop_5ns - 0.193).abs() < 0.06,
            "droop@5ns {}",
            f.droop_5ns
        );
        assert!(
            (f.droop_10ns - 0.396).abs() < 0.06,
            "droop@10ns {}",
            f.droop_10ns
        );
        assert!(f.droop_10ns > f.droop_5ns);
    }

    #[test]
    fn droop_mode_corrupts_macs_measurably() {
        let err = droop_mac_error(&MacroConfig::default(), 72);
        assert!(err > 0.05, "mean rel err {err}");
    }
}

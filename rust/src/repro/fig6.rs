//! Experiments E4/E5 — Fig 6(a) power breakdown and Fig 6(b) sensing
//! energy comparison.
//!
//! 6(a): Monte-Carlo over uniform-random 8-bit inputs on the full macro
//! simulator, averaging the per-component energy ledger — the paper
//! states OSG = 72.6 % of total.
//!
//! 6(b): every readout scheme's per-conversion energy at 8 bits plus a
//! precision sweep (4..10 bits) showing the scaling trends the models
//! generate beyond the calibrated anchor point.

use crate::baselines::{
    anchors, CogReadout, OsgReadout, Readout, SarAdc, Tdc,
};
use crate::config::MacroConfig;
use crate::energy::EnergyBreakdown;
use crate::macro_model::CimMacro;
use crate::util::rng::Rng;

use super::report::{self, Table};

/// Fig 6(a) result.
#[derive(Debug, Clone)]
pub struct Fig6a {
    pub mean_energy: EnergyBreakdown,
    /// shares: [array, smu, osg, control, noc, write] — noc and write
    /// are always 0 for a single macro op (the fabric charges NoC,
    /// DESIGN.md S15; the reliability runtime charges writes, S19).
    pub shares: [f64; 6],
    pub tops_per_watt: f64,
    pub mvms: usize,
}

pub fn run_fig6a(cfg: &MacroConfig, mvms: usize, seed: u64) -> Fig6a {
    let mut m = CimMacro::new(cfg.clone());
    let mut rng = Rng::new(seed);
    let codes: Vec<u8> = (0..cfg.rows * cfg.cols)
        .map(|_| rng.below(4) as u8)
        .collect();
    m.program(&codes);
    // One batched engine call for the whole Monte-Carlo sweep
    // (DESIGN.md S16) — the draws and per-op ledgers are bit-identical
    // to the serial per-MVM loop.
    let xs: Vec<Vec<u32>> = (0..mvms)
        .map(|_| (0..cfg.rows).map(|_| rng.below(256) as u32).collect())
        .collect();
    let total = m.mvm_batch(&xs).total_energy();
    let mean = total.scaled(1.0 / mvms as f64);
    let tops = crate::energy::tops_per_watt(cfg.ops_per_mvm(), mean.total_fj());
    Fig6a {
        shares: mean.shares(),
        mean_energy: mean,
        tops_per_watt: tops,
        mvms,
    }
}

pub fn render_fig6a(f: &Fig6a) -> String {
    let mut t = Table::new(
        "Fig 6(a) — power breakdown (Monte-Carlo, uniform 8-bit inputs)",
        &["Component", "Energy / MVM", "Share", "Paper"],
    );
    let names = ["Array read", "SMU", "OSG", "Control"];
    let paper = ["(small)", "—", "72.6 %", "—"];
    let vals = [
        f.mean_energy.array_fj,
        f.mean_energy.smu_fj,
        f.mean_energy.osg_fj,
        f.mean_energy.control_fj,
    ];
    for i in 0..4 {
        t.row(&[
            names[i].into(),
            format!("{:.1} pJ", vals[i] / 1000.0),
            format!("{:.1} %", f.shares[i] * 100.0),
            paper[i].into(),
        ]);
    }
    let mut s = t.render();
    s.push_str(&format!(
        "\ntotal {:.1} pJ/MVM → {:.1} TOPS/W (paper: 243.6) over {} MVMs\n",
        f.mean_energy.total_pj(),
        f.tops_per_watt,
        f.mvms
    ));
    s
}

/// Fig 6(b) result: per-scheme conversion energy + reductions.
#[derive(Debug, Clone)]
pub struct Fig6b {
    /// (name, energy fJ at 8 b, our reduction vs it, paper's reduction)
    pub rows: Vec<(String, f64, f64, Option<f64>)>,
    pub sweep_csv: String,
}

pub fn run_fig6b(cfg: &MacroConfig) -> Fig6b {
    let ours = OsgReadout::new(cfg.clone());
    let adc = SarAdc::calibrated(8, anchors::ADC_DAC24_FJ);
    let cog = CogReadout::calibrated(8, anchors::SPIKE_DAC20_FJ);
    let tdc = Tdc::calibrated(8, anchors::TDC_NATURE22_FJ);

    let e_ours = ours.energy_per_conversion_fj(8);
    let schemes: Vec<(&dyn Readout, Option<f64>)> = vec![
        (&adc, Some(0.966)),
        (&cog, Some(0.928)),
        (&tdc, Some(0.712)),
        (&ours, None),
    ];
    let rows = schemes
        .iter()
        .map(|(s, paper)| {
            let e = s.energy_per_conversion_fj(8);
            (s.name().to_string(), e, 1.0 - e_ours / e, *paper)
        })
        .collect();

    // Precision sweep 4..=10 bits (model-generated trends).
    let bits: Vec<f64> = (4..=10).map(|b| b as f64).collect();
    let col = |s: &dyn Readout| -> Vec<f64> {
        (4..=10u32)
            .map(|b| s.energy_per_conversion_fj(b))
            .collect()
    };
    let csv = report::xy_csv(&[
        ("bits", &bits),
        ("osg_fj", &col(&ours)),
        ("adc_fj", &col(&adc)),
        ("cog_fj", &col(&cog)),
        ("tdc_fj", &col(&tdc)),
    ]);
    let path = report::save("fig6b_sensing_energy_sweep.csv", &csv);
    Fig6b {
        rows,
        sweep_csv: path.display().to_string(),
    }
}

pub fn render_fig6b(f: &Fig6b) -> String {
    let mut t = Table::new(
        "Fig 6(b) — sensing/readout energy per 8-bit conversion",
        &["Scheme", "Energy", "Our reduction", "Paper"],
    );
    for (name, e, red, paper) in &f.rows {
        t.row(&[
            name.clone(),
            format!("{:.2} pJ", e / 1000.0),
            if *red > 0.0 {
                format!("{:.1} %", red * 100.0)
            } else {
                "—".into()
            },
            paper
                .map(|p| format!("{:.1} %", p * 100.0))
                .unwrap_or_else(|| "—".into()),
        ]);
    }
    let mut s = t.render();
    s.push_str(&format!("\nprecision sweep: {}\n", f.sweep_csv));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6a_reproduces_osg_share_and_peak_efficiency() {
        let f = run_fig6a(&MacroConfig::default(), 20, 61);
        assert!(
            (f.shares[2] - 0.726).abs() < 0.03,
            "OSG share {}",
            f.shares[2]
        );
        assert!(
            (f.tops_per_watt - 243.6).abs() / 243.6 < 0.05,
            "{} TOPS/W",
            f.tops_per_watt
        );
    }

    #[test]
    fn fig6b_reproduces_reductions() {
        std::env::set_var("SPIKEMRAM_RESULTS", "/tmp/spikemram_test_results");
        let f = run_fig6b(&MacroConfig::default());
        let by_name = |n: &str| {
            f.rows
                .iter()
                .find(|(name, ..)| name.contains(n))
                .unwrap()
                .2
        };
        assert!((by_name("ADC") - 0.966).abs() < 0.01);
        assert!((by_name("COG") - 0.928).abs() < 0.01);
        assert!((by_name("TDC") - 0.712).abs() < 0.02);
    }

    #[test]
    fn render_includes_paper_column() {
        std::env::set_var("SPIKEMRAM_RESULTS", "/tmp/spikemram_test_results");
        let s = render_fig6b(&run_fig6b(&MacroConfig::default()));
        assert!(s.contains("96.6 %"));
        assert!(s.contains("OSG (this work)"));
    }
}

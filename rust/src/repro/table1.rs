//! Experiment E1 — Table I: key parameters of the simulation.

use crate::config::MacroConfig;

use super::report::Table;

/// Render Table I from a config (checks nothing; the config tests pin the
/// values — this is the human-readable artifact).
pub fn table1(cfg: &MacroConfig) -> String {
    let mut t = Table::new(
        "Table I — Key parameters of simulation",
        &["Parameter", "Value", "Source"],
    );
    t.row(&[
        "Cell structure".into(),
        format!("3T-2J ({} states/cell)", cfg.states_per_cell()),
        "paper Table I".into(),
    ]);
    t.row(&[
        "Supply voltage".into(),
        format!("{:.1} V", cfg.vdd),
        "paper Table I".into(),
    ]);
    t.row(&[
        "R_LRS of MTJ".into(),
        format!("{:.0} MΩ", cfg.r_lrs_mohm),
        "paper Table I [25]".into(),
    ]);
    t.row(&[
        "TMR".into(),
        format!("{:.0} %", cfg.tmr * 100.0),
        "paper Table I".into(),
    ]);
    t.row(&[
        "Array size".into(),
        format!("{}×{}", cfg.rows, cfg.cols),
        "paper §IV".into(),
    ]);
    t.row(&[
        "Interval per bit".into(),
        format!("{:.1} ns", cfg.t_bit_ns),
        "paper §IV".into(),
    ]);
    t.row(&[
        "C_rt / C_com".into(),
        format!("{:.0} fF / {:.0} fF", cfg.c_rt_ff, cfg.c_com_ff),
        "paper §IV".into(),
    ]);
    t.row(&[
        "V_in,clamp / V_clamp".into(),
        format!(
            "{:.0} mV / {:.0} mV",
            cfg.v_in_clamp * 1000.0,
            cfg.v_clamp * 1000.0
        ),
        "paper §IV".into(),
    ]);
    t.row(&[
        "V_read".into(),
        format!("{:.0} mV", cfg.v_read() * 1000.0),
        "derived".into(),
    ]);
    t.row(&[
        "I_com".into(),
        format!("{:.1} µA", cfg.i_com_ua),
        "sized (DESIGN §6)".into(),
    ]);
    t.row(&[
        "α (Eq. 2)".into(),
        format!("{:.4} ns/(µS·ns)", cfg.alpha()),
        "derived".into(),
    ]);
    t.row(&[
        "Input / weight precision".into(),
        format!("{} b / {} b", cfg.input_bits, cfg.weight_bits),
        "paper §IV".into(),
    ]);
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_paper_values() {
        let s = table1(&MacroConfig::default());
        for needle in [
            "3T-2J", "1.1 V", "1 MΩ", "100 %", "128×128", "0.2 ns",
            "200 fF", "300 mV / 400 mV", "100 mV",
        ] {
            assert!(s.contains(needle), "missing {needle} in:\n{s}");
        }
    }
}

//! Experiment E2 — Fig 3(c): SMU transient simulation.
//!
//! One row receives a dual-spike pair; we render the input spikes, the
//! DFF's Event_flag_i, and the clamped V_in settling between V_clamp and
//! V_in,clamp — the same three traces the paper's scope shot shows.

use crate::circuit::smu::{SmuParams, SmuRow};
use crate::coding::{DualSpikeCodec, SpikePair};
use crate::config::MacroConfig;

use super::report;

/// Outcome summary of the Fig 3(c) run.
#[derive(Debug, Clone)]
pub struct Fig3c {
    pub pair: SpikePair,
    pub flag_duration_ns: f64,
    pub v_in_active_mv: f64,
    pub v_in_idle_mv: f64,
    pub csv_path: String,
}

/// Run the SMU transient for input value `x` and save the waveform CSV.
pub fn run(cfg: &MacroConfig, x: u32) -> Fig3c {
    let codec = DualSpikeCodec::new(cfg.t_bit_ns, cfg.input_bits);
    let pair = codec.encode(x, 1.0); // first spike at t = 1 ns
    let smu = SmuRow::new(SmuParams::default_28nm(cfg.v_clamp, cfg.v_in_clamp));
    let t_end = pair.t1_ns() + 4.0;
    let wf = smu.waveforms(&pair, t_end, 0.002);

    let flag = smu.flag_window(&pair).expect("nonzero value");
    let v_in = wf.get("v_in").unwrap();
    let mid = (flag.rise_ns + flag.fall_ns) / 2.0;
    let fig = Fig3c {
        pair,
        flag_duration_ns: flag.duration_ns(),
        v_in_active_mv: v_in.at(mid) * 1000.0,
        v_in_idle_mv: v_in.at(t_end) * 1000.0,
        csv_path: report::save("fig3c_smu_transient.csv", &wf.to_csv())
            .display()
            .to_string(),
    };
    fig
}

pub fn render(f: &Fig3c) -> String {
    format!(
        "Fig 3(c) — SMU transient\n\
         input spike pair: t0 = {:.2} ns, Δ = {:.2} ns\n\
         Event_flag_i duration: {:.2} ns (= inter-spike interval)\n\
         V_in during event: {:.1} mV (target {:.0} mV)\n\
         V_in after event:  {:.1} mV (target {:.0} mV)\n\
         waveforms: {}\n",
        f.pair.t0_ns,
        f.pair.dt_ns,
        f.flag_duration_ns,
        f.v_in_active_mv,
        300.0,
        f.v_in_idle_mv,
        400.0,
        f.csv_path
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smu_transient_matches_paper_behaviour() {
        std::env::set_var("SPIKEMRAM_RESULTS", "/tmp/spikemram_test_results");
        let cfg = MacroConfig::default();
        let f = run(&cfg, 16); // Δ = 3.2 ns, as in Fig 3(c)
        assert!((f.pair.dt_ns - 3.2).abs() < 1e-12);
        assert!((f.flag_duration_ns - 3.2).abs() < 1e-9);
        // V_in clamps to 300 mV during the event, 400 mV after.
        assert!((f.v_in_active_mv - 300.0).abs() < 5.0);
        assert!((f.v_in_idle_mv - 400.0).abs() < 5.0);
        assert!(report::exists("fig3c_smu_transient.csv"));
    }
}

//! Experiment E3 — Fig 5: transient simulation of a full conversion on
//! one column: charge phase while Event_flag is high, then the C_com ramp
//! and the comparator firing the second output spike.

use crate::circuit::osg::{self, OsgParams};
use crate::config::MacroConfig;

use super::report;

#[derive(Debug, Clone)]
pub struct Fig5 {
    /// (value, code) per active row driven into the column.
    pub stimulus: Vec<(u32, u8)>,
    pub v_charge: f64,
    pub t_flag_drop_ns: f64,
    pub t_out_ns: f64,
    /// Exact Eq. 2 prediction for the same stimulus.
    pub t_out_eq2_ns: f64,
    pub csv_path: String,
}

/// Drive a few rows with mixed values (the paper uses a handful of active
/// wordlines) and render the conversion waveforms.
pub fn run(cfg: &MacroConfig) -> Fig5 {
    let stimulus: Vec<(u32, u8)> = vec![(200, 3), (120, 2), (64, 1), (255, 0)];
    let levels = cfg.level_map.levels();
    let windows: Vec<(f64, f64)> = stimulus
        .iter()
        .map(|&(x, code)| {
            (x as f64 * cfg.t_bit_ns, levels[code as usize])
        })
        .collect();
    let t_drop = windows
        .iter()
        .map(|&(t, _)| t)
        .fold(0.0, f64::max);
    let params = OsgParams::ideal(
        cfg.v_read(),
        cfg.c_rt_ff,
        cfg.c_com_ff,
        cfg.i_com_ua,
    );
    let result = osg::convert(&params, &windows, t_drop);
    let wf = osg::waveforms(&params, &windows, t_drop, 0.005);

    let mac: f64 = windows.iter().map(|&(t, g)| t * g).sum();
    Fig5 {
        stimulus,
        v_charge: result.v_charge,
        t_flag_drop_ns: t_drop,
        t_out_ns: result.t_out_ns,
        t_out_eq2_ns: params.alpha() * mac,
        csv_path: report::save("fig5_macro_transient.csv", &wf.to_csv())
            .display()
            .to_string(),
    }
}

pub fn render(f: &Fig5) -> String {
    let mut s = String::from("Fig 5 — transient of one column conversion\n");
    for (i, (x, c)) in f.stimulus.iter().enumerate() {
        s.push_str(&format!("  row {i}: input {x} (code {c})\n"));
    }
    s.push_str(&format!(
        "Event_flag drops at {:.2} ns (last input spike)\n\
         V_charge at drop: {:.4} V\n\
         T_out (sim): {:.4} ns — Eq. 2 predicts {:.4} ns (Δ {:.2e} ns)\n\
         waveforms: {}\n",
        f.t_flag_drop_ns,
        f.v_charge,
        f.t_out_ns,
        f.t_out_eq2_ns,
        (f.t_out_ns - f.t_out_eq2_ns).abs(),
        f.csv_path
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversion_matches_eq2() {
        std::env::set_var("SPIKEMRAM_RESULTS", "/tmp/spikemram_test_results");
        let f = run(&MacroConfig::default());
        assert!((f.t_out_ns - f.t_out_eq2_ns).abs() < 1e-9);
        assert!(f.t_flag_drop_ns > 0.0);
        assert!(f.v_charge > 0.0 && f.v_charge < 1.1);
        assert!(report::exists("fig5_macro_transient.csv"));
    }

    #[test]
    fn waveform_csv_has_all_signals() {
        std::env::set_var("SPIKEMRAM_RESULTS", "/tmp/spikemram_test_results");
        run(&MacroConfig::default());
        let csv = report::load("fig5_macro_transient.csv").unwrap();
        let header = csv.lines().next().unwrap();
        for sig in ["event_flag", "v_charge", "v_com", "spike_out"] {
            assert!(header.contains(sig), "missing {sig}");
        }
    }
}

//! Output helpers for the repro harness (DESIGN.md S14): results directory
//! management, CSV/markdown writers, and a tiny fixed-width table builder
//! shared by all experiments.

use std::fmt::Write as _;
use std::path::PathBuf;

/// Resolve the results directory (created on demand).
pub fn results_dir() -> PathBuf {
    let dir = std::env::var("SPIKEMRAM_RESULTS")
        .unwrap_or_else(|_| "results".to_string());
    let p = PathBuf::from(dir);
    let _ = std::fs::create_dir_all(&p);
    p
}

/// Save text into `results/<name>` and return the full path.
pub fn save(name: &str, contents: &str) -> PathBuf {
    let path = results_dir().join(name);
    if let Some(parent) = path.parent() {
        let _ = std::fs::create_dir_all(parent);
    }
    std::fs::write(&path, contents)
        .unwrap_or_else(|e| panic!("writing {}: {e}", path.display()));
    path
}

/// Load a previously saved result (tests use this).
pub fn load(name: &str) -> Option<String> {
    std::fs::read_to_string(results_dir().join(name)).ok()
}

/// Does a result exist?
pub fn exists(name: &str) -> bool {
    results_dir().join(name).exists()
}

/// Fixed-width text table (markdown-flavored).
#[derive(Debug, Default, Clone)]
pub struct Table {
    pub title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "column count");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "## {}\n", self.title);
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for i in 0..ncols {
                let _ = write!(line, " {:<w$} |", cells[i], w = widths[i]);
            }
            line
        };
        let _ = writeln!(out, "{}", fmt_row(&self.header));
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{}|", "-".repeat(w + 2));
        }
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row));
        }
        out
    }
}

/// Render xy-series as CSV.
pub fn xy_csv(cols: &[(&str, &[f64])]) -> String {
    assert!(!cols.is_empty());
    let n = cols[0].1.len();
    assert!(cols.iter().all(|(_, v)| v.len() == n), "ragged columns");
    let mut out = cols
        .iter()
        .map(|(name, _)| *name)
        .collect::<Vec<_>>()
        .join(",");
    out.push('\n');
    for i in 0..n {
        let row = cols
            .iter()
            .map(|(_, v)| format!("{:.9}", v[i]))
            .collect::<Vec<_>>()
            .join(",");
        out.push_str(&row);
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_markdown() {
        let mut t = Table::new("Demo", &["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("## Demo"));
        assert!(s.contains("| a | bb |"));
        assert!(s.contains("| 1 | 2  |"));
    }

    #[test]
    #[should_panic(expected = "column count")]
    fn table_rejects_ragged_rows() {
        Table::new("x", &["a"]).row(&["1".into(), "2".into()]);
    }

    #[test]
    fn xy_csv_shape() {
        let csv = xy_csv(&[("t", &[0.0, 1.0]), ("v", &[2.0, 3.0])]);
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("t,v\n"));
    }

    #[test]
    fn save_and_load_roundtrip() {
        std::env::set_var("SPIKEMRAM_RESULTS", "/tmp/spikemram_test_results");
        save("unit/roundtrip.txt", "hello");
        assert_eq!(load("unit/roundtrip.txt").unwrap(), "hello");
        assert!(exists("unit/roundtrip.txt"));
    }
}

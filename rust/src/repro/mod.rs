//! Figure/table regeneration harness (DESIGN.md S14, §5): one module per
//! experiment in the paper's evaluation, each producing a structured
//! result, a rendered text block, and CSVs under `results/`.
//!
//! | experiment | module | paper artifact |
//! |---|---|---|
//! | E1 | [`table1`] | Table I |
//! | E2 | [`fig3`] | Fig 3(c) SMU transient |
//! | E3 | [`fig5`] | Fig 5 conversion transient |
//! | E4 | [`fig6::run_fig6a`] | Fig 6(a) power breakdown |
//! | E5 | [`fig6::run_fig6b`] | Fig 6(b) sensing energy |
//! | E6 | [`fig7::run_fig7a`] | Fig 7(a) linearity |
//! | E7 | [`fig7::run_fig7b`] | Fig 7(b) droop |
//! | E8 | [`table2`] | Table II comparison |
//! | EX1 | [`scaling`] | extension: array-size scaling |
//! | EX2 | [`fabric`] | extension: multi-macro fabric scaling (S15) |
//! | EX3 | [`stream`] | extension: temporal streaming sweep (S18) |
//! | EX4 | [`reliability`] | extension: fault-injection reliability (S19) |
//! | EX5 | [`overload`] | extension: overload & admission control (S21) |
//! | EX6 | [`endurance`] | extension: mission-clock endurance & wear SLO (S22) |
//! | EX7 | [`serving`] | extension: network serving over TCP (S23) |
//!
//! E9 (end-to-end SNN) lives in `examples/snn_inference.rs`.

pub mod ablations;
pub mod endurance;
pub mod fabric;
pub mod fig3;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod overload;
pub mod reliability;
pub mod report;
pub mod scaling;
pub mod serving;
pub mod stream;
pub mod table1;
pub mod table2;

use crate::config::MacroConfig;

/// Run every experiment and return the combined report text.
pub fn run_all(cfg: &MacroConfig, seed: u64) -> String {
    let mut out = String::new();
    out.push_str(&table1::table1(cfg));
    out.push('\n');
    out.push_str(&fig3::render(&fig3::run(cfg, 16)));
    out.push('\n');
    out.push_str(&fig5::render(&fig5::run(cfg)));
    out.push('\n');
    out.push_str(&fig6::render_fig6a(&fig6::run_fig6a(cfg, 50, seed)));
    out.push('\n');
    out.push_str(&fig6::render_fig6b(&fig6::run_fig6b(cfg)));
    out.push('\n');
    out.push_str(&fig7::render_fig7a(&fig7::run_fig7a(cfg, 2048, seed)));
    out.push('\n');
    out.push_str(&fig7::render_fig7b(&fig7::run_fig7b(
        cfg,
        fig7::FIG7B_ACTIVE_ROWS,
    )));
    out.push('\n');
    out.push_str(&table2::render(&table2::run(cfg, 50, seed)));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_all_produces_every_section() {
        std::env::set_var("SPIKEMRAM_RESULTS", "/tmp/spikemram_test_results");
        let s = run_all(&MacroConfig::default(), 99);
        for needle in [
            "Table I", "Fig 3(c)", "Fig 5", "Fig 6(a)", "Fig 6(b)",
            "Fig 7(a)", "Fig 7(b)", "Table II",
        ] {
            assert!(s.contains(needle), "missing {needle}");
        }
    }
}

//! Neural-network workload layer (DESIGN.md S13): synthetic-digits
//! dataset, float MLP + trainer, 2-bit conductance quantization, and
//! macro-mapped inference with energy accounting — the end-to-end
//! validation pipeline (experiment E9).

pub mod dataset;
pub mod infer;
pub mod mlp;
pub mod quant;

pub use dataset::{Dataset, Example};
pub use infer::{collect_activations, InferStats, MacroMlp};
pub use mlp::{accuracy, train, Mlp};
pub use quant::{quantize_layer, ActQuant, QuantLayer};

//! Float MLP + SGD trainer (DESIGN.md S13). The deployment pipeline is
//! train-float → quantize to 2-bit conductance codes (`quant.rs`) → run
//! on the macro (`infer.rs`), mirroring how a real accelerator would be
//! fed. Pure Rust, no BLAS — the sizes are tiny (256-128-128-16).

use crate::util::rng::Rng;

/// Fully-connected layer y = relu?(W·x + b), W row-major (out × in).
#[derive(Debug, Clone)]
pub struct Dense {
    pub in_dim: usize,
    pub out_dim: usize,
    pub w: Vec<f32>,
    pub b: Vec<f32>,
}

impl Dense {
    pub fn new(in_dim: usize, out_dim: usize, rng: &mut Rng) -> Self {
        // He initialization.
        let sd = (2.0 / in_dim as f64).sqrt();
        Dense {
            in_dim,
            out_dim,
            w: (0..in_dim * out_dim)
                .map(|_| rng.normal_ms(0.0, sd) as f32)
                .collect(),
            b: vec![0.0; out_dim],
        }
    }

    pub fn forward(&self, x: &[f32], out: &mut Vec<f32>) {
        out.clear();
        out.resize(self.out_dim, 0.0);
        for o in 0..self.out_dim {
            let row = &self.w[o * self.in_dim..(o + 1) * self.in_dim];
            let mut acc = self.b[o];
            for (wi, xi) in row.iter().zip(x) {
                acc += wi * xi;
            }
            out[o] = acc;
        }
    }
}

/// 3-layer MLP: 256 → h1 → h2 → 16 (10 classes used, padded for tiling).
#[derive(Debug, Clone)]
pub struct Mlp {
    pub l1: Dense,
    pub l2: Dense,
    pub l3: Dense,
}

pub const IN_DIM: usize = 256;
pub const H1: usize = 128;
pub const H2: usize = 128;
pub const OUT_DIM: usize = 16; // 10 classes + padding to tile nicely

fn relu(x: &mut [f32]) {
    for v in x {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

fn softmax_xent_grad(logits: &[f32], label: usize, grad: &mut Vec<f32>) -> f32 {
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = logits.iter().map(|&z| (z - max).exp()).collect();
    let sum: f32 = exps.iter().sum();
    grad.clear();
    grad.extend(exps.iter().map(|e| e / sum));
    let loss = -(grad[label].max(1e-12)).ln();
    grad[label] -= 1.0;
    loss
}

impl Mlp {
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        Mlp {
            l1: Dense::new(IN_DIM, H1, &mut rng),
            l2: Dense::new(H1, H2, &mut rng),
            l3: Dense::new(H2, OUT_DIM, &mut rng),
        }
    }

    /// Forward pass; returns (h1, h2, logits).
    pub fn forward(&self, x: &[f32]) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut h1 = Vec::new();
        let mut h2 = Vec::new();
        let mut logits = Vec::new();
        self.l1.forward(x, &mut h1);
        relu(&mut h1);
        self.l2.forward(&h1, &mut h2);
        relu(&mut h2);
        self.l3.forward(&h2, &mut logits);
        (h1, h2, logits)
    }

    pub fn predict(&self, x: &[f32]) -> usize {
        let (_, _, logits) = self.forward(x);
        argmax(&logits[..10])
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap()
}

/// Plain SGD with momentum.
pub struct Trainer {
    pub lr: f32,
    pub momentum: f32,
    v1: Vec<f32>,
    v2: Vec<f32>,
    v3: Vec<f32>,
    vb1: Vec<f32>,
    vb2: Vec<f32>,
    vb3: Vec<f32>,
}

impl Trainer {
    pub fn new(model: &Mlp, lr: f32, momentum: f32) -> Self {
        Trainer {
            lr,
            momentum,
            v1: vec![0.0; model.l1.w.len()],
            v2: vec![0.0; model.l2.w.len()],
            v3: vec![0.0; model.l3.w.len()],
            vb1: vec![0.0; model.l1.b.len()],
            vb2: vec![0.0; model.l2.b.len()],
            vb3: vec![0.0; model.l3.b.len()],
        }
    }

    /// One SGD step on a single example; returns the loss.
    pub fn step(&mut self, m: &mut Mlp, x: &[f32], label: usize) -> f32 {
        let (h1, h2, logits) = m.forward(x);
        let mut dz3 = Vec::new();
        let loss = softmax_xent_grad(&logits, label, &mut dz3);

        // Backprop. dW3 = dz3 ⊗ h2 ; dh2 = W3ᵀ·dz3 (masked by relu).
        let mut dh2 = vec![0.0f32; H2];
        for o in 0..OUT_DIM {
            let g = dz3[o];
            let row = &m.l3.w[o * H2..(o + 1) * H2];
            for (i, &w) in row.iter().enumerate() {
                dh2[i] += w * g;
            }
        }
        for v in dh2.iter_mut().zip(&h2) {
            if *v.1 <= 0.0 {
                *v.0 = 0.0;
            }
        }
        let mut dh1 = vec![0.0f32; H1];
        for o in 0..H2 {
            let g = dh2[o];
            if g == 0.0 {
                continue;
            }
            let row = &m.l2.w[o * H1..(o + 1) * H1];
            for (i, &w) in row.iter().enumerate() {
                dh1[i] += w * g;
            }
        }
        for v in dh1.iter_mut().zip(&h1) {
            if *v.1 <= 0.0 {
                *v.0 = 0.0;
            }
        }

        // Parameter updates (momentum SGD).
        let lr = self.lr;
        let mu = self.momentum;
        let upd =
            |w: &mut [f32], v: &mut [f32], grads: &dyn Fn(usize) -> f32| {
                for i in 0..w.len() {
                    v[i] = mu * v[i] + grads(i);
                    w[i] -= lr * v[i];
                }
            };
        upd(&mut m.l3.w, &mut self.v3, &|i| dz3[i / H2] * h2[i % H2]);
        upd(&mut m.l3.b, &mut self.vb3, &|i| dz3[i]);
        upd(&mut m.l2.w, &mut self.v2, &|i| dh2[i / H1] * h1[i % H1]);
        upd(&mut m.l2.b, &mut self.vb2, &|i| dh2[i]);
        upd(&mut m.l1.w, &mut self.v1, &|i| dh1[i / IN_DIM] * x[i % IN_DIM]);
        upd(&mut m.l1.b, &mut self.vb1, &|i| dh1[i]);
        loss
    }
}

/// Train on a dataset; returns (model, final train accuracy).
pub fn train(
    data: &crate::snn::dataset::Dataset,
    epochs: usize,
    seed: u64,
) -> (Mlp, f64) {
    let mut model = Mlp::new(seed);
    // Per-sample SGD: momentum destabilizes at this batch size (tuning
    // log in EXPERIMENTS.md §E9); plain SGD at lr 0.02 reaches ~97 %.
    let mut trainer = Trainer::new(&model, 0.02, 0.0);
    let mut order: Vec<usize> = (0..data.len()).collect();
    let mut rng = Rng::new(seed ^ 0xfeed);
    for _epoch in 0..epochs {
        rng.shuffle(&mut order);
        for &i in &order {
            let x = data.features_f32(i);
            trainer.step(&mut model, &x, data.examples[i].label);
        }
    }
    let acc = accuracy(&model, data);
    (model, acc)
}

pub fn accuracy(model: &Mlp, data: &crate::snn::dataset::Dataset) -> f64 {
    let mut correct = 0;
    for i in 0..data.len() {
        if model.predict(&data.features_f32(i)) == data.examples[i].label {
            correct += 1;
        }
    }
    correct as f64 / data.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::dataset::Dataset;

    #[test]
    fn forward_shapes() {
        let m = Mlp::new(1);
        let (h1, h2, logits) = m.forward(&vec![0.5; IN_DIM]);
        assert_eq!(h1.len(), H1);
        assert_eq!(h2.len(), H2);
        assert_eq!(logits.len(), OUT_DIM);
    }

    #[test]
    fn loss_decreases_during_training() {
        let data = Dataset::generate(100, 11);
        let mut model = Mlp::new(2);
        let mut trainer = Trainer::new(&model, 0.02, 0.0);
        let first: f32 = (0..data.len())
            .map(|i| {
                trainer.step(
                    &mut model,
                    &data.features_f32(i),
                    data.examples[i].label,
                )
            })
            .sum();
        let later: f32 = (0..data.len())
            .map(|i| {
                trainer.step(
                    &mut model,
                    &data.features_f32(i),
                    data.examples[i].label,
                )
            })
            .sum();
        assert!(later < first, "{later} !< {first}");
    }

    #[test]
    fn trains_to_high_accuracy() {
        let data = Dataset::generate(300, 13);
        let (_, acc) = train(&data, 6, 5);
        assert!(acc > 0.9, "train accuracy {acc}");
    }

    #[test]
    fn generalizes_to_fresh_samples() {
        let train_data = Dataset::generate(300, 17);
        let test_data = Dataset::generate(100, 991);
        let (model, _) = train(&train_data, 6, 5);
        let acc = accuracy(&model, &test_data);
        assert!(acc > 0.85, "test accuracy {acc}");
    }

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
    }
}

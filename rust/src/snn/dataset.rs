//! Synthetic digits dataset (DESIGN.md S13): 16×16 grayscale renderings
//! of the ten digits built from line-segment strokes, with random shift,
//! per-pixel noise and contrast jitter. Stands in for the MNIST-class
//! workload the paper's "neural network accelerator" framing implies
//! (substitution table, DESIGN.md §2) while keeping the repo dependency-
//! and download-free.

use crate::util::rng::Rng;

pub const SIDE: usize = 16;
pub const PIXELS: usize = SIDE * SIDE;
pub const CLASSES: usize = 10;

/// Seven-segment-style strokes per digit on a 16×16 canvas.
/// Segments: (x0, y0, x1, y1) in canvas coordinates 2..=13.
fn strokes(digit: usize) -> &'static [(i32, i32, i32, i32)] {
    const TOP: (i32, i32, i32, i32) = (4, 2, 11, 2);
    const MID: (i32, i32, i32, i32) = (4, 7, 11, 7);
    const BOT: (i32, i32, i32, i32) = (4, 13, 11, 13);
    const TL: (i32, i32, i32, i32) = (4, 2, 4, 7);
    const TR: (i32, i32, i32, i32) = (11, 2, 11, 7);
    const BL: (i32, i32, i32, i32) = (4, 7, 4, 13);
    const BR: (i32, i32, i32, i32) = (11, 7, 11, 13);
    match digit {
        0 => &[TOP, BOT, TL, TR, BL, BR],
        1 => &[TR, BR],
        2 => &[TOP, TR, MID, BL, BOT],
        3 => &[TOP, TR, MID, BR, BOT],
        4 => &[TL, TR, MID, BR],
        5 => &[TOP, TL, MID, BR, BOT],
        6 => &[TOP, TL, MID, BL, BR, BOT],
        7 => &[TOP, TR, BR],
        8 => &[TOP, MID, BOT, TL, TR, BL, BR],
        9 => &[TOP, MID, BOT, TL, TR, BR],
        _ => panic!("digit 0..=9"),
    }
}

fn draw_segment(img: &mut [f32], seg: (i32, i32, i32, i32), dx: i32, dy: i32) {
    let (x0, y0, x1, y1) = seg;
    let steps = (x1 - x0).abs().max((y1 - y0).abs()).max(1);
    for s in 0..=steps {
        let x = x0 + (x1 - x0) * s / steps + dx;
        let y = y0 + (y1 - y0) * s / steps + dy;
        // 2-pixel-thick stroke
        for (ox, oy) in [(0, 0), (1, 0), (0, 1)] {
            let (px, py) = (x + ox, y + oy);
            if (0..SIDE as i32).contains(&px) && (0..SIDE as i32).contains(&py) {
                img[py as usize * SIDE + px as usize] = 1.0;
            }
        }
    }
}

/// One rendered example.
#[derive(Debug, Clone)]
pub struct Example {
    /// 8-bit pixels, row-major 16×16.
    pub pixels: Vec<u8>,
    pub label: usize,
}

/// Render a digit with the given jitter controls.
pub fn render(digit: usize, rng: &mut Rng) -> Example {
    let mut img = vec![0.0f32; PIXELS];
    let dx = rng.int_range(-2, 2) as i32;
    let dy = rng.int_range(-1, 1) as i32;
    for &seg in strokes(digit) {
        draw_segment(&mut img, seg, dx, dy);
    }
    let contrast = rng.uniform(0.7, 1.0);
    let noise_sd = 0.08;
    let pixels = img
        .iter()
        .map(|&v| {
            let x = v as f64 * contrast + rng.normal_ms(0.0, noise_sd);
            (x.clamp(0.0, 1.0) * 255.0).round() as u8
        })
        .collect();
    Example {
        pixels,
        label: digit,
    }
}

/// A generated dataset split.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub examples: Vec<Example>,
}

impl Dataset {
    /// `n` examples with balanced classes, deterministic in `seed`.
    pub fn generate(n: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let mut examples: Vec<Example> =
            (0..n).map(|i| render(i % CLASSES, &mut rng)).collect();
        rng.shuffle(&mut examples);
        Dataset { examples }
    }

    pub fn len(&self) -> usize {
        self.examples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.examples.is_empty()
    }

    /// Pixels as f32 in [0,1] (training input).
    pub fn features_f32(&self, i: usize) -> Vec<f32> {
        self.examples[i]
            .pixels
            .iter()
            .map(|&p| p as f32 / 255.0)
            .collect()
    }

    /// Pixels as 8-bit macro inputs.
    pub fn features_u8(&self, i: usize) -> Vec<u32> {
        self.examples[i].pixels.iter().map(|&p| p as u32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let a = Dataset::generate(50, 7);
        let b = Dataset::generate(50, 7);
        for (x, y) in a.examples.iter().zip(&b.examples) {
            assert_eq!(x.pixels, y.pixels);
            assert_eq!(x.label, y.label);
        }
    }

    #[test]
    fn balanced_classes() {
        let d = Dataset::generate(100, 1);
        let mut counts = [0usize; CLASSES];
        for e in &d.examples {
            counts[e.label] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10), "{counts:?}");
    }

    #[test]
    fn digits_are_distinguishable_in_pixel_space() {
        // Mean intra-class distance must be well below inter-class.
        let mut rng = Rng::new(3);
        let dist = |a: &Example, b: &Example| -> f64 {
            a.pixels
                .iter()
                .zip(&b.pixels)
                .map(|(&x, &y)| {
                    let d = x as f64 - y as f64;
                    d * d
                })
                .sum::<f64>()
                .sqrt()
        };
        let mut intra = 0.0;
        let mut inter = 0.0;
        let mut n_intra = 0;
        let mut n_inter = 0;
        let samples: Vec<Example> = (0..CLASSES)
            .flat_map(|d| (0..4).map(|_| render(d, &mut rng)).collect::<Vec<_>>())
            .collect();
        for i in 0..samples.len() {
            for j in (i + 1)..samples.len() {
                if samples[i].label == samples[j].label {
                    intra += dist(&samples[i], &samples[j]);
                    n_intra += 1;
                } else {
                    inter += dist(&samples[i], &samples[j]);
                    n_inter += 1;
                }
            }
        }
        let intra = intra / n_intra as f64;
        let inter = inter / n_inter as f64;
        assert!(
            inter > 1.15 * intra,
            "inter {inter} should exceed intra {intra}"
        );
    }

    #[test]
    fn pixels_use_dynamic_range() {
        let d = Dataset::generate(20, 5);
        let maxpix = d
            .examples
            .iter()
            .flat_map(|e| e.pixels.iter())
            .cloned()
            .max()
            .unwrap();
        assert!(maxpix > 150);
    }
}

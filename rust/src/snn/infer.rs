//! Quantized inference on the macro (DESIGN.md S13, experiment E9): runs
//! every matmul of the MLP through simulated CIM macros — dual-spike
//! encoded activations, 2-bit conductance weights, OSG readout — with the
//! conductance-offset trick recovering signed weights, and full energy /
//! latency accounting from the per-op ledgers.
//!
//! Two deployment targets behind one `MacroMlp` (DESIGN.md S15):
//! * **per-layer tile pools** (the default): each layer owns one macro
//!   per weight tile; tile MVMs run on scoped worker threads, matching
//!   the model's latency-parallel row tiles in wall-clock too;
//! * **fabric chip** (`attach_fabric`): all layers' shards live on one
//!   event-routed mesh; forwards add NoC traffic (`noc_fj`, hop counts)
//!   while staying bit-identical to the tile-pool path — the fabric is
//!   a *transparent* deployment target.

use anyhow::Result;

use crate::config::{FabricConfig, LevelMap, MacroConfig};
use crate::coordinator::TiledMatrix;
use crate::energy::EnergyBreakdown;
use crate::fabric::{FabricChip, FabricPipeline, StageRelay};
use crate::macro_model::{mvm_tiled_batch_strided, CimMacro, TiledBatchItem};
use crate::snn::dataset::Dataset;
use crate::snn::mlp::{argmax, Mlp};
use crate::snn::quant::{quantize_layer, ActQuant, QuantLayer};

/// One macro-mapped layer: quantized codes tiled onto macros.
struct MacroLayer {
    q: QuantLayer,
    tiled: TiledMatrix,
    /// One programmed macro per weight tile (weight-stationary); empty
    /// when the whole model executes on a shared fabric chip.
    macros: Vec<CimMacro>,
    /// Reusable per-row-tile flat slice batches (DESIGN.md S17).
    xparts: Vec<Vec<u32>>,
}

impl MacroLayer {
    fn new(q: QuantLayer, cfg: &MacroConfig) -> MacroLayer {
        let tile = cfg.rows;
        let tiled = TiledMatrix::new(&q.codes, q.in_dim, q.out_dim, tile);
        let macros = (0..tiled.num_tiles())
            .map(|t| {
                let mut m = CimMacro::new(cfg.clone());
                m.program(tiled.tile_codes_flat(t));
                m
            })
            .collect();
        MacroLayer {
            q,
            tiled,
            macros,
            xparts: Vec::new(),
        }
    }

    /// Run every tile's MVM for a whole minibatch (DESIGN.md S16/S17):
    /// every tile macro streams its weights once over the batch; the
    /// persistent worker pool fans the independent tile macros out, and
    /// the input slices land in reusable flat buffers. Partials come
    /// back per item in deterministic (ti, tj) order plus summed energy
    /// and the critical-path latency.
    fn forward_tiles_batch(&mut self, xs: &[Vec<u32>]) -> Vec<TiledBatchItem> {
        let rt = self.tiled.row_tiles;
        self.xparts.resize_with(rt, Vec::new);
        for p in &mut self.xparts {
            p.clear();
        }
        for x in xs {
            self.tiled.split_input_into(x, &mut self.xparts);
        }
        mvm_tiled_batch_strided(
            &mut self.macros,
            &self.xparts,
            xs.len(),
            rt,
            self.tiled.col_tiles,
        )
    }

    /// Accumulated MAC → float pre-activations (see [`dequant_z`]).
    fn finish_z(&self, x: &[u32], mac: &[f64], x_step: f32) -> Vec<f32> {
        dequant_z(self.q.scale, self.q.g_mid, &self.q.bias, x_step, x, mac)
    }
}

/// Accumulated MAC → float pre-activations: removes the conductance
/// offset, applies the weight scale and the activation step, adds the
/// bias. The single site shared by the serial path
/// ([`MacroLayer::finish_z`]) and the pipelined stage relays
/// ([`MacroMlp::evaluate_pipelined`]) — bit-identity between them
/// (asserted in `rust/tests/fabric_e2e.rs`) must not drift.
fn dequant_z(
    scale: f64,
    g_mid: f64,
    bias: &[f32],
    x_step: f32,
    x: &[u32],
    mac: &[f64],
) -> Vec<f32> {
    let sum_x: f64 = x.iter().map(|&v| v as f64).sum();
    mac.iter()
        .enumerate()
        .map(|(o, &m)| {
            (scale * (m - g_mid * sum_x)) as f32 * x_step
                + bias.get(o).copied().unwrap_or(0.0)
        })
        .collect()
}

/// Float-forward the first `cap` calibration examples and collect the
/// hidden activations `(h1, h2)` — the single calibration sweep shared
/// by [`MacroMlp::from_float`]'s `ActQuant` steps and the stream
/// runtime's λ-threshold normalization (DESIGN.md S18).
pub fn collect_activations(
    model: &Mlp,
    calib: &Dataset,
    cap: usize,
) -> (Vec<f32>, Vec<f32>) {
    let mut h1_all = Vec::new();
    let mut h2_all = Vec::new();
    for i in 0..calib.len().min(cap) {
        let x = calib.features_f32(i);
        let (h1, h2, _) = model.forward(&x);
        h1_all.extend(h1);
        h2_all.extend(h2);
    }
    (h1_all, h2_all)
}

/// The full quantized MLP deployed on macros.
pub struct MacroMlp {
    layers: Vec<MacroLayer>,
    /// When present, forwards route through this chip (DESIGN.md S15)
    /// and the per-layer `macros` pools are empty.
    fabric: Option<FabricChip>,
    /// Activation quantizers between layers (len = layers − 1).
    pub act_quants: Vec<ActQuant>,
    /// Input activation scale (pixels are already 8-bit; step in float
    /// units so float-model parity holds: x_float = pixel/255).
    pub input_step: f32,
}

/// Per-inference statistics.
#[derive(Debug, Clone, Default)]
pub struct InferStats {
    pub energy: EnergyBreakdown,
    pub latency_ns: f64,
    /// MAC operations executed on macros (2 OPs each).
    pub macs: u64,
    /// Spike packets routed on the fabric NoC (0 off-fabric).
    pub noc_packets: u64,
    /// Total NoC hops those packets travelled (0 off-fabric).
    pub noc_hops: u64,
    /// Macro row activations across all layers (DESIGN.md S17) — the
    /// event-driven occupancy of the inference.
    pub active_rows: u64,
}

impl MacroMlp {
    /// Quantize a trained float model and calibrate activation steps on
    /// `calib` examples.
    pub fn from_float(
        model: &Mlp,
        calib: &Dataset,
        cfg: &MacroConfig,
        level_map: LevelMap,
    ) -> MacroMlp {
        let q1 = quantize_layer(
            &model.l1.w,
            &model.l1.b,
            model.l1.in_dim,
            model.l1.out_dim,
            level_map,
        );
        let q2 = quantize_layer(
            &model.l2.w,
            &model.l2.b,
            model.l2.in_dim,
            model.l2.out_dim,
            level_map,
        );
        let q3 = quantize_layer(
            &model.l3.w,
            &model.l3.b,
            model.l3.in_dim,
            model.l3.out_dim,
            level_map,
        );

        // Calibrate activation ranges with float forward passes.
        let (h1_all, h2_all) = collect_activations(model, calib, 64);
        let act_quants = vec![
            ActQuant::calibrate(&h1_all, 99.5),
            ActQuant::calibrate(&h2_all, 99.5),
        ];

        MacroMlp {
            layers: vec![
                MacroLayer::new(q1, cfg),
                MacroLayer::new(q2, cfg),
                MacroLayer::new(q3, cfg),
            ],
            fabric: None,
            act_quants,
            input_step: 1.0 / 255.0,
        }
    }

    /// Re-deploy the quantized layers onto a multi-macro fabric chip:
    /// every layer's weight tiles become NoC-routed mesh tiles
    /// (weight-stationary). Fails when the mesh cannot hold all shards.
    pub fn attach_fabric(
        mut self,
        cfg: &MacroConfig,
        fabric: FabricConfig,
    ) -> Result<MacroMlp> {
        let tiled: Vec<TiledMatrix> =
            self.layers.iter().map(|l| l.tiled.clone()).collect();
        let chip = FabricChip::new(cfg, fabric, tiled)?;
        for l in &mut self.layers {
            l.macros.clear(); // the chip owns the programmed tiles now
        }
        self.fabric = Some(chip);
        Ok(self)
    }

    /// Is this model deployed on a fabric chip?
    pub fn on_fabric(&self) -> bool {
        self.fabric.is_some()
    }

    /// Forward pass from 8-bit pixels; returns (logits, stats). A
    /// single-item run of [`forward_batch`](Self::forward_batch).
    pub fn forward(&mut self, pixels: &[u32]) -> (Vec<f32>, InferStats) {
        self.forward_batch(std::slice::from_ref(&pixels.to_vec()))
            .pop()
            .expect("one item")
    }

    /// Batched forward pass (DESIGN.md S16): every layer runs the whole
    /// minibatch through its tile pool (or fabric chip) with one weight
    /// pass per macro, then requantizes each item for the next layer.
    /// MACs on macros are in (x LSB)·µS; `finish_z` folds the activation
    /// step back in so z comes out in float units. Per-item results are
    /// batch-size invariant (asserted in `rust/tests/fabric_e2e.rs`).
    pub fn forward_batch(
        &mut self,
        pixels: &[Vec<u32>],
    ) -> Vec<(Vec<f32>, InferStats)> {
        let n = pixels.len();
        let mut stats = vec![InferStats::default(); n];
        let mut xs: Vec<Vec<u32>> = pixels.to_vec();
        let mut x_step = self.input_step;
        let n_layers = self.layers.len();
        let mut logits: Vec<Vec<f32>> = vec![Vec::new(); n];
        for li in 0..n_layers {
            let layer = &mut self.layers[li];
            // (partials, energy, latency, packets, hops, active) per item.
            let per_item: Vec<_> = match self.fabric.as_mut() {
                None => layer
                    .forward_tiles_batch(&xs)
                    .into_iter()
                    .map(|t| {
                        (
                            t.partials,
                            t.energy,
                            t.latency_ns,
                            0u64,
                            0u64,
                            t.active_rows,
                        )
                    })
                    .collect(),
                Some(chip) => chip
                    .forward_layer_batch(li, &xs)
                    .into_iter()
                    .map(|r| {
                        (
                            r.partials,
                            r.energy,
                            r.latency_ns,
                            r.packets,
                            r.hops,
                            r.active_rows,
                        )
                    })
                    .collect(),
            };
            let macs = (layer.q.in_dim * layer.q.out_dim) as u64;
            let aq = if li + 1 == n_layers {
                None
            } else {
                Some(self.act_quants[li])
            };
            for (i, (partials, energy, lat, packets, hops, active)) in
                per_item.into_iter().enumerate()
            {
                stats[i].energy.add(&energy);
                stats[i].latency_ns += lat;
                stats[i].macs += macs;
                stats[i].noc_packets += packets;
                stats[i].noc_hops += hops;
                stats[i].active_rows += active;
                let mac = layer.tiled.accumulate(&partials);
                let z = layer.finish_z(&xs[i], &mac, x_step);
                match aq {
                    None => logits[i] = z,
                    Some(a) => {
                        xs[i] = z.iter().map(|&v| a.quantize(v)).collect()
                    }
                }
            }
            if let Some(a) = aq {
                x_step = a.step;
            }
        }
        logits.into_iter().zip(stats).collect()
    }

    pub fn predict(&mut self, pixels: &[u32]) -> (usize, InferStats) {
        let (logits, stats) = self.forward(pixels);
        (argmax(&logits[..10]), stats)
    }

    /// Evaluate on a dataset: (accuracy, aggregate stats). Runs on the
    /// batched engine (DESIGN.md S16) — bit-identical to per-example
    /// [`predict`](Self::predict) calls, asserted in
    /// `rust/tests/fabric_e2e.rs`.
    pub fn evaluate(&mut self, data: &Dataset) -> (f64, InferStats) {
        self.evaluate_batched(data, 32)
    }

    /// [`evaluate`](Self::evaluate) with an explicit minibatch size.
    pub fn evaluate_batched(
        &mut self,
        data: &Dataset,
        batch: usize,
    ) -> (f64, InferStats) {
        assert!(batch > 0, "batch size");
        let mut agg = InferStats::default();
        let mut correct = 0usize;
        let mut lo = 0usize;
        while lo < data.len() {
            let hi = (lo + batch).min(data.len());
            let pixels: Vec<Vec<u32>> =
                (lo..hi).map(|i| data.features_u8(i)).collect();
            for (j, (logits, stats)) in
                self.forward_batch(&pixels).into_iter().enumerate()
            {
                if argmax(&logits[..10]) == data.examples[lo + j].label {
                    correct += 1;
                }
                agg.energy.add(&stats.energy);
                agg.latency_ns += stats.latency_ns;
                agg.macs += stats.macs;
                agg.noc_packets += stats.noc_packets;
                agg.noc_hops += stats.noc_hops;
                agg.active_rows += stats.active_rows;
            }
            lo = hi;
        }
        (correct as f64 / data.len() as f64, agg)
    }

    /// Evaluate with the fabric dataflow executor: one thread per layer,
    /// inter-layer pipelining (DESIGN.md S15). Consumes the model (the
    /// chip's stages move onto the worker threads). Predictions are
    /// bit-identical to the serial [`evaluate`](Self::evaluate) path.
    ///
    /// Panics when the model is not fabric-backed — call
    /// [`attach_fabric`](Self::attach_fabric) first.
    pub fn evaluate_pipelined(self, data: &Dataset) -> (f64, InferStats) {
        let MacroMlp {
            layers,
            act_quants,
            input_step,
            fabric,
        } = self;
        let chip = fabric
            .expect("evaluate_pipelined needs a fabric-backed model");
        let n_layers = layers.len();
        let macs_per_inf: u64 = layers
            .iter()
            .map(|l| (l.q.in_dim * l.q.out_dim) as u64)
            .sum();

        // Per-stage relays reproduce finish_z + activation quantization
        // with stage-constant parameters; the last stage emits the
        // predicted label (argmax over the 10 digit logits).
        let mut relays: Vec<StageRelay> = Vec::with_capacity(n_layers);
        let mut x_step = input_step;
        for (li, layer) in layers.into_iter().enumerate() {
            let scale = layer.q.scale;
            let g_mid = layer.q.g_mid;
            let bias = layer.q.bias;
            let step = x_step;
            let aq = if li + 1 == n_layers {
                None
            } else {
                Some(act_quants[li])
            };
            if let Some(a) = aq {
                x_step = a.step;
            }
            relays.push(Box::new(move |x: &[u32], mac: Vec<f64>| {
                let z = dequant_z(scale, g_mid, &bias, step, x, &mac);
                match aq {
                    Some(a) => z.iter().map(|&v| a.quantize(v)).collect(),
                    None => vec![argmax(&z[..10]) as u32],
                }
            }));
        }

        let inputs: Vec<Vec<u32>> =
            (0..data.len()).map(|i| data.features_u8(i)).collect();
        // Minibatches of 8 between stages: each stage does one weight
        // pass per chunk (DESIGN.md S16); results are batch-invariant.
        let (outs, p) =
            FabricPipeline::new(chip, relays).run_batched(inputs, 8);
        let correct = outs
            .iter()
            .zip(&data.examples)
            .filter(|(o, ex)| o[0] as usize == ex.label)
            .count();
        let stats = InferStats {
            energy: p.energy,
            latency_ns: p.latency_ns,
            macs: macs_per_inf * data.len() as u64,
            noc_packets: p.packets,
            noc_hops: p.hops,
            active_rows: p.active_rows,
        };
        (correct as f64 / data.len() as f64, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::mlp::train;

    fn trained() -> (Mlp, Dataset, Dataset) {
        let train_data = Dataset::generate(300, 41);
        let test_data = Dataset::generate(100, 42);
        let (model, acc) = train(&train_data, 6, 7);
        assert!(acc > 0.9);
        (model, train_data, test_data)
    }

    #[test]
    fn quantized_model_close_to_float_accuracy() {
        let (model, train_data, test_data) = trained();
        let float_acc = crate::snn::mlp::accuracy(&model, &test_data);
        let cfg = MacroConfig::default();
        let mut mm =
            MacroMlp::from_float(&model, &train_data, &cfg, LevelMap::DeviceTrue);
        let (acc, stats) = mm.evaluate(&test_data);
        assert!(
            acc > float_acc - 0.15,
            "macro acc {acc} vs float {float_acc}"
        );
        assert!(stats.macs > 0);
        assert!(stats.energy.total_pj() > 0.0);
        assert_eq!(stats.noc_packets, 0, "no fabric: no NoC traffic");
    }

    #[test]
    fn stats_accumulate_per_inference() {
        let (model, train_data, _) = trained();
        let cfg = MacroConfig::default();
        let mut mm =
            MacroMlp::from_float(&model, &train_data, &cfg, LevelMap::DeviceTrue);
        let x = train_data.features_u8(0);
        let (_, s1) = mm.predict(&x);
        // 3 layers: 256×128 + 128×128 + 128×16 MACs.
        assert_eq!(s1.macs, (256 * 128 + 128 * 128 + 128 * 16) as u64);
        assert!(s1.latency_ns > 0.0);
        // Event-driven occupancy: some rows fire, bounded by the row
        // slots the three layers offer (256 + 128 + 128 per inference).
        assert!(s1.active_rows > 0);
        assert!(s1.active_rows <= 256 + 128 + 128);
    }

    #[test]
    fn deterministic_predictions() {
        let (model, train_data, test_data) = trained();
        let cfg = MacroConfig::default();
        let mut mm =
            MacroMlp::from_float(&model, &train_data, &cfg, LevelMap::DeviceTrue);
        let x = test_data.features_u8(3);
        let (p1, _) = mm.predict(&x);
        let (p2, _) = mm.predict(&x);
        assert_eq!(p1, p2);
    }

    #[test]
    fn fabric_backed_model_reports_noc_traffic() {
        let (model, train_data, test_data) = trained();
        let cfg = MacroConfig::default();
        let mut mm =
            MacroMlp::from_float(&model, &train_data, &cfg, LevelMap::DeviceTrue)
                .attach_fabric(&cfg, FabricConfig::square(2))
                .unwrap();
        assert!(mm.on_fabric());
        let x = test_data.features_u8(1);
        let (_, stats) = mm.predict(&x);
        assert!(stats.noc_packets > 0);
        assert!(stats.noc_hops > 0);
        assert!(stats.energy.noc_fj > 0.0);
    }

    #[test]
    fn fabric_too_small_is_an_error() {
        let (model, train_data, _) = trained();
        let cfg = MacroConfig::default();
        // The 3-layer MLP needs 4 shards; a 1×1 mesh cannot hold them.
        let err =
            MacroMlp::from_float(&model, &train_data, &cfg, LevelMap::DeviceTrue)
                .attach_fabric(&cfg, FabricConfig::square(1))
                .err()
                .expect("placement must fail");
        assert!(err.to_string().contains("exceed"), "{err}");
    }
}

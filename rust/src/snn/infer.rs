//! Quantized inference on the macro (DESIGN.md S13, experiment E9): runs
//! every matmul of the MLP through simulated CIM macros — dual-spike
//! encoded activations, 2-bit conductance weights, OSG readout — with the
//! conductance-offset trick recovering signed weights, and full energy /
//! latency accounting from the per-op ledgers.

use crate::config::{LevelMap, MacroConfig};
use crate::coordinator::TiledMatrix;
use crate::energy::EnergyBreakdown;
use crate::macro_model::CimMacro;
use crate::snn::dataset::Dataset;
use crate::snn::mlp::{argmax, Mlp};
use crate::snn::quant::{quantize_layer, ActQuant, QuantLayer};

/// One macro-mapped layer: quantized codes tiled onto macros.
struct MacroLayer {
    q: QuantLayer,
    tiled: TiledMatrix,
    /// One programmed macro per weight tile (weight-stationary).
    macros: Vec<CimMacro>,
}

impl MacroLayer {
    fn new(q: QuantLayer, cfg: &MacroConfig) -> MacroLayer {
        let tile = cfg.rows;
        let tiled = TiledMatrix::new(&q.codes, q.in_dim, q.out_dim, tile);
        let macros = (0..tiled.num_tiles())
            .map(|t| {
                let mut m = CimMacro::new(cfg.clone());
                m.program(tiled.tile_codes_flat(t));
                m
            })
            .collect();
        MacroLayer { q, tiled, macros }
    }

    /// MAC through the macros; returns (z floats, energy, latency ns).
    fn forward(&mut self, x: &[u32]) -> (Vec<f32>, EnergyBreakdown, f64) {
        let xparts = self.tiled.split_input(x);
        let mut energy = EnergyBreakdown::default();
        let mut latency: f64 = 0.0; // row tiles run in parallel macros
        let mut partials: Vec<Vec<Vec<f64>>> = Vec::new();
        for ti in 0..self.tiled.row_tiles {
            let mut row = Vec::new();
            for tj in 0..self.tiled.col_tiles {
                let idx = ti * self.tiled.col_tiles + tj;
                let r = self.macros[idx].mvm(&xparts[ti]);
                energy.add(&r.energy);
                latency = latency.max(r.latency_ns);
                row.push(r.y_mac);
            }
            partials.push(row);
        }
        let mac = self.tiled.accumulate(&partials);
        let sum_x: f64 = x.iter().map(|&v| v as f64).sum();
        let z: Vec<f32> = mac
            .iter()
            .enumerate()
            .map(|(o, &m)| {
                (self.q.scale * (m - self.q.g_mid * sum_x)) as f32
                    + self.q.bias.get(o).copied().unwrap_or(0.0)
            })
            .collect();
        (z, energy, latency)
    }
}

/// The full quantized MLP deployed on macros.
pub struct MacroMlp {
    layers: Vec<MacroLayer>,
    /// Activation quantizers between layers (len = layers − 1).
    pub act_quants: Vec<ActQuant>,
    /// Input activation scale (pixels are already 8-bit; step in float
    /// units so float-model parity holds: x_float = pixel/255).
    pub input_step: f32,
}

/// Per-inference statistics.
#[derive(Debug, Clone, Default)]
pub struct InferStats {
    pub energy: EnergyBreakdown,
    pub latency_ns: f64,
    /// MAC operations executed on macros (2 OPs each).
    pub macs: u64,
}

impl MacroMlp {
    /// Quantize a trained float model and calibrate activation steps on
    /// `calib` examples.
    pub fn from_float(
        model: &Mlp,
        calib: &Dataset,
        cfg: &MacroConfig,
        level_map: LevelMap,
    ) -> MacroMlp {
        let q1 = quantize_layer(
            &model.l1.w,
            &model.l1.b,
            model.l1.in_dim,
            model.l1.out_dim,
            level_map,
        );
        let q2 = quantize_layer(
            &model.l2.w,
            &model.l2.b,
            model.l2.in_dim,
            model.l2.out_dim,
            level_map,
        );
        let q3 = quantize_layer(
            &model.l3.w,
            &model.l3.b,
            model.l3.in_dim,
            model.l3.out_dim,
            level_map,
        );

        // Calibrate activation ranges with float forward passes.
        let mut h1_all = Vec::new();
        let mut h2_all = Vec::new();
        for i in 0..calib.len().min(64) {
            let x = calib.features_f32(i);
            let (h1, h2, _) = model.forward(&x);
            h1_all.extend(h1);
            h2_all.extend(h2);
        }
        let act_quants = vec![
            ActQuant::calibrate(&h1_all, 99.5),
            ActQuant::calibrate(&h2_all, 99.5),
        ];

        MacroMlp {
            layers: vec![
                MacroLayer::new(q1, cfg),
                MacroLayer::new(q2, cfg),
                MacroLayer::new(q3, cfg),
            ],
            act_quants,
            input_step: 1.0 / 255.0,
        }
    }

    /// Forward pass from 8-bit pixels; returns (logits, stats).
    pub fn forward(&mut self, pixels: &[u32]) -> (Vec<f32>, InferStats) {
        let mut stats = InferStats::default();
        let mut x: Vec<u32> = pixels.to_vec();
        let mut x_step = self.input_step;
        let n_layers = self.layers.len();
        let mut logits = Vec::new();
        for li in 0..n_layers {
            // MACs on macros are in (x LSB)·µS; the layer scale expects
            // float activations, so fold the activation step in.
            let (z_lsb, energy, lat) = self.layers[li].forward(&x);
            stats.energy.add(&energy);
            stats.latency_ns += lat;
            stats.macs += (self.layers[li].q.in_dim
                * self.layers[li].q.out_dim) as u64;
            // z computed with x in LSB units: scale by x_step to float.
            let z: Vec<f32> = z_lsb
                .iter()
                .enumerate()
                .map(|(o, &v)| {
                    let bias = self.layers[li].q.bias.get(o).copied().unwrap_or(0.0);
                    // layer.forward already added bias once (unscaled);
                    // remove and re-add correctly scaled.
                    (v - bias) * x_step + bias
                })
                .collect();
            if li + 1 == n_layers {
                logits = z;
            } else {
                let aq = self.act_quants[li];
                x = z.iter().map(|&v| aq.quantize(v)).collect();
                x_step = aq.step;
            }
        }
        (logits, stats)
    }

    pub fn predict(&mut self, pixels: &[u32]) -> (usize, InferStats) {
        let (logits, stats) = self.forward(pixels);
        (argmax(&logits[..10]), stats)
    }

    /// Evaluate on a dataset: (accuracy, aggregate stats).
    pub fn evaluate(&mut self, data: &Dataset) -> (f64, InferStats) {
        let mut agg = InferStats::default();
        let mut correct = 0usize;
        for i in 0..data.len() {
            let (pred, stats) = self.predict(&data.features_u8(i));
            if pred == data.examples[i].label {
                correct += 1;
            }
            agg.energy.add(&stats.energy);
            agg.latency_ns += stats.latency_ns;
            agg.macs += stats.macs;
        }
        (correct as f64 / data.len() as f64, agg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::mlp::train;

    fn trained() -> (Mlp, Dataset, Dataset) {
        let train_data = Dataset::generate(300, 41);
        let test_data = Dataset::generate(100, 42);
        let (model, acc) = train(&train_data, 6, 7);
        assert!(acc > 0.9);
        (model, train_data, test_data)
    }

    #[test]
    fn quantized_model_close_to_float_accuracy() {
        let (model, train_data, test_data) = trained();
        let float_acc = crate::snn::mlp::accuracy(&model, &test_data);
        let cfg = MacroConfig::default();
        let mut mm =
            MacroMlp::from_float(&model, &train_data, &cfg, LevelMap::DeviceTrue);
        let (acc, stats) = mm.evaluate(&test_data);
        assert!(
            acc > float_acc - 0.15,
            "macro acc {acc} vs float {float_acc}"
        );
        assert!(stats.macs > 0);
        assert!(stats.energy.total_pj() > 0.0);
    }

    #[test]
    fn stats_accumulate_per_inference() {
        let (model, train_data, _) = trained();
        let cfg = MacroConfig::default();
        let mut mm =
            MacroMlp::from_float(&model, &train_data, &cfg, LevelMap::DeviceTrue);
        let x = train_data.features_u8(0);
        let (_, s1) = mm.predict(&x);
        // 3 layers: 256×128 + 128×128 + 128×16 MACs.
        assert_eq!(s1.macs, (256 * 128 + 128 * 128 + 128 * 16) as u64);
        assert!(s1.latency_ns > 0.0);
    }

    #[test]
    fn deterministic_predictions() {
        let (model, train_data, test_data) = trained();
        let cfg = MacroConfig::default();
        let mut mm =
            MacroMlp::from_float(&model, &train_data, &cfg, LevelMap::DeviceTrue);
        let x = test_data.features_u8(3);
        let (p1, _) = mm.predict(&x);
        let (p2, _) = mm.predict(&x);
        assert_eq!(p1, p2);
    }
}

//! Quantization to macro codes (DESIGN.md S13, §7): float weights →
//! 2-bit conductance codes + per-layer scale, float activations → 8-bit
//! dual-spike inputs + per-layer step.
//!
//! Signed weights use the conductance-offset scheme: the effective weight
//! of code c is  s·(G(c) − G_mid), so a layer's MAC is recovered as
//! s·(Σ x·G(code) − G_mid·Σ x). The quantizer searches the scale s that
//! minimizes MSE against the *actual* (possibly non-uniform) device
//! levels — this is where the DeviceTrue vs IdealLinear ablation bites.

use crate::config::LevelMap;

/// A quantized dense layer, laid out for the macro: codes are (in × out)
/// row-major (input rows = wordlines, output cols = bitlines).
#[derive(Debug, Clone)]
pub struct QuantLayer {
    pub in_dim: usize,
    pub out_dim: usize,
    /// 2-bit codes, row-major in_dim × out_dim.
    pub codes: Vec<u8>,
    /// Weight scale s.
    pub scale: f64,
    /// Offset conductance G_mid.
    pub g_mid: f64,
    /// Folded bias (float, applied digitally after the MAC).
    pub bias: Vec<f32>,
}

/// Quantize weights `w` (out × in row-major, as `mlp::Dense`) to codes.
///
/// The scale is chosen by golden-section-free grid search over candidate
/// scales spanning the weight range, minimizing total squared error.
pub fn quantize_layer(
    w: &[f32],
    bias: &[f32],
    in_dim: usize,
    out_dim: usize,
    level_map: LevelMap,
) -> QuantLayer {
    assert_eq!(w.len(), in_dim * out_dim);
    let levels = level_map.levels();
    let g_mid = level_map.g_mid();
    // Centered level values: e(c) = G(c) − G_mid.
    let e: Vec<f64> = levels.iter().map(|&g| g - g_mid).collect();
    let e_max = e[3];

    let w_absmax = w
        .iter()
        .map(|&x| (x as f64).abs())
        .fold(0.0f64, f64::max)
        .max(1e-12);

    // Candidate scales: map w_absmax to between 0.5× and 1.5× of e_max.
    let mut best_scale = w_absmax / e_max;
    let mut best_err = f64::INFINITY;
    for step in 0..60 {
        let s = (0.5 + step as f64 / 40.0) * w_absmax / e_max;
        let err: f64 = w
            .iter()
            .map(|&wi| {
                let t = wi as f64 / s;
                let c = nearest_level(&e, t);
                let d = t - e[c];
                d * d
            })
            .sum::<f64>()
            * s
            * s;
        if err < best_err {
            best_err = err;
            best_scale = s;
        }
    }

    // Emit codes TRANSPOSED into macro layout (in × out).
    let mut codes = vec![0u8; in_dim * out_dim];
    for o in 0..out_dim {
        for i in 0..in_dim {
            let wi = w[o * in_dim + i] as f64;
            let c = nearest_level(&e, wi / best_scale);
            codes[i * out_dim + o] = c as u8;
        }
    }
    QuantLayer {
        in_dim,
        out_dim,
        codes,
        scale: best_scale,
        g_mid,
        bias: bias.to_vec(),
    }
}

fn nearest_level(e: &[f64], t: f64) -> usize {
    let mut best = 0;
    let mut best_d = f64::INFINITY;
    for (c, &ec) in e.iter().enumerate() {
        let d = (t - ec).abs();
        if d < best_d {
            best_d = d;
            best = c;
        }
    }
    best
}

/// Effective float weight represented by a code (for error analysis).
pub fn dequantize(layer: &QuantLayer, level_map: LevelMap) -> Vec<f32> {
    let levels = level_map.levels();
    let mut w = vec![0.0f32; layer.in_dim * layer.out_dim];
    for i in 0..layer.in_dim {
        for o in 0..layer.out_dim {
            let g = levels[layer.codes[i * layer.out_dim + o] as usize];
            w[o * layer.in_dim + i] =
                (layer.scale * (g - layer.g_mid)) as f32;
        }
    }
    w // back in (out × in) layout
}

/// Mean-squared quantization error of a layer's weights.
pub fn quant_mse(w: &[f32], layer: &QuantLayer, level_map: LevelMap) -> f64 {
    let wq = dequantize(layer, level_map);
    w.iter()
        .zip(&wq)
        .map(|(&a, &b)| {
            let d = (a - b) as f64;
            d * d
        })
        .sum::<f64>()
        / w.len() as f64
}

/// Activation quantizer: symmetric [0, a_max] → [0, 255].
#[derive(Debug, Clone, Copy)]
pub struct ActQuant {
    pub step: f32,
}

impl ActQuant {
    /// Calibrate from observed activations (`pct` percentile as a_max).
    pub fn calibrate(acts: &[f32], pct: f64) -> ActQuant {
        let mut v: Vec<f64> = acts
            .iter()
            .filter(|&&a| a > 0.0)
            .map(|&a| a as f64)
            .collect();
        if v.is_empty() {
            return ActQuant { step: 1.0 / 255.0 };
        }
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let a_max = crate::util::stats::percentile(&v, pct).max(1e-6);
        ActQuant {
            step: (a_max / 255.0) as f32,
        }
    }

    /// The calibrated full-scale activation a_max (= `step · 255`) —
    /// the stream runtime's per-layer normalization threshold λ
    /// (DESIGN.md S18).
    pub fn a_max(&self) -> f32 {
        self.step * 255.0
    }

    pub fn quantize(&self, a: f32) -> u32 {
        ((a.max(0.0) / self.step).round() as u32).min(255)
    }

    pub fn dequantize(&self, q: u32) -> f32 {
        q as f32 * self.step
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_weights(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal_ms(0.0, 0.1) as f32).collect()
    }

    #[test]
    fn codes_in_range_and_layout_transposed() {
        let w = random_weights(6, 1); // 2 out × 3 in
        let q = quantize_layer(&w, &[0.0, 0.0], 3, 2, LevelMap::DeviceTrue);
        assert_eq!(q.codes.len(), 6);
        assert!(q.codes.iter().all(|&c| c < 4));
        // spot-check transposition: w[o=1,i=2] lands at codes[i=2][o=1]
        let e: Vec<f64> = LevelMap::DeviceTrue
            .levels()
            .iter()
            .map(|&g| g - q.g_mid)
            .collect();
        let expect = super::nearest_level(&e, w[1 * 3 + 2] as f64 / q.scale);
        assert_eq!(q.codes[2 * 2 + 1] as usize, expect);
    }

    #[test]
    fn dequantized_weights_correlate_with_originals() {
        let w = random_weights(128 * 64, 2);
        let q = quantize_layer(&w, &vec![0.0; 64], 128, 64, LevelMap::DeviceTrue);
        let wq = dequantize(&q, LevelMap::DeviceTrue);
        // Pearson correlation > 0.85 for 2-bit quantization of gaussians.
        let n = w.len() as f64;
        let (mut sx, mut sy, mut sxx, mut syy, mut sxy) =
            (0.0, 0.0, 0.0, 0.0, 0.0);
        for (&a, &b) in w.iter().zip(&wq) {
            let (a, b) = (a as f64, b as f64);
            sx += a;
            sy += b;
            sxx += a * a;
            syy += b * b;
            sxy += a * b;
        }
        let corr = (n * sxy - sx * sy)
            / ((n * sxx - sx * sx).sqrt() * (n * syy - sy * sy).sqrt());
        assert!(corr > 0.85, "corr {corr}");
    }

    #[test]
    fn ideal_levels_quantize_no_worse_than_device_true() {
        // Equally-spaced levels should fit gaussian weights at least as
        // well (ablation direction check).
        let w = random_weights(4096, 3);
        let qd = quantize_layer(&w, &[], 64, 64, LevelMap::DeviceTrue);
        let qi = quantize_layer(&w, &[], 64, 64, LevelMap::IdealLinear);
        let mse_d = quant_mse(&w, &qd, LevelMap::DeviceTrue);
        let mse_i = quant_mse(&w, &qi, LevelMap::IdealLinear);
        assert!(mse_i <= mse_d * 1.05, "ideal {mse_i} vs device {mse_d}");
    }

    #[test]
    fn act_quant_roundtrip() {
        let acts: Vec<f32> = (0..1000).map(|i| i as f32 / 100.0).collect();
        let q = ActQuant::calibrate(&acts, 99.0);
        let a = 5.0f32;
        let code = q.quantize(a);
        assert!((q.dequantize(code) - a).abs() < q.step);
        assert_eq!(q.quantize(-1.0), 0);
        assert_eq!(q.quantize(1e9), 255);
    }

    #[test]
    fn act_quant_empty_is_safe() {
        let q = ActQuant::calibrate(&[], 99.0);
        assert!(q.step > 0.0);
    }
}

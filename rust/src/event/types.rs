//! Event types for the event-driven macro simulation.
//!
//! The paper's operating principle (§III-B/C): computation is *triggered*
//! by spike events, not clocked. The simulator mirrors that — every state
//! change in a macro op is a timestamped event processed in time order.

/// What happened.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// First spike of an input pair — row's Event_flag_i asserts.
    RowRise { row: u32 },
    /// Second spike — row's Event_flag_i de-asserts.
    RowFall { row: u32 },
    /// Global Event_flag de-asserted (all input events complete);
    /// the OSG comparison phase starts (§III-C).
    GlobalFlagDrop,
    /// A column's comparator toggled: second output spike emitted.
    CompareFire { col: u32 },
    /// End-of-operation marker (all output spikes emitted).
    OpDone,
}

/// A timestamped event. Ordering: by time, then by sequence number so
/// simultaneous events process in deterministic insertion order.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub t_ns: f64,
    pub seq: u64,
    pub kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.t_ns == other.t_ns && self.seq == other.seq
    }
}
impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // NaN-free by construction (asserted at push); total order.
        self.t_ns
            .partial_cmp(&other.t_ns)
            .unwrap()
            .then(self.seq.cmp(&other.seq))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_by_time_then_seq() {
        let a = Event { t_ns: 1.0, seq: 5, kind: EventKind::OpDone };
        let b = Event { t_ns: 2.0, seq: 1, kind: EventKind::OpDone };
        let c = Event { t_ns: 1.0, seq: 6, kind: EventKind::OpDone };
        assert!(a < b);
        assert!(a < c);
        assert!(c < b);
    }

    #[test]
    fn equal_iff_time_and_seq_match() {
        let a = Event { t_ns: 1.0, seq: 1, kind: EventKind::OpDone };
        let b = Event {
            t_ns: 1.0,
            seq: 1,
            kind: EventKind::RowRise { row: 3 },
        };
        assert_eq!(a, b); // kind not part of identity (queue ordering only)
    }
}

//! Event_flag aggregation (paper §III-B, Fig 3b).
//!
//! Every row's SMU raises `Event_flag_i` while its spike pair is open; the
//! global `Event_flag` is their OR and gates the OSG charging window. In
//! hardware this is a wired-OR / OR-tree; behaviorally it is a counter of
//! active rows whose 1→0 transition is *the* event that starts the output
//! comparison phase (fully asynchronous, no clock).

/// OR-aggregator over `n` row flags with transition timestamps.
#[derive(Debug, Clone)]
pub struct FlagTree {
    active: Vec<bool>,
    count: usize,
    /// Time the global flag last rose (ns), if currently high.
    rose_at: Option<f64>,
    /// Completed high intervals (rise, fall) — the Fig 3b waveform.
    intervals: Vec<(f64, f64)>,
}

impl FlagTree {
    pub fn new(n: usize) -> Self {
        FlagTree {
            active: vec![false; n],
            count: 0,
            rose_at: None,
            intervals: Vec::new(),
        }
    }

    pub fn rows(&self) -> usize {
        self.active.len()
    }

    /// Row `i` flag asserts at time `t_ns`. Returns true if this raised
    /// the *global* flag (0 → 1 active rows).
    pub fn assert_row(&mut self, i: usize, t_ns: f64) -> bool {
        assert!(!self.active[i], "row {i} already asserted");
        self.active[i] = true;
        self.count += 1;
        if self.count == 1 {
            self.rose_at = Some(t_ns);
            true
        } else {
            false
        }
    }

    /// Row `i` flag de-asserts at `t_ns`. Returns true if this dropped the
    /// global flag (last active row) — the OSG trigger.
    pub fn deassert_row(&mut self, i: usize, t_ns: f64) -> bool {
        assert!(self.active[i], "row {i} not asserted");
        self.active[i] = false;
        self.count -= 1;
        if self.count == 0 {
            let rose = self.rose_at.take().expect("rise recorded");
            self.intervals.push((rose, t_ns));
            true
        } else {
            false
        }
    }

    /// Is the global flag currently high?
    pub fn global(&self) -> bool {
        self.count > 0
    }

    pub fn active_rows(&self) -> usize {
        self.count
    }

    /// Completed (rise, fall) intervals of the global flag.
    pub fn intervals(&self) -> &[(f64, f64)] {
        &self.intervals
    }

    /// Reset all rows (reuse across ops; keeps interval history cleared).
    pub fn reset(&mut self) {
        self.active.iter_mut().for_each(|a| *a = false);
        self.count = 0;
        self.rose_at = None;
        self.intervals.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn global_is_or_of_rows() {
        let mut f = FlagTree::new(4);
        assert!(!f.global());
        assert!(f.assert_row(1, 0.0)); // 0→1 raises global
        assert!(!f.assert_row(2, 0.1)); // already high
        assert!(!f.deassert_row(1, 0.5)); // row 2 still active
        assert!(f.global());
        assert!(f.deassert_row(2, 0.9)); // last one drops global
        assert!(!f.global());
    }

    #[test]
    fn interval_records_envelope_of_all_rows() {
        let mut f = FlagTree::new(3);
        f.assert_row(0, 0.0);
        f.assert_row(1, 0.2);
        f.assert_row(2, 0.3);
        f.deassert_row(0, 1.0);
        f.deassert_row(2, 2.0);
        f.deassert_row(1, 5.0);
        assert_eq!(f.intervals(), &[(0.0, 5.0)]);
    }

    #[test]
    fn multiple_disjoint_windows() {
        let mut f = FlagTree::new(1);
        f.assert_row(0, 0.0);
        f.deassert_row(0, 1.0);
        f.assert_row(0, 3.0);
        f.deassert_row(0, 4.5);
        assert_eq!(f.intervals(), &[(0.0, 1.0), (3.0, 4.5)]);
    }

    #[test]
    #[should_panic(expected = "already asserted")]
    fn double_assert_panics() {
        let mut f = FlagTree::new(2);
        f.assert_row(0, 0.0);
        f.assert_row(0, 0.1);
    }

    #[test]
    #[should_panic(expected = "not asserted")]
    fn deassert_without_assert_panics() {
        let mut f = FlagTree::new(2);
        f.deassert_row(1, 0.0);
    }

    #[test]
    fn reset_clears_state() {
        let mut f = FlagTree::new(2);
        f.assert_row(0, 0.0);
        f.reset();
        assert!(!f.global());
        assert!(f.intervals().is_empty());
        assert!(f.assert_row(0, 0.0));
    }
}

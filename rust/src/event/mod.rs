//! Event-driven simulation kernel (DESIGN.md S3): timestamped spike
//! events, a deterministic time-ordered queue, and the Event_flag
//! OR-aggregation that gives the macro its asynchronous control.

pub mod flag;
pub mod queue;
pub mod types;

pub use flag::FlagTree;
pub use queue::EventQueue;
pub use types::{Event, EventKind};

//! Time-ordered event queue — the hot-path data structure of the
//! event-driven simulator (DESIGN.md S3).
//!
//! A thin wrapper over `BinaryHeap<Reverse<Event>>` that stamps a
//! monotone sequence number on push, so same-time events pop in
//! deterministic insertion order and the heap's order is total even
//! though times are floats.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::types::{Event, EventKind};

/// Min-heap of events by (time, sequence).
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<Event>>,
    next_seq: u64,
    now_ns: f64,
    popped: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            next_seq: 0,
            now_ns: 0.0,
            popped: 0,
        }
    }

    /// With pre-allocated capacity (hot path: one macro op = 2·rows+cols+2
    /// events; pre-sizing avoids growth in the loop).
    pub fn with_capacity(cap: usize) -> Self {
        EventQueue {
            heap: BinaryHeap::with_capacity(cap),
            next_seq: 0,
            now_ns: 0.0,
            popped: 0,
        }
    }

    /// Schedule `kind` at absolute time `t_ns`.
    ///
    /// Panics if `t_ns` is NaN or in the past (event-driven causality).
    pub fn push(&mut self, t_ns: f64, kind: EventKind) {
        assert!(t_ns.is_finite(), "event time must be finite");
        assert!(
            t_ns >= self.now_ns,
            "causality violation: t={} < now={}",
            t_ns,
            self.now_ns
        );
        let ev = Event {
            t_ns,
            seq: self.next_seq,
            kind,
        };
        self.next_seq += 1;
        self.heap.push(Reverse(ev));
    }

    /// Pop the earliest event, advancing simulated time.
    pub fn pop(&mut self) -> Option<Event> {
        let ev = self.heap.pop().map(|r| r.0)?;
        debug_assert!(ev.t_ns >= self.now_ns);
        self.now_ns = ev.t_ns;
        self.popped += 1;
        Some(ev)
    }

    /// Earliest pending event time without popping.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|r| r.0.t_ns)
    }

    /// Current simulated time (time of the last popped event).
    pub fn now_ns(&self) -> f64 {
        self.now_ns
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Total events processed (metrics).
    pub fn processed(&self) -> u64 {
        self.popped
    }

    /// Reset for reuse across macro ops without freeing the allocation.
    pub fn reset(&mut self) {
        self.heap.clear();
        self.next_seq = 0;
        self.now_ns = 0.0;
        self.popped = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, EventKind::OpDone);
        q.push(1.0, EventKind::RowRise { row: 0 });
        q.push(2.0, EventKind::RowFall { row: 0 });
        let times: Vec<f64> = std::iter::from_fn(|| q.pop().map(|e| e.t_ns))
            .collect();
        assert_eq!(times, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn simultaneous_events_fifo() {
        let mut q = EventQueue::new();
        for row in 0..10u32 {
            q.push(5.0, EventKind::RowRise { row });
        }
        for row in 0..10u32 {
            match q.pop().unwrap().kind {
                EventKind::RowRise { row: r } => assert_eq!(r, row),
                k => panic!("unexpected {k:?}"),
            }
        }
    }

    #[test]
    fn now_advances_monotonically() {
        let mut q = EventQueue::new();
        q.push(1.0, EventKind::OpDone);
        q.push(4.0, EventKind::OpDone);
        assert_eq!(q.now_ns(), 0.0);
        q.pop();
        assert_eq!(q.now_ns(), 1.0);
        q.pop();
        assert_eq!(q.now_ns(), 4.0);
        assert_eq!(q.processed(), 2);
    }

    #[test]
    #[should_panic(expected = "causality")]
    fn rejects_events_in_the_past() {
        let mut q = EventQueue::new();
        q.push(5.0, EventKind::OpDone);
        q.pop();
        q.push(1.0, EventKind::OpDone);
    }

    #[test]
    fn reset_reuses_allocation() {
        let mut q = EventQueue::with_capacity(64);
        q.push(1.0, EventKind::OpDone);
        q.pop();
        q.reset();
        assert!(q.is_empty());
        assert_eq!(q.now_ns(), 0.0);
        q.push(0.5, EventKind::OpDone); // allowed again after reset
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn peek_does_not_advance_time() {
        let mut q = EventQueue::new();
        q.push(2.0, EventKind::OpDone);
        assert_eq!(q.peek_time(), Some(2.0));
        assert_eq!(q.now_ns(), 0.0);
    }
}

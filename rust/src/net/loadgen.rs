//! Closed-loop load generator for the S23 wire front end.
//!
//! Drives a live `spikemram serve --listen` endpoint over real TCP
//! with N concurrent connections, each running one streaming session,
//! and reports client-observed latency percentiles, shed rate, and
//! server-side energy per request (from `metrics` snapshot deltas).
//!
//! Two drive modes:
//!
//! * **closed** — each connection keeps exactly one request in flight
//!   (send, wait, repeat). Measures the server's native service
//!   latency; offered load self-limits to capacity.
//! * **open** — arrivals are paced toward `target_fps` on an
//!   *absolute-due* schedule interleaved across connections (the k-th
//!   global arrival is due at `k / target_fps`; connection `tid` takes
//!   every `connections`-th slot), so a slow reply doesn't silently
//!   shift the schedule and the connections don't fire in synchronized
//!   bursts. Latency is measured from the due time, which charges
//!   queueing delay to the server instead of hiding it
//!   (coordinated-omission correction). Because each connection is
//!   synchronous, in-flight load is capped at `connections` — overload
//!   experiments need `connections` to exceed the server's total queue
//!   slots.
//!
//! Session churn (`churn_every`) closes and reopens the session every
//! N frames, exercising open/close paths and worker re-pinning under
//! load.

use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::util::stats;

use super::client::NetClient;
use super::proto::Response;

/// How offered load is generated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoadMode {
    /// One request in flight per connection.
    Closed,
    /// Paced toward `target_fps`, independent of reply latency.
    Open,
}

/// Load-generator knobs. `events_pool` is cycled per connection with a
/// per-connection offset so concurrent sessions don't submit in
/// lockstep.
#[derive(Clone)]
pub struct LoadGenConfig {
    pub mode: LoadMode,
    /// Concurrent TCP connections (one streaming session each).
    pub connections: usize,
    /// Frames each connection submits.
    pub frames: usize,
    /// Total offered frames/sec across all connections (open mode).
    pub target_fps: f64,
    /// Close + reopen the session every N frames (0 = never).
    pub churn_every: usize,
    /// Client-side deadline: replies slower than this count as late.
    pub deadline: Option<Duration>,
    /// Event frames to submit (cycled). Must be non-empty, and every
    /// frame valid for the server's `in_dim`.
    pub events_pool: Vec<Vec<u32>>,
}

/// Aggregated results of one load run.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub offered: u64,
    pub served: u64,
    pub shed: u64,
    /// Protocol-level error responses (should be 0 in a healthy run).
    pub errors: u64,
    /// Served replies that missed the client-side deadline.
    pub late: u64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub wall_s: f64,
    /// Served frames per second of wall time.
    pub achieved_rps: f64,
    pub shed_rate: f64,
    /// Server-side modeled energy per served request over the run
    /// (pJ), from `metrics` snapshot deltas; 0 when the backend has no
    /// energy model.
    pub energy_pj_per_req: f64,
}

struct ThreadOut {
    latencies_ms: Vec<f64>,
    served: u64,
    shed: u64,
    errors: u64,
    late: u64,
}

fn snap_f64(snapshot: &crate::util::json::Json, key: &str) -> f64 {
    snapshot.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0)
}

fn drive_one(
    addr: &str,
    cfg: &LoadGenConfig,
    tid: usize,
    t0: Instant,
) -> Result<ThreadOut> {
    let mut client =
        NetClient::connect(addr).context("loadgen connect")?;
    let mut session = client.open_session()?;
    let mut out = ThreadOut {
        latencies_ms: Vec::with_capacity(cfg.frames),
        served: 0,
        shed: 0,
        errors: 0,
        late: 0,
    };
    for i in 0..cfg.frames {
        if cfg.churn_every > 0 && i > 0 && i % cfg.churn_every == 0 {
            client.close_session(session)?;
            session = client.open_session()?;
        }
        // Absolute-due pacing (open mode): the k-th *global* arrival
        // is due at k / target_fps past the shared epoch, with the
        // connections interleaved (k = i·conns + tid) so they don't
        // fire in synchronized bursts. A slow reply can't stretch the
        // schedule — a thread behind its due time submits immediately
        // and the slip is charged to latency (coordinated-omission
        // correction).
        let start = if cfg.mode == LoadMode::Open && cfg.target_fps > 0.0 {
            let k = (i * cfg.connections + tid) as f64;
            let due = t0 + Duration::from_secs_f64(k / cfg.target_fps);
            let now = Instant::now();
            if due > now {
                std::thread::sleep(due - now);
                due
            } else {
                due
            }
        } else {
            Instant::now()
        };
        let events =
            cfg.events_pool[(i + tid) % cfg.events_pool.len()].clone();
        match client.stream_frame(session, events)? {
            Response::Frame { .. } => {
                let lat = start.elapsed();
                out.served += 1;
                out.latencies_ms.push(lat.as_secs_f64() * 1e3);
                if let Some(d) = cfg.deadline {
                    if lat > d {
                        out.late += 1;
                    }
                }
            }
            Response::Shed { .. } => out.shed += 1,
            Response::Error { .. } => out.errors += 1,
            other => {
                return Err(anyhow!(
                    "unexpected response to stream_frame: {other:?}"
                ))
            }
        }
    }
    client.close_session(session)?;
    Ok(out)
}

/// Run one load point against a live server. Opens
/// `cfg.connections + 1` TCP connections: one per driver thread plus a
/// control connection for before/after metrics snapshots.
pub fn run(addr: &str, cfg: &LoadGenConfig) -> Result<LoadReport> {
    assert!(!cfg.events_pool.is_empty(), "events_pool must be non-empty");
    assert!(cfg.connections > 0, "need at least one connection");
    let mut control =
        NetClient::connect(addr).context("loadgen control connect")?;
    let snap0 = control.metrics()?;
    let t0 = Instant::now();
    let outs: Vec<Result<ThreadOut>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..cfg.connections)
            .map(|tid| {
                s.spawn(move || drive_one(addr, cfg, tid, t0))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("loadgen thread panicked"))
            .collect()
    });
    let wall_s = t0.elapsed().as_secs_f64();
    let snap1 = control.metrics()?;

    let mut latencies = Vec::new();
    let mut served = 0u64;
    let mut shed = 0u64;
    let mut errors = 0u64;
    let mut late = 0u64;
    for out in outs {
        let out = out?;
        latencies.extend(out.latencies_ms);
        served += out.served;
        shed += out.shed;
        errors += out.errors;
        late += out.late;
    }
    let offered = (cfg.connections * cfg.frames) as u64;

    let d_energy_fj =
        snap_f64(&snap1, "energy_fj") - snap_f64(&snap0, "energy_fj");
    let d_requests =
        snap_f64(&snap1, "requests") - snap_f64(&snap0, "requests");
    let energy_pj_per_req = if d_requests > 0.0 {
        (d_energy_fj / 1e3 / d_requests).max(0.0)
    } else {
        0.0
    };

    let pct = |p: f64| {
        if latencies.is_empty() {
            0.0
        } else {
            stats::percentile(&latencies, p)
        }
    };
    Ok(LoadReport {
        offered,
        served,
        shed,
        errors,
        late,
        p50_ms: pct(50.0),
        p95_ms: pct(95.0),
        p99_ms: pct(99.0),
        wall_s,
        achieved_rps: if wall_s > 0.0 {
            served as f64 / wall_s
        } else {
            0.0
        },
        shed_rate: if offered > 0 {
            shed as f64 / offered as f64
        } else {
            0.0
        },
        energy_pj_per_req,
    })
}

//! Typed request/response protocol over the wire framing (DESIGN.md
//! S23).
//!
//! Every frame body is a JSON object with a `"type"` discriminator.
//! Decoding is *strict*: an unknown `"type"`, an unknown field, or a
//! field of the wrong shape is an error — the server answers with
//! [`Response::Error`] rather than guessing, so protocol drift between
//! client and server versions surfaces immediately instead of as
//! silently-ignored fields.
//!
//! Shed mapping (satellite of S21): every admission-control rejection
//! crosses the wire as [`Response::Shed`] carrying the supervisor's
//! [`ShedReason::wire_name`] string — or [`SHED_QUEUE_FULL`] for
//! queue-full sheds, which are rejected at admission before a reason
//! is ever attached — plus the EWMA `retry_after` hint in
//! milliseconds, so a closed-loop client can back off by exactly the
//! amount the server's service-time estimate suggests.
//!
//! [`ShedReason::wire_name`]: crate::coordinator::ShedReason::wire_name

use crate::util::json::{self, Json};

/// Wire name for queue-full sheds (no `ShedReason` exists for these:
/// the frame is rejected at admission, before a worker ever sees it).
pub const SHED_QUEUE_FULL: &str = "queue_full";

/// Largest `deadline_ms` a `drain` request may carry (24 hours). A
/// bound is load-bearing, not cosmetic: `Duration::from_secs_f64`
/// panics near 1.8e22 ms, so an unbounded value off the wire would let
/// one hostile frame panic a connection thread mid-drain and wedge the
/// server. No legitimate drain waits a day.
pub const MAX_DEADLINE_MS: f64 = 86_400_000.0;

/// A client-to-server request frame.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// One-shot inference on the macro backend (`sim`/`pjrt`/`fabric`
    /// serve modes): `x` is a dense spike-count vector of `in_dim`
    /// entries.
    Infer { x: Vec<u32> },
    /// Open a streaming session on the stream backend.
    OpenSession,
    /// Submit one event frame (sorted, unique, `< in_dim` indices) to
    /// an open session.
    StreamFrame { session: u64, events: Vec<u32> },
    /// Close a session and collect its final reply.
    CloseSession { session: u64 },
    /// Fetch the server's full metrics snapshot as JSON.
    MetricsQuery,
    /// Gracefully drain the backend within `deadline_ms`, then stop
    /// accepting work. Live connections get the drain report.
    Drain { deadline_ms: f64 },
}

/// A server-to-client response frame.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    /// Macro inference result: one accumulator per output column.
    InferOk { y: Vec<f64> },
    /// A streaming session is open under this id.
    SessionOpen { session: u64 },
    /// Per-frame streaming output at step `t`.
    Frame {
        session: u64,
        t: u64,
        out_v: Vec<f64>,
        label: u64,
    },
    /// Final reply for a closed session.
    SessionClosed {
        session: u64,
        t: u64,
        out_v: Vec<f64>,
        label: u64,
    },
    /// Metrics snapshot (the `MetricsSnapshot::to_json` document).
    MetricsOk { snapshot: Json },
    /// The request was admission-controlled away. `reason` is a
    /// `ShedReason::wire_name` or [`SHED_QUEUE_FULL`]; `retry_after_ms`
    /// is the server's EWMA backoff hint.
    Shed { reason: String, retry_after_ms: f64 },
    /// Drain completed: how long it took, how many queued items were
    /// shed on the way down, and whether every worker joined cleanly.
    DrainOk {
        drain_ms: f64,
        shed: u64,
        clean: bool,
    },
    /// The request could not be decoded or is invalid for this
    /// backend. The connection stays open.
    Error { msg: String },
}

fn num(x: f64) -> Json {
    Json::Num(x)
}

fn u64_num(x: u64) -> Json {
    Json::Num(x as f64)
}

fn arr_u32(xs: &[u32]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
}

/// Reject objects carrying fields outside `allowed` — strict decoding
/// so typos and version drift fail loudly.
fn expect_keys(
    o: &std::collections::BTreeMap<String, Json>,
    allowed: &[&str],
) -> Result<(), String> {
    for k in o.keys() {
        if !allowed.contains(&k.as_str()) {
            return Err(format!("unknown field {k:?}"));
        }
    }
    for k in allowed {
        if !o.contains_key(*k) {
            return Err(format!("missing field {k:?}"));
        }
    }
    Ok(())
}

fn get_f64(o: &std::collections::BTreeMap<String, Json>, k: &str) -> Result<f64, String> {
    o.get(k)
        .and_then(|v| v.as_f64())
        .filter(|x| x.is_finite())
        .ok_or_else(|| format!("field {k:?} must be a finite number"))
}

fn get_u64(o: &std::collections::BTreeMap<String, Json>, k: &str) -> Result<u64, String> {
    let x = get_f64(o, k)?;
    if x < 0.0 || x.fract() != 0.0 || x > u64::MAX as f64 {
        return Err(format!("field {k:?} must be a non-negative integer"));
    }
    Ok(x as u64)
}

fn get_bool(o: &std::collections::BTreeMap<String, Json>, k: &str) -> Result<bool, String> {
    match o.get(k) {
        Some(Json::Bool(b)) => Ok(*b),
        _ => Err(format!("field {k:?} must be a bool")),
    }
}

fn get_str(o: &std::collections::BTreeMap<String, Json>, k: &str) -> Result<String, String> {
    o.get(k)
        .and_then(|v| v.as_str())
        .map(|s| s.to_string())
        .ok_or_else(|| format!("field {k:?} must be a string"))
}

fn get_u32_arr(
    o: &std::collections::BTreeMap<String, Json>,
    k: &str,
) -> Result<Vec<u32>, String> {
    let a = o
        .get(k)
        .and_then(|v| v.as_arr())
        .ok_or_else(|| format!("field {k:?} must be an array"))?;
    let mut out = Vec::with_capacity(a.len());
    for (i, v) in a.iter().enumerate() {
        let x = v
            .as_f64()
            .filter(|x| x.is_finite() && *x >= 0.0 && x.fract() == 0.0)
            .filter(|x| *x <= u32::MAX as f64)
            .ok_or_else(|| format!("{k}[{i}] must be a u32"))?;
        out.push(x as u32);
    }
    Ok(out)
}

fn get_f64_arr(
    o: &std::collections::BTreeMap<String, Json>,
    k: &str,
) -> Result<Vec<f64>, String> {
    let a = o
        .get(k)
        .and_then(|v| v.as_arr())
        .ok_or_else(|| format!("field {k:?} must be an array"))?;
    let mut out = Vec::with_capacity(a.len());
    for (i, v) in a.iter().enumerate() {
        out.push(
            v.as_f64()
                .filter(|x| x.is_finite())
                .ok_or_else(|| format!("{k}[{i}] must be a finite number"))?,
        );
    }
    Ok(out)
}

impl Request {
    pub fn to_json(&self) -> Json {
        match self {
            Request::Infer { x } => json::obj(vec![
                ("type", Json::Str("infer".into())),
                ("x", arr_u32(x)),
            ]),
            Request::OpenSession => {
                json::obj(vec![("type", Json::Str("open_session".into()))])
            }
            Request::StreamFrame { session, events } => json::obj(vec![
                ("type", Json::Str("stream_frame".into())),
                ("session", u64_num(*session)),
                ("events", arr_u32(events)),
            ]),
            Request::CloseSession { session } => json::obj(vec![
                ("type", Json::Str("close_session".into())),
                ("session", u64_num(*session)),
            ]),
            Request::MetricsQuery => {
                json::obj(vec![("type", Json::Str("metrics".into()))])
            }
            Request::Drain { deadline_ms } => json::obj(vec![
                ("type", Json::Str("drain".into())),
                ("deadline_ms", num(*deadline_ms)),
            ]),
        }
    }

    pub fn from_json(j: &Json) -> Result<Request, String> {
        let o = j.as_obj().ok_or("request frame must be a JSON object")?;
        let ty = get_str(o, "type")?;
        match ty.as_str() {
            "infer" => {
                expect_keys(o, &["type", "x"])?;
                Ok(Request::Infer {
                    x: get_u32_arr(o, "x")?,
                })
            }
            "open_session" => {
                expect_keys(o, &["type"])?;
                Ok(Request::OpenSession)
            }
            "stream_frame" => {
                expect_keys(o, &["type", "session", "events"])?;
                Ok(Request::StreamFrame {
                    session: get_u64(o, "session")?,
                    events: get_u32_arr(o, "events")?,
                })
            }
            "close_session" => {
                expect_keys(o, &["type", "session"])?;
                Ok(Request::CloseSession {
                    session: get_u64(o, "session")?,
                })
            }
            "metrics" => {
                expect_keys(o, &["type"])?;
                Ok(Request::MetricsQuery)
            }
            "drain" => {
                expect_keys(o, &["type", "deadline_ms"])?;
                let deadline_ms = get_f64(o, "deadline_ms")?;
                if !(0.0..=MAX_DEADLINE_MS).contains(&deadline_ms) {
                    return Err(format!(
                        "field \"deadline_ms\" must be in \
                         [0, {MAX_DEADLINE_MS}]"
                    ));
                }
                Ok(Request::Drain { deadline_ms })
            }
            other => Err(format!("unknown request type {other:?}")),
        }
    }
}

impl Response {
    pub fn to_json(&self) -> Json {
        match self {
            Response::InferOk { y } => json::obj(vec![
                ("type", Json::Str("infer_ok".into())),
                ("y", json::arr_f64(y)),
            ]),
            Response::SessionOpen { session } => json::obj(vec![
                ("type", Json::Str("session_open".into())),
                ("session", u64_num(*session)),
            ]),
            Response::Frame {
                session,
                t,
                out_v,
                label,
            } => json::obj(vec![
                ("type", Json::Str("frame".into())),
                ("session", u64_num(*session)),
                ("t", u64_num(*t)),
                ("out_v", json::arr_f64(out_v)),
                ("label", u64_num(*label)),
            ]),
            Response::SessionClosed {
                session,
                t,
                out_v,
                label,
            } => json::obj(vec![
                ("type", Json::Str("session_closed".into())),
                ("session", u64_num(*session)),
                ("t", u64_num(*t)),
                ("out_v", json::arr_f64(out_v)),
                ("label", u64_num(*label)),
            ]),
            Response::MetricsOk { snapshot } => json::obj(vec![
                ("type", Json::Str("metrics_ok".into())),
                ("snapshot", snapshot.clone()),
            ]),
            Response::Shed {
                reason,
                retry_after_ms,
            } => json::obj(vec![
                ("type", Json::Str("shed".into())),
                ("reason", Json::Str(reason.clone())),
                ("retry_after_ms", num(*retry_after_ms)),
            ]),
            Response::DrainOk {
                drain_ms,
                shed,
                clean,
            } => json::obj(vec![
                ("type", Json::Str("drain_ok".into())),
                ("drain_ms", num(*drain_ms)),
                ("shed", u64_num(*shed)),
                ("clean", Json::Bool(*clean)),
            ]),
            Response::Error { msg } => json::obj(vec![
                ("type", Json::Str("error".into())),
                ("msg", Json::Str(msg.clone())),
            ]),
        }
    }

    pub fn from_json(j: &Json) -> Result<Response, String> {
        let o = j.as_obj().ok_or("response frame must be a JSON object")?;
        let ty = get_str(o, "type")?;
        match ty.as_str() {
            "infer_ok" => {
                expect_keys(o, &["type", "y"])?;
                Ok(Response::InferOk {
                    y: get_f64_arr(o, "y")?,
                })
            }
            "session_open" => {
                expect_keys(o, &["type", "session"])?;
                Ok(Response::SessionOpen {
                    session: get_u64(o, "session")?,
                })
            }
            "frame" | "session_closed" => {
                expect_keys(o, &["type", "session", "t", "out_v", "label"])?;
                let session = get_u64(o, "session")?;
                let t = get_u64(o, "t")?;
                let out_v = get_f64_arr(o, "out_v")?;
                let label = get_u64(o, "label")?;
                if ty == "frame" {
                    Ok(Response::Frame {
                        session,
                        t,
                        out_v,
                        label,
                    })
                } else {
                    Ok(Response::SessionClosed {
                        session,
                        t,
                        out_v,
                        label,
                    })
                }
            }
            "metrics_ok" => {
                expect_keys(o, &["type", "snapshot"])?;
                Ok(Response::MetricsOk {
                    snapshot: o.get("snapshot").cloned().unwrap(),
                })
            }
            "shed" => {
                expect_keys(o, &["type", "reason", "retry_after_ms"])?;
                Ok(Response::Shed {
                    reason: get_str(o, "reason")?,
                    retry_after_ms: get_f64(o, "retry_after_ms")?,
                })
            }
            "drain_ok" => {
                expect_keys(o, &["type", "drain_ms", "shed", "clean"])?;
                Ok(Response::DrainOk {
                    drain_ms: get_f64(o, "drain_ms")?,
                    shed: get_u64(o, "shed")?,
                    clean: get_bool(o, "clean")?,
                })
            }
            "error" => {
                expect_keys(o, &["type", "msg"])?;
                Ok(Response::Error {
                    msg: get_str(o, "msg")?,
                })
            }
            other => Err(format!("unknown response type {other:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rt_req(r: Request) {
        let j = r.to_json();
        // Through the serializer and back, as it would cross the wire.
        let j2 = json::parse(&j.to_string()).unwrap();
        assert_eq!(Request::from_json(&j2).unwrap(), r);
    }

    fn rt_resp(r: Response) {
        let j = r.to_json();
        let j2 = json::parse(&j.to_string()).unwrap();
        assert_eq!(Response::from_json(&j2).unwrap(), r);
    }

    #[test]
    fn requests_round_trip() {
        rt_req(Request::Infer { x: vec![0, 3, 9, 250] });
        rt_req(Request::OpenSession);
        rt_req(Request::StreamFrame {
            session: 7,
            events: vec![1, 4, 63],
        });
        rt_req(Request::StreamFrame {
            session: 0,
            events: vec![],
        });
        rt_req(Request::CloseSession { session: 42 });
        rt_req(Request::MetricsQuery);
        rt_req(Request::Drain { deadline_ms: 1500.0 });
    }

    #[test]
    fn responses_round_trip() {
        rt_resp(Response::InferOk { y: vec![0.5, -2.25] });
        rt_resp(Response::SessionOpen { session: 3 });
        rt_resp(Response::Frame {
            session: 3,
            t: 11,
            out_v: vec![1.0, 0.0, -0.125],
            label: 2,
        });
        rt_resp(Response::SessionClosed {
            session: 3,
            t: 12,
            out_v: vec![0.75],
            label: 0,
        });
        rt_resp(Response::MetricsOk {
            snapshot: json::obj(vec![("served", Json::Num(5.0))]),
        });
        rt_resp(Response::Shed {
            reason: SHED_QUEUE_FULL.into(),
            retry_after_ms: 2.5,
        });
        rt_resp(Response::Shed {
            reason: "draining".into(),
            retry_after_ms: 0.0,
        });
        rt_resp(Response::DrainOk {
            drain_ms: 12.5,
            shed: 4,
            clean: true,
        });
        rt_resp(Response::Error { msg: "nope".into() });
    }

    #[test]
    fn unknown_type_rejected() {
        let j = json::obj(vec![("type", Json::Str("fire_missiles".into()))]);
        let err = Request::from_json(&j).unwrap_err();
        assert!(err.contains("unknown request type"), "{err}");
        let err = Response::from_json(&j).unwrap_err();
        assert!(err.contains("unknown response type"), "{err}");
    }

    #[test]
    fn unknown_and_missing_fields_rejected() {
        // Extra field on an otherwise valid request.
        let j = json::obj(vec![
            ("type", Json::Str("open_session".into())),
            ("surprise", Json::Num(1.0)),
        ]);
        let err = Request::from_json(&j).unwrap_err();
        assert!(err.contains("unknown field"), "{err}");
        // Missing required field.
        let j = json::obj(vec![("type", Json::Str("close_session".into()))]);
        let err = Request::from_json(&j).unwrap_err();
        assert!(err.contains("missing field"), "{err}");
        // Non-object frame.
        assert!(Request::from_json(&Json::Num(1.0)).is_err());
    }

    #[test]
    fn field_shapes_validated() {
        // Fractional session id.
        let j = json::obj(vec![
            ("type", Json::Str("close_session".into())),
            ("session", Json::Num(1.5)),
        ]);
        assert!(Request::from_json(&j).is_err());
        // Negative event index.
        let j = json::obj(vec![
            ("type", Json::Str("stream_frame".into())),
            ("session", Json::Num(1.0)),
            ("events", Json::Arr(vec![Json::Num(-3.0)])),
        ]);
        assert!(Request::from_json(&j).is_err());
        // Negative drain deadline.
        let j = json::obj(vec![
            ("type", Json::Str("drain".into())),
            ("deadline_ms", Json::Num(-1.0)),
        ]);
        assert!(Request::from_json(&j).is_err());
        // Absurd drain deadline (1e23 ms overflows
        // Duration::from_secs_f64 — must be a decode error, never a
        // panic downstream).
        let j = json::obj(vec![
            ("type", Json::Str("drain".into())),
            ("deadline_ms", Json::Num(1e23)),
        ]);
        assert!(Request::from_json(&j).is_err());
        // The bound itself is accepted.
        let j = json::obj(vec![
            ("type", Json::Str("drain".into())),
            ("deadline_ms", Json::Num(MAX_DEADLINE_MS)),
        ]);
        assert!(Request::from_json(&j).is_ok());
        // String where a number belongs.
        let j = json::obj(vec![
            ("type", Json::Str("infer".into())),
            ("x", Json::Arr(vec![Json::Str("1".into())])),
        ]);
        assert!(Request::from_json(&j).is_err());
    }
}

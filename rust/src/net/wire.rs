//! Length-prefixed JSON framing (DESIGN.md S23).
//!
//! One frame = a 4-byte big-endian `u32` length prefix followed by
//! exactly that many bytes of UTF-8 JSON (via the vendored
//! [`util::json`]). The codec treats every inbound byte as hostile:
//! the length prefix is capped at [`MAX_FRAME_BYTES`] *before* any
//! allocation, the body must be valid UTF-8, and the JSON parse runs
//! under [`MAX_FRAME_DEPTH`] so `[[[[…` can't recurse the stack away
//! (the `util::json` hardening this frame cap composes with).
//!
//! The error taxonomy encodes what a connection handler can do next:
//!
//! * [`WireError::Malformed`] — the *frame boundary was honored* (the
//!   bad bytes were fully consumed), so the handler can answer with an
//!   error response and keep the connection;
//! * [`WireError::TooLarge`] / [`WireError::Truncated`] — the stream
//!   itself can no longer be trusted (a bogus prefix, or EOF
//!   mid-frame); the only clean move is to drop the connection;
//! * [`WireError::Closed`] — orderly EOF on a frame boundary.
//!
//! [`util::json`]: crate::util::json

use std::fmt;
use std::io::{self, Read, Write};

use crate::util::json::{self, Json};

/// Largest frame body the codec will read or write (1 MiB). A remote
/// peer claiming more gets [`WireError::TooLarge`] before a single
/// body byte is allocated.
pub const MAX_FRAME_BYTES: usize = 1 << 20;

/// Maximum JSON nesting depth inside one frame — far above anything
/// the protocol emits (requests nest 2 levels, metrics snapshots 3).
pub const MAX_FRAME_DEPTH: usize = 16;

/// Why a frame could not be read.
#[derive(Debug)]
pub enum WireError {
    /// Orderly EOF between frames.
    Closed,
    /// EOF mid-frame: the peer vanished with bytes outstanding.
    Truncated,
    /// The length prefix exceeded [`MAX_FRAME_BYTES`] (nothing was
    /// allocated; the stream is desynced from here on).
    TooLarge(usize),
    /// The framed body was rejected (bad UTF-8 or bad JSON). The frame
    /// itself was fully consumed — the connection can survive.
    Malformed(String),
    /// Transport error from the underlying stream.
    Io(io::Error),
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Closed => write!(f, "connection closed"),
            WireError::Truncated => write!(f, "connection closed mid-frame"),
            WireError::TooLarge(n) => write!(
                f,
                "frame of {n} bytes exceeds the {MAX_FRAME_BYTES}-byte cap"
            ),
            WireError::Malformed(msg) => write!(f, "malformed frame: {msg}"),
            WireError::Io(e) => write!(f, "wire i/o: {e}"),
        }
    }
}

impl std::error::Error for WireError {}

fn is_wait(e: &io::Error) -> bool {
    matches!(
        e.kind(),
        io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut
    )
}

/// Incremental frame reader that survives read timeouts: partial bytes
/// stay buffered across [`poll`](Self::poll) calls, so a server
/// connection thread can use short socket timeouts to observe
/// stop/drain flags without ever desyncing the stream.
#[derive(Default)]
pub struct FrameReader {
    hdr: [u8; 4],
    hdr_got: usize,
    body: Vec<u8>,
    body_got: usize,
    in_body: bool,
}

impl FrameReader {
    pub fn new() -> FrameReader {
        FrameReader::default()
    }

    fn reset(&mut self) {
        self.hdr_got = 0;
        self.body = Vec::new();
        self.body_got = 0;
        self.in_body = false;
    }

    /// Pump bytes from `r` toward one complete frame.
    ///
    /// * `Ok(Some(json))` — a full frame arrived and parsed;
    /// * `Ok(None)` — the read timed out / would block; partial state
    ///   is kept, call again;
    /// * `Err(Malformed)` — the frame was fully consumed but its body
    ///   was rejected; the reader has reset and the stream is still in
    ///   sync (answer with an error response and keep reading);
    /// * any other `Err` — the stream is closed or desynced.
    pub fn poll(&mut self, r: &mut impl Read) -> Result<Option<Json>, WireError> {
        loop {
            if !self.in_body {
                match r.read(&mut self.hdr[self.hdr_got..]) {
                    Ok(0) => {
                        return Err(if self.hdr_got == 0 {
                            WireError::Closed
                        } else {
                            WireError::Truncated
                        })
                    }
                    Ok(n) => {
                        self.hdr_got += n;
                        if self.hdr_got == 4 {
                            let len = u32::from_be_bytes(self.hdr) as usize;
                            if len > MAX_FRAME_BYTES {
                                return Err(WireError::TooLarge(len));
                            }
                            self.body = vec![0u8; len];
                            self.body_got = 0;
                            self.in_body = true;
                        }
                    }
                    Err(e) if is_wait(&e) => return Ok(None),
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                    Err(e) => return Err(WireError::Io(e)),
                }
                continue;
            }
            if self.body_got == self.body.len() {
                let body = std::mem::take(&mut self.body);
                self.reset();
                let text = std::str::from_utf8(&body).map_err(|_| {
                    WireError::Malformed("frame body is not valid UTF-8".into())
                })?;
                return json::parse_with_limits(
                    text,
                    MAX_FRAME_BYTES,
                    MAX_FRAME_DEPTH,
                )
                .map(Some)
                .map_err(WireError::Malformed);
            }
            let at = self.body_got;
            match r.read(&mut self.body[at..]) {
                Ok(0) => return Err(WireError::Truncated),
                Ok(n) => self.body_got += n,
                Err(e) if is_wait(&e) => return Ok(None),
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) => return Err(WireError::Io(e)),
            }
        }
    }
}

/// Blocking read of one frame (client side — sockets without a read
/// timeout; a spurious `WouldBlock` just retries).
pub fn read_frame(r: &mut impl Read) -> Result<Json, WireError> {
    let mut fr = FrameReader::new();
    loop {
        if let Some(j) = fr.poll(r)? {
            return Ok(j);
        }
    }
}

/// Write one frame: big-endian `u32` length prefix + compact JSON.
/// Panics if the serialized body exceeds [`MAX_FRAME_BYTES`] — a
/// sender bug (responses are bounded by construction), not a remote
/// input.
pub fn write_frame(w: &mut impl Write, j: &Json) -> io::Result<()> {
    let body = j.to_string();
    assert!(
        body.len() <= MAX_FRAME_BYTES,
        "outbound frame of {} bytes exceeds the cap",
        body.len()
    );
    w.write_all(&(body.len() as u32).to_be_bytes())?;
    w.write_all(body.as_bytes())?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn frame_bytes(j: &Json) -> Vec<u8> {
        let mut buf = Vec::new();
        write_frame(&mut buf, j).unwrap();
        buf
    }

    #[test]
    fn round_trip_one_frame() {
        let j = json::obj(vec![
            ("type", Json::Str("infer".into())),
            ("x", json::arr_f64(&[1.0, 2.0, 3.0])),
        ]);
        let bytes = frame_bytes(&j);
        assert_eq!(bytes.len(), 4 + j.to_string().len());
        assert_eq!(&bytes[..4], &(j.to_string().len() as u32).to_be_bytes());
        let back = read_frame(&mut Cursor::new(bytes)).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn back_to_back_frames_stay_in_sync() {
        let a = json::obj(vec![("type", Json::Str("open_session".into()))]);
        let b = json::obj(vec![("type", Json::Str("metrics".into()))]);
        let mut bytes = frame_bytes(&a);
        bytes.extend(frame_bytes(&b));
        let mut cur = Cursor::new(bytes);
        assert_eq!(read_frame(&mut cur).unwrap(), a);
        assert_eq!(read_frame(&mut cur).unwrap(), b);
        assert!(matches!(read_frame(&mut cur), Err(WireError::Closed)));
    }

    #[test]
    fn oversized_length_prefix_rejected_before_allocation() {
        let mut bytes = Vec::new();
        bytes.extend(((MAX_FRAME_BYTES + 1) as u32).to_be_bytes());
        bytes.extend([b'x'; 8]);
        match read_frame(&mut Cursor::new(bytes)) {
            Err(WireError::TooLarge(n)) => assert_eq!(n, MAX_FRAME_BYTES + 1),
            other => panic!("expected TooLarge, got {other:?}"),
        }
    }

    #[test]
    fn truncated_frame_is_not_closed() {
        // Header promises 100 bytes, only 3 arrive before EOF.
        let mut bytes = Vec::new();
        bytes.extend(100u32.to_be_bytes());
        bytes.extend(b"abc");
        assert!(matches!(
            read_frame(&mut Cursor::new(bytes)),
            Err(WireError::Truncated)
        ));
        // EOF inside the header is truncation too.
        let bytes = vec![0u8, 0];
        assert!(matches!(
            read_frame(&mut Cursor::new(bytes)),
            Err(WireError::Truncated)
        ));
        // EOF on the boundary is a clean close.
        assert!(matches!(
            read_frame(&mut Cursor::new(Vec::new())),
            Err(WireError::Closed)
        ));
    }

    #[test]
    fn invalid_utf8_and_bad_json_are_malformed_and_recoverable() {
        let mut reader = FrameReader::new();
        // Frame 1: framed garbage bytes (invalid UTF-8).
        let mut bytes = Vec::new();
        bytes.extend(2u32.to_be_bytes());
        bytes.extend([0xff, 0xfe]);
        // Frame 2: framed non-JSON text.
        bytes.extend(5u32.to_be_bytes());
        bytes.extend(b"hello");
        // Frame 3: a good frame — the reader must still be in sync.
        let good = json::obj(vec![("ok", Json::Bool(true))]);
        bytes.extend(frame_bytes(&good));
        let mut cur = Cursor::new(bytes);
        match reader.poll(&mut cur) {
            Err(WireError::Malformed(m)) => assert!(m.contains("UTF-8"), "{m}"),
            other => panic!("expected Malformed, got {other:?}"),
        }
        assert!(matches!(
            reader.poll(&mut cur),
            Err(WireError::Malformed(_))
        ));
        assert_eq!(reader.poll(&mut cur).unwrap(), Some(good));
    }

    #[test]
    fn deep_nesting_inside_a_frame_is_malformed() {
        let deep = "[".repeat(MAX_FRAME_DEPTH + 1)
            + &"]".repeat(MAX_FRAME_DEPTH + 1);
        let mut bytes = Vec::new();
        bytes.extend((deep.len() as u32).to_be_bytes());
        bytes.extend(deep.as_bytes());
        match read_frame(&mut Cursor::new(bytes)) {
            Err(WireError::Malformed(m)) => {
                assert!(m.contains("nesting too deep"), "{m}")
            }
            other => panic!("expected Malformed, got {other:?}"),
        }
    }

    #[test]
    fn byte_at_a_time_delivery_reassembles() {
        // A reader that hands out one byte per call, interleaved with
        // WouldBlock — the pathological TCP segmentation the
        // FrameReader state machine exists for.
        struct Trickle {
            data: Vec<u8>,
            at: usize,
            starve: bool,
        }
        impl Read for Trickle {
            fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
                self.starve = !self.starve;
                if self.starve {
                    return Err(io::Error::new(
                        io::ErrorKind::WouldBlock,
                        "starved",
                    ));
                }
                if self.at >= self.data.len() {
                    return Ok(0);
                }
                buf[0] = self.data[self.at];
                self.at += 1;
                Ok(1)
            }
        }
        let j = json::obj(vec![("n", Json::Num(42.0))]);
        let mut src = Trickle {
            data: frame_bytes(&j),
            at: 0,
            starve: false,
        };
        let mut reader = FrameReader::new();
        let mut polls = 0usize;
        let got = loop {
            polls += 1;
            assert!(polls < 1000, "reassembly must terminate");
            match reader.poll(&mut src).unwrap() {
                Some(v) => break v,
                None => continue,
            }
        };
        assert_eq!(got, j);
    }

    #[test]
    fn empty_body_is_malformed_not_a_crash() {
        let bytes = 0u32.to_be_bytes().to_vec();
        assert!(matches!(
            read_frame(&mut Cursor::new(bytes)),
            Err(WireError::Malformed(_))
        ));
    }
}

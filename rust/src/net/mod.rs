//! S23: network serving front end — a hermetic, std-only wire layer
//! over the serving backends.
//!
//! Layering (bottom up):
//!
//! * [`wire`] — length-prefixed JSON framing with hard caps on frame
//!   size and parse depth; the only layer that touches raw bytes.
//! * [`proto`] — the typed request/response protocol, strictly
//!   decoded (unknown types/fields are errors, not warnings).
//! * [`server`] — blocking thread-per-connection [`NetServer`]
//!   dispatching onto a [`NetBackend`] (macro one-shot inference or
//!   streaming sessions), with graceful drain over live connections.
//! * [`client`] — a minimal synchronous [`NetClient`].
//! * [`loadgen`] — the closed-loop load harness behind `spikemram
//!   loadgen` and the EX7 serving sweep.
//!
//! Everything rides on `std::net` blocking sockets plus the repo's
//! threads-and-channels substrate — no async runtime, no external
//! crates, per the hermetic-build rule (DESIGN.md S0).

pub mod client;
pub mod loadgen;
pub mod proto;
pub mod server;
pub mod wire;

pub use client::NetClient;
pub use loadgen::{LoadGenConfig, LoadMode, LoadReport};
pub use proto::{Request, Response, MAX_DEADLINE_MS, SHED_QUEUE_FULL};
pub use server::{NetBackend, NetServer};
pub use wire::{
    read_frame, write_frame, FrameReader, WireError, MAX_FRAME_BYTES,
    MAX_FRAME_DEPTH,
};

//! Blocking TCP front end over the serving backends (DESIGN.md S23).
//!
//! One accept thread plus one thread per connection — the same
//! threads-and-channels substrate as the rest of the repo (no async
//! runtime exists offline, and serving-side concurrency is already
//! bounded by the backend's worker pool, so thread-per-connection is
//! the honest model rather than a limitation).
//!
//! Lock discipline: the backend lives in a `Mutex<Option<NetBackend>>`.
//! Handlers take the lock only long enough to *submit* (admission is
//! cheap and lock-free inside the backend) and always release it
//! before blocking on the reply receiver — connections do not
//! serialize behind one slow inference. `Drain` `take()`s the backend
//! out of the option, so every later request observes `None` and maps
//! to a `draining` shed response, while the drain itself runs on the
//! requesting connection's thread without holding the lock.
//!
//! Session affinity rides on the backend: `StreamServer` pins
//! `session % workers`, so a session opened over the wire keeps its
//! worker across frames no matter which connection carries them.
//!
//! Drain-over-wire contract: after the `drain_ok` response is written,
//! the server stops reading, every live connection is closed on a
//! frame boundary (peers see a clean EOF, never a truncated frame),
//! and the accept loop exits. [`NetServer::wait`] then returns.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::{Admission, MacroServer, Metrics};
use crate::stream::{DrainReport, FrameOutcome, StreamServer};

use super::proto::{Request, Response, SHED_QUEUE_FULL};
use super::wire::{write_frame, FrameReader, WireError};

/// How often a connection thread wakes from a blocked read to check
/// the stop flag (socket read timeout).
const POLL_TICK: Duration = Duration::from_millis(25);

/// A serving backend the wire front end can dispatch onto.
pub enum NetBackend {
    /// One-shot dense inference ([`Request::Infer`]); covers the
    /// `sim`, `pjrt` and `fabric` serve modes.
    Macro(MacroServer),
    /// Event-driven streaming sessions
    /// ([`Request::OpenSession`]/[`Request::StreamFrame`]).
    Stream(StreamServer),
}

impl NetBackend {
    fn metrics(&self) -> Arc<Metrics> {
        match self {
            NetBackend::Macro(s) => s.metrics.clone(),
            NetBackend::Stream(s) => s.metrics.clone(),
        }
    }

    /// Drain within `deadline`. `MacroServer::shutdown` has no
    /// deadline knob (its queue is always fully drained) so it is
    /// timed and reported as clean; `StreamServer` delegates to
    /// `shutdown_within`.
    fn drain(self, deadline: Duration) -> DrainReport {
        match self {
            NetBackend::Macro(s) => {
                let t0 = Instant::now();
                s.shutdown();
                DrainReport {
                    drain_ms: t0.elapsed().as_secs_f64() * 1e3,
                    shed: 0,
                    clean: true,
                }
            }
            NetBackend::Stream(s) => s.shutdown_within(deadline),
        }
    }
}

struct Shared {
    backend: Mutex<Option<NetBackend>>,
    metrics: Arc<Metrics>,
    stop: AtomicBool,
    conns: Mutex<Vec<JoinHandle<()>>>,
}

/// The listening front end. Bind with [`start`](Self::start), then
/// either [`wait`](Self::wait) for a wire-initiated drain or call
/// [`shutdown_within`](Self::shutdown_within) programmatically.
pub struct NetServer {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
}

impl NetServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) and
    /// start accepting connections over `backend`.
    pub fn start(backend: NetBackend, addr: &str) -> Result<NetServer> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("bind {addr}"))?;
        listener
            .set_nonblocking(true)
            .context("listener nonblocking")?;
        let addr = listener.local_addr().context("local_addr")?;
        let shared = Arc::new(Shared {
            metrics: backend.metrics(),
            backend: Mutex::new(Some(backend)),
            stop: AtomicBool::new(false),
            conns: Mutex::new(Vec::new()),
        });
        let accept = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("spikemram-net-accept".into())
                .spawn(move || accept_loop(listener, shared))
                .context("spawn accept thread")?
        };
        Ok(NetServer {
            addr,
            shared,
            accept: Some(accept),
        })
    }

    /// The bound address (resolves the port when bound to `:0`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        self.shared.metrics.clone()
    }

    fn join_threads(&mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let handles = std::mem::take(&mut *self.shared.conns.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
    }

    /// Block until a wire `drain` request stops the server, then join
    /// every connection thread. This is what `spikemram serve
    /// --listen` parks on.
    pub fn wait(mut self) {
        self.join_threads();
    }

    /// Programmatic shutdown: drain the backend within `deadline`,
    /// close all connections on frame boundaries, join all threads.
    /// Reports zeros if a wire `drain` already took the backend.
    pub fn shutdown_within(mut self, deadline: Duration) -> DrainReport {
        let taken = self.shared.backend.lock().unwrap().take();
        let rep = match taken {
            Some(b) => b.drain(deadline),
            None => DrainReport {
                drain_ms: 0.0,
                shed: 0,
                clean: true,
            },
        };
        self.shared.stop.store(true, Ordering::Release);
        self.join_threads();
        rep
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    loop {
        if shared.stop.load(Ordering::Acquire) {
            return;
        }
        match listener.accept() {
            Ok((sock, _peer)) => {
                let _ = sock.set_nodelay(true);
                if sock.set_read_timeout(Some(POLL_TICK)).is_err() {
                    // Without the poll tick this connection could block
                    // in read() forever and never observe the stop
                    // flag, hanging shutdown at join time — refuse it
                    // instead.
                    continue;
                }
                let sh = Arc::clone(&shared);
                let h = std::thread::Builder::new()
                    .name("spikemram-net-conn".into())
                    .spawn(move || handle_conn(sh, sock))
                    .expect("spawn connection thread");
                let mut conns = shared.conns.lock().unwrap();
                // Reap finished connections as new ones arrive so a
                // long-lived endpoint with churn doesn't accumulate
                // JoinHandles (and their thread resources) without
                // bound. Finished threads join without blocking.
                let mut i = 0;
                while i < conns.len() {
                    if conns[i].is_finished() {
                        let _ = conns.swap_remove(i).join();
                    } else {
                        i += 1;
                    }
                }
                conns.push(h);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => {
                // Transient accept error (EMFILE, ECONNABORTED, ...):
                // back off and keep serving the connections we have.
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    }
}

fn handle_conn(shared: Arc<Shared>, mut sock: TcpStream) {
    let metrics = shared.metrics.clone();
    let mut reader = FrameReader::new();
    loop {
        if shared.stop.load(Ordering::Acquire) {
            // Drain finished elsewhere: close on the frame boundary so
            // the peer sees an orderly EOF, not a truncated frame.
            return;
        }
        let frame = match reader.poll(&mut sock) {
            Ok(None) => continue, // read tick elapsed; re-check stop
            Ok(Some(j)) => j,
            Err(WireError::Closed) => return,
            Err(WireError::Malformed(msg)) => {
                // Frame boundary intact: answer and keep the line.
                metrics.record_wire_malformed();
                let resp = Response::Error { msg };
                if write_frame(&mut sock, &resp.to_json()).is_err() {
                    metrics.record_wire_disconnect();
                    return;
                }
                continue;
            }
            Err(e @ WireError::TooLarge(_)) => {
                // The stream is desynced past this prefix — tell the
                // peer why, then hang up.
                metrics.record_wire_malformed();
                metrics.record_wire_disconnect();
                let resp = Response::Error { msg: e.to_string() };
                let _ = write_frame(&mut sock, &resp.to_json());
                return;
            }
            Err(WireError::Truncated) | Err(WireError::Io(_)) => {
                metrics.record_wire_disconnect();
                return;
            }
        };
        let req = match Request::from_json(&frame) {
            Ok(r) => r,
            Err(msg) => {
                metrics.record_wire_malformed();
                let resp = Response::Error { msg };
                if write_frame(&mut sock, &resp.to_json()).is_err() {
                    metrics.record_wire_disconnect();
                    return;
                }
                continue;
            }
        };
        metrics.record_wire_request();
        let (resp, done) = dispatch(&shared, req);
        if matches!(resp, Response::Shed { .. }) {
            metrics.record_wire_shed();
        }
        if write_frame(&mut sock, &resp.to_json()).is_err() {
            metrics.record_wire_disconnect();
            return;
        }
        if done {
            return;
        }
    }
}

fn shed_draining() -> (Response, bool) {
    (
        Response::Shed {
            reason: "draining".into(),
            retry_after_ms: 0.0,
        },
        false,
    )
}

fn wrong_backend(msg: &str) -> (Response, bool) {
    (Response::Error { msg: msg.into() }, false)
}

/// Pre-flight the event list against the assertions
/// `StreamServer::try_submit_frame` makes on the submitting thread —
/// a hostile frame must fail its own connection with an error
/// response, not panic a server thread.
fn validate_events(events: &[u32], in_dim: usize) -> Result<(), String> {
    let mut prev: i64 = -1;
    for &r in events {
        if (r as usize) >= in_dim {
            return Err(format!(
                "event row {r} out of range (in_dim {in_dim})"
            ));
        }
        if i64::from(r) <= prev {
            return Err(
                "events must be sorted ascending without duplicates".into()
            );
        }
        prev = i64::from(r);
    }
    Ok(())
}

fn ms(d: Duration) -> f64 {
    d.as_secs_f64() * 1e3
}

/// Handle one decoded request. Returns the response plus a `done`
/// flag (true only after a drain completes on this connection).
fn dispatch(shared: &Shared, req: Request) -> (Response, bool) {
    match req {
        Request::MetricsQuery => {
            // Served even after drain: the metrics Arc outlives the
            // backend, so post-drain accounting queries still work.
            let snap = shared.metrics.snapshot();
            (
                Response::MetricsOk {
                    snapshot: snap.to_json(),
                },
                false,
            )
        }
        Request::Infer { x } => {
            let guard = shared.backend.lock().unwrap();
            let srv = match guard.as_ref() {
                None => return shed_draining(),
                Some(NetBackend::Stream(_)) => {
                    return wrong_backend(
                        "infer requires a macro backend; \
                         this server streams (use stream_frame)",
                    )
                }
                Some(NetBackend::Macro(s)) => s,
            };
            if x.len() != srv.in_dim() {
                let msg = format!(
                    "x has {} entries; backend in_dim is {}",
                    x.len(),
                    srv.in_dim()
                );
                return (Response::Error { msg }, false);
            }
            let rx = srv.submit(x);
            drop(guard); // never block on recv while holding the lock
            match rx.recv() {
                Ok(y) => (Response::InferOk { y }, false),
                Err(_) => (
                    Response::Error {
                        msg: "backend dropped the request".into(),
                    },
                    false,
                ),
            }
        }
        Request::OpenSession => {
            let guard = shared.backend.lock().unwrap();
            match guard.as_ref() {
                None => shed_draining(),
                Some(NetBackend::Macro(_)) => wrong_backend(
                    "open_session requires a stream backend (use infer)",
                ),
                Some(NetBackend::Stream(s)) => (
                    Response::SessionOpen {
                        session: s.open_session(),
                    },
                    false,
                ),
            }
        }
        Request::StreamFrame { session, events } => {
            let guard = shared.backend.lock().unwrap();
            let srv = match guard.as_ref() {
                None => return shed_draining(),
                Some(NetBackend::Macro(_)) => {
                    return wrong_backend(
                        "stream_frame requires a stream backend (use infer)",
                    )
                }
                Some(NetBackend::Stream(s)) => s,
            };
            if let Err(msg) = validate_events(&events, srv.in_dim()) {
                shared.metrics.record_wire_malformed();
                return (Response::Error { msg }, false);
            }
            let hint = srv.retry_hint();
            match srv.try_submit_frame(session, events) {
                Admission::Shed { retry_after } => (
                    // With the backend still installed the server is
                    // accepting, so an admission-side shed means the
                    // session's queue is full.
                    Response::Shed {
                        reason: SHED_QUEUE_FULL.into(),
                        retry_after_ms: ms(retry_after),
                    },
                    false,
                ),
                Admission::Accepted(rx) => {
                    drop(guard); // reply waits happen outside the lock
                    match rx.recv() {
                        Ok(FrameOutcome::Served(r)) => (
                            Response::Frame {
                                session: r.session,
                                t: r.t as u64,
                                out_v: r.out_v,
                                label: r.label as u64,
                            },
                            false,
                        ),
                        Ok(FrameOutcome::Shed { reason, .. }) => (
                            Response::Shed {
                                reason: reason.wire_name().into(),
                                retry_after_ms: ms(hint),
                            },
                            false,
                        ),
                        Err(_) => (
                            Response::Error {
                                msg: "backend dropped the frame".into(),
                            },
                            false,
                        ),
                    }
                }
            }
        }
        Request::CloseSession { session } => {
            let guard = shared.backend.lock().unwrap();
            match guard.as_ref() {
                None => shed_draining(),
                Some(NetBackend::Macro(_)) => wrong_backend(
                    "close_session requires a stream backend",
                ),
                Some(NetBackend::Stream(s)) => {
                    let r = s.finish(session);
                    (
                        Response::SessionClosed {
                            session: r.session,
                            t: r.t as u64,
                            out_v: r.out_v,
                            label: r.label as u64,
                        },
                        false,
                    )
                }
            }
        }
        Request::Drain { deadline_ms } => {
            // `Request::from_json` bounds deadline_ms, but convert
            // fallibly anyway and do it *before* take(): a panic past
            // that point would strand the backend out of the Option
            // with `stop` never set — every later request sheds as
            // "draining" and `wait()` never returns.
            let deadline =
                match Duration::try_from_secs_f64(deadline_ms / 1e3) {
                    Ok(d) => d,
                    Err(_) => {
                        return (
                            Response::Error {
                                msg: format!(
                                    "deadline_ms {deadline_ms} is out of \
                                     range"
                                ),
                            },
                            false,
                        )
                    }
                };
            let taken = shared.backend.lock().unwrap().take();
            match taken {
                None => (
                    Response::Error {
                        msg: "already drained".into(),
                    },
                    false,
                ),
                Some(b) => {
                    // The lock is already released: other connections
                    // shed with `draining` while this one drains.
                    let rep = b.drain(deadline);
                    shared.stop.store(true, Ordering::Release);
                    (
                        Response::DrainOk {
                            drain_ms: rep.drain_ms,
                            shed: rep.shed,
                            clean: rep.clean,
                        },
                        true,
                    )
                }
            }
        }
    }
}

//! Minimal blocking client for the S23 wire protocol — used by the
//! closed-loop load generator, the e2e tests, and `examples/net_client`.

use std::net::TcpStream;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

use super::proto::{Request, Response};
use super::wire::{read_frame, write_frame};

/// One connection to a [`NetServer`](super::NetServer). All calls are
/// synchronous: write one request frame, read one response frame.
pub struct NetClient {
    stream: TcpStream,
}

impl NetClient {
    pub fn connect(addr: &str) -> Result<NetClient> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connect {addr}"))?;
        let _ = stream.set_nodelay(true);
        Ok(NetClient { stream })
    }

    /// Bound how long [`call`](Self::call) may block on the response.
    /// `None` waits forever.
    pub fn set_timeout(&self, timeout: Option<Duration>) -> Result<()> {
        self.stream
            .set_read_timeout(timeout)
            .context("set_read_timeout")?;
        self.stream
            .set_write_timeout(timeout)
            .context("set_write_timeout")?;
        Ok(())
    }

    /// One request/response round trip.
    pub fn call(&mut self, req: &Request) -> Result<Response> {
        write_frame(&mut self.stream, &req.to_json())
            .context("write request frame")?;
        let j = read_frame(&mut self.stream)
            .map_err(|e| anyhow!("read response frame: {e}"))?;
        Response::from_json(&j)
            .map_err(|msg| anyhow!("bad response frame: {msg}"))
    }

    /// Dense one-shot inference (macro backends).
    pub fn infer(&mut self, x: Vec<u32>) -> Result<Vec<f64>> {
        match self.call(&Request::Infer { x })? {
            Response::InferOk { y } => Ok(y),
            other => bail!("unexpected response to infer: {other:?}"),
        }
    }

    /// Open a streaming session; returns its id.
    pub fn open_session(&mut self) -> Result<u64> {
        match self.call(&Request::OpenSession)? {
            Response::SessionOpen { session } => Ok(session),
            other => bail!("unexpected response to open_session: {other:?}"),
        }
    }

    /// Submit one event frame. Returns the full [`Response`] because
    /// shedding is an expected outcome near capacity, not an error.
    pub fn stream_frame(
        &mut self,
        session: u64,
        events: Vec<u32>,
    ) -> Result<Response> {
        self.call(&Request::StreamFrame { session, events })
    }

    /// Close a session; returns `(t, out_v, label)` of the final reply.
    pub fn close_session(
        &mut self,
        session: u64,
    ) -> Result<(u64, Vec<f64>, u64)> {
        match self.call(&Request::CloseSession { session })? {
            Response::SessionClosed { t, out_v, label, .. } => {
                Ok((t, out_v, label))
            }
            other => bail!("unexpected response to close_session: {other:?}"),
        }
    }

    /// Fetch the server's metrics snapshot document.
    pub fn metrics(&mut self) -> Result<Json> {
        match self.call(&Request::MetricsQuery)? {
            Response::MetricsOk { snapshot } => Ok(snapshot),
            other => bail!("unexpected response to metrics: {other:?}"),
        }
    }

    /// Drain the server within `deadline_ms`; returns
    /// `(drain_ms, shed, clean)`.
    pub fn drain(&mut self, deadline_ms: f64) -> Result<(f64, u64, bool)> {
        match self.call(&Request::Drain { deadline_ms })? {
            Response::DrainOk {
                drain_ms,
                shed,
                clean,
            } => Ok((drain_ms, shed, clean)),
            other => bail!("unexpected response to drain: {other:?}"),
        }
    }
}

//! The complete spiking CIM macro (DESIGN.md S8).

use crate::energy::EnergyBreakdown;
use crate::util::pool;

pub mod cim_macro;

pub use cim_macro::{CimMacro, EngineUsed, MacroResult, MvmBatch};

/// Fan a tiled layer's input slices across its shard macros (ti-major
/// order) and regroup the outputs as `partials[ti][tj]`, plus summed
/// energy and the critical-path (max) latency. A single-item run of
/// [`mvm_tiled_batch`], itself a wrapper over
/// [`mvm_tiled_batch_strided`] — the one implementation of the (ti, tj)
/// convention that both `snn::infer` and `fabric::chip` rely on for
/// bit-identity; do not fork it.
pub fn mvm_tiled(
    macros: &mut [CimMacro],
    xparts: &[Vec<u32>],
    row_tiles: usize,
    col_tiles: usize,
) -> (Vec<Vec<Vec<f64>>>, EnergyBreakdown, f64) {
    let xbatch: Vec<Vec<Vec<u32>>> =
        xparts.iter().map(|p| vec![p.clone()]).collect();
    mvm_tiled_batch(macros, &xbatch, row_tiles, col_tiles)
        .pop()
        .expect("one item")
}

/// Run many independent tile MVMs on the persistent shared worker pool
/// (DESIGN.md S15/S17): `jobs` pairs each programmed macro with its
/// input slice.
///
/// Results come back in job order, bit-identical to a serial loop — each
/// macro is its own deterministic simulator, so parallelism changes only
/// wall-clock (row tiles were always *modeled* as latency-parallel; this
/// makes the implementation match the model). The pool is long-lived and
/// channel-fed, so repeated calls pay no thread-spawn cost.
pub fn mvm_parallel(jobs: Vec<(&mut CimMacro, &[u32])>) -> Vec<MacroResult> {
    par_map_jobs(jobs, |(m, x)| m.mvm(x))
}

/// Batched [`mvm_parallel`] (DESIGN.md S16): each job pairs a programmed
/// macro with the *whole request batch* for that macro, so every pool
/// worker streams its weight matrix once per batch instead of once per
/// input. Ledgers come back in job order, bit-identical to calling
/// [`CimMacro::mvm_batch`] serially per job.
pub fn mvm_parallel_batch(
    jobs: Vec<(&mut CimMacro, &[Vec<u32>])>,
) -> Vec<MvmBatch> {
    par_map_jobs(jobs, |(m, xs)| m.mvm_batch(xs))
}

/// [`mvm_parallel`] for the binary-spike fast path (DESIGN.md S18):
/// each job pairs a programmed macro with its *sorted active-row event
/// list* for one timestep — the stream runtime's per-tile fan-out.
/// Results in job order, bit-identical to serial
/// [`CimMacro::mvm_events`] calls.
pub fn mvm_events_parallel(
    jobs: Vec<(&mut CimMacro, &[u32])>,
) -> Vec<MacroResult> {
    par_map_jobs(jobs, |(m, ev)| m.mvm_events(ev))
}

/// Flat-input [`mvm_parallel_batch`] (DESIGN.md S17): each job carries
/// its batch as one `[batch × in_dim]` flat slice, so upstream callers
/// (fabric stages, servers) feed reusable buffers instead of allocating
/// `Vec<Vec<u32>>` per batch.
pub fn mvm_parallel_batch_strided(
    jobs: Vec<(&mut CimMacro, &[u32])>,
    in_dim: usize,
) -> Vec<MvmBatch> {
    par_map_jobs(jobs, move |(m, xs)| m.mvm_batch_strided(xs, in_dim))
}

/// The shared fan-out behind [`mvm_parallel`] and friends — since
/// DESIGN.md S17 a thin veneer over [`util::pool::scope_map`]
/// (persistent channel-fed workers, deterministic job order, zero
/// per-call spawns); single jobs run inline.
///
/// [`util::pool::scope_map`]: crate::util::pool::scope_map
fn par_map_jobs<T: Send, R: Send>(
    jobs: Vec<T>,
    f: impl Fn(T) -> R + Sync,
) -> Vec<R> {
    pool::scope_map(jobs, f)
}

/// One batch item's tiled-MVM output (DESIGN.md S17): the per-shard
/// partials in (ti, tj) order plus the op-level tallies.
#[derive(Debug, Clone)]
pub struct TiledBatchItem {
    /// `partials[ti][tj]` — ready for `TiledMatrix::accumulate`.
    pub partials: Vec<Vec<Vec<f64>>>,
    /// Summed energy over all shards.
    pub energy: EnergyBreakdown,
    /// Critical-path latency (tiles are physically concurrent, ns).
    pub latency_ns: f64,
    /// Macro row activations summed over *all* shards of this item
    /// (each active input row fires once per column tile it feeds).
    pub active_rows: u64,
}

/// Batched [`mvm_tiled`] (DESIGN.md S16): `xparts[ti]` carries the whole
/// minibatch of row-tile `ti`'s input slices. Returns one
/// `(partials, energy, latency)` triple per batch item, each bit-identical
/// to what `mvm_tiled` would produce for that item alone — the (ti, tj)
/// convention and the shard accumulation order are unchanged. A thin
/// flattening wrapper over [`mvm_tiled_batch_strided`].
pub fn mvm_tiled_batch(
    macros: &mut [CimMacro],
    xparts: &[Vec<Vec<u32>>],
    row_tiles: usize,
    col_tiles: usize,
) -> Vec<(Vec<Vec<Vec<f64>>>, EnergyBreakdown, f64)> {
    assert_eq!(xparts.len(), row_tiles, "one slice batch per row tile");
    let batch = xparts.first().map_or(0, |p| p.len());
    assert!(
        xparts.iter().all(|p| p.len() == batch),
        "ragged batch across row tiles"
    );
    let flat: Vec<Vec<u32>> = xparts
        .iter()
        .map(|p| p.iter().flatten().copied().collect())
        .collect();
    mvm_tiled_batch_strided(macros, &flat, batch, row_tiles, col_tiles)
        .into_iter()
        .map(|i| (i.partials, i.energy, i.latency_ns))
        .collect()
}

/// Flat-input batched tiled MVM (DESIGN.md S17): `xparts[ti]` is row
/// tile `ti`'s whole minibatch as one `[batch × tile]` flat slice.
/// The one implementation of the (ti, tj) convention that `snn::infer`
/// and `fabric::chip` rely on for bit-identity; do not fork it.
pub fn mvm_tiled_batch_strided(
    macros: &mut [CimMacro],
    xparts: &[Vec<u32>],
    batch: usize,
    row_tiles: usize,
    col_tiles: usize,
) -> Vec<TiledBatchItem> {
    assert_eq!(macros.len(), row_tiles * col_tiles, "shard count");
    assert_eq!(xparts.len(), row_tiles, "one flat batch per row tile");
    let tile = macros.first().map_or(0, |m| m.cfg.rows);
    for p in xparts {
        assert_eq!(p.len(), batch * tile, "flat batch shape");
    }
    let jobs: Vec<(&mut CimMacro, &[u32])> = macros
        .iter_mut()
        .enumerate()
        .map(|(sidx, m)| (m, xparts[sidx / col_tiles].as_slice()))
        .collect();
    let ledgers = mvm_parallel_batch_strided(jobs, tile);
    (0..batch)
        .map(|b| {
            let mut energy = EnergyBreakdown::default();
            let mut latency = 0.0f64; // tiles are physically concurrent
            let mut active_rows = 0u64;
            let mut partials: Vec<Vec<Vec<f64>>> = (0..row_tiles)
                .map(|_| Vec::with_capacity(col_tiles))
                .collect();
            for (sidx, l) in ledgers.iter().enumerate() {
                energy.add(l.energy(b));
                latency = latency.max(l.latency_ns(b));
                active_rows += l.active_rows(b) as u64;
                partials[sidx / col_tiles].push(l.y_mac(b).to_vec());
            }
            TiledBatchItem {
                partials,
                energy,
                latency_ns: latency,
                active_rows,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MacroConfig;
    use crate::util::rng::Rng;

    /// Deterministically build `n` programmed macros and `n` inputs.
    fn fleet(n: usize, seed: u64) -> (Vec<CimMacro>, Vec<Vec<u32>>) {
        let cfg = MacroConfig::default();
        let mut rng = Rng::new(seed);
        let macros = (0..n)
            .map(|_| {
                let mut m = CimMacro::new(cfg.clone());
                let codes: Vec<u8> = (0..cfg.rows * cfg.cols)
                    .map(|_| rng.below(4) as u8)
                    .collect();
                m.program(&codes);
                m
            })
            .collect();
        let xs = (0..n)
            .map(|_| (0..cfg.rows).map(|_| rng.below(256) as u32).collect())
            .collect();
        (macros, xs)
    }

    #[test]
    fn parallel_tiles_match_serial_bit_for_bit() {
        let (mut serial, xs) = fleet(5, 77);
        let want: Vec<MacroResult> = serial
            .iter_mut()
            .zip(&xs)
            .map(|(m, x)| m.mvm(x))
            .collect();

        let (mut par, _) = fleet(5, 77); // identical rebuild
        let jobs: Vec<(&mut CimMacro, &[u32])> = par
            .iter_mut()
            .zip(&xs)
            .map(|(m, x)| (m, x.as_slice()))
            .collect();
        let got = mvm_parallel(jobs);

        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.y_mac, w.y_mac);
            assert_eq!(g.events, w.events);
            assert_eq!(g.energy, w.energy);
        }
    }

    #[test]
    fn single_job_runs_inline() {
        let (mut ms, xs) = fleet(1, 78);
        let jobs = vec![(&mut ms[0], xs[0].as_slice())];
        let got = mvm_parallel(jobs);
        assert_eq!(got.len(), 1);
        assert!(got[0].y_mac.iter().any(|&v| v > 0.0));
    }

    #[test]
    fn parallel_batch_matches_serial_batches_bit_for_bit() {
        let cfg = MacroConfig::default();
        let mut rng = Rng::new(81);
        let batches: Vec<Vec<Vec<u32>>> = (0..5)
            .map(|_| {
                (0..4)
                    .map(|_| {
                        (0..cfg.rows).map(|_| rng.below(256) as u32).collect()
                    })
                    .collect()
            })
            .collect();
        let (mut serial, _) = fleet(5, 80);
        let want: Vec<MvmBatch> = serial
            .iter_mut()
            .zip(&batches)
            .map(|(m, xs)| m.mvm_batch(xs))
            .collect();

        let (mut par, _) = fleet(5, 80); // identical rebuild
        let jobs: Vec<(&mut CimMacro, &[Vec<u32>])> = par
            .iter_mut()
            .zip(&batches)
            .map(|(m, xs)| (m, xs.as_slice()))
            .collect();
        let got = mvm_parallel_batch(jobs);

        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.len(), w.len());
            for b in 0..g.len() {
                assert_eq!(g.y_mac(b), w.y_mac(b));
                assert_eq!(g.events(b), w.events(b));
                assert_eq!(g.energy(b), w.energy(b));
            }
        }
    }

    #[test]
    fn tiled_batch_matches_per_item_tiled_bit_for_bit() {
        // 2×2 tile grid over a 256×256 matrix, batch of 5 inputs.
        let cfg = MacroConfig::default();
        let mut rng = Rng::new(83);
        let (rt, ct) = (2usize, 2usize);
        let mk_fleet = |seed: u64| {
            let mut rng = Rng::new(seed);
            (0..rt * ct)
                .map(|_| {
                    let mut m = CimMacro::new(cfg.clone());
                    let codes: Vec<u8> = (0..cfg.rows * cfg.cols)
                        .map(|_| rng.below(4) as u8)
                        .collect();
                    m.program(&codes);
                    m
                })
                .collect::<Vec<_>>()
        };
        let batch = 5usize;
        // xparts[ti][b]: per-row-tile slice batches.
        let xparts: Vec<Vec<Vec<u32>>> = (0..rt)
            .map(|_| {
                (0..batch)
                    .map(|_| {
                        (0..cfg.rows).map(|_| rng.below(256) as u32).collect()
                    })
                    .collect()
            })
            .collect();

        let mut serial = mk_fleet(84);
        let want: Vec<_> = (0..batch)
            .map(|b| {
                let parts: Vec<Vec<u32>> =
                    (0..rt).map(|ti| xparts[ti][b].clone()).collect();
                mvm_tiled(&mut serial, &parts, rt, ct)
            })
            .collect();

        let mut batched = mk_fleet(84);
        let got = mvm_tiled_batch(&mut batched, &xparts, rt, ct);

        assert_eq!(got.len(), batch);
        for ((gp, ge, gl), (wp, we, wl)) in got.iter().zip(&want) {
            assert_eq!(gp, wp, "partials diverge");
            assert_eq!(ge, we, "energy diverges");
            assert_eq!(gl, wl, "latency diverges");
        }

        // The flat-input entry (DESIGN.md S17) is the same engine:
        // bitwise identical output, plus the activity tallies.
        let mut strided = mk_fleet(84);
        let flat: Vec<Vec<u32>> = xparts
            .iter()
            .map(|p| p.iter().flatten().copied().collect())
            .collect();
        let got2 =
            mvm_tiled_batch_strided(&mut strided, &flat, batch, rt, ct);
        assert_eq!(got2.len(), batch);
        for (b, (g2, (wp, we, wl))) in got2.iter().zip(&want).enumerate() {
            assert_eq!(&g2.partials, wp);
            assert_eq!(&g2.energy, we);
            assert_eq!(g2.latency_ns, *wl);
            // Each active input row fires once per column tile it feeds.
            let nonzero: u64 = (0..rt)
                .map(|ti| {
                    xparts[ti][b].iter().filter(|&&v| v > 0).count() as u64
                })
                .sum();
            assert_eq!(g2.active_rows, nonzero * ct as u64);
        }
    }

    #[test]
    fn parallel_strided_matches_parallel_batch() {
        let cfg = MacroConfig::default();
        let mut rng = Rng::new(85);
        let batches: Vec<Vec<Vec<u32>>> = (0..4)
            .map(|_| {
                (0..3)
                    .map(|_| {
                        (0..cfg.rows).map(|_| rng.below(256) as u32).collect()
                    })
                    .collect()
            })
            .collect();
        let (mut a, _) = fleet(4, 86);
        let want = mvm_parallel_batch(
            a.iter_mut()
                .zip(&batches)
                .map(|(m, xs)| (m, xs.as_slice()))
                .collect(),
        );
        let (mut b, _) = fleet(4, 86);
        let flats: Vec<Vec<u32>> = batches
            .iter()
            .map(|xs| xs.iter().flatten().copied().collect())
            .collect();
        let got = mvm_parallel_batch_strided(
            b.iter_mut()
                .zip(&flats)
                .map(|(m, xs)| (m, xs.as_slice()))
                .collect(),
            cfg.rows,
        );
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            for i in 0..g.len() {
                assert_eq!(g.y_mac(i), w.y_mac(i));
                assert_eq!(g.energy(i), w.energy(i));
                assert_eq!(g.active_rows(i), w.active_rows(i));
            }
        }
    }
}

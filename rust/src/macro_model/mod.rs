//! The complete spiking CIM macro (DESIGN.md S8).

pub mod cim_macro;

pub use cim_macro::{CimMacro, MacroResult};

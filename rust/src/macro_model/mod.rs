//! The complete spiking CIM macro (DESIGN.md S8).

use crate::energy::EnergyBreakdown;

pub mod cim_macro;

pub use cim_macro::{CimMacro, MacroResult};

/// Fan a tiled layer's input slices across its shard macros (ti-major
/// order) and regroup the outputs as `partials[ti][tj]`, plus summed
/// energy and the critical-path (max) latency. This is the single
/// implementation of the (ti, tj) convention that both `snn::infer` and
/// `fabric::chip` rely on for bit-identity — do not fork it.
pub fn mvm_tiled(
    macros: &mut [CimMacro],
    xparts: &[Vec<u32>],
    row_tiles: usize,
    col_tiles: usize,
) -> (Vec<Vec<Vec<f64>>>, EnergyBreakdown, f64) {
    assert_eq!(macros.len(), row_tiles * col_tiles, "shard count");
    let jobs: Vec<(&mut CimMacro, &[u32])> = macros
        .iter_mut()
        .enumerate()
        .map(|(sidx, m)| (m, xparts[sidx / col_tiles].as_slice()))
        .collect();
    let results = mvm_parallel(jobs);
    let mut energy = EnergyBreakdown::default();
    let mut latency = 0.0f64; // tiles are physically concurrent
    let mut partials: Vec<Vec<Vec<f64>>> = (0..row_tiles)
        .map(|_| Vec::with_capacity(col_tiles))
        .collect();
    for (sidx, r) in results.into_iter().enumerate() {
        energy.add(&r.energy);
        latency = latency.max(r.latency_ns);
        partials[sidx / col_tiles].push(r.y_mac);
    }
    (partials, energy, latency)
}

/// Run many independent tile MVMs on scoped worker threads (DESIGN.md
/// S15): `jobs` pairs each programmed macro with its input slice.
///
/// Results come back in job order, bit-identical to a serial loop — each
/// macro is its own deterministic simulator, so parallelism changes only
/// wall-clock (row tiles were always *modeled* as latency-parallel; this
/// makes the implementation match the model). Jobs are chunked over at
/// most `available_parallelism` threads so spawn overhead stays
/// negligible at small tile counts.
pub fn mvm_parallel(jobs: Vec<(&mut CimMacro, &[u32])>) -> Vec<MacroResult> {
    let n = jobs.len();
    if n <= 1 {
        return jobs.into_iter().map(|(m, x)| m.mvm(x)).collect();
    }
    let threads = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(4)
        .min(n);
    let chunk = n.div_ceil(threads);
    let mut rest = jobs;
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(threads);
        while !rest.is_empty() {
            let tail = rest.split_off(chunk.min(rest.len()));
            let batch = std::mem::replace(&mut rest, tail);
            handles.push(s.spawn(move || {
                batch
                    .into_iter()
                    .map(|(m, x)| m.mvm(x))
                    .collect::<Vec<_>>()
            }));
        }
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("tile worker panicked"))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MacroConfig;
    use crate::util::rng::Rng;

    /// Deterministically build `n` programmed macros and `n` inputs.
    fn fleet(n: usize, seed: u64) -> (Vec<CimMacro>, Vec<Vec<u32>>) {
        let cfg = MacroConfig::default();
        let mut rng = Rng::new(seed);
        let macros = (0..n)
            .map(|_| {
                let mut m = CimMacro::new(cfg.clone());
                let codes: Vec<u8> = (0..cfg.rows * cfg.cols)
                    .map(|_| rng.below(4) as u8)
                    .collect();
                m.program(&codes);
                m
            })
            .collect();
        let xs = (0..n)
            .map(|_| (0..cfg.rows).map(|_| rng.below(256) as u32).collect())
            .collect();
        (macros, xs)
    }

    #[test]
    fn parallel_tiles_match_serial_bit_for_bit() {
        let (mut serial, xs) = fleet(5, 77);
        let want: Vec<MacroResult> = serial
            .iter_mut()
            .zip(&xs)
            .map(|(m, x)| m.mvm(x))
            .collect();

        let (mut par, _) = fleet(5, 77); // identical rebuild
        let jobs: Vec<(&mut CimMacro, &[u32])> = par
            .iter_mut()
            .zip(&xs)
            .map(|(m, x)| (m, x.as_slice()))
            .collect();
        let got = mvm_parallel(jobs);

        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.y_mac, w.y_mac);
            assert_eq!(g.events, w.events);
            assert_eq!(g.energy, w.energy);
        }
    }

    #[test]
    fn single_job_runs_inline() {
        let (mut ms, xs) = fleet(1, 78);
        let jobs = vec![(&mut ms[0], xs[0].as_slice())];
        let got = mvm_parallel(jobs);
        assert_eq!(got.len(), 1);
        assert!(got[0].y_mac.iter().any(|&v| v > 0.0));
    }
}

//! The complete spiking CIM macro (DESIGN.md S8).

use crate::energy::EnergyBreakdown;

pub mod cim_macro;

pub use cim_macro::{CimMacro, MacroResult, MvmBatch};

/// Fan a tiled layer's input slices across its shard macros (ti-major
/// order) and regroup the outputs as `partials[ti][tj]`, plus summed
/// energy and the critical-path (max) latency. A single-item run of
/// [`mvm_tiled_batch`] — the one implementation of the (ti, tj)
/// convention that both `snn::infer` and `fabric::chip` rely on for
/// bit-identity; do not fork it.
pub fn mvm_tiled(
    macros: &mut [CimMacro],
    xparts: &[Vec<u32>],
    row_tiles: usize,
    col_tiles: usize,
) -> (Vec<Vec<Vec<f64>>>, EnergyBreakdown, f64) {
    let xbatch: Vec<Vec<Vec<u32>>> =
        xparts.iter().map(|p| vec![p.clone()]).collect();
    mvm_tiled_batch(macros, &xbatch, row_tiles, col_tiles)
        .pop()
        .expect("one item")
}

/// Run many independent tile MVMs on scoped worker threads (DESIGN.md
/// S15): `jobs` pairs each programmed macro with its input slice.
///
/// Results come back in job order, bit-identical to a serial loop — each
/// macro is its own deterministic simulator, so parallelism changes only
/// wall-clock (row tiles were always *modeled* as latency-parallel; this
/// makes the implementation match the model). Jobs are chunked over at
/// most `available_parallelism` threads so spawn overhead stays
/// negligible at small tile counts.
pub fn mvm_parallel(jobs: Vec<(&mut CimMacro, &[u32])>) -> Vec<MacroResult> {
    par_map_jobs(jobs, |(m, x)| m.mvm(x))
}

/// Batched [`mvm_parallel`] (DESIGN.md S16): each job pairs a programmed
/// macro with the *whole request batch* for that macro, so every worker
/// thread streams its weight matrix once per batch instead of once per
/// input. Ledgers come back in job order, bit-identical to calling
/// [`CimMacro::mvm_batch`] serially per job.
pub fn mvm_parallel_batch(
    jobs: Vec<(&mut CimMacro, &[Vec<u32>])>,
) -> Vec<MvmBatch> {
    par_map_jobs(jobs, |(m, xs)| m.mvm_batch(xs))
}

/// The shared scoped-thread fan-out behind [`mvm_parallel`] and
/// [`mvm_parallel_batch`]: chunk `jobs` over at most
/// `available_parallelism` threads (spawn overhead stays negligible at
/// small tile counts) and return results in job order.
fn par_map_jobs<T: Send, R: Send>(
    jobs: Vec<T>,
    f: impl Fn(T) -> R + Sync,
) -> Vec<R> {
    let n = jobs.len();
    if n <= 1 {
        return jobs.into_iter().map(f).collect();
    }
    let threads = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(4)
        .min(n);
    let chunk = n.div_ceil(threads);
    let mut rest = jobs;
    let f = &f;
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(threads);
        while !rest.is_empty() {
            let tail = rest.split_off(chunk.min(rest.len()));
            let batch = std::mem::replace(&mut rest, tail);
            handles.push(
                s.spawn(move || batch.into_iter().map(f).collect::<Vec<_>>()),
            );
        }
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("tile worker panicked"))
            .collect()
    })
}

/// Batched [`mvm_tiled`] (DESIGN.md S16): `xparts[ti]` carries the whole
/// minibatch of row-tile `ti`'s input slices. Returns one
/// `(partials, energy, latency)` triple per batch item, each bit-identical
/// to what `mvm_tiled` would produce for that item alone — the (ti, tj)
/// convention and the shard accumulation order are unchanged.
pub fn mvm_tiled_batch(
    macros: &mut [CimMacro],
    xparts: &[Vec<Vec<u32>>],
    row_tiles: usize,
    col_tiles: usize,
) -> Vec<(Vec<Vec<Vec<f64>>>, EnergyBreakdown, f64)> {
    assert_eq!(macros.len(), row_tiles * col_tiles, "shard count");
    assert_eq!(xparts.len(), row_tiles, "one slice batch per row tile");
    let batch = xparts.first().map_or(0, |p| p.len());
    assert!(
        xparts.iter().all(|p| p.len() == batch),
        "ragged batch across row tiles"
    );
    let jobs: Vec<(&mut CimMacro, &[Vec<u32>])> = macros
        .iter_mut()
        .enumerate()
        .map(|(sidx, m)| (m, xparts[sidx / col_tiles].as_slice()))
        .collect();
    let ledgers = mvm_parallel_batch(jobs);
    (0..batch)
        .map(|b| {
            let mut energy = EnergyBreakdown::default();
            let mut latency = 0.0f64; // tiles are physically concurrent
            let mut partials: Vec<Vec<Vec<f64>>> = (0..row_tiles)
                .map(|_| Vec::with_capacity(col_tiles))
                .collect();
            for (sidx, l) in ledgers.iter().enumerate() {
                energy.add(l.energy(b));
                latency = latency.max(l.latency_ns(b));
                partials[sidx / col_tiles].push(l.y_mac(b).to_vec());
            }
            (partials, energy, latency)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MacroConfig;
    use crate::util::rng::Rng;

    /// Deterministically build `n` programmed macros and `n` inputs.
    fn fleet(n: usize, seed: u64) -> (Vec<CimMacro>, Vec<Vec<u32>>) {
        let cfg = MacroConfig::default();
        let mut rng = Rng::new(seed);
        let macros = (0..n)
            .map(|_| {
                let mut m = CimMacro::new(cfg.clone());
                let codes: Vec<u8> = (0..cfg.rows * cfg.cols)
                    .map(|_| rng.below(4) as u8)
                    .collect();
                m.program(&codes);
                m
            })
            .collect();
        let xs = (0..n)
            .map(|_| (0..cfg.rows).map(|_| rng.below(256) as u32).collect())
            .collect();
        (macros, xs)
    }

    #[test]
    fn parallel_tiles_match_serial_bit_for_bit() {
        let (mut serial, xs) = fleet(5, 77);
        let want: Vec<MacroResult> = serial
            .iter_mut()
            .zip(&xs)
            .map(|(m, x)| m.mvm(x))
            .collect();

        let (mut par, _) = fleet(5, 77); // identical rebuild
        let jobs: Vec<(&mut CimMacro, &[u32])> = par
            .iter_mut()
            .zip(&xs)
            .map(|(m, x)| (m, x.as_slice()))
            .collect();
        let got = mvm_parallel(jobs);

        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.y_mac, w.y_mac);
            assert_eq!(g.events, w.events);
            assert_eq!(g.energy, w.energy);
        }
    }

    #[test]
    fn single_job_runs_inline() {
        let (mut ms, xs) = fleet(1, 78);
        let jobs = vec![(&mut ms[0], xs[0].as_slice())];
        let got = mvm_parallel(jobs);
        assert_eq!(got.len(), 1);
        assert!(got[0].y_mac.iter().any(|&v| v > 0.0));
    }

    #[test]
    fn parallel_batch_matches_serial_batches_bit_for_bit() {
        let cfg = MacroConfig::default();
        let mut rng = Rng::new(81);
        let batches: Vec<Vec<Vec<u32>>> = (0..5)
            .map(|_| {
                (0..4)
                    .map(|_| {
                        (0..cfg.rows).map(|_| rng.below(256) as u32).collect()
                    })
                    .collect()
            })
            .collect();
        let (mut serial, _) = fleet(5, 80);
        let want: Vec<MvmBatch> = serial
            .iter_mut()
            .zip(&batches)
            .map(|(m, xs)| m.mvm_batch(xs))
            .collect();

        let (mut par, _) = fleet(5, 80); // identical rebuild
        let jobs: Vec<(&mut CimMacro, &[Vec<u32>])> = par
            .iter_mut()
            .zip(&batches)
            .map(|(m, xs)| (m, xs.as_slice()))
            .collect();
        let got = mvm_parallel_batch(jobs);

        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.len(), w.len());
            for b in 0..g.len() {
                assert_eq!(g.y_mac(b), w.y_mac(b));
                assert_eq!(g.events(b), w.events(b));
                assert_eq!(g.energy(b), w.energy(b));
            }
        }
    }

    #[test]
    fn tiled_batch_matches_per_item_tiled_bit_for_bit() {
        // 2×2 tile grid over a 256×256 matrix, batch of 5 inputs.
        let cfg = MacroConfig::default();
        let mut rng = Rng::new(83);
        let (rt, ct) = (2usize, 2usize);
        let mk_fleet = |seed: u64| {
            let mut rng = Rng::new(seed);
            (0..rt * ct)
                .map(|_| {
                    let mut m = CimMacro::new(cfg.clone());
                    let codes: Vec<u8> = (0..cfg.rows * cfg.cols)
                        .map(|_| rng.below(4) as u8)
                        .collect();
                    m.program(&codes);
                    m
                })
                .collect::<Vec<_>>()
        };
        let batch = 5usize;
        // xparts[ti][b]: per-row-tile slice batches.
        let xparts: Vec<Vec<Vec<u32>>> = (0..rt)
            .map(|_| {
                (0..batch)
                    .map(|_| {
                        (0..cfg.rows).map(|_| rng.below(256) as u32).collect()
                    })
                    .collect()
            })
            .collect();

        let mut serial = mk_fleet(84);
        let want: Vec<_> = (0..batch)
            .map(|b| {
                let parts: Vec<Vec<u32>> =
                    (0..rt).map(|ti| xparts[ti][b].clone()).collect();
                mvm_tiled(&mut serial, &parts, rt, ct)
            })
            .collect();

        let mut batched = mk_fleet(84);
        let got = mvm_tiled_batch(&mut batched, &xparts, rt, ct);

        assert_eq!(got.len(), batch);
        for ((gp, ge, gl), (wp, we, wl)) in got.iter().zip(&want) {
            assert_eq!(gp, wp, "partials diverge");
            assert_eq!(ge, we, "energy diverges");
            assert_eq!(gl, wl, "latency diverges");
        }
    }
}

//! The full CIM macro (DESIGN.md S8): 128×128 crossbar + per-row SMUs +
//! per-column OSGs, operated event-driven exactly as §III describes:
//!
//! 1. dual-spike inputs open per-row Event_flag windows (SMU),
//! 2. the global Event_flag (OR tree) gates the charge phase,
//! 3. its falling edge triggers every column's OSG comparison phase,
//! 4. output spike pairs encode the MACs (Eq. 2).
//!
//! The simulation processes the spike events through the real
//! `EventQueue`/`FlagTree` machinery and solves the analog physics
//! piecewise-analytically between events — no time-stepping on the hot
//! path. Energy is accounted from the same event windows.

use crate::circuit::components::{Comparator, CurrentMirror};
use crate::circuit::osg::{self, OsgParams};
use crate::coding::DualSpikeCodec;
use crate::config::MacroConfig;
use crate::energy::{mvm_energy, EnergyBreakdown, EnergyParams, MvmActivity};
use crate::event::{EventKind, EventQueue, FlagTree};
use crate::util::rng::Rng;
use crate::xbar::Crossbar;

/// Result of one macro MVM.
#[derive(Debug, Clone)]
pub struct MacroResult {
    /// Output inter-spike intervals per column (ns).
    pub t_out_ns: Vec<f64>,
    /// Decoded MAC values per column: Σ x_i·G_ij (LSB·µS), from T_out.
    pub y_mac: Vec<f64>,
    /// V_charge per column at flag drop (V).
    pub v_charge: Vec<f64>,
    /// End-to-end latency: charge phase + slowest column conversion (ns).
    pub latency_ns: f64,
    /// Energy breakdown of this op.
    pub energy: EnergyBreakdown,
    /// Spike events processed.
    pub events: u64,
}

/// One spiking CIM macro instance.
pub struct CimMacro {
    pub cfg: MacroConfig,
    pub xbar: Crossbar,
    pub codec: DualSpikeCodec,
    pub energy_params: EnergyParams,
    osg_params: Vec<OsgParams>,
    /// All mirror gains are exactly 1.0·k (enables the linear fast path).
    uniform_gain: bool,
    /// RNG for cycle-to-cycle noise (None = noiseless reads).
    rng: Option<Rng>,
    // --- reusable buffers (hot path, no per-op allocation) ---
    g_on: Vec<f64>,
    charge: Vec<f64>,
    queue: EventQueue,
}

impl CimMacro {
    /// Ideal macro (no variation, ideal circuits).
    pub fn new(cfg: MacroConfig) -> Self {
        let xbar = Crossbar::new(&cfg);
        Self::from_parts(cfg, xbar, None)
    }

    /// Macro with frozen device variation and per-column circuit
    /// non-idealities sampled from `cfg.nonideal` using `seed`.
    pub fn with_nonidealities(cfg: MacroConfig, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let xbar = Crossbar::with_variation(&cfg, &mut rng);
        Self::from_parts(cfg, xbar, Some(rng))
    }

    fn from_parts(cfg: MacroConfig, xbar: Crossbar, mut rng: Option<Rng>) -> Self {
        let ni = cfg.nonideal;
        let osg_params: Vec<OsgParams> = (0..cfg.cols)
            .map(|_| {
                let (gain_err, offset) = match rng.as_mut() {
                    Some(r) if ni.mirror_gain_sigma > 0.0
                        || ni.comparator_offset_v > 0.0 =>
                    {
                        (
                            1.0 + r.normal_ms(0.0, ni.mirror_gain_sigma),
                            r.normal_ms(0.0, ni.comparator_offset_v),
                        )
                    }
                    _ => (1.0, 0.0),
                };
                OsgParams {
                    mirror: CurrentMirror {
                        k: cfg.k_mirror,
                        gain_err,
                        r_out_mohm: f64::INFINITY,
                    },
                    comparator: Comparator {
                        offset_v: offset,
                        delay_ns: ni.comparator_delay_ns,
                    },
                    c_rt_ff: cfg.c_rt_ff,
                    c_com_ff: cfg.c_com_ff,
                    i_com_ua: cfg.i_com_ua,
                    v_read: cfg.v_read(),
                    clamp_cm_enabled: ni.clamp_current_mirror,
                }
            })
            .collect();
        let codec = DualSpikeCodec::new(cfg.t_bit_ns, cfg.input_bits);
        let cols = cfg.cols;
        let rows = cfg.rows;
        let uniform_gain =
            osg_params.iter().all(|p| p.mirror.gain_err == 1.0);
        CimMacro {
            cfg,
            xbar,
            codec,
            energy_params: EnergyParams::default(),
            osg_params,
            uniform_gain,
            rng,
            g_on: vec![0.0; cols],
            charge: vec![0.0; cols],
            queue: EventQueue::with_capacity(2 * rows + 2),
        }
    }

    /// Program weights (row-major 2-bit codes).
    pub fn program(&mut self, codes: &[u8]) {
        self.xbar.program_codes(codes);
    }

    /// Sensing gain α of this macro's OSGs (Eq. 2).
    pub fn alpha(&self) -> f64 {
        self.cfg.alpha()
    }

    /// Event-driven MVM: `x` is one digital input per row (8-bit).
    ///
    /// Drives the spike events through the queue + flag tree, integrates
    /// the charge per column piecewise-analytically, runs every OSG's
    /// compare phase at the global flag drop, and accounts energy.
    pub fn mvm(&mut self, x: &[u32]) -> MacroResult {
        let rows = self.cfg.rows;
        let cols = self.cfg.cols;
        assert_eq!(x.len(), rows, "input length");
        let droop_mode = !self.cfg.nonideal.clamp_current_mirror;
        let v_read = self.cfg.v_read();

        // --- encode inputs into event windows ---
        let mut windows_ns = vec![0.0f64; rows];
        let mut active_rows = 0usize;
        for (r, &xv) in x.iter().enumerate() {
            let pair = self.codec.encode(xv, 0.0);
            if pair.dt_ns > 0.0 {
                windows_ns[r] = pair.dt_ns;
                active_rows += 1;
            }
        }

        // Per-row conductance rows are cached in the crossbar. Cycle-to-
        // cycle read noise is sampled once per row *read* (correlated
        // across the row, as a read-pulse amplitude error) and the same
        // factor is removed at the row's fall event so charge integration
        // stays consistent.
        let sigma_c2c = self.cfg.nonideal.sigma_r_c2c;

        self.g_on.iter_mut().for_each(|g| *g = 0.0);
        self.charge.iter_mut().for_each(|c| *c = 0.0);
        let mut col_charge_nsus = vec![0.0f64; cols];

        let mut t_prev = 0.0f64;
        let mut t_drop = 0.0f64;
        let mut events: u64 = 0;

        // Fast path (§Perf, EXPERIMENTS.md): with the clamp+current-mirror
        // and no per-read noise / gain mismatch, the charge integral is a
        // plain weighted row sum — identical math, evaluated row-major
        // (cache-friendly, auto-vectorized) instead of event-by-event.
        // Every non-ideality falls back to the general event loop below.
        let fast =
            !droop_mode && sigma_c2c == 0.0 && self.uniform_gain;

        if active_rows == 0 {
            // All-zero input: no events, no charge (fully event-driven —
            // the array never turns on).
        } else if fast {
            for (r, &w) in windows_ns.iter().enumerate() {
                if w == 0.0 {
                    continue;
                }
                t_drop = t_drop.max(w);
                let grow = r * cols;
                let gs = &self.xbar.conductances()[grow..grow + cols];
                for (q, &g) in col_charge_nsus.iter_mut().zip(gs) {
                    *q += w * g;
                }
            }
            let scale = self.cfg.k_mirror * v_read / self.cfg.c_rt_ff;
            for (c, &q) in self.charge.iter_mut().zip(&col_charge_nsus) {
                *c = scale * q;
            }
            events = 2 * active_rows as u64;
        } else {
            // --- general event-driven loop (any non-ideality) ---
            self.queue.reset();
            let mut flags = FlagTree::new(rows);
            let mut row_factor = vec![1.0f64; rows];
            for (r, &w) in windows_ns.iter().enumerate() {
                if w > 0.0 {
                    self.queue
                        .push(0.0, EventKind::RowRise { row: r as u32 });
                    self.queue
                        .push(w, EventKind::RowFall { row: r as u32 });
                }
            }
            while let Some(ev) = self.queue.pop() {
                events += 1;
                let dt = ev.t_ns - t_prev;
                if dt > 0.0 {
                    // advance analog state over [t_prev, ev.t]
                    if droop_mode {
                        for c in 0..cols {
                            let g = self.g_on[c];
                            if g > 0.0 {
                                let tau = self.cfg.c_rt_ff / g;
                                self.charge[c] = v_read
                                    + (self.charge[c] - v_read)
                                        * (-dt / tau).exp();
                                col_charge_nsus[c] += g * dt;
                            }
                        }
                    } else {
                        let k = self.cfg.k_mirror;
                        for c in 0..cols {
                            let g = self.g_on[c];
                            if g > 0.0 {
                                let gain = self.osg_params[c].mirror.gain_err;
                                self.charge[c] += k * gain * v_read * g * dt
                                    / self.cfg.c_rt_ff;
                                col_charge_nsus[c] += g * dt;
                            }
                        }
                    }
                    t_prev = ev.t_ns;
                }
                match ev.kind {
                    EventKind::RowRise { row } => {
                        let r = row as usize;
                        flags.assert_row(r, ev.t_ns);
                        if sigma_c2c > 0.0 {
                            let rng = self.rng.get_or_insert_with(|| Rng::new(0));
                            row_factor[r] = 1.0
                                / (1.0 + rng.normal_ms(0.0, sigma_c2c)).max(0.5);
                        }
                        let f = row_factor[r];
                        let grow = r * cols;
                        let gs = &self.xbar.conductances()[grow..grow + cols];
                        for (c, &g) in gs.iter().enumerate() {
                            self.g_on[c] += g * f;
                        }
                    }
                    EventKind::RowFall { row } => {
                        let r = row as usize;
                        let global_dropped = flags.deassert_row(r, ev.t_ns);
                        let f = row_factor[r];
                        let grow = r * cols;
                        let gs = &self.xbar.conductances()[grow..grow + cols];
                        for (c, &g) in gs.iter().enumerate() {
                            self.g_on[c] -= g * f;
                        }
                        if global_dropped {
                            t_drop = ev.t_ns;
                        }
                    }
                    _ => unreachable!("only row events scheduled"),
                }
            }
            // Numerical hygiene: g_on returns to ~0 after all falls.
            debug_assert!(self.g_on.iter().all(|g| g.abs() < 1e-9));
        }

        // --- OSG compare phase (triggered by the global flag drop) ---
        let mut t_out_ns = Vec::with_capacity(cols);
        let mut v_charge = Vec::with_capacity(cols);
        let mut y_mac = Vec::with_capacity(cols);
        let alpha = self.cfg.alpha();
        let mut max_t_out = 0.0f64;
        for c in 0..cols {
            let v = self.charge[c];
            let t = osg::compare_phase(&self.osg_params[c], v);
            max_t_out = max_t_out.max(t);
            t_out_ns.push(t);
            v_charge.push(v);
            y_mac.push(self.codec.decode_mac(t, alpha));
        }
        events += cols as u64; // compare-fire events

        let activity = MvmActivity {
            row_windows_ns: windows_ns,
            col_charge_nsus,
            v_charge: v_charge.clone(),
            t_out_ns: t_out_ns.clone(),
            t_charge_ns: t_drop,
            events,
        };
        let energy = mvm_energy(&self.cfg, &self.energy_params, &activity);

        MacroResult {
            t_out_ns,
            y_mac,
            v_charge,
            latency_ns: t_drop + max_t_out,
            energy,
            events,
        }
    }

    /// The exact digital oracle for this macro's programmed weights.
    pub fn ideal_mvm(&self, x: &[u32]) -> Vec<f64> {
        self.xbar.ideal_mvm(x)
    }

    /// Bit-serial MVM (§IV-B extension, `coding::bitserial`): run one
    /// analog pass per input chunk and recombine digitally. Shorter
    /// charge windows per pass (lower V_charge ceiling) for `passes`×
    /// more conversions. Returns (combined MACs, summed result).
    pub fn mvm_bitserial(
        &mut self,
        x: &[u32],
        plan: crate::coding::BitSerialPlan,
    ) -> (Vec<f64>, MacroResult) {
        assert_eq!(plan.total_bits, self.cfg.input_bits);
        let chunks = plan.split_vector(x);
        let mut pass_macs = Vec::with_capacity(chunks.len());
        let mut agg: Option<MacroResult> = None;
        for chunk in &chunks {
            let r = self.mvm(chunk);
            pass_macs.push(r.y_mac.clone());
            agg = Some(match agg {
                None => r,
                Some(mut a) => {
                    a.energy.add(&r.energy);
                    a.latency_ns += r.latency_ns; // passes are sequential
                    a.events += r.events;
                    for (va, vb) in a.v_charge.iter_mut().zip(&r.v_charge) {
                        *va = va.max(*vb); // report worst-case headroom
                    }
                    a
                }
            });
        }
        let combined = plan.combine(&pass_macs);
        let mut result = agg.expect("at least one pass");
        result.y_mac = combined.clone();
        (combined, result)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NonIdeality;

    fn macro_with_codes(seed: u64) -> (CimMacro, Vec<u8>) {
        let cfg = MacroConfig::default();
        let mut m = CimMacro::new(cfg);
        let mut rng = Rng::new(seed);
        let codes: Vec<u8> =
            (0..128 * 128).map(|_| rng.below(4) as u8).collect();
        m.program(&codes);
        (m, codes)
    }

    #[test]
    fn ideal_macro_is_bit_true_vs_oracle() {
        let (mut m, _) = macro_with_codes(1);
        let mut rng = Rng::new(2);
        let x: Vec<u32> = (0..128).map(|_| rng.below(256) as u32).collect();
        let got = m.mvm(&x);
        let want = m.ideal_mvm(&x);
        for (g, w) in got.y_mac.iter().zip(&want) {
            assert!((g - w).abs() < 1e-6, "{g} vs {w}");
        }
    }

    #[test]
    fn t_out_satisfies_eq2() {
        let (mut m, _) = macro_with_codes(3);
        let mut rng = Rng::new(4);
        let x: Vec<u32> = (0..128).map(|_| rng.below(256) as u32).collect();
        let r = m.mvm(&x);
        let alpha = m.alpha();
        let want = m.ideal_mvm(&x);
        for (c, &t) in r.t_out_ns.iter().enumerate() {
            let mac_nsus = want[c] * m.cfg.t_bit_ns; // Σ T_in·G
            assert!((t - alpha * mac_nsus).abs() < 1e-9);
        }
    }

    #[test]
    fn zero_input_consumes_no_array_energy() {
        let (mut m, _) = macro_with_codes(5);
        let r = m.mvm(&vec![0u32; 128]);
        assert_eq!(r.energy.array_fj, 0.0);
        assert_eq!(r.energy.smu_fj, 0.0);
        assert!(r.y_mac.iter().all(|&y| y == 0.0));
        assert_eq!(r.latency_ns, 0.0);
    }

    #[test]
    fn latency_is_window_plus_compare() {
        let (mut m, _) = macro_with_codes(7);
        let mut x = vec![0u32; 128];
        x[5] = 255; // single active row, window = 51 ns
        let r = m.mvm(&x);
        assert!(r.latency_ns > 51.0);
        let max_t_out = r.t_out_ns.iter().cloned().fold(0.0, f64::max);
        assert!((r.latency_ns - (51.0 + max_t_out)).abs() < 1e-9);
    }

    #[test]
    fn event_count_matches_activity() {
        let (mut m, _) = macro_with_codes(9);
        let mut x = vec![0u32; 128];
        for i in 0..10 {
            x[i] = 100 + i as u32;
        }
        let r = m.mvm(&x);
        // 10 rises + 10 falls + 128 compare fires.
        assert_eq!(r.events, 10 + 10 + 128);
    }

    #[test]
    fn energy_close_to_nominal_model_on_uniform_input() {
        let (mut m, _) = macro_with_codes(11);
        let mut rng = Rng::new(12);
        let x: Vec<u32> = (0..128).map(|_| rng.below(256) as u32).collect();
        let r = m.mvm(&x);
        // Monte-Carlo op ≈ closed-form nominal activity within 10 %.
        let nominal = crate::energy::mvm_energy(
            &m.cfg,
            &m.energy_params,
            &crate::energy::nominal_activity(&m.cfg),
        );
        let ratio = r.energy.total_fj() / nominal.total_fj();
        assert!((0.9..1.1).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn droop_mode_underestimates_macs() {
        let cfg = MacroConfig {
            nonideal: NonIdeality {
                clamp_current_mirror: false,
                ..NonIdeality::ideal()
            },
            ..MacroConfig::default()
        };
        let mut m = CimMacro::new(cfg);
        let mut rng = Rng::new(13);
        let codes: Vec<u8> =
            (0..128 * 128).map(|_| rng.below(4) as u8).collect();
        m.program(&codes);
        let x: Vec<u32> = vec![200; 128];
        let r = m.mvm(&x);
        let want = m.ideal_mvm(&x);
        for (g, w) in r.y_mac.iter().zip(&want) {
            assert!(*g < *w * 0.95, "droop should lose charge: {g} vs {w}");
        }
    }

    #[test]
    fn nonidealities_perturb_but_dont_break() {
        let cfg = MacroConfig {
            nonideal: NonIdeality::realistic(),
            ..MacroConfig::default()
        };
        let mut m = CimMacro::with_nonidealities(cfg, 99);
        let mut rng = Rng::new(14);
        let codes: Vec<u8> =
            (0..128 * 128).map(|_| rng.below(4) as u8).collect();
        m.program(&codes);
        let x: Vec<u32> = (0..128).map(|_| rng.below(256) as u32).collect();
        let r = m.mvm(&x);
        let want = m.ideal_mvm(&x);
        for (g, w) in r.y_mac.iter().zip(&want) {
            let rel = (g - w).abs() / w.max(1.0);
            assert!(rel < 0.10, "rel err {rel}");
        }
    }

    #[test]
    fn bitserial_matches_full_precision_exactly() {
        let (mut m, _) = macro_with_codes(17);
        let mut rng = Rng::new(18);
        let x: Vec<u32> = (0..128).map(|_| rng.below(256) as u32).collect();
        let full = m.mvm(&x).y_mac;
        for bits_per_pass in [2u32, 4, 8] {
            let plan = crate::coding::BitSerialPlan::new(8, bits_per_pass);
            let (combined, _) = m.mvm_bitserial(&x, plan);
            for (a, b) in combined.iter().zip(&full) {
                assert!((a - b).abs() < 1e-6, "{bits_per_pass}b: {a} vs {b}");
            }
        }
    }

    #[test]
    fn bitserial_lowers_v_charge_ceiling() {
        // The point of bit-serial: each pass's V_charge stays far below
        // the full-window worst case → headroom for larger arrays.
        let (mut m, _) = macro_with_codes(19);
        let x: Vec<u32> = vec![255; 128]; // worst case
        let full = m.mvm(&x);
        let v_full = full.v_charge.iter().cloned().fold(0.0, f64::max);
        let plan = crate::coding::BitSerialPlan::new(8, 4);
        let (_, serial) = m.mvm_bitserial(&x, plan);
        let v_serial = serial.v_charge.iter().cloned().fold(0.0, f64::max);
        assert!(v_serial < v_full / 10.0, "{v_serial} vs {v_full}");
    }

    #[test]
    fn bitserial_energy_structure() {
        let (mut m, _) = macro_with_codes(20);
        let mut rng = Rng::new(21);
        let x: Vec<u32> = (0..128).map(|_| 16 + rng.below(240) as u32).collect();
        let full = m.mvm(&x);
        let plan = crate::coding::BitSerialPlan::new(8, 4);
        let (_, serial) = m.mvm_bitserial(&x, plan);
        // 2× the conversions → 2× the events and control energy…
        assert!(serial.events > full.events);
        assert!(serial.energy.control_fj > 1.8 * full.energy.control_fj);
        // …while the analog charge *drops*: the MSB pass applies a
        // 2^4-shorter window and the scale-up happens digitally, so the
        // array integrates chunk sums, not the full value.
        assert!(serial.energy.array_fj < full.energy.array_fj);
        // Window-proportional biases (mirror/comparator/clamp) shrink with
        // the shorter per-pass windows — the model finding documented in
        // DESIGN.md §7: bit-serial trades control energy + error
        // amplification (next test) for bias energy.
        assert!(serial.energy.osg_fj < full.energy.osg_fj);
    }

    #[test]
    fn bitserial_amplifies_absolute_analog_errors() {
        // Under realistic comparator offset, the MSB pass's absolute
        // error is scaled by 2^bits_per_pass at recombination — the
        // physical reason the paper uses one full-precision window.
        let cfg = MacroConfig {
            nonideal: NonIdeality {
                comparator_offset_v: 0.002,
                ..NonIdeality::ideal()
            },
            ..MacroConfig::default()
        };
        let mut m = CimMacro::with_nonidealities(cfg, 31);
        let mut rng = Rng::new(32);
        let codes: Vec<u8> =
            (0..128 * 128).map(|_| rng.below(4) as u8).collect();
        m.program(&codes);
        let x: Vec<u32> = (0..128).map(|_| rng.below(256) as u32).collect();
        let want = m.ideal_mvm(&x);
        let err = |y: &[f64]| -> f64 {
            y.iter()
                .zip(&want)
                .map(|(a, b)| (a - b).abs())
                .sum::<f64>()
                / y.len() as f64
        };
        let full_err = err(&m.mvm(&x).y_mac);
        let (serial_y, _) =
            m.mvm_bitserial(&x, crate::coding::BitSerialPlan::new(8, 4));
        let serial_err = err(&serial_y);
        assert!(
            serial_err > 5.0 * full_err,
            "serial {serial_err} vs full {full_err}"
        );
    }

    #[test]
    fn repeated_ops_reuse_buffers_deterministically() {
        let (mut m, _) = macro_with_codes(15);
        let x: Vec<u32> = (0..128).map(|i| (i * 2) as u32).collect();
        let a = m.mvm(&x);
        let b = m.mvm(&x);
        assert_eq!(a.y_mac, b.y_mac);
        assert_eq!(a.events, b.events);
    }
}

//! The full CIM macro (DESIGN.md S8): 128×128 crossbar + per-row SMUs +
//! per-column OSGs, operated event-driven exactly as §III describes:
//!
//! 1. dual-spike inputs open per-row Event_flag windows (SMU),
//! 2. the global Event_flag (OR tree) gates the charge phase,
//! 3. its falling edge triggers every column's OSG comparison phase,
//! 4. output spike pairs encode the MACs (Eq. 2).
//!
//! The simulation processes the spike events through the real
//! `EventQueue`/`FlagTree` machinery and solves the analog physics
//! piecewise-analytically between events — no time-stepping on the hot
//! path. Energy is accounted from the same event windows.

use crate::circuit::components::{Comparator, CurrentMirror};
use crate::circuit::osg::{self, OsgParams};
use crate::coding::DualSpikeCodec;
use crate::config::{MacroConfig, MvmEngine};
use crate::energy::{mvm_energy, ActivityView, EnergyBreakdown, EnergyParams};
use crate::event::{EventKind, EventQueue, FlagTree};
use crate::obs::{self, TraceKind};
use crate::util::rng::Rng;
use crate::xbar::Crossbar;

/// Which charge-integration path a batch actually ran (DESIGN.md S17).
/// `MvmEngine` is the *request*; this records the resolution — any
/// non-ideality resolves to `General` (the event loop is the only path
/// that models it).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineUsed {
    /// The general event loop (queue + flag tree), or an empty batch.
    #[default]
    General,
    /// Row-outer weight-stationary batch streaming (DESIGN.md S16).
    Dense,
    /// Item-outer active-row event-list streaming (bit-identical to
    /// `Dense`).
    EventList,
    /// Integer level-plane accumulation (exact vs
    /// [`CimMacro::ideal_mvm_quantized`]).
    Quantized,
}

/// Result of one macro MVM.
#[derive(Debug, Clone)]
pub struct MacroResult {
    /// Output inter-spike intervals per column (ns).
    pub t_out_ns: Vec<f64>,
    /// Decoded MAC values per column: Σ x_i·G_ij (LSB·µS), from T_out.
    pub y_mac: Vec<f64>,
    /// V_charge per column at flag drop (V).
    pub v_charge: Vec<f64>,
    /// End-to-end latency: charge phase + slowest column conversion (ns).
    pub latency_ns: f64,
    /// Energy breakdown of this op.
    pub energy: EnergyBreakdown,
    /// Spike events processed.
    pub events: u64,
}

/// Batch ledger (DESIGN.md S16): the results of one [`CimMacro::mvm_batch`]
/// call, stored as flat `[batch × cols]` row-major arrays so the engine
/// writes every item into pre-sized memory — zero per-op heap allocation
/// once the ledger has warmed up (reuse it via
/// [`CimMacro::mvm_batch_into`]).
///
/// Item `b`'s numbers are bit-identical to what the `b`-th of B serial
/// [`CimMacro::mvm`] calls would return (asserted in
/// `rust/tests/batch_identity.rs`).
#[derive(Debug, Clone, Default)]
pub struct MvmBatch {
    batch: usize,
    cols: usize,
    rows: usize,
    t_out_ns: Vec<f64>,
    v_charge: Vec<f64>,
    y_mac: Vec<f64>,
    latency_ns: Vec<f64>,
    t_charge_ns: Vec<f64>,
    events: Vec<u64>,
    energy: Vec<EnergyBreakdown>,
    /// Rows with a nonzero window per item (DESIGN.md S17) — the
    /// event-driven occupancy the fabric and server metrics surface.
    active_rows: Vec<u32>,
    /// Which engine integrated the charge for this batch.
    engine: EngineUsed,
}

impl MvmBatch {
    /// Number of items in the ledger.
    pub fn len(&self) -> usize {
        self.batch
    }

    pub fn is_empty(&self) -> bool {
        self.batch == 0
    }

    /// Columns per item.
    pub fn cols(&self) -> usize {
        self.cols
    }

    fn item(&self, b: usize) -> std::ops::Range<usize> {
        assert!(b < self.batch, "batch index {b} of {}", self.batch);
        b * self.cols..(b + 1) * self.cols
    }

    /// Item `b`'s decoded MACs per column.
    pub fn y_mac(&self, b: usize) -> &[f64] {
        &self.y_mac[self.item(b)]
    }

    /// Item `b`'s output intervals per column (ns).
    pub fn t_out_ns(&self, b: usize) -> &[f64] {
        &self.t_out_ns[self.item(b)]
    }

    /// Item `b`'s V_charge per column (V).
    pub fn v_charge(&self, b: usize) -> &[f64] {
        &self.v_charge[self.item(b)]
    }

    /// Item `b`'s end-to-end latency (ns).
    pub fn latency_ns(&self, b: usize) -> f64 {
        self.latency_ns[b]
    }

    /// Item `b`'s charge-phase length (global flag high time, ns).
    pub fn t_charge_ns(&self, b: usize) -> f64 {
        self.t_charge_ns[b]
    }

    /// Item `b`'s processed event count.
    pub fn events(&self, b: usize) -> u64 {
        self.events[b]
    }

    /// Item `b`'s energy breakdown.
    pub fn energy(&self, b: usize) -> &EnergyBreakdown {
        &self.energy[b]
    }

    /// Summed energy over the whole batch.
    pub fn total_energy(&self) -> EnergyBreakdown {
        let mut e = EnergyBreakdown::default();
        for item in &self.energy {
            e.add(item);
        }
        e
    }

    /// Total events over the whole batch.
    pub fn total_events(&self) -> u64 {
        self.events.iter().sum()
    }

    /// Item `b`'s count of rows with a nonzero input window.
    pub fn active_rows(&self, b: usize) -> u32 {
        self.active_rows[b]
    }

    /// Total active rows across the batch (DESIGN.md S17).
    pub fn total_active_rows(&self) -> u64 {
        self.active_rows.iter().map(|&a| a as u64).sum()
    }

    /// Row slots offered to the batch: `batch × rows`. With
    /// [`total_active_rows`](Self::total_active_rows) this gives the
    /// batch's input occupancy.
    pub fn row_slots(&self) -> u64 {
        (self.batch * self.rows) as u64
    }

    /// Fraction of row slots that carried a spike pair (0 for an empty
    /// batch).
    pub fn occupancy(&self) -> f64 {
        if self.batch == 0 || self.rows == 0 {
            0.0
        } else {
            self.total_active_rows() as f64 / self.row_slots() as f64
        }
    }

    /// Which engine integrated this batch's charge.
    pub fn engine_used(&self) -> EngineUsed {
        self.engine
    }

    /// Clone item `b` out as a standalone [`MacroResult`].
    pub fn result(&self, b: usize) -> MacroResult {
        MacroResult {
            t_out_ns: self.t_out_ns(b).to_vec(),
            y_mac: self.y_mac(b).to_vec(),
            v_charge: self.v_charge(b).to_vec(),
            latency_ns: self.latency_ns[b],
            energy: self.energy[b],
            events: self.events[b],
        }
    }

    /// Consume a single-item ledger as a [`MacroResult`] (moves the
    /// column vectors out — no copy).
    fn into_single(mut self) -> MacroResult {
        assert_eq!(self.batch, 1, "into_single needs exactly one item");
        MacroResult {
            t_out_ns: self.t_out_ns,
            y_mac: self.y_mac,
            v_charge: self.v_charge,
            latency_ns: self.latency_ns[0],
            energy: self.energy.pop().expect("one item"),
            events: self.events[0],
        }
    }

    /// Re-size for `batch` items of `cols` columns, reusing capacity.
    fn reset(&mut self, batch: usize, cols: usize, rows: usize) {
        self.batch = batch;
        self.cols = cols;
        self.rows = rows;
        self.engine = EngineUsed::General;
        self.active_rows.clear();
        self.active_rows.resize(batch, 0);
        let flat = batch * cols;
        self.t_out_ns.clear();
        self.t_out_ns.resize(flat, 0.0);
        self.v_charge.clear();
        self.v_charge.resize(flat, 0.0);
        self.y_mac.clear();
        self.y_mac.resize(flat, 0.0);
        self.latency_ns.clear();
        self.latency_ns.resize(batch, 0.0);
        self.t_charge_ns.clear();
        self.t_charge_ns.resize(batch, 0.0);
        self.events.clear();
        self.events.resize(batch, 0);
        self.energy.clear();
    }
}

/// Reusable per-op working memory (DESIGN.md S16): sized on first use,
/// then stable across every subsequent `mvm`/`mvm_batch` call — the
/// general event path allocates nothing per op.
struct MvmScratch {
    /// Encoded input windows, `[batch × rows]` flat.
    windows_ns: Vec<f64>,
    /// Clamped integer inputs (LSBs), `[batch × rows]` flat — the
    /// quantized engine accumulates these, not the f64 windows.
    x_lsb: Vec<u32>,
    /// Per-column charge integrals Σ T·G, `[batch × cols]` flat.
    col_charge_nsus: Vec<f64>,
    /// Active (non-zero) rows per item.
    active_rows: Vec<u32>,
    /// Compressed event lists (DESIGN.md S17): the active row indices
    /// of every item, concatenated in encode order.
    active_list: Vec<u32>,
    /// Item `b`'s event list is `active_list[active_start[b]..
    /// active_start[b + 1]]` (len `batch + 1`).
    active_start: Vec<usize>,
    /// Packed per-level spike counts, `[batch × cols]` flat: four
    /// 16-bit lanes per u64, lane `l` = Σ x over rows coded `l`
    /// (quantized engine only; sized lazily).
    level_acc: Vec<u64>,
    /// Per-column exact MACs of the current item (quantized engine).
    mac_us: Vec<f64>,
    /// Max window per item (= flag-drop time on the fast path).
    w_max: Vec<f64>,
    /// Event_flag OR-tree, reset per item on the general path.
    flags: FlagTree,
    /// Per-row c2c read-noise factors; entries are (re)written at each
    /// row-rise before being read, so no per-item reset is needed.
    row_factor: Vec<f64>,
}

/// One spiking CIM macro instance.
pub struct CimMacro {
    pub cfg: MacroConfig,
    pub xbar: Crossbar,
    pub codec: DualSpikeCodec,
    pub energy_params: EnergyParams,
    osg_params: Vec<OsgParams>,
    /// All mirror gains are exactly 1.0·k (enables the linear fast path).
    uniform_gain: bool,
    /// Requested fast-path engine (DESIGN.md S17); resolved per batch.
    engine: MvmEngine,
    /// RNG for cycle-to-cycle noise (None = noiseless reads).
    rng: Option<Rng>,
    // --- reusable buffers (hot path, no per-op allocation) ---
    g_on: Vec<f64>,
    charge: Vec<f64>,
    queue: EventQueue,
    scratch: MvmScratch,
}

impl CimMacro {
    /// Ideal macro (no variation, ideal circuits).
    pub fn new(cfg: MacroConfig) -> Self {
        let xbar = Crossbar::new(&cfg);
        Self::from_parts(cfg, xbar, None)
    }

    /// Macro with frozen device variation and per-column circuit
    /// non-idealities sampled from `cfg.nonideal` using `seed`.
    pub fn with_nonidealities(cfg: MacroConfig, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let xbar = Crossbar::with_variation(&cfg, &mut rng);
        Self::from_parts(cfg, xbar, Some(rng))
    }

    fn from_parts(cfg: MacroConfig, xbar: Crossbar, mut rng: Option<Rng>) -> Self {
        let ni = cfg.nonideal;
        let osg_params: Vec<OsgParams> = (0..cfg.cols)
            .map(|_| {
                let (gain_err, offset) = match rng.as_mut() {
                    Some(r) if ni.mirror_gain_sigma > 0.0
                        || ni.comparator_offset_v > 0.0 =>
                    {
                        (
                            1.0 + r.normal_ms(0.0, ni.mirror_gain_sigma),
                            r.normal_ms(0.0, ni.comparator_offset_v),
                        )
                    }
                    _ => (1.0, 0.0),
                };
                OsgParams {
                    mirror: CurrentMirror {
                        k: cfg.k_mirror,
                        gain_err,
                        r_out_mohm: f64::INFINITY,
                    },
                    comparator: Comparator {
                        offset_v: offset,
                        delay_ns: ni.comparator_delay_ns,
                    },
                    c_rt_ff: cfg.c_rt_ff,
                    c_com_ff: cfg.c_com_ff,
                    i_com_ua: cfg.i_com_ua,
                    v_read: cfg.v_read(),
                    clamp_cm_enabled: ni.clamp_current_mirror,
                }
            })
            .collect();
        let codec = DualSpikeCodec::new(cfg.t_bit_ns, cfg.input_bits);
        let cols = cfg.cols;
        let rows = cfg.rows;
        let uniform_gain =
            osg_params.iter().all(|p| p.mirror.gain_err == 1.0);
        let engine = cfg.engine;
        CimMacro {
            cfg,
            xbar,
            codec,
            energy_params: EnergyParams::default(),
            osg_params,
            uniform_gain,
            engine,
            rng,
            g_on: vec![0.0; cols],
            charge: vec![0.0; cols],
            queue: EventQueue::with_capacity(2 * rows + 2),
            scratch: MvmScratch {
                windows_ns: Vec::new(),
                x_lsb: Vec::new(),
                col_charge_nsus: Vec::new(),
                active_rows: Vec::new(),
                active_list: Vec::new(),
                active_start: Vec::new(),
                level_acc: Vec::new(),
                mac_us: vec![0.0; cols],
                w_max: Vec::new(),
                flags: FlagTree::new(rows),
                row_factor: vec![1.0; rows],
            },
        }
    }

    /// Request a fast-path engine (DESIGN.md S17). Benches force
    /// `Dense`/`EventList`/`Quantized` to compare them; `Auto` (the
    /// default, also settable via `MacroConfig::engine`) picks per
    /// batch.
    pub fn set_engine(&mut self, engine: MvmEngine) {
        self.engine = engine;
    }

    /// The currently requested fast-path engine.
    pub fn engine(&self) -> MvmEngine {
        self.engine
    }

    /// Program weights (row-major 2-bit codes).
    pub fn program(&mut self, codes: &[u8]) {
        self.xbar.program_codes(codes);
    }

    /// Golden-code snapshot of the programmed array (row-major) — the
    /// scrubber's reference copy (DESIGN.md S19).
    pub fn golden_codes(&self) -> Vec<u8> {
        self.xbar.read_codes()
    }

    /// Verify-and-rewrite this macro's array against a golden snapshot
    /// (DESIGN.md S19): forwards to [`Crossbar::scrub_to`], charging
    /// SOT write energy and wear through `device::write`.
    pub fn scrub_against(
        &mut self,
        golden: &[u8],
        wp: &crate::device::SotWriteParams,
        rng: &mut Rng,
    ) -> crate::device::ScrubOutcome {
        self.xbar.scrub_to(golden, wp, rng)
    }

    /// Sensing gain α of this macro's OSGs (Eq. 2).
    pub fn alpha(&self) -> f64 {
        self.cfg.alpha()
    }

    /// Event-driven MVM: `x` is one digital input per row (8-bit).
    ///
    /// Drives the spike events through the queue + flag tree, integrates
    /// the charge per column piecewise-analytically, runs every OSG's
    /// compare phase at the global flag drop, and accounts energy. A
    /// single-item run of the batch engine (DESIGN.md S16).
    pub fn mvm(&mut self, x: &[u32]) -> MacroResult {
        self.begin_batch(1);
        self.encode_item(0, x);
        let mut out = MvmBatch::default();
        self.run_batch(1, &mut out);
        out.into_single()
    }

    /// Batched event-driven MVM (DESIGN.md S16): encodes all B inputs up
    /// front, then — on the fast path — streams each conductance row
    /// slice once across the whole batch (one pass over the weight
    /// matrix instead of B), or runs the general event loop per input
    /// against the preallocated scratch. Bit-identical to B serial
    /// [`mvm`](Self::mvm) calls in the same order, including the c2c
    /// noise RNG stream (asserted in `rust/tests/batch_identity.rs`).
    pub fn mvm_batch(&mut self, xs: &[Vec<u32>]) -> MvmBatch {
        let mut out = MvmBatch::default();
        self.mvm_batch_into(xs, &mut out);
        out
    }

    /// [`mvm_batch`](Self::mvm_batch) into a caller-held ledger: after
    /// the first call at a given batch size, the whole op is
    /// allocation-free (scratch and ledger both reuse their capacity).
    pub fn mvm_batch_into(&mut self, xs: &[Vec<u32>], out: &mut MvmBatch) {
        self.begin_batch(xs.len());
        for (b, x) in xs.iter().enumerate() {
            self.encode_item(b, x);
        }
        self.run_batch(xs.len(), out);
    }

    /// Binary-spike fast path (DESIGN.md S18): `active` lists the rows
    /// that carry a unit spike this timestep — sorted ascending, no
    /// duplicates, every index `< rows`. Each listed row's window is
    /// exactly one T_bit (the dual-spike encoding of the value 1), so
    /// the per-row codec encode is skipped entirely: the event list IS
    /// the encoded input. Bitwise identical to [`mvm`](Self::mvm) on
    /// the equivalent 0/1 vector — same scratch contents, same engine
    /// resolution, same RNG stream — asserted across densities and
    /// engines in `rust/tests/stream_e2e.rs`.
    pub fn mvm_events(&mut self, active: &[u32]) -> MacroResult {
        self.begin_batch(1);
        self.encode_event_item(0, active);
        let mut out = MvmBatch::default();
        self.run_batch(1, &mut out);
        out.into_single()
    }

    /// Batched [`mvm_events`](Self::mvm_events): one sorted active-row
    /// list per timestep/item.
    pub fn mvm_events_batch(&mut self, lists: &[Vec<u32>]) -> MvmBatch {
        let mut out = MvmBatch::default();
        self.mvm_events_batch_into(lists, &mut out);
        out
    }

    /// [`mvm_events_batch`](Self::mvm_events_batch) into a caller-held
    /// ledger (allocation-free steady state, like
    /// [`mvm_batch_into`](Self::mvm_batch_into)).
    pub fn mvm_events_batch_into(
        &mut self,
        lists: &[Vec<u32>],
        out: &mut MvmBatch,
    ) {
        self.begin_batch(lists.len());
        for (b, ev) in lists.iter().enumerate() {
            self.encode_event_item(b, ev);
        }
        self.run_batch(lists.len(), out);
    }

    /// Flat batch input (DESIGN.md S17): `xs` is `batch` inputs of
    /// `in_dim` values each, concatenated row-major — callers that
    /// collect requests (server workers, fabric stages) feed one
    /// reusable flat buffer instead of allocating a `Vec<Vec<u32>>`
    /// per batch. Bit-identical to [`mvm_batch`](Self::mvm_batch) on
    /// the same values; the slice-of-vecs entry remains as a thin
    /// wrapper for callers that already hold that shape.
    pub fn mvm_batch_strided(&mut self, xs: &[u32], in_dim: usize) -> MvmBatch {
        let mut out = MvmBatch::default();
        self.mvm_batch_strided_into(xs, in_dim, &mut out);
        out
    }

    /// [`mvm_batch_strided`](Self::mvm_batch_strided) into a
    /// caller-held ledger (the fully allocation-free steady state).
    pub fn mvm_batch_strided_into(
        &mut self,
        xs: &[u32],
        in_dim: usize,
        out: &mut MvmBatch,
    ) {
        assert_eq!(in_dim, self.cfg.rows, "strided input dim must be rows");
        assert_eq!(xs.len() % in_dim, 0, "ragged flat batch");
        let batch = xs.len() / in_dim;
        self.begin_batch(batch);
        for b in 0..batch {
            self.encode_item(b, &xs[b * in_dim..(b + 1) * in_dim]);
        }
        self.run_batch(batch, out);
    }

    /// Size the scratch for `batch` items and zero the accumulators.
    fn begin_batch(&mut self, batch: usize) {
        let rows = self.cfg.rows;
        let cols = self.cfg.cols;
        let s = &mut self.scratch;
        s.windows_ns.clear();
        s.windows_ns.resize(batch * rows, 0.0);
        s.x_lsb.clear();
        s.x_lsb.resize(batch * rows, 0);
        s.col_charge_nsus.clear();
        s.col_charge_nsus.resize(batch * cols, 0.0);
        s.active_rows.clear();
        s.active_rows.resize(batch, 0);
        s.active_list.clear();
        s.active_start.clear();
        s.active_start.push(0);
        s.w_max.clear();
        s.w_max.resize(batch, 0.0);
    }

    /// Encode item `b`'s inputs into its scratch window slice and
    /// append its compressed active-row event list (DESIGN.md S17).
    /// Items must be encoded in order after [`begin_batch`].
    fn encode_item(&mut self, b: usize, x: &[u32]) {
        let rows = self.cfg.rows;
        assert_eq!(x.len(), rows, "input length");
        debug_assert_eq!(self.scratch.active_start.len(), b + 1, "encode order");
        let base = b * rows;
        let w = &mut self.scratch.windows_ns[base..base + rows];
        let xq = &mut self.scratch.x_lsb[base..base + rows];
        let mut active = 0u32;
        let mut w_max = 0.0f64;
        for (r, &xv) in x.iter().enumerate() {
            let pair = self.codec.encode(xv, 0.0);
            if pair.dt_ns > 0.0 {
                w[r] = pair.dt_ns;
                xq[r] = xv.min(self.codec.max_value());
                self.scratch.active_list.push(r as u32);
                active += 1;
                w_max = w_max.max(pair.dt_ns);
            }
        }
        self.scratch.active_rows[b] = active;
        self.scratch.w_max[b] = w_max;
        self.scratch.active_start.push(self.scratch.active_list.len());
    }

    /// Encode item `b` from a sorted binary-spike event list
    /// (DESIGN.md S18): every listed row gets a one-T_bit window and a
    /// 1-LSB quantized input — exactly what [`encode_item`] writes for
    /// the equivalent 0/1 vector, without touching the silent rows or
    /// the per-row codec. Items must be encoded in order after
    /// [`begin_batch`].
    ///
    /// [`encode_item`]: Self::encode_item
    fn encode_event_item(&mut self, b: usize, active: &[u32]) {
        let rows = self.cfg.rows;
        debug_assert_eq!(self.scratch.active_start.len(), b + 1, "encode order");
        let t_bit = self.codec.t_bit_ns;
        let base = b * rows;
        let mut prev: i64 = -1;
        for &r in active {
            assert!((r as usize) < rows, "event row {r} of {rows}");
            assert!(
                i64::from(r) > prev,
                "event list must be sorted ascending without duplicates"
            );
            prev = i64::from(r);
            self.scratch.windows_ns[base + r as usize] = t_bit;
            self.scratch.x_lsb[base + r as usize] = 1;
            self.scratch.active_list.push(r);
        }
        self.scratch.active_rows[b] = active.len() as u32;
        self.scratch.w_max[b] = if active.is_empty() { 0.0 } else { t_bit };
        self.scratch.active_start.push(self.scratch.active_list.len());
    }

    /// Run the encoded batch: charge integration (one of the linear
    /// fast-path engines, DESIGN.md S16/S17, or the per-item event
    /// loop), compare phase, and energy accounting, all into the
    /// ledger.
    fn run_batch(&mut self, batch: usize, out: &mut MvmBatch) {
        // S20 span: the whole charge+compare batch; payload records the
        // total active rows and which engine resolved (EngineUsed order).
        let mut span = obs::Span::begin(TraceKind::MacroMvm, 0);
        let rows = self.cfg.rows;
        let cols = self.cfg.cols;
        let droop_mode = !self.cfg.nonideal.clamp_current_mirror;
        let v_read = self.cfg.v_read();
        let sigma_c2c = self.cfg.nonideal.sigma_r_c2c;
        out.reset(batch, cols, rows);
        out.active_rows.copy_from_slice(&self.scratch.active_rows);

        // Linear fast path (§Perf, EXPERIMENTS.md): with the clamp +
        // current-mirror and no per-read noise / gain mismatch, the
        // charge integral is a plain weighted row sum — identical math,
        // evaluated by one of three engines (DESIGN.md S17). Every
        // non-ideality falls back to the general event loop below.
        let fast = !droop_mode && sigma_c2c == 0.0 && self.uniform_gain;
        // The quantized level-plane engine is additionally lossless
        // only when every cell sits exactly at its level target and the
        // packed 16-bit per-level counts cannot overflow.
        let quant_ok = fast
            && self.xbar.uniform_levels()
            && (rows as u64) * (self.codec.max_value() as u64)
                <= u16::MAX as u64;
        let total_active = self.scratch.active_list.len();
        let resolved = match self.engine {
            MvmEngine::Quantized => {
                assert!(
                    quant_ok,
                    "quantized engine forced but ineligible: it needs \
                     ideal circuits (clamp+mirror, no c2c noise, no gain \
                     mismatch), exact level conductances (no device \
                     variation), and rows x max_input < 2^16 headroom"
                );
                EngineUsed::Quantized
            }
            _ if !fast => EngineUsed::General,
            MvmEngine::Dense => EngineUsed::Dense,
            MvmEngine::EventList => EngineUsed::EventList,
            MvmEngine::Auto => {
                if quant_ok {
                    EngineUsed::Quantized
                } else if 4 * total_active <= batch * rows {
                    // Sparse batch: the event lists skip the silent
                    // 3/4+ of the rows; dense streaming wins once most
                    // rows are occupied anyway (bit-identical either
                    // way, so this is purely a wall-clock knob).
                    EngineUsed::EventList
                } else {
                    EngineUsed::Dense
                }
            }
        };
        out.engine = resolved;
        span.note(
            total_active as f64,
            match resolved {
                EngineUsed::General => 0.0,
                EngineUsed::Dense => 1.0,
                EngineUsed::EventList => 2.0,
                EngineUsed::Quantized => 3.0,
            },
        );

        match resolved {
            EngineUsed::Dense => {
                // Weight-stationary batch streaming (DESIGN.md S16):
                // each 1-row conductance slice is read once and applied
                // to every item's accumulator while still L1-hot —
                // per-item accumulation order over rows is unchanged,
                // so the sums are bit-identical to serial.
                let cond = self.xbar.conductances();
                let windows = &self.scratch.windows_ns;
                let qs = &mut self.scratch.col_charge_nsus;
                for r in 0..rows {
                    let gs = &cond[r * cols..(r + 1) * cols];
                    for b in 0..batch {
                        let w = windows[b * rows + r];
                        if w == 0.0 {
                            continue;
                        }
                        let q = &mut qs[b * cols..(b + 1) * cols];
                        for (qc, &g) in q.iter_mut().zip(gs) {
                            *qc += w * g;
                        }
                    }
                }
            }
            EngineUsed::EventList => {
                // Active-row event lists (DESIGN.md S17): walk each
                // item's compressed list — silent rows are never
                // visited. Per item the accumulation still runs over
                // rows ascending, and a skipped row would have added
                // exactly +0.0 to every column, so the result is
                // bitwise identical to the dense stream.
                let cond = self.xbar.conductances();
                let windows = &self.scratch.windows_ns;
                let list = &self.scratch.active_list;
                let starts = &self.scratch.active_start;
                let qs = &mut self.scratch.col_charge_nsus;
                for b in 0..batch {
                    let q = &mut qs[b * cols..(b + 1) * cols];
                    for &r in &list[starts[b]..starts[b + 1]] {
                        let r = r as usize;
                        let w = windows[b * rows + r];
                        let gs = &cond[r * cols..(r + 1) * cols];
                        for (qc, &g) in q.iter_mut().zip(gs) {
                            *qc += w * g;
                        }
                    }
                }
            }
            EngineUsed::Quantized => {
                // Level-plane decomposition (DESIGN.md S17): with every
                // cell exactly at its level target, the charge integral
                // per column is t_bit · Σ_level g_level · S_level with
                // S_level an *integer* spike count. The inner loop is
                // an integer MAC over the 1-byte code matrix — the four
                // 16-bit per-level counts ride packed in one u64 per
                // column (headroom asserted above); the per-level f64
                // scales happen once per column at unpack time.
                let codes = self.xbar.codes();
                let xq = &self.scratch.x_lsb;
                let list = &self.scratch.active_list;
                let starts = &self.scratch.active_start;
                let acc = &mut self.scratch.level_acc;
                acc.clear();
                acc.resize(batch * cols, 0);
                for b in 0..batch {
                    let a = &mut acc[b * cols..(b + 1) * cols];
                    for &r in &list[starts[b]..starts[b + 1]] {
                        let r = r as usize;
                        let xv = xq[b * rows + r] as u64;
                        let crow = &codes[r * cols..(r + 1) * cols];
                        for (av, &code) in a.iter_mut().zip(crow) {
                            *av += xv << (16 * code as u32);
                        }
                    }
                }
            }
            EngineUsed::General => {} // per-item event loop below
        }

        let scale = self.cfg.k_mirror * v_read / self.cfg.c_rt_ff;
        let alpha = self.cfg.alpha();
        let t_bit = self.cfg.t_bit_ns;
        let lvl = self.xbar.levels();
        for b in 0..batch {
            let t_drop;
            let mut events;
            let quant_item = resolved == EngineUsed::Quantized
                && self.scratch.active_rows[b] > 0;
            if self.scratch.active_rows[b] == 0 {
                // All-zero input: no events, no charge (fully event-
                // driven — the array never turns on).
                t_drop = 0.0;
                events = 0;
                self.charge.iter_mut().for_each(|c| *c = 0.0);
            } else if quant_item {
                // Unpack the per-level counts: one deterministic f64
                // scale per level, in fixed level order — exactly the
                // integer oracle (`ideal_mvm_quantized`).
                t_drop = self.scratch.w_max[b];
                let qbase = b * cols;
                for c in 0..cols {
                    let a = self.scratch.level_acc[qbase + c];
                    let mac = lvl[0] * ((a & 0xFFFF) as f64)
                        + lvl[1] * (((a >> 16) & 0xFFFF) as f64)
                        + lvl[2] * (((a >> 32) & 0xFFFF) as f64)
                        + lvl[3] * ((a >> 48) as f64);
                    let q = mac * t_bit;
                    self.scratch.mac_us[c] = mac;
                    self.scratch.col_charge_nsus[qbase + c] = q;
                    self.charge[c] = scale * q;
                }
                events = 2 * self.scratch.active_rows[b] as u64;
            } else if matches!(
                resolved,
                EngineUsed::Dense | EngineUsed::EventList
            ) {
                t_drop = self.scratch.w_max[b];
                let q = &self.scratch.col_charge_nsus[b * cols..(b + 1) * cols];
                for (c, &qv) in self.charge.iter_mut().zip(q) {
                    *c = scale * qv;
                }
                events = 2 * self.scratch.active_rows[b] as u64;
            } else {
                let (td, ev) = self.run_general_item(b);
                t_drop = td;
                events = ev;
            }

            // --- OSG compare phase (triggered by the global flag drop) ---
            let base = b * cols;
            let mut max_t_out = 0.0f64;
            for c in 0..cols {
                let v = self.charge[c];
                let t = osg::compare_phase(&self.osg_params[c], v);
                max_t_out = max_t_out.max(t);
                out.t_out_ns[base + c] = t;
                out.v_charge[base + c] = v;
                // The quantized engine's decoded MAC *is* the exact
                // level-plane sum (the analog roundtrip would only
                // re-round it); the other engines decode T_out per
                // Eq. 2 as the hardware does.
                out.y_mac[base + c] = if quant_item {
                    self.scratch.mac_us[c]
                } else {
                    self.codec.decode_mac(t, alpha)
                };
            }
            events += cols as u64; // compare-fire events

            out.latency_ns[b] = t_drop + max_t_out;
            out.t_charge_ns[b] = t_drop;
            out.events[b] = events;
            let activity = ActivityView {
                row_windows_ns: &self.scratch.windows_ns
                    [b * rows..(b + 1) * rows],
                col_charge_nsus: &self.scratch.col_charge_nsus
                    [b * cols..(b + 1) * cols],
                v_charge: &out.v_charge[base..base + cols],
                t_out_ns: &out.t_out_ns[base..base + cols],
                t_charge_ns: t_drop,
                events,
            };
            out.energy
                .push(mvm_energy(&self.cfg, &self.energy_params, activity));
        }
    }

    /// General event-driven loop for item `b` (any non-ideality): drives
    /// the spike events through the queue + flag tree against reusable
    /// scratch. Returns (flag-drop time, events processed).
    ///
    /// Cycle-to-cycle read noise is sampled once per row *read*
    /// (correlated across the row, as a read-pulse amplitude error) and
    /// the same factor is removed at the row's fall event so charge
    /// integration stays consistent.
    fn run_general_item(&mut self, b: usize) -> (f64, u64) {
        let rows = self.cfg.rows;
        let cols = self.cfg.cols;
        let droop_mode = !self.cfg.nonideal.clamp_current_mirror;
        let v_read = self.cfg.v_read();
        let sigma_c2c = self.cfg.nonideal.sigma_r_c2c;
        let qbase = b * cols;

        self.g_on.iter_mut().for_each(|g| *g = 0.0);
        self.charge.iter_mut().for_each(|c| *c = 0.0);
        self.queue.reset();
        self.scratch.flags.reset();
        {
            let windows = &self.scratch.windows_ns[b * rows..(b + 1) * rows];
            for (r, &w) in windows.iter().enumerate() {
                if w > 0.0 {
                    self.queue
                        .push(0.0, EventKind::RowRise { row: r as u32 });
                    self.queue
                        .push(w, EventKind::RowFall { row: r as u32 });
                }
            }
        }
        let mut t_prev = 0.0f64;
        let mut t_drop = 0.0f64;
        let mut events: u64 = 0;
        while let Some(ev) = self.queue.pop() {
            events += 1;
            let dt = ev.t_ns - t_prev;
            if dt > 0.0 {
                // advance analog state over [t_prev, ev.t]
                if droop_mode {
                    for c in 0..cols {
                        let g = self.g_on[c];
                        if g > 0.0 {
                            let tau = self.cfg.c_rt_ff / g;
                            self.charge[c] = v_read
                                + (self.charge[c] - v_read)
                                    * (-dt / tau).exp();
                            self.scratch.col_charge_nsus[qbase + c] += g * dt;
                        }
                    }
                } else {
                    let k = self.cfg.k_mirror;
                    for c in 0..cols {
                        let g = self.g_on[c];
                        if g > 0.0 {
                            let gain = self.osg_params[c].mirror.gain_err;
                            self.charge[c] += k * gain * v_read * g * dt
                                / self.cfg.c_rt_ff;
                            self.scratch.col_charge_nsus[qbase + c] += g * dt;
                        }
                    }
                }
                t_prev = ev.t_ns;
            }
            match ev.kind {
                EventKind::RowRise { row } => {
                    let r = row as usize;
                    self.scratch.flags.assert_row(r, ev.t_ns);
                    if sigma_c2c > 0.0 {
                        let rng = self.rng.get_or_insert_with(|| Rng::new(0));
                        self.scratch.row_factor[r] = 1.0
                            / (1.0 + rng.normal_ms(0.0, sigma_c2c)).max(0.5);
                    }
                    let f = self.scratch.row_factor[r];
                    let grow = r * cols;
                    let gs = &self.xbar.conductances()[grow..grow + cols];
                    for (c, &g) in gs.iter().enumerate() {
                        self.g_on[c] += g * f;
                    }
                }
                EventKind::RowFall { row } => {
                    let r = row as usize;
                    let global_dropped =
                        self.scratch.flags.deassert_row(r, ev.t_ns);
                    let f = self.scratch.row_factor[r];
                    let grow = r * cols;
                    let gs = &self.xbar.conductances()[grow..grow + cols];
                    for (c, &g) in gs.iter().enumerate() {
                        self.g_on[c] -= g * f;
                    }
                    if global_dropped {
                        t_drop = ev.t_ns;
                    }
                }
                _ => unreachable!("only row events scheduled"),
            }
        }
        // Numerical hygiene: g_on returns to ~0 after all falls.
        debug_assert!(self.g_on.iter().all(|g| g.abs() < 1e-9));
        (t_drop, events)
    }

    /// The exact digital oracle for this macro's programmed weights.
    pub fn ideal_mvm(&self, x: &[u32]) -> Vec<f64> {
        self.xbar.ideal_mvm(x)
    }

    /// The integer level-plane oracle (DESIGN.md S17): per column,
    /// accumulate the *integer* spike count per conductance level
    /// (exact — integer addition is order-independent), then combine
    /// with one f64 multiply per level in fixed level order. The
    /// quantized engine's `y_mac` is asserted **bitwise equal** to this
    /// (same integers, same combination); it also agrees with
    /// [`ideal_mvm`](Self::ideal_mvm) to f64 rounding of the row-order
    /// sum. Inputs are clamped to the codec's max value, exactly as the
    /// SMU encoding saturates them.
    pub fn ideal_mvm_quantized(&self, x: &[u32]) -> Vec<f64> {
        let rows = self.cfg.rows;
        let cols = self.cfg.cols;
        assert_eq!(x.len(), rows);
        let codes = self.xbar.codes();
        let lvl = self.xbar.levels();
        let xmax = self.codec.max_value();
        let mut y = Vec::with_capacity(cols);
        for c in 0..cols {
            let mut counts = [0u64; 4];
            for (r, &xv) in x.iter().enumerate() {
                counts[codes[r * cols + c] as usize] +=
                    xv.min(xmax) as u64;
            }
            y.push(
                lvl[0] * (counts[0] as f64)
                    + lvl[1] * (counts[1] as f64)
                    + lvl[2] * (counts[2] as f64)
                    + lvl[3] * (counts[3] as f64),
            );
        }
        y
    }

    /// Bit-serial MVM (§IV-B extension, `coding::bitserial`): run one
    /// analog pass per input chunk and recombine digitally. Shorter
    /// charge windows per pass (lower V_charge ceiling) for `passes`×
    /// more conversions. Returns (combined MACs, summed result).
    pub fn mvm_bitserial(
        &mut self,
        x: &[u32],
        plan: crate::coding::BitSerialPlan,
    ) -> (Vec<f64>, MacroResult) {
        assert_eq!(plan.total_bits, self.cfg.input_bits);
        let chunks = plan.split_vector(x);
        let mut pass_macs = Vec::with_capacity(chunks.len());
        let mut agg: Option<MacroResult> = None;
        for chunk in &chunks {
            let r = self.mvm(chunk);
            pass_macs.push(r.y_mac.clone());
            agg = Some(match agg {
                None => r,
                Some(mut a) => {
                    a.energy.add(&r.energy);
                    a.latency_ns += r.latency_ns; // passes are sequential
                    a.events += r.events;
                    for (va, vb) in a.v_charge.iter_mut().zip(&r.v_charge) {
                        *va = va.max(*vb); // report worst-case headroom
                    }
                    a
                }
            });
        }
        let combined = plan.combine(&pass_macs);
        let mut result = agg.expect("at least one pass");
        result.y_mac = combined.clone();
        (combined, result)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::NonIdeality;

    fn macro_with_codes(seed: u64) -> (CimMacro, Vec<u8>) {
        let cfg = MacroConfig::default();
        let mut m = CimMacro::new(cfg);
        let mut rng = Rng::new(seed);
        let codes: Vec<u8> =
            (0..128 * 128).map(|_| rng.below(4) as u8).collect();
        m.program(&codes);
        (m, codes)
    }

    #[test]
    fn ideal_macro_is_bit_true_vs_oracle() {
        let (mut m, _) = macro_with_codes(1);
        let mut rng = Rng::new(2);
        let x: Vec<u32> = (0..128).map(|_| rng.below(256) as u32).collect();
        let got = m.mvm(&x);
        let want = m.ideal_mvm(&x);
        for (g, w) in got.y_mac.iter().zip(&want) {
            assert!((g - w).abs() < 1e-6, "{g} vs {w}");
        }
    }

    #[test]
    fn t_out_satisfies_eq2() {
        let (mut m, _) = macro_with_codes(3);
        let mut rng = Rng::new(4);
        let x: Vec<u32> = (0..128).map(|_| rng.below(256) as u32).collect();
        let r = m.mvm(&x);
        let alpha = m.alpha();
        let want = m.ideal_mvm(&x);
        for (c, &t) in r.t_out_ns.iter().enumerate() {
            let mac_nsus = want[c] * m.cfg.t_bit_ns; // Σ T_in·G
            assert!((t - alpha * mac_nsus).abs() < 1e-9);
        }
    }

    #[test]
    fn zero_input_consumes_no_array_energy() {
        let (mut m, _) = macro_with_codes(5);
        let r = m.mvm(&vec![0u32; 128]);
        assert_eq!(r.energy.array_fj, 0.0);
        assert_eq!(r.energy.smu_fj, 0.0);
        assert!(r.y_mac.iter().all(|&y| y == 0.0));
        assert_eq!(r.latency_ns, 0.0);
    }

    #[test]
    fn latency_is_window_plus_compare() {
        let (mut m, _) = macro_with_codes(7);
        let mut x = vec![0u32; 128];
        x[5] = 255; // single active row, window = 51 ns
        let r = m.mvm(&x);
        assert!(r.latency_ns > 51.0);
        let max_t_out = r.t_out_ns.iter().cloned().fold(0.0, f64::max);
        assert!((r.latency_ns - (51.0 + max_t_out)).abs() < 1e-9);
    }

    #[test]
    fn event_count_matches_activity() {
        let (mut m, _) = macro_with_codes(9);
        let mut x = vec![0u32; 128];
        for i in 0..10 {
            x[i] = 100 + i as u32;
        }
        let r = m.mvm(&x);
        // 10 rises + 10 falls + 128 compare fires.
        assert_eq!(r.events, 10 + 10 + 128);
    }

    #[test]
    fn energy_close_to_nominal_model_on_uniform_input() {
        let (mut m, _) = macro_with_codes(11);
        let mut rng = Rng::new(12);
        let x: Vec<u32> = (0..128).map(|_| rng.below(256) as u32).collect();
        let r = m.mvm(&x);
        // Monte-Carlo op ≈ closed-form nominal activity within 10 %.
        let nominal = crate::energy::mvm_energy(
            &m.cfg,
            &m.energy_params,
            &crate::energy::nominal_activity(&m.cfg),
        );
        let ratio = r.energy.total_fj() / nominal.total_fj();
        assert!((0.9..1.1).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn droop_mode_underestimates_macs() {
        let cfg = MacroConfig {
            nonideal: NonIdeality {
                clamp_current_mirror: false,
                ..NonIdeality::ideal()
            },
            ..MacroConfig::default()
        };
        let mut m = CimMacro::new(cfg);
        let mut rng = Rng::new(13);
        let codes: Vec<u8> =
            (0..128 * 128).map(|_| rng.below(4) as u8).collect();
        m.program(&codes);
        let x: Vec<u32> = vec![200; 128];
        let r = m.mvm(&x);
        let want = m.ideal_mvm(&x);
        for (g, w) in r.y_mac.iter().zip(&want) {
            assert!(*g < *w * 0.95, "droop should lose charge: {g} vs {w}");
        }
    }

    #[test]
    fn nonidealities_perturb_but_dont_break() {
        let cfg = MacroConfig {
            nonideal: NonIdeality::realistic(),
            ..MacroConfig::default()
        };
        let mut m = CimMacro::with_nonidealities(cfg, 99);
        let mut rng = Rng::new(14);
        let codes: Vec<u8> =
            (0..128 * 128).map(|_| rng.below(4) as u8).collect();
        m.program(&codes);
        let x: Vec<u32> = (0..128).map(|_| rng.below(256) as u32).collect();
        let r = m.mvm(&x);
        let want = m.ideal_mvm(&x);
        for (g, w) in r.y_mac.iter().zip(&want) {
            let rel = (g - w).abs() / w.max(1.0);
            assert!(rel < 0.10, "rel err {rel}");
        }
    }

    #[test]
    fn bitserial_matches_full_precision_exactly() {
        let (mut m, _) = macro_with_codes(17);
        let mut rng = Rng::new(18);
        let x: Vec<u32> = (0..128).map(|_| rng.below(256) as u32).collect();
        let full = m.mvm(&x).y_mac;
        for bits_per_pass in [2u32, 4, 8] {
            let plan = crate::coding::BitSerialPlan::new(8, bits_per_pass);
            let (combined, _) = m.mvm_bitserial(&x, plan);
            for (a, b) in combined.iter().zip(&full) {
                assert!((a - b).abs() < 1e-6, "{bits_per_pass}b: {a} vs {b}");
            }
        }
    }

    #[test]
    fn bitserial_lowers_v_charge_ceiling() {
        // The point of bit-serial: each pass's V_charge stays far below
        // the full-window worst case → headroom for larger arrays.
        let (mut m, _) = macro_with_codes(19);
        let x: Vec<u32> = vec![255; 128]; // worst case
        let full = m.mvm(&x);
        let v_full = full.v_charge.iter().cloned().fold(0.0, f64::max);
        let plan = crate::coding::BitSerialPlan::new(8, 4);
        let (_, serial) = m.mvm_bitserial(&x, plan);
        let v_serial = serial.v_charge.iter().cloned().fold(0.0, f64::max);
        assert!(v_serial < v_full / 10.0, "{v_serial} vs {v_full}");
    }

    #[test]
    fn bitserial_energy_structure() {
        let (mut m, _) = macro_with_codes(20);
        let mut rng = Rng::new(21);
        let x: Vec<u32> = (0..128).map(|_| 16 + rng.below(240) as u32).collect();
        let full = m.mvm(&x);
        let plan = crate::coding::BitSerialPlan::new(8, 4);
        let (_, serial) = m.mvm_bitserial(&x, plan);
        // 2× the conversions → 2× the events and control energy…
        assert!(serial.events > full.events);
        assert!(serial.energy.control_fj > 1.8 * full.energy.control_fj);
        // …while the analog charge *drops*: the MSB pass applies a
        // 2^4-shorter window and the scale-up happens digitally, so the
        // array integrates chunk sums, not the full value.
        assert!(serial.energy.array_fj < full.energy.array_fj);
        // Window-proportional biases (mirror/comparator/clamp) shrink with
        // the shorter per-pass windows — the model finding documented in
        // DESIGN.md §7: bit-serial trades control energy + error
        // amplification (next test) for bias energy.
        assert!(serial.energy.osg_fj < full.energy.osg_fj);
    }

    #[test]
    fn bitserial_amplifies_absolute_analog_errors() {
        // Under realistic comparator offset, the MSB pass's absolute
        // error is scaled by 2^bits_per_pass at recombination — the
        // physical reason the paper uses one full-precision window.
        let cfg = MacroConfig {
            nonideal: NonIdeality {
                comparator_offset_v: 0.002,
                ..NonIdeality::ideal()
            },
            ..MacroConfig::default()
        };
        let mut m = CimMacro::with_nonidealities(cfg, 31);
        let mut rng = Rng::new(32);
        let codes: Vec<u8> =
            (0..128 * 128).map(|_| rng.below(4) as u8).collect();
        m.program(&codes);
        let x: Vec<u32> = (0..128).map(|_| rng.below(256) as u32).collect();
        let want = m.ideal_mvm(&x);
        let err = |y: &[f64]| -> f64 {
            y.iter()
                .zip(&want)
                .map(|(a, b)| (a - b).abs())
                .sum::<f64>()
                / y.len() as f64
        };
        let full_err = err(&m.mvm(&x).y_mac);
        let (serial_y, _) =
            m.mvm_bitserial(&x, crate::coding::BitSerialPlan::new(8, 4));
        let serial_err = err(&serial_y);
        assert!(
            serial_err > 5.0 * full_err,
            "serial {serial_err} vs full {full_err}"
        );
    }

    #[test]
    fn repeated_ops_reuse_buffers_deterministically() {
        let (mut m, _) = macro_with_codes(15);
        let x: Vec<u32> = (0..128).map(|i| (i * 2) as u32).collect();
        let a = m.mvm(&x);
        let b = m.mvm(&x);
        assert_eq!(a.y_mac, b.y_mac);
        assert_eq!(a.events, b.events);
    }

    /// Run `xs` serially on one macro and batched on an identically
    /// built one; assert every ledger field is bitwise equal.
    fn assert_batch_bit_identical(
        mut serial: CimMacro,
        mut batched: CimMacro,
        xs: &[Vec<u32>],
    ) {
        let want: Vec<MacroResult> = xs.iter().map(|x| serial.mvm(x)).collect();
        let got = batched.mvm_batch(xs);
        assert_eq!(got.len(), xs.len());
        for (b, w) in want.iter().enumerate() {
            assert_eq!(got.y_mac(b), w.y_mac.as_slice(), "y_mac item {b}");
            assert_eq!(got.t_out_ns(b), w.t_out_ns.as_slice());
            assert_eq!(got.v_charge(b), w.v_charge.as_slice());
            assert_eq!(got.latency_ns(b), w.latency_ns);
            assert_eq!(got.events(b), w.events);
            assert_eq!(*got.energy(b), w.energy, "energy item {b}");
            assert_eq!(got.result(b).y_mac, w.y_mac);
        }
        assert_eq!(got.total_events(), want.iter().map(|r| r.events).sum());
    }

    fn sparse_inputs(seed: u64, density: f64, n: usize) -> Vec<Vec<u32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                (0..128)
                    .map(|_| {
                        if rng.f64() < density {
                            1 + rng.below(255) as u32
                        } else {
                            0
                        }
                    })
                    .collect()
            })
            .collect()
    }

    #[test]
    fn batch_bit_identical_across_sparsities_every_engine() {
        for engine in [
            MvmEngine::Auto,
            MvmEngine::Dense,
            MvmEngine::EventList,
            MvmEngine::Quantized,
        ] {
            for (seed, density) in
                [(21u64, 1.0), (22, 0.5), (23, 1.0 / 16.0), (24, 0.0)]
            {
                let (mut serial, _) = macro_with_codes(seed);
                let (mut batched, _) = macro_with_codes(seed);
                serial.set_engine(engine);
                batched.set_engine(engine);
                let xs = sparse_inputs(seed ^ 0xb, density, 7);
                assert_batch_bit_identical(serial, batched, &xs);
            }
        }
    }

    #[test]
    fn event_list_engine_bitwise_equals_dense_stream() {
        // The event-list acceptance bar (DESIGN.md S17): bitwise equal
        // to the PR-3 dense batched engine across densities, with
        // all-zero and all-dense items in the same batch.
        let (mut dense, _) = macro_with_codes(61);
        let (mut evlist, _) = macro_with_codes(61);
        dense.set_engine(MvmEngine::Dense);
        evlist.set_engine(MvmEngine::EventList);
        let mut xs: Vec<Vec<u32>> = Vec::new();
        let mut rng = Rng::new(62);
        for density in [0.0, 0.01, 0.1, 0.5, 1.0] {
            xs.push(
                (0..128)
                    .map(|_| {
                        if rng.f64() < density {
                            1 + rng.below(255) as u32
                        } else {
                            0
                        }
                    })
                    .collect(),
            );
        }
        xs.push(vec![255u32; 128]); // saturated all-dense item
        let want = dense.mvm_batch(&xs);
        let got = evlist.mvm_batch(&xs);
        assert_eq!(want.engine_used(), EngineUsed::Dense);
        assert_eq!(got.engine_used(), EngineUsed::EventList);
        for b in 0..xs.len() {
            assert_eq!(got.y_mac(b), want.y_mac(b), "item {b}");
            assert_eq!(got.t_out_ns(b), want.t_out_ns(b));
            assert_eq!(got.v_charge(b), want.v_charge(b));
            assert_eq!(got.latency_ns(b), want.latency_ns(b));
            assert_eq!(got.events(b), want.events(b));
            assert_eq!(got.energy(b), want.energy(b));
            assert_eq!(got.active_rows(b), want.active_rows(b));
        }
        // Serial calls agree too (a single-item batch per call).
        for x in &xs {
            let a = dense.mvm(x);
            let e = evlist.mvm(x);
            assert_eq!(a.y_mac, e.y_mac);
            assert_eq!(a.energy, e.energy);
        }
    }

    #[test]
    fn quantized_engine_exactly_matches_integer_oracle() {
        // Every code-alphabet size (1..=4 distinct levels in the
        // programmed matrix) and a density sweep: the quantized engine
        // must equal `ideal_mvm_quantized` bitwise and the row-order
        // `ideal_mvm` to f64 rounding.
        let cfg = MacroConfig::default();
        let mut rng = Rng::new(71);
        for alphabet in 1u8..=4 {
            let mut m = CimMacro::new(cfg.clone());
            m.set_engine(MvmEngine::Quantized);
            let codes: Vec<u8> = (0..cfg.rows * cfg.cols)
                .map(|_| rng.below(alphabet as u64) as u8)
                .collect();
            m.program(&codes);
            for density in [0.0, 0.05, 0.5, 1.0] {
                let x: Vec<u32> = (0..cfg.rows)
                    .map(|_| {
                        if rng.f64() < density {
                            1 + rng.below(255) as u32
                        } else {
                            0
                        }
                    })
                    .collect();
                let r = m.mvm(&x);
                let oracle = m.ideal_mvm_quantized(&x);
                assert_eq!(
                    r.y_mac, oracle,
                    "alphabet {alphabet}, density {density}"
                );
                let ideal = m.ideal_mvm(&x);
                for (g, w) in r.y_mac.iter().zip(&ideal) {
                    assert!((g - w).abs() < 1e-6, "{g} vs {w}");
                }
            }
        }
    }

    #[test]
    fn strided_flat_batch_bitwise_equals_slice_of_vecs() {
        let (mut a, _) = macro_with_codes(81);
        let (mut b, _) = macro_with_codes(81);
        let xs = sparse_inputs(82, 0.3, 6);
        let flat: Vec<u32> = xs.iter().flatten().copied().collect();
        let want = a.mvm_batch(&xs);
        let got = b.mvm_batch_strided(&flat, 128);
        assert_eq!(got.len(), want.len());
        for i in 0..xs.len() {
            assert_eq!(got.y_mac(i), want.y_mac(i));
            assert_eq!(got.t_out_ns(i), want.t_out_ns(i));
            assert_eq!(got.events(i), want.events(i));
            assert_eq!(got.energy(i), want.energy(i));
        }
        // Empty flat batch is a clean no-op.
        let empty = b.mvm_batch_strided(&[], 128);
        assert!(empty.is_empty());
    }

    #[test]
    fn auto_engine_selection_rules() {
        // Ideal macro: quantized is exact, so Auto picks it.
        let (mut ideal, _) = macro_with_codes(91);
        let dense_x = vec![200u32; 128];
        let r = ideal.mvm_batch(std::slice::from_ref(&dense_x));
        assert_eq!(r.engine_used(), EngineUsed::Quantized);

        // Device variation breaks the level planes: Auto falls back to
        // the bit-identity pair, chosen by occupancy.
        let cfg = MacroConfig {
            nonideal: NonIdeality {
                sigma_r_d2d: 0.02,
                ..NonIdeality::ideal()
            },
            ..MacroConfig::default()
        };
        let mut varied = CimMacro::with_nonidealities(cfg, 9);
        let mut rng = Rng::new(92);
        let codes: Vec<u8> =
            (0..128 * 128).map(|_| rng.below(4) as u8).collect();
        varied.program(&codes);
        let r = varied.mvm_batch(std::slice::from_ref(&dense_x));
        assert_eq!(r.engine_used(), EngineUsed::Dense);
        let mut sparse_x = vec![0u32; 128];
        sparse_x[7] = 40;
        let r = varied.mvm_batch(std::slice::from_ref(&sparse_x));
        assert_eq!(r.engine_used(), EngineUsed::EventList);

        // Any circuit non-ideality → the general event loop.
        let cfg = MacroConfig {
            nonideal: NonIdeality {
                sigma_r_c2c: 0.01,
                ..NonIdeality::ideal()
            },
            ..MacroConfig::default()
        };
        let mut noisy = CimMacro::with_nonidealities(cfg, 10);
        noisy.program(&codes);
        let r = noisy.mvm_batch(std::slice::from_ref(&dense_x));
        assert_eq!(r.engine_used(), EngineUsed::General);
    }

    #[test]
    fn auto_survives_live_fault_injection() {
        use crate::device::faults::{FaultPlan, FaultState};
        use crate::device::RetentionParams;
        // A healthy Auto macro picks Quantized...
        let (mut m, _) = macro_with_codes(97);
        let golden = m.golden_codes();
        let dense_x = vec![180u32; 128];
        let r = m.mvm_batch(std::slice::from_ref(&dense_x));
        assert_eq!(r.engine_used(), EngineUsed::Quantized);

        // ...retention drift alone moves codes, not levels: Quantized
        // stays eligible (wrong answers faithfully computed)...
        let plan = FaultPlan::drift_only(RetentionParams::stress(), 5);
        let mut fs = FaultState::new(plan, 0);
        let flips = fs.advance(&mut m.xbar, plan.retention.tau_ret_ns());
        assert!(flips > 0);
        let r = m.mvm_batch(std::slice::from_ref(&dense_x));
        assert_eq!(r.engine_used(), EngineUsed::Quantized);

        // ...but die-to-die variation breaks the level planes, and Auto
        // must degrade to a fallback engine instead of panicking.
        let mut harsh = FaultState::new(FaultPlan::harsh(5), 0);
        harsh.deploy(&mut m.xbar);
        assert!(!m.xbar.uniform_levels());
        let r = m.mvm_batch(std::slice::from_ref(&dense_x));
        assert_ne!(r.engine_used(), EngineUsed::Quantized);

        // Scrubbing restores the codes; the d2d variation is permanent,
        // so the fallback persists — and still computes.
        let mut rng = Rng::new(6);
        let out =
            m.scrub_against(&golden, &crate::device::SotWriteParams::default(), &mut rng);
        assert!(out.mismatched > 0);
        assert_eq!(m.golden_codes(), golden);
        let r = m.mvm_batch(std::slice::from_ref(&dense_x));
        assert_ne!(r.engine_used(), EngineUsed::Quantized);
    }

    #[test]
    #[should_panic(expected = "quantized engine forced but ineligible")]
    fn forcing_quantized_on_varied_array_panics() {
        let cfg = MacroConfig {
            nonideal: NonIdeality {
                sigma_r_d2d: 0.02,
                ..NonIdeality::ideal()
            },
            ..MacroConfig::default()
        };
        let mut m = CimMacro::with_nonidealities(cfg, 11);
        m.set_engine(MvmEngine::Quantized);
        let _ = m.mvm(&vec![1u32; 128]);
    }

    #[test]
    fn ledger_surfaces_activity_counters() {
        let (mut m, _) = macro_with_codes(95);
        let mut xs = vec![vec![0u32; 128]; 3];
        xs[1][3] = 9;
        xs[1][100] = 200;
        xs[2] = vec![7u32; 128];
        let r = m.mvm_batch(&xs);
        assert_eq!(r.active_rows(0), 0);
        assert_eq!(r.active_rows(1), 2);
        assert_eq!(r.active_rows(2), 128);
        assert_eq!(r.total_active_rows(), 130);
        assert_eq!(r.row_slots(), 3 * 128);
        assert!((r.occupancy() - 130.0 / 384.0).abs() < 1e-12);
        assert_eq!(MvmBatch::default().occupancy(), 0.0);
    }

    #[test]
    fn batch_bit_identical_droop_mode() {
        let cfg = MacroConfig {
            nonideal: NonIdeality {
                clamp_current_mirror: false,
                ..NonIdeality::ideal()
            },
            ..MacroConfig::default()
        };
        let mut rng = Rng::new(25);
        let codes: Vec<u8> =
            (0..128 * 128).map(|_| rng.below(4) as u8).collect();
        let mk = || {
            let mut m = CimMacro::new(cfg.clone());
            m.program(&codes);
            m
        };
        let xs = sparse_inputs(26, 0.7, 5);
        assert_batch_bit_identical(mk(), mk(), &xs);
    }

    #[test]
    fn batch_bit_identical_c2c_noise_shares_rng_stream() {
        // The general path draws one noise factor per row read; the
        // batch engine must consume the identical RNG stream.
        let cfg = MacroConfig {
            nonideal: NonIdeality {
                sigma_r_c2c: 0.01,
                ..NonIdeality::ideal()
            },
            ..MacroConfig::default()
        };
        let mut rng = Rng::new(27);
        let codes: Vec<u8> =
            (0..128 * 128).map(|_| rng.below(4) as u8).collect();
        let mk = || {
            let mut m = CimMacro::with_nonidealities(cfg.clone(), 99);
            m.program(&codes);
            m
        };
        let xs = sparse_inputs(28, 0.8, 5);
        assert_batch_bit_identical(mk(), mk(), &xs);
    }

    #[test]
    fn batch_bit_identical_gain_mismatch() {
        let cfg = MacroConfig {
            nonideal: NonIdeality {
                mirror_gain_sigma: 0.01,
                ..NonIdeality::ideal()
            },
            ..MacroConfig::default()
        };
        let mut rng = Rng::new(29);
        let codes: Vec<u8> =
            (0..128 * 128).map(|_| rng.below(4) as u8).collect();
        let mk = || {
            let mut m = CimMacro::with_nonidealities(cfg.clone(), 7);
            m.program(&codes);
            m
        };
        let xs = sparse_inputs(30, 0.9, 4);
        assert_batch_bit_identical(mk(), mk(), &xs);
    }

    /// Binary 0/1 input vector and its sorted active-row event list.
    fn binary_input(seed: u64, density: f64) -> (Vec<u32>, Vec<u32>) {
        let mut rng = Rng::new(seed);
        let x: Vec<u32> = (0..128)
            .map(|_| if rng.f64() < density { 1 } else { 0 })
            .collect();
        let ev: Vec<u32> = x
            .iter()
            .enumerate()
            .filter(|(_, &v)| v > 0)
            .map(|(r, _)| r as u32)
            .collect();
        (x, ev)
    }

    #[test]
    fn mvm_events_bitwise_equals_mvm_on_binary_vector() {
        // The S18 binary-spike contract: the event-list entry is the
        // same op as the 0/1 vector, for every engine and density —
        // including the empty frame and the all-dense frame.
        for engine in [
            MvmEngine::Auto,
            MvmEngine::Dense,
            MvmEngine::EventList,
            MvmEngine::Quantized,
        ] {
            for (seed, density) in
                [(301u64, 0.0), (302, 0.05), (303, 0.5), (304, 1.0)]
            {
                let (mut a, _) = macro_with_codes(seed);
                let (mut b, _) = macro_with_codes(seed);
                a.set_engine(engine);
                b.set_engine(engine);
                let (x, ev) = binary_input(seed ^ 0xe, density);
                let want = a.mvm(&x);
                let got = b.mvm_events(&ev);
                assert_eq!(got.y_mac, want.y_mac, "{engine:?} d={density}");
                assert_eq!(got.t_out_ns, want.t_out_ns);
                assert_eq!(got.v_charge, want.v_charge);
                assert_eq!(got.latency_ns, want.latency_ns);
                assert_eq!(got.events, want.events);
                assert_eq!(got.energy, want.energy);
            }
        }
    }

    #[test]
    fn mvm_events_batch_bitwise_equals_mvm_batch() {
        let (mut a, _) = macro_with_codes(311);
        let (mut b, _) = macro_with_codes(311);
        let mut xs = Vec::new();
        let mut evs = Vec::new();
        for (i, density) in [0.0, 0.02, 0.3, 1.0].into_iter().enumerate() {
            let (x, ev) = binary_input(320 + i as u64, density);
            xs.push(x);
            evs.push(ev);
        }
        let want = a.mvm_batch(&xs);
        let got = b.mvm_events_batch(&evs);
        assert_eq!(got.engine_used(), want.engine_used());
        for i in 0..xs.len() {
            assert_eq!(got.y_mac(i), want.y_mac(i), "item {i}");
            assert_eq!(got.t_out_ns(i), want.t_out_ns(i));
            assert_eq!(got.latency_ns(i), want.latency_ns(i));
            assert_eq!(got.events(i), want.events(i));
            assert_eq!(got.energy(i), want.energy(i));
            assert_eq!(got.active_rows(i), want.active_rows(i));
        }
    }

    #[test]
    fn mvm_events_nonideal_consumes_same_rng_stream() {
        // The general event loop (c2c noise) must see the same windows
        // and draw the same per-row factors either way.
        let cfg = MacroConfig {
            nonideal: NonIdeality {
                sigma_r_c2c: 0.01,
                ..NonIdeality::ideal()
            },
            ..MacroConfig::default()
        };
        let mut rng = Rng::new(331);
        let codes: Vec<u8> =
            (0..128 * 128).map(|_| rng.below(4) as u8).collect();
        let mk = || {
            let mut m = CimMacro::with_nonidealities(cfg.clone(), 5);
            m.program(&codes);
            m
        };
        let (mut a, mut b) = (mk(), mk());
        for (i, density) in [0.3, 0.0, 0.9].into_iter().enumerate() {
            let (x, ev) = binary_input(340 + i as u64, density);
            let want = a.mvm(&x);
            let got = b.mvm_events(&ev);
            assert_eq!(got.y_mac, want.y_mac, "step {i}");
            assert_eq!(got.energy, want.energy);
        }
    }

    #[test]
    #[should_panic(expected = "sorted ascending")]
    fn mvm_events_rejects_unsorted_list() {
        let (mut m, _) = macro_with_codes(351);
        let _ = m.mvm_events(&[5, 3]);
    }

    #[test]
    fn batch_then_serial_reuse_is_clean() {
        // Ledger/scratch reuse across differing batch sizes must not
        // leak state between calls.
        let (mut m, _) = macro_with_codes(33);
        let xs = sparse_inputs(34, 1.0, 9);
        let mut ledger = MvmBatch::default();
        m.mvm_batch_into(&xs, &mut ledger);
        let y8 = ledger.y_mac(8).to_vec();
        m.mvm_batch_into(&xs[3..5], &mut ledger);
        assert_eq!(ledger.len(), 2);
        let solo = m.mvm(&xs[8]);
        assert_eq!(solo.y_mac, y8);
        m.mvm_batch_into(&[], &mut ledger);
        assert!(ledger.is_empty());
    }
}

//! Waveform capture (DESIGN.md S6): named (t, value) traces recorded by
//! the transient engine, exportable as CSV — the repo's equivalent of the
//! paper's Cadence transient plots (Figs 3c, 5, 7b).

use std::fmt::Write as _;

/// One named signal trace.
#[derive(Debug, Clone)]
pub struct Trace {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Trace {
    pub fn new(name: impl Into<String>) -> Self {
        Trace {
            name: name.into(),
            points: Vec::new(),
        }
    }

    pub fn push(&mut self, t_ns: f64, v: f64) {
        debug_assert!(
            self.points.last().map(|&(t, _)| t_ns >= t).unwrap_or(true),
            "trace time must be non-decreasing"
        );
        self.points.push((t_ns, v));
    }

    /// Value at time `t_ns` by linear interpolation (clamped at the ends).
    pub fn at(&self, t_ns: f64) -> f64 {
        assert!(!self.points.is_empty());
        let pts = &self.points;
        if t_ns <= pts[0].0 {
            return pts[0].1;
        }
        if t_ns >= pts[pts.len() - 1].0 {
            return pts[pts.len() - 1].1;
        }
        // Binary search for the bracketing segment.
        let mut lo = 0;
        let mut hi = pts.len() - 1;
        while hi - lo > 1 {
            let mid = (lo + hi) / 2;
            if pts[mid].0 <= t_ns {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        let (t0, v0) = pts[lo];
        let (t1, v1) = pts[hi];
        if t1 == t0 {
            v1
        } else {
            v0 + (v1 - v0) * (t_ns - t0) / (t1 - t0)
        }
    }

    pub fn last_value(&self) -> f64 {
        self.points.last().map(|&(_, v)| v).unwrap_or(0.0)
    }

    pub fn max_value(&self) -> f64 {
        self.points
            .iter()
            .map(|&(_, v)| v)
            .fold(f64::NEG_INFINITY, f64::max)
    }
}

/// A set of traces sharing a time axis (one simulation run).
#[derive(Debug, Clone, Default)]
pub struct Waveforms {
    pub traces: Vec<Trace>,
}

impl Waveforms {
    pub fn new() -> Self {
        Self::default()
    }

    /// Get-or-create a trace by name; returns its index.
    pub fn trace_idx(&mut self, name: &str) -> usize {
        if let Some(i) = self.traces.iter().position(|t| t.name == name) {
            return i;
        }
        self.traces.push(Trace::new(name));
        self.traces.len() - 1
    }

    pub fn push(&mut self, name: &str, t_ns: f64, v: f64) {
        let i = self.trace_idx(name);
        self.traces[i].push(t_ns, v);
    }

    pub fn get(&self, name: &str) -> Option<&Trace> {
        self.traces.iter().find(|t| t.name == name)
    }

    /// CSV with a shared, merged time axis; traces are interpolated.
    pub fn to_csv(&self) -> String {
        let mut times: Vec<f64> = self
            .traces
            .iter()
            .flat_map(|t| t.points.iter().map(|&(t, _)| t))
            .collect();
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        times.dedup();
        let mut out = String::from("t_ns");
        for t in &self.traces {
            let _ = write!(out, ",{}", t.name);
        }
        out.push('\n');
        for &t in &times {
            let _ = write!(out, "{t:.6}");
            for tr in &self.traces {
                let _ = write!(out, ",{:.9}", tr.at(t));
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interpolation_between_points() {
        let mut t = Trace::new("v");
        t.push(0.0, 0.0);
        t.push(2.0, 1.0);
        assert!((t.at(1.0) - 0.5).abs() < 1e-12);
        assert_eq!(t.at(-1.0), 0.0); // clamp left
        assert_eq!(t.at(5.0), 1.0); // clamp right
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut w = Waveforms::new();
        w.push("a", 0.0, 1.0);
        w.push("a", 1.0, 2.0);
        w.push("b", 0.5, 3.0);
        let csv = w.to_csv();
        let mut lines = csv.lines();
        assert_eq!(lines.next(), Some("t_ns,a,b"));
        assert_eq!(csv.lines().count(), 4); // header + 3 distinct times
    }

    #[test]
    fn max_and_last() {
        let mut t = Trace::new("x");
        t.push(0.0, 1.0);
        t.push(1.0, 5.0);
        t.push(2.0, 3.0);
        assert_eq!(t.max_value(), 5.0);
        assert_eq!(t.last_value(), 3.0);
    }

    #[test]
    fn trace_idx_is_stable() {
        let mut w = Waveforms::new();
        let a = w.trace_idx("a");
        let b = w.trace_idx("b");
        assert_eq!(w.trace_idx("a"), a);
        assert_ne!(a, b);
    }
}

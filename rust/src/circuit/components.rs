//! Behavioral analog components (DESIGN.md S4). Each models the
//! *observable* first-order behaviour of the corresponding 28 nm block in
//! Fig 3/4 of the paper, with the non-ideality knobs the evaluation needs.
//!
//! Units: ns / V / µA / µS / fF / MΩ / fJ (see `crate::config`).

/// An ideal capacitor integrating current into voltage.
#[derive(Debug, Clone, Copy)]
pub struct Capacitor {
    pub c_ff: f64,
    pub v: f64,
}

impl Capacitor {
    pub fn new(c_ff: f64) -> Self {
        assert!(c_ff > 0.0);
        Capacitor { c_ff, v: 0.0 }
    }

    /// Integrate a constant current `i_ua` for `dt_ns`.
    pub fn charge(&mut self, i_ua: f64, dt_ns: f64) {
        self.v += i_ua * dt_ns / self.c_ff;
    }

    /// Exponential charge toward `v_inf` through conductance `g_us` for
    /// `dt_ns` (exact RC segment solution, used by the event-driven path).
    pub fn charge_rc(&mut self, v_inf: f64, g_us: f64, dt_ns: f64) {
        if g_us <= 0.0 || dt_ns <= 0.0 {
            return;
        }
        let tau = self.c_ff / g_us; // fF/µS = ns
        self.v = v_inf + (self.v - v_inf) * (-dt_ns / tau).exp();
    }

    pub fn reset(&mut self) {
        self.v = 0.0;
    }

    /// Energy to charge this cap to its present voltage from the supply
    /// (CV·Vdd, the standard switched-capacitor cost), in fJ.
    pub fn charge_energy_fj(&self, vdd: f64) -> f64 {
        self.c_ff * self.v.abs() * vdd
    }
}

/// Clamping + current-mirror block (Fig 4a).
///
/// Holds the bit line at `v_clamp` (so cell current is V_read-determined,
/// not V_charge-dependent) and mirrors the column current into the result
/// capacitor with gain `k` (± a per-column gain error). The finite output
/// resistance `r_out_mohm` models residual droop at high V_charge.
#[derive(Debug, Clone, Copy)]
pub struct CurrentMirror {
    /// Nominal gain k.
    pub k: f64,
    /// Multiplicative gain error (1.0 = ideal), frozen per column.
    pub gain_err: f64,
    /// Output resistance (MΩ); f64::INFINITY = ideal.
    pub r_out_mohm: f64,
}

impl CurrentMirror {
    pub fn ideal(k: f64) -> Self {
        CurrentMirror {
            k,
            gain_err: 1.0,
            r_out_mohm: f64::INFINITY,
        }
    }

    /// Mirrored output current (µA) for input `i_in_ua` when the output
    /// node sits at `v_out`: k·err·I_in − V_out/R_out.
    pub fn output_current(&self, i_in_ua: f64, v_out: f64) -> f64 {
        let ideal = self.k * self.gain_err * i_in_ua;
        if self.r_out_mohm.is_finite() {
            ideal - v_out / self.r_out_mohm
        } else {
            ideal
        }
    }
}

/// Continuous-time comparator (Fig 4b): output toggles when V+ crosses
/// V− + offset; the toggle propagates after `delay_ns`.
#[derive(Debug, Clone, Copy)]
pub struct Comparator {
    pub offset_v: f64,
    pub delay_ns: f64,
}

impl Comparator {
    pub fn ideal() -> Self {
        Comparator {
            offset_v: 0.0,
            delay_ns: 0.0,
        }
    }

    /// Given a ramp V+(t) = slope·t (V/ns) and a threshold `v_thresh`,
    /// the time the comparator *output* fires. None if slope ≤ 0 or the
    /// effective threshold is negative (fires immediately → t = delay).
    pub fn fire_time(&self, slope_v_per_ns: f64, v_thresh: f64) -> Option<f64> {
        if slope_v_per_ns <= 0.0 {
            return None;
        }
        let eff = v_thresh + self.offset_v;
        if eff <= 0.0 {
            return Some(self.delay_ns);
        }
        Some(eff / slope_v_per_ns + self.delay_ns)
    }

    /// Did V+ cross (V− + offset) between two sampled instants?
    pub fn crossed(&self, v_plus: f64, v_minus: f64) -> bool {
        v_plus >= v_minus + self.offset_v
    }
}

/// Input clamping circuit (Fig 3a): drives the crossbar input line to
/// `v_in_clamp` while the row's Event_flag is high, to `v_clamp` otherwise,
/// with a first-order settling time constant `tau_ns`.
#[derive(Debug, Clone, Copy)]
pub struct Clamp {
    pub v_clamp: f64,
    pub v_in_clamp: f64,
    /// Settling time constant of the clamp loop (ns).
    pub tau_ns: f64,
}

impl Clamp {
    /// Target voltage for a given flag state.
    pub fn target(&self, flag_high: bool) -> f64 {
        if flag_high {
            self.v_in_clamp
        } else {
            self.v_clamp
        }
    }

    /// Settle `v` toward the target for `dt_ns` (exact 1st-order step).
    pub fn settle(&self, v: f64, flag_high: bool, dt_ns: f64) -> f64 {
        let tgt = self.target(flag_high);
        if self.tau_ns <= 0.0 {
            return tgt;
        }
        tgt + (v - tgt) * (-dt_ns / self.tau_ns).exp()
    }

    /// Read voltage across the cell when fully settled & flag high.
    pub fn v_read(&self) -> f64 {
        self.v_clamp - self.v_in_clamp
    }
}

/// Edge-triggered spike generator (Fig 4c): emits a fixed-width pulse on
/// each rising input edge.
#[derive(Debug, Clone, Copy)]
pub struct SpikeGenerator {
    pub pulse_width_ns: f64,
    /// Energy per emitted spike (fJ) — CV² of the pulse driver.
    pub energy_fj: f64,
}

impl SpikeGenerator {
    /// Spike (start, end) for a rising edge at `t_ns`.
    pub fn fire(&self, t_ns: f64) -> (f64, f64) {
        (t_ns, t_ns + self.pulse_width_ns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacitor_linear_charge() {
        let mut c = Capacitor::new(200.0);
        c.charge(2.0, 100.0); // 2 µA for 100 ns into 200 fF = 1 V
        assert!((c.v - 1.0).abs() < 1e-12);
    }

    #[test]
    fn capacitor_rc_charge_approaches_v_inf() {
        let mut c = Capacitor::new(200.0);
        c.charge_rc(0.1, 10.0, 1e6); // many time constants
        assert!((c.v - 0.1).abs() < 1e-9);
        // one tau: 1 − e^−1 of the way
        let mut c2 = Capacitor::new(200.0);
        c2.charge_rc(1.0, 10.0, 20.0); // tau = 20 ns
        assert!((c2.v - (1.0 - (-1.0f64).exp())).abs() < 1e-12);
    }

    #[test]
    fn mirror_ideal_gain() {
        let m = CurrentMirror::ideal(1.0);
        assert_eq!(m.output_current(3.0, 0.9), 3.0);
    }

    #[test]
    fn mirror_finite_rout_droops_with_v() {
        let m = CurrentMirror {
            k: 1.0,
            gain_err: 1.0,
            r_out_mohm: 10.0,
        };
        let hi = m.output_current(3.0, 0.0);
        let lo = m.output_current(3.0, 1.0);
        assert!(lo < hi);
        assert!((hi - lo - 0.1).abs() < 1e-12); // 1 V / 10 MΩ = 0.1 µA
    }

    #[test]
    fn comparator_fire_time_linear_ramp() {
        let c = Comparator::ideal();
        // ramp 0.01 V/ns, threshold 0.5 V → 50 ns
        assert!((c.fire_time(0.01, 0.5).unwrap() - 50.0).abs() < 1e-12);
        assert!(c.fire_time(0.0, 0.5).is_none());
    }

    #[test]
    fn comparator_offset_and_delay_shift_fire_time() {
        let c = Comparator {
            offset_v: 0.01,
            delay_ns: 2.0,
        };
        let t = c.fire_time(0.01, 0.5).unwrap();
        assert!((t - (0.51 / 0.01 + 2.0)).abs() < 1e-12);
    }

    #[test]
    fn clamp_settles_to_targets() {
        let cl = Clamp {
            v_clamp: 0.4,
            v_in_clamp: 0.3,
            tau_ns: 0.1,
        };
        assert!((cl.v_read() - 0.1).abs() < 1e-12);
        let v = cl.settle(0.4, true, 10.0); // 100 taus
        assert!((v - 0.3).abs() < 1e-9);
        assert_eq!(cl.settle(0.25, false, 0.0), 0.25); // dt=0 keeps state
    }

    #[test]
    fn clamp_instant_when_tau_zero() {
        let cl = Clamp {
            v_clamp: 0.4,
            v_in_clamp: 0.3,
            tau_ns: 0.0,
        };
        assert_eq!(cl.settle(0.0, true, 0.0), 0.3);
    }

    #[test]
    fn spike_generator_pulse() {
        let sg = SpikeGenerator {
            pulse_width_ns: 0.1,
            energy_fj: 1.0,
        };
        assert_eq!(sg.fire(5.0), (5.0, 5.1));
    }
}

//! Fixed-step transient integrator (DESIGN.md S6) — the waveform-fidelity
//! path of the behavioral circuit engine (used for Figs 3c / 5 / 7b).
//!
//! The *hot* path of the simulator never uses this: macro ops are solved
//! event-analytically (piecewise closed forms between spike events, see
//! `circuit::osg`). This integrator exists to (a) render dense waveforms
//! like the paper's Cadence plots and (b) cross-check the analytic path
//! (they must agree to discretization error — tested below and in
//! `python/compile/kernels/transient.py`).

use super::waveform::Waveforms;

/// A system integrated as dv/dt = f(t, v) per named state.
pub trait TransientSystem {
    /// Number of state variables.
    fn dim(&self) -> usize;
    /// Derivatives dv/dt (units V/ns) at time `t_ns` for states `v`.
    fn deriv(&self, t_ns: f64, v: &[f64], dv: &mut [f64]);
    /// Names for waveform capture (len == dim()).
    fn names(&self) -> Vec<String>;
}

/// Integration configuration.
#[derive(Debug, Clone, Copy)]
pub struct TransientConfig {
    pub dt_ns: f64,
    pub t_end_ns: f64,
    /// Record every `stride`-th step into the waveform set (1 = all).
    pub record_stride: usize,
}

impl Default for TransientConfig {
    fn default() -> Self {
        TransientConfig {
            dt_ns: 0.01,
            t_end_ns: 100.0,
            record_stride: 1,
        }
    }
}

/// RK4 fixed-step integration with waveform capture.
///
/// Returns (final state, waveforms). RK4 rather than Euler so the
/// cross-check against the analytic event path converges fast enough to
/// assert tight tolerances.
pub fn integrate<S: TransientSystem>(
    sys: &S,
    v0: &[f64],
    cfg: &TransientConfig,
) -> (Vec<f64>, Waveforms) {
    assert_eq!(v0.len(), sys.dim());
    assert!(cfg.dt_ns > 0.0 && cfg.t_end_ns >= 0.0);
    let names = sys.names();
    let n = sys.dim();
    let mut v = v0.to_vec();
    let mut wf = Waveforms::new();
    let steps = (cfg.t_end_ns / cfg.dt_ns).round() as usize;

    let mut k1 = vec![0.0; n];
    let mut k2 = vec![0.0; n];
    let mut k3 = vec![0.0; n];
    let mut k4 = vec![0.0; n];
    let mut tmp = vec![0.0; n];

    let record = |wf: &mut Waveforms, t: f64, v: &[f64]| {
        for (name, &val) in names.iter().zip(v) {
            wf.push(name, t, val);
        }
    };
    record(&mut wf, 0.0, &v);

    for s in 0..steps {
        let t = s as f64 * cfg.dt_ns;
        let h = cfg.dt_ns;
        sys.deriv(t, &v, &mut k1);
        for i in 0..n {
            tmp[i] = v[i] + 0.5 * h * k1[i];
        }
        sys.deriv(t + 0.5 * h, &tmp, &mut k2);
        for i in 0..n {
            tmp[i] = v[i] + 0.5 * h * k2[i];
        }
        sys.deriv(t + 0.5 * h, &tmp, &mut k3);
        for i in 0..n {
            tmp[i] = v[i] + h * k3[i];
        }
        sys.deriv(t + h, &tmp, &mut k4);
        for i in 0..n {
            v[i] += h / 6.0 * (k1[i] + 2.0 * k2[i] + 2.0 * k3[i] + k4[i]);
        }
        if (s + 1) % cfg.record_stride == 0 || s + 1 == steps {
            record(&mut wf, t + h, &v);
        }
    }
    (v, wf)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// dv/dt = −v (exact: e^−t).
    struct Decay;
    impl TransientSystem for Decay {
        fn dim(&self) -> usize {
            1
        }
        fn deriv(&self, _t: f64, v: &[f64], dv: &mut [f64]) {
            dv[0] = -v[0];
        }
        fn names(&self) -> Vec<String> {
            vec!["v".into()]
        }
    }

    #[test]
    fn rk4_matches_exponential_decay() {
        let cfg = TransientConfig {
            dt_ns: 0.05,
            t_end_ns: 2.0,
            record_stride: 1,
        };
        let (v, _) = integrate(&Decay, &[1.0], &cfg);
        assert!((v[0] - (-2.0f64).exp()).abs() < 1e-7);
    }

    /// Constant-current capacitor: dv/dt = 0.01 (linear ramp).
    struct Ramp;
    impl TransientSystem for Ramp {
        fn dim(&self) -> usize {
            1
        }
        fn deriv(&self, _t: f64, _v: &[f64], dv: &mut [f64]) {
            dv[0] = 0.01;
        }
        fn names(&self) -> Vec<String> {
            vec!["vc".into()]
        }
    }

    #[test]
    fn ramp_is_exact_and_recorded() {
        let cfg = TransientConfig {
            dt_ns: 0.1,
            t_end_ns: 10.0,
            record_stride: 10,
        };
        let (v, wf) = integrate(&Ramp, &[0.0], &cfg);
        assert!((v[0] - 0.1).abs() < 1e-12);
        let tr = wf.get("vc").unwrap();
        assert!((tr.at(5.0) - 0.05).abs() < 1e-9);
        // stride 10 over 100 steps → 11 recorded points incl. t=0
        assert_eq!(tr.points.len(), 11);
    }

    /// Two coupled states: dv0 = 1, dv1 = v0 (v1 = t²/2).
    struct Coupled;
    impl TransientSystem for Coupled {
        fn dim(&self) -> usize {
            2
        }
        fn deriv(&self, _t: f64, v: &[f64], dv: &mut [f64]) {
            dv[0] = 1.0;
            dv[1] = v[0];
        }
        fn names(&self) -> Vec<String> {
            vec!["a".into(), "b".into()]
        }
    }

    #[test]
    fn coupled_states_integrate_together() {
        let cfg = TransientConfig {
            dt_ns: 0.01,
            t_end_ns: 3.0,
            record_stride: 100,
        };
        let (v, _) = integrate(&Coupled, &[0.0, 0.0], &cfg);
        assert!((v[0] - 3.0).abs() < 1e-9);
        assert!((v[1] - 4.5).abs() < 1e-6);
    }
}

//! Spike Modulation Unit (paper §III-B, Fig 3).
//!
//! Per row: a DFF turns the input spike pair into `Event_flag_i` (high
//! between the two spikes), and the input clamping circuit regulates the
//! crossbar input line to `V_in,clamp` while the flag is high (N1 path)
//! and to `V_clamp` otherwise (N2 path), so a fixed
//! V_read = V_clamp − V_in,clamp appears across the cells exactly during
//! the event window.

use crate::coding::SpikePair;

use super::components::Clamp;
use super::waveform::Waveforms;

/// SMU behavioral parameters.
#[derive(Debug, Clone, Copy)]
pub struct SmuParams {
    pub clamp: Clamp,
    /// DFF clk→Q delay (ns) applied to both flag edges.
    pub dff_delay_ns: f64,
    /// Energy per DFF toggle (fJ).
    pub e_dff_toggle_fj: f64,
    /// Clamp bias power while the row is active (µW = fJ/ns).
    pub p_clamp_active_uw: f64,
}

impl SmuParams {
    /// Defaults per DESIGN.md §6 (28 nm standard-cell-class numbers).
    pub fn default_28nm(v_clamp: f64, v_in_clamp: f64) -> Self {
        SmuParams {
            clamp: Clamp {
                v_clamp,
                v_in_clamp,
                tau_ns: 0.05,
            },
            dff_delay_ns: 0.03,
            e_dff_toggle_fj: 1.2,
            p_clamp_active_uw: 2.0,
        }
    }
}

/// The Event_flag_i window produced by a spike pair (DFF output).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FlagWindow {
    pub rise_ns: f64,
    pub fall_ns: f64,
}

impl FlagWindow {
    pub fn duration_ns(&self) -> f64 {
        self.fall_ns - self.rise_ns
    }
}

/// One SMU row.
#[derive(Debug, Clone, Copy)]
pub struct SmuRow {
    pub params: SmuParams,
}

impl SmuRow {
    pub fn new(params: SmuParams) -> Self {
        SmuRow { params }
    }

    /// DFF: spike pair → flag window (both edges shifted by clk→Q delay).
    /// A zero-interval pair (value 0) produces no window.
    pub fn flag_window(&self, pair: &SpikePair) -> Option<FlagWindow> {
        if pair.dt_ns <= 0.0 {
            return None;
        }
        let d = self.params.dff_delay_ns;
        Some(FlagWindow {
            rise_ns: pair.t0_ns + d,
            fall_ns: pair.t1_ns() + d,
        })
    }

    /// Energy consumed by this row for one spike pair (fJ):
    /// two DFF toggles + clamp bias over the active window.
    pub fn event_energy_fj(&self, pair: &SpikePair) -> f64 {
        match self.flag_window(pair) {
            None => 0.0,
            Some(w) => {
                2.0 * self.params.e_dff_toggle_fj
                    + self.params.p_clamp_active_uw * w.duration_ns()
            }
        }
    }

    /// Dense waveforms for Fig 3(c): input spikes, Event_flag_i, V_in.
    /// V_in follows the clamp's first-order settling between targets.
    pub fn waveforms(&self, pair: &SpikePair, t_end_ns: f64, dt_ns: f64) -> Waveforms {
        let mut wf = Waveforms::new();
        let window = self.flag_window(pair);
        let spike_w = 0.1; // drawn spike width (ns)
        let mut v_in = self.params.clamp.v_clamp; // idle level
        let steps = (t_end_ns / dt_ns).ceil() as usize;
        for s in 0..=steps {
            let t = s as f64 * dt_ns;
            // input spike train (two narrow pulses)
            let in_spike = ((t - pair.t0_ns) >= 0.0 && (t - pair.t0_ns) < spike_w)
                || ((t - pair.t1_ns()) >= 0.0 && (t - pair.t1_ns()) < spike_w);
            let flag = window
                .map(|w| t >= w.rise_ns && t < w.fall_ns)
                .unwrap_or(false);
            v_in = self.params.clamp.settle(v_in, flag, dt_ns);
            wf.push("spike_in", t, if in_spike { 1.0 } else { 0.0 });
            wf.push("event_flag_i", t, if flag { 1.0 } else { 0.0 });
            wf.push("v_in", t, v_in);
        }
        wf
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row() -> SmuRow {
        SmuRow::new(SmuParams::default_28nm(0.4, 0.3))
    }

    fn pair(t0: f64, dt: f64) -> SpikePair {
        SpikePair { t0_ns: t0, dt_ns: dt }
    }

    #[test]
    fn flag_window_matches_interspike_interval() {
        let r = row();
        let w = r.flag_window(&pair(1.0, 3.2)).unwrap();
        assert!((w.duration_ns() - 3.2).abs() < 1e-12);
        assert!((w.rise_ns - 1.03).abs() < 1e-12); // + dff delay
    }

    #[test]
    fn zero_value_produces_no_window_or_energy() {
        let r = row();
        assert!(r.flag_window(&pair(0.0, 0.0)).is_none());
        assert_eq!(r.event_energy_fj(&pair(0.0, 0.0)), 0.0);
    }

    #[test]
    fn event_energy_scales_with_window() {
        let r = row();
        let e_small = r.event_energy_fj(&pair(0.0, 1.0));
        let e_large = r.event_energy_fj(&pair(0.0, 10.0));
        assert!(e_large > e_small);
        // Both include the fixed 2-toggle DFF cost.
        let fixed = 2.0 * r.params.e_dff_toggle_fj;
        assert!((e_small - fixed - 2.0).abs() < 1e-12); // 2 µW × 1 ns
    }

    #[test]
    fn vin_settles_to_clamp_targets_fig3c() {
        // Fig 3(c): V_in pulled to V_in,clamp during the event window,
        // back to V_clamp after.
        let r = row();
        let p = pair(1.0, 5.0);
        let wf = r.waveforms(&p, 10.0, 0.005);
        let v_in = wf.get("v_in").unwrap();
        // mid-window: settled to 0.3 V
        assert!((v_in.at(4.0) - 0.3).abs() < 1e-3);
        // well after: back to 0.4 V
        assert!((v_in.at(9.5) - 0.4).abs() < 1e-3);
        // flag matches window
        let flag = wf.get("event_flag_i").unwrap();
        assert_eq!(flag.at(3.0), 1.0);
        assert_eq!(flag.at(8.0), 0.0);
    }

    #[test]
    fn read_voltage_is_100mv() {
        let r = row();
        assert!((r.params.clamp.v_read() - 0.1).abs() < 1e-12);
    }
}

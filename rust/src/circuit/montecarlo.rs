//! Monte-Carlo corner analysis (DESIGN.md S6, experiment E-MC — the
//! "thoroughly validated" claim of §I made quantitative): sweep process
//! corners and mismatch seeds, measure
//! the distribution of linearity (R²), MAC error, and energy across many
//! virtual die — the behavioral stand-in for the paper's Cadence MC runs.

use crate::config::{MacroConfig, NonIdeality};
use crate::macro_model::CimMacro;
use crate::util::rng::Rng;
use crate::util::stats::{line_fit, mean, percentile, std_dev};

/// Process corner: scales the analog non-ideality magnitudes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Corner {
    /// Typical-typical: the `NonIdeality::realistic()` magnitudes.
    TT,
    /// Fast-fast: tighter matching (0.5× sigmas).
    FF,
    /// Slow-slow: worse matching (2× sigmas).
    SS,
}

impl Corner {
    pub fn scale(self) -> f64 {
        match self {
            Corner::FF => 0.5,
            Corner::TT => 1.0,
            Corner::SS => 2.0,
        }
    }

    pub fn nonideality(self) -> NonIdeality {
        let base = NonIdeality::realistic();
        let s = self.scale();
        NonIdeality {
            sigma_r_d2d: base.sigma_r_d2d * s,
            sigma_r_c2c: base.sigma_r_c2c * s,
            comparator_offset_v: base.comparator_offset_v * s,
            comparator_delay_ns: base.comparator_delay_ns,
            mirror_gain_sigma: base.mirror_gain_sigma * s,
            clamp_current_mirror: true,
        }
    }
}

/// One die's measured figures of merit.
#[derive(Debug, Clone, Copy)]
pub struct DieResult {
    pub r2: f64,
    /// Mean relative MAC error vs the die's own programmed weights.
    pub mac_rel_err: f64,
    /// Energy per MVM (pJ).
    pub energy_pj: f64,
}

/// Distribution summary over the MC population.
#[derive(Debug, Clone)]
pub struct McSummary {
    pub corner: Corner,
    pub dies: usize,
    pub r2_mean: f64,
    pub r2_p5: f64,
    pub mac_err_mean: f64,
    pub mac_err_sd: f64,
    pub energy_pj_mean: f64,
}

/// Measure one virtual die (fresh mismatch seed).
pub fn measure_die(cfg: &MacroConfig, seed: u64, mvms: usize) -> DieResult {
    let mut m = CimMacro::with_nonidealities(cfg.clone(), seed);
    let mut rng = Rng::new(seed ^ 0x00d1e);
    let codes: Vec<u8> = (0..cfg.rows * cfg.cols)
        .map(|_| rng.below(4) as u8)
        .collect();
    m.program(&codes);

    let mut xs = Vec::new();
    let mut ys = Vec::new();
    let mut rel_err_acc = 0.0;
    let mut energy = 0.0;
    for _ in 0..mvms {
        let x: Vec<u32> = (0..cfg.rows).map(|_| rng.below(256) as u32).collect();
        let r = m.mvm(&x);
        let ideal = m.ideal_mvm(&x);
        energy += r.energy.total_pj();
        for c in 0..cfg.cols {
            xs.push(ideal[c] * cfg.t_bit_ns);
            ys.push(r.t_out_ns[c]);
            rel_err_acc += (r.y_mac[c] - ideal[c]).abs() / ideal[c].max(1.0);
        }
    }
    DieResult {
        r2: line_fit(&xs, &ys).r2,
        mac_rel_err: rel_err_acc / (mvms * cfg.cols) as f64,
        energy_pj: energy / mvms as f64,
    }
}

/// Run the MC population for one corner.
pub fn run_corner(
    base: &MacroConfig,
    corner: Corner,
    dies: usize,
    mvms_per_die: usize,
    seed: u64,
) -> McSummary {
    let cfg = MacroConfig {
        nonideal: corner.nonideality(),
        ..base.clone()
    };
    let mut meta = Rng::new(seed);
    let results: Vec<DieResult> = (0..dies)
        .map(|_| measure_die(&cfg, meta.next_u64(), mvms_per_die))
        .collect();
    let r2s: Vec<f64> = results.iter().map(|d| d.r2).collect();
    let errs: Vec<f64> = results.iter().map(|d| d.mac_rel_err).collect();
    let es: Vec<f64> = results.iter().map(|d| d.energy_pj).collect();
    McSummary {
        corner,
        dies,
        r2_mean: mean(&r2s),
        r2_p5: percentile(&r2s, 5.0),
        mac_err_mean: mean(&errs),
        mac_err_sd: std_dev(&errs),
        energy_pj_mean: mean(&es),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corners_order_nonideality_magnitude() {
        assert!(Corner::FF.scale() < Corner::TT.scale());
        assert!(Corner::TT.scale() < Corner::SS.scale());
        let ff = Corner::FF.nonideality();
        let ss = Corner::SS.nonideality();
        assert!(ff.sigma_r_d2d < ss.sigma_r_d2d);
    }

    #[test]
    fn linearity_survives_tt_corner() {
        let s = run_corner(&MacroConfig::default(), Corner::TT, 4, 2, 777);
        // With realistic mismatch the pooled-column fit keeps R² > 0.98
        // (per-column gain spread is the limiter; see fig7 bench for the
        // per-knob decomposition) and MAC error stays ~1 %.
        assert!(s.r2_mean > 0.98, "R² {}", s.r2_mean);
        assert!(s.mac_err_mean < 0.02, "err {}", s.mac_err_mean);
    }

    #[test]
    fn ss_corner_is_worse_than_ff() {
        let cfg = MacroConfig::default();
        let ff = run_corner(&cfg, Corner::FF, 4, 2, 778);
        let ss = run_corner(&cfg, Corner::SS, 4, 2, 778);
        assert!(ss.mac_err_mean > ff.mac_err_mean);
        assert!(ss.r2_p5 <= ff.r2_p5 + 1e-12);
    }

    #[test]
    fn die_results_are_deterministic_in_seed() {
        let cfg = MacroConfig {
            nonideal: Corner::TT.nonideality(),
            ..MacroConfig::default()
        };
        let a = measure_die(&cfg, 42, 1);
        let b = measure_die(&cfg, 42, 1);
        assert_eq!(a.r2, b.r2);
        assert_eq!(a.mac_rel_err, b.mac_rel_err);
    }
}

//! Output Spike Generator (paper §III-C, Fig 4) — the readout that makes
//! the temporal MAC linear.
//!
//! Two phases per column:
//!
//! 1. **Charge** (while global Event_flag is high): the clamp+current-
//!    mirror copies the column current into C_rt. With the mirror the
//!    charging is source-independent → V_charge is *linear* in
//!    Σ T_in,i·G_i. Without it (Fig 7b baseline) C_rt is charged straight
//!    from the bit line and the rising V_charge steals drive voltage →
//!    exponential droop.
//! 2. **Compare** (after Event_flag drops): C_com ramps at I_com; when
//!    V_com crosses V_charge the comparator fires the second output spike.
//!    T_out = V_charge·C_com/I_com  ⇒  Eq. (2).
//!
//! The hot path is *event-analytic*: conductance-sum changes only at row
//! fall events, and both charging modes have closed forms per segment, so
//! a 128-row column is solved in O(rows·log rows) with zero time-stepping.
//! `waveforms()` renders the same physics densely for Fig 5.

use super::components::{Capacitor, Comparator, CurrentMirror};
use super::waveform::Waveforms;

/// OSG circuit parameters for one column.
#[derive(Debug, Clone, Copy)]
pub struct OsgParams {
    pub mirror: CurrentMirror,
    pub comparator: Comparator,
    pub c_rt_ff: f64,
    pub c_com_ff: f64,
    pub i_com_ua: f64,
    /// Read voltage across cells while their row window is open (V).
    pub v_read: f64,
    /// false → Fig 7b baseline: direct bit-line charging (droop).
    pub clamp_cm_enabled: bool,
}

impl OsgParams {
    pub fn ideal(v_read: f64, c_rt_ff: f64, c_com_ff: f64, i_com_ua: f64) -> Self {
        OsgParams {
            mirror: CurrentMirror::ideal(1.0),
            comparator: Comparator::ideal(),
            c_rt_ff,
            c_com_ff,
            i_com_ua,
            v_read,
            clamp_cm_enabled: true,
        }
    }

    /// Sensing gain α = k·V_read·C_com/(C_rt·I_com)  (Eq. 2, DESIGN §1).
    pub fn alpha(&self) -> f64 {
        self.mirror.k * self.v_read * self.c_com_ff
            / (self.c_rt_ff * self.i_com_ua)
    }
}

/// Result of one column conversion.
#[derive(Debug, Clone, Copy)]
pub struct ColumnResult {
    /// Voltage on C_rt when the global flag dropped (V).
    pub v_charge: f64,
    /// Output inter-spike interval (ns).
    pub t_out_ns: f64,
    /// Duration of the charge phase (= global flag high time, ns).
    pub charge_ns: f64,
}

/// One column's active-row windows: (fall time ns, cell conductance µS).
/// All windows are assumed to open at t = 0 (aligned first spikes, §III-A);
/// rows with value 0 simply don't appear.
pub type ColumnWindows = [(f64, f64)];

/// Event-analytic charge phase: returns V_charge at `t_end` (the global
/// flag drop = max fall time; pass it explicitly since it is shared by
/// all columns of the macro).
pub fn charge_phase(params: &OsgParams, windows: &ColumnWindows, t_end_ns: f64) -> f64 {
    // Sort fall events ascending; walk segments with the running G sum.
    let mut falls: Vec<(f64, f64)> = windows
        .iter()
        .copied()
        .filter(|&(t, g)| t > 0.0 && g > 0.0)
        .collect();
    falls.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());

    let mut g_on: f64 = falls.iter().map(|&(_, g)| g).sum();
    let mut cap = Capacitor::new(params.c_rt_ff);
    let mut t = 0.0;

    let advance = |cap: &mut Capacitor, g_on: f64, dt: f64| {
        if dt <= 0.0 || g_on <= 0.0 {
            return;
        }
        if params.clamp_cm_enabled {
            // dV/dt = (k·err·V_read·g_on − V/R_out)/C.
            let i_in = params.v_read * g_on;
            let m = &params.mirror;
            if m.r_out_mohm.is_finite() {
                let v_inf = m.k * m.gain_err * i_in * m.r_out_mohm;
                let g_eff = 1.0 / m.r_out_mohm; // µS
                cap.charge_rc(v_inf, g_eff, dt);
            } else {
                cap.charge(m.k * m.gain_err * i_in, dt);
            }
        } else {
            // Direct bit-line charging: dV/dt = g_on·(V_read − V)/C.
            cap.charge_rc(params.v_read, g_on, dt);
        }
    };

    for &(t_fall, g) in &falls {
        advance(&mut cap, g_on, t_fall - t);
        t = t_fall;
        g_on -= g;
    }
    // After the last fall no current flows; V holds until t_end.
    debug_assert!(t <= t_end_ns + 1e-9);
    cap.v
}

/// Compare phase: V_com ramps at I_com/C_com from the flag drop; the
/// comparator fires when V_com crosses V_charge (+offset, +delay).
pub fn compare_phase(params: &OsgParams, v_charge: f64) -> f64 {
    let slope = params.i_com_ua / params.c_com_ff; // V/ns
    params
        .comparator
        .fire_time(slope, v_charge)
        .expect("positive ramp")
}

/// Full conversion for one column.
pub fn convert(
    params: &OsgParams,
    windows: &ColumnWindows,
    t_flag_drop_ns: f64,
) -> ColumnResult {
    let v_charge = charge_phase(params, windows, t_flag_drop_ns);
    let t_out_ns = compare_phase(params, v_charge);
    ColumnResult {
        v_charge,
        t_out_ns,
        charge_ns: t_flag_drop_ns,
    }
}

/// Dense waveforms of both phases for Fig 5: `v_charge`, `v_com`,
/// `event_flag` (global), `spike_out`. Euler at `dt_ns`.
pub fn waveforms(
    params: &OsgParams,
    windows: &ColumnWindows,
    t_flag_drop_ns: f64,
    dt_ns: f64,
) -> Waveforms {
    let mut wf = Waveforms::new();
    let mut falls: Vec<(f64, f64)> = windows.to_vec();
    falls.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let result = convert(params, windows, t_flag_drop_ns);
    let t_end = t_flag_drop_ns + result.t_out_ns + 5.0;
    let spike_w = 0.1;

    let mut v_rt = 0.0f64;
    let mut v_com = 0.0f64;
    let steps = (t_end / dt_ns).ceil() as usize;
    let fire_abs = t_flag_drop_ns + result.t_out_ns;
    for s in 0..=steps {
        let t = s as f64 * dt_ns;
        let flag_high = t < t_flag_drop_ns;
        if flag_high {
            let g_on: f64 = falls
                .iter()
                .filter(|&&(tf, _)| t < tf)
                .map(|&(_, g)| g)
                .sum();
            if params.clamp_cm_enabled {
                let m = &params.mirror;
                let i_in = params.v_read * g_on;
                let i_out = m.output_current(i_in, v_rt);
                v_rt += i_out * dt_ns / params.c_rt_ff;
            } else {
                v_rt += g_on * (params.v_read - v_rt) * dt_ns / params.c_rt_ff;
            }
        } else if v_com < v_rt + params.comparator.offset_v + 0.2 {
            // C_com ramp (keeps ramping slightly past crossing for plot).
            v_com += params.i_com_ua * dt_ns / params.c_com_ff;
        }
        let spike = ((t - t_flag_drop_ns) >= 0.0 && (t - t_flag_drop_ns) < spike_w)
            || ((t - fire_abs) >= 0.0 && (t - fire_abs) < spike_w);
        wf.push("event_flag", t, if flag_high { 1.0 } else { 0.0 });
        wf.push("v_charge", t, v_rt);
        wf.push("v_com", t, v_com);
        wf.push("spike_out", t, if spike { 1.0 } else { 0.0 });
    }
    wf
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> OsgParams {
        OsgParams::ideal(0.1, 200.0, 200.0, 2.0)
    }

    #[test]
    fn alpha_matches_config() {
        assert!((params().alpha() - 0.05).abs() < 1e-12);
    }

    #[test]
    fn ideal_charge_is_exact_weighted_sum() {
        // V_charge = k·V_read·Σ(T_i·G_i)/C_rt, exactly.
        let p = params();
        let windows = [(10.0, 0.25), (20.0, 1.0 / 3.0), (5.0, 1.0 / 6.0)];
        let want = 0.1 * (10.0 * 0.25 + 20.0 / 3.0 + 5.0 / 6.0) / 200.0;
        let got = charge_phase(&p, &windows, 20.0);
        assert!((got - want).abs() < 1e-12, "{got} vs {want}");
    }

    #[test]
    fn t_out_is_alpha_times_mac() {
        // Eq. 2 end to end: T_out = α·Σ T_i·G_i.
        let p = params();
        let windows = [(10.0, 0.25), (20.0, 1.0 / 3.0)];
        let mac = 10.0 * 0.25 + 20.0 / 3.0;
        let r = convert(&p, &windows, 20.0);
        assert!((r.t_out_ns - p.alpha() * mac).abs() < 1e-9);
    }

    #[test]
    fn empty_column_fires_immediately() {
        let p = params();
        let r = convert(&p, &[], 10.0);
        assert_eq!(r.v_charge, 0.0);
        assert_eq!(r.t_out_ns, 0.0);
    }

    #[test]
    fn droop_mode_charges_less_fig7b() {
        let p = params();
        let mut pd = p;
        pd.clamp_cm_enabled = false;
        // All 128 rows at max conductance for 10 ns — the Fig 7b stress.
        let windows: Vec<(f64, f64)> = (0..128).map(|_| (10.0, 1.0 / 3.0)).collect();
        let v_ideal = charge_phase(&p, &windows, 10.0);
        let v_droop = charge_phase(&pd, &windows, 10.0);
        assert!(v_droop < v_ideal);
        let droop = 1.0 - v_droop / v_ideal;
        // Exponential RC: 1 − (1−e^−x)/x with x = G·t/C = 128/3·10/200 ≈ 2.13
        // → ≈ 58 % droop. The paper's 39.6 % uses a lighter load; shape match.
        assert!(droop > 0.3 && droop < 0.8, "droop {droop}");
    }

    #[test]
    fn droop_matches_closed_form_single_segment() {
        let mut p = params();
        p.clamp_cm_enabled = false;
        let g = 0.5;
        let t = 8.0;
        let v = charge_phase(&p, &[(t, g)], t);
        let want = 0.1 * (1.0 - (-g * t / 200.0f64).exp());
        assert!((v - want).abs() < 1e-12);
    }

    #[test]
    fn finite_mirror_rout_reduces_charge() {
        let mut p = params();
        p.mirror.r_out_mohm = 50.0;
        let windows = [(40.0, 1.0 / 3.0); 64];
        let v_ideal = charge_phase(&params(), &windows, 40.0);
        let v_real = charge_phase(&p, &windows, 40.0);
        assert!(v_real < v_ideal);
        assert!(v_real > 0.8 * v_ideal); // second-order effect
    }

    #[test]
    fn comparator_offset_and_delay_shift_t_out() {
        let mut p = params();
        p.comparator = Comparator {
            offset_v: 0.01,
            delay_ns: 1.0,
        };
        let windows = [(10.0, 0.25)];
        let r = convert(&p, &windows, 10.0);
        let ideal = convert(&params(), &windows, 10.0);
        // +0.01 V at 0.01 V/ns ramp = +1 ns, +1 ns delay.
        assert!((r.t_out_ns - ideal.t_out_ns - 2.0).abs() < 1e-9);
    }

    #[test]
    fn waveform_mode_agrees_with_analytic() {
        let p = params();
        let windows = [(10.0, 0.25), (20.0, 1.0 / 3.0), (15.0, 0.2)];
        let r = convert(&p, &windows, 20.0);
        let wf = waveforms(&p, &windows, 20.0, 0.001);
        let v_wf = wf.get("v_charge").unwrap().at(20.0);
        assert!(
            (v_wf - r.v_charge).abs() < 1e-4,
            "euler {v_wf} vs analytic {}",
            r.v_charge
        );
    }

    #[test]
    fn waveform_vcom_crosses_vcharge_at_t_out() {
        let p = params();
        let windows = [(30.0, 1.0 / 3.0); 32];
        let r = convert(&p, &windows, 30.0);
        let wf = waveforms(&p, &windows, 30.0, 0.001);
        let v_com = wf.get("v_com").unwrap();
        let cross = 30.0 + r.t_out_ns;
        assert!((v_com.at(cross) - r.v_charge).abs() < 2e-3);
    }

    #[test]
    fn charge_monotone_in_each_window() {
        // Linearity sanity: adding any window increases V_charge.
        let p = params();
        let base = [(10.0, 0.25), (20.0, 0.2)];
        let more = [(10.0, 0.25), (20.0, 0.2), (5.0, 1.0 / 6.0)];
        assert!(charge_phase(&p, &more, 20.0) > charge_phase(&p, &base, 20.0));
    }
}

//! Behavioral analog circuit engine (DESIGN.md S4–S6).
//!
//! Two resolutions of the same physics:
//! * **event-analytic** — closed-form segment solutions between spike
//!   events (the simulator's hot path; `osg::charge_phase`);
//! * **dense transient** — RK4/Euler waveform rendering for the paper's
//!   scope plots (`transient::integrate`, `smu::waveforms`,
//!   `osg::waveforms`).
//!
//! They are cross-checked against each other in tests, and against the
//! Pallas `transient.py` kernel in `rust/tests/`.

pub mod components;
pub mod montecarlo;
pub mod osg;
pub mod smu;
pub mod transient;
pub mod waveform;

pub use components::{Capacitor, Clamp, Comparator, CurrentMirror, SpikeGenerator};
pub use osg::{ColumnResult, OsgParams};
pub use smu::{FlagWindow, SmuParams, SmuRow};
pub use waveform::{Trace, Waveforms};

//! Bit-line wire parasitics (DESIGN.md S7, experiment EX1 — the paper's
//! §V "scalable analog computing" made quantitative).
//!
//! In a real crossbar the clamp only holds the *near end* of the bit line
//! at V_clamp; a cell `r` rows away sees the wire resistance of `r`
//! segments carrying the downstream current, so its effective read
//! voltage is reduced. With MΩ cells and mΩ–Ω segments the effect is tiny
//! at 128 rows — exactly why the paper's high-R stack scales — but it
//! grows quadratically with array height, which is what
//! `repro::scaling` sweeps.
//!
//! Model: uniform segment resistance R_w per cell pitch, all active cells
//! drawing I_r = V_eff(r)·G_r. First-order (single Jacobi pass, exact to
//! O((R_w·ΣG)²)): the IR drop seen by cell r is
//! R_w · Σ_{s≥r} partial sums of downstream currents.

/// Bit-line parasitic parameters.
#[derive(Debug, Clone, Copy)]
pub struct Parasitics {
    /// Wire resistance per cell pitch (Ω — NOT MΩ; converted internally).
    pub r_seg_ohm: f64,
}

impl Parasitics {
    /// 28 nm M2-class wire: ≈ 2 Ω per cell pitch.
    pub fn metal2() -> Self {
        Parasitics { r_seg_ohm: 2.0 }
    }

    /// Effective per-cell read voltages (V) for one column.
    ///
    /// `g_us[r]` = conductance of the cell at row r (µS, 0 = inactive),
    /// row 0 is nearest the clamp. `v_read` is the ideal read voltage.
    pub fn effective_v_read(&self, g_us: &[f64], v_read: f64) -> Vec<f64> {
        let n = g_us.len();
        // Ideal currents (µA); Ω·µA = µV → /1e6 to volts.
        let i_ideal: Vec<f64> = g_us.iter().map(|&g| v_read * g).collect();
        // Cumulative downstream current through each segment: segment s
        // (between row s−1 and s) carries Σ_{r≥s} I_r.
        let mut suffix = vec![0.0f64; n + 1];
        for r in (0..n).rev() {
            suffix[r] = suffix[r + 1] + i_ideal[r];
        }
        // Voltage drop at row r = R_w · Σ_{s=1..=r} suffix[s].
        let mut drop_uv = 0.0;
        let mut out = Vec::with_capacity(n);
        for r in 0..n {
            if r > 0 {
                drop_uv += self.r_seg_ohm * suffix[r];
            }
            out.push(v_read - drop_uv * 1e-6);
        }
        out
    }

    /// Worst-case (far-end) relative V_read loss for a fully-on column
    /// of `rows` cells at conductance `g_us` each.
    pub fn worst_case_loss(&self, rows: usize, g_us: f64, v_read: f64) -> f64 {
        let g = vec![g_us; rows];
        let v = self.effective_v_read(&g, v_read);
        1.0 - v[rows - 1] / v_read
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn near_cell_sees_full_v_read() {
        let p = Parasitics::metal2();
        let g = vec![1.0 / 3.0; 128];
        let v = p.effective_v_read(&g, 0.1);
        assert_eq!(v[0], 0.1);
        assert!(v[127] < 0.1);
    }

    #[test]
    fn loss_negligible_at_128_rows_with_mohm_cells() {
        // The paper's scaling argument: MΩ cells + 2 Ω wire → loss ≈
        // R_w·G·n²/2 ≈ 2·0.33e-6·8192 ≈ 0.5 %. Stays below 1 %.
        let p = Parasitics::metal2();
        let loss = p.worst_case_loss(128, 1.0 / 3.0, 0.1);
        assert!(loss < 0.01, "loss {loss}");
        assert!(loss > 1e-4); // but not zero — the model is active
    }

    #[test]
    fn loss_grows_quadratically_with_rows() {
        let p = Parasitics::metal2();
        let l128 = p.worst_case_loss(128, 1.0 / 3.0, 0.1);
        let l512 = p.worst_case_loss(512, 1.0 / 3.0, 0.1);
        let ratio = l512 / l128;
        assert!(
            (ratio - 16.0).abs() < 1.5,
            "expected ~16× at 4× rows, got {ratio}"
        );
    }

    #[test]
    fn inactive_cells_draw_no_current() {
        let p = Parasitics { r_seg_ohm: 100.0 };
        let mut g = vec![0.0; 64];
        g[63] = 1.0 / 3.0; // one far cell active
        let v = p.effective_v_read(&g, 0.1);
        // Drop = 63 segments × its own current only.
        let i = 0.1 / 3.0; // µA
        let want = 0.1 - 63.0 * 100.0 * i * 1e-6;
        assert!((v[63] - want).abs() < 1e-9);
    }

    #[test]
    fn low_resistance_cells_would_break_scaling() {
        // Counterfactual: kΩ-class cells (ReRAM-like, G = 100 µS) lose
        // >50 % at 512 rows — the paper's motivation for MΩ MTJ stacks.
        let p = Parasitics::metal2();
        let loss = p.worst_case_loss(512, 100.0, 0.1);
        assert!(loss > 0.5, "loss {loss}");
    }
}

//! 128×128 3T-2MTJ crossbar array (paper §III-A, DESIGN.md S7).
//!
//! Row-major grid of series cells. Weights are programmed as 2-bit codes
//! through the SOT write path; reads expose per-cell conductance (with
//! optional cycle-to-cycle noise) and per-column conductance views that
//! the OSG consumes.

use crate::config::MacroConfig;
use crate::device::cell::Cell3T2J;
use crate::util::rng::Rng;

/// Programmed crossbar array.
#[derive(Debug, Clone)]
pub struct Crossbar {
    pub rows: usize,
    pub cols: usize,
    cells: Vec<Cell3T2J>,
    /// Cached conductance matrix (µS), row-major; rebuilt on programming.
    g_cache: Vec<f64>,
    /// Cached programmed codes, row-major; rebuilt on programming. The
    /// quantized level-plane engine (DESIGN.md S17) walks this 1-byte
    /// matrix instead of the 8-byte conductances.
    codes_cache: Vec<u8>,
    /// True iff every cell's conductance is *exactly* its level target
    /// (no device variation) — the precondition for the level-plane
    /// decomposition to be lossless.
    uniform_levels: bool,
    /// Target conductance per code (µS) from `cfg.level_map`.
    level_g: [f64; 4],
    /// Nominal device conductance per code (µS) for the 3T-2MTJ stack —
    /// used to carry per-cell variation over to hypothetical level maps
    /// (DESIGN.md §7 ablation): g = level_g[code] · (g_cell/dev_g[code]).
    dev_g: [f64; 4],
    /// Cycle-to-cycle read sigma (fraction), applied by `read_noisy`.
    sigma_c2c: f64,
    /// Total write pulses issued (endurance metric).
    pub write_pulses: u64,
}

impl Crossbar {
    /// Build an array of nominal cells (no variation), all code 0.
    pub fn new(cfg: &MacroConfig) -> Self {
        let mut cells = Vec::with_capacity(cfg.rows * cfg.cols);
        for _ in 0..cfg.rows * cfg.cols {
            let mut c = Cell3T2J::new(cfg.r_lrs_mohm, cfg.tmr);
            c.program(0);
            cells.push(c);
        }
        let mut xb = Crossbar {
            rows: cfg.rows,
            cols: cfg.cols,
            cells,
            g_cache: vec![0.0; cfg.rows * cfg.cols],
            codes_cache: vec![0; cfg.rows * cfg.cols],
            uniform_levels: false,
            level_g: Self::level_targets(cfg),
            dev_g: Self::device_levels(cfg),
            sigma_c2c: cfg.nonideal.sigma_r_c2c,
            write_pulses: 0,
        };
        xb.rebuild_cache();
        xb
    }

    /// Nominal series-stack conductances per code for this R_LRS.
    fn device_levels(cfg: &MacroConfig) -> [f64; 4] {
        let mut cell = Cell3T2J::new(cfg.r_lrs_mohm, cfg.tmr);
        let mut out = [0.0; 4];
        for code in 0..4u8 {
            cell.program(code);
            out[code as usize] = cell.conductance_us();
        }
        out
    }

    /// Target conductances per code from the configured level map,
    /// rescaled from the map's reference R_LRS = 1 MΩ to this config's.
    fn level_targets(cfg: &MacroConfig) -> [f64; 4] {
        let base = cfg.level_map.levels();
        let mut out = [0.0; 4];
        for (i, b) in base.iter().enumerate() {
            out[i] = b / cfg.r_lrs_mohm;
        }
        out
    }

    /// Build with frozen device-to-device variation (σ_R fraction).
    pub fn with_variation(cfg: &MacroConfig, rng: &mut Rng) -> Self {
        let sigma = cfg.nonideal.sigma_r_d2d;
        let mut cells = Vec::with_capacity(cfg.rows * cfg.cols);
        for _ in 0..cfg.rows * cfg.cols {
            let f1 = (1.0 + rng.normal_ms(0.0, sigma)).max(0.5);
            let f2 = (1.0 + rng.normal_ms(0.0, sigma)).max(0.5);
            let mut c = Cell3T2J::with_variation(cfg.r_lrs_mohm, cfg.tmr, f1, f2);
            c.program(0);
            cells.push(c);
        }
        let mut xb = Crossbar {
            rows: cfg.rows,
            cols: cfg.cols,
            cells,
            g_cache: vec![0.0; cfg.rows * cfg.cols],
            codes_cache: vec![0; cfg.rows * cfg.cols],
            uniform_levels: false,
            level_g: Self::level_targets(cfg),
            dev_g: Self::device_levels(cfg),
            sigma_c2c: cfg.nonideal.sigma_r_c2c,
            write_pulses: 0,
        };
        xb.rebuild_cache();
        xb
    }

    #[inline]
    fn idx(&self, row: usize, col: usize) -> usize {
        debug_assert!(row < self.rows && col < self.cols);
        row * self.cols + col
    }

    pub fn cell(&self, row: usize, col: usize) -> &Cell3T2J {
        &self.cells[self.idx(row, col)]
    }

    fn rebuild_cache(&mut self) {
        let mut uniform = true;
        for i in 0..self.cells.len() {
            let code = self.cells[i].code() as usize;
            // Device-true: level_g == dev_g, so this is exactly the cell
            // conductance. Hypothetical maps keep the cell's variation
            // ratio but move the nominal level.
            self.g_cache[i] = self.level_g[code]
                * (self.cells[i].conductance_us() / self.dev_g[code]);
            self.codes_cache[i] = code as u8;
            uniform &= self.g_cache[i] == self.level_g[code];
        }
        self.uniform_levels = uniform;
    }

    /// Program the whole array from a row-major code matrix (§III-A write:
    /// 2 junction writes per cell).
    pub fn program_codes(&mut self, codes: &[u8]) {
        assert_eq!(codes.len(), self.rows * self.cols, "code matrix shape");
        for (i, &code) in codes.iter().enumerate() {
            self.cells[i].program(code);
            self.write_pulses += 2;
        }
        self.rebuild_cache();
    }

    /// Read back the programmed codes (row-major).
    pub fn read_codes(&self) -> Vec<u8> {
        self.cells.iter().map(|c| c.code()).collect()
    }

    /// Nominal conductance at (row, col) in µS.
    #[inline]
    pub fn g_us(&self, row: usize, col: usize) -> f64 {
        self.g_cache[self.idx(row, col)]
    }

    /// Conductance with a fresh cycle-to-cycle noise sample.
    #[inline]
    pub fn g_us_noisy(&self, row: usize, col: usize, rng: &mut Rng) -> f64 {
        let g = self.g_us(row, col);
        if self.sigma_c2c == 0.0 {
            g
        } else {
            // Resistance noise → conductance divides.
            g / (1.0 + rng.normal_ms(0.0, self.sigma_c2c)).max(0.5)
        }
    }

    /// Row-major conductance matrix view (µS).
    pub fn conductances(&self) -> &[f64] {
        &self.g_cache
    }

    /// Row-major programmed-code matrix view (cached, no allocation —
    /// unlike [`read_codes`](Self::read_codes)).
    pub fn codes(&self) -> &[u8] {
        &self.codes_cache
    }

    /// The four per-code conductance targets (µS) of this array's level
    /// map at its R_LRS.
    pub fn levels(&self) -> [f64; 4] {
        self.level_g
    }

    /// True iff every cell sits *exactly* at its code's level target —
    /// the lossless-decomposition precondition of the quantized
    /// level-plane engine (DESIGN.md S17). False as soon as any
    /// device-to-device variation moved a conductance off its level.
    pub fn uniform_levels(&self) -> bool {
        self.uniform_levels
    }

    /// One column's conductances (µS), gathered.
    pub fn column_g(&self, col: usize) -> Vec<f64> {
        (0..self.rows).map(|r| self.g_us(r, col)).collect()
    }

    /// Exact digital MVM oracle on the nominal conductances:
    /// y[c] = Σ_r x[r]·G[r,c] (x in LSBs, result in LSB·µS).
    pub fn ideal_mvm(&self, x: &[u32]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows);
        let mut y = vec![0.0; self.cols];
        for r in 0..self.rows {
            let xv = x[r] as f64;
            if xv == 0.0 {
                continue;
            }
            let row = &self.g_cache[r * self.cols..(r + 1) * self.cols];
            for (c, &g) in row.iter().enumerate() {
                y[c] += xv * g;
            }
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::LevelMap;

    fn cfg() -> MacroConfig {
        MacroConfig::default()
    }

    fn small_cfg(rows: usize, cols: usize) -> MacroConfig {
        MacroConfig {
            rows,
            cols,
            ..MacroConfig::default()
        }
    }

    #[test]
    fn program_read_roundtrip() {
        let c = small_cfg(8, 8);
        let mut xb = Crossbar::new(&c);
        let codes: Vec<u8> = (0..64).map(|i| (i % 4) as u8).collect();
        xb.program_codes(&codes);
        assert_eq!(xb.read_codes(), codes);
        assert_eq!(xb.write_pulses, 128); // 2 junctions per cell
    }

    #[test]
    fn conductance_matches_level_map() {
        let c = small_cfg(4, 4);
        let levels = LevelMap::DeviceTrue.levels();
        let mut xb = Crossbar::new(&c);
        xb.program_codes(&[0, 1, 2, 3, 3, 2, 1, 0, 0, 0, 3, 3, 1, 1, 2, 2]);
        assert!((xb.g_us(0, 1) - levels[1]).abs() < 1e-12);
        assert!((xb.g_us(1, 0) - levels[3]).abs() < 1e-12);
        assert!((xb.g_us(3, 2) - levels[2]).abs() < 1e-12);
    }

    #[test]
    fn column_view_is_consistent() {
        let c = small_cfg(4, 4);
        let mut xb = Crossbar::new(&c);
        xb.program_codes(&(0..16).map(|i| (i % 4) as u8).collect::<Vec<_>>());
        let col2 = xb.column_g(2);
        for r in 0..4 {
            assert_eq!(col2[r], xb.g_us(r, 2));
        }
    }

    #[test]
    fn ideal_mvm_hand_computed() {
        let c = small_cfg(2, 2);
        let mut xb = Crossbar::new(&c);
        // codes [[3,0],[1,2]] → G [[1/3,1/6],[1/5,1/4]]
        xb.program_codes(&[3, 0, 1, 2]);
        let y = xb.ideal_mvm(&[2, 4]);
        assert!((y[0] - (2.0 / 3.0 + 4.0 / 5.0)).abs() < 1e-12);
        assert!((y[1] - (2.0 / 6.0 + 4.0 / 4.0)).abs() < 1e-12);
    }

    #[test]
    fn full_size_array_constructs() {
        let xb = Crossbar::new(&cfg());
        assert_eq!(xb.conductances().len(), 128 * 128);
    }

    #[test]
    fn d2d_variation_spreads_conductance() {
        let mut c = cfg();
        c.nonideal.sigma_r_d2d = 0.05;
        let mut rng = Rng::new(11);
        let mut xb = Crossbar::with_variation(&c, &mut rng);
        xb.program_codes(&vec![3u8; 128 * 128]);
        let gs = xb.conductances();
        let mean: f64 = gs.iter().sum::<f64>() / gs.len() as f64;
        let sd = (gs.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>()
            / gs.len() as f64)
            .sqrt();
        assert!(sd / mean > 0.02, "relative sd {}", sd / mean);
        assert!(sd / mean < 0.10);
    }

    #[test]
    fn c2c_noise_changes_reads_but_not_nominal() {
        let mut c = small_cfg(2, 2);
        c.nonideal.sigma_r_c2c = 0.05;
        let mut xb = Crossbar::new(&c);
        xb.program_codes(&[3, 3, 3, 3]);
        let mut rng = Rng::new(5);
        let a = xb.g_us_noisy(0, 0, &mut rng);
        let b = xb.g_us_noisy(0, 0, &mut rng);
        assert_ne!(a, b);
        assert_eq!(xb.g_us(0, 0), xb.g_us(0, 0)); // nominal stable
    }

    #[test]
    fn codes_view_matches_read_codes_without_alloc_per_read() {
        let mut xb = Crossbar::new(&small_cfg(4, 4));
        let codes: Vec<u8> = (0..16).map(|i| ((i * 3) % 4) as u8).collect();
        xb.program_codes(&codes);
        assert_eq!(xb.codes(), codes.as_slice());
        assert_eq!(xb.codes(), xb.read_codes().as_slice());
    }

    #[test]
    fn uniform_levels_tracks_device_variation() {
        let c = cfg();
        let mut ideal = Crossbar::new(&c);
        ideal.program_codes(&vec![2u8; 128 * 128]);
        assert!(ideal.uniform_levels());
        assert_eq!(ideal.levels(), LevelMap::DeviceTrue.levels());

        let mut vc = c.clone();
        vc.nonideal.sigma_r_d2d = 0.05;
        let mut rng = Rng::new(7);
        let mut varied = Crossbar::with_variation(&vc, &mut rng);
        varied.program_codes(&vec![2u8; 128 * 128]);
        assert!(!varied.uniform_levels());

        // σ = 0 variation is *exactly* nominal: still uniform.
        let mut rng = Rng::new(8);
        let mut zero_sigma = Crossbar::with_variation(&c, &mut rng);
        zero_sigma.program_codes(&vec![1u8; 128 * 128]);
        assert!(zero_sigma.uniform_levels());
    }

    #[test]
    #[should_panic(expected = "shape")]
    fn program_rejects_wrong_shape() {
        let mut xb = Crossbar::new(&small_cfg(2, 2));
        xb.program_codes(&[0, 1, 2]);
    }
}

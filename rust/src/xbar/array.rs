//! 128×128 3T-2MTJ crossbar array (paper §III-A, DESIGN.md S7).
//!
//! Row-major grid of series cells. Weights are programmed as 2-bit codes
//! through the SOT write path; reads expose per-cell conductance (with
//! optional cycle-to-cycle noise) and per-column conductance views that
//! the OSG consumes.

use crate::config::MacroConfig;
use crate::device::cell::Cell3T2J;
use crate::device::faults::ScrubOutcome;
use crate::device::mtj::MtjState;
use crate::device::retention::{corrupt_codes, RetentionParams};
use crate::device::write::{write_verify, SotWriteParams, WritePulse};
use crate::util::rng::Rng;

/// Programmed crossbar array.
#[derive(Debug, Clone)]
pub struct Crossbar {
    pub rows: usize,
    pub cols: usize,
    cells: Vec<Cell3T2J>,
    /// Cached conductance matrix (µS), row-major; rebuilt on programming.
    g_cache: Vec<f64>,
    /// Cached programmed codes, row-major; rebuilt on programming. The
    /// quantized level-plane engine (DESIGN.md S17) walks this 1-byte
    /// matrix instead of the 8-byte conductances.
    codes_cache: Vec<u8>,
    /// True iff every cell's conductance is *exactly* its level target
    /// (no device variation) — the precondition for the level-plane
    /// decomposition to be lossless.
    uniform_levels: bool,
    /// Target conductance per code (µS) from `cfg.level_map`.
    level_g: [f64; 4],
    /// Nominal device conductance per code (µS) for the 3T-2MTJ stack —
    /// used to carry per-cell variation over to hypothetical level maps
    /// (DESIGN.md §7 ablation): g = level_g[code] · (g_cell/dev_g[code]).
    dev_g: [f64; 4],
    /// Cycle-to-cycle read sigma (fraction), applied by `read_noisy`.
    sigma_c2c: f64,
    /// Total write pulses issued (endurance metric).
    pub write_pulses: u64,
}

impl Crossbar {
    /// Build an array of nominal cells (no variation), all code 0.
    pub fn new(cfg: &MacroConfig) -> Self {
        let mut cells = Vec::with_capacity(cfg.rows * cfg.cols);
        for _ in 0..cfg.rows * cfg.cols {
            let mut c = Cell3T2J::new(cfg.r_lrs_mohm, cfg.tmr);
            c.program(0);
            cells.push(c);
        }
        let mut xb = Crossbar {
            rows: cfg.rows,
            cols: cfg.cols,
            cells,
            g_cache: vec![0.0; cfg.rows * cfg.cols],
            codes_cache: vec![0; cfg.rows * cfg.cols],
            uniform_levels: false,
            level_g: Self::level_targets(cfg),
            dev_g: Self::device_levels(cfg),
            sigma_c2c: cfg.nonideal.sigma_r_c2c,
            write_pulses: 0,
        };
        xb.rebuild_cache();
        xb
    }

    /// Nominal series-stack conductances per code for this R_LRS.
    fn device_levels(cfg: &MacroConfig) -> [f64; 4] {
        let mut cell = Cell3T2J::new(cfg.r_lrs_mohm, cfg.tmr);
        let mut out = [0.0; 4];
        for code in 0..4u8 {
            cell.program(code);
            out[code as usize] = cell.conductance_us();
        }
        out
    }

    /// Target conductances per code from the configured level map,
    /// rescaled from the map's reference R_LRS = 1 MΩ to this config's.
    fn level_targets(cfg: &MacroConfig) -> [f64; 4] {
        let base = cfg.level_map.levels();
        let mut out = [0.0; 4];
        for (i, b) in base.iter().enumerate() {
            out[i] = b / cfg.r_lrs_mohm;
        }
        out
    }

    /// Build with frozen device-to-device variation (σ_R fraction).
    pub fn with_variation(cfg: &MacroConfig, rng: &mut Rng) -> Self {
        let sigma = cfg.nonideal.sigma_r_d2d;
        let mut cells = Vec::with_capacity(cfg.rows * cfg.cols);
        for _ in 0..cfg.rows * cfg.cols {
            let f1 = (1.0 + rng.normal_ms(0.0, sigma)).max(0.5);
            let f2 = (1.0 + rng.normal_ms(0.0, sigma)).max(0.5);
            let mut c = Cell3T2J::with_variation(cfg.r_lrs_mohm, cfg.tmr, f1, f2);
            c.program(0);
            cells.push(c);
        }
        let mut xb = Crossbar {
            rows: cfg.rows,
            cols: cfg.cols,
            cells,
            g_cache: vec![0.0; cfg.rows * cfg.cols],
            codes_cache: vec![0; cfg.rows * cfg.cols],
            uniform_levels: false,
            level_g: Self::level_targets(cfg),
            dev_g: Self::device_levels(cfg),
            sigma_c2c: cfg.nonideal.sigma_r_c2c,
            write_pulses: 0,
        };
        xb.rebuild_cache();
        xb
    }

    #[inline]
    fn idx(&self, row: usize, col: usize) -> usize {
        debug_assert!(row < self.rows && col < self.cols);
        row * self.cols + col
    }

    pub fn cell(&self, row: usize, col: usize) -> &Cell3T2J {
        &self.cells[self.idx(row, col)]
    }

    fn rebuild_cache(&mut self) {
        let mut uniform = true;
        for i in 0..self.cells.len() {
            let code = self.cells[i].code() as usize;
            // Device-true: level_g == dev_g, so this is exactly the cell
            // conductance. Hypothetical maps keep the cell's variation
            // ratio but move the nominal level.
            self.g_cache[i] = self.level_g[code]
                * (self.cells[i].conductance_us() / self.dev_g[code]);
            self.codes_cache[i] = code as u8;
            uniform &= self.g_cache[i] == self.level_g[code];
        }
        self.uniform_levels = uniform;
    }

    /// Program the whole array from a row-major code matrix (§III-A write:
    /// 2 junction writes per cell).
    pub fn program_codes(&mut self, codes: &[u8]) {
        assert_eq!(codes.len(), self.rows * self.cols, "code matrix shape");
        for (i, &code) in codes.iter().enumerate() {
            self.cells[i].program(code);
            self.write_pulses += 2;
        }
        self.rebuild_cache();
    }

    /// Read back the programmed codes (row-major).
    pub fn read_codes(&self) -> Vec<u8> {
        self.cells.iter().map(|c| c.code()).collect()
    }

    /// Nominal conductance at (row, col) in µS.
    #[inline]
    pub fn g_us(&self, row: usize, col: usize) -> f64 {
        self.g_cache[self.idx(row, col)]
    }

    /// Conductance with a fresh cycle-to-cycle noise sample.
    #[inline]
    pub fn g_us_noisy(&self, row: usize, col: usize, rng: &mut Rng) -> f64 {
        let g = self.g_us(row, col);
        if self.sigma_c2c == 0.0 {
            g
        } else {
            // Resistance noise → conductance divides.
            g / (1.0 + rng.normal_ms(0.0, self.sigma_c2c)).max(0.5)
        }
    }

    /// Row-major conductance matrix view (µS).
    pub fn conductances(&self) -> &[f64] {
        &self.g_cache
    }

    /// Row-major programmed-code matrix view (cached, no allocation —
    /// unlike [`read_codes`](Self::read_codes)).
    pub fn codes(&self) -> &[u8] {
        &self.codes_cache
    }

    /// The four per-code conductance targets (µS) of this array's level
    /// map at its R_LRS.
    pub fn levels(&self) -> [f64; 4] {
        self.level_g
    }

    /// True iff every cell sits *exactly* at its code's level target —
    /// the lossless-decomposition precondition of the quantized
    /// level-plane engine (DESIGN.md S17). False as soon as any
    /// device-to-device variation moved a conductance off its level.
    pub fn uniform_levels(&self) -> bool {
        self.uniform_levels
    }

    /// One column's conductances (µS), gathered.
    pub fn column_g(&self, col: usize) -> Vec<f64> {
        (0..self.rows).map(|r| self.g_us(r, col)).collect()
    }

    /// Flip a cell's junction states to read as `code` without issuing
    /// write pulses: fault injection is physics acting on the free
    /// layers, not a programming operation, so no wear is charged.
    fn set_code_silent(&mut self, i: usize, code: u8) {
        debug_assert!(code < 4);
        let c = &mut self.cells[i];
        c.j1.state = MtjState::from_bit(code & 1 == 0);
        c.j2.state = MtjState::from_bit(code & 2 == 0);
    }

    /// Retention drift over an idle window (DESIGN.md S19): junction
    /// states flip in place with the Arrhenius relaxation probability
    /// and the caches rebuild. R_P/TMR are untouched — a drifted array
    /// keeps `uniform_levels()` — and no wear accrues. Returns the
    /// number of cells whose code changed.
    pub fn corrupt_retention(
        &mut self,
        idle_ns: f64,
        params: &RetentionParams,
        rng: &mut Rng,
    ) -> usize {
        let mut codes = self.codes_cache.clone();
        let changed = corrupt_codes(&mut codes, idle_ns, params, rng);
        if changed > 0 {
            for i in 0..codes.len() {
                if codes[i] != self.codes_cache[i] {
                    self.set_code_silent(i, codes[i]);
                }
            }
            self.rebuild_cache();
        }
        changed
    }

    /// Pin cells at fixed codes (stuck-at faults): each `(index, code)`
    /// entry is forced silently. Returns how many cells actually
    /// changed (already-pinned cells are free).
    pub fn force_codes(&mut self, pins: &[(usize, u8)]) -> usize {
        let mut changed = 0;
        for &(i, code) in pins {
            if self.codes_cache[i] != code {
                self.set_code_silent(i, code);
                changed += 1;
            }
        }
        if changed > 0 {
            self.rebuild_cache();
        }
        changed
    }

    /// Freeze additional die-to-die variation into the live array:
    /// every junction's R_P is scaled by an independent (1 + N(0, σ))
    /// factor (floored at 0.5, matching `with_variation`). After this
    /// the array is no longer `uniform_levels()` (in all but measure-
    /// zero draws), which disqualifies the quantized engine.
    pub fn inject_gain_variation(&mut self, sigma: f64, rng: &mut Rng) {
        if sigma <= 0.0 {
            return;
        }
        for c in self.cells.iter_mut() {
            c.j1.r_p_mohm *= (1.0 + rng.normal_ms(0.0, sigma)).max(0.5);
            c.j2.r_p_mohm *= (1.0 + rng.normal_ms(0.0, sigma)).max(0.5);
        }
        self.rebuild_cache();
    }

    /// Scale every junction's R_P by one uniform factor (DESIGN.md
    /// S22 gain drift): the die-level analog gain moves while the
    /// stored codes stay exactly right, so a verify-and-rewrite scrub
    /// finds nothing to fix — only per-layer recalibration compensates.
    /// Breaks `uniform_levels()` for any `r_scale != 1`; no wear (the
    /// free layers never switch).
    pub fn scale_gain(&mut self, r_scale: f64) {
        assert!(r_scale > 0.0, "resistance scale must be positive");
        if r_scale == 1.0 {
            return;
        }
        for c in self.cells.iter_mut() {
            c.j1.r_p_mohm *= r_scale;
            c.j2.r_p_mohm *= r_scale;
        }
        self.rebuild_cache();
    }

    /// Verify-and-rewrite the array against a golden code snapshot:
    /// each mismatched junction gets verified SOT pulses at 1.5·I_c0
    /// overdrive (deterministic switching), charging I²·R·t energy and
    /// wear through `device::write`. Because drift never moves R_P, a
    /// completed scrub restores the pristine array bit-for-bit.
    pub fn scrub_to(
        &mut self,
        golden: &[u8],
        wp: &SotWriteParams,
        rng: &mut Rng,
    ) -> ScrubOutcome {
        assert_eq!(golden.len(), self.rows * self.cols, "code matrix shape");
        let mut out = ScrubOutcome {
            checked: golden.len(),
            ..ScrubOutcome::default()
        };
        let amp = 1.5 * wp.i_c0_ua;
        let mut touched = false;
        for (i, &want) in golden.iter().enumerate() {
            if self.codes_cache[i] == want {
                continue;
            }
            out.mismatched += 1;
            touched = true;
            let cell = &mut self.cells[i];
            for (bit_clear, j) in
                [(want & 1 == 0, &mut cell.j1), (want & 2 == 0, &mut cell.j2)]
            {
                let target = MtjState::from_bit(bit_clear);
                if j.state == target {
                    continue;
                }
                let sign = if target == MtjState::AntiParallel {
                    1.0
                } else {
                    -1.0
                };
                let pulse = WritePulse {
                    i_ua: sign * amp,
                    t_ns: 2.0,
                };
                let (_, tries, energy) = write_verify(j, wp, &pulse, rng, 8);
                out.junction_pulses += tries as u64;
                self.write_pulses += tries as u64;
                out.energy_fj += energy;
            }
            if cell.code() == want {
                out.repaired += 1;
            }
        }
        if touched {
            self.rebuild_cache();
        }
        out
    }

    /// Exact digital MVM oracle on the nominal conductances:
    /// y[c] = Σ_r x[r]·G[r,c] (x in LSBs, result in LSB·µS).
    pub fn ideal_mvm(&self, x: &[u32]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows);
        let mut y = vec![0.0; self.cols];
        for r in 0..self.rows {
            let xv = x[r] as f64;
            if xv == 0.0 {
                continue;
            }
            let row = &self.g_cache[r * self.cols..(r + 1) * self.cols];
            for (c, &g) in row.iter().enumerate() {
                y[c] += xv * g;
            }
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::LevelMap;

    fn cfg() -> MacroConfig {
        MacroConfig::default()
    }

    fn small_cfg(rows: usize, cols: usize) -> MacroConfig {
        MacroConfig {
            rows,
            cols,
            ..MacroConfig::default()
        }
    }

    #[test]
    fn program_read_roundtrip() {
        let c = small_cfg(8, 8);
        let mut xb = Crossbar::new(&c);
        let codes: Vec<u8> = (0..64).map(|i| (i % 4) as u8).collect();
        xb.program_codes(&codes);
        assert_eq!(xb.read_codes(), codes);
        assert_eq!(xb.write_pulses, 128); // 2 junctions per cell
    }

    #[test]
    fn conductance_matches_level_map() {
        let c = small_cfg(4, 4);
        let levels = LevelMap::DeviceTrue.levels();
        let mut xb = Crossbar::new(&c);
        xb.program_codes(&[0, 1, 2, 3, 3, 2, 1, 0, 0, 0, 3, 3, 1, 1, 2, 2]);
        assert!((xb.g_us(0, 1) - levels[1]).abs() < 1e-12);
        assert!((xb.g_us(1, 0) - levels[3]).abs() < 1e-12);
        assert!((xb.g_us(3, 2) - levels[2]).abs() < 1e-12);
    }

    #[test]
    fn column_view_is_consistent() {
        let c = small_cfg(4, 4);
        let mut xb = Crossbar::new(&c);
        xb.program_codes(&(0..16).map(|i| (i % 4) as u8).collect::<Vec<_>>());
        let col2 = xb.column_g(2);
        for r in 0..4 {
            assert_eq!(col2[r], xb.g_us(r, 2));
        }
    }

    #[test]
    fn ideal_mvm_hand_computed() {
        let c = small_cfg(2, 2);
        let mut xb = Crossbar::new(&c);
        // codes [[3,0],[1,2]] → G [[1/3,1/6],[1/5,1/4]]
        xb.program_codes(&[3, 0, 1, 2]);
        let y = xb.ideal_mvm(&[2, 4]);
        assert!((y[0] - (2.0 / 3.0 + 4.0 / 5.0)).abs() < 1e-12);
        assert!((y[1] - (2.0 / 6.0 + 4.0 / 4.0)).abs() < 1e-12);
    }

    #[test]
    fn full_size_array_constructs() {
        let xb = Crossbar::new(&cfg());
        assert_eq!(xb.conductances().len(), 128 * 128);
    }

    #[test]
    fn d2d_variation_spreads_conductance() {
        let mut c = cfg();
        c.nonideal.sigma_r_d2d = 0.05;
        let mut rng = Rng::new(11);
        let mut xb = Crossbar::with_variation(&c, &mut rng);
        xb.program_codes(&vec![3u8; 128 * 128]);
        let gs = xb.conductances();
        let mean: f64 = gs.iter().sum::<f64>() / gs.len() as f64;
        let sd = (gs.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>()
            / gs.len() as f64)
            .sqrt();
        assert!(sd / mean > 0.02, "relative sd {}", sd / mean);
        assert!(sd / mean < 0.10);
    }

    #[test]
    fn c2c_noise_changes_reads_but_not_nominal() {
        let mut c = small_cfg(2, 2);
        c.nonideal.sigma_r_c2c = 0.05;
        let mut xb = Crossbar::new(&c);
        xb.program_codes(&[3, 3, 3, 3]);
        let mut rng = Rng::new(5);
        let a = xb.g_us_noisy(0, 0, &mut rng);
        let b = xb.g_us_noisy(0, 0, &mut rng);
        assert_ne!(a, b);
        assert_eq!(xb.g_us(0, 0), xb.g_us(0, 0)); // nominal stable
    }

    #[test]
    fn codes_view_matches_read_codes_without_alloc_per_read() {
        let mut xb = Crossbar::new(&small_cfg(4, 4));
        let codes: Vec<u8> = (0..16).map(|i| ((i * 3) % 4) as u8).collect();
        xb.program_codes(&codes);
        assert_eq!(xb.codes(), codes.as_slice());
        assert_eq!(xb.codes(), xb.read_codes().as_slice());
    }

    #[test]
    fn uniform_levels_tracks_device_variation() {
        let c = cfg();
        let mut ideal = Crossbar::new(&c);
        ideal.program_codes(&vec![2u8; 128 * 128]);
        assert!(ideal.uniform_levels());
        assert_eq!(ideal.levels(), LevelMap::DeviceTrue.levels());

        let mut vc = c.clone();
        vc.nonideal.sigma_r_d2d = 0.05;
        let mut rng = Rng::new(7);
        let mut varied = Crossbar::with_variation(&vc, &mut rng);
        varied.program_codes(&vec![2u8; 128 * 128]);
        assert!(!varied.uniform_levels());

        // σ = 0 variation is *exactly* nominal: still uniform.
        let mut rng = Rng::new(8);
        let mut zero_sigma = Crossbar::with_variation(&c, &mut rng);
        zero_sigma.program_codes(&vec![1u8; 128 * 128]);
        assert!(zero_sigma.uniform_levels());
    }

    #[test]
    #[should_panic(expected = "shape")]
    fn program_rejects_wrong_shape() {
        let mut xb = Crossbar::new(&small_cfg(2, 2));
        xb.program_codes(&[0, 1, 2]);
    }

    #[test]
    fn retention_corruption_carries_no_wear_and_keeps_levels() {
        use crate::device::retention::RetentionParams;
        let c = small_cfg(8, 8);
        let mut xb = Crossbar::new(&c);
        let golden: Vec<u8> = (0..64).map(|i| (i % 4) as u8).collect();
        xb.program_codes(&golden);
        let pulses_before = xb.write_pulses;
        let j_writes_before = xb.cell(0, 0).j1.writes;
        let ret = RetentionParams::stress();
        let mut rng = Rng::new(99);
        let flipped = xb.corrupt_retention(ret.tau_ret_ns(), &ret, &mut rng);
        assert!(flipped > 0, "t = τ at the stress corner must flip cells");
        assert_ne!(xb.read_codes(), golden);
        // Drift is physics, not programming: zero wear, codes cache
        // coherent with junction states, levels still uniform.
        assert_eq!(xb.write_pulses, pulses_before);
        assert_eq!(xb.cell(0, 0).j1.writes, j_writes_before);
        assert_eq!(xb.codes(), xb.read_codes().as_slice());
        assert!(xb.uniform_levels());
    }

    #[test]
    fn scrub_restores_pristine_array_bitwise() {
        use crate::device::retention::RetentionParams;
        use crate::device::write::SotWriteParams;
        let c = small_cfg(8, 8);
        let mut pristine = Crossbar::new(&c);
        let golden: Vec<u8> = (0..64).map(|i| ((i * 7) % 4) as u8).collect();
        pristine.program_codes(&golden);
        let mut xb = pristine.clone();
        let ret = RetentionParams::stress();
        let mut rng = Rng::new(5);
        let flipped = xb.corrupt_retention(ret.tau_ret_ns(), &ret, &mut rng);
        assert!(flipped > 0);
        let wp = SotWriteParams::default();
        let out = xb.scrub_to(&golden, &wp, &mut rng);
        assert_eq!(out.checked, 64);
        assert_eq!(out.mismatched, flipped);
        assert_eq!(out.repaired, flipped, "overdrive scrub is deterministic");
        assert!(out.junction_pulses > 0);
        assert!(out.energy_fj > 0.0, "scrub writes must cost energy");
        // Bit-identical to the never-drifted array: codes, conductances,
        // level uniformity (drift never moved R_P).
        assert_eq!(xb.read_codes(), golden);
        assert_eq!(xb.conductances(), pristine.conductances());
        assert!(xb.uniform_levels());
        // Wear landed: the scrubbed array has more write pulses.
        assert_eq!(
            xb.write_pulses,
            pristine.write_pulses + out.junction_pulses
        );
    }

    #[test]
    fn forced_codes_pin_without_wear() {
        let c = small_cfg(4, 4);
        let mut xb = Crossbar::new(&c);
        xb.program_codes(&[1u8; 16]);
        let pulses = xb.write_pulses;
        let changed = xb.force_codes(&[(0, 0), (5, 3), (7, 1)]);
        assert_eq!(changed, 2, "cell 7 already holds code 1");
        assert_eq!(xb.codes()[0], 0);
        assert_eq!(xb.codes()[5], 3);
        assert_eq!(xb.write_pulses, pulses);
    }

    #[test]
    fn scale_gain_is_uniform_wearless_and_scrubproof() {
        use crate::device::write::SotWriteParams;
        let c = small_cfg(8, 8);
        let mut xb = Crossbar::new(&c);
        let golden: Vec<u8> = (0..64).map(|i| (i % 4) as u8).collect();
        xb.program_codes(&golden);
        let g_before = xb.conductances().to_vec();
        let pulses = xb.write_pulses;
        // R scaled up 25 % ⇒ conductance down by exactly 1/1.25.
        xb.scale_gain(1.25);
        assert_eq!(xb.read_codes(), golden, "codes never move");
        assert_eq!(xb.write_pulses, pulses, "no wear");
        assert!(!xb.uniform_levels());
        for (g, g0) in xb.conductances().iter().zip(&g_before) {
            assert!((g / g0 - 1.0 / 1.25).abs() < 1e-12);
        }
        // The codes are golden, so a scrub pass is a certain no-op.
        let mut rng = Rng::new(1);
        let out = xb.scrub_to(&golden, &SotWriteParams::default(), &mut rng);
        assert_eq!(out.mismatched, 0);
        assert_eq!(out.energy_fj, 0.0);
        // Unity scale is an exact no-op (no cache churn either).
        let g_now = xb.conductances().to_vec();
        xb.scale_gain(1.0);
        assert_eq!(xb.conductances(), g_now.as_slice());
    }

    #[test]
    fn injected_gain_variation_breaks_uniform_levels() {
        let c = small_cfg(8, 8);
        let mut xb = Crossbar::new(&c);
        xb.program_codes(&[2u8; 64]);
        assert!(xb.uniform_levels());
        let mut rng = Rng::new(3);
        xb.inject_gain_variation(0.05, &mut rng);
        assert!(!xb.uniform_levels());
        // Codes are untouched — only the analog levels moved.
        assert_eq!(xb.read_codes(), [2u8; 64]);
    }
}

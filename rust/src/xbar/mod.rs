//! SOT-MRAM crossbar array substrate (DESIGN.md S7).

pub mod array;
pub mod parasitics;

pub use array::Crossbar;
pub use parasitics::Parasitics;

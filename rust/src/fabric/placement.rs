//! Weight placement (DESIGN.md S15): shard multi-layer tiled weights
//! onto the fabric mesh, weight-stationary, with a serpentine
//! locality-aware scan so consecutive layers land on adjacent tiles.
//!
//! Policy: tiles are visited in boustrophedon order (row 0 left→right,
//! row 1 right→left, …) and layers claim tiles contiguously, shards in
//! (ti, tj) ti-major order. Consecutive scan positions are always
//! grid-adjacent, so layer *l*'s last shard neighbours layer *l+1*'s
//! head — the inter-layer egress hop is short by construction.
//!
//! Invariants (unit-tested): every shard is placed, every tile carries
//! at most one shard, and placement fails loudly when the workload
//! exceeds the mesh (no silent time-multiplexing at this layer).

use anyhow::{ensure, Result};

use crate::config::FabricConfig;

use super::noc::TileCoord;

/// Identity of one weight shard: `layer`'s tile (ti, tj).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardId {
    pub layer: usize,
    pub ti: usize,
    pub tj: usize,
}

/// A complete, validated shard→tile assignment.
#[derive(Debug, Clone)]
pub struct Placement {
    pub grid_x: usize,
    pub grid_y: usize,
    /// Per layer, shard order (ti-major): the tile each shard occupies.
    pub per_layer: Vec<Vec<TileCoord>>,
    /// Grid index (y·grid_x + x) → occupying shard.
    pub assign: Vec<Option<ShardId>>,
}

/// Serpentine (boustrophedon) scan order of the mesh.
pub fn serpentine(grid_x: usize, grid_y: usize) -> Vec<TileCoord> {
    let mut order = Vec::with_capacity(grid_x * grid_y);
    for y in 0..grid_y {
        for i in 0..grid_x {
            let x = if y % 2 == 0 { i } else { grid_x - 1 - i };
            order.push(TileCoord { x, y });
        }
    }
    order
}

/// Place every layer's (row_tiles × col_tiles) shards onto the mesh.
pub fn place(
    shapes: &[(usize, usize)],
    f: &FabricConfig,
) -> Result<Placement> {
    ensure!(f.grid_x > 0 && f.grid_y > 0, "empty fabric grid");
    ensure!(
        f.io_tile.0 < f.grid_x && f.io_tile.1 < f.grid_y,
        "io_tile ({}, {}) outside the {}×{} grid",
        f.io_tile.0,
        f.io_tile.1,
        f.grid_x,
        f.grid_y
    );
    let total: usize = shapes.iter().map(|&(rt, ct)| rt * ct).sum();
    ensure!(total > 0, "nothing to place");
    ensure!(
        total <= f.tiles(),
        "{total} weight shards exceed the {}×{} fabric ({} tiles)",
        f.grid_x,
        f.grid_y,
        f.tiles()
    );
    let order = serpentine(f.grid_x, f.grid_y);
    let mut assign = vec![None; f.tiles()];
    let mut per_layer = Vec::with_capacity(shapes.len());
    let mut next = 0usize;
    for (layer, &(rt, ct)) in shapes.iter().enumerate() {
        let mut locs = Vec::with_capacity(rt * ct);
        for ti in 0..rt {
            for tj in 0..ct {
                let coord = order[next];
                next += 1;
                assign[coord.index(f.grid_x)] =
                    Some(ShardId { layer, ti, tj });
                locs.push(coord);
            }
        }
        per_layer.push(locs);
    }
    Ok(Placement {
        grid_x: f.grid_x,
        grid_y: f.grid_y,
        per_layer,
        assign,
    })
}

impl Placement {
    /// (occupied tiles, total tiles).
    pub fn utilization(&self) -> (usize, usize) {
        (
            self.assign.iter().filter(|a| a.is_some()).count(),
            self.assign.len(),
        )
    }

    /// First tile of a layer — its NoC entry point.
    pub fn head(&self, layer: usize) -> TileCoord {
        self.per_layer[layer][0]
    }

    /// ASCII map of the mesh (rows top to bottom): `L<l>.<ti>.<tj>` per
    /// occupied tile, `·` per free tile.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for y in 0..self.grid_y {
            for x in 0..self.grid_x {
                let cell = match self.assign
                    [TileCoord { x, y }.index(self.grid_x)]
                {
                    Some(s) => format!("L{}.{}.{}", s.layer, s.ti, s.tj),
                    None => "·".to_string(),
                };
                out.push_str(&format!("{cell:>7} "));
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serpentine_steps_are_grid_adjacent() {
        let order = serpentine(4, 3);
        assert_eq!(order.len(), 12);
        assert!(order.windows(2).all(|w| w[0].hops(w[1]) == 1));
        // All coordinates distinct.
        let mut seen = order.clone();
        seen.sort();
        seen.dedup();
        assert_eq!(seen.len(), 12);
    }

    #[test]
    fn every_shard_placed_each_tile_at_most_once() {
        let shapes = [(2usize, 2usize), (3, 1), (1, 1)];
        let f = FabricConfig::square(4);
        let p = place(&shapes, &f).unwrap();
        assert_eq!(p.per_layer[0].len(), 4);
        assert_eq!(p.per_layer[1].len(), 3);
        assert_eq!(p.per_layer[2].len(), 1);
        assert_eq!(p.utilization(), (8, 16));
        // Assigned coords are pairwise distinct across all layers.
        let mut all: Vec<TileCoord> =
            p.per_layer.iter().flatten().copied().collect();
        all.sort();
        all.dedup();
        assert_eq!(all.len(), 8);
        // assign[] mirrors per_layer with ti-major shard order.
        for (layer, locs) in p.per_layer.iter().enumerate() {
            let ct = shapes[layer].1;
            for (s, loc) in locs.iter().enumerate() {
                let got = p.assign[loc.index(p.grid_x)].unwrap();
                assert_eq!(
                    (got.layer, got.ti, got.tj),
                    (layer, s / ct, s % ct)
                );
            }
        }
    }

    #[test]
    fn consecutive_layers_are_neighbours() {
        let f = FabricConfig::square(3);
        let p = place(&[(2, 2), (1, 1)], &f).unwrap();
        let last0 = *p.per_layer[0].last().unwrap();
        assert_eq!(
            last0.hops(p.head(1)),
            1,
            "locality-aware: next layer's head adjoins this layer's tail"
        );
    }

    #[test]
    fn overflow_is_an_error() {
        let f = FabricConfig::square(2);
        let err = place(&[(2, 2), (1, 1)], &f).unwrap_err();
        assert!(err.to_string().contains("exceed"), "{err}");
        assert!(place(&[], &f).is_err());
    }

    #[test]
    fn render_shows_shards_and_free_tiles() {
        let f = FabricConfig::square(2);
        let p = place(&[(1, 2), (1, 1)], &f).unwrap();
        let s = p.render();
        assert!(s.contains("L0.0.0"));
        assert!(s.contains("L0.0.1"));
        assert!(s.contains("L1.0.0"));
        assert!(s.contains('·'));
    }
}

//! X-Y mesh NoC model (DESIGN.md S15): deterministic dimension-ordered
//! routing of spike packets with per-hop latency/energy costs.
//!
//! The model is event-driven at packet granularity and congestion-free:
//! a packet's cost is `flits · hops · E_hop` energy and `hops · T_hop`
//! store-and-forward latency, and packets that carry no information —
//! zero-hop local delivery, or slices with no spikes — cost nothing.
//! Contention/backpressure is out of scope at this altitude (the fabric
//! phases below serialize around compute anyway); DESIGN.md S15 records
//! the assumption.

use crate::config::FabricConfig;

/// A tile position on the fabric mesh.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct TileCoord {
    pub x: usize,
    pub y: usize,
}

impl TileCoord {
    /// Row-major grid index.
    pub fn index(self, grid_x: usize) -> usize {
        self.y * grid_x + self.x
    }

    /// Manhattan hop distance (X-Y routes are minimal).
    pub fn hops(self, other: TileCoord) -> u64 {
        (self.x.abs_diff(other.x) + self.y.abs_diff(other.y)) as u64
    }
}

/// One logical spike packet: a burst of spike-coded values moving from
/// `src` to `dst`. Multicast is modeled as one packet per destination.
#[derive(Debug, Clone, Copy)]
pub struct SpikePacket {
    pub src: TileCoord,
    pub dst: TileCoord,
    pub payload_bits: u64,
}

impl SpikePacket {
    pub fn hops(&self) -> u64 {
        self.src.hops(self.dst)
    }

    /// Flits on the wire: header + payload, rounded up to flit width.
    pub fn flits(&self, f: &FabricConfig) -> u64 {
        (self.payload_bits + f.header_bits as u64)
            .div_ceil(f.flit_bits as u64)
    }

    /// Link+router energy over the whole route (fJ).
    pub fn energy_fj(&self, f: &FabricConfig) -> f64 {
        (self.flits(f) * self.hops()) as f64 * f.hop_energy_fj
    }

    /// Delivery latency (ns), store-and-forward per router.
    pub fn latency_ns(&self, f: &FabricConfig) -> f64 {
        self.hops() as f64 * f.hop_latency_ns
    }
}

/// The deterministic X-then-Y route, inclusive of `src` and `dst`.
pub fn xy_route(src: TileCoord, dst: TileCoord) -> Vec<TileCoord> {
    let mut path = vec![src];
    let mut cur = src;
    while cur.x != dst.x {
        cur.x = if dst.x > cur.x { cur.x + 1 } else { cur.x - 1 };
        path.push(cur);
    }
    while cur.y != dst.y {
        cur.y = if dst.y > cur.y { cur.y + 1 } else { cur.y - 1 };
        path.push(cur);
    }
    path
}

#[cfg(test)]
mod tests {
    use super::*;

    const A: TileCoord = TileCoord { x: 1, y: 2 };
    const B: TileCoord = TileCoord { x: 4, y: 0 };

    #[test]
    fn route_is_minimal_and_deterministic() {
        let r1 = xy_route(A, B);
        let r2 = xy_route(A, B);
        assert_eq!(r1, r2, "routing must be deterministic");
        assert_eq!(r1.len() as u64, A.hops(B) + 1);
        assert_eq!(r1.first(), Some(&A));
        assert_eq!(r1.last(), Some(&B));
        // Every step moves exactly one hop.
        assert!(r1.windows(2).all(|w| w[0].hops(w[1]) == 1));
    }

    #[test]
    fn route_resolves_x_before_y() {
        let r = xy_route(A, B);
        // Once y starts changing, x must already be at the destination.
        let mut y_started = false;
        for w in r.windows(2) {
            if w[0].y != w[1].y {
                y_started = true;
            }
            if y_started {
                assert_eq!(w[0].x, B.x, "x settled before y turns");
            }
        }
    }

    #[test]
    fn hops_are_symmetric_and_zero_on_self() {
        assert_eq!(A.hops(B), B.hops(A));
        assert_eq!(A.hops(B), 5);
        assert_eq!(A.hops(A), 0);
        assert_eq!(xy_route(A, A), vec![A]);
    }

    #[test]
    fn flit_and_cost_arithmetic() {
        let f = FabricConfig::default(); // 64-bit flits, 32-bit header
        let p = SpikePacket {
            src: A,
            dst: B,
            payload_bits: 1024,
        };
        assert_eq!(p.flits(&f), (1024 + 32u64).div_ceil(64)); // 17
        assert_eq!(p.energy_fj(&f), (17 * 5) as f64 * f.hop_energy_fj);
        assert_eq!(p.latency_ns(&f), 5.0 * f.hop_latency_ns);
        // A 1-bit payload still needs one flit.
        let tiny = SpikePacket {
            payload_bits: 1,
            ..p
        };
        assert_eq!(tiny.flits(&f), 1);
        // Zero-hop delivery costs nothing.
        let local = SpikePacket {
            dst: A,
            ..p
        };
        assert_eq!(local.energy_fj(&f), 0.0);
        assert_eq!(local.latency_ns(&f), 0.0);
    }
}

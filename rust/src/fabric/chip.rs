//! The fabric chip (DESIGN.md S15): a mesh of weight-stationary
//! `CimMacro` tiles executing tiled layers as routed spike packets.
//!
//! One layer forward runs in five NoC phases, each priced by the S15
//! cost model and folded into the op's `EnergyBreakdown` (`noc_fj`):
//!
//! 1. **ingress** — the input vector reaches the layer head (chip I/O
//!    port for layer 0; inner layers receive it from the upstream
//!    egress, which already paid the hops),
//! 2. **distribute** — the head unicasts each row-tile slice to the
//!    shards that consume it (all-zero slices emit no spikes, hence no
//!    packets: the NoC is as event-driven as the array),
//! 3. **compute** — every shard's MVM, physically concurrent tiles
//!    (scoped worker threads make wall-clock match the model),
//! 4. **gather** — row tiles ti>0 stream partials to their column-head
//!    shard (0, tj),
//! 5. **egress** — column heads forward accumulated segments to the
//!    next layer's head (or back to the I/O port).
//!
//! Latency is the phase-sequential critical path: max-hop delivery per
//! NoC phase plus the slowest tile's conversion. Partials come back in
//! deterministic (ti, tj) order so `TiledMatrix::accumulate` reproduces
//! the single-macro tiling bit for bit.

use anyhow::{ensure, Result};

use crate::config::{FabricConfig, MacroConfig};
use crate::coordinator::TiledMatrix;
use crate::energy::EnergyBreakdown;
use crate::macro_model::{
    mvm_events_parallel, mvm_tiled_batch_strided, CimMacro, TiledBatchItem,
};
use crate::obs::{self, TraceKind};

use super::noc::{SpikePacket, TileCoord};
use super::placement::{place, Placement};

/// Cumulative NoC traffic counters (whole chip, or one drained interval).
#[derive(Debug, Clone, Copy, Default)]
pub struct FabricStats {
    pub packets: u64,
    pub flits: u64,
    pub hops: u64,
    pub noc_fj: f64,
    /// Layer-0 forwards seen (≈ inferences for a multi-layer chip).
    pub mvms: u64,
    /// Macro row activations across all forwards (DESIGN.md S17) — the
    /// event-driven occupancy gauge the serving metrics surface.
    pub active_rows: u64,
}

/// Result of one layer forward on the fabric.
#[derive(Debug, Clone)]
pub struct LayerResult {
    /// Per-tile MAC partials in (ti, tj) order — `partials[ti][tj]` is
    /// that shard's column output, ready for `TiledMatrix::accumulate`.
    pub partials: Vec<Vec<Vec<f64>>>,
    /// Tile compute energy plus this layer's NoC traffic (`noc_fj`).
    pub energy: EnergyBreakdown,
    /// Modeled critical path: ingress + distribute + slowest tile +
    /// gather + egress (ns).
    pub latency_ns: f64,
    pub packets: u64,
    pub flits: u64,
    pub hops: u64,
    /// Macro row activations summed over this layer's shards
    /// (DESIGN.md S17): each active input row fires once per column
    /// tile it feeds; 0 for an all-silent input.
    pub active_rows: u64,
}

/// Account one unicast packet; returns its delivery latency. Zero-hop
/// (local) delivery is free and uncounted.
fn send(
    f: &FabricConfig,
    src: TileCoord,
    dst: TileCoord,
    payload_bits: u64,
    energy: &mut EnergyBreakdown,
    tally: &mut FabricStats,
) -> f64 {
    let pkt = SpikePacket {
        src,
        dst,
        payload_bits,
    };
    let hops = pkt.hops();
    if hops == 0 {
        return 0.0;
    }
    tally.packets += 1;
    tally.flits += pkt.flits(f);
    tally.hops += hops;
    let e = pkt.energy_fj(f);
    tally.noc_fj += e;
    energy.noc_fj += e;
    pkt.latency_ns(f)
}

/// One layer's slice of the chip: its shard macros (ti-major order),
/// their mesh locations, and the routing endpoints. Owns everything it
/// needs so the dataflow executor can run it on its own thread.
pub struct LayerStage {
    pub tiled: TiledMatrix,
    macros: Vec<CimMacro>,
    locs: Vec<TileCoord>,
    /// Where inputs are delivered from (`Some` only for layer 0 — inner
    /// layers receive at their head via the upstream egress).
    ingress: Option<TileCoord>,
    /// Where outputs go: the next layer's head, or the chip I/O port.
    egress: TileCoord,
    fabric: FabricConfig,
    /// Reusable per-row-tile flat slice batches (`[batch × tile]` each,
    /// DESIGN.md S17): refilled per `run_batch*` call, so the steady
    /// state allocates no per-item `Vec`s.
    xparts: Vec<Vec<u32>>,
    /// Reusable per-row-tile event sublists (DESIGN.md S18): refilled
    /// per [`run_events`](Self::run_events) call with tile-local row
    /// indices.
    eparts: Vec<Vec<u32>>,
}

/// One input's routed NoC phases (everything but compute): the latency
/// contributions in phase order plus the traffic charged. Compute is
/// independent of routing, so [`LayerStage::run_batch`] prices each
/// item's phases with exactly the same per-packet model as the serial
/// [`LayerStage::run`].
struct RoutedPhases {
    /// Ingress + distribute latency (phases 1–2, before compute).
    lat_pre: f64,
    /// Gather latency (phase 4).
    t_gather: f64,
    /// Egress latency (phase 5).
    t_egress: f64,
    /// NoC energy of all four routed phases.
    energy: EnergyBreakdown,
    tally: FabricStats,
}

impl LayerStage {
    /// This layer's NoC entry point.
    pub fn head(&self) -> TileCoord {
        self.locs[0]
    }

    /// This stage's shard macros (ti-major order) — read access for
    /// golden-code snapshots (DESIGN.md S19).
    pub fn macros(&self) -> &[CimMacro] {
        &self.macros
    }

    /// Mutable shard access for the reliability runtime (DESIGN.md
    /// S19): fault injection and scrubbing mutate deployed arrays in
    /// place. Weights-as-computed change, so callers own the
    /// consistency of anything derived from the old conductances.
    pub fn macros_mut(&mut self) -> &mut [CimMacro] {
        &mut self.macros
    }

    /// Price the four NoC phases of one input vector (ingress,
    /// distribute, gather, egress) from its per-row-tile slices.
    fn route<P: AsRef<[u32]>>(&self, xparts: &[P]) -> RoutedPhases {
        // Per-row-tile spike activity: a silent slice produces no input
        // spikes *and* no output spikes at its shards (the flag never
        // rises, so the OSGs never fire) — such shards route nothing in
        // either direction.
        let slice_active: Vec<bool> = xparts
            .iter()
            .map(|p| p.as_ref().iter().any(|&v| v > 0))
            .collect();
        self.route_flags(&slice_active)
    }

    /// The routed-phase pricing behind [`route`](Self::route), from
    /// per-row-tile activity flags alone (DESIGN.md S18): packet sizes
    /// depend only on the (padded) slice length `tiled.tile` and the
    /// layer width `tiled.k`, never on the values — so the binary-spike
    /// path ([`run_events`](Self::run_events)) prices its traffic with
    /// exactly the per-packet model the value path uses.
    fn route_flags(&self, slice_active: &[bool]) -> RoutedPhases {
        // S20 span: one vector's 4 routed NoC phases; payload records
        // the packets and hops this routing priced.
        let mut span = obs::Span::begin(TraceKind::NocRoute, 0);
        let ct = self.tiled.col_tiles;
        let head = self.locs[0];
        let mut tally = FabricStats::default();
        let mut energy = EnergyBreakdown::default();
        let mut lat_pre = 0.0f64;
        let active = slice_active.iter().any(|&a| a);

        // Phase 1 — ingress.
        if active {
            if let Some(port) = self.ingress {
                let bits = self.fabric.in_value_bits as u64
                    * self.tiled.k as u64;
                lat_pre +=
                    send(&self.fabric, port, head, bits, &mut energy, &mut tally);
            }
        }

        // Phase 2 — distribute row-tile slices (skip silent slices).
        let mut t_dist = 0.0f64;
        if active {
            for (sidx, &loc) in self.locs.iter().enumerate() {
                if !slice_active[sidx / ct] {
                    continue;
                }
                // Slices are zero-padded to the tile size, so every
                // distribute packet carries `tile` values.
                let bits = self.fabric.in_value_bits as u64
                    * self.tiled.tile as u64;
                t_dist = t_dist.max(send(
                    &self.fabric,
                    head,
                    loc,
                    bits,
                    &mut energy,
                    &mut tally,
                ));
            }
        }
        lat_pre += t_dist;

        // Phases 4+5 — gather partials to column heads, then egress. An
        // all-silent layer emits only zero-interval (no-information)
        // output pairs, which the event-driven NoC suppresses.
        let part_bits =
            self.fabric.out_value_bits as u64 * self.tiled.tile as u64;
        let mut t_gather = 0.0f64;
        let mut t_egress = 0.0f64;
        if active {
            for sidx in ct..self.locs.len() {
                if !slice_active[sidx / ct] {
                    continue; // silent shard: no output spikes to gather
                }
                let tj = sidx % ct; // column head = shard (0, tj)
                t_gather = t_gather.max(send(
                    &self.fabric,
                    self.locs[sidx],
                    self.locs[tj],
                    part_bits,
                    &mut energy,
                    &mut tally,
                ));
            }
            for tj in 0..ct {
                t_egress = t_egress.max(send(
                    &self.fabric,
                    self.locs[tj],
                    self.egress,
                    part_bits,
                    &mut energy,
                    &mut tally,
                ));
            }
        }

        span.note(tally.packets as f64, tally.hops as f64);
        RoutedPhases {
            lat_pre,
            t_gather,
            t_egress,
            energy,
            tally,
        }
    }

    /// Fold routed phases and tile compute into one [`LayerResult`],
    /// keeping the serial path's latency association and energy
    /// accumulation order.
    fn assemble(routed: RoutedPhases, item: TiledBatchItem) -> LayerResult {
        let TiledBatchItem {
            partials,
            energy: e_tiles,
            latency_ns: t_compute,
            active_rows,
        } = item;
        let mut energy = routed.energy;
        energy.add(&e_tiles);
        LayerResult {
            partials,
            energy,
            latency_ns: ((routed.lat_pre + t_compute) + routed.t_gather)
                + routed.t_egress,
            packets: routed.tally.packets,
            flits: routed.tally.flits,
            hops: routed.tally.hops,
            active_rows,
        }
    }

    /// Forward one input vector through this layer's shards. A
    /// single-item run of [`run_batch`](Self::run_batch).
    pub fn run(&mut self, x: &[u32]) -> LayerResult {
        self.run_batch(std::slice::from_ref(&x.to_vec()))
            .pop()
            .expect("one item")
    }

    /// Forward a whole minibatch through this layer (DESIGN.md S16):
    /// every shard streams its weights once over the batch (phase 3 —
    /// concurrent tiles, deterministic order; the shared
    /// `mvm_tiled_batch_strided` keeps the (ti, tj) convention in one
    /// place), while each item's NoC phases are priced individually
    /// with the same per-packet cost model — per-item results and
    /// traffic are batch-size invariant.
    pub fn run_batch(&mut self, xs: &[Vec<u32>]) -> Vec<LayerResult> {
        self.reset_parts();
        for x in xs {
            assert_eq!(x.len(), self.tiled.k, "layer input length");
            self.tiled.split_input_into(x, &mut self.xparts);
        }
        self.run_parts(xs.len())
    }

    /// Flat-input [`run_batch`](Self::run_batch) (DESIGN.md S17): the
    /// minibatch arrives as one `[batch × k]` slice, so upstream
    /// collectors feed a reusable buffer instead of a `Vec<Vec<u32>>`.
    pub fn run_batch_strided(
        &mut self,
        xs: &[u32],
        in_dim: usize,
    ) -> Vec<LayerResult> {
        assert_eq!(in_dim, self.tiled.k, "layer input length");
        assert_eq!(xs.len() % in_dim.max(1), 0, "ragged flat batch");
        let batch = xs.len() / in_dim.max(1);
        self.reset_parts();
        for b in 0..batch {
            self.tiled.split_input_into(
                &xs[b * in_dim..(b + 1) * in_dim],
                &mut self.xparts,
            );
        }
        self.run_parts(batch)
    }

    /// Binary-spike layer forward (DESIGN.md S18): one timestep's
    /// sorted input-row event list drives every shard's
    /// [`CimMacro::mvm_events`] fast path — no window matrix is ever
    /// materialized — and the NoC phases are priced from slice activity
    /// with exactly the per-packet model [`run`](Self::run) uses.
    /// Bitwise identical to `run` on the equivalent 0/1 vector
    /// (asserted in `rust/tests/stream_e2e.rs`): identical per-shard
    /// scratch, identical (ti, tj) partial order, identical energy
    /// accumulation order, identical routed traffic.
    pub fn run_events(&mut self, events: &[u32]) -> LayerResult {
        let rt = self.tiled.row_tiles;
        let ct = self.tiled.col_tiles;
        let tile = self.tiled.tile;
        self.eparts.resize_with(rt, Vec::new);
        for p in &mut self.eparts {
            p.clear();
        }
        let mut prev: i64 = -1;
        for &r in events {
            assert!((r as usize) < self.tiled.k, "event row {r} of layer");
            assert!(
                i64::from(r) > prev,
                "event list must be sorted ascending without duplicates"
            );
            prev = i64::from(r);
            self.eparts[r as usize / tile].push((r as usize % tile) as u32);
        }
        let slice_active: Vec<bool> =
            self.eparts.iter().map(|p| !p.is_empty()).collect();
        let eparts = &self.eparts;
        let jobs: Vec<(&mut CimMacro, &[u32])> = self
            .macros
            .iter_mut()
            .enumerate()
            .map(|(sidx, m)| (m, eparts[sidx / ct].as_slice()))
            .collect();
        let results = mvm_events_parallel(jobs);
        let mut energy = EnergyBreakdown::default();
        let mut latency = 0.0f64; // tiles are physically concurrent
        let mut partials: Vec<Vec<Vec<f64>>> =
            (0..rt).map(|_| Vec::with_capacity(ct)).collect();
        for (sidx, r) in results.into_iter().enumerate() {
            energy.add(&r.energy);
            latency = latency.max(r.latency_ns);
            partials[sidx / ct].push(r.y_mac);
        }
        // Each active input row fires once per column tile it feeds.
        let active_rows = events.len() as u64 * ct as u64;
        let routed = self.route_flags(&slice_active);
        Self::assemble(
            routed,
            TiledBatchItem {
                partials,
                energy,
                latency_ns: latency,
                active_rows,
            },
        )
    }

    /// Clear the reusable per-row-tile slice buffers (capacity kept).
    fn reset_parts(&mut self) {
        let rt = self.tiled.row_tiles;
        self.xparts.resize_with(rt, Vec::new);
        for p in &mut self.xparts {
            p.clear();
        }
    }

    /// Compute + route the `batch` items already split into
    /// `self.xparts`.
    fn run_parts(&mut self, batch: usize) -> Vec<LayerResult> {
        let rt = self.tiled.row_tiles;
        let ct = self.tiled.col_tiles;
        let tile = self.tiled.tile;
        let computed = mvm_tiled_batch_strided(
            &mut self.macros,
            &self.xparts,
            batch,
            rt,
            ct,
        );
        computed
            .into_iter()
            .enumerate()
            .map(|(b, item)| {
                let item_parts: Vec<&[u32]> = (0..rt)
                    .map(|ti| &self.xparts[ti][b * tile..(b + 1) * tile])
                    .collect();
                let routed = self.route(&item_parts);
                Self::assemble(routed, item)
            })
            .collect()
    }
}

/// The assembled chip: placement + per-layer stages + traffic counters.
pub struct FabricChip {
    pub fabric: FabricConfig,
    pub placement: Placement,
    stages: Vec<LayerStage>,
    /// Cumulative NoC traffic since construction (or the last drain).
    pub stats: FabricStats,
}

impl FabricChip {
    /// The geometry + placement validation [`FabricChip::new`] performs,
    /// without programming a single macro cell — the cheap fail-fast
    /// servers run before spawning workers. `shapes` is each layer's
    /// (row_tiles, col_tiles).
    pub fn validate(
        mcfg: &MacroConfig,
        fabric: &FabricConfig,
        shapes: &[(usize, usize)],
    ) -> Result<Placement> {
        ensure!(!shapes.is_empty(), "fabric chip needs at least one layer");
        ensure!(
            mcfg.rows == mcfg.cols,
            "fabric tiles are square macros (rows == cols)"
        );
        place(shapes, fabric)
    }

    /// Build a chip for `layers` (already tiled to the macro geometry):
    /// places every shard, programs one macro per shard.
    pub fn new(
        mcfg: &MacroConfig,
        fabric: FabricConfig,
        layers: Vec<TiledMatrix>,
    ) -> Result<FabricChip> {
        for t in &layers {
            ensure!(
                t.tile == mcfg.rows,
                "layer tile {} must match the macro array ({} rows)",
                t.tile,
                mcfg.rows
            );
        }
        let shapes: Vec<(usize, usize)> =
            layers.iter().map(|t| (t.row_tiles, t.col_tiles)).collect();
        let placement = Self::validate(mcfg, &fabric, &shapes)?;
        let io = TileCoord {
            x: fabric.io_tile.0,
            y: fabric.io_tile.1,
        };
        let n_layers = layers.len();
        let stages: Vec<LayerStage> = layers
            .into_iter()
            .enumerate()
            .map(|(li, tiled)| {
                let locs = placement.per_layer[li].clone();
                let macros = (0..tiled.num_tiles())
                    .map(|s| {
                        let mut m = CimMacro::new(mcfg.clone());
                        m.program(tiled.tile_codes_flat(s));
                        m
                    })
                    .collect();
                let egress = if li + 1 < n_layers {
                    placement.head(li + 1)
                } else {
                    io
                };
                LayerStage {
                    tiled,
                    macros,
                    locs,
                    ingress: (li == 0).then_some(io),
                    egress,
                    fabric: fabric.clone(),
                    xparts: Vec::new(),
                    eparts: Vec::new(),
                }
            })
            .collect();
        Ok(FabricChip {
            fabric,
            placement,
            stages,
            stats: FabricStats::default(),
        })
    }

    pub fn num_layers(&self) -> usize {
        self.stages.len()
    }

    /// Tiles carrying a weight shard.
    pub fn tiles_used(&self) -> usize {
        self.placement.utilization().0
    }

    /// Total mesh tile slots.
    pub fn tiles_total(&self) -> usize {
        self.placement.utilization().1
    }

    /// Forward one layer; NoC traffic accumulates into `self.stats`. A
    /// single-item run of [`forward_layer_batch`](Self::forward_layer_batch).
    pub fn forward_layer(&mut self, layer: usize, x: &[u32]) -> LayerResult {
        self.forward_layer_batch(layer, std::slice::from_ref(&x.to_vec()))
            .pop()
            .expect("one item")
    }

    /// Forward one layer for a whole minibatch (DESIGN.md S16): one
    /// weight-matrix pass per shard for all B inputs, per-item NoC
    /// accounting — results and `stats` deltas bit-identical to B
    /// [`forward_layer`](Self::forward_layer) calls.
    pub fn forward_layer_batch(
        &mut self,
        layer: usize,
        xs: &[Vec<u32>],
    ) -> Vec<LayerResult> {
        // S20 span (stage = layer index); payload: batch items and the
        // summed macro row activations they lit.
        let mut span = obs::Span::begin(TraceKind::LayerForward, layer as u16);
        let rs = self.stages[layer].run_batch(xs);
        self.absorb_layer(layer, &rs, xs.len());
        span.note(
            xs.len() as f64,
            rs.iter().map(|r| r.active_rows).sum::<u64>() as f64,
        );
        rs
    }

    /// Binary-spike layer forward (DESIGN.md S18): one timestep's
    /// sorted event list through [`LayerStage::run_events`], traffic
    /// absorbed into `self.stats` like
    /// [`forward_layer`](Self::forward_layer).
    pub fn forward_layer_events(
        &mut self,
        layer: usize,
        events: &[u32],
    ) -> LayerResult {
        let mut span = obs::Span::begin(TraceKind::LayerForward, layer as u16);
        let r = self.stages[layer].run_events(events);
        self.absorb_layer(layer, std::slice::from_ref(&r), 1);
        span.note(1.0, r.active_rows as f64);
        r
    }

    /// Flat-input [`forward_layer_batch`](Self::forward_layer_batch)
    /// (DESIGN.md S17): `xs` is the whole minibatch concatenated,
    /// `in_dim` values per item.
    pub fn forward_layer_batch_strided(
        &mut self,
        layer: usize,
        xs: &[u32],
        in_dim: usize,
    ) -> Vec<LayerResult> {
        let mut span = obs::Span::begin(TraceKind::LayerForward, layer as u16);
        let rs = self.stages[layer].run_batch_strided(xs, in_dim);
        self.absorb_layer(layer, &rs, rs.len());
        span.note(
            rs.len() as f64,
            rs.iter().map(|r| r.active_rows).sum::<u64>() as f64,
        );
        rs
    }

    /// Accumulate one layer batch's traffic + activity into
    /// `self.stats`.
    fn absorb_layer(&mut self, layer: usize, rs: &[LayerResult], items: usize) {
        for r in rs {
            self.stats.packets += r.packets;
            self.stats.flits += r.flits;
            self.stats.hops += r.hops;
            self.stats.noc_fj += r.energy.noc_fj;
            self.stats.active_rows += r.active_rows;
        }
        if layer == 0 {
            self.stats.mvms += items as u64;
        }
    }

    /// Single-layer convenience: run the whole tiled matrix as one MVM
    /// and accumulate the partials into the dense length-N result.
    pub fn mvm(&mut self, x: &[u32]) -> (Vec<f64>, LayerResult) {
        assert_eq!(self.stages.len(), 1, "mvm() is the single-layer path");
        let r = self.forward_layer(0, x);
        let y = self.stages[0].tiled.accumulate(&r.partials);
        (y, r)
    }

    /// Batched single-layer MVM (DESIGN.md S16): the whole minibatch
    /// streams through the mesh with one weight pass per shard.
    pub fn mvm_batch(
        &mut self,
        xs: &[Vec<u32>],
    ) -> Vec<(Vec<f64>, LayerResult)> {
        assert_eq!(
            self.stages.len(),
            1,
            "mvm_batch() is the single-layer path"
        );
        let rs = self.forward_layer_batch(0, xs);
        rs.into_iter()
            .map(|r| {
                let y = self.stages[0].tiled.accumulate(&r.partials);
                (y, r)
            })
            .collect()
    }

    /// Flat-input [`mvm_batch`](Self::mvm_batch) (DESIGN.md S17): the
    /// serving hot path — one reusable `[batch × k]` buffer in, no
    /// per-batch `Vec<Vec<u32>>`.
    pub fn mvm_batch_strided(
        &mut self,
        xs: &[u32],
        in_dim: usize,
    ) -> Vec<(Vec<f64>, LayerResult)> {
        assert_eq!(
            self.stages.len(),
            1,
            "mvm_batch_strided() is the single-layer path"
        );
        let rs = self.forward_layer_batch_strided(0, xs, in_dim);
        rs.into_iter()
            .map(|r| {
                let y = self.stages[0].tiled.accumulate(&r.partials);
                (y, r)
            })
            .collect()
    }

    /// Drain the cumulative traffic counters (serving metrics use this).
    pub fn drain_stats(&mut self) -> FabricStats {
        std::mem::take(&mut self.stats)
    }

    /// Tear the chip into per-layer stages for the dataflow executor.
    pub fn into_stages(self) -> Vec<LayerStage> {
        self.stages
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::LevelMap;
    use crate::util::rng::Rng;

    fn random_codes(k: usize, n: usize, seed: u64) -> Vec<u8> {
        let mut rng = Rng::new(seed);
        (0..k * n).map(|_| rng.below(4) as u8).collect()
    }

    #[test]
    fn single_layer_fabric_mvm_matches_dense_oracle() {
        let cfg = MacroConfig::default();
        let (k, n) = (300, 200); // ragged: pads rows and cols
        let codes = random_codes(k, n, 91);
        let tiled = TiledMatrix::new(&codes, k, n, cfg.rows);
        let mut chip =
            FabricChip::new(&cfg, FabricConfig::square(3), vec![tiled])
                .unwrap();
        let mut rng = Rng::new(92);
        let x: Vec<u32> = (0..k).map(|_| rng.below(256) as u32).collect();
        let (got, r) = chip.mvm(&x);

        let levels = LevelMap::DeviceTrue.levels();
        let mut want = vec![0.0f64; n];
        for row in 0..k {
            for c in 0..n {
                want[c] +=
                    x[row] as f64 * levels[codes[row * n + c] as usize];
            }
        }
        assert_eq!(got.len(), n);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-6, "{g} vs {w}");
        }
        assert!(r.energy.noc_fj > 0.0, "routed traffic must be charged");
        assert!(r.packets > 0 && r.hops > 0);
        assert!(
            r.energy.noc_fj < 0.5 * r.energy.total_fj(),
            "NoC must not dominate compute"
        );
    }

    #[test]
    fn latency_includes_noc_phases() {
        let cfg = MacroConfig::default();
        let codes = random_codes(256, 256, 93);
        let mk = |grid: usize| {
            let tiled = TiledMatrix::new(&codes, 256, 256, cfg.rows);
            FabricChip::new(&cfg, FabricConfig::square(grid), vec![tiled])
                .unwrap()
        };
        let x: Vec<u32> = vec![200; 256];
        // The 2×2 mesh pays routing hops on top of compute: fabric
        // latency must exceed the raw macro critical path.
        let mut chip = mk(2);
        let (_, r) = chip.mvm(&x);
        let mut lone = CimMacro::new(cfg.clone());
        lone.program(
            TiledMatrix::new(&codes, 256, 256, cfg.rows).tile_codes_flat(0),
        );
        let compute_only = lone.mvm(&x[..cfg.rows]).latency_ns;
        assert!(
            r.latency_ns > compute_only,
            "{} vs {}",
            r.latency_ns,
            compute_only
        );
        assert_eq!(chip.stats.mvms, 1);
    }

    #[test]
    fn zero_input_sends_no_packets() {
        let cfg = MacroConfig::default();
        let codes = random_codes(256, 256, 94);
        let tiled = TiledMatrix::new(&codes, 256, 256, cfg.rows);
        let mut chip =
            FabricChip::new(&cfg, FabricConfig::square(2), vec![tiled])
                .unwrap();
        let zeros = [0u32; 256];
        let (y, r) = chip.mvm(&zeros);
        assert!(y.iter().all(|&v| v == 0.0));
        assert_eq!(r.packets, 0);
        assert_eq!(r.hops, 0);
        assert_eq!(r.energy.noc_fj, 0.0);
        assert_eq!(r.active_rows, 0, "silent input: no row events");
        assert_eq!(chip.stats.active_rows, 0);
    }

    #[test]
    fn strided_mesh_batch_bitwise_equals_vec_of_vecs() {
        let cfg = MacroConfig::default();
        let codes = random_codes(300, 200, 195);
        let mk = || {
            let tiled = TiledMatrix::new(&codes, 300, 200, cfg.rows);
            FabricChip::new(&cfg, FabricConfig::square(3), vec![tiled])
                .unwrap()
        };
        let mut rng = Rng::new(196);
        let mut xs: Vec<Vec<u32>> = (0..4)
            .map(|_| (0..300).map(|_| 1 + rng.below(255) as u32).collect())
            .collect();
        xs.push(vec![0u32; 300]);
        let flat: Vec<u32> = xs.iter().flatten().copied().collect();

        let mut a = mk();
        let want = a.mvm_batch(&xs);
        let mut b = mk();
        let got = b.mvm_batch_strided(&flat, 300);

        assert_eq!(got.len(), want.len());
        for ((gy, gr), (wy, wr)) in got.iter().zip(&want) {
            assert_eq!(gy, wy);
            assert_eq!(gr.partials, wr.partials);
            assert_eq!(gr.energy, wr.energy);
            assert_eq!(gr.active_rows, wr.active_rows);
        }
        assert_eq!(a.stats.active_rows, b.stats.active_rows);
        // 3×2 tile grid over dense 300-row inputs: each of the 4 dense
        // items activates 300 rows × 2 column tiles.
        assert_eq!(a.stats.active_rows, 4 * 300 * 2);
    }

    #[test]
    fn multi_layer_chip_places_and_routes_between_layers() {
        let cfg = MacroConfig::default();
        let l1 = TiledMatrix::new(
            &random_codes(256, 128, 95),
            256,
            128,
            cfg.rows,
        );
        let l2 = TiledMatrix::new(
            &random_codes(128, 128, 96),
            128,
            128,
            cfg.rows,
        );
        let mut chip =
            FabricChip::new(&cfg, FabricConfig::square(2), vec![l1, l2])
                .unwrap();
        assert_eq!(chip.num_layers(), 2);
        assert_eq!(chip.tiles_used(), 3);
        assert_eq!(chip.tiles_total(), 4);
        let mut rng = Rng::new(97);
        let x: Vec<u32> = (0..256).map(|_| rng.below(256) as u32).collect();
        let r1 = chip.forward_layer(0, &x);
        assert_eq!(r1.partials.len(), 2); // two row tiles
        let x2: Vec<u32> = (0..128).map(|_| rng.below(256) as u32).collect();
        let r2 = chip.forward_layer(1, &x2);
        // Single-shard inner layer still pays egress back to I/O.
        assert!(r2.hops > 0);
        let drained = chip.drain_stats();
        assert_eq!(drained.packets, r1.packets + r2.packets);
        assert_eq!(chip.stats.packets, 0, "drain resets the counters");
    }

    #[test]
    fn batched_mesh_mvm_bit_identical_to_serial() {
        let cfg = MacroConfig::default();
        let codes = random_codes(300, 200, 191);
        let mk = || {
            let tiled = TiledMatrix::new(&codes, 300, 200, cfg.rows);
            FabricChip::new(&cfg, FabricConfig::square(3), vec![tiled])
                .unwrap()
        };
        let mut rng = Rng::new(192);
        let mut xs: Vec<Vec<u32>> = (0..5)
            .map(|_| (0..300).map(|_| rng.below(256) as u32).collect())
            .collect();
        xs.push(vec![0u32; 300]); // silent item routes nothing

        let mut serial = mk();
        let want: Vec<(Vec<f64>, LayerResult)> =
            xs.iter().map(|x| serial.mvm(x)).collect();

        let mut batched = mk();
        let got = batched.mvm_batch(&xs);

        assert_eq!(got.len(), want.len());
        for ((gy, gr), (wy, wr)) in got.iter().zip(&want) {
            assert_eq!(gy, wy, "accumulated MACs diverge");
            assert_eq!(gr.partials, wr.partials);
            assert_eq!(gr.energy, wr.energy);
            assert_eq!(gr.latency_ns, wr.latency_ns);
            assert_eq!(
                (gr.packets, gr.flits, gr.hops),
                (wr.packets, wr.flits, wr.hops)
            );
        }
        // Chip-level counters march identically too.
        assert_eq!(batched.stats.packets, serial.stats.packets);
        assert_eq!(batched.stats.hops, serial.stats.hops);
        assert_eq!(batched.stats.mvms, serial.stats.mvms);
        assert_eq!(batched.stats.noc_fj, serial.stats.noc_fj);
    }

    #[test]
    fn run_events_bitwise_equals_value_forward_on_binary_input() {
        // The S18 fabric-level contract: a timestep's event list through
        // `forward_layer_events` is the same op as the equivalent 0/1
        // vector through `forward_layer` — partials, energy, latency,
        // and every NoC tally, across densities (incl. an all-silent
        // frame, which routes nothing, and a frame that leaves a whole
        // row tile silent).
        let cfg = MacroConfig::default();
        let codes = random_codes(300, 200, 501);
        let mk = || {
            let tiled = TiledMatrix::new(&codes, 300, 200, cfg.rows);
            FabricChip::new(&cfg, FabricConfig::square(3), vec![tiled])
                .unwrap()
        };
        let mut values = mk();
        let mut events = mk();
        let mut rng = Rng::new(502);
        let mut frames: Vec<Vec<u32>> = [0.0, 0.04, 0.4, 1.0]
            .iter()
            .map(|&density| {
                (0..300u32).filter(|_| rng.f64() < density).collect()
            })
            .collect();
        frames.push((0..128).collect()); // row tiles 1–2 fully silent
        for (i, ev) in frames.iter().enumerate() {
            let mut x = vec![0u32; 300];
            for &r in ev {
                x[r as usize] = 1;
            }
            let want = values.forward_layer(0, &x);
            let got = events.forward_layer_events(0, ev);
            assert_eq!(got.partials, want.partials, "frame {i}");
            assert_eq!(got.energy, want.energy);
            assert_eq!(got.latency_ns, want.latency_ns);
            assert_eq!(
                (got.packets, got.flits, got.hops),
                (want.packets, want.flits, want.hops)
            );
            assert_eq!(got.active_rows, want.active_rows);
        }
        assert_eq!(values.stats.packets, events.stats.packets);
        assert_eq!(values.stats.noc_fj, events.stats.noc_fj);
        assert_eq!(values.stats.active_rows, events.stats.active_rows);
        assert_eq!(values.stats.mvms, events.stats.mvms);
    }

    #[test]
    fn workload_too_big_for_mesh_is_an_error() {
        let cfg = MacroConfig::default();
        let tiled = TiledMatrix::new(
            &random_codes(512, 512, 98),
            512,
            512,
            cfg.rows,
        );
        // 16 shards on a 2×2 mesh: must refuse.
        let err = FabricChip::new(&cfg, FabricConfig::square(2), vec![tiled])
            .err()
            .expect("placement must fail");
        assert!(err.to_string().contains("exceed"), "{err}");
    }
}

//! Chip-level fabric (DESIGN.md S15): an event-routed multi-macro
//! subsystem that turns "many macros" from a per-caller loop into a
//! modeled artifact — a mesh of weight-stationary `CimMacro` tiles
//! joined by a spike-packet X-Y NoC, a placement engine that shards
//! tiled weights onto the mesh, and a dataflow executor that pipelines
//! multi-layer inference across worker threads.
//!
//! * [`noc`] — `TileCoord`, `SpikePacket`, deterministic X-Y routing,
//!   and the per-hop latency/energy cost model.
//! * [`placement`] — serpentine locality-aware shard→tile assignment
//!   with validated invariants.
//! * [`chip`] — `FabricChip`/`LayerStage`: the routed layer forward,
//!   bit-identical to single-macro tiling, with NoC traffic folded into
//!   `EnergyBreakdown::noc_fj`.
//! * [`executor`] — `FabricPipeline`: per-layer streaming scheduled on
//!   the persistent shared worker pool (DESIGN.md S17).
//!
//! Consumers: `snn::MacroMlp::attach_fabric` (fabric-backed inference),
//! `coordinator::BackendKind::Fabric` (serving matrices larger than one
//! macro), and `repro::fabric` (the macros 1→64 scaling sweep, EX2).

pub mod chip;
pub mod executor;
pub mod noc;
pub mod placement;

pub use chip::{FabricChip, FabricStats, LayerResult, LayerStage};
pub use executor::{FabricPipeline, PipelineStats, StageRelay};
pub use noc::{xy_route, SpikePacket, TileCoord};
pub use placement::{place, serpentine, Placement, ShardId};

//! Dataflow executor (DESIGN.md S15/S17): pipelined layer execution
//! over the fabric — item i+1's layer-l work overlaps item i's
//! layer-(l+1) work, the chip-level analogue of `coordinator::pipeline`
//! with NoC accounting attached.
//!
//! Since S17 the executor spawns **no threads of its own**: each layer
//! is a *stage node* (its `LayerStage` torn out of a `FabricChip`, its
//! relay, its tally, and an inbox of minibatches), and stage turns are
//! scheduled as tasks on the persistent shared worker pool
//! (`util::pool`). A node is claimed by at most one task at a time, so
//! every stage processes its chunks serially in arrival order —
//! outputs and tallies are bit-identical to running the stages one
//! after the other (asserted by the tests here and in `rust/tests/`) —
//! while distinct stages run concurrently on distinct pool workers.
//! Stage turns never block (an empty inbox ends the turn; delivering
//! downstream is a non-blocking push + schedule), which keeps the
//! shared pool deadlock-free by construction no matter how many
//! pipelines and tile fan-outs share it.
//!
//! Each stage runs the routed forward, accumulates partials into the
//! layer MAC, and hands the result to a caller-supplied *relay* that
//! produces the next stage's input codes (requantization for an SNN,
//! thresholding for a raw chain, …).

use std::collections::VecDeque;
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use crate::energy::EnergyBreakdown;
use crate::util::pool;

use super::chip::{FabricChip, LayerStage};

/// Per-stage post-processing: maps (stage input, accumulated layer MAC)
/// to the next stage's input codes; the last stage's relay produces the
/// final output codes.
pub type StageRelay = Box<dyn FnMut(&[u32], Vec<f64>) -> Vec<u32> + Send>;

/// Aggregate tallies of one pipelined run.
#[derive(Debug, Clone, Default)]
pub struct PipelineStats {
    pub items: usize,
    pub energy: EnergyBreakdown,
    /// Σ per-item per-stage modeled latency — equal to the serial model
    /// by construction; the pipelining buys wall-clock, not model time.
    pub latency_ns: f64,
    pub packets: u64,
    pub hops: u64,
    /// Macro row activations across all stages (DESIGN.md S17).
    pub active_rows: u64,
}

impl PipelineStats {
    fn absorb(&mut self, other: &PipelineStats) {
        self.energy.add(&other.energy);
        self.latency_ns += other.latency_ns;
        self.packets += other.packets;
        self.hops += other.hops;
        self.active_rows += other.active_rows;
    }
}

/// What leaves the pipeline: finished chunks, per-stage tallies at
/// drain time, or a stage panic to re-raise on the caller.
enum OutMsg {
    Chunk(usize, Vec<Vec<u32>>),
    Tally(usize, PipelineStats),
    Panic(Box<dyn std::any::Any + Send>),
}

/// The movable compute state of one stage; exactly one scheduled task
/// holds it at a time.
struct StageCore {
    stage: LayerStage,
    relay: StageRelay,
    tally: PipelineStats,
    processed: usize,
}

/// One stage's scheduling cell.
struct StageNode {
    inbox: Mutex<Inbox>,
}

struct Inbox {
    queue: VecDeque<(usize, Vec<Vec<u32>>)>,
    /// `None` while a scheduled task is out processing with the core.
    core: Option<StageCore>,
    /// True while a task is scheduled/running for this node; feeders
    /// only schedule a new task when it is false (single-claimant).
    scheduled: bool,
    /// A stage panicked: drop further traffic.
    poisoned: bool,
}

struct PipeCtx {
    nodes: Vec<StageNode>,
    n_chunks: usize,
    out_tx: mpsc::Sender<OutMsg>,
}

/// Deliver one chunk to stage `s`, scheduling a stage turn on the
/// shared pool if none is in flight. Non-blocking.
fn feed(ctx: &Arc<PipeCtx>, s: usize, id: usize, chunk: Vec<Vec<u32>>) {
    let mut g = ctx.nodes[s].inbox.lock().unwrap();
    if g.poisoned {
        return;
    }
    g.queue.push_back((id, chunk));
    if !g.scheduled {
        g.scheduled = true;
        let ctx = ctx.clone();
        pool::spawn(move || stage_turns(ctx, s));
    }
}

/// One scheduled run of stage `s`: drain the inbox chunk by chunk (in
/// arrival = id order), forwarding each result downstream, until the
/// inbox is empty. Never blocks.
fn stage_turns(ctx: Arc<PipeCtx>, s: usize) {
    loop {
        let (id, chunk, mut core) = {
            let mut g = ctx.nodes[s].inbox.lock().unwrap();
            match g.queue.pop_front() {
                Some((id, chunk)) => {
                    let core = g.core.take().expect("core parked");
                    (id, chunk, core)
                }
                None => {
                    g.scheduled = false;
                    return;
                }
            }
        };
        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            move || {
                let rs = core.stage.run_batch(&chunk);
                let mut outs = Vec::with_capacity(chunk.len());
                for (x, r) in chunk.iter().zip(rs) {
                    core.tally.energy.add(&r.energy);
                    core.tally.latency_ns += r.latency_ns;
                    core.tally.packets += r.packets;
                    core.tally.hops += r.hops;
                    core.tally.active_rows += r.active_rows;
                    let mac = core.stage.tiled.accumulate(&r.partials);
                    outs.push((core.relay)(x, mac));
                }
                core.processed += 1;
                (core, outs)
            },
        ));
        match run {
            Ok((mut core, outs)) => {
                let finished = core.processed == ctx.n_chunks;
                let tally = if finished {
                    Some(std::mem::take(&mut core.tally))
                } else {
                    None
                };
                {
                    let mut g = ctx.nodes[s].inbox.lock().unwrap();
                    g.core = Some(core);
                }
                if s + 1 < ctx.nodes.len() {
                    feed(&ctx, s + 1, id, outs);
                } else {
                    let _ = ctx.out_tx.send(OutMsg::Chunk(id, outs));
                }
                if let Some(t) = tally {
                    let _ = ctx.out_tx.send(OutMsg::Tally(s, t));
                }
            }
            Err(p) => {
                {
                    let mut g = ctx.nodes[s].inbox.lock().unwrap();
                    g.poisoned = true;
                    g.scheduled = false;
                    g.queue.clear();
                }
                let _ = ctx.out_tx.send(OutMsg::Panic(p));
                return;
            }
        }
    }
}

/// A chip rearranged for streaming: stage turns scheduled on the shared
/// worker pool at run time (DESIGN.md S17).
pub struct FabricPipeline {
    stages: Vec<(LayerStage, StageRelay)>,
}

impl FabricPipeline {
    /// Pair every chip layer with its relay.
    pub fn new(chip: FabricChip, relays: Vec<StageRelay>) -> FabricPipeline {
        let stages = chip.into_stages();
        assert_eq!(stages.len(), relays.len(), "one relay per layer");
        FabricPipeline {
            stages: stages.into_iter().zip(relays).collect(),
        }
    }

    /// Stream `inputs` through all stages one item at a time; returns
    /// outputs in input order plus the run tallies.
    pub fn run(self, inputs: Vec<Vec<u32>>) -> (Vec<Vec<u32>>, PipelineStats) {
        self.run_batched(inputs, 1)
    }

    /// Stream `inputs` through all stages in minibatches of `batch`
    /// items (DESIGN.md S16): each stage executes a whole minibatch as
    /// one `run_batch` call — one weight pass per shard per minibatch —
    /// and minibatches move between stage nodes through their inboxes.
    /// Outputs and tallies are bit-identical to [`run`](Self::run) at
    /// any batch size; only wall-clock changes.
    pub fn run_batched(
        self,
        inputs: Vec<Vec<u32>>,
        batch: usize,
    ) -> (Vec<Vec<u32>>, PipelineStats) {
        assert!(!self.stages.is_empty());
        assert!(batch > 0, "batch size");
        let n = inputs.len();
        let n_chunks = n.div_ceil(batch);
        let mut stats = PipelineStats {
            items: n,
            ..PipelineStats::default()
        };
        if n_chunks == 0 {
            return (Vec::new(), stats);
        }
        let n_stages = self.stages.len();
        let (out_tx, out_rx) = mpsc::channel::<OutMsg>();
        let ctx = Arc::new(PipeCtx {
            nodes: self
                .stages
                .into_iter()
                .map(|(stage, relay)| StageNode {
                    inbox: Mutex::new(Inbox {
                        queue: VecDeque::new(),
                        core: Some(StageCore {
                            stage,
                            relay,
                            tally: PipelineStats::default(),
                            processed: 0,
                        }),
                        scheduled: false,
                        poisoned: false,
                    }),
                })
                .collect(),
            n_chunks,
            out_tx,
        });
        let mut feed_iter = inputs.into_iter();
        for id in 0..n_chunks {
            let chunk: Vec<Vec<u32>> = feed_iter.by_ref().take(batch).collect();
            feed(&ctx, 0, id, chunk);
        }
        let mut out: Vec<Option<Vec<Vec<u32>>>> =
            (0..n_chunks).map(|_| None).collect();
        let mut tallies: Vec<Option<PipelineStats>> =
            (0..n_stages).map(|_| None).collect();
        let mut chunks_left = n_chunks;
        let mut tallies_left = n_stages;
        // Watchdog (DESIGN.md S21): a generous recv_timeout instead of
        // a blocking recv, so a lost stage (bug, wedged pool) surfaces
        // as a diagnosable panic instead of hanging the caller forever.
        const WATCHDOG: Duration = Duration::from_secs(60);
        while chunks_left > 0 || tallies_left > 0 {
            match out_rx.recv_timeout(WATCHDOG) {
                Ok(OutMsg::Chunk(id, items)) => {
                    out[id] = Some(items);
                    chunks_left -= 1;
                }
                Ok(OutMsg::Tally(s, t)) => {
                    tallies[s] = Some(t);
                    tallies_left -= 1;
                }
                Ok(OutMsg::Panic(p)) => std::panic::resume_unwind(p),
                Err(mpsc::RecvTimeoutError::Timeout) => panic!(
                    "pipeline collector starved for {WATCHDOG:?} \
                     ({chunks_left} chunks, {tallies_left} tallies \
                     outstanding) — a stage died without reporting"
                ),
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    unreachable!("pipeline ctx alive")
                }
            }
        }
        // Absorb per-stage tallies in stage order (deterministic f64
        // accumulation, matching the old join order).
        for t in tallies.into_iter().flatten() {
            stats.absorb(&t);
        }
        let outputs: Vec<Vec<u32>> = out
            .into_iter()
            .flat_map(|o| o.expect("every chunk answered"))
            .collect();
        (outputs, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{FabricConfig, MacroConfig};
    use crate::coordinator::TiledMatrix;
    use crate::fabric::FabricChip;
    use crate::util::rng::Rng;

    fn requant(y: Vec<f64>) -> Vec<u32> {
        y.into_iter()
            .map(|v| ((v / 40.0).round().max(0.0) as u32).min(255))
            .collect()
    }

    fn two_layer_chip(seed: u64) -> FabricChip {
        let cfg = MacroConfig::default();
        let mut rng = Rng::new(seed);
        let layers: Vec<TiledMatrix> = (0..2)
            .map(|_| {
                let codes: Vec<u8> = (0..cfg.rows * cfg.cols)
                    .map(|_| rng.below(4) as u8)
                    .collect();
                TiledMatrix::new(&codes, cfg.rows, cfg.cols, cfg.rows)
            })
            .collect();
        FabricChip::new(&cfg, FabricConfig::square(2), layers).unwrap()
    }

    #[test]
    fn pipelined_run_matches_serial_chip_bit_for_bit() {
        let mut rng = Rng::new(606);
        let inputs: Vec<Vec<u32>> = (0..10)
            .map(|_| (0..128).map(|_| rng.below(256) as u32).collect())
            .collect();

        // Serial reference on an identical chip. Each 128×128 layer is a
        // single shard, so its partial IS the accumulated MAC (the
        // pipeline's `accumulate` adds it onto zeros — exact in f64).
        let mut serial_chip = two_layer_chip(605);
        let mut serial_out = Vec::new();
        let mut serial_energy = EnergyBreakdown::default();
        for x in &inputs {
            let mut v = x.clone();
            for li in 0..2 {
                let r = serial_chip.forward_layer(li, &v);
                serial_energy.add(&r.energy);
                v = requant(r.partials[0][0].clone());
            }
            serial_out.push(v);
        }

        // Pipelined run.
        let chip = two_layer_chip(605);
        let relays: Vec<StageRelay> = (0..2)
            .map(|_| {
                Box::new(|_x: &[u32], mac: Vec<f64>| requant(mac))
                    as StageRelay
            })
            .collect();
        let (pipe_out, stats) =
            FabricPipeline::new(chip, relays).run(inputs.clone());

        assert_eq!(pipe_out, serial_out);
        assert_eq!(stats.items, 10);
        assert!(
            (stats.energy.total_fj() - serial_energy.total_fj()).abs()
                / serial_energy.total_fj()
                < 1e-9
        );
        assert!(stats.packets > 0 && stats.hops > 0);
        // Two single-shard 128-row layers, 10 items: row activations
        // are bounded by the full-dense count and, with random inputs,
        // well above zero.
        assert!(stats.active_rows > 0);
        assert!(stats.active_rows <= 10 * 2 * 128);

        // Minibatched streaming (DESIGN.md S16): identical outputs and
        // tallies at any chunk size, including a ragged final chunk.
        for batch in [1usize, 3, 4, 16] {
            let chip = two_layer_chip(605);
            let relays: Vec<StageRelay> = (0..2)
                .map(|_| {
                    Box::new(|_x: &[u32], mac: Vec<f64>| requant(mac))
                        as StageRelay
                })
                .collect();
            let (out_b, stats_b) = FabricPipeline::new(chip, relays)
                .run_batched(inputs.clone(), batch);
            assert_eq!(out_b, serial_out, "batch {batch} output diverges");
            assert_eq!(stats_b.items, 10);
            assert_eq!(stats_b.packets, stats.packets);
            assert_eq!(stats_b.hops, stats.hops);
            assert_eq!(stats_b.latency_ns, stats.latency_ns);
            assert_eq!(stats_b.active_rows, stats.active_rows);
        }
    }

    #[test]
    fn empty_input_stream_is_a_clean_noop() {
        let chip = two_layer_chip(607);
        let relays: Vec<StageRelay> = (0..2)
            .map(|_| {
                Box::new(|_x: &[u32], mac: Vec<f64>| requant(mac))
                    as StageRelay
            })
            .collect();
        let (outs, stats) = FabricPipeline::new(chip, relays).run(Vec::new());
        assert!(outs.is_empty());
        assert_eq!(stats.items, 0);
        assert_eq!(stats.packets, 0);
        assert_eq!(stats.energy.total_fj(), 0.0);
    }
}

//! Dataflow executor (DESIGN.md S15): thread-per-layer pipelining over
//! the fabric — item i+1's layer-l work overlaps item i's layer-(l+1)
//! work, the chip-level analogue of `coordinator::pipeline` with NoC
//! accounting attached.
//!
//! Each stage owns its layer's tiles (torn out of a `FabricChip`), runs
//! the routed forward, accumulates partials into the layer MAC, and
//! hands the result to a caller-supplied *relay* that produces the next
//! stage's input codes (requantization for an SNN, thresholding for a
//! raw chain, …). Channels preserve order and every stage is
//! deterministic, so outputs are bit-identical to running the stages
//! serially — asserted by the tests here and in `rust/tests/`.
//!
//! Deliberately *not* built on `coordinator::ThreadedPipeline`: its
//! `StageFn<T>: FnMut(T) -> T` shape streams one item type end to end,
//! while fabric stages must own heavy state (a layer's macros) and
//! return per-stage [`PipelineStats`] at join time — threading tallies
//! through `T` would push NoC accounting into every relay. The ~40
//! lines of mpsc wiring are the cheaper coupling.

use std::sync::mpsc;

use crate::energy::EnergyBreakdown;

use super::chip::{FabricChip, LayerStage};

/// Per-stage post-processing: maps (stage input, accumulated layer MAC)
/// to the next stage's input codes; the last stage's relay produces the
/// final output codes.
pub type StageRelay = Box<dyn FnMut(&[u32], Vec<f64>) -> Vec<u32> + Send>;

/// Aggregate tallies of one pipelined run.
#[derive(Debug, Clone, Default)]
pub struct PipelineStats {
    pub items: usize,
    pub energy: EnergyBreakdown,
    /// Σ per-item per-stage modeled latency — equal to the serial model
    /// by construction; the pipelining buys wall-clock, not model time.
    pub latency_ns: f64,
    pub packets: u64,
    pub hops: u64,
}

impl PipelineStats {
    fn absorb(&mut self, other: &PipelineStats) {
        self.energy.add(&other.energy);
        self.latency_ns += other.latency_ns;
        self.packets += other.packets;
        self.hops += other.hops;
    }
}

/// A chip rearranged for streaming: one thread per layer at run time.
pub struct FabricPipeline {
    stages: Vec<(LayerStage, StageRelay)>,
}

impl FabricPipeline {
    /// Pair every chip layer with its relay.
    pub fn new(chip: FabricChip, relays: Vec<StageRelay>) -> FabricPipeline {
        let stages = chip.into_stages();
        assert_eq!(stages.len(), relays.len(), "one relay per layer");
        FabricPipeline {
            stages: stages.into_iter().zip(relays).collect(),
        }
    }

    /// Stream `inputs` through all stages; returns outputs in input
    /// order plus the run tallies.
    pub fn run(self, inputs: Vec<Vec<u32>>) -> (Vec<Vec<u32>>, PipelineStats) {
        assert!(!self.stages.is_empty());
        let n = inputs.len();
        let (first_tx, mut prev_rx) = mpsc::channel::<(usize, Vec<u32>)>();
        let mut handles = Vec::with_capacity(self.stages.len());
        for (mut stage, mut relay) in self.stages {
            let (tx, rx) = mpsc::channel::<(usize, Vec<u32>)>();
            let rx_in = std::mem::replace(&mut prev_rx, rx);
            handles.push(std::thread::spawn(move || {
                let mut tally = PipelineStats::default();
                while let Ok((id, x)) = rx_in.recv() {
                    let r = stage.run(&x);
                    tally.energy.add(&r.energy);
                    tally.latency_ns += r.latency_ns;
                    tally.packets += r.packets;
                    tally.hops += r.hops;
                    let mac = stage.tiled.accumulate(&r.partials);
                    let _ = tx.send((id, relay(&x, mac)));
                }
                tally
            }));
        }
        for (i, x) in inputs.into_iter().enumerate() {
            first_tx.send((i, x)).expect("stage 0 alive");
        }
        drop(first_tx); // end-of-stream ripples down the pipeline
        let mut out: Vec<Option<Vec<u32>>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (id, item) = prev_rx.recv().expect("pipeline output");
            out[id] = Some(item);
        }
        let mut stats = PipelineStats {
            items: n,
            ..PipelineStats::default()
        };
        for h in handles {
            stats.absorb(&h.join().expect("stage thread"));
        }
        (
            out.into_iter().map(|o| o.expect("every id answered")).collect(),
            stats,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{FabricConfig, MacroConfig};
    use crate::coordinator::TiledMatrix;
    use crate::fabric::FabricChip;
    use crate::util::rng::Rng;

    fn requant(y: Vec<f64>) -> Vec<u32> {
        y.into_iter()
            .map(|v| ((v / 40.0).round().max(0.0) as u32).min(255))
            .collect()
    }

    fn two_layer_chip(seed: u64) -> FabricChip {
        let cfg = MacroConfig::default();
        let mut rng = Rng::new(seed);
        let layers: Vec<TiledMatrix> = (0..2)
            .map(|_| {
                let codes: Vec<u8> = (0..cfg.rows * cfg.cols)
                    .map(|_| rng.below(4) as u8)
                    .collect();
                TiledMatrix::new(&codes, cfg.rows, cfg.cols, cfg.rows)
            })
            .collect();
        FabricChip::new(&cfg, FabricConfig::square(2), layers).unwrap()
    }

    #[test]
    fn pipelined_run_matches_serial_chip_bit_for_bit() {
        let mut rng = Rng::new(606);
        let inputs: Vec<Vec<u32>> = (0..10)
            .map(|_| (0..128).map(|_| rng.below(256) as u32).collect())
            .collect();

        // Serial reference on an identical chip. Each 128×128 layer is a
        // single shard, so its partial IS the accumulated MAC (the
        // pipeline's `accumulate` adds it onto zeros — exact in f64).
        let mut serial_chip = two_layer_chip(605);
        let mut serial_out = Vec::new();
        let mut serial_energy = EnergyBreakdown::default();
        for x in &inputs {
            let mut v = x.clone();
            for li in 0..2 {
                let r = serial_chip.forward_layer(li, &v);
                serial_energy.add(&r.energy);
                v = requant(r.partials[0][0].clone());
            }
            serial_out.push(v);
        }

        // Pipelined run.
        let chip = two_layer_chip(605);
        let relays: Vec<StageRelay> = (0..2)
            .map(|_| {
                Box::new(|_x: &[u32], mac: Vec<f64>| requant(mac))
                    as StageRelay
            })
            .collect();
        let (pipe_out, stats) =
            FabricPipeline::new(chip, relays).run(inputs.clone());

        assert_eq!(pipe_out, serial_out);
        assert_eq!(stats.items, 10);
        assert!(
            (stats.energy.total_fj() - serial_energy.total_fj()).abs()
                / serial_energy.total_fj()
                < 1e-9
        );
        assert!(stats.packets > 0 && stats.hops > 0);
    }
}

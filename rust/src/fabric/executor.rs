//! Dataflow executor (DESIGN.md S15): thread-per-layer pipelining over
//! the fabric — item i+1's layer-l work overlaps item i's layer-(l+1)
//! work, the chip-level analogue of `coordinator::pipeline` with NoC
//! accounting attached.
//!
//! Each stage owns its layer's tiles (torn out of a `FabricChip`), runs
//! the routed forward, accumulates partials into the layer MAC, and
//! hands the result to a caller-supplied *relay* that produces the next
//! stage's input codes (requantization for an SNN, thresholding for a
//! raw chain, …). Channels preserve order and every stage is
//! deterministic, so outputs are bit-identical to running the stages
//! serially — asserted by the tests here and in `rust/tests/`.
//!
//! Deliberately *not* built on `coordinator::ThreadedPipeline`: its
//! `StageFn<T>: FnMut(T) -> T` shape streams one item type end to end,
//! while fabric stages must own heavy state (a layer's macros) and
//! return per-stage [`PipelineStats`] at join time — threading tallies
//! through `T` would push NoC accounting into every relay. The ~40
//! lines of mpsc wiring are the cheaper coupling.

use std::sync::mpsc;

use crate::energy::EnergyBreakdown;

use super::chip::{FabricChip, LayerStage};

/// Per-stage post-processing: maps (stage input, accumulated layer MAC)
/// to the next stage's input codes; the last stage's relay produces the
/// final output codes.
pub type StageRelay = Box<dyn FnMut(&[u32], Vec<f64>) -> Vec<u32> + Send>;

/// Aggregate tallies of one pipelined run.
#[derive(Debug, Clone, Default)]
pub struct PipelineStats {
    pub items: usize,
    pub energy: EnergyBreakdown,
    /// Σ per-item per-stage modeled latency — equal to the serial model
    /// by construction; the pipelining buys wall-clock, not model time.
    pub latency_ns: f64,
    pub packets: u64,
    pub hops: u64,
}

impl PipelineStats {
    fn absorb(&mut self, other: &PipelineStats) {
        self.energy.add(&other.energy);
        self.latency_ns += other.latency_ns;
        self.packets += other.packets;
        self.hops += other.hops;
    }
}

/// A chip rearranged for streaming: one thread per layer at run time.
pub struct FabricPipeline {
    stages: Vec<(LayerStage, StageRelay)>,
}

impl FabricPipeline {
    /// Pair every chip layer with its relay.
    pub fn new(chip: FabricChip, relays: Vec<StageRelay>) -> FabricPipeline {
        let stages = chip.into_stages();
        assert_eq!(stages.len(), relays.len(), "one relay per layer");
        FabricPipeline {
            stages: stages.into_iter().zip(relays).collect(),
        }
    }

    /// Stream `inputs` through all stages one item at a time; returns
    /// outputs in input order plus the run tallies.
    pub fn run(self, inputs: Vec<Vec<u32>>) -> (Vec<Vec<u32>>, PipelineStats) {
        self.run_batched(inputs, 1)
    }

    /// Stream `inputs` through all stages in minibatches of `batch`
    /// items (DESIGN.md S16): each stage executes a whole minibatch as
    /// one `run_batch` call — one weight pass per shard per minibatch —
    /// and relays move minibatches between stage threads. Outputs and
    /// tallies are bit-identical to [`run`](Self::run) at any batch
    /// size; only wall-clock changes.
    pub fn run_batched(
        self,
        inputs: Vec<Vec<u32>>,
        batch: usize,
    ) -> (Vec<Vec<u32>>, PipelineStats) {
        assert!(!self.stages.is_empty());
        assert!(batch > 0, "batch size");
        let n = inputs.len();
        let n_chunks = n.div_ceil(batch);
        let (first_tx, mut prev_rx) =
            mpsc::channel::<(usize, Vec<Vec<u32>>)>();
        let mut handles = Vec::with_capacity(self.stages.len());
        for (mut stage, mut relay) in self.stages {
            let (tx, rx) = mpsc::channel::<(usize, Vec<Vec<u32>>)>();
            let rx_in = std::mem::replace(&mut prev_rx, rx);
            handles.push(std::thread::spawn(move || {
                let mut tally = PipelineStats::default();
                while let Ok((id, chunk)) = rx_in.recv() {
                    let rs = stage.run_batch(&chunk);
                    let mut outs = Vec::with_capacity(chunk.len());
                    for (x, r) in chunk.iter().zip(rs) {
                        tally.energy.add(&r.energy);
                        tally.latency_ns += r.latency_ns;
                        tally.packets += r.packets;
                        tally.hops += r.hops;
                        let mac = stage.tiled.accumulate(&r.partials);
                        outs.push(relay(x, mac));
                    }
                    let _ = tx.send((id, outs));
                }
                tally
            }));
        }
        let mut feed = inputs.into_iter();
        for id in 0..n_chunks {
            let chunk: Vec<Vec<u32>> = feed.by_ref().take(batch).collect();
            first_tx.send((id, chunk)).expect("stage 0 alive");
        }
        drop(first_tx); // end-of-stream ripples down the pipeline
        let mut out: Vec<Option<Vec<Vec<u32>>>> =
            (0..n_chunks).map(|_| None).collect();
        for _ in 0..n_chunks {
            let (id, items) = prev_rx.recv().expect("pipeline output");
            out[id] = Some(items);
        }
        let mut stats = PipelineStats {
            items: n,
            ..PipelineStats::default()
        };
        for h in handles {
            stats.absorb(&h.join().expect("stage thread"));
        }
        let outputs: Vec<Vec<u32>> = out
            .into_iter()
            .flat_map(|o| o.expect("every chunk answered"))
            .collect();
        (outputs, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{FabricConfig, MacroConfig};
    use crate::coordinator::TiledMatrix;
    use crate::fabric::FabricChip;
    use crate::util::rng::Rng;

    fn requant(y: Vec<f64>) -> Vec<u32> {
        y.into_iter()
            .map(|v| ((v / 40.0).round().max(0.0) as u32).min(255))
            .collect()
    }

    fn two_layer_chip(seed: u64) -> FabricChip {
        let cfg = MacroConfig::default();
        let mut rng = Rng::new(seed);
        let layers: Vec<TiledMatrix> = (0..2)
            .map(|_| {
                let codes: Vec<u8> = (0..cfg.rows * cfg.cols)
                    .map(|_| rng.below(4) as u8)
                    .collect();
                TiledMatrix::new(&codes, cfg.rows, cfg.cols, cfg.rows)
            })
            .collect();
        FabricChip::new(&cfg, FabricConfig::square(2), layers).unwrap()
    }

    #[test]
    fn pipelined_run_matches_serial_chip_bit_for_bit() {
        let mut rng = Rng::new(606);
        let inputs: Vec<Vec<u32>> = (0..10)
            .map(|_| (0..128).map(|_| rng.below(256) as u32).collect())
            .collect();

        // Serial reference on an identical chip. Each 128×128 layer is a
        // single shard, so its partial IS the accumulated MAC (the
        // pipeline's `accumulate` adds it onto zeros — exact in f64).
        let mut serial_chip = two_layer_chip(605);
        let mut serial_out = Vec::new();
        let mut serial_energy = EnergyBreakdown::default();
        for x in &inputs {
            let mut v = x.clone();
            for li in 0..2 {
                let r = serial_chip.forward_layer(li, &v);
                serial_energy.add(&r.energy);
                v = requant(r.partials[0][0].clone());
            }
            serial_out.push(v);
        }

        // Pipelined run.
        let chip = two_layer_chip(605);
        let relays: Vec<StageRelay> = (0..2)
            .map(|_| {
                Box::new(|_x: &[u32], mac: Vec<f64>| requant(mac))
                    as StageRelay
            })
            .collect();
        let (pipe_out, stats) =
            FabricPipeline::new(chip, relays).run(inputs.clone());

        assert_eq!(pipe_out, serial_out);
        assert_eq!(stats.items, 10);
        assert!(
            (stats.energy.total_fj() - serial_energy.total_fj()).abs()
                / serial_energy.total_fj()
                < 1e-9
        );
        assert!(stats.packets > 0 && stats.hops > 0);

        // Minibatched streaming (DESIGN.md S16): identical outputs and
        // tallies at any chunk size, including a ragged final chunk.
        for batch in [1usize, 3, 4, 16] {
            let chip = two_layer_chip(605);
            let relays: Vec<StageRelay> = (0..2)
                .map(|_| {
                    Box::new(|_x: &[u32], mac: Vec<f64>| requant(mac))
                        as StageRelay
                })
                .collect();
            let (out_b, stats_b) = FabricPipeline::new(chip, relays)
                .run_batched(inputs.clone(), batch);
            assert_eq!(out_b, serial_out, "batch {batch} output diverges");
            assert_eq!(stats_b.items, 10);
            assert_eq!(stats_b.packets, stats.packets);
            assert_eq!(stats_b.hops, stats.hops);
            assert_eq!(stats_b.latency_ns, stats.latency_ns);
        }
    }
}

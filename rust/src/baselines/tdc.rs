//! Time-to-digital-converter readout baseline (Nature'22 [15] in Fig 6b:
//! crossbar current → integration time → flash TDC).
//!
//! A flash/delay-line TDC needs 2^bits delay stages sampled at the stop
//! edge, plus a thermometer→binary encoder. One free parameter
//! (`e_stage_fj`) is calibrated to the Fig 6(b) anchor.

use super::Readout;

#[derive(Debug, Clone, Copy)]
pub struct Tdc {
    pub bits: u32,
    /// Energy per delay stage per conversion (fJ).
    pub e_stage_fj: f64,
    /// Encoder energy per output bit (fJ).
    pub e_encoder_fj: f64,
    /// Stage delay (ns) — sets resolution & conversion range.
    pub t_stage_ns: f64,
}

impl Tdc {
    pub fn new(bits: u32, e_stage_fj: f64) -> Self {
        Tdc {
            bits,
            e_stage_fj,
            e_encoder_fj: 10.0,
            t_stage_ns: 0.2,
        }
    }

    /// Calibrate `e_stage_fj` to hit `anchor_fj` at `bits`.
    pub fn calibrated(bits: u32, anchor_fj: f64) -> Self {
        let proto = Tdc::new(bits, 0.0);
        let fixed = proto.e_encoder_fj * bits as f64;
        let stage_term = anchor_fj - fixed;
        assert!(stage_term > 0.0);
        Tdc::new(bits, stage_term / (1u64 << bits) as f64)
    }

    /// Functional model: digitize an interval (ns) to a code.
    pub fn quantize(&self, dt_ns: f64) -> u32 {
        let max = (1u64 << self.bits) - 1;
        let q = (dt_ns / self.t_stage_ns).floor().max(0.0) as u64;
        q.min(max) as u32
    }
}

impl Readout for Tdc {
    fn name(&self) -> &'static str {
        "TDC"
    }

    fn energy_per_conversion_fj(&self, bits: u32) -> f64 {
        (1u64 << bits) as f64 * self.e_stage_fj
            + self.e_encoder_fj * bits as f64
    }

    fn latency_ns(&self, bits: u32) -> f64 {
        // Full-range conversion: the whole delay line.
        (1u64 << bits) as f64 * self.t_stage_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_hits_anchor() {
        // Fig 6(b): TDC-based [15] ≈ ours/0.288 ≈ 2.65 pJ at 8 b.
        let tdc = Tdc::calibrated(8, 2_649.0);
        assert!((tdc.energy_per_conversion_fj(8) - 2_649.0).abs() < 1.0);
    }

    #[test]
    fn quantize_floor_and_saturate() {
        let tdc = Tdc::new(8, 1.0);
        assert_eq!(tdc.quantize(0.39), 1); // 0.39/0.2 = 1.95 → 1
        assert_eq!(tdc.quantize(1000.0), 255);
        assert_eq!(tdc.quantize(-1.0), 0);
    }

    #[test]
    fn energy_scales_with_stage_count() {
        let tdc = Tdc::calibrated(8, 2_649.0);
        assert!(
            tdc.energy_per_conversion_fj(8)
                > 3.0 * tdc.energy_per_conversion_fj(6)
        );
    }
}

//! Single-spike capacitor-bank readout (DAC'20 ReSiPE [14] in Fig 6b /
//! Table II: "COG" — clock-output-generation with a synchronous ramp).
//!
//! The result capacitor is compared against a clocked staircase reference;
//! each clock step switches a slice of the capacitor bank, so a full-range
//! conversion costs 2^bits slice-switch events plus clocked control — and,
//! critically, it needs the *global clock* the paper's event-driven design
//! eliminates (§II-B).

use super::Readout;

#[derive(Debug, Clone, Copy)]
pub struct CogReadout {
    pub bits: u32,
    /// Energy per staircase step (capacitor slice + clocked comparator sample, fJ).
    pub e_step_fj: f64,
    /// Clock-tree energy per conversion per bit (fJ) — the synchronous tax.
    pub e_clock_fj: f64,
    /// Clock period (ns).
    pub t_clk_ns: f64,
}

impl CogReadout {
    pub fn new(bits: u32, e_step_fj: f64) -> Self {
        CogReadout {
            bits,
            e_step_fj,
            e_clock_fj: 45.0,
            t_clk_ns: 0.5,
        }
    }

    /// Calibrate `e_step_fj` to `anchor_fj` at `bits`.
    pub fn calibrated(bits: u32, anchor_fj: f64) -> Self {
        let proto = CogReadout::new(bits, 0.0);
        let fixed = proto.e_clock_fj * bits as f64;
        let step_term = anchor_fj - fixed;
        assert!(step_term > 0.0);
        CogReadout::new(bits, step_term / (1u64 << bits) as f64)
    }

    /// Functional model: staircase conversion of a voltage fraction
    /// v/v_full ∈ [0,1] → code (each step t_clk, quantized upward).
    pub fn quantize(&self, v_frac: f64) -> u32 {
        let max = (1u64 << self.bits) - 1;
        ((v_frac.clamp(0.0, 1.0) * max as f64).round() as u64).min(max) as u32
    }
}

impl Readout for CogReadout {
    fn name(&self) -> &'static str {
        "COG (single-spike)"
    }

    fn energy_per_conversion_fj(&self, bits: u32) -> f64 {
        (1u64 << bits) as f64 * self.e_step_fj + self.e_clock_fj * bits as f64
    }

    fn latency_ns(&self, bits: u32) -> f64 {
        (1u64 << bits) as f64 * self.t_clk_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_hits_anchor() {
        // Fig 6(b): spike-based [14] ≈ ours/0.072 ≈ 10.6 pJ at 8 b.
        let cog = CogReadout::calibrated(8, 10_597.0);
        assert!((cog.energy_per_conversion_fj(8) - 10_597.0).abs() < 1.0);
    }

    #[test]
    fn needs_full_staircase_latency() {
        let cog = CogReadout::new(8, 1.0);
        assert_eq!(cog.latency_ns(8), 128.0); // 256 × 0.5 ns
    }

    #[test]
    fn quantizer_roundtrip_at_codes() {
        let cog = CogReadout::new(8, 1.0);
        for code in [0u32, 1, 100, 255] {
            let v = code as f64 / 255.0;
            assert_eq!(cog.quantize(v), code);
        }
    }
}

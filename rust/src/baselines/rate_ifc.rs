//! Rate-coded current-to-frequency readout (VLSI'19 [18]: "CA+IFC" —
//! current amplifier + integrate-fire converter).
//!
//! Input values arrive rate-coded (x spikes per window) and the output is
//! again a spike count, so a conversion processes O(2^bits) input *and*
//! output events — the energy-per-value scaling that motivated temporal
//! coding in the first place (§II-B).

use super::Readout;

#[derive(Debug, Clone, Copy)]
pub struct RateIfc {
    /// Current-amplifier energy per input spike event (fJ).
    pub e_in_event_fj: f64,
    /// IFC energy per output spike (fJ).
    pub e_out_spike_fj: f64,
    /// Static CA bias power (µW).
    pub p_bias_uw: f64,
    /// Spike slot period (ns).
    pub t_slot_ns: f64,
}

impl Default for RateIfc {
    fn default() -> Self {
        RateIfc {
            e_in_event_fj: 30.0,
            e_out_spike_fj: 35.0,
            p_bias_uw: 3.0,
            t_slot_ns: 1.0,
        }
    }
}

impl RateIfc {
    /// Energy to convert a value `x` at `bits` precision (average case
    /// assumes output rate tracks input rate).
    pub fn value_energy_fj(&self, x: u32, bits: u32) -> f64 {
        let window = self.latency_ns(bits);
        self.p_bias_uw * window
            + (self.e_in_event_fj + self.e_out_spike_fj) * x as f64
    }
}

impl Readout for RateIfc {
    fn name(&self) -> &'static str {
        "Rate CA+IFC"
    }

    fn energy_per_conversion_fj(&self, bits: u32) -> f64 {
        // Average value = half the full scale.
        self.value_energy_fj(1u32 << (bits - 1), bits)
    }

    fn latency_ns(&self, bits: u32) -> f64 {
        (1u64 << bits) as f64 * self.t_slot_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_linear_in_value() {
        let r = RateIfc::default();
        let e0 = r.value_energy_fj(0, 8);
        let e100 = r.value_energy_fj(100, 8);
        let e200 = r.value_energy_fj(200, 8);
        assert!((e200 - e100) - (e100 - e0) < 1e-9);
        assert!(e200 > e100 && e100 > e0);
    }

    #[test]
    fn window_exponential_in_bits() {
        let r = RateIfc::default();
        assert_eq!(r.latency_ns(8), 256.0);
        assert_eq!(r.latency_ns(4), 16.0);
    }

    #[test]
    fn dualspike_beats_rate_on_events() {
        // 2 events vs ≈ x events per value — the core §II-B argument.
        let r = RateIfc::default();
        let per_event = r.e_in_event_fj + r.e_out_spike_fj;
        let rate_e = r.value_energy_fj(200, 8);
        let dual_e = 2.0 * per_event; // same event cost, only 2 events
        assert!(rate_e > 10.0 * dual_e);
    }
}

//! Leaky-integrate-and-fire readout baseline (TCAS-I'22 [24] / Tempo-CIM
//! [22] style): the column current charges a leaky membrane; output spikes
//! fire whenever the membrane crosses threshold. Rate-decoded.
//!
//! Exists to demonstrate the §II-B accuracy critique quantitatively: the
//! leak makes the spike count *nonlinear* in the input current — measured
//! by `nonlinearity()` and shown in the ablation bench.

use super::Readout;

#[derive(Debug, Clone, Copy)]
pub struct LifNeuron {
    /// Membrane capacitance (fF).
    pub c_mem_ff: f64,
    /// Leak conductance (µS).
    pub g_leak_us: f64,
    /// Firing threshold (V).
    pub v_th: f64,
    /// Refractory period after each spike (ns).
    pub t_refrac_ns: f64,
    /// Energy per fired spike (reset + pulse, fJ).
    pub e_spike_fj: f64,
    /// Static bias power of the neuron (µW).
    pub p_bias_uw: f64,
}

impl Default for LifNeuron {
    fn default() -> Self {
        LifNeuron {
            c_mem_ff: 50.0,
            g_leak_us: 0.5,
            v_th: 0.3,
            t_refrac_ns: 1.0,
            e_spike_fj: 40.0,
            p_bias_uw: 4.0,
        }
    }
}

impl LifNeuron {
    /// Simulate a constant input current `i_ua` for `t_ns`; returns the
    /// number of output spikes. Exact per-interval solution (no stepping):
    /// between spikes the membrane is an RC charge toward i/g_leak.
    pub fn spikes_for(&self, i_ua: f64, t_ns: f64) -> u32 {
        if i_ua <= 0.0 || t_ns <= 0.0 {
            return 0;
        }
        let v_inf = i_ua / self.g_leak_us;
        if v_inf <= self.v_th {
            return 0; // never reaches threshold (sub-threshold leak)
        }
        let tau = self.c_mem_ff / self.g_leak_us;
        // Time to cross threshold from reset: t = τ·ln(v∞/(v∞−v_th)).
        let t_cross = tau * (v_inf / (v_inf - self.v_th)).ln();
        let period = t_cross + self.t_refrac_ns;
        (t_ns / period).floor() as u32
    }

    /// Energy of one conversion window (fJ).
    pub fn conversion_energy_fj(&self, i_ua: f64, t_ns: f64) -> f64 {
        self.p_bias_uw * t_ns
            + self.e_spike_fj * self.spikes_for(i_ua, t_ns) as f64
    }

    /// Max deviation from the best-fit line of spike-count vs current,
    /// as a fraction of full scale — the §II-B nonlinearity.
    pub fn nonlinearity(&self, i_max_ua: f64, t_ns: f64, points: usize) -> f64 {
        let xs: Vec<f64> = (1..=points)
            .map(|k| i_max_ua * k as f64 / points as f64)
            .collect();
        let ys: Vec<f64> = xs
            .iter()
            .map(|&i| self.spikes_for(i, t_ns) as f64)
            .collect();
        let fit = crate::util::stats::line_fit(&xs, &ys);
        let full = ys.iter().cloned().fold(0.0, f64::max).max(1.0);
        fit.max_abs_err / full
    }
}

/// Readout-trait wrapper: energy for a full-precision conversion window
/// (2^bits spike slots at the nominal rate).
#[derive(Debug, Clone, Copy)]
pub struct LifReadout {
    pub neuron: LifNeuron,
    /// Nominal input current at full scale (µA).
    pub i_full_ua: f64,
}

impl LifReadout {
    pub fn new(neuron: LifNeuron, i_full_ua: f64) -> Self {
        LifReadout { neuron, i_full_ua }
    }

    /// Window long enough to count 2^bits spikes at full-scale input.
    pub fn window_ns(&self, bits: u32) -> f64 {
        let v_inf = self.i_full_ua / self.neuron.g_leak_us;
        let tau = self.neuron.c_mem_ff / self.neuron.g_leak_us;
        let t_cross = if v_inf > self.neuron.v_th {
            tau * (v_inf / (v_inf - self.neuron.v_th)).ln()
        } else {
            return f64::INFINITY;
        };
        (t_cross + self.neuron.t_refrac_ns) * (1u64 << bits) as f64
    }
}

impl Readout for LifReadout {
    fn name(&self) -> &'static str {
        "LIF (rate)"
    }

    fn energy_per_conversion_fj(&self, bits: u32) -> f64 {
        let t = self.window_ns(bits);
        self.neuron.conversion_energy_fj(self.i_full_ua, t)
    }

    fn latency_ns(&self, bits: u32) -> f64 {
        self.window_ns(bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subthreshold_never_fires() {
        let n = LifNeuron::default();
        // v∞ = i/g = 0.1/0.5 = 0.2 V < 0.3 V threshold.
        assert_eq!(n.spikes_for(0.1, 1e6), 0);
    }

    #[test]
    fn rate_increases_with_current() {
        let n = LifNeuron::default();
        let lo = n.spikes_for(0.2, 1000.0);
        let hi = n.spikes_for(2.0, 1000.0);
        assert!(hi > lo, "{hi} vs {lo}");
    }

    #[test]
    fn leak_makes_rate_nonlinear() {
        // The §II-B critique: LIF rate vs current deviates from a line
        // by several percent of full scale; the OSG's max deviation is
        // ~1e-9 (see repro::fig7). Threshold chosen ≫ noise.
        let n = LifNeuron::default();
        let nl = n.nonlinearity(2.0, 2000.0, 64);
        assert!(nl > 0.01, "nonlinearity {nl}");
    }

    #[test]
    fn conversion_energy_includes_bias_and_spikes() {
        let n = LifNeuron::default();
        let e_idle = n.conversion_energy_fj(0.0, 100.0);
        let e_busy = n.conversion_energy_fj(2.0, 100.0);
        assert!((e_idle - 400.0).abs() < 1e-9); // bias only
        assert!(e_busy > e_idle);
    }

    #[test]
    fn window_scales_exponentially_with_bits() {
        let r = LifReadout::new(LifNeuron::default(), 2.0);
        assert!(r.window_ns(8) / r.window_ns(4) > 15.0);
        assert!(r.energy_per_conversion_fj(8) > r.energy_per_conversion_fj(4));
    }
}

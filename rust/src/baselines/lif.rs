//! Leaky-integrate-and-fire readout baseline (TCAS-I'22 [24] / Tempo-CIM
//! [22] style): the column current charges a leaky membrane; output spikes
//! fire whenever the membrane crosses threshold. Rate-decoded.
//!
//! Exists to demonstrate the §II-B accuracy critique quantitatively: the
//! leak makes the spike count *nonlinear* in the input current — measured
//! by `nonlinearity()` and shown in the ablation bench.

use super::Readout;

#[derive(Debug, Clone, Copy)]
pub struct LifNeuron {
    /// Membrane capacitance (fF).
    pub c_mem_ff: f64,
    /// Leak conductance (µS).
    pub g_leak_us: f64,
    /// Firing threshold (V).
    pub v_th: f64,
    /// Refractory period after each spike (ns).
    pub t_refrac_ns: f64,
    /// Energy per fired spike (reset + pulse, fJ).
    pub e_spike_fj: f64,
    /// Static bias power of the neuron (µW).
    pub p_bias_uw: f64,
}

impl Default for LifNeuron {
    fn default() -> Self {
        LifNeuron {
            c_mem_ff: 50.0,
            g_leak_us: 0.5,
            v_th: 0.3,
            t_refrac_ns: 1.0,
            e_spike_fj: 40.0,
            p_bias_uw: 4.0,
        }
    }
}

impl LifNeuron {
    /// Simulate a constant input current `i_ua` for `t_ns`; returns the
    /// number of output spikes. Exact per-interval solution (no stepping):
    /// between spikes the membrane is an RC charge toward i/g_leak.
    pub fn spikes_for(&self, i_ua: f64, t_ns: f64) -> u32 {
        if i_ua <= 0.0 || t_ns <= 0.0 {
            return 0;
        }
        let v_inf = i_ua / self.g_leak_us;
        if v_inf <= self.v_th {
            return 0; // never reaches threshold (sub-threshold leak)
        }
        let tau = self.c_mem_ff / self.g_leak_us;
        // Time to cross threshold from reset: t = τ·ln(v∞/(v∞−v_th)).
        let t_cross = tau * (v_inf / (v_inf - self.v_th)).ln();
        let period = t_cross + self.t_refrac_ns;
        (t_ns / period).floor() as u32
    }

    /// Energy of one conversion window (fJ).
    pub fn conversion_energy_fj(&self, i_ua: f64, t_ns: f64) -> f64 {
        self.p_bias_uw * t_ns
            + self.e_spike_fj * self.spikes_for(i_ua, t_ns) as f64
    }

    /// Max deviation from the best-fit line of spike-count vs current,
    /// as a fraction of full scale — the §II-B nonlinearity.
    pub fn nonlinearity(&self, i_max_ua: f64, t_ns: f64, points: usize) -> f64 {
        let xs: Vec<f64> = (1..=points)
            .map(|k| i_max_ua * k as f64 / points as f64)
            .collect();
        let ys: Vec<f64> = xs
            .iter()
            .map(|&i| self.spikes_for(i, t_ns) as f64)
            .collect();
        let fit = crate::util::stats::line_fit(&xs, &ys);
        let full = ys.iter().cloned().fold(0.0, f64::max).max(1.0);
        fit.max_abs_err / full
    }
}

/// Discrete-time LIF layer state (DESIGN.md S18): one membrane per
/// neuron, stepped once per streaming timestep. The stream runtime
/// carries one of these per [`SpikingMlp`] stage, resident across
/// timesteps.
///
/// Update rule per step (deterministic, fixed neuron order — the
/// pipelined-vs-serial bit-identity contract leans on this):
/// `v ← v·(1 − leak) + i`; if `v ≥ v_th`, emit a spike and subtract
/// the threshold (reset-by-subtraction, so residual charge carries —
/// the spike count stays linear in the drive, the property §II-B
/// demands). With `leak = 0` this is the exact integrate-and-fire used
/// for rate-coded ANN→SNN conversion.
///
/// [`SpikingMlp`]: crate::stream::SpikingMlp
#[derive(Debug, Clone)]
pub struct DiscreteLif {
    /// Membrane potentials (float activation units).
    pub v: Vec<f64>,
    /// Firing threshold (set `f64::INFINITY` for a pure accumulator).
    pub v_th: f64,
    /// Per-step decay fraction in `[0, 1)`.
    pub leak: f64,
}

impl DiscreteLif {
    pub fn new(n: usize, v_th: f64, leak: f64) -> DiscreteLif {
        assert!(v_th > 0.0, "threshold must be positive");
        assert!((0.0..1.0).contains(&leak), "leak in [0, 1)");
        DiscreteLif {
            v: vec![0.0; n],
            v_th,
            leak,
        }
    }

    /// Zero every membrane (start of a new stream/session).
    pub fn reset(&mut self) {
        self.v.iter_mut().for_each(|v| *v = 0.0);
    }

    /// Leak + integrate one timestep's input currents without firing
    /// (the readout accumulator path).
    pub fn integrate(&mut self, cur: &[f64]) {
        assert_eq!(cur.len(), self.v.len(), "current vector length");
        let keep = 1.0 - self.leak;
        for (v, &i) in self.v.iter_mut().zip(cur) {
            *v = *v * keep + i;
        }
    }

    /// Leak, integrate, fire: appends the spiking neuron indices to
    /// `out` (ascending — already a valid macro event list) and returns
    /// the spike count. At most one spike per neuron per step; excess
    /// drive stays on the membrane.
    pub fn step(&mut self, cur: &[f64], out: &mut Vec<u32>) -> u32 {
        assert_eq!(cur.len(), self.v.len(), "current vector length");
        out.clear();
        let keep = 1.0 - self.leak;
        for (n, (v, &i)) in self.v.iter_mut().zip(cur).enumerate() {
            *v = *v * keep + i;
            if *v >= self.v_th {
                *v -= self.v_th;
                out.push(n as u32);
            }
        }
        out.len() as u32
    }
}

/// Readout-trait wrapper: energy for a full-precision conversion window
/// (2^bits spike slots at the nominal rate).
#[derive(Debug, Clone, Copy)]
pub struct LifReadout {
    pub neuron: LifNeuron,
    /// Nominal input current at full scale (µA).
    pub i_full_ua: f64,
}

impl LifReadout {
    pub fn new(neuron: LifNeuron, i_full_ua: f64) -> Self {
        LifReadout { neuron, i_full_ua }
    }

    /// Window long enough to count 2^bits spikes at full-scale input.
    pub fn window_ns(&self, bits: u32) -> f64 {
        let v_inf = self.i_full_ua / self.neuron.g_leak_us;
        let tau = self.neuron.c_mem_ff / self.neuron.g_leak_us;
        let t_cross = if v_inf > self.neuron.v_th {
            tau * (v_inf / (v_inf - self.neuron.v_th)).ln()
        } else {
            return f64::INFINITY;
        };
        (t_cross + self.neuron.t_refrac_ns) * (1u64 << bits) as f64
    }
}

impl Readout for LifReadout {
    fn name(&self) -> &'static str {
        "LIF (rate)"
    }

    fn energy_per_conversion_fj(&self, bits: u32) -> f64 {
        let t = self.window_ns(bits);
        self.neuron.conversion_energy_fj(self.i_full_ua, t)
    }

    fn latency_ns(&self, bits: u32) -> f64 {
        self.window_ns(bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subthreshold_never_fires() {
        let n = LifNeuron::default();
        // v∞ = i/g = 0.1/0.5 = 0.2 V < 0.3 V threshold.
        assert_eq!(n.spikes_for(0.1, 1e6), 0);
    }

    #[test]
    fn rate_increases_with_current() {
        let n = LifNeuron::default();
        let lo = n.spikes_for(0.2, 1000.0);
        let hi = n.spikes_for(2.0, 1000.0);
        assert!(hi > lo, "{hi} vs {lo}");
    }

    #[test]
    fn leak_makes_rate_nonlinear() {
        // The §II-B critique: LIF rate vs current deviates from a line
        // by several percent of full scale; the OSG's max deviation is
        // ~1e-9 (see repro::fig7). Threshold chosen ≫ noise.
        let n = LifNeuron::default();
        let nl = n.nonlinearity(2.0, 2000.0, 64);
        assert!(nl > 0.01, "nonlinearity {nl}");
    }

    #[test]
    fn conversion_energy_includes_bias_and_spikes() {
        let n = LifNeuron::default();
        let e_idle = n.conversion_energy_fj(0.0, 100.0);
        let e_busy = n.conversion_energy_fj(2.0, 100.0);
        assert!((e_idle - 400.0).abs() < 1e-9); // bias only
        assert!(e_busy > e_idle);
    }

    #[test]
    fn discrete_lif_rate_tracks_drive_linearly() {
        // Reset-by-subtraction keeps the count linear: constant drive d
        // over T steps yields floor-ish T·d/v_th spikes.
        let mut lif = DiscreteLif::new(1, 1.0, 0.0);
        let mut out = Vec::new();
        let mut spikes = 0u32;
        for _ in 0..100 {
            spikes += lif.step(&[0.3], &mut out);
        }
        assert_eq!(spikes, 30);
        // Double drive → double rate.
        lif.reset();
        assert_eq!(lif.v, vec![0.0]);
        let mut spikes2 = 0u32;
        for _ in 0..100 {
            spikes2 += lif.step(&[0.6], &mut out);
        }
        assert_eq!(spikes2, 60);
    }

    #[test]
    fn discrete_lif_leak_suppresses_subthreshold_drive() {
        // With leak, v converges to d/leak; below threshold it never
        // fires — the LIF nonlinearity the IF (leak = 0) variant lacks.
        let mut leaky = DiscreteLif::new(1, 1.0, 0.5);
        let mut ifree = DiscreteLif::new(1, 1.0, 0.0);
        let mut out = Vec::new();
        let (mut s_leaky, mut s_if) = (0u32, 0u32);
        for _ in 0..200 {
            s_leaky += leaky.step(&[0.4], &mut out);
            s_if += ifree.step(&[0.4], &mut out);
        }
        assert_eq!(s_leaky, 0, "v∞ = 0.8 < 1.0 never crosses");
        assert_eq!(s_if, 80);
    }

    #[test]
    fn discrete_lif_emits_sorted_event_list() {
        let mut lif = DiscreteLif::new(4, 1.0, 0.0);
        let mut out = Vec::new();
        lif.step(&[1.5, 0.2, 3.0, 1.0], &mut out);
        assert_eq!(out, vec![0, 2, 3]);
        // Residuals carry: neuron 0 holds 0.5, neuron 2 holds 2.0.
        assert_eq!(lif.v, vec![0.5, 0.2, 2.0, 0.0]);
        // Readout accumulator: integrate never fires.
        let mut acc = DiscreteLif::new(2, f64::INFINITY, 0.0);
        acc.integrate(&[5.0, -1.0]);
        acc.integrate(&[5.0, -1.0]);
        assert_eq!(acc.v, vec![10.0, -2.0]);
    }

    #[test]
    fn window_scales_exponentially_with_bits() {
        let r = LifReadout::new(LifNeuron::default(), 2.0);
        assert!(r.window_ns(8) / r.window_ns(4) > 15.0);
        assert!(r.energy_per_conversion_fj(8) > r.energy_per_conversion_fj(4));
    }
}

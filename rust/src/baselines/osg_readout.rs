//! Our OSG expressed through the common `Readout` interface, so the
//! Fig 6(b)/Table II comparisons query every scheme the same way.
//!
//! Energy comes from the calibrated `EnergyParams` (the same numbers the
//! macro simulator charges per conversion), not a separate anchor — so the
//! comparison is self-consistent with the end-to-end energy ledger.

use crate::config::MacroConfig;
use crate::energy::{mvm_energy, nominal_activity, EnergyParams};

use super::Readout;

#[derive(Debug, Clone)]
pub struct OsgReadout {
    pub cfg: MacroConfig,
    pub params: EnergyParams,
}

impl OsgReadout {
    pub fn new(cfg: MacroConfig) -> Self {
        OsgReadout {
            cfg,
            params: EnergyParams::default(),
        }
    }

    fn scaled_cfg(&self, bits: u32) -> MacroConfig {
        MacroConfig {
            input_bits: bits,
            ..self.cfg.clone()
        }
    }
}

impl Readout for OsgReadout {
    fn name(&self) -> &'static str {
        "OSG (this work)"
    }

    fn energy_per_conversion_fj(&self, bits: u32) -> f64 {
        // Per-column OSG energy of the nominal workload at `bits`.
        let cfg = self.scaled_cfg(bits);
        let e = mvm_energy(&cfg, &self.params, &nominal_activity(&cfg));
        e.osg_fj / cfg.cols as f64
    }

    fn latency_ns(&self, bits: u32) -> f64 {
        let cfg = self.scaled_cfg(bits);
        let act = nominal_activity(&cfg);
        act.t_charge_ns + act.t_out_ns[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eight_bit_conversion_near_763_fj() {
        let r = OsgReadout::new(MacroConfig::default());
        let e = r.energy_per_conversion_fj(8);
        assert!((e - 763.0).abs() < 40.0, "{e}");
    }

    #[test]
    fn energy_scales_linearly_not_exponentially() {
        // Temporal coding: window halves per bit removed — linear-ish in
        // 2^bits but with large fixed-free structure vs ADC's cap array.
        let r = OsgReadout::new(MacroConfig::default());
        let e8 = r.energy_per_conversion_fj(8);
        let e4 = r.energy_per_conversion_fj(4);
        assert!(e8 > e4);
        assert!(e8 / e4 < 20.0);
    }

    #[test]
    fn latency_includes_charge_and_compare() {
        let r = OsgReadout::new(MacroConfig::default());
        let l = r.latency_ns(8);
        assert!(l > 51.0 && l < 120.0, "{l}");
    }
}

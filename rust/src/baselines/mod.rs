//! Baseline readout schemes (DESIGN.md S10) — every comparison point of
//! Table II and Fig 6(b), behind one interface:
//!
//! | scheme | paper | role |
//! |---|---|---|
//! | [`SarAdc`] | DAC'24 [16], ESSCIRC'21 [13] | analog-CIM ADC readout |
//! | [`Tdc`] | Nature'22 [15] | time-to-digital readout |
//! | [`CogReadout`] | DAC'20 [14] | clocked single-spike readout |
//! | [`LifReadout`] | TCAS-I'22 [24] | leaky integrate-fire (rate out) |
//! | [`RateIfc`] | VLSI'19 [18] | rate-coded CA+IFC |
//! | [`OsgReadout`] | this work | event-driven dual-spike OSG |
//!
//! Each baseline has exactly one free parameter calibrated to its
//! published Fig 6(b) anchor; all trends (precision, latency, array-size
//! scaling) are produced by the models.

pub mod adc;
pub mod cog;
pub mod lif;
pub mod osg_readout;
pub mod rate_ifc;
pub mod tdc;

pub use adc::SarAdc;
pub use cog::CogReadout;
pub use lif::{DiscreteLif, LifNeuron, LifReadout};
pub use osg_readout::OsgReadout;
pub use rate_ifc::RateIfc;
pub use tdc::Tdc;

/// Common interface over all readout/sensing schemes.
pub trait Readout {
    fn name(&self) -> &'static str;
    /// Energy for one column conversion at `bits` input precision (fJ).
    fn energy_per_conversion_fj(&self, bits: u32) -> f64;
    /// Conversion latency (ns).
    fn latency_ns(&self, bits: u32) -> f64;
}

/// The Fig 6(b) anchor set (fJ per 8-bit conversion), derived from the
/// paper's stated reductions against our ≈763 fJ OSG conversion:
/// 96.6 % vs ADC [16], 92.8 % vs spike [14], 71.2 % vs TDC [15].
pub mod anchors {
    /// Our OSG conversion energy at 8 bits (DESIGN.md §6).
    pub const OURS_FJ: f64 = 763.0;
    pub const ADC_DAC24_FJ: f64 = OURS_FJ / (1.0 - 0.966);
    pub const SPIKE_DAC20_FJ: f64 = OURS_FJ / (1.0 - 0.928);
    pub const TDC_NATURE22_FJ: f64 = OURS_FJ / (1.0 - 0.712);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_schemes() -> Vec<Box<dyn Readout>> {
        vec![
            Box::new(SarAdc::calibrated(8, anchors::ADC_DAC24_FJ)),
            Box::new(Tdc::calibrated(8, anchors::TDC_NATURE22_FJ)),
            Box::new(CogReadout::calibrated(8, anchors::SPIKE_DAC20_FJ)),
            Box::new(OsgReadout::new(crate::config::MacroConfig::default())),
        ]
    }

    #[test]
    fn ours_is_cheapest_at_8bit() {
        let schemes = all_schemes();
        let ours = schemes.last().unwrap().energy_per_conversion_fj(8);
        for s in &schemes[..schemes.len() - 1] {
            assert!(
                ours < s.energy_per_conversion_fj(8),
                "{} should cost more",
                s.name()
            );
        }
    }

    #[test]
    fn fig6b_reductions_match_paper() {
        let ours = OsgReadout::new(crate::config::MacroConfig::default())
            .energy_per_conversion_fj(8);
        let adc = SarAdc::calibrated(8, anchors::ADC_DAC24_FJ)
            .energy_per_conversion_fj(8);
        let cog = CogReadout::calibrated(8, anchors::SPIKE_DAC20_FJ)
            .energy_per_conversion_fj(8);
        let tdc = Tdc::calibrated(8, anchors::TDC_NATURE22_FJ)
            .energy_per_conversion_fj(8);
        let red = |base: f64| 1.0 - ours / base;
        assert!((red(adc) - 0.966).abs() < 0.01, "{}", red(adc));
        assert!((red(cog) - 0.928).abs() < 0.01, "{}", red(cog));
        assert!((red(tdc) - 0.712).abs() < 0.02, "{}", red(tdc));
    }
}

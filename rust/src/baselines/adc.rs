//! SAR-ADC readout baseline (the analog-CIM comparison points: DAC'24
//! [16] and ESSCIRC'21 [13] in Table II / Fig 6b).
//!
//! Per conversion: a binary-weighted capacitive DAC (2^bits·C_unit·V_ref²
//! switched-cap energy), `bits` comparator decisions, and SAR logic.
//! One free parameter (`c_unit_ff`) is solved from the paper's Fig 6(b)
//! anchor; the *scaling* vs precision is produced by the model.

use super::Readout;

#[derive(Debug, Clone, Copy)]
pub struct SarAdc {
    pub bits: u32,
    pub c_unit_ff: f64,
    pub v_ref: f64,
    /// Energy per comparator decision (fJ).
    pub e_comp_fj: f64,
    /// SAR logic energy per bit cycle (fJ).
    pub e_logic_fj: f64,
    /// Conversion time per bit (ns) — SAR is one decision per bit.
    pub t_bit_cycle_ns: f64,
}

impl SarAdc {
    /// Generic 28 nm-class SAR.
    pub fn new(bits: u32, c_unit_ff: f64) -> Self {
        SarAdc {
            bits,
            c_unit_ff,
            v_ref: 1.1,
            e_comp_fj: 15.0,
            e_logic_fj: 8.0,
            t_bit_cycle_ns: 1.0,
        }
    }

    /// Solve `c_unit_ff` so that `energy_per_conversion_fj` == `anchor_fj`
    /// at `bits` — calibration to the published comparison point.
    pub fn calibrated(bits: u32, anchor_fj: f64) -> Self {
        let proto = SarAdc::new(bits, 0.0);
        let fixed = (proto.e_comp_fj + proto.e_logic_fj) * bits as f64;
        let cap_term = anchor_fj - fixed;
        assert!(cap_term > 0.0, "anchor too small for fixed costs");
        let c_unit =
            cap_term / ((1u64 << bits) as f64 * proto.v_ref * proto.v_ref);
        SarAdc::new(bits, c_unit)
    }

    /// Functional model: quantize a voltage in [0, v_ref] to a code.
    pub fn quantize(&self, v: f64) -> u32 {
        let max = (1u64 << self.bits) - 1;
        let q = (v / self.v_ref * max as f64).round();
        (q.max(0.0) as u64).min(max) as u32
    }
}

impl Readout for SarAdc {
    fn name(&self) -> &'static str {
        "SAR-ADC"
    }

    fn energy_per_conversion_fj(&self, bits: u32) -> f64 {
        // DAC array scales 2^bits; comparator+logic scale linearly.
        (1u64 << bits) as f64 * self.c_unit_ff * self.v_ref * self.v_ref
            + (self.e_comp_fj + self.e_logic_fj) * bits as f64
    }

    fn latency_ns(&self, bits: u32) -> f64 {
        bits as f64 * self.t_bit_cycle_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_hits_anchor() {
        // Fig 6(b): ADC-based [16] sensing ≈ ours/0.034 ≈ 22.4 pJ at 8 b.
        let adc = SarAdc::calibrated(8, 22_441.0);
        let e = adc.energy_per_conversion_fj(8);
        assert!((e - 22_441.0).abs() < 1.0, "{e}");
    }

    #[test]
    fn energy_grows_exponentially_with_bits() {
        let adc = SarAdc::calibrated(8, 22_441.0);
        let e6 = adc.energy_per_conversion_fj(6);
        let e8 = adc.energy_per_conversion_fj(8);
        assert!(e8 / e6 > 3.0, "cap-array term must dominate: {}", e8 / e6);
    }

    #[test]
    fn quantizer_endpoints() {
        let adc = SarAdc::new(8, 1.0);
        assert_eq!(adc.quantize(0.0), 0);
        assert_eq!(adc.quantize(1.1), 255);
        assert_eq!(adc.quantize(2.0), 255); // clamps
        assert_eq!(adc.quantize(0.55), 128);
    }

    #[test]
    fn latency_linear_in_bits() {
        let adc = SarAdc::new(8, 1.0);
        assert_eq!(adc.latency_ns(8), 8.0);
        assert_eq!(adc.latency_ns(4), 4.0);
    }
}

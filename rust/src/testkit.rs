//! Property-testing kit (DESIGN.md S0; substrate — `proptest` is
//! unavailable offline).
//!
//! Deterministic random-case property runner with failure reporting and
//! seed replay: each property runs N generated cases; on failure the
//! offending case seed is printed so `replay(seed)` reproduces it.
//! Used by `rust/tests/coordinator_props.rs` and friends.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig {
            cases: 64,
            seed: 0x5eed_cafe,
        }
    }
}

/// Run `prop` on `cases` generated inputs; panics with the case seed on
/// the first failure. `gen` builds an input from a fresh Rng.
pub fn check<T: std::fmt::Debug, G, P>(cfg: PropConfig, name: &str, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut meta = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let case_seed = meta.next_u64();
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed on case {case} \
                 (replay seed {case_seed:#x}):\n  input: {input:?}\n  {msg}"
            );
        }
    }
}

/// Where a fast-mode tier-1 record for bench `group` should land: the
/// bench dir (`SPIKEMRAM_BENCH_DIR`, default the working directory),
/// unless a release-profile record (from the ci.sh smoke runs) already
/// sits there — never clobber that one; validate the writer against a
/// scratch directory instead. The single keep-release-record policy
/// shared by the tier-1 record writers in `rust/tests/batch_identity.rs`
/// and `rust/tests/stream_e2e.rs`.
pub fn bench_record_dir(group: &str) -> std::path::PathBuf {
    let record_dir = std::path::PathBuf::from(
        std::env::var("SPIKEMRAM_BENCH_DIR").unwrap_or_else(|_| ".".into()),
    );
    let keep_release = std::fs::read_to_string(
        record_dir.join(format!("BENCH_{group}.json")),
    )
    .ok()
    .and_then(|s| crate::util::json::parse(&s).ok())
    .and_then(|d| d.get("profile").and_then(|p| p.as_str().map(String::from)))
    .is_some_and(|p| p == "release");
    if keep_release {
        let dir =
            std::env::temp_dir().join(format!("spikemram_{group}_json_test"));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    } else {
        record_dir
    }
}

/// Common generators.
pub mod gen {
    use crate::util::rng::Rng;

    /// Vector of 8-bit digital inputs.
    pub fn input_vec(rng: &mut Rng, len: usize) -> Vec<u32> {
        (0..len).map(|_| rng.below(256) as u32).collect()
    }

    /// Sparse input vector with the given active fraction.
    pub fn sparse_input(rng: &mut Rng, len: usize, density: f64) -> Vec<u32> {
        (0..len)
            .map(|_| {
                if rng.f64() < density {
                    1 + rng.below(255) as u32
                } else {
                    0
                }
            })
            .collect()
    }

    /// Row-major 2-bit code matrix.
    pub fn codes(rng: &mut Rng, k: usize, n: usize) -> Vec<u8> {
        (0..k * n).map(|_| rng.below(4) as u8).collect()
    }
}

/// Assert two floats are close (relative + absolute tolerance).
pub fn assert_close(a: f64, b: f64, rtol: f64, atol: f64) -> Result<(), String> {
    let diff = (a - b).abs();
    let bound = atol + rtol * b.abs().max(a.abs());
    if diff <= bound {
        Ok(())
    } else {
        Err(format!("{a} !≈ {b} (diff {diff} > bound {bound})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check(
            PropConfig { cases: 16, seed: 1 },
            "sum_commutes",
            |rng| (rng.below(100), rng.below(100)),
            |&(a, b)| {
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("math broke".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        check(
            PropConfig { cases: 16, seed: 2 },
            "always_fails",
            |rng| rng.below(10),
            |_| Err("nope".into()),
        );
    }

    #[test]
    fn assert_close_tolerances() {
        assert!(assert_close(1.0, 1.0 + 1e-9, 1e-6, 0.0).is_ok());
        assert!(assert_close(1.0, 1.1, 1e-6, 0.0).is_err());
        assert!(assert_close(0.0, 1e-9, 0.0, 1e-6).is_ok());
    }

    #[test]
    fn generators_respect_bounds() {
        let mut rng = Rng::new(3);
        let x = gen::input_vec(&mut rng, 64);
        assert!(x.iter().all(|&v| v < 256));
        let s = gen::sparse_input(&mut rng, 1000, 0.1);
        let active = s.iter().filter(|&&v| v > 0).count();
        assert!(active > 40 && active < 250, "{active}");
        let c = gen::codes(&mut rng, 8, 8);
        assert!(c.iter().all(|&v| v < 4));
    }
}

//! Small statistics toolkit used by the repro harness and benches:
//! summary stats, percentiles, least-squares line fit (Fig 7a linearity),
//! and a latency histogram for the serving path.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64)
        .sqrt()
}

/// p-th percentile (0..=100) by linear interpolation on sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Result of a least-squares straight-line fit y = a + b·x.
#[derive(Debug, Clone, Copy)]
pub struct LineFit {
    /// Intercept.
    pub a: f64,
    /// Slope.
    pub b: f64,
    /// Coefficient of determination.
    pub r2: f64,
    /// Root-mean-square residual.
    pub rmse: f64,
    /// Max |residual|.
    pub max_abs_err: f64,
}

/// Ordinary least squares fit; panics if fewer than 2 points.
pub fn line_fit(x: &[f64], y: &[f64]) -> LineFit {
    assert_eq!(x.len(), y.len());
    assert!(x.len() >= 2, "need >= 2 points");
    let n = x.len() as f64;
    let mx = mean(x);
    let my = mean(y);
    let sxx: f64 = x.iter().map(|xi| (xi - mx) * (xi - mx)).sum();
    let sxy: f64 = x
        .iter()
        .zip(y)
        .map(|(xi, yi)| (xi - mx) * (yi - my))
        .sum();
    let b = if sxx == 0.0 { 0.0 } else { sxy / sxx };
    let a = my - b * mx;
    let ss_tot: f64 = y.iter().map(|yi| (yi - my) * (yi - my)).sum();
    let mut ss_res = 0.0;
    let mut max_abs = 0.0f64;
    for (xi, yi) in x.iter().zip(y) {
        let r = yi - (a + b * xi);
        ss_res += r * r;
        max_abs = max_abs.max(r.abs());
    }
    let r2 = if ss_tot == 0.0 { 1.0 } else { 1.0 - ss_res / ss_tot };
    LineFit {
        a,
        b,
        r2,
        rmse: (ss_res / n).sqrt(),
        max_abs_err: max_abs,
    }
}

/// Fixed-bucket latency histogram (log-ish buckets supplied by caller).
#[derive(Debug, Clone)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    total: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Histogram {
    /// `bounds` are ascending upper edges; one overflow bucket is added.
    pub fn new(bounds: Vec<f64>) -> Self {
        assert!(bounds.windows(2).all(|w| w[0] < w[1]));
        let n = bounds.len() + 1;
        Histogram {
            bounds,
            counts: vec![0; n],
            total: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn record(&mut self, x: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| x <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    /// Approximate quantile from bucket counts (upper-edge convention).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return if i < self.bounds.len() {
                    self.bounds[i]
                } else {
                    self.max
                };
            }
        }
        self.max
    }

    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.3} min={:.3} p50={:.3} p95={:.3} p99={:.3} max={:.3}",
            self.total,
            self.mean(),
            self.min,
            self.quantile(0.50),
            self.quantile(0.95),
            self.quantile(0.99),
            self.max
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basics() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn perfect_line_has_r2_one() {
        let x: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|xi| 3.0 + 2.0 * xi).collect();
        let f = line_fit(&x, &y);
        assert!((f.a - 3.0).abs() < 1e-9);
        assert!((f.b - 2.0).abs() < 1e-9);
        assert!((f.r2 - 1.0).abs() < 1e-12);
        assert!(f.rmse < 1e-9);
    }

    #[test]
    fn noisy_line_r2_below_one() {
        let x: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let y: Vec<f64> = x
            .iter()
            .enumerate()
            .map(|(i, xi)| 2.0 * xi + if i % 2 == 0 { 5.0 } else { -5.0 })
            .collect();
        let f = line_fit(&x, &y);
        assert!(f.r2 < 1.0);
        assert!(f.r2 > 0.9); // still dominated by the trend
        assert!(f.max_abs_err > 4.0);
    }

    #[test]
    fn histogram_quantiles() {
        let mut h = Histogram::new(vec![1.0, 2.0, 4.0, 8.0]);
        for x in [0.5, 0.9, 1.5, 3.0, 3.5, 7.0, 20.0] {
            h.record(x);
        }
        assert_eq!(h.count(), 7);
        assert_eq!(h.quantile(0.01), 1.0);
        assert_eq!(h.quantile(0.99), 20.0);
        assert!(h.mean() > 0.0);
        assert!(h.summary().contains("n=7"));
    }
}

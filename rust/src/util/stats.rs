//! Small statistics toolkit used by the repro harness and benches:
//! summary stats, percentiles, least-squares line fit (Fig 7a linearity),
//! and a latency histogram for the serving path.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64)
        .sqrt()
}

/// p-th percentile (0..=100) by linear interpolation on sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Result of a least-squares straight-line fit y = a + b·x.
#[derive(Debug, Clone, Copy)]
pub struct LineFit {
    /// Intercept.
    pub a: f64,
    /// Slope.
    pub b: f64,
    /// Coefficient of determination.
    pub r2: f64,
    /// Root-mean-square residual.
    pub rmse: f64,
    /// Max |residual|.
    pub max_abs_err: f64,
}

/// Ordinary least squares fit; panics if fewer than 2 points.
pub fn line_fit(x: &[f64], y: &[f64]) -> LineFit {
    assert_eq!(x.len(), y.len());
    assert!(x.len() >= 2, "need >= 2 points");
    let n = x.len() as f64;
    let mx = mean(x);
    let my = mean(y);
    let sxx: f64 = x.iter().map(|xi| (xi - mx) * (xi - mx)).sum();
    let sxy: f64 = x
        .iter()
        .zip(y)
        .map(|(xi, yi)| (xi - mx) * (yi - my))
        .sum();
    let b = if sxx == 0.0 { 0.0 } else { sxy / sxx };
    let a = my - b * mx;
    let ss_tot: f64 = y.iter().map(|yi| (yi - my) * (yi - my)).sum();
    let mut ss_res = 0.0;
    let mut max_abs = 0.0f64;
    for (xi, yi) in x.iter().zip(y) {
        let r = yi - (a + b * xi);
        ss_res += r * r;
        max_abs = max_abs.max(r.abs());
    }
    let r2 = if ss_tot == 0.0 { 1.0 } else { 1.0 - ss_res / ss_tot };
    LineFit {
        a,
        b,
        r2,
        rmse: (ss_res / n).sqrt(),
        max_abs_err: max_abs,
    }
}

/// Fixed-bucket latency histogram (log-ish buckets supplied by caller).
#[derive(Debug, Clone)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    total: u64,
    sum: f64,
    min: f64,
    max: f64,
    /// Records below the first edge (they land in bucket 0, which
    /// silently floors quantiles at `bounds[0]` — surfaced so readers
    /// can tell).
    underflow: u64,
}

impl Histogram {
    /// `bounds` are ascending upper edges; one overflow bucket is added.
    pub fn new(bounds: Vec<f64>) -> Self {
        assert!(bounds.windows(2).all(|w| w[0] < w[1]));
        let n = bounds.len() + 1;
        Histogram {
            bounds,
            counts: vec![0; n],
            total: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            underflow: 0,
        }
    }

    /// `n` logarithmically spaced upper edges from `lo` to `hi`
    /// inclusive (both pinned exactly) — the shared constructor behind
    /// the serving latency/batch histograms, replacing hand-listed
    /// bucket tables.
    pub fn log_spaced(lo: f64, hi: f64, n: usize) -> Self {
        assert!(lo > 0.0 && hi > lo, "log_spaced needs 0 < lo < hi");
        assert!(n >= 2, "log_spaced needs >= 2 edges");
        let (llo, lhi) = (lo.ln(), hi.ln());
        let bounds: Vec<f64> = (0..n)
            .map(|i| {
                if i == 0 {
                    lo
                } else if i == n - 1 {
                    hi
                } else {
                    let t = i as f64 / (n - 1) as f64;
                    (llo + t * (lhi - llo)).exp()
                }
            })
            .collect();
        Histogram::new(bounds)
    }

    pub fn record(&mut self, x: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| x <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        if self.bounds.first().is_some_and(|&b| x < b) {
            self.underflow += 1;
        }
    }

    /// Records above the last edge (bucket quantiles report `max` for
    /// them).
    pub fn overflow_count(&self) -> u64 {
        *self.counts.last().expect("overflow bucket")
    }

    /// Records below the first edge.
    pub fn underflow_count(&self) -> u64 {
        self.underflow
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    /// Approximate quantile from bucket counts (upper-edge convention).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return if i < self.bounds.len() {
                    self.bounds[i]
                } else {
                    self.max
                };
            }
        }
        self.max
    }

    /// Scalar digest of the distribution — the single source both the
    /// text [`summary`](Self::summary) and the JSON telemetry
    /// (`MetricsSnapshot::to_json`) are built from, so they can never
    /// disagree.
    pub fn stats(&self) -> HistStats {
        HistStats {
            n: self.total,
            mean: self.mean(),
            min: self.min,
            max: self.max,
            p50: self.quantile(0.50),
            p95: self.quantile(0.95),
            p99: self.quantile(0.99),
            overflow: self.overflow_count(),
            underflow: self.underflow,
        }
    }

    pub fn summary(&self) -> String {
        self.stats().summary_line()
    }
}

/// Scalar digest of a [`Histogram`] (DESIGN.md S20): one struct both
/// the human summary line and the machine-readable JSON render from.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HistStats {
    pub n: u64,
    pub mean: f64,
    /// Exact observed min (`+inf` when empty, like a fresh histogram).
    pub min: f64,
    /// Exact observed max (`-inf` when empty).
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    /// Records above the last bucket edge.
    pub overflow: u64,
    /// Records below the first bucket edge.
    pub underflow: u64,
}

impl Default for HistStats {
    fn default() -> Self {
        HistStats {
            n: 0,
            mean: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            p50: 0.0,
            p95: 0.0,
            p99: 0.0,
            overflow: 0,
            underflow: 0,
        }
    }
}

impl HistStats {
    /// The canonical one-line text form (used verbatim inside
    /// `Metrics::summary`).
    pub fn summary_line(&self) -> String {
        format!(
            "n={} mean={:.3} min={:.3} p50={:.3} p95={:.3} p99={:.3} \
             max={:.3} of={} uf={}",
            self.n,
            self.mean,
            self.min,
            self.p50,
            self.p95,
            self.p99,
            self.max,
            self.overflow,
            self.underflow
        )
    }

    /// Machine-readable form. Note the vendored writer serializes
    /// non-finite numbers (empty-histogram min/max) as `null`.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{self, Json};
        json::obj(vec![
            ("n", Json::Num(self.n as f64)),
            ("mean", Json::Num(self.mean)),
            ("min", Json::Num(self.min)),
            ("max", Json::Num(self.max)),
            ("p50", Json::Num(self.p50)),
            ("p95", Json::Num(self.p95)),
            ("p99", Json::Num(self.p99)),
            ("overflow", Json::Num(self.overflow as f64)),
            ("underflow", Json::Num(self.underflow as f64)),
        ])
    }

    /// Inverse of [`to_json`](Self::to_json); `null`/missing min and
    /// max fall back to the empty-histogram sentinels.
    pub fn from_json(j: &crate::util::json::Json) -> HistStats {
        use crate::util::json::Json;
        let f = |k: &str, d: f64| j.get(k).and_then(Json::as_f64).unwrap_or(d);
        HistStats {
            n: f("n", 0.0) as u64,
            mean: f("mean", 0.0),
            min: f("min", f64::INFINITY),
            max: f("max", f64::NEG_INFINITY),
            p50: f("p50", 0.0),
            p95: f("p95", 0.0),
            p99: f("p99", 0.0),
            overflow: f("overflow", 0.0) as u64,
            underflow: f("underflow", 0.0) as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basics() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn perfect_line_has_r2_one() {
        let x: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|xi| 3.0 + 2.0 * xi).collect();
        let f = line_fit(&x, &y);
        assert!((f.a - 3.0).abs() < 1e-9);
        assert!((f.b - 2.0).abs() < 1e-9);
        assert!((f.r2 - 1.0).abs() < 1e-12);
        assert!(f.rmse < 1e-9);
    }

    #[test]
    fn noisy_line_r2_below_one() {
        let x: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let y: Vec<f64> = x
            .iter()
            .enumerate()
            .map(|(i, xi)| 2.0 * xi + if i % 2 == 0 { 5.0 } else { -5.0 })
            .collect();
        let f = line_fit(&x, &y);
        assert!(f.r2 < 1.0);
        assert!(f.r2 > 0.9); // still dominated by the trend
        assert!(f.max_abs_err > 4.0);
    }

    #[test]
    fn histogram_quantiles() {
        let mut h = Histogram::new(vec![1.0, 2.0, 4.0, 8.0]);
        for x in [0.5, 0.9, 1.5, 3.0, 3.5, 7.0, 20.0] {
            h.record(x);
        }
        assert_eq!(h.count(), 7);
        assert_eq!(h.quantile(0.01), 1.0);
        assert_eq!(h.quantile(0.99), 20.0);
        assert!(h.mean() > 0.0);
        assert!(h.summary().contains("n=7"));
    }

    #[test]
    fn histogram_surfaces_overflow_and_underflow() {
        let mut h = Histogram::new(vec![1.0, 2.0, 4.0, 8.0]);
        for x in [0.5, 0.9, 1.5, 3.0, 3.5, 7.0, 20.0] {
            h.record(x);
        }
        assert_eq!(h.overflow_count(), 1); // 20.0
        assert_eq!(h.underflow_count(), 2); // 0.5, 0.9
        let s = h.summary();
        assert!(s.contains("of=1"), "{s}");
        assert!(s.contains("uf=2"), "{s}");
    }

    #[test]
    fn log_spaced_pins_endpoints_and_ascends() {
        let h = Histogram::log_spaced(10.0, 200_000.0, 12);
        assert_eq!(h.bounds.len(), 12);
        assert_eq!(h.bounds[0], 10.0);
        assert_eq!(h.bounds[11], 200_000.0);
        assert!(h.bounds.windows(2).all(|w| w[0] < w[1]));
        // Log spacing: near-constant ratio between adjacent edges.
        let r0 = h.bounds[1] / h.bounds[0];
        let r1 = h.bounds[10] / h.bounds[9];
        assert!((r0 / r1 - 1.0).abs() < 1e-6, "{r0} vs {r1}");

        // The batch-size flavor lands on the familiar powers of two.
        let b = Histogram::log_spaced(1.0, 64.0, 7);
        for (i, want) in [1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0]
            .iter()
            .enumerate()
        {
            assert!((b.bounds[i] - want).abs() < 1e-9, "{:?}", b.bounds);
        }
    }

    #[test]
    fn hist_stats_json_round_trip_and_summary_match() {
        let mut h = Histogram::log_spaced(1.0, 1000.0, 7);
        for x in [2.0, 30.0, 400.0, 5000.0] {
            h.record(x);
        }
        let s = h.stats();
        assert_eq!(s.summary_line(), h.summary());
        let back = HistStats::from_json(&s.to_json());
        assert_eq!(back, s);
        // Empty histograms keep their sentinels through JSON built
        // in-memory (serialized text would null the infinities).
        let empty = Histogram::new(vec![1.0]).stats();
        let back = HistStats::from_json(&empty.to_json());
        assert_eq!(back.summary_line(), empty.summary_line());
    }
}

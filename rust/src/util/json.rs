//! Minimal JSON reader/writer (substrate — `serde_json` unavailable offline).
//!
//! Covers exactly what the repo needs: parsing `artifacts/manifest.json`
//! and emitting result/metric files. Supports the full JSON value grammar
//! (objects, arrays, strings with escapes, numbers, bools, null) but is not
//! tuned for huge documents.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// `obj["a"]["b"]`-style access; returns None on any miss.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent + 1);
        let pad_end = "  ".repeat(indent);
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&pad_end);
                out.push(']');
            }
            Json::Obj(o) if !o.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&pad_end);
                out.push('}');
            }
            _ => self.write(out),
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_finite() && x == x.trunc() && x.abs() < 1e15 {
        let _ = write!(out, "{}", x as i64);
    } else if x.is_finite() {
        let _ = write!(out, "{x}");
    } else {
        out.push_str("null"); // JSON has no Inf/NaN
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Build a `Json::Obj` from key/value pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// f64 array helper.
pub fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

/// Default nesting-depth ceiling for [`parse`]. Deep enough for every
/// document this repo emits (BENCH records nest ~6 levels), shallow
/// enough that a hostile `[[[[...` frame errors out instead of blowing
/// the stack through `value()` recursion.
pub const DEFAULT_MAX_DEPTH: usize = 64;

/// Default input-length ceiling for [`parse`], in bytes. Matches the
/// largest trusted document the repo reads (Perfetto traces run ~1 MiB);
/// the wire layer applies its own, tighter frame cap before parsing.
pub const DEFAULT_MAX_LEN: usize = 16 * 1024 * 1024;

/// Parse a JSON document with the default untrusted-input limits
/// ([`DEFAULT_MAX_DEPTH`], [`DEFAULT_MAX_LEN`]). Returns Err with byte
/// offset context on failure.
pub fn parse(src: &str) -> Result<Json, String> {
    parse_with_limits(src, DEFAULT_MAX_LEN, DEFAULT_MAX_DEPTH)
}

/// Parse with explicit resource limits: inputs longer than `max_len`
/// bytes or nesting deeper than `max_depth` containers return an error
/// before any unbounded recursion or allocation happens. The wire front
/// end (DESIGN.md S23) calls this with its frame caps.
pub fn parse_with_limits(
    src: &str,
    max_len: usize,
    max_depth: usize,
) -> Result<Json, String> {
    if src.len() > max_len {
        return Err(format!(
            "input too large: {} bytes > limit {max_len}",
            src.len()
        ));
    }
    let mut p = Parser {
        b: src.as_bytes(),
        i: 0,
        depth: 0,
        max_depth,
    };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("trailing garbage at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
    max_depth: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    /// Bump the container-nesting depth (entering `{` or `[`); errors
    /// once `max_depth` is exceeded so hostile inputs can't drive
    /// `value()` recursion arbitrarily deep.
    fn enter(&mut self) -> Result<(), String> {
        self.depth += 1;
        if self.depth > self.max_depth {
            return Err(format!(
                "nesting too deep at byte {}: {} levels > limit {}",
                self.i, self.depth, self.max_depth
            ));
        }
        Ok(())
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        self.enter()?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            map.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(map));
                }
                other => {
                    return Err(format!(
                        "expected ',' or '}}' at byte {}, found {:?}",
                        self.i,
                        other.map(|b| b as char)
                    ))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        self.enter()?;
        let mut xs = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            self.ws();
            xs.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(xs));
                }
                other => {
                    return Err(format!(
                        "expected ',' or ']' at byte {}, found {:?}",
                        self.i,
                        other.map(|b| b as char)
                    ))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| "bad \\u escape".to_string())?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            // Surrogate pairs unsupported (not needed here).
                            s.push(
                                char::from_u32(cp).unwrap_or('\u{fffd}'),
                            );
                            self.i += 4;
                        }
                        other => {
                            return Err(format!("bad escape {:?}", other))
                        }
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {s:?}: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1.5", "\"hi\""] {
            let v = parse(src).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x\ny")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn parse_real_manifest_shape() {
        let src = r#"{
          "spiking_mvm_b8_128x128": {
            "file": "spiking_mvm_b8_128x128.hlo.txt",
            "sha256": "abc",
            "args": [{"shape": [8, 128], "dtype": "float32"}],
            "alpha": 0.05,
            "t_bit_ns": 0.2
          }
        }"#;
        let v = parse(src).unwrap();
        let e = v.get("spiking_mvm_b8_128x128").unwrap();
        assert_eq!(e.get("alpha").unwrap().as_f64(), Some(0.05));
        let args = e.get("args").unwrap().as_arr().unwrap();
        assert_eq!(
            args[0].get("shape").unwrap().as_arr().unwrap()[1].as_f64(),
            Some(128.0)
        );
    }

    #[test]
    fn unicode_and_escapes_roundtrip() {
        let v = Json::Str("π \"q\" \\ \n\t".into());
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn depth_limit_rejects_hostile_nesting() {
        // One past the ceiling fails; at the ceiling succeeds.
        let deep = "[".repeat(DEFAULT_MAX_DEPTH + 1)
            + &"]".repeat(DEFAULT_MAX_DEPTH + 1);
        let err = parse(&deep).unwrap_err();
        assert!(err.contains("nesting too deep"), "got: {err}");
        let ok =
            "[".repeat(DEFAULT_MAX_DEPTH) + &"]".repeat(DEFAULT_MAX_DEPTH);
        assert!(parse(&ok).is_ok());
        // Objects count against the same budget as arrays.
        let objs = r#"{"a":"#.repeat(DEFAULT_MAX_DEPTH + 1)
            + "null"
            + &"}".repeat(DEFAULT_MAX_DEPTH + 1);
        assert!(parse(&objs).unwrap_err().contains("nesting too deep"));
        // Sibling containers do NOT accumulate: depth is nesting, not count.
        let wide = format!("[{}]", vec!["[]"; 1000].join(","));
        assert!(parse_with_limits(&wide, DEFAULT_MAX_LEN, 4).is_ok());
    }

    #[test]
    fn length_limit_rejects_oversized_input() {
        let big = format!("[{}]", vec!["0"; 100].join(","));
        let err = parse_with_limits(&big, 16, DEFAULT_MAX_DEPTH).unwrap_err();
        assert!(err.contains("input too large"), "got: {err}");
        // Same document passes under a sufficient limit.
        assert!(parse_with_limits(&big, big.len(), DEFAULT_MAX_DEPTH).is_ok());
    }

    #[test]
    fn pretty_is_reparseable() {
        let v = obj(vec![
            ("x", arr_f64(&[1.0, 2.5])),
            ("y", Json::Str("s".into())),
        ]);
        assert_eq!(parse(&v.to_pretty()).unwrap(), v);
    }
}

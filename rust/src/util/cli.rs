//! Tiny CLI argument parser (substrate — `clap` unavailable offline).
//!
//! Supports `prog <subcommand> [--flag] [--key value] [positional...]`,
//! typed accessors with defaults, and auto-generated usage text.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand plus flags/options/positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(key.to_string(), v);
                } else {
                    out.flags.push(key.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// Parse the process's own command line.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    panic!("--{name} expects an integer, got {v:?}")
                })
            })
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    panic!("--{name} expects an integer, got {v:?}")
                })
            })
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    panic!("--{name} expects a number, got {v:?}")
                })
            })
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_subcommand_and_options() {
        let a = parse("fig7a out.csv --points 500 --seed=9 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("fig7a"));
        assert_eq!(a.get_usize("points", 0), 500);
        assert_eq!(a.get_u64("seed", 0), 9);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["out.csv"]);
    }

    #[test]
    fn defaults_apply_when_missing() {
        let a = parse("serve");
        assert_eq!(a.get_f64("timeout-ms", 2.5), 2.5);
        assert_eq!(a.get_str("backend", "sim"), "sim");
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse("run --fast");
        assert!(a.flag("fast"));
        assert!(a.get("fast").is_none());
    }

    #[test]
    fn empty_args_ok() {
        let a = parse("");
        assert!(a.subcommand.is_none());
    }

    #[test]
    fn negative_number_as_value() {
        // "--bias -3" : -3 doesn't start with --, so it's the value.
        let a = parse("x --bias -3");
        assert_eq!(a.get_f64("bias", 0.0), -3.0);
    }
}

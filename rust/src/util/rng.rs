//! Deterministic PRNG (substrate — the `rand` crate is unavailable offline).
//!
//! xoshiro256++ seeded via SplitMix64, plus the distribution helpers the
//! simulator needs (uniform, normal, choice, shuffle). All simulation
//! randomness flows through this type so every experiment is reproducible
//! from a single `u64` seed recorded in EXPERIMENTS.md.

/// xoshiro256++ PRNG. Not cryptographic; period 2^256 − 1.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator; any seed (including 0) is fine.
    pub fn new(seed: u64) -> Self {
        let mut x = seed;
        Rng {
            s: [
                splitmix64(&mut x),
                splitmix64(&mut x),
                splitmix64(&mut x),
                splitmix64(&mut x),
            ],
        }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) via 128-bit multiply-shift.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Standard normal via Box–Muller (one value per call, simple & fine).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (std::f64::consts::TAU * u2).cos();
            }
        }
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Random element of a non-empty slice.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Split off an independent stream (for per-worker determinism).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_is_centered() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let m: f64 = (0..n).map(|_| r.uniform(-1.0, 1.0)).sum::<f64>() / n as f64;
        assert!(m.abs() < 0.02, "mean {m}");
    }

    #[test]
    fn below_covers_all_residues() {
        let mut r = Rng::new(9);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn int_range_inclusive_bounds() {
        let mut r = Rng::new(11);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..2000 {
            let v = r.int_range(-3, 3);
            assert!((-3..=3).contains(&v));
            lo_seen |= v == -3;
            hi_seen |= v == 3;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut a = Rng::new(21);
        let mut f = a.fork();
        let x: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let y: Vec<u64> = (0..8).map(|_| f.next_u64()).collect();
        assert_ne!(x, y);
    }
}

//! Persistent shared worker pool (DESIGN.md S17).
//!
//! Before S17 every parallel fan-out in the crate —
//! `macro_model::par_map_jobs` behind `mvm_parallel[_batch]`, and the
//! thread-per-layer `FabricPipeline` — paid a `thread::scope`/`spawn`
//! per call. This module replaces all of them with ONE long-lived,
//! channel-fed pool of `available_parallelism` workers, started lazily
//! on first use and shared by every subsystem (tiles, fabric, server
//! examples, benches).
//!
//! Two entry points:
//!
//! * [`scope_map`] — run `jobs` through `f` on the pool and return the
//!   results **in job order** (deterministic, like the scoped-thread
//!   fan-out it replaces). Jobs may borrow non-`'static` data: the call
//!   does not return until every job has finished, and the submitted
//!   tickets are self-scheduling claims that can never touch a job
//!   after the scope's counter says it is spent. The *caller claims
//!   jobs too* — even with every worker busy (or blocked inside a
//!   nested `scope_map`), the calling thread drains its own scope, so
//!   nesting cannot deadlock the pool.
//! * [`spawn`] — fire-and-forget a `'static` task (the fabric dataflow
//!   executor schedules its stage turns this way).
//!
//! Panic policy: a panicking job is caught on the worker, carried back,
//! and re-raised on the calling thread (matching `thread::scope`);
//! workers themselves never die, because they are shared state.
//!
//! Observability (DESIGN.md S20): every enqueue bumps a channel-depth
//! counter whose high-water mark [`queue_high_water`] exposes, each
//! claimed job runs under an `obs` `PoolExec` span, and — when the
//! `PoolWait` kind is enabled — tasks carry their enqueue timestamp so
//! the dequeuing worker records the queue-wait interval. All of it is
//! behind `obs::enabled` checks (one relaxed load when tracing is off).

use std::cell::UnsafeCell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, OnceLock};
use std::time::Instant;

use crate::obs::{self, TraceKind};

type Task = Box<dyn FnOnce() + Send + 'static>;

struct Pool {
    tx: mpsc::Sender<Task>,
    workers: usize,
}

static POOL: OnceLock<Pool> = OnceLock::new();

/// Tasks currently sitting in the pool channel (sent, not yet picked
/// up by a worker).
static QUEUE_DEPTH: AtomicUsize = AtomicUsize::new(0);
/// High-water mark of `QUEUE_DEPTH` since process start.
static QUEUE_HW: AtomicUsize = AtomicUsize::new(0);
/// Detached tasks whose panic was caught on a pool worker (S21
/// supervision satellite): the worker thread survives — panics here
/// must never shrink the shared pool — and this counter makes the
/// event observable instead of a lone stderr line.
static POOL_PANICS: AtomicUsize = AtomicUsize::new(0);

/// Deepest the pool channel has ever been (S20 gauge; feed it to
/// `Metrics::record_pool_queue_depth`).
pub fn queue_high_water() -> usize {
    QUEUE_HW.load(Ordering::Relaxed)
}

/// Detached `spawn` tasks that panicked since process start (S21
/// gauge; feed it to `Metrics::record_pool_panics`).
pub fn panics() -> u64 {
    POOL_PANICS.load(Ordering::Relaxed) as u64
}

/// The one enqueue path: counts depth + high-water, samples the
/// queue-depth counter kind, then sends.
fn send_task(p: &Pool, t: Task) {
    let depth = QUEUE_DEPTH.fetch_add(1, Ordering::Relaxed) + 1;
    QUEUE_HW.fetch_max(depth, Ordering::Relaxed);
    obs::counter(TraceKind::QueueDepth, 0, depth as f64);
    p.tx.send(t).expect("pool alive");
}

fn pool() -> &'static Pool {
    POOL.get_or_init(|| {
        let (tx, rx) = mpsc::channel::<Task>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(4);
        for i in 0..workers {
            let rx = rx.clone();
            std::thread::Builder::new()
                .name(format!("spikemram-pool-{i}"))
                .spawn(move || loop {
                    // Take one task with the lock *released* before
                    // running it; a panicking task must not poison the
                    // shared receiver.
                    let task = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match task {
                        Ok(t) => {
                            QUEUE_DEPTH.fetch_sub(1, Ordering::Relaxed);
                            if catch_unwind(AssertUnwindSafe(t)).is_err() {
                                // Scoped jobs catch their own panics and
                                // re-raise on the caller; anything that
                                // reaches here is a detached task's bug.
                                // Count it (S21 pool_panics gauge) and
                                // keep this worker alive — a panicking
                                // spawn must never shrink the pool.
                                POOL_PANICS
                                    .fetch_add(1, Ordering::Relaxed);
                                eprintln!(
                                    "spikemram pool: detached task panicked"
                                );
                            }
                        }
                        Err(_) => return, // sender gone: process exit
                    }
                })
                .expect("spawn pool worker");
        }
        Pool { tx, workers }
    })
}

/// Number of worker threads in the shared pool.
pub fn workers() -> usize {
    pool().workers
}

/// Fire-and-forget a task onto the shared pool.
pub fn spawn(task: impl FnOnce() + Send + 'static) {
    let p = pool();
    if obs::enabled(TraceKind::PoolWait) {
        // Carry the enqueue time so the dequeuing worker can record how
        // long the task sat in the channel (stage 1 = detached spawn).
        let queued = Instant::now();
        send_task(
            p,
            Box::new(move || {
                obs::wait_since(TraceKind::PoolWait, 1, queued);
                task()
            }),
        );
    } else {
        send_task(p, Box::new(task));
    }
}

/// Shared state of one `scope_map` call. Job `i` is claimed exactly
/// once (a `fetch_add` ticket), so the `UnsafeCell` slots are accessed
/// exclusively; `done` is incremented *after* the result write with
/// `Release`, and the caller returns only after acquiring `done == n` —
/// no borrow escapes the call.
struct Scope<T, R, F> {
    /// The job closure; shared (`&F`) while any claim index < n is in
    /// flight, then taken back by the caller before `scope_map`
    /// returns, so a late ticket's Arc never runs non-trivial drop glue
    /// (a closure's captures may own Drop types borrowing caller state).
    f: UnsafeCell<Option<F>>,
    jobs: Vec<UnsafeCell<Option<T>>>,
    results: Vec<UnsafeCell<Option<R>>>,
    claimed: AtomicUsize,
    done: AtomicUsize,
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
    /// Blocks the caller until `done == n` (no busy spin: in-flight
    /// jobs can be whole batched MVMs).
    done_lock: Mutex<()>,
    done_cv: Condvar,
}

// SAFETY: job/result slots are accessed only by the unique claimant of
// their index (`claimed` ticket) and by the caller after it has
// acquired `done == n`; `f` is only *read* (`&F`, hence F: Sync) while
// claims < n are possible, and the caller takes it back after
// `done == n`, when no ticket can touch it again (claims only grow). A
// late ticket's Arc may therefore drop the Scope on a worker thread
// after `scope_map` returned, but by then every cell is `None` — no
// drop glue of T, R, or F runs outside the caller's lifetime.
unsafe impl<T: Send, R: Send, F: Sync> Sync for Scope<T, R, F> {}
unsafe impl<T: Send, R: Send, F: Sync> Send for Scope<T, R, F> {}

/// Claim and run the next unclaimed job of `s`; false when none are
/// left. Tickets that arrive after the scope is drained claim an index
/// `>= n` and touch nothing.
fn run_one<T, R, F: Fn(T) -> R>(s: &Scope<T, R, F>) -> bool {
    let i = s.claimed.fetch_add(1, Ordering::Relaxed);
    if i >= s.jobs.len() {
        return false;
    }
    let job = unsafe { (*s.jobs[i].get()).take() }.expect("claimed once");
    // SAFETY: `f` is Some for every claim index < n (the caller only
    // takes it after done == n, which requires this call to have
    // finished); concurrent claimants share it immutably.
    let f = unsafe { (*s.f.get()).as_ref() }.expect("f alive while claiming");
    let outcome = {
        // Span covers exactly the job body (payload: job index, scope
        // size); recorded on Drop, even when the job panics.
        let mut sp = obs::Span::begin(TraceKind::PoolExec, 0);
        sp.note(i as f64, s.jobs.len() as f64);
        catch_unwind(AssertUnwindSafe(|| f(job)))
    };
    match outcome {
        Ok(r) => unsafe { *s.results[i].get() = Some(r) },
        Err(p) => *s.panic.lock().unwrap() = Some(p),
    }
    if s.done.fetch_add(1, Ordering::Release) + 1 == s.jobs.len() {
        // Last job: wake the (possibly waiting) caller. Taking the lock
        // orders this notify after the caller's condition check.
        let _g = s.done_lock.lock().unwrap();
        s.done_cv.notify_all();
    }
    true
}

/// Run every job through `f` on the shared pool; results come back in
/// job order, bit-identical to a serial loop (each job is independent
/// and deterministic — parallelism only changes wall-clock). Single
/// jobs run inline without touching the pool.
pub fn scope_map<T: Send, R: Send, F: Fn(T) -> R + Sync>(
    jobs: Vec<T>,
    f: F,
) -> Vec<R> {
    let n = jobs.len();
    if n <= 1 {
        return jobs.into_iter().map(f).collect();
    }
    let p = pool();
    let scope = Arc::new(Scope {
        f: UnsafeCell::new(Some(f)),
        jobs: jobs.into_iter().map(|j| UnsafeCell::new(Some(j))).collect(),
        results: (0..n).map(|_| UnsafeCell::new(None)).collect(),
        claimed: AtomicUsize::new(0),
        done: AtomicUsize::new(0),
        panic: Mutex::new(None),
        done_lock: Mutex::new(()),
        done_cv: Condvar::new(),
    });
    // One self-scheduling ticket per job the caller cannot take itself,
    // capped at the worker count (each ticket loops until the scope is
    // dry, so more would be pure queue traffic).
    // `Instant` is Copy + 'static, so carrying the enqueue time through
    // the transmute below changes nothing about the borrow argument.
    let queued = obs::enabled(TraceKind::PoolWait).then(Instant::now);
    for _ in 0..(n - 1).min(p.workers) {
        let s = scope.clone();
        let ticket: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
            if let Some(q) = queued {
                obs::wait_since(TraceKind::PoolWait, 0, q);
            }
            while run_one(&s) {}
        });
        // SAFETY: the ticket borrows non-'static job/result/closure
        // data only through `Scope`, whose slots it touches only for
        // claim indices < n. Every such access happens before the
        // matching `done` increment, and this function returns only
        // after `done == n` — so no borrow is used after `scope_map`
        // returns. Late-arriving tickets hold the Arc (alive memory)
        // but claim an index >= n and exit immediately.
        let ticket: Task = unsafe {
            std::mem::transmute::<
                Box<dyn FnOnce() + Send + '_>,
                Box<dyn FnOnce() + Send + 'static>,
            >(ticket)
        };
        send_task(p, ticket);
    }
    // The caller claims jobs too: guaranteed progress even if every
    // worker is busy or parked inside another scope.
    while run_one(&scope) {}
    // Block (no spin) until the in-flight remainder lands on workers.
    {
        let mut g = scope.done_lock.lock().unwrap();
        while scope.done.load(Ordering::Acquire) < n {
            g = scope.done_cv.wait(g).unwrap();
        }
    }
    // Reclaim the closure and all results on THIS thread, before any
    // borrow expires — a late ticket's Arc then drops only empty cells.
    let f = unsafe { (*scope.f.get()).take() };
    let panic = scope.panic.lock().unwrap().take();
    let results: Vec<Option<R>> = scope
        .results
        .iter()
        .map(|c| unsafe { (*c.get()).take() })
        .collect();
    if let Some(p) = panic {
        drop(results); // drop partial results before unwinding
        drop(f);
        resume_unwind(p);
    }
    drop(f);
    results
        .into_iter()
        .map(|r| r.expect("every job produced a result"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_returns_results_in_job_order() {
        let jobs: Vec<usize> = (0..64).collect();
        let got = scope_map(jobs, |i| i * i);
        assert_eq!(got, (0..64).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn jobs_may_borrow_and_mutate_caller_state() {
        // The mvm_parallel shape: each job owns &mut into caller data.
        let mut cells = vec![0u64; 16];
        let jobs: Vec<(&mut u64, u64)> = cells
            .iter_mut()
            .zip(1..)
            .map(|(c, i)| (c, i))
            .collect();
        let returned = scope_map(jobs, |(c, i)| {
            *c = i * 10;
            i
        });
        assert_eq!(returned, (1..=16).collect::<Vec<u64>>());
        assert_eq!(cells[0], 10);
        assert_eq!(cells[15], 160);
    }

    #[test]
    fn nested_scopes_make_progress() {
        // Saturate the pool with outer jobs that each fan out again:
        // the caller-claims rule keeps everything live.
        let outer: Vec<u64> = (0..(workers() * 4) as u64).collect();
        let got = scope_map(outer, |i| {
            let inner: Vec<u64> = (0..8).map(|j| i * 8 + j).collect();
            scope_map(inner, |v| v * 2).into_iter().sum::<u64>()
        });
        for (i, v) in got.iter().enumerate() {
            let i = i as u64;
            let want: u64 = (0..8).map(|j| (i * 8 + j) * 2).sum();
            assert_eq!(*v, want);
        }
    }

    #[test]
    fn concurrent_scopes_from_many_threads() {
        std::thread::scope(|s| {
            for t in 0..8u64 {
                s.spawn(move || {
                    let jobs: Vec<u64> = (0..32).map(|i| t * 100 + i).collect();
                    let got = scope_map(jobs.clone(), |v| v + 1);
                    assert_eq!(
                        got,
                        jobs.iter().map(|v| v + 1).collect::<Vec<_>>()
                    );
                });
            }
        });
    }

    #[test]
    fn job_panic_propagates_to_caller() {
        let r = catch_unwind(AssertUnwindSafe(|| {
            scope_map((0..8).collect::<Vec<i32>>(), |i| {
                assert!(i != 5, "job five exploded");
                i
            })
        }));
        assert!(r.is_err(), "panic must reach the caller");
        // The pool survives: a fresh scope still works.
        assert_eq!(scope_map(vec![1, 2, 3], |i| i * 2), vec![2, 4, 6]);
    }

    #[test]
    fn queue_high_water_rises_after_fanout() {
        // A 64-job scope sends min(63, workers) >= 1 tickets through
        // send_task, so the high-water mark must be nonzero afterwards.
        let _ = scope_map((0..64usize).collect::<Vec<_>>(), |i| i);
        assert!(queue_high_water() >= 1);
    }

    #[test]
    fn detached_spawn_runs() {
        let (tx, rx) = mpsc::channel();
        spawn(move || tx.send(41 + 1).unwrap());
        assert_eq!(
            rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap(),
            42
        );
    }

    #[test]
    fn detached_panic_is_counted_and_the_pool_survives() {
        // S21 regression: a panicking detached task must neither kill
        // its worker nor vanish silently — the pool keeps serving and
        // the pool_panics gauge moves.
        let before = panics();
        let (ptx, prx) = mpsc::channel();
        spawn(move || {
            ptx.send(()).unwrap();
            panic!("detached task exploded (intentional)");
        });
        prx.recv_timeout(std::time::Duration::from_secs(10)).unwrap();
        // Wait for the catch_unwind branch to account the panic.
        let deadline = Instant::now() + std::time::Duration::from_secs(10);
        while panics() <= before {
            assert!(Instant::now() < deadline, "pool panic never counted");
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        // Every worker still serves: a full-width scope completes.
        let n = workers().max(2);
        let got = scope_map((0..n * 4).collect::<Vec<_>>(), |i| i + 1);
        assert_eq!(got, (1..=n * 4).collect::<Vec<_>>());
        let (tx, rx) = mpsc::channel();
        spawn(move || tx.send(7).unwrap());
        assert_eq!(
            rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap(),
            7
        );
    }
}

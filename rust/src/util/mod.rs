//! Offline-substrate utilities (DESIGN.md S0): PRNG (`rand` replacement),
//! JSON (`serde_json` replacement), CLI parsing (`clap` replacement), and
//! the statistics helpers shared by the repro harness and benches.

pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;

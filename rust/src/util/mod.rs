//! Offline-substrate utilities (DESIGN.md S0): PRNG (`rand` replacement),
//! JSON (`serde_json` replacement), CLI parsing (`clap` replacement), the
//! statistics helpers shared by the repro harness and benches, and the
//! persistent shared worker pool (DESIGN.md S17, `rayon` replacement).

pub mod cli;
pub mod json;
pub mod pool;
pub mod rng;
pub mod stats;

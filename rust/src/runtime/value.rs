//! Backend-agnostic argument/output values (DESIGN.md S12): the typed
//! tensor interchange between the coordinator and whichever runtime
//! backend executes the artifacts — PJRT (`pjrt` feature) or the pure-Rust
//! interpreter (default).

/// Argument/output values exchanged with an executable.
#[derive(Debug, Clone)]
pub enum Value {
    F32 { data: Vec<f32>, shape: Vec<usize> },
    I32 { data: Vec<i32>, shape: Vec<usize> },
}

impl Value {
    pub fn f32(data: Vec<f32>, shape: &[usize]) -> Value {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        Value::F32 {
            data,
            shape: shape.to_vec(),
        }
    }

    pub fn i32(data: Vec<i32>, shape: &[usize]) -> Value {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        Value::I32 {
            data,
            shape: shape.to_vec(),
        }
    }

    /// The value's shape (row-major dims).
    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32 { shape, .. } | Value::I32 { shape, .. } => shape,
        }
    }

    pub fn as_f32(&self) -> &[f32] {
        match self {
            Value::F32 { data, .. } => data,
            _ => panic!("expected f32 value"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match self {
            Value::I32 { data, .. } => data,
            _ => panic!("expected i32 value"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_shape_product_checked() {
        let v = Value::f32(vec![0.0; 6], &[2, 3]);
        assert_eq!(v.as_f32().len(), 6);
        assert_eq!(v.shape(), &[2, 3]);
    }

    #[test]
    #[should_panic]
    fn value_shape_mismatch_panics() {
        let _ = Value::f32(vec![0.0; 5], &[2, 3]);
    }

    #[test]
    #[should_panic(expected = "expected f32")]
    fn as_f32_on_i32_panics() {
        let v = Value::i32(vec![1, 2], &[2]);
        let _ = v.as_f32();
    }

    #[test]
    #[should_panic(expected = "expected i32")]
    fn as_i32_on_f32_panics() {
        let v = Value::f32(vec![1.0], &[1]);
        let _ = v.as_i32();
    }
}

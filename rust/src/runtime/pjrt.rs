//! PJRT runtime (DESIGN.md S12): load AOT-compiled HLO **text** artifacts
//! (emitted once by `python/compile/aot.py`) and execute them on the CPU
//! PJRT client via the `xla` crate. This is the fast functional backend of
//! the coordinator; python never runs here.
//!
//! Interchange is HLO text, not serialized protos: jax ≥ 0.5 emits 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and DESIGN.md §3).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// A compiled HLO executable plus its argument contract.
pub struct Executable {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

/// Argument/output values exchanged with an executable.
#[derive(Debug, Clone)]
pub enum Value {
    F32 { data: Vec<f32>, shape: Vec<usize> },
    I32 { data: Vec<i32>, shape: Vec<usize> },
}

impl Value {
    pub fn f32(data: Vec<f32>, shape: &[usize]) -> Value {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        Value::F32 {
            data,
            shape: shape.to_vec(),
        }
    }

    pub fn i32(data: Vec<i32>, shape: &[usize]) -> Value {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        Value::I32 {
            data,
            shape: shape.to_vec(),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            Value::F32 { data, shape } => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
            Value::I32 { data, shape } => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
        };
        Ok(lit)
    }

    pub fn as_f32(&self) -> &[f32] {
        match self {
            Value::F32 { data, .. } => data,
            _ => panic!("expected f32 value"),
        }
    }
}

impl Executable {
    /// Execute with positional args; returns the flattened f32 outputs of
    /// the result tuple (aot.py lowers every entry with return_tuple=True).
    pub fn run_f32(&self, args: &[Value]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> = args
            .iter()
            .map(|v| v.to_literal())
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        let tuple = result.to_tuple()?;
        tuple
            .into_iter()
            .map(|lit| {
                lit.to_vec::<f32>()
                    .with_context(|| format!("{}: non-f32 output", self.name))
            })
            .collect()
    }
}

/// PJRT CPU runtime owning the client and a cache of compiled artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    cache: HashMap<String, std::sync::Arc<Executable>>,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at an artifacts directory.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            artifacts_dir: artifacts_dir.as_ref().to_path_buf(),
            cache: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile `<name>.hlo.txt` (cached after the first call).
    pub fn load(&mut self, name: &str) -> Result<std::sync::Arc<Executable>> {
        if let Some(e) = self.cache.get(name) {
            return Ok(e.clone());
        }
        let path = self.artifacts_dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path utf-8")?,
        )
        .with_context(|| format!("loading {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        let e = std::sync::Arc::new(Executable {
            name: name.to_string(),
            exe,
        });
        self.cache.insert(name.to_string(), e.clone());
        Ok(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Execution tests live in rust/tests/pjrt_roundtrip.rs (they need the
    // artifacts). Here only the Value plumbing, which is pure.

    #[test]
    fn value_shape_product_checked() {
        let v = Value::f32(vec![0.0; 6], &[2, 3]);
        assert_eq!(v.as_f32().len(), 6);
    }

    #[test]
    #[should_panic]
    fn value_shape_mismatch_panics() {
        let _ = Value::f32(vec![0.0; 5], &[2, 3]);
    }

    #[test]
    #[should_panic(expected = "expected f32")]
    fn as_f32_on_i32_panics() {
        let v = Value::i32(vec![1, 2], &[2]);
        let _ = v.as_f32();
    }
}

//! PJRT runtime (DESIGN.md S12): load AOT-compiled HLO **text** artifacts
//! (emitted once by `python/compile/aot.py`) and execute them on the CPU
//! PJRT client via the `xla` crate. This is the fast functional backend of
//! the coordinator; python never runs here.
//!
//! Compiled only with the `pjrt` cargo feature (the `xla` dependency needs
//! a local `xla_extension` install — see README.md). The default build
//! serves the same API through [`crate::runtime::interp`].
//!
//! Interchange is HLO text, not serialized protos: jax ≥ 0.5 emits 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see DESIGN.md §3).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::value::Value;

/// A compiled HLO executable plus its argument contract.
pub struct Executable {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

fn to_literal(value: &Value) -> Result<xla::Literal> {
    let lit = match value {
        Value::F32 { data, shape } => {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            xla::Literal::vec1(data).reshape(&dims)?
        }
        Value::I32 { data, shape } => {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            xla::Literal::vec1(data).reshape(&dims)?
        }
    };
    Ok(lit)
}

impl Executable {
    /// Execute with positional args; returns the flattened f32 outputs of
    /// the result tuple (aot.py lowers every entry with return_tuple=True).
    pub fn run_f32(&self, args: &[Value]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> =
            args.iter().map(to_literal).collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        let tuple = result.to_tuple()?;
        tuple
            .into_iter()
            .map(|lit| {
                lit.to_vec::<f32>()
                    .with_context(|| format!("{}: non-f32 output", self.name))
            })
            .collect()
    }
}

/// PJRT CPU runtime owning the client and a cache of compiled artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    cache: HashMap<String, std::sync::Arc<Executable>>,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at an artifacts directory.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            artifacts_dir: artifacts_dir.as_ref().to_path_buf(),
            cache: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile `<name>.hlo.txt` (cached after the first call).
    pub fn load(&mut self, name: &str) -> Result<std::sync::Arc<Executable>> {
        if let Some(e) = self.cache.get(name) {
            return Ok(e.clone());
        }
        let path = self.artifacts_dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path utf-8")?,
        )
        .with_context(|| format!("loading {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        let e = std::sync::Arc::new(Executable {
            name: name.to_string(),
            exe,
        });
        self.cache.insert(name.to_string(), e.clone());
        Ok(e)
    }
}

// Execution tests live in rust/tests/pjrt_roundtrip-style integration
// tests (they need the artifacts); the pure `Value` plumbing is tested in
// `runtime::value`.

//! PJRT runtime bridge (DESIGN.md S12): `artifacts/*.hlo.txt` →
//! compile-once → execute from the L3 hot path.

pub mod artifacts;
pub mod pjrt;

pub use artifacts::{ArtifactEntry, Manifest};
pub use pjrt::{Executable, Runtime, Value};

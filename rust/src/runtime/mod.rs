//! Runtime bridge (DESIGN.md S12): `artifacts/*.hlo.txt` → compile-once →
//! execute from the L3 hot path.
//!
//! Two interchangeable backends behind one API surface
//! (`Runtime::new` → `load` → `Executable::run_f32`, with [`Value`] as the
//! tensor interchange and [`Manifest`] as the shape/dtype contract):
//!
//! * **`pjrt`** (cargo feature `pjrt`) — compiles the AOT HLO text via the
//!   `xla` crate's CPU PJRT client; requires an `xla_extension` install
//!   (see README.md).
//! * **[`interp`]** (default) — a pure-Rust interpreter of the same
//!   artifact contracts, so the default build is hermetic: no network, no
//!   native libraries, and `--backend pjrt` code paths still run.

pub mod artifacts;
pub mod interp;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod value;

pub use artifacts::{ArtifactEntry, Manifest};
pub use value::Value;

#[cfg(feature = "pjrt")]
pub use pjrt::{Executable, Runtime};

#[cfg(not(feature = "pjrt"))]
pub use interp::{Executable, Runtime};

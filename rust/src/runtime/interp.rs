//! Pure-Rust artifact interpreter (DESIGN.md S12) — the default runtime
//! backend when the crate is built without the `pjrt` feature.
//!
//! Presents the exact same API as `runtime::pjrt` (`Runtime` → `load` →
//! `Executable::run_f32`) but instead of compiling HLO text it
//! *interprets the artifact's functional contract*: every AOT entry in
//! `python/compile/aot.py` is a closed-form map (Eq. 2 and the Euler
//! transient), so the interpreter re-evaluates the same math in f32 —
//! bit-close to the XLA execution — with zero native dependencies. The
//! `artifacts/` directory is optional: when `manifest.json` exists its
//! `alpha`/`t_bit_ns` calibration is honored and argument shapes are taken
//! from the contract; otherwise shapes are parsed from the entry name and
//! the Table I defaults apply (DESIGN.md §1, §6).
//!
//! Supported entries: `spiking_mvm_b{B}_{K}x{N}`, `macro_fwd_b{B}`, and
//! `fig7b_transient`. The MLP forwards (`mlp_fwd_*`) involve per-layer
//! requantization state and are only served by the real PJRT backend —
//! loading them here returns a descriptive error.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::artifacts::Manifest;
use super::value::Value;

/// Device-true conductance LUT in f32 (matches `LEVELS_DEVICE_TRUE` in
/// `python/compile/kernels/spiking_mvm.py`).
const LEVELS: [f32; 4] = [1.0 / 6.0, 1.0 / 5.0, 1.0 / 4.0, 1.0 / 3.0];

/// Which closed-form program an artifact name denotes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Program {
    /// `spiking_mvm_b{B}_{K}x{N}`: (t_in f32[B,K], codes i32[K,N]) →
    /// (t_out f32[B,N] = α · T_in·G).
    SpikingMvm {
        batch: usize,
        rows: usize,
        cols: usize,
    },
    /// `macro_fwd_b{B}`: (x i32[B,K], codes i32[K,N]) → (t_out, y) with
    /// y = t_out / (α·T_bit).
    MacroFwd {
        batch: usize,
        rows: usize,
        cols: usize,
    },
    /// `fig7b_transient`: (t_in f32[K], g f32[K]) → (v_mirror[n], v_droop[n])
    /// Euler traces, dt = 0.01 ns, n = 1000.
    Fig7bTransient { rows: usize, n_steps: usize },
}

/// A "compiled" artifact: its program plus lowering-time calibration.
pub struct Executable {
    pub name: String,
    program: Program,
    alpha: f64,
    t_bit_ns: f64,
}

fn parse_name(name: &str) -> Option<Program> {
    if let Some(rest) = name.strip_prefix("spiking_mvm_b") {
        let (b, dims) = rest.split_once('_')?;
        let (k, n) = dims.split_once('x')?;
        return Some(Program::SpikingMvm {
            batch: b.parse().ok()?,
            rows: k.parse().ok()?,
            cols: n.parse().ok()?,
        });
    }
    if let Some(b) = name.strip_prefix("macro_fwd_b") {
        return Some(Program::MacroFwd {
            batch: b.parse().ok()?,
            rows: 128,
            cols: 128,
        });
    }
    if name == "fig7b_transient" {
        return Some(Program::Fig7bTransient {
            rows: 128,
            n_steps: 1000,
        });
    }
    None
}

/// Override the name-derived geometry with the manifest's argument shapes
/// (the authoritative contract when artifacts exist): arg 0 is `[B, K]`
/// (or `[K]` for the transient), arg 1 is `[K, N]`.
fn reshape_from_manifest(
    program: Program,
    args: &[super::artifacts::ArgSpec],
) -> Program {
    match (program, args) {
        (Program::SpikingMvm { .. }, [a0, a1])
            if a0.shape.len() == 2 && a1.shape.len() == 2 =>
        {
            Program::SpikingMvm {
                batch: a0.shape[0],
                rows: a0.shape[1],
                cols: a1.shape[1],
            }
        }
        (Program::MacroFwd { .. }, [a0, a1])
            if a0.shape.len() == 2 && a1.shape.len() == 2 =>
        {
            Program::MacroFwd {
                batch: a0.shape[0],
                rows: a0.shape[1],
                cols: a1.shape[1],
            }
        }
        (Program::Fig7bTransient { n_steps, .. }, [a0, _])
            if a0.shape.len() == 1 =>
        {
            Program::Fig7bTransient {
                rows: a0.shape[0],
                n_steps,
            }
        }
        _ => program,
    }
}

fn expand_codes_f32(codes: &[i32], rows: usize, cols: usize) -> Result<Vec<f32>> {
    let mut g = Vec::with_capacity(rows * cols);
    for &c in codes {
        if !(0..4).contains(&c) {
            bail!("weight code {c} out of range 0..=3");
        }
        g.push(LEVELS[c as usize]);
    }
    Ok(g)
}

/// t_out[b,n] = alpha · Σ_k t_in[b,k]·G[k,n], f32 accumulation like XLA.
fn spiking_mvm_f32(
    t_in: &[f32],
    g: &[f32],
    batch: usize,
    rows: usize,
    cols: usize,
    alpha: f32,
) -> Vec<f32> {
    let mut out = vec![0.0f32; batch * cols];
    for b in 0..batch {
        let x = &t_in[b * rows..(b + 1) * rows];
        let o = &mut out[b * cols..(b + 1) * cols];
        for (k, &xv) in x.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let grow = &g[k * cols..(k + 1) * cols];
            for (ov, &gv) in o.iter_mut().zip(grow) {
                *ov += xv * gv;
            }
        }
        for ov in o.iter_mut() {
            *ov *= alpha;
        }
    }
    out
}

impl Executable {
    fn check_shape(&self, got: &Value, want: &[usize], arg: usize) -> Result<()> {
        if got.shape() != want {
            bail!(
                "{}: arg {arg} has shape {:?}, expected {:?}",
                self.name,
                got.shape(),
                want
            );
        }
        Ok(())
    }

    /// Execute with positional args; returns the flattened f32 outputs of
    /// the result tuple — the same contract as the PJRT backend.
    pub fn run_f32(&self, args: &[Value]) -> Result<Vec<Vec<f32>>> {
        match self.program {
            Program::SpikingMvm { batch, rows, cols } => {
                if args.len() != 2 {
                    bail!("{}: expected 2 args, got {}", self.name, args.len());
                }
                self.check_shape(&args[0], &[batch, rows], 0)?;
                self.check_shape(&args[1], &[rows, cols], 1)?;
                let g = expand_codes_f32(args[1].as_i32(), rows, cols)?;
                let t_out = spiking_mvm_f32(
                    args[0].as_f32(),
                    &g,
                    batch,
                    rows,
                    cols,
                    self.alpha as f32,
                );
                Ok(vec![t_out])
            }
            Program::MacroFwd { batch, rows, cols } => {
                if args.len() != 2 {
                    bail!("{}: expected 2 args, got {}", self.name, args.len());
                }
                self.check_shape(&args[0], &[batch, rows], 0)?;
                self.check_shape(&args[1], &[rows, cols], 1)?;
                let t_bit = self.t_bit_ns as f32;
                let t_in: Vec<f32> = args[0]
                    .as_i32()
                    .iter()
                    .map(|&x| x as f32 * t_bit)
                    .collect();
                let g = expand_codes_f32(args[1].as_i32(), rows, cols)?;
                let t_out = spiking_mvm_f32(
                    &t_in,
                    &g,
                    batch,
                    rows,
                    cols,
                    self.alpha as f32,
                );
                let scale = 1.0f32 / (self.alpha as f32 * t_bit);
                let y: Vec<f32> = t_out.iter().map(|&t| t * scale).collect();
                Ok(vec![t_out, y])
            }
            Program::Fig7bTransient { rows, n_steps } => {
                if args.len() != 2 {
                    bail!("{}: expected 2 args, got {}", self.name, args.len());
                }
                self.check_shape(&args[0], &[rows], 0)?;
                self.check_shape(&args[1], &[rows], 1)?;
                let t_in = args[0].as_f32();
                let g = args[1].as_f32();
                // Constants of python/compile/model.py::fig7b_transient.
                let (dt, v_read, c_ff, k_mirror) = (0.01f32, 0.1f32, 200.0f32, 1.0f32);
                let mut vm = 0.0f32;
                let mut vd = 0.0f32;
                let mut out_m = Vec::with_capacity(n_steps);
                let mut out_d = Vec::with_capacity(n_steps);
                for s in 0..n_steps {
                    let t = s as f32 * dt;
                    let g_on: f32 = t_in
                        .iter()
                        .zip(g)
                        .filter(|&(&ti, _)| t < ti)
                        .map(|(_, &gv)| gv)
                        .sum();
                    vm += k_mirror * v_read * g_on * dt / c_ff;
                    vd += g_on * (v_read - vd) * dt / c_ff;
                    out_m.push(vm);
                    out_d.push(vd);
                }
                Ok(vec![out_m, out_d])
            }
        }
    }
}

/// Interpreter runtime mirroring the PJRT backend's `Runtime` API.
pub struct Runtime {
    artifacts_dir: PathBuf,
    manifest: Option<Manifest>,
    cache: HashMap<String, Arc<Executable>>,
}

impl Runtime {
    /// Root the interpreter at an artifacts directory. The directory (and
    /// its `manifest.json`) may be absent — entries are then derived from
    /// their names with Table I default calibration.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir).ok();
        Ok(Runtime {
            artifacts_dir: dir,
            manifest,
            cache: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        "interp (pure Rust; build with --features pjrt for PJRT)".to_string()
    }

    /// Resolve `name` to an interpretable program (cached).
    pub fn load(&mut self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.get(name) {
            return Ok(e.clone());
        }
        let program = parse_name(name).with_context(|| {
            format!(
                "artifact {name:?} is not interpretable by the pure-Rust \
                 backend (mlp_fwd_* and custom entries need --features pjrt); \
                 artifacts dir: {}",
                self.artifacts_dir.display()
            )
        })?;
        // Calibration and shapes from the manifest when available; the
        // name-derived contract with Table I defaults otherwise.
        let cfg = crate::config::MacroConfig::default();
        let entry = self.manifest.as_ref().and_then(|m| m.get(name));
        let program = match entry {
            Some(e) => reshape_from_manifest(program, &e.args),
            None => program,
        };
        let (alpha, t_bit_ns) = match entry {
            Some(e) => (
                if e.alpha > 0.0 { e.alpha } else { cfg.alpha() },
                e.t_bit_ns,
            ),
            None => (cfg.alpha(), cfg.t_bit_ns),
        };
        let e = Arc::new(Executable {
            name: name.to_string(),
            program,
            alpha,
            t_bit_ns,
        });
        self.cache.insert(name.to_string(), e.clone());
        Ok(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MacroConfig;
    use crate::macro_model::CimMacro;
    use crate::util::rng::Rng;

    fn load(name: &str) -> Arc<Executable> {
        // Point at a directory that does not exist: name-derived contract.
        let mut rt = Runtime::new("/nonexistent/artifacts").unwrap();
        rt.load(name).unwrap()
    }

    #[test]
    fn parses_entry_names() {
        assert_eq!(
            parse_name("spiking_mvm_b8_128x128"),
            Some(Program::SpikingMvm {
                batch: 8,
                rows: 128,
                cols: 128
            })
        );
        assert_eq!(
            parse_name("spiking_mvm_b32_128x128"),
            Some(Program::SpikingMvm {
                batch: 32,
                rows: 128,
                cols: 128
            })
        );
        assert_eq!(
            parse_name("macro_fwd_b8"),
            Some(Program::MacroFwd {
                batch: 8,
                rows: 128,
                cols: 128
            })
        );
        assert!(parse_name("fig7b_transient").is_some());
        assert!(parse_name("mlp_fwd_b16").is_none());
        assert!(parse_name("spiking_mvm_bx_128x128").is_none());
    }

    #[test]
    fn unsupported_entry_gives_descriptive_error() {
        let mut rt = Runtime::new("/nonexistent/artifacts").unwrap();
        let err = rt.load("mlp_fwd_b16").unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("mlp_fwd"), "{msg}");
    }

    #[test]
    fn spiking_mvm_matches_behavioral_sim() {
        // The interp backend and the event-driven simulator implement the
        // same Eq. 2 through different code paths — cross-check (the same
        // invariant integration_stack.rs asserts for the PJRT backend).
        let cfg = MacroConfig::default();
        let exe = load("spiking_mvm_b8_128x128");
        let mut rng = Rng::new(4001);
        let codes: Vec<u8> = (0..cfg.rows * cfg.cols)
            .map(|_| rng.below(4) as u8)
            .collect();
        let mut sim = CimMacro::new(cfg.clone());
        sim.program(&codes);

        let xs: Vec<Vec<u32>> = (0..8)
            .map(|_| (0..cfg.rows).map(|_| rng.below(256) as u32).collect())
            .collect();
        let mut t_in = vec![0.0f32; 8 * cfg.rows];
        for (b, x) in xs.iter().enumerate() {
            for (r, &v) in x.iter().enumerate() {
                t_in[b * cfg.rows + r] = v as f32 * cfg.t_bit_ns as f32;
            }
        }
        let out = exe
            .run_f32(&[
                Value::f32(t_in, &[8, cfg.rows]),
                Value::i32(
                    codes.iter().map(|&c| c as i32).collect(),
                    &[cfg.rows, cfg.cols],
                ),
            ])
            .unwrap();
        for (b, x) in xs.iter().enumerate() {
            let r = sim.mvm(x);
            for c in 0..cfg.cols {
                let interp = out[0][b * cfg.cols + c] as f64;
                let simulated = r.t_out_ns[c];
                let rel = (interp - simulated).abs() / simulated.abs().max(1e-6);
                assert!(rel < 1e-5, "b{b} c{c}: {interp} vs {simulated}");
            }
        }
    }

    #[test]
    fn macro_fwd_decodes_to_digital_macs() {
        let cfg = MacroConfig::default();
        let exe = load("macro_fwd_b8");
        let mut rng = Rng::new(4002);
        let codes: Vec<u8> = (0..cfg.rows * cfg.cols)
            .map(|_| rng.below(4) as u8)
            .collect();
        let x: Vec<i32> = (0..8 * cfg.rows)
            .map(|_| rng.below(256) as i32)
            .collect();
        let out = exe
            .run_f32(&[
                Value::i32(x.clone(), &[8, cfg.rows]),
                Value::i32(
                    codes.iter().map(|&c| c as i32).collect(),
                    &[cfg.rows, cfg.cols],
                ),
            ])
            .unwrap();
        assert_eq!(out.len(), 2);
        let mut sim = CimMacro::new(cfg.clone());
        sim.program(&codes);
        for b in 0..8 {
            let xb: Vec<u32> = (0..cfg.rows)
                .map(|r| x[b * cfg.rows + r] as u32)
                .collect();
            let want = sim.ideal_mvm(&xb);
            for c in 0..cfg.cols {
                let got = out[1][b * cfg.cols + c] as f64;
                let rel = (got - want[c]).abs() / want[c].max(1.0);
                assert!(rel < 1e-4, "b{b} c{c}: {got} vs {}", want[c]);
            }
        }
    }

    #[test]
    fn fig7b_droop_stays_below_mirror_trace() {
        let exe = load("fig7b_transient");
        let mut rng = Rng::new(4003);
        let t_in: Vec<f32> = (0..128)
            .map(|_| rng.below(256) as f32 * 0.2)
            .collect();
        let g: Vec<f32> = (0..128)
            .map(|_| LEVELS[rng.below(4) as usize])
            .collect();
        let out = exe
            .run_f32(&[Value::f32(t_in, &[128]), Value::f32(g, &[128])])
            .unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].len(), 1000);
        // Mirror trace is monotone; droop trace never exceeds it.
        for s in 1..1000 {
            assert!(out[0][s] >= out[0][s - 1]);
            assert!(out[1][s] <= out[0][s] + 1e-6);
        }
        assert!(out[1][999] < out[0][999]);
    }

    #[test]
    fn shape_mismatch_is_an_error_not_a_panic() {
        let exe = load("spiking_mvm_b8_128x128");
        let err = exe
            .run_f32(&[
                Value::f32(vec![0.0; 8 * 127], &[8, 127]),
                Value::i32(vec![0; 128 * 128], &[128, 128]),
            ])
            .unwrap_err();
        assert!(format!("{err}").contains("shape"), "{err}");
    }

    #[test]
    fn manifest_shapes_override_name_derived_geometry() {
        // A macro_fwd lowered for a 64-row geometry: the manifest's arg
        // shapes are the contract, not the 128×128 name default.
        let dir = std::env::temp_dir().join("spikemram_interp_shape_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"macro_fwd_b2": {"file": "x.hlo.txt",
                "args": [{"shape": [2, 64], "dtype": "int32"},
                         {"shape": [64, 32], "dtype": "int32"}],
                "alpha": 0.05, "t_bit_ns": 0.2}}"#,
        )
        .unwrap();
        let mut rt = Runtime::new(&dir).unwrap();
        let exe = rt.load("macro_fwd_b2").unwrap();
        let out = exe
            .run_f32(&[
                Value::i32(vec![1; 2 * 64], &[2, 64]),
                Value::i32(vec![0; 64 * 32], &[64, 32]),
            ])
            .unwrap();
        assert_eq!(out[0].len(), 2 * 32);
        // y = Σ x·G = 64 rows × 1 × G(0) = 64/6 per column.
        assert!((out[1][0] - 64.0 / 6.0).abs() < 1e-3, "{}", out[1][0]);
    }

    #[test]
    fn manifest_alpha_overrides_default() {
        // Write a manifest with a distinctive alpha and confirm it's used.
        let dir = std::env::temp_dir().join("spikemram_interp_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"spiking_mvm_b8_128x128": {"file": "x.hlo.txt",
                "args": [{"shape": [8, 128], "dtype": "float32"},
                         {"shape": [128, 128], "dtype": "int32"}],
                "alpha": 0.1, "t_bit_ns": 0.2}}"#,
        )
        .unwrap();
        let mut rt = Runtime::new(&dir).unwrap();
        let exe = rt.load("spiking_mvm_b8_128x128").unwrap();
        let t_in = vec![1.0f32; 8 * 128];
        let codes = vec![3i32; 128 * 128]; // G = 1/3 µS everywhere
        let out = exe
            .run_f32(&[
                Value::f32(t_in, &[8, 128]),
                Value::i32(codes, &[128, 128]),
            ])
            .unwrap();
        // t_out = alpha · Σ 1·(1/3) over 128 rows = 0.1 · 128/3.
        let want = 0.1f32 * 128.0 / 3.0;
        assert!((out[0][0] - want).abs() < 1e-3, "{}", out[0][0]);
    }
}

//! Artifact manifest: the shape/dtype contract between `aot.py` and the
//! Rust runtime, parsed from `artifacts/manifest.json`.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};

/// One lowered entry's argument spec.
#[derive(Debug, Clone, PartialEq)]
pub struct ArgSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One AOT-lowered entry.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    pub sha256: String,
    pub args: Vec<ArgSpec>,
    /// OSG sensing gain the artifact was lowered with.
    pub alpha: f64,
    pub t_bit_ns: f64,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Manifest> {
        let path = artifacts_dir.as_ref().join("manifest.json");
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&src)
    }

    pub fn parse(src: &str) -> Result<Manifest> {
        let root = json::parse(src).map_err(|e| anyhow::anyhow!(e))?;
        let obj = match &root {
            Json::Obj(o) => o,
            _ => bail!("manifest root must be an object"),
        };
        let mut entries = Vec::new();
        for (name, v) in obj {
            let args = v
                .get("args")
                .and_then(Json::as_arr)
                .context("entry.args")?
                .iter()
                .map(|a| {
                    let shape = a
                        .get("shape")
                        .and_then(Json::as_arr)
                        .context("arg.shape")?
                        .iter()
                        .map(|d| d.as_f64().context("dim").map(|x| x as usize))
                        .collect::<Result<Vec<_>>>()?;
                    let dtype = a
                        .get("dtype")
                        .and_then(Json::as_str)
                        .context("arg.dtype")?
                        .to_string();
                    Ok(ArgSpec { shape, dtype })
                })
                .collect::<Result<Vec<_>>>()?;
            entries.push(ArtifactEntry {
                name: name.clone(),
                file: v
                    .get("file")
                    .and_then(Json::as_str)
                    .context("entry.file")?
                    .to_string(),
                sha256: v
                    .get("sha256")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string(),
                args,
                alpha: v.get("alpha").and_then(Json::as_f64).unwrap_or(0.0),
                t_bit_ns: v
                    .get("t_bit_ns")
                    .and_then(Json::as_f64)
                    .unwrap_or(0.2),
            });
        }
        Ok(Manifest { entries })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Check an intended call's shapes against the manifest contract.
    pub fn check_args(&self, name: &str, shapes: &[Vec<usize>]) -> Result<()> {
        let e = self.get(name).with_context(|| format!("no entry {name}"))?;
        if e.args.len() != shapes.len() {
            bail!(
                "{name}: expected {} args, got {}",
                e.args.len(),
                shapes.len()
            );
        }
        for (i, (spec, got)) in e.args.iter().zip(shapes).enumerate() {
            if &spec.shape != got {
                bail!(
                    "{name} arg {i}: expected shape {:?}, got {:?}",
                    spec.shape,
                    got
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SRC: &str = r#"{
      "spiking_mvm_b8_128x128": {
        "file": "spiking_mvm_b8_128x128.hlo.txt",
        "sha256": "deadbeef",
        "args": [
          {"shape": [8, 128], "dtype": "float32"},
          {"shape": [128, 128], "dtype": "int32"}
        ],
        "alpha": 0.05,
        "t_bit_ns": 0.2
      }
    }"#;

    #[test]
    fn parses_entry() {
        let m = Manifest::parse(SRC).unwrap();
        let e = m.get("spiking_mvm_b8_128x128").unwrap();
        assert_eq!(e.args.len(), 2);
        assert_eq!(e.args[0].shape, vec![8, 128]);
        assert_eq!(e.args[1].dtype, "int32");
        assert!((e.alpha - 0.05).abs() < 1e-12);
    }

    #[test]
    fn check_args_accepts_matching_shapes() {
        let m = Manifest::parse(SRC).unwrap();
        m.check_args(
            "spiking_mvm_b8_128x128",
            &[vec![8, 128], vec![128, 128]],
        )
        .unwrap();
    }

    #[test]
    fn check_args_rejects_wrong_shape_and_arity() {
        let m = Manifest::parse(SRC).unwrap();
        assert!(m
            .check_args("spiking_mvm_b8_128x128", &[vec![8, 128]])
            .is_err());
        assert!(m
            .check_args(
                "spiking_mvm_b8_128x128",
                &[vec![8, 127], vec![128, 128]]
            )
            .is_err());
        assert!(m.check_args("nope", &[]).is_err());
    }
}

//! `spikemram` CLI — the L3 leader entrypoint.
//!
//! Subcommands map 1:1 onto the paper's evaluation artifacts plus a few
//! operational modes:
//!
//! ```text
//! spikemram table1|table2|fig3c|fig5|fig6a|fig6b|fig7a|fig7b|all
//! spikemram mvm   [--seed N] [--backend sim|pjrt] [--artifacts DIR]
//! spikemram snn   [--train N] [--test N] [--epochs N] [--levels device|ideal]
//! spikemram serve [--requests N] [--workers N] [--batch N] [--backend ...]
//! spikemram selfcheck [--artifacts DIR]
//! ```
//!
//! `--backend pjrt` uses the real XLA/PJRT runtime when the crate is built
//! with `--features pjrt`, and the pure-Rust artifact interpreter
//! (DESIGN.md S12) otherwise — so the `pjrt` code paths work on the
//! hermetic default build. `selfcheck` is the exception: it verifies the
//! generated `artifacts/` against the simulator and reports an error when
//! the manifest is missing.

use anyhow::{bail, Context, Result};

use spikemram::config::{FabricConfig, LevelMap, MacroConfig, TraceConfig};
use spikemram::coordinator::{BackendKind, MacroServer, Metrics, ServerConfig};
use spikemram::macro_model::CimMacro;
use spikemram::net::{NetBackend, NetServer};
use spikemram::obs;
use spikemram::repro;
use spikemram::runtime::{Manifest, Runtime, Value};
use spikemram::snn;
use spikemram::util::cli::Args;
use spikemram::util::pool;
use spikemram::util::rng::Rng;

const USAGE: &str = "\
spikemram — event-driven spiking CIM macro on SOT-MRAM (paper reproduction)

USAGE: spikemram <subcommand> [options]

experiments (paper artifacts → results/):
  table1            Table I   key simulation parameters
  table2            Table II  comparison with other CIM designs
  fig3c             Fig 3(c)  SMU transient waveforms
  fig5              Fig 5     column conversion transient
  fig6a             Fig 6(a)  power breakdown
  fig6b             Fig 6(b)  sensing energy comparison
  fig7a             Fig 7(a)  computing linearity
  fig7b             Fig 7(b)  V_charge droop without clamp+CM
  all               run everything above
  ablations         design-knob + Monte-Carlo corner sweep [--mvms N]
  scaling           EX1 array-size scaling study (parasitics + headroom)
  fabric            EX2 multi-macro fabric scaling sweep (macros 1 → 64:
                    spike-packet NoC share, hops, modeled throughput)
  stream            EX3 temporal streaming sweep (accuracy/energy/occupancy
                    vs T ∈ {1,2,4,8,16} on the binary-spike path)
  reliability       EX4 fault-injection reliability sweep (accuracy + energy
                    per decision vs simulated uptime, with/without scrubbing)
  overload          EX5 overload & admission-control sweep (shed rate and
                    bounded p99 vs offered load on the S21 control plane)
                    [--frames N per point]
  endurance         EX6 mission-clock endurance sweep (accuracy, scrub
                    energy, wear fraction vs days of simulated uptime
                    across scrub-only/recal-only/adaptive arms, plus the
                    wear-ceiling degrade demo)  [--train N] [--test N]
                    [--epochs N]
  serving           EX7 network serving sweep over real TCP (p50/p95/p99,
                    shed rate, energy/request vs offered load through the
                    S23 wire front end)  [--frames N per connection]

operations:
  mvm        run one 128×128 macro MVM   [--seed N] [--backend sim|pjrt]
  snn        train + quantize + run the digits MLP on macros
             [--train N] [--test N] [--epochs N] [--levels device|ideal]
  serve      spin up the batching server  [--requests N] [--workers N]
             [--batch N] [--backend sim|pjrt|fabric|stream]
             [--artifacts DIR] [--grid G] [--k K] [--n N]
             [--trace-out PATH] [--metrics-json PATH]
             (fabric: K×N weights, G×G mesh)
             (stream: [--sessions S] [--steps T] per-session LIF state;
              admission control [--queue-cap N] [--deadline-ms MS]
              [--max-restarts N];
              mission clock [--hours H simulated] [--uptime-factor F
              simulated ns per wall ns, default 1e9]
              [--mission scrub|recal|adaptive] [--gain-sigma S])
             network mode: [--listen HOST:PORT] exposes the backend over
             the S23 wire protocol instead of running the demo workload
             (port 0 picks an ephemeral port; [--listen-addr-file PATH]
             writes the bound address for scripts); stop it with a wire
             `drain` request (e.g. `spikemram loadgen --drain`)
  loadgen    closed-loop load harness against a live `serve --listen`
             endpoint  [--connect HOST:PORT] [--mode closed|open]
             [--connections N] [--frames N per connection] [--rps R]
             [--churn N] [--deadline-ms MS] [--steps T] [--drain]
  trace      serve a short synthetic stream workload with full tracing
             on and write a Perfetto/Chrome trace_event JSON
             (default results/trace_<seed>.json)  [--sessions S]
             [--steps T] [--workers N] [--trace-out PATH]
  selfcheck  verify PJRT artifacts match the behavioral simulator

common options: --seed N   --artifacts DIR (default: artifacts)
";

fn main() -> Result<()> {
    let args = Args::from_env();
    let seed = args.get_u64("seed", 42);
    let cfg = MacroConfig::default();
    let sub = match args.subcommand.as_deref() {
        Some(s) => s.to_string(),
        None => {
            print!("{USAGE}");
            return Ok(());
        }
    };
    match sub.as_str() {
        "table1" => println!("{}", repro::table1::table1(&cfg)),
        "table2" => println!(
            "{}",
            repro::table2::render(&repro::table2::run(&cfg, 50, seed))
        ),
        "fig3c" => println!("{}", repro::fig3::render(&repro::fig3::run(&cfg, 16))),
        "fig5" => println!("{}", repro::fig5::render(&repro::fig5::run(&cfg))),
        "fig6a" => println!(
            "{}",
            repro::fig6::render_fig6a(&repro::fig6::run_fig6a(&cfg, 50, seed))
        ),
        "fig6b" => println!(
            "{}",
            repro::fig6::render_fig6b(&repro::fig6::run_fig6b(&cfg))
        ),
        "fig7a" => {
            let points = args.get_usize("points", 4096);
            println!(
                "{}",
                repro::fig7::render_fig7a(&repro::fig7::run_fig7a(
                    &cfg, points, seed
                ))
            );
        }
        "fig7b" => println!(
            "{}",
            repro::fig7::render_fig7b(&repro::fig7::run_fig7b(
                &cfg,
                repro::fig7::FIG7B_ACTIVE_ROWS
            ))
        ),
        "all" => {
            let report = repro::run_all(&cfg, seed);
            let path = repro::report::save("full_report.md", &report);
            println!("{report}\nsaved to {}", path.display());
        }
        "ablations" => {
            let mvms = args.get_usize("mvms", 4);
            println!("{}", repro::ablations::run_and_save(seed, mvms));
        }
        "scaling" => {
            println!("{}", repro::scaling::render(&repro::scaling::run(&cfg)));
        }
        "fabric" => {
            println!(
                "{}",
                repro::fabric::render(&repro::fabric::run(&cfg, seed))
            );
        }
        "stream" => {
            println!(
                "{}",
                repro::stream::render(&repro::stream::run(&cfg, seed))
            );
        }
        "reliability" => {
            println!(
                "{}",
                repro::reliability::render(&repro::reliability::run(
                    &cfg, seed
                ))
            );
        }
        "overload" => {
            let frames = args.get_usize("frames", 400);
            let sweep = repro::overload::run(seed, frames);
            println!("{}", repro::overload::render(&sweep));
            let p = repro::overload::write_bench_record(&sweep);
            println!("bench record: {}", p.display());
        }
        "endurance" => {
            let n_train = args.get_usize("train", 300);
            let n_test = args.get_usize("test", 60);
            let epochs = args.get_usize("epochs", 6);
            let sweep = repro::endurance::run_points(
                seed,
                &[24.0, 48.0, 96.0],
                n_train,
                n_test,
                epochs,
            );
            println!("{}", repro::endurance::render(&sweep));
            let p = repro::endurance::write_bench_record(&sweep);
            println!("bench record: {}", p.display());
        }
        "serving" => {
            let frames = args.get_usize("frames", 48);
            let sweep = repro::serving::run(seed, frames);
            println!("{}", repro::serving::render(&sweep));
            let p = repro::serving::write_bench_record(&sweep);
            println!("bench record: {}", p.display());
        }
        "mvm" => cmd_mvm(&args, &cfg, seed)?,
        "snn" => cmd_snn(&args, &cfg, seed)?,
        "serve" => cmd_serve(&args, &cfg, seed)?,
        "loadgen" => cmd_loadgen(&args, seed)?,
        "trace" => cmd_trace(&args, &cfg, seed)?,
        "selfcheck" => cmd_selfcheck(&args, &cfg, seed)?,
        other => {
            eprint!("unknown subcommand {other:?}\n\n{USAGE}");
            bail!("unknown subcommand");
        }
    }
    Ok(())
}

fn random_codes(cfg: &MacroConfig, rng: &mut Rng) -> Vec<u8> {
    (0..cfg.rows * cfg.cols).map(|_| rng.below(4) as u8).collect()
}

fn cmd_mvm(args: &Args, cfg: &MacroConfig, seed: u64) -> Result<()> {
    let mut rng = Rng::new(seed);
    let codes = random_codes(cfg, &mut rng);
    let x: Vec<u32> = (0..cfg.rows).map(|_| rng.below(256) as u32).collect();
    let backend = args.get_str("backend", "sim");
    match backend.as_str() {
        "sim" => {
            let mut m = CimMacro::new(cfg.clone());
            m.program(&codes);
            let r = m.mvm(&x);
            let ideal = m.ideal_mvm(&x);
            let max_err = r
                .y_mac
                .iter()
                .zip(&ideal)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f64::max);
            println!(
                "sim MVM: latency {:.1} ns, energy {:.1} pJ, {} events",
                r.latency_ns,
                r.energy.total_pj(),
                r.events
            );
            println!(
                "first 8 MACs: {:?}",
                &r.y_mac[..8.min(r.y_mac.len())]
                    .iter()
                    .map(|v| (v * 10.0).round() / 10.0)
                    .collect::<Vec<_>>()
            );
            println!("max |err| vs digital oracle: {max_err:.2e}");
            println!(
                "efficiency: {:.1} TOPS/W",
                spikemram::energy::tops_per_watt(
                    cfg.ops_per_mvm(),
                    r.energy.total_fj()
                )
            );
        }
        "pjrt" => {
            let dir = args.get_str("artifacts", "artifacts");
            let mut rt = Runtime::new(&dir)?;
            println!("PJRT platform: {}", rt.platform());
            let exe = rt.load("spiking_mvm_b8_128x128")?;
            let t_in: Vec<f32> = (0..8 * cfg.rows)
                .map(|i| x[i % cfg.rows] as f32 * cfg.t_bit_ns as f32)
                .collect();
            let codes_i32: Vec<i32> = codes.iter().map(|&c| c as i32).collect();
            let out = exe.run_f32(&[
                Value::f32(t_in, &[8, cfg.rows]),
                Value::i32(codes_i32, &[cfg.rows, cfg.cols]),
            ])?;
            println!("pjrt MVM ok: t_out[0][..8] = {:?}", &out[0][..8]);
        }
        other => bail!("unknown backend {other:?}"),
    }
    Ok(())
}

fn cmd_snn(args: &Args, cfg: &MacroConfig, seed: u64) -> Result<()> {
    let n_train = args.get_usize("train", 400);
    let n_test = args.get_usize("test", 200);
    let epochs = args.get_usize("epochs", 6);
    let levels = match args.get_str("levels", "device").as_str() {
        "device" => LevelMap::DeviceTrue,
        "ideal" => LevelMap::IdealLinear,
        other => bail!("--levels device|ideal, got {other:?}"),
    };
    let train_data = snn::Dataset::generate(n_train, seed);
    let test_data = snn::Dataset::generate(n_test, seed ^ 0xabcd);
    println!("training float MLP on {n_train} synthetic digits…");
    let (model, train_acc) = snn::train(&train_data, epochs, seed);
    let float_acc = snn::accuracy(&model, &test_data);
    println!("float: train acc {train_acc:.3}, test acc {float_acc:.3}");

    let mut mm = snn::MacroMlp::from_float(&model, &train_data, cfg, levels);
    let (acc, stats) = mm.evaluate(&test_data);
    let per_inf = stats.energy.total_pj() / n_test as f64;
    println!(
        "macro ({levels:?} levels): test acc {acc:.3}  \
         energy {per_inf:.1} pJ/inference  latency {:.1} ns/inference  \
         {:.1} TOPS/W on MACs",
        stats.latency_ns / n_test as f64,
        spikemram::energy::tops_per_watt(stats.macs * 2, stats.energy.total_fj())
    );
    Ok(())
}

/// Post-run observability drain (DESIGN.md S20), shared by `serve` and
/// `trace`: fold the pool queue high-water mark into `metrics`, then —
/// when requested — drain the trace rings into a Perfetto JSON
/// (`--trace-out`) and write/print the machine-readable metrics
/// snapshot (`--metrics-json`).
fn finish_observability(
    metrics: &Metrics,
    trace_out: Option<&str>,
    metrics_json: Option<&str>,
) -> Result<()> {
    metrics.record_pool_queue_depth(pool::queue_high_water() as u64);
    metrics.record_pool_panics(pool::panics());
    if let Some(path) = trace_out {
        let report = obs::drain();
        metrics.absorb_trace(&report);
        let p = obs::write_chrome_trace(std::path::Path::new(path), &report)?;
        println!(
            "trace: {} events ({} dropped) → {}",
            report.events.len(),
            report.dropped,
            p.display()
        );
    }
    if let Some(path) = metrics_json {
        let j = metrics.snapshot().to_json().to_string();
        std::fs::write(path, &j).with_context(|| format!("write {path}"))?;
        println!("metrics json → {path}");
    }
    Ok(())
}

fn cmd_serve(args: &Args, cfg: &MacroConfig, seed: u64) -> Result<()> {
    let n = args.get_usize("requests", 256);
    if args.get_str("backend", "sim") == "stream" {
        return cmd_serve_stream(args, cfg, seed);
    }
    if args.get("trace-out").is_some() {
        obs::install(&TraceConfig::all());
    }
    let backend = match args.get_str("backend", "sim").as_str() {
        "sim" => BackendKind::Sim,
        "pjrt" => BackendKind::Pjrt {
            artifacts_dir: args.get_str("artifacts", "artifacts"),
        },
        "fabric" => {
            let g = args.get_usize("grid", 4);
            BackendKind::Fabric {
                fabric: FabricConfig::square(g),
                k: args.get_usize("k", 2 * cfg.rows),
                n: args.get_usize("n", 2 * cfg.cols),
            }
        }
        other => bail!("unknown backend {other:?}"),
    };
    let scfg = ServerConfig {
        workers: args.get_usize("workers", 2),
        max_batch: args.get_usize("batch", 8),
        backend,
        ..ServerConfig::default()
    };
    let mut rng = Rng::new(seed);
    let (in_dim, codes) = match &scfg.backend {
        BackendKind::Fabric { k, n, .. } => (
            *k,
            (0..k * n).map(|_| rng.below(4) as u8).collect(),
        ),
        _ => (cfg.rows, random_codes(cfg, &mut rng)),
    };
    let server = MacroServer::start(cfg.clone(), codes, scfg)?;
    if let Some(listen) = args.get("listen") {
        return serve_listen(
            NetBackend::Macro(server),
            listen,
            args.get("listen-addr-file"),
        );
    }
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..n)
        .map(|_| {
            let x: Vec<u32> =
                (0..in_dim).map(|_| rng.below(256) as u32).collect();
            server.submit(x)
        })
        .collect();
    for rx in rxs {
        rx.recv().context("reply")?;
    }
    let dt = t0.elapsed();
    println!(
        "{n} requests in {:.1} ms → {:.0} req/s",
        dt.as_secs_f64() * 1e3,
        n as f64 / dt.as_secs_f64()
    );
    finish_observability(
        &server.metrics,
        args.get("trace-out"),
        args.get("metrics-json"),
    )?;
    println!("{}", server.metrics.summary());
    let snap = server.metrics.snapshot();
    if snap.tiles_total > 0 {
        println!(
            "fabric: {:.0} % of {} tiles utilized, {:.1} hops/packet",
            snap.tile_utilization() * 100.0,
            snap.tiles_total,
            snap.hops_per_packet()
        );
    }
    server.shutdown();
    Ok(())
}

/// `serve --backend stream` (DESIGN.md S18): session mode — every
/// request stream is a temporal inference with per-session LIF state
/// resident on the server; metrics report per-timestep latency,
/// energy, and occupancy.
fn cmd_serve_stream(args: &Args, cfg: &MacroConfig, seed: u64) -> Result<()> {
    use spikemram::config::StreamConfig;
    use spikemram::device::faults::FaultPlan;
    use spikemram::device::retention::RetentionParams;
    use spikemram::stream::{
        FrameEncoder, MissionConfig, MissionMode, StreamServer,
        StreamServerConfig, StreamSpec, TemporalCode,
    };

    if args.get("trace-out").is_some() {
        obs::install(&TraceConfig::all());
    }
    let sessions = args.get_usize("sessions", 8);
    let mission_hours = args.get_f64("hours", 0.0);
    let t_steps = args.get_usize("steps", 8);
    let n_train = args.get_usize("train", 200);
    println!("training the digit MLP ({n_train} examples)…");
    let train_data = snn::Dataset::generate(n_train, seed);
    let (model, acc) = snn::train(&train_data, 4, seed);
    println!("float train accuracy {acc:.3}; deploying per worker…");
    let spec = StreamSpec {
        model,
        calib: train_data,
        mcfg: cfg.clone(),
        fabric: FabricConfig::square(2),
        level_map: LevelMap::DeviceTrue,
        stream: StreamConfig {
            t_steps,
            ..StreamConfig::default()
        },
    };
    // S21 admission-control knobs. Defaults match
    // `StreamServerConfig::default()`: a 1024-deep queue, no deadline,
    // the standard restart budget.
    let mut scfg = StreamServerConfig {
        workers: args.get_usize("workers", 2),
        queue_cap: args.get_usize("queue-cap", 1024),
        ..StreamServerConfig::default()
    };
    if let Some(ms) = args.get("deadline-ms") {
        let ms: f64 = ms.parse().context("--deadline-ms expects a number")?;
        scfg.deadline = Some(parse_deadline_ms(ms)?);
    }
    if let Some(n) = args.get("max-restarts") {
        scfg.restart.max_restarts =
            n.parse().context("--max-restarts expects an integer")?;
    }
    // S22 mission clock: --hours H lands H simulated hours of uptime on
    // the workers while they serve — drift and maintenance flow through
    // the same per-worker FIFOs as frames, no explicit drift() calls.
    // Virtual uptime needs something to age, so the weak retention
    // corner plus gain wander is deployed as the fault plan.
    if mission_hours > 0.0 {
        scfg.faults = Some(FaultPlan::mission(
            RetentionParams::weak(),
            args.get_f64("gain-sigma", 0.05),
            seed ^ 0x5eed,
        ));
    }
    let server = StreamServer::start(spec, scfg)?;
    if mission_hours > 0.0 {
        let factor = args.get_f64("uptime-factor", 1e9);
        let mode = match args.get_str("mission", "adaptive").as_str() {
            "scrub" => MissionMode::ScrubOnly,
            "recal" => MissionMode::RecalOnly,
            "adaptive" => MissionMode::Adaptive,
            other => bail!("--mission scrub|recal|adaptive, got {other:?}"),
        };
        let mcfg = MissionConfig::compressed(
            factor,
            mission_hours,
            std::time::Duration::from_millis(5),
            mode,
        );
        println!(
            "mission clock: {mission_hours} h simulated at {factor:.0e}x \
             compression → {} ticks of {:.1} h ({mode:?})",
            mcfg.horizon,
            mcfg.sim_dt_ns / 3.6e12,
        );
        server.start_mission(mcfg);
    }
    if let Some(listen) = args.get("listen") {
        return serve_listen(
            NetBackend::Stream(server),
            listen,
            args.get("listen-addr-file"),
        );
    }

    let test = snn::Dataset::generate(sessions, seed ^ 0xabcd);
    let enc = FrameEncoder::new(TemporalCode::Rate, t_steps, 255);
    let t0 = std::time::Instant::now();
    let ids: Vec<u64> =
        (0..sessions).map(|_| server.open_session()).collect();
    // Interleave the sessions' timesteps — streaming traffic, not
    // one-shot batches.
    let frames: Vec<Vec<Vec<u32>>> = (0..sessions)
        .map(|i| enc.encode_frames(&test.features_u8(i)))
        .collect();
    // Periodic report on a *windowed* basis (DESIGN.md S20):
    // `snapshot_since` differences against the previous snapshot, so
    // every printed figure — rates, shed fraction, scrub duty cycle —
    // covers this window, not the meaningless average since
    // construction (which includes training/idle time). The duty cycle
    // and shed rate are computed on the *delta* snapshot: lifetime
    // counters would dilute a busy window with hours of earlier idle.
    let mut prev = server.metrics.snapshot();
    for t in 0..t_steps {
        for (s, &id) in ids.iter().enumerate() {
            let _ = server.frame(id, frames[s][t].clone());
        }
        if (t + 1) % 4 == 0 || t + 1 == t_steps {
            let w = server.metrics.snapshot_since(&prev);
            println!(
                "  [t={}] window: {} frames, {:.0} frames/s, \
                 {:.2e} mac/s, shed {:.1} %, {} scrubs, \
                 scrub duty {:.2} %",
                t + 1,
                w.requests,
                w.rps,
                w.macs_per_s,
                w.shed_rate() * 100.0,
                w.scrubs,
                w.scrub_duty_cycle() * 100.0
            );
            prev = server.metrics.snapshot();
        }
    }
    let mut correct = 0usize;
    for (s, &id) in ids.iter().enumerate() {
        let r = server.finish(id);
        if r.label == test.examples[s].label {
            correct += 1;
        }
    }
    let dt = t0.elapsed();
    println!(
        "{sessions} sessions × {t_steps} timesteps in {:.1} ms → \
         {:.0} frames/s; {} / {sessions} labels correct",
        dt.as_secs_f64() * 1e3,
        (sessions * t_steps) as f64 / dt.as_secs_f64(),
        correct
    );
    if mission_hours > 0.0 {
        // Bounded missions stop at their horizon; wait so the final
        // metrics include the whole simulated lifetime.
        let sim_ns = server.mission_wait();
        server.stop_mission();
        let snap = server.metrics.snapshot();
        println!(
            "mission: {:.1} h simulated uptime, {} flips injected, \
             {} repaired, {} scrubs, {} recals, wear max {:.4} %",
            sim_ns / 3.6e12,
            snap.flips_injected,
            snap.flips_repaired,
            snap.scrubs,
            snap.recalibrations,
            snap.wear_max() * 100.0
        );
    }
    finish_observability(
        &server.metrics,
        args.get("trace-out"),
        args.get("metrics-json"),
    )?;
    println!("{}", server.metrics.summary());
    let snap = server.metrics.snapshot();
    println!(
        "per-timestep: {:.2} pJ, occupancy {:.1} %",
        snap.energy_fj / 1e3 / snap.requests.max(1) as f64,
        snap.input_density() * 100.0
    );
    server.shutdown();
    Ok(())
}

/// `serve --listen` (DESIGN.md S23): park the booted backend behind
/// the wire front end until a remote `drain` request stops it. The
/// bound address goes to stdout and — for scripts driving ephemeral
/// ports — optionally to `--listen-addr-file`.
fn serve_listen(
    backend: NetBackend,
    listen: &str,
    addr_file: Option<&str>,
) -> Result<()> {
    let net = NetServer::start(backend, listen)?;
    let addr = net.addr();
    println!("listening on {addr} (stop with a wire `drain` request)");
    if let Some(path) = addr_file {
        std::fs::write(path, addr.to_string())
            .with_context(|| format!("write {path}"))?;
    }
    let metrics = net.metrics();
    net.wait();
    println!("drained; all connections closed");
    println!("{}", metrics.summary());
    Ok(())
}

/// Convert a `--deadline-ms` value fallibly: `Duration::from_secs_f64`
/// panics on NaN/negative/overflow (~1.8e22 ms), so huge or garbage
/// values must be a CLI error, not a crash.
fn parse_deadline_ms(ms: f64) -> Result<std::time::Duration> {
    std::time::Duration::try_from_secs_f64(ms / 1e3)
        .map_err(|e| anyhow::anyhow!("--deadline-ms {ms}: {e}"))
}

/// `spikemram loadgen` (DESIGN.md S23): drive a live `serve --listen`
/// endpoint with the closed-loop load harness and print the client-side
/// report. `--drain` gracefully stops the server afterwards (which lets
/// a backgrounded `serve --listen` exit).
fn cmd_loadgen(args: &Args, seed: u64) -> Result<()> {
    use spikemram::net::{loadgen, LoadGenConfig, LoadMode, NetClient};
    use spikemram::stream::{FrameEncoder, TemporalCode};

    let connect = match args.get("connect") {
        Some(a) => a.to_string(),
        None => bail!(
            "--connect HOST:PORT is required (boot a server with \
             `spikemram serve --backend stream --listen 127.0.0.1:0`)"
        ),
    };
    let mode = match args.get_str("mode", "closed").as_str() {
        "closed" => LoadMode::Closed,
        "open" => LoadMode::Open,
        other => bail!("--mode closed|open, got {other:?}"),
    };
    let deadline = match args.get("deadline-ms") {
        Some(ms) => {
            let ms: f64 =
                ms.parse().context("--deadline-ms expects a number")?;
            Some(parse_deadline_ms(ms)?)
        }
        None => None,
    };
    // Rate-coded frames from the synthetic digit set — the same spike
    // traffic the EX7 sweep offers.
    let t_steps = args.get_usize("steps", 4);
    let data = snn::Dataset::generate(8, seed ^ 0x11);
    let enc = FrameEncoder::new(TemporalCode::Rate, t_steps, 255);
    let pool: Vec<Vec<u32>> = (0..data.len())
        .flat_map(|i| enc.encode_frames(&data.features_u8(i)))
        .collect();
    let lcfg = LoadGenConfig {
        mode,
        connections: args.get_usize("connections", 4),
        frames: args.get_usize("frames", 64),
        target_fps: args.get_f64("rps", 200.0),
        churn_every: args.get_usize("churn", 0),
        deadline,
        events_pool: pool,
    };
    let rep = loadgen::run(&connect, &lcfg)?;
    println!(
        "loadgen {mode:?} against {connect}: {} offered over {} \
         connections in {:.2} s",
        rep.offered,
        lcfg.connections,
        rep.wall_s
    );
    println!(
        "  served {} ({:.0} req/s), shed {} ({:.1} %), errors {}, \
         late {}",
        rep.served,
        rep.achieved_rps,
        rep.shed,
        rep.shed_rate * 100.0,
        rep.errors,
        rep.late
    );
    println!(
        "  latency p50 {:.2} ms  p95 {:.2} ms  p99 {:.2} ms; \
         energy {:.2} pJ/request",
        rep.p50_ms, rep.p95_ms, rep.p99_ms, rep.energy_pj_per_req
    );
    if args.flag("drain") {
        let mut ctl = NetClient::connect(&connect)?;
        let (drain_ms, shed, clean) = ctl.drain(10_000.0)?;
        println!(
            "drained server in {drain_ms:.1} ms (shed {shed}, clean \
             {clean})"
        );
    }
    Ok(())
}

/// `spikemram trace` (DESIGN.md S20): serve a short synthetic stream
/// workload with every trace kind enabled and write the Perfetto
/// `trace_event` JSON to `results/trace_<seed>.json` (override with
/// `--trace-out`). Deploys an *untrained* model — the trace needs
/// representative work through every instrumented site, not accuracy —
/// so it runs in seconds (the ci.sh smoke target).
fn cmd_trace(args: &Args, cfg: &MacroConfig, seed: u64) -> Result<()> {
    use spikemram::config::StreamConfig;
    use spikemram::stream::{
        FrameEncoder, StreamServer, StreamServerConfig, StreamSpec,
        TemporalCode,
    };

    obs::install(&TraceConfig::all());
    let sessions = args.get_usize("sessions", 4);
    let t_steps = args.get_usize("steps", 4);
    let calib = snn::Dataset::generate(sessions.max(32), seed);
    let spec = StreamSpec {
        model: snn::Mlp::new(seed),
        calib: calib.clone(),
        mcfg: cfg.clone(),
        fabric: FabricConfig::square(2),
        level_map: LevelMap::DeviceTrue,
        stream: StreamConfig {
            t_steps,
            ..StreamConfig::default()
        },
    };
    let server = StreamServer::start(
        spec,
        StreamServerConfig {
            workers: args.get_usize("workers", 2),
            ..StreamServerConfig::default()
        },
    )?;
    let enc = FrameEncoder::new(TemporalCode::Rate, t_steps, 255);
    let ids: Vec<u64> =
        (0..sessions).map(|_| server.open_session()).collect();
    let frames: Vec<Vec<Vec<u32>>> = (0..sessions)
        .map(|i| enc.encode_frames(&calib.features_u8(i)))
        .collect();
    for t in 0..t_steps {
        for (s, &id) in ids.iter().enumerate() {
            let _ = server.frame(id, frames[s][t].clone());
        }
    }
    for &id in &ids {
        let _ = server.finish(id);
    }
    let default_out = repro::report::results_dir()
        .join(format!("trace_{seed}.json"))
        .to_string_lossy()
        .into_owned();
    let trace_out = args.get_str("trace-out", &default_out);
    finish_observability(
        &server.metrics,
        Some(&trace_out),
        args.get("metrics-json"),
    )?;
    println!("{}", server.metrics.summary());
    server.shutdown();
    obs::install(&TraceConfig::off());
    Ok(())
}

fn cmd_selfcheck(args: &Args, cfg: &MacroConfig, seed: u64) -> Result<()> {
    let dir = args.get_str("artifacts", "artifacts");
    let manifest = Manifest::load(&dir)
        .context("manifest.json missing — run `make artifacts` first")?;
    println!("manifest: {} entries", manifest.entries.len());
    manifest.check_args(
        "spiking_mvm_b8_128x128",
        &[vec![8, cfg.rows], vec![cfg.rows, cfg.cols]],
    )?;

    let mut rt = Runtime::new(&dir)?;
    let exe = rt.load("spiking_mvm_b8_128x128")?;
    let mut rng = Rng::new(seed);
    let codes = random_codes(cfg, &mut rng);
    let mut m = CimMacro::new(cfg.clone());
    m.program(&codes);

    let xs: Vec<Vec<u32>> = (0..8)
        .map(|_| (0..cfg.rows).map(|_| rng.below(256) as u32).collect())
        .collect();
    let mut t_in = vec![0.0f32; 8 * cfg.rows];
    for (b, x) in xs.iter().enumerate() {
        for (r, &v) in x.iter().enumerate() {
            t_in[b * cfg.rows + r] = v as f32 * cfg.t_bit_ns as f32;
        }
    }
    let codes_i32: Vec<i32> = codes.iter().map(|&c| c as i32).collect();
    let out = exe.run_f32(&[
        Value::f32(t_in, &[8, cfg.rows]),
        Value::i32(codes_i32, &[cfg.rows, cfg.cols]),
    ])?;
    let mut max_rel = 0.0f64;
    for (b, x) in xs.iter().enumerate() {
        let r = m.mvm(x);
        for c in 0..cfg.cols {
            let pjrt = out[0][b * cfg.cols + c] as f64;
            let sim = r.t_out_ns[c];
            let rel = (pjrt - sim).abs() / sim.abs().max(1e-6);
            max_rel = max_rel.max(rel);
        }
    }
    println!("sim vs pjrt max rel err over 8×128 outputs: {max_rel:.3e}");
    if max_rel > 1e-4 {
        bail!("selfcheck FAILED: backends disagree");
    }
    println!("selfcheck OK — behavioral sim and AOT artifact agree");
    Ok(())
}

//! Component energy model, calibrated to the paper's published aggregates
//! (DESIGN.md §6):
//!
//! * 243.6 TOPS/W peak at 8-bit inputs on the uniform-random workload,
//! * OSG = 72.6 % of total power (Fig 6a),
//! * sensing-energy reductions vs ADC/spike/TDC baselines (Fig 6b).
//!
//! Only the *aggregates* are anchored; the model itself is compositional —
//! array energy is pure physics (V²·G·t), SMU/OSG/control scale with the
//! actual event windows of the workload — so precision/size/sparsity
//! sweeps produce genuine trends rather than hard-coded numbers.

use crate::config::MacroConfig;

use super::accounting::EnergyBreakdown;

/// Calibrated per-component energy parameters (28 nm class).
#[derive(Debug, Clone, Copy)]
pub struct EnergyParams {
    // --- SMU (per row) ---
    /// Energy per DFF toggle (fJ); two toggles per spike pair.
    pub e_dff_toggle_fj: f64,
    /// Clamp bias power while a row window is open (µW).
    pub p_clamp_uw: f64,
    // --- OSG (per column) ---
    /// Mirror + bit-line clamp bias power during the charge phase (µW).
    pub p_mirror_uw: f64,
    /// Comparator bias power during the compare phase (µW).
    pub p_comp_uw: f64,
    /// Energy per emitted output spike (fJ); two per conversion.
    pub e_spike_fj: f64,
    // --- control ---
    /// Event-driven control logic energy per processed event (fJ).
    pub e_ctrl_event_fj: f64,
    /// Fixed per-op control energy (decoders, flag OR-tree, handshake; fJ).
    pub e_op_fixed_fj: f64,
}

impl Default for EnergyParams {
    fn default() -> Self {
        // Calibration derivation in DESIGN.md §6; verified by the
        // `calibration_*` tests below.
        EnergyParams {
            e_dff_toggle_fj: 1.2,
            p_clamp_uw: 6.0,
            p_mirror_uw: 6.0,
            p_comp_uw: 6.0,
            e_spike_fj: 27.0,
            e_ctrl_event_fj: 27.0,
            e_op_fixed_fj: 5500.0,
        }
    }
}

/// Workload description of one full-array MVM, produced by the macro sim.
#[derive(Debug, Clone)]
pub struct MvmActivity {
    /// Per-row input window durations T_in,i (ns); 0 = row inactive.
    pub row_windows_ns: Vec<f64>,
    /// Per-column charge-phase cell-current integrals Σ_i T_i·G_ij (ns·µS).
    pub col_charge_nsus: Vec<f64>,
    /// Per-column V_charge at flag drop (V).
    pub v_charge: Vec<f64>,
    /// Per-column output intervals T_out (ns).
    pub t_out_ns: Vec<f64>,
    /// Global flag high duration (charge phase length, ns).
    pub t_charge_ns: f64,
    /// Events processed (row rises + falls + compare fires).
    pub events: u64,
}

impl MvmActivity {
    /// Borrow this activity as an [`ActivityView`].
    pub fn view(&self) -> ActivityView<'_> {
        ActivityView {
            row_windows_ns: &self.row_windows_ns,
            col_charge_nsus: &self.col_charge_nsus,
            v_charge: &self.v_charge,
            t_out_ns: &self.t_out_ns,
            t_charge_ns: self.t_charge_ns,
            events: self.events,
        }
    }
}

/// Borrowed view of one MVM's activity (DESIGN.md S16): the macro's hot
/// path hands its scratch and ledger slices straight to [`mvm_energy`]
/// without cloning the per-column vectors.
#[derive(Debug, Clone, Copy)]
pub struct ActivityView<'a> {
    pub row_windows_ns: &'a [f64],
    pub col_charge_nsus: &'a [f64],
    pub v_charge: &'a [f64],
    pub t_out_ns: &'a [f64],
    pub t_charge_ns: f64,
    pub events: u64,
}

impl<'a> From<&'a MvmActivity> for ActivityView<'a> {
    fn from(act: &'a MvmActivity) -> ActivityView<'a> {
        act.view()
    }
}

/// Compute the energy breakdown of one macro MVM. Accepts either an owned
/// `&MvmActivity` or a borrowed [`ActivityView`] over scratch slices.
pub fn mvm_energy<'a>(
    cfg: &MacroConfig,
    p: &EnergyParams,
    act: impl Into<ActivityView<'a>>,
) -> EnergyBreakdown {
    let act = act.into();
    let v_read = cfg.v_read();

    // Array: E = Σ_cells V_read²·G·T = V_read² · Σ_cols (Σ_i T_i·G_ij)...
    // col_charge already integrates T·G per column.
    let array_fj: f64 =
        act.col_charge_nsus.iter().map(|&q| v_read * v_read * q).sum();

    // SMU: two DFF toggles + clamp bias per *active* row.
    let smu_fj: f64 = act
        .row_windows_ns
        .iter()
        .filter(|&&w| w > 0.0)
        .map(|&w| 2.0 * p.e_dff_toggle_fj + p.p_clamp_uw * w)
        .sum();

    // OSG per column: mirror bias over the (shared) charge window,
    // comparator over its own compare window, two output spikes, and the
    // switched-capacitor cost of C_rt and C_com (CV·Vdd each).
    let osg_fj: f64 = act
        .v_charge
        .iter()
        .zip(act.t_out_ns)
        .map(|(&v, &t_out)| {
            p.p_mirror_uw * act.t_charge_ns
                + p.p_comp_uw * t_out
                + 2.0 * p.e_spike_fj
                + (cfg.c_rt_ff + cfg.c_com_ff) * v * cfg.vdd
        })
        .sum();

    let control_fj = p.e_op_fixed_fj + p.e_ctrl_event_fj * act.events as f64;

    EnergyBreakdown {
        array_fj,
        smu_fj,
        osg_fj,
        control_fj,
        // Single-macro op: NoC traffic is charged by S15, write/scrub
        // pulses by the S19 reliability runtime.
        ..EnergyBreakdown::default()
    }
}

/// The nominal workload used for the headline number: every row active
/// with the *average* 8-bit value, every column at the average code.
/// (The uniform-random Monte-Carlo version lives in the repro harness;
/// this closed form keeps the calibration tests fast and exact.)
pub fn nominal_activity(cfg: &MacroConfig) -> MvmActivity {
    let rows = cfg.rows;
    let cols = cfg.cols;
    let t_avg = (cfg.t_in_max_ns()) / 2.0; // E[x]·t_bit for uniform x
    let levels = cfg.level_map.levels();
    let g_avg = levels.iter().sum::<f64>() / 4.0;
    let q_col = rows as f64 * t_avg * g_avg; // Σ T·G per column
    let v_charge =
        cfg.k_mirror * cfg.v_read() * q_col / cfg.c_rt_ff;
    let t_out = v_charge * cfg.c_com_ff / cfg.i_com_ua;
    MvmActivity {
        row_windows_ns: vec![t_avg; rows],
        col_charge_nsus: vec![q_col; cols],
        v_charge: vec![v_charge; cols],
        t_out_ns: vec![t_out; cols],
        t_charge_ns: cfg.t_in_max_ns(), // global window ≈ max input
        events: (2 * rows + cols) as u64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::accounting::tops_per_watt;

    #[test]
    fn calibration_hits_papers_peak_efficiency() {
        // The headline: 243.6 TOPS/W at 8-bit inputs (±2 %).
        let cfg = MacroConfig::default();
        let e = mvm_energy(&cfg, &EnergyParams::default(), &nominal_activity(&cfg));
        let tops = tops_per_watt(cfg.ops_per_mvm(), e.total_fj());
        assert!(
            (tops - 243.6).abs() / 243.6 < 0.02,
            "got {tops} TOPS/W, energy {} pJ",
            e.total_pj()
        );
    }

    #[test]
    fn calibration_osg_dominates_at_paper_share() {
        // Fig 6(a): OSG = 72.6 % of the total (±2 points).
        let cfg = MacroConfig::default();
        let e = mvm_energy(&cfg, &EnergyParams::default(), &nominal_activity(&cfg));
        let osg_share = e.shares()[2];
        assert!(
            (osg_share - 0.726).abs() < 0.02,
            "OSG share {osg_share}"
        );
    }

    #[test]
    fn array_energy_is_small_due_to_mohm_cells() {
        // §IV-A: "MRAM devices with high resistance values (MΩ level)
        // ... naturally contribute to improving the overall energy
        // efficiency" — array read must be a ~1 % term.
        let cfg = MacroConfig::default();
        let e = mvm_energy(&cfg, &EnergyParams::default(), &nominal_activity(&cfg));
        assert!(e.shares()[0] < 0.02, "array share {}", e.shares()[0]);
    }

    #[test]
    fn energy_scales_down_with_input_precision() {
        // Event-driven scaling: smaller inputs → shorter windows → less E.
        let cfg = MacroConfig::default();
        let p = EnergyParams::default();
        let mut act4 = nominal_activity(&cfg);
        // 4-bit inputs: windows and charges shrink 16×.
        let s = 15.0 / 255.0;
        for w in &mut act4.row_windows_ns {
            *w *= s;
        }
        for q in &mut act4.col_charge_nsus {
            *q *= s;
        }
        for v in &mut act4.v_charge {
            *v *= s;
        }
        for t in &mut act4.t_out_ns {
            *t *= s;
        }
        act4.t_charge_ns *= s;
        let e8 = mvm_energy(&cfg, &p, &nominal_activity(&cfg)).total_fj();
        let e4 = mvm_energy(&cfg, &p, &act4).total_fj();
        assert!(e4 < 0.5 * e8, "e4 {e4} vs e8 {e8}");
    }

    #[test]
    fn sparse_input_skips_row_energy() {
        // Rows with value 0 must contribute zero SMU energy (event-driven).
        let cfg = MacroConfig::default();
        let p = EnergyParams::default();
        let mut act = nominal_activity(&cfg);
        let full = mvm_energy(&cfg, &p, &act).smu_fj;
        for w in act.row_windows_ns.iter_mut().take(64) {
            *w = 0.0;
        }
        let half = mvm_energy(&cfg, &p, &act).smu_fj;
        assert!((half - full / 2.0).abs() / full < 1e-9);
    }

    #[test]
    fn per_conversion_osg_energy_anchor() {
        // Fig 6(b) anchor: our sensing (OSG) energy per 8-bit conversion
        // ≈ 763 fJ (derivation in DESIGN.md §6).
        let cfg = MacroConfig::default();
        let e = mvm_energy(&cfg, &EnergyParams::default(), &nominal_activity(&cfg));
        let per_conv = e.osg_fj / cfg.cols as f64;
        assert!(
            (per_conv - 763.0).abs() < 40.0,
            "per-conversion OSG {per_conv} fJ"
        );
    }
}

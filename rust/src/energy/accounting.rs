//! Per-operation energy ledger (DESIGN.md S9).
//!
//! Every macro op returns an `EnergyBreakdown`; the coordinator sums them
//! across tiles/batches. Categories follow the paper's Fig 6(a) power
//! breakdown — array read, SMU, OSG, control — plus the chip-level NoC
//! category charged by the fabric subsystem (DESIGN.md S15) and the SOT
//! write/scrub category charged by the reliability runtime (DESIGN.md
//! S19). A single macro op never produces `noc_fj` or `write_fj`; only
//! routed fabric traffic and scrub/reprogram pulses do.

/// Energy per component for one (or many accumulated) macro ops, in fJ.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EnergyBreakdown {
    pub array_fj: f64,
    pub smu_fj: f64,
    pub osg_fj: f64,
    pub control_fj: f64,
    /// Spike-packet NoC traffic (fabric link+router energy, S15).
    pub noc_fj: f64,
    /// SOT programming pulses: scrub rewrites and reprogramming (S19).
    pub write_fj: f64,
}

impl EnergyBreakdown {
    pub fn total_fj(&self) -> f64 {
        self.array_fj + self.smu_fj + self.osg_fj + self.control_fj
            + self.noc_fj
            + self.write_fj
    }

    pub fn total_pj(&self) -> f64 {
        self.total_fj() / 1000.0
    }

    /// Component shares (array, smu, osg, control, noc, write), summing
    /// to 1. The first five indices predate `write_fj` and keep their
    /// positions (fig6/EX consumers index into this array).
    pub fn shares(&self) -> [f64; 6] {
        let t = self.total_fj();
        if t == 0.0 {
            return [0.0; 6];
        }
        [
            self.array_fj / t,
            self.smu_fj / t,
            self.osg_fj / t,
            self.control_fj / t,
            self.noc_fj / t,
            self.write_fj / t,
        ]
    }

    pub fn add(&mut self, other: &EnergyBreakdown) {
        self.array_fj += other.array_fj;
        self.smu_fj += other.smu_fj;
        self.osg_fj += other.osg_fj;
        self.control_fj += other.control_fj;
        self.noc_fj += other.noc_fj;
        self.write_fj += other.write_fj;
    }

    pub fn scaled(&self, f: f64) -> EnergyBreakdown {
        EnergyBreakdown {
            array_fj: self.array_fj * f,
            smu_fj: self.smu_fj * f,
            osg_fj: self.osg_fj * f,
            control_fj: self.control_fj * f,
            noc_fj: self.noc_fj * f,
            write_fj: self.write_fj * f,
        }
    }

    /// Category names, in the [`shares`](Self::shares) index order.
    pub const CATEGORIES: [&'static str; 6] =
        ["array", "smu", "osg", "control", "noc", "write"];

    /// `(name, fJ)` per category, in [`CATEGORIES`](Self::CATEGORIES)
    /// order.
    pub fn named(&self) -> [(&'static str, f64); 6] {
        [
            ("array", self.array_fj),
            ("smu", self.smu_fj),
            ("osg", self.osg_fj),
            ("control", self.control_fj),
            ("noc", self.noc_fj),
            ("write", self.write_fj),
        ]
    }

    /// One category's share of the total by name (DESIGN.md S20) — the
    /// readable replacement for positional `shares()[i]` lookups.
    /// Panics on an unknown name so typos fail loudly.
    pub fn share(&self, name: &str) -> f64 {
        let i = Self::CATEGORIES
            .iter()
            .position(|c| *c == name)
            .unwrap_or_else(|| panic!("unknown energy category {name:?}"));
        self.shares()[i]
    }

    /// Machine-readable ledger with *named* categories (DESIGN.md
    /// S20): per-category fJ and share, plus the total — consumers
    /// read `"osg"` instead of `shares()[2]`.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{self, Json};
        let shares = self.shares();
        let mut fields: Vec<(&str, Json)> = Vec::with_capacity(8);
        let mut share_fields: Vec<(&str, Json)> = Vec::with_capacity(6);
        for (i, (name, fj)) in self.named().into_iter().enumerate() {
            fields.push((name, Json::Num(fj)));
            share_fields.push((name, Json::Num(shares[i])));
        }
        fields.push(("total_fj", Json::Num(self.total_fj())));
        fields.push(("shares", json::obj(share_fields)));
        json::obj(fields)
    }
}

/// TOPS/W for `ops` operations costing `energy_fj` femtojoules.
///
/// ops/fJ = ops/(1e-15 J) ⇒ TOPS/W = ops/J / 1e12 = ops / (fJ · 1e-3).
pub fn tops_per_watt(ops: u64, energy_fj: f64) -> f64 {
    assert!(energy_fj > 0.0);
    ops as f64 / energy_fj * 1000.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shares_sum_to_one() {
        let e = EnergyBreakdown {
            array_fj: 1.0,
            smu_fj: 2.0,
            osg_fj: 5.0,
            control_fj: 2.0,
            ..EnergyBreakdown::default()
        };
        let s = e.shares();
        assert!((s.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((s[2] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn add_and_scale() {
        let mut a = EnergyBreakdown {
            array_fj: 1.0,
            smu_fj: 1.0,
            osg_fj: 1.0,
            control_fj: 1.0,
            ..EnergyBreakdown::default()
        };
        a.add(&a.clone());
        assert_eq!(a.total_fj(), 8.0);
        assert_eq!(a.scaled(0.5).total_fj(), 4.0);
    }

    #[test]
    fn noc_category_counts_toward_total_and_shares() {
        let e = EnergyBreakdown {
            noc_fj: 3.0,
            control_fj: 1.0,
            ..EnergyBreakdown::default()
        };
        assert_eq!(e.total_fj(), 4.0);
        let s = e.shares();
        assert!((s[4] - 0.75).abs() < 1e-12);
        assert!((s.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn write_category_counts_toward_total_and_shares() {
        // Scrub energy (S19) must be visible in the ledger: it moves
        // the total and takes the sixth share slot without disturbing
        // the five original indices.
        let e = EnergyBreakdown {
            array_fj: 1.0,
            write_fj: 3.0,
            ..EnergyBreakdown::default()
        };
        assert_eq!(e.total_fj(), 4.0);
        let s = e.shares();
        assert!((s[0] - 0.25).abs() < 1e-12);
        assert!((s[5] - 0.75).abs() < 1e-12);
        assert!((s.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn named_json_matches_positional_shares() {
        use crate::util::json::{self, Json};
        let e = EnergyBreakdown {
            array_fj: 1.0,
            smu_fj: 2.0,
            osg_fj: 4.0,
            control_fj: 1.0,
            noc_fj: 1.0,
            write_fj: 1.0,
        };
        // The named API and the positional array agree category by
        // category…
        for (i, name) in EnergyBreakdown::CATEGORIES.iter().enumerate() {
            assert_eq!(e.share(name), e.shares()[i], "{name}");
            assert_eq!(e.named()[i].0, *name);
        }
        // …and the JSON round-trips through the vendored parser with
        // every category readable by name.
        let back = json::parse(&e.to_json().to_string()).expect("parse");
        assert_eq!(back.get("total_fj").and_then(Json::as_f64), Some(10.0));
        assert_eq!(back.get("osg").and_then(Json::as_f64), Some(4.0));
        assert_eq!(
            back.get("shares")
                .and_then(|s| s.get("osg"))
                .and_then(Json::as_f64),
            Some(0.4)
        );
    }

    #[test]
    #[should_panic(expected = "unknown energy category")]
    fn share_rejects_unknown_category() {
        EnergyBreakdown::default().share("adc");
    }

    #[test]
    fn tops_per_watt_reference_point() {
        // 32768 OPs at 134.5 pJ ≈ 243.6 TOPS/W (the paper's headline).
        let t = tops_per_watt(32768, 134_500.0);
        assert!((t - 243.6).abs() < 1.0, "{t}");
    }

    #[test]
    fn tops_per_watt_unit_sanity() {
        // 1 OP per fJ = 1000 TOPS/W.
        assert!((tops_per_watt(1, 1.0) - 1000.0).abs() < 1e-9);
    }
}

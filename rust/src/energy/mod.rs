//! Energy/power accounting (DESIGN.md S9): the calibrated component model
//! and the TOPS/W arithmetic behind Table II and Fig 6.

pub mod accounting;
pub mod model;

pub use accounting::{tops_per_watt, EnergyBreakdown};
pub use model::{
    mvm_energy, nominal_activity, ActivityView, EnergyParams, MvmActivity,
};

//! Layer pipelining (DESIGN.md S11 extension): run a multi-layer model as
//! a pipeline of stages, each owning its macros, connected by channels —
//! batch i+1's layer-1 work overlaps batch i's layer-2 work, exactly how
//! a multi-macro chip would stream inferences.
//!
//! Two views:
//! * [`pipeline_makespan_ns`] — the analytic virtual-time model
//!   (makespan = Σlat + (n−1)·max lat) used by tests and the scheduler;
//! * [`ThreadedPipeline`] — a real thread-per-stage implementation whose
//!   results must match the serial execution bit-for-bit.

use std::sync::mpsc;
use std::thread::JoinHandle;

/// Analytic pipeline makespan for `n` items over stages with the given
/// per-item latencies (ns): fill + drain around the bottleneck stage.
pub fn pipeline_makespan_ns(stage_lat_ns: &[f64], n: usize) -> f64 {
    if n == 0 || stage_lat_ns.is_empty() {
        return 0.0;
    }
    let sum: f64 = stage_lat_ns.iter().sum();
    let max = stage_lat_ns.iter().cloned().fold(0.0, f64::max);
    sum + (n as f64 - 1.0) * max
}

/// Serial makespan for comparison.
pub fn serial_makespan_ns(stage_lat_ns: &[f64], n: usize) -> f64 {
    stage_lat_ns.iter().sum::<f64>() * n as f64
}

/// A pipeline stage: transforms an item (owned, Send).
pub type StageFn<T> = Box<dyn FnMut(T) -> T + Send>;

/// Thread-per-stage pipeline over items of type `T`.
pub struct ThreadedPipeline<T: Send + 'static> {
    input: Option<mpsc::Sender<(usize, T)>>,
    output: mpsc::Receiver<(usize, T)>,
    handles: Vec<JoinHandle<()>>,
}

impl<T: Send + 'static> ThreadedPipeline<T> {
    pub fn new(stages: Vec<StageFn<T>>) -> Self {
        assert!(!stages.is_empty());
        let (first_tx, mut prev_rx) = mpsc::channel::<(usize, T)>();
        let mut handles = Vec::new();
        let n = stages.len();
        let mut out_rx_final = None;
        for (i, mut stage) in stages.into_iter().enumerate() {
            let (tx, rx) = mpsc::channel::<(usize, T)>();
            let rx_in = prev_rx;
            handles.push(std::thread::spawn(move || {
                while let Ok((id, item)) = rx_in.recv() {
                    let _ = tx.send((id, stage(item)));
                }
            }));
            if i + 1 == n {
                out_rx_final = Some(rx);
                // prev_rx moved; create a dummy to satisfy the loop var.
                let (_t, dummy) = mpsc::channel();
                prev_rx = dummy;
            } else {
                prev_rx = rx;
            }
        }
        ThreadedPipeline {
            input: Some(first_tx),
            output: out_rx_final.unwrap(),
            handles,
        }
    }

    /// Stream `items` through; returns outputs in input order.
    pub fn run(mut self, items: Vec<T>) -> Vec<T> {
        let n = items.len();
        let tx = self.input.take().unwrap();
        let feeder = std::thread::spawn(move || {
            for (i, item) in items.into_iter().enumerate() {
                if tx.send((i, item)).is_err() {
                    return;
                }
            }
            // Drop tx: signals end-of-stream down the pipeline.
        });
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (id, item) = self.output.recv().expect("pipeline output");
            out[id] = Some(item);
        }
        feeder.join().unwrap();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        out.into_iter().map(|o| o.unwrap()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analytic_pipeline_beats_serial() {
        let lats = [100.0, 250.0, 80.0];
        let n = 64;
        let pipe = pipeline_makespan_ns(&lats, n);
        let serial = serial_makespan_ns(&lats, n);
        assert!(pipe < serial);
        // Asymptotic rate = bottleneck stage.
        let rate = n as f64 / pipe;
        assert!((rate - 1.0 / 250.0).abs() / (1.0 / 250.0) < 0.05);
    }

    #[test]
    fn analytic_single_item_equals_serial() {
        let lats = [10.0, 20.0];
        assert_eq!(
            pipeline_makespan_ns(&lats, 1),
            serial_makespan_ns(&lats, 1)
        );
        assert_eq!(pipeline_makespan_ns(&lats, 0), 0.0);
    }

    #[test]
    fn threaded_pipeline_preserves_order_and_values() {
        let stages: Vec<StageFn<u64>> = vec![
            Box::new(|x| x + 1),
            Box::new(|x| x * 3),
            Box::new(|x| x - 2),
        ];
        let p = ThreadedPipeline::new(stages);
        let out = p.run((0..100).collect());
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, (i as u64 + 1) * 3 - 2);
        }
    }

    #[test]
    fn threaded_pipeline_on_macro_layers_matches_serial() {
        // Three real macro stages (one 128×128 MVM each, thresholded back
        // to 8-bit) — the pipeline must be bit-identical to serial.
        use crate::config::MacroConfig;
        use crate::macro_model::CimMacro;
        use crate::util::rng::Rng;

        let cfg = MacroConfig::default();
        let mut rng = Rng::new(808);
        let mk_codes = |rng: &mut Rng| -> Vec<u8> {
            (0..cfg.rows * cfg.cols).map(|_| rng.below(4) as u8).collect()
        };
        let codes: Vec<Vec<u8>> =
            (0..3).map(|_| mk_codes(&mut rng)).collect();

        let requant = |y: Vec<f64>| -> Vec<u32> {
            y.into_iter()
                .map(|v| ((v / 40.0).round().max(0.0) as u32).min(255))
                .collect()
        };

        // Serial reference.
        let mut serial_macros: Vec<CimMacro> = codes
            .iter()
            .map(|c| {
                let mut m = CimMacro::new(cfg.clone());
                m.program(c);
                m
            })
            .collect();
        let inputs: Vec<Vec<u32>> = (0..12)
            .map(|_| (0..cfg.rows).map(|_| rng.below(256) as u32).collect())
            .collect();
        let serial_out: Vec<Vec<u32>> = inputs
            .iter()
            .map(|x| {
                let mut v = x.clone();
                for m in serial_macros.iter_mut() {
                    v = requant(m.mvm(&v).y_mac);
                }
                v
            })
            .collect();

        // Pipelined.
        let stages: Vec<StageFn<Vec<u32>>> = codes
            .iter()
            .map(|c| {
                let mut m = CimMacro::new(cfg.clone());
                m.program(c);
                let f: StageFn<Vec<u32>> =
                    Box::new(move |x: Vec<u32>| requant(m.mvm(&x).y_mac));
                f
            })
            .collect();
        let pipe_out = ThreadedPipeline::new(stages).run(inputs);
        assert_eq!(pipe_out, serial_out);
    }
}

//! Serving supervision control plane (DESIGN.md S21): worker lifecycle
//! policy for the stream server — restart budgets with exponential
//! backoff, explicit admission-control outcomes, deterministic chaos
//! injection for the soak tests, and the supervisor control loop that
//! workers report panics to over a status channel.
//!
//! The split follows the async-control-plane / blocking-compute-plane
//! idiom (SNIPPETS.md snippet 1): compute workers never make lifecycle
//! decisions themselves. A worker that catches a panic mid-frame sends
//! one [`StatusMsg`] carrying a one-shot reply channel and *blocks* on
//! the [`Verdict`] — restart (after a policy-chosen backoff) or degrade
//! (stop serving frames, keep draining session state). All policy state
//! (per-worker attempt counts, the degraded set) lives in the single
//! supervisor thread, so there is no shared-mutable lifecycle state and
//! no new lock-order edge (DESIGN.md §S21 lock order).
//!
//! Everything here is serving-substrate: [`StreamServer`]
//! (`stream::serve`) owns the wiring, this module owns the decisions.
//!
//! [`StreamServer`]: crate::stream::StreamServer

use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::coordinator::Metrics;
use crate::util::rng::Rng;

/// Restart budget + backoff policy for one serving backend.
#[derive(Debug, Clone, Copy)]
pub struct RestartPolicy {
    /// Restarts allowed *per worker* before it degrades permanently.
    pub max_restarts: u32,
    /// Backoff before the first restart; doubles per attempt.
    pub backoff: Duration,
    /// Cap on the exponential backoff.
    pub backoff_max: Duration,
}

impl RestartPolicy {
    /// Defaults tuned for a simulated backend: short backoffs (the
    /// "die swap" is a rebuild, not a reboot), a small budget.
    pub fn standard() -> Self {
        RestartPolicy {
            max_restarts: 3,
            backoff: Duration::from_millis(1),
            backoff_max: Duration::from_millis(50),
        }
    }

    /// Backoff before restart attempt `attempt` (1-based):
    /// `backoff · 2^(attempt−1)`, capped at `backoff_max`.
    pub fn backoff_for(&self, attempt: u32) -> Duration {
        let shift = attempt.saturating_sub(1).min(20);
        self.backoff
            .saturating_mul(1u32 << shift)
            .min(self.backoff_max)
    }
}

impl Default for RestartPolicy {
    fn default() -> Self {
        Self::standard()
    }
}

/// Admission-control outcome of an enqueue attempt: the request is in
/// the queue, or it was shed with a load-derived retry hint. Callers
/// must handle `Shed` — an overloaded server refuses work instead of
/// queueing without bound.
#[derive(Debug)]
pub enum Admission<T> {
    /// Enqueued; `T` is the reply handle.
    Accepted(T),
    /// Refused (queue at capacity, or admissions stopped for drain).
    Shed {
        /// Rough time until a slot frees up: queue depth × the
        /// server's EWMA per-frame service time.
        retry_after: Duration,
    },
}

impl<T> Admission<T> {
    /// The reply handle, if admitted.
    pub fn accepted(self) -> Option<T> {
        match self {
            Admission::Accepted(t) => Some(t),
            Admission::Shed { .. } => None,
        }
    }

    /// Was the request shed?
    pub fn is_shed(&self) -> bool {
        matches!(self, Admission::Shed { .. })
    }

    /// Unwrap, panicking on `Shed` — for callers (tests, the legacy
    /// blocking API) that sized the queue so shedding cannot happen.
    pub fn expect_accepted(self) -> T {
        match self {
            Admission::Accepted(t) => t,
            Admission::Shed { retry_after } => panic!(
                "admission shed (retry_after {retry_after:?}) — \
                 queue capacity too small for this workload"
            ),
        }
    }
}

/// Why a queued frame was shed at dequeue instead of served. (Queue-cap
/// sheds never reach a worker — the caller gets [`Admission::Shed`] at
/// submit time.)
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// The frame's deadline had already expired when the worker
    /// dequeued it: stale work is dropped, not computed.
    DeadlineExpired,
    /// The server's drain deadline passed with the frame still queued.
    Draining,
    /// The worker exhausted its restart budget and is degraded — it
    /// only drains session state, it no longer computes frames.
    RestartBudget,
}

impl ShedReason {
    /// Stable wire-protocol name (DESIGN.md S23). The network front
    /// end sends these in shed responses; clients match on them, so
    /// they are a compatibility surface — never rename.
    pub fn wire_name(&self) -> &'static str {
        match self {
            ShedReason::DeadlineExpired => "deadline",
            ShedReason::Draining => "draining",
            ShedReason::RestartBudget => "restart_budget",
        }
    }

    /// Inverse of [`wire_name`](Self::wire_name); `None` for unknown
    /// names (e.g. the admission-level `"queue_full"`, which has no
    /// dequeue-side variant by design).
    pub fn from_wire_name(name: &str) -> Option<ShedReason> {
        match name {
            "deadline" => Some(ShedReason::DeadlineExpired),
            "draining" => Some(ShedReason::Draining),
            "restart_budget" => Some(ShedReason::RestartBudget),
            _ => None,
        }
    }
}

/// Deterministic fault injection for the chaos tests: makes a worker
/// panic mid-frame. Two modes:
///
/// * `every` ≥ 2 — fire on every `every`-th frame *attempt* a worker
///   makes (deterministic; a retry increments the attempt counter, so
///   a retried frame can never re-fire and the soak converges);
/// * otherwise — fire i.i.d. with probability `rate` per attempt from
///   a per-worker seeded stream (the 1 %-of-frames soak).
#[derive(Debug, Clone, Copy)]
pub struct ChaosPlan {
    /// Per-attempt panic probability (used when `every == 0`).
    pub rate: f64,
    /// Deterministic mode: fire on attempts `every, 2·every, …`.
    pub every: u64,
    /// Seed for the per-worker draw streams (rate mode).
    pub seed: u64,
}

impl ChaosPlan {
    /// Deterministic mode; `n >= 2` so a retried frame cannot re-fire.
    pub fn every(n: u64) -> ChaosPlan {
        assert!(n >= 2, "every-mode needs n >= 2 so retries converge");
        ChaosPlan {
            rate: 0.0,
            every: n,
            seed: 0,
        }
    }

    /// Probabilistic mode: each attempt fires with `rate`.
    pub fn rate(rate: f64, seed: u64) -> ChaosPlan {
        assert!((0.0..=1.0).contains(&rate), "rate in [0, 1]");
        ChaosPlan {
            rate,
            every: 0,
            seed,
        }
    }

    /// The draw stream for worker `w` (rate mode; unused in every-mode).
    pub fn rng_for(&self, worker: usize) -> Rng {
        Rng::new(
            self.seed ^ (worker as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15),
        )
    }

    /// Does frame attempt `count` (1-based, per worker) fire?
    pub fn fires(&self, count: u64, rng: &mut Rng) -> bool {
        if self.every > 0 {
            count % self.every == 0
        } else {
            self.rate > 0.0 && rng.f64() < self.rate
        }
    }
}

/// Worker → supervisor: "I caught a panic serving a frame" — or, with
/// `wear_out` set, "my die crossed its wear ceiling" (DESIGN.md S22).
/// The one-shot verdict channel rides in the message, so the supervisor
/// needs no per-worker reply plumbing.
pub struct StatusMsg {
    pub worker: usize,
    /// Wear-SLO report: the die is spent, not the process. Restarting
    /// cannot help (the physical array is the same), so the verdict is
    /// an immediate [`Verdict::Degrade`] regardless of remaining
    /// restart budget.
    pub wear_out: bool,
    pub reply: mpsc::Sender<Verdict>,
}

/// Supervisor → worker decision after a panic report.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// Rebuild the replica and continue; sleep `backoff` first.
    Restart { attempt: u32, backoff: Duration },
    /// Budget exhausted: degrade — stop computing frames, keep
    /// draining session state (Finish still works).
    Degrade,
}

/// The supervisor control loop: one thread owning all lifecycle state.
/// Exits when every worker's status sender is dropped (server
/// shutdown), which is when [`Supervisor::join`] returns.
pub struct Supervisor {
    handle: Option<JoinHandle<()>>,
}

impl Supervisor {
    /// Start the loop for `workers` replicas. Returns the supervisor
    /// handle and the status sender to clone into each worker.
    pub fn start(
        workers: usize,
        policy: RestartPolicy,
        metrics: Arc<Metrics>,
    ) -> (Supervisor, mpsc::Sender<StatusMsg>) {
        let (tx, rx) = mpsc::channel::<StatusMsg>();
        let handle = std::thread::Builder::new()
            .name("spikemram-supervisor".to_string())
            .spawn(move || {
                let mut attempts = vec![0u32; workers];
                let mut degraded = vec![false; workers];
                while let Ok(StatusMsg {
                    worker,
                    wear_out,
                    reply,
                }) = rx.recv()
                {
                    let verdict = if !wear_out
                        && worker < workers
                        && attempts[worker] < policy.max_restarts
                    {
                        attempts[worker] += 1;
                        Verdict::Restart {
                            attempt: attempts[worker],
                            backoff: policy.backoff_for(attempts[worker]),
                        }
                    } else {
                        if worker < workers && !degraded[worker] {
                            degraded[worker] = true;
                            let n = degraded.iter().filter(|&&d| d).count();
                            metrics.set_degraded_workers(n as u64);
                        }
                        Verdict::Degrade
                    };
                    // A worker that died between send and verdict just
                    // leaves a closed reply channel — not our problem.
                    let _ = reply.send(verdict);
                }
            })
            .expect("spawn supervisor");
        (
            Supervisor {
                handle: Some(handle),
            },
            tx,
        )
    }

    /// Wait for the loop to exit (all status senders dropped first, or
    /// this blocks forever).
    pub fn join(mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Supervisor {
    fn drop(&mut self) {
        // Detach rather than join: the loop exits on its own once the
        // last status sender drops, and Drop must never deadlock.
        let _ = self.handle.take();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_doubles_and_caps() {
        let p = RestartPolicy {
            max_restarts: 10,
            backoff: Duration::from_millis(2),
            backoff_max: Duration::from_millis(10),
        };
        assert_eq!(p.backoff_for(1), Duration::from_millis(2));
        assert_eq!(p.backoff_for(2), Duration::from_millis(4));
        assert_eq!(p.backoff_for(3), Duration::from_millis(8));
        assert_eq!(p.backoff_for(4), Duration::from_millis(10), "capped");
        assert_eq!(p.backoff_for(40), Duration::from_millis(10));
        // attempt 0 behaves like attempt 1 (no underflow).
        assert_eq!(p.backoff_for(0), Duration::from_millis(2));
    }

    #[test]
    fn shed_reason_wire_names_round_trip() {
        for r in [
            ShedReason::DeadlineExpired,
            ShedReason::Draining,
            ShedReason::RestartBudget,
        ] {
            assert_eq!(ShedReason::from_wire_name(r.wire_name()), Some(r));
        }
        // Admission-level refusals use "queue_full" on the wire but
        // have no dequeue-side variant to map back to.
        assert_eq!(ShedReason::from_wire_name("queue_full"), None);
        assert_eq!(ShedReason::from_wire_name("bogus"), None);
    }

    #[test]
    fn admission_accessors() {
        let a: Admission<u32> = Admission::Accepted(7);
        assert!(!a.is_shed());
        assert_eq!(a.accepted(), Some(7));
        let s: Admission<u32> = Admission::Shed {
            retry_after: Duration::from_millis(3),
        };
        assert!(s.is_shed());
        assert!(s.accepted().is_none());
    }

    #[test]
    #[should_panic(expected = "admission shed")]
    fn expect_accepted_panics_on_shed() {
        let s: Admission<u32> = Admission::Shed {
            retry_after: Duration::from_millis(1),
        };
        let _ = s.expect_accepted();
    }

    #[test]
    fn chaos_every_mode_is_deterministic_and_retry_safe() {
        let plan = ChaosPlan::every(5);
        let mut rng = plan.rng_for(0);
        let fired: Vec<u64> = (1..=20)
            .filter(|&c| plan.fires(c, &mut rng))
            .collect();
        assert_eq!(fired, vec![5, 10, 15, 20]);
        // The attempt after a firing one never fires (retry safety).
        for &c in &fired {
            assert!(!plan.fires(c + 1, &mut rng));
        }
    }

    #[test]
    fn chaos_rate_mode_fires_at_roughly_the_rate() {
        let plan = ChaosPlan::rate(0.25, 99);
        let mut rng = plan.rng_for(1);
        let n = 4000;
        let fired = (1..=n).filter(|&c| plan.fires(c, &mut rng)).count();
        let frac = fired as f64 / n as f64;
        assert!((0.15..0.35).contains(&frac), "fired {frac}");
        // rate 0 never fires.
        let never = ChaosPlan::rate(0.0, 1);
        let mut r2 = never.rng_for(0);
        assert!((1..=100).all(|c| !never.fires(c, &mut r2)));
    }

    #[test]
    #[should_panic(expected = "retries converge")]
    fn chaos_every_rejects_one() {
        let _ = ChaosPlan::every(1);
    }

    #[test]
    fn supervisor_grants_budget_then_degrades() {
        let metrics = Arc::new(Metrics::new());
        let policy = RestartPolicy {
            max_restarts: 2,
            backoff: Duration::from_millis(1),
            backoff_max: Duration::from_millis(4),
        };
        let (sup, tx) = Supervisor::start(2, policy, metrics.clone());
        let ask = |w: usize| -> Verdict {
            let (rtx, rrx) = mpsc::channel();
            tx.send(StatusMsg {
                worker: w,
                wear_out: false,
                reply: rtx,
            })
            .unwrap();
            rrx.recv().unwrap()
        };
        assert_eq!(
            ask(0),
            Verdict::Restart {
                attempt: 1,
                backoff: Duration::from_millis(1)
            }
        );
        assert_eq!(
            ask(0),
            Verdict::Restart {
                attempt: 2,
                backoff: Duration::from_millis(2)
            }
        );
        assert_eq!(ask(0), Verdict::Degrade);
        assert_eq!(metrics.snapshot().degraded_workers, 1);
        // Worker 1 has its own budget.
        assert!(matches!(ask(1), Verdict::Restart { attempt: 1, .. }));
        // Degrading again does not double-count.
        assert_eq!(ask(0), Verdict::Degrade);
        assert_eq!(metrics.snapshot().degraded_workers, 1);
        drop(tx);
        sup.join();
    }

    #[test]
    fn wear_out_degrades_immediately_despite_restart_budget() {
        let metrics = Arc::new(Metrics::new());
        let (sup, tx) =
            Supervisor::start(2, RestartPolicy::standard(), metrics.clone());
        let ask = |w: usize, wear_out: bool| -> Verdict {
            let (rtx, rrx) = mpsc::channel();
            tx.send(StatusMsg {
                worker: w,
                wear_out,
                reply: rtx,
            })
            .unwrap();
            rrx.recv().unwrap()
        };
        // Fresh worker, full budget — but the die is spent: no restart
        // can help, the verdict is Degrade on the first report.
        assert_eq!(ask(0, true), Verdict::Degrade);
        assert_eq!(metrics.snapshot().degraded_workers, 1);
        // The other worker's panic path is unaffected.
        assert!(matches!(ask(1, false), Verdict::Restart { attempt: 1, .. }));
        drop(tx);
        sup.join();
    }
}

//! Event-driven serving loop (DESIGN.md S11).
//!
//! Thread + channel architecture (tokio is unavailable offline; the
//! blocking-worker design matches the macro's event-driven nature — a
//! worker sleeps until a request *event* arrives, exactly like the array
//! idles until a spike):
//!
//! ```text
//!   submit() ──mpsc──▶ shared queue ──▶ N worker threads
//!                                        ├─ batcher (size/timeout)
//!                                        ├─ backend: Sim (CimMacro)
//!                                        │        or Pjrt (HLO artifact)
//!                                        │        or Fabric (NoC mesh)
//!                                        └─ per-request oneshot reply
//! ```
//!
//! The `Fabric` backend (DESIGN.md S15) serves weight matrices *larger
//! than one macro*: the k×n codes are sharded onto a mesh of tiles and
//! every request is executed as routed spike packets, with hop counts
//! and tile utilization reported through [`Metrics`].

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::{FabricConfig, MacroConfig, MvmEngine};
use crate::coordinator::TiledMatrix;
use crate::fabric::FabricChip;
use crate::macro_model::{CimMacro, MvmBatch};
use crate::runtime::{Runtime, Value};

use super::metrics::Metrics;

/// Which compute backend workers use.
#[derive(Debug, Clone)]
pub enum BackendKind {
    /// Full behavioral macro simulation (bit-true, energy-accounted).
    Sim,
    /// AOT HLO artifact via PJRT (functional fast path).
    Pjrt { artifacts_dir: String },
    /// Multi-macro fabric chip (DESIGN.md S15): the k×n code matrix is
    /// sharded onto a NoC mesh; requests take `k` inputs, replies carry
    /// `n` MACs.
    Fabric {
        fabric: FabricConfig,
        k: usize,
        n: usize,
    },
}

impl BackendKind {
    /// (input length, output length) served by this backend.
    fn dims(&self, cfg: &MacroConfig) -> (usize, usize) {
        match self {
            BackendKind::Fabric { k, n, .. } => (*k, *n),
            _ => (cfg.rows, cfg.cols),
        }
    }
}

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub workers: usize,
    pub max_batch: usize,
    pub batch_timeout: Duration,
    pub backend: BackendKind,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            workers: 2,
            max_batch: 8,
            batch_timeout: Duration::from_micros(200),
            backend: BackendKind::Sim,
        }
    }
}

struct Job {
    x: Vec<u32>,
    submitted: Instant,
    reply: mpsc::Sender<Vec<f64>>,
}

/// A running macro service for one programmed weight matrix (one macro
/// tile for `Sim`/`Pjrt`; an arbitrary k×n matrix for `Fabric`).
pub struct MacroServer {
    tx: Option<mpsc::Sender<Job>>,
    pub metrics: Arc<Metrics>,
    handles: Vec<JoinHandle<()>>,
    in_dim: usize,
}

impl MacroServer {
    /// Start worker threads for the weight matrix given as codes
    /// (128×128 for `Sim`/`Pjrt`; k×n for the `Fabric` backend).
    pub fn start(
        cfg: MacroConfig,
        codes: Vec<u8>,
        server_cfg: ServerConfig,
    ) -> Result<MacroServer> {
        let (in_dim, out_dim) = server_cfg.backend.dims(&cfg);
        assert_eq!(codes.len(), in_dim * out_dim, "code matrix shape");
        if let BackendKind::Fabric { fabric, k, n } = &server_cfg.backend {
            // Fail fast with the chip's own validation (no macro cells
            // programmed); worker-side construction errors would only
            // surface as thread panics after start() returned Ok. The
            // shape mirrors TiledMatrix::new's row/col_tiles derivation.
            FabricChip::validate(
                &cfg,
                fabric,
                &[(k.div_ceil(cfg.rows), n.div_ceil(cfg.rows))],
            )?;
        }
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let metrics = Arc::new(Metrics::new());
        let mut handles = Vec::new();
        for wid in 0..server_cfg.workers {
            let rx = rx.clone();
            let metrics = metrics.clone();
            let cfg = cfg.clone();
            let codes = codes.clone();
            let scfg = server_cfg.clone();
            handles.push(std::thread::spawn(move || {
                worker_loop(wid, cfg, codes, scfg, rx, metrics);
            }));
        }
        Ok(MacroServer {
            tx: Some(tx),
            metrics,
            handles,
            in_dim,
        })
    }

    /// Input dimension the server was programmed for. The wire front
    /// end (DESIGN.md S23) validates remote `Infer` vectors against it
    /// before calling [`submit`](Self::submit), whose length assertion
    /// is for in-process caller bugs.
    pub fn in_dim(&self) -> usize {
        self.in_dim
    }

    /// Submit one input vector; returns a receiver for the MAC result.
    pub fn submit(&self, x: Vec<u32>) -> mpsc::Receiver<Vec<f64>> {
        assert_eq!(x.len(), self.in_dim, "input length");
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .as_ref()
            .expect("server running")
            .send(Job {
                x,
                submitted: Instant::now(),
                reply: reply_tx,
            })
            .expect("workers alive");
        reply_rx
    }

    /// Convenience: submit and wait.
    pub fn call(&self, x: Vec<u32>) -> Vec<f64> {
        self.submit(x).recv().expect("reply")
    }

    /// Stop accepting work and join the workers.
    pub fn shutdown(mut self) {
        drop(self.tx.take()); // closes the channel; workers drain & exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

enum WorkerBackend {
    /// The behavioral macro plus a reusable batch ledger: each collected
    /// batch executes as ONE `mvm_batch_into` call (DESIGN.md S16) — the
    /// size-or-timeout batcher buys weight-stationary compute
    /// amortization, not just queueing — and the ledger keeps the steady
    /// state allocation-free.
    Sim {
        m: Box<CimMacro>,
        ledger: MvmBatch,
    },
    /// One fabric chip per worker (weight-stationary, like `Sim`'s
    /// per-worker macro). NoC counters drain to `Metrics` per batch.
    Fabric(Box<FabricChip>),
    Pjrt {
        exe: std::sync::Arc<crate::runtime::Executable>,
        codes_i32: Vec<i32>,
        batch: usize,
        rows: usize,
        cols: usize,
        alpha: f64,
        t_bit: f64,
        // keep the runtime alive for the executable's lifetime
        _rt: Runtime,
    },
}

impl WorkerBackend {
    fn create(cfg: &MacroConfig, codes: &[u8], kind: &BackendKind) -> WorkerBackend {
        match kind {
            BackendKind::Sim => {
                let mut m = CimMacro::new(cfg.clone());
                m.program(codes);
                WorkerBackend::Sim {
                    m: Box::new(m),
                    ledger: MvmBatch::default(),
                }
            }
            BackendKind::Fabric { fabric, k, n } => {
                let tiled = TiledMatrix::new(codes, *k, *n, cfg.rows);
                let chip =
                    FabricChip::new(cfg, fabric.clone(), vec![tiled])
                        .expect("fabric placement");
                WorkerBackend::Fabric(Box::new(chip))
            }
            BackendKind::Pjrt { artifacts_dir } => {
                let mut rt = Runtime::new(artifacts_dir).expect("pjrt client");
                let exe = rt
                    .load("spiking_mvm_b8_128x128")
                    .expect("artifact spiking_mvm_b8_128x128");
                WorkerBackend::Pjrt {
                    exe,
                    codes_i32: codes.iter().map(|&c| c as i32).collect(),
                    batch: 8,
                    rows: cfg.rows,
                    cols: cfg.cols,
                    alpha: cfg.alpha(),
                    t_bit: cfg.t_bit_ns,
                    _rt: rt,
                }
            }
        }
    }

    /// Compute MACs for a collected batch — the inputs arrive as ONE
    /// flat `[n × in_dim]` buffer (DESIGN.md S17: the worker reuses it
    /// across batches, no `Vec<Vec<u32>>` per collection) and execute
    /// as one batched engine call, bit-identical to per-job serial
    /// execution.
    fn mvm_batch_strided(
        &mut self,
        xs: &[u32],
        in_dim: usize,
        n: usize,
    ) -> Vec<Vec<f64>> {
        debug_assert_eq!(xs.len(), n * in_dim);
        match self {
            WorkerBackend::Sim { m, ledger } => {
                m.mvm_batch_strided_into(xs, in_dim, ledger);
                (0..n).map(|b| ledger.y_mac(b).to_vec()).collect()
            }
            WorkerBackend::Fabric(chip) => chip
                .mvm_batch_strided(xs, in_dim)
                .into_iter()
                .map(|(y, _)| y)
                .collect(),
            WorkerBackend::Pjrt {
                exe,
                codes_i32,
                batch,
                rows,
                cols,
                alpha,
                t_bit,
                ..
            } => {
                let mut out = Vec::with_capacity(n);
                for lo in (0..n).step_by(*batch) {
                    let hi = (lo + *batch).min(n);
                    // Encode + pad the chunk to the artifact's batch shape.
                    let mut t_in = vec![0.0f32; *batch * *rows];
                    for (b, item) in (lo..hi).enumerate() {
                        let x = &xs[item * in_dim..(item + 1) * in_dim];
                        for (r, &v) in x.iter().enumerate() {
                            t_in[b * *rows + r] = v as f32 * *t_bit as f32;
                        }
                    }
                    let args = [
                        Value::f32(t_in, &[*batch, *rows]),
                        Value::i32(codes_i32.clone(), &[*rows, *cols]),
                    ];
                    let outputs = exe.run_f32(&args).expect("pjrt execute");
                    let t_out = &outputs[0];
                    let scale = 1.0 / (*alpha * *t_bit);
                    for b in 0..hi - lo {
                        out.push(
                            t_out[b * *cols..(b + 1) * *cols]
                                .iter()
                                .map(|&t| t as f64 * scale)
                                .collect(),
                        );
                    }
                }
                out
            }
        }
    }
}

fn worker_loop(
    _wid: usize,
    cfg: MacroConfig,
    codes: Vec<u8>,
    scfg: ServerConfig,
    rx: Arc<Mutex<mpsc::Receiver<Job>>>,
    metrics: Arc<Metrics>,
) {
    let mut backend = WorkerBackend::create(&cfg, &codes, &scfg.backend);
    let (in_dim, out_dim) = scfg.backend.dims(&cfg);
    let macs_per_op = (in_dim * out_dim) as u64;
    if let WorkerBackend::Fabric(chip) = &backend {
        metrics.set_tile_usage(
            chip.tiles_used() as u64,
            chip.tiles_total() as u64,
        );
    }
    // Reusable flat input buffer (DESIGN.md S17): each collected batch
    // is concatenated here and executed strided — no per-batch
    // `Vec<Vec<u32>>`.
    let mut xflat: Vec<u32> = Vec::new();
    loop {
        // Collect a batch: block for the first job, then fill until the
        // batch is full or the timeout elapses.
        let mut jobs: Vec<Job> = Vec::with_capacity(scfg.max_batch);
        {
            let guard = rx.lock().unwrap();
            match guard.recv() {
                Ok(j) => jobs.push(j),
                Err(_) => return, // channel closed: shut down
            }
            let deadline = Instant::now() + scfg.batch_timeout;
            while jobs.len() < scfg.max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match guard.recv_timeout(deadline - now) {
                    Ok(j) => jobs.push(j),
                    Err(mpsc::RecvTimeoutError::Timeout) => break,
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
        } // release the lock before computing

        xflat.clear();
        for j in &jobs {
            xflat.extend_from_slice(&j.x);
        }
        let results = backend.mvm_batch_strided(&xflat, in_dim, jobs.len());
        metrics.record_batch(jobs.len(), macs_per_op * jobs.len() as u64);
        // Event-driven occupancy of the served traffic (S17): count the
        // input rows that actually carried spikes, backend-independent.
        let active = xflat.iter().filter(|&&v| v > 0).count() as u64;
        metrics.record_activity(active, xflat.len() as u64);
        if let WorkerBackend::Fabric(chip) = &mut backend {
            // Drain before replying so a caller who awaits the reply
            // already sees this batch's traffic in the snapshot.
            let t = chip.drain_stats();
            metrics.record_noc(t.packets, t.hops);
        }
        for (job, y) in jobs.into_iter().zip(results) {
            let lat_us = job.submitted.elapsed().as_secs_f64() * 1e6;
            metrics.record_request(lat_us);
            let _ = job.reply.send(y); // receiver may have gone away
        }
    }
}

/// Multi-model router: name → running server (DESIGN.md S11 "router").
pub struct Router {
    services: std::collections::BTreeMap<String, MacroServer>,
}

impl Default for Router {
    fn default() -> Self {
        Self::new()
    }
}

impl Router {
    pub fn new() -> Self {
        Router {
            services: Default::default(),
        }
    }

    pub fn register(&mut self, name: impl Into<String>, server: MacroServer) {
        self.services.insert(name.into(), server);
    }

    pub fn call(&self, name: &str, x: Vec<u32>) -> Option<Vec<f64>> {
        self.services.get(name).map(|s| s.call(x))
    }

    pub fn names(&self) -> Vec<&str> {
        self.services.keys().map(|s| s.as_str()).collect()
    }

    pub fn shutdown(self) {
        for (_, s) in self.services {
            s.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn codes(seed: u64) -> Vec<u8> {
        let mut rng = Rng::new(seed);
        (0..128 * 128).map(|_| rng.below(4) as u8).collect()
    }

    #[test]
    fn sim_server_matches_oracle() {
        let cfg = MacroConfig::default();
        let cs = codes(31);
        let mut oracle = CimMacro::new(cfg.clone());
        oracle.program(&cs);

        let server =
            MacroServer::start(cfg, cs, ServerConfig::default()).unwrap();
        let mut rng = Rng::new(32);
        for _ in 0..5 {
            let x: Vec<u32> = (0..128).map(|_| rng.below(256) as u32).collect();
            let got = server.call(x.clone());
            let want = oracle.ideal_mvm(&x);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-6);
            }
        }
        assert_eq!(server.metrics.requests(), 5);
        server.shutdown();
    }

    #[test]
    fn concurrent_submissions_all_answered() {
        let cfg = MacroConfig::default();
        let server = MacroServer::start(
            cfg,
            codes(33),
            ServerConfig {
                workers: 4,
                max_batch: 4,
                ..ServerConfig::default()
            },
        )
        .unwrap();
        let mut rxs = Vec::new();
        let mut rng = Rng::new(34);
        for _ in 0..32 {
            let x: Vec<u32> = (0..128).map(|_| rng.below(256) as u32).collect();
            rxs.push(server.submit(x));
        }
        for rx in rxs {
            let y = rx.recv().unwrap();
            assert_eq!(y.len(), 128);
        }
        assert_eq!(server.metrics.requests(), 32);
        server.shutdown();
    }

    #[test]
    fn batched_execution_replies_match_serial_mvm_exactly() {
        // A single worker with a large batch window collects concurrent
        // submissions into one `mvm_batch` call (DESIGN.md S16); every
        // reply must be bitwise what a serial `mvm` would have returned.
        let cfg = MacroConfig::default();
        let cs = codes(38);
        let mut oracle = CimMacro::new(cfg.clone());
        oracle.program(&cs);

        let server = MacroServer::start(
            cfg,
            cs,
            ServerConfig {
                workers: 1,
                max_batch: 8,
                batch_timeout: Duration::from_millis(5),
                backend: BackendKind::Sim,
            },
        )
        .unwrap();
        let mut rng = Rng::new(39);
        let xs: Vec<Vec<u32>> = (0..24)
            .map(|_| (0..128).map(|_| rng.below(256) as u32).collect())
            .collect();
        let rxs: Vec<_> =
            xs.iter().map(|x| server.submit(x.clone())).collect();
        for (x, rx) in xs.iter().zip(rxs) {
            let got = rx.recv().unwrap();
            let want = oracle.mvm(x).y_mac;
            assert_eq!(got, want, "batched reply diverges from serial mvm");
        }
        let snap = server.metrics.snapshot();
        assert_eq!(snap.requests, 24);
        assert!(
            snap.batches < 24,
            "expected some multi-job batches, got {} batches",
            snap.batches
        );
        // Activity counters (DESIGN.md S17): every input row slot was
        // offered, and nearly all carried spikes (uniform 0..255 draw).
        assert_eq!(snap.row_slots, 24 * 128);
        assert!(snap.active_rows <= snap.row_slots);
        assert!(snap.input_density() > 0.9, "{}", snap.input_density());
        server.shutdown();
    }

    #[test]
    fn event_list_server_replies_bitwise_equal_dense_oracle() {
        // Server-level S17 bit-identity: an event-list-engined server's
        // replies are bitwise what a dense-engined serial macro returns,
        // under sparse traffic where the engines take different code
        // paths.
        let cfg_ev = MacroConfig {
            engine: MvmEngine::EventList,
            ..MacroConfig::default()
        };
        let cs = codes(44);
        let mut oracle = CimMacro::new(MacroConfig {
            engine: MvmEngine::Dense,
            ..MacroConfig::default()
        });
        oracle.program(&cs);
        let server = MacroServer::start(
            cfg_ev,
            cs,
            ServerConfig {
                workers: 2,
                ..ServerConfig::default()
            },
        )
        .unwrap();
        let mut rng = Rng::new(45);
        for _ in 0..6 {
            let x: Vec<u32> = (0..128)
                .map(|_| {
                    if rng.f64() < 0.1 {
                        1 + rng.below(255) as u32
                    } else {
                        0
                    }
                })
                .collect();
            let got = server.call(x.clone());
            assert_eq!(got, oracle.mvm(&x).y_mac);
        }
        let snap = server.metrics.snapshot();
        assert!(snap.input_density() < 0.3, "{}", snap.input_density());
        server.shutdown();
    }

    #[test]
    fn router_dispatches_by_name() {
        let cfg = MacroConfig::default();
        let mut router = Router::new();
        router.register(
            "layer0",
            MacroServer::start(cfg.clone(), codes(35), ServerConfig::default())
                .unwrap(),
        );
        router.register(
            "layer1",
            MacroServer::start(cfg, codes(36), ServerConfig::default()).unwrap(),
        );
        assert_eq!(router.names(), vec!["layer0", "layer1"]);
        let y = router.call("layer0", vec![1; 128]).unwrap();
        assert_eq!(y.len(), 128);
        assert!(router.call("nope", vec![1; 128]).is_none());
        router.shutdown();
    }

    #[test]
    fn fabric_backend_rejects_oversized_workload_at_start() {
        let cfg = MacroConfig::default();
        let (k, n) = (1024usize, 1024usize); // 64 shards
        let codes = vec![0u8; k * n];
        let res = MacroServer::start(
            cfg,
            codes,
            ServerConfig {
                backend: BackendKind::Fabric {
                    fabric: FabricConfig::square(2), // 4 tiles
                    k,
                    n,
                },
                ..ServerConfig::default()
            },
        );
        let err = res.err().expect("placement must fail at start()");
        assert!(err.to_string().contains("exceed"), "{err}");
    }

    #[test]
    fn fabric_backend_serves_matrices_larger_than_one_macro() {
        let cfg = MacroConfig::default();
        let (k, n) = (256usize, 256usize);
        let mut rng = Rng::new(41);
        let big_codes: Vec<u8> =
            (0..k * n).map(|_| rng.below(4) as u8).collect();
        let fabric = FabricConfig::square(2);

        // Serial oracle chip with identical codes/placement.
        let tiled = TiledMatrix::new(&big_codes, k, n, cfg.rows);
        let mut oracle =
            FabricChip::new(&cfg, fabric.clone(), vec![tiled]).unwrap();

        let server = MacroServer::start(
            cfg,
            big_codes,
            ServerConfig {
                backend: BackendKind::Fabric { fabric, k, n },
                ..ServerConfig::default()
            },
        )
        .unwrap();
        for _ in 0..4 {
            let x: Vec<u32> =
                (0..k).map(|_| rng.below(256) as u32).collect();
            let got = server.call(x.clone());
            let (want, _) = oracle.mvm(&x);
            assert_eq!(got.len(), n);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-9, "{g} vs {w}");
            }
        }
        let snap = server.metrics.snapshot();
        assert_eq!(snap.requests, 4);
        assert_eq!(snap.macs, 4 * (k * n) as u64);
        assert!(snap.noc_packets > 0 && snap.noc_hops > 0);
        assert_eq!((snap.tiles_used, snap.tiles_total), (4, 4));
        assert!((snap.tile_utilization() - 1.0).abs() < 1e-12);
        server.shutdown();
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let cfg = MacroConfig::default();
        let server =
            MacroServer::start(cfg, codes(37), ServerConfig::default()).unwrap();
        server.call(vec![0; 128]);
        server.shutdown(); // must not hang
    }
}

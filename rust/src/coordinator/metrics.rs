//! Serving metrics (DESIGN.md S11): throughput counters + latency
//! histogram, shared by the server threads behind a mutex (coarse-grained
//! is fine — the hot path is the macro computation, not metric updates).

use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::Histogram;

/// Aggregated serving metrics.
pub struct Metrics {
    inner: Mutex<Inner>,
    started: Instant,
}

struct Inner {
    requests: u64,
    batches: u64,
    macs: u64,
    latency_us: Histogram,
    batch_sizes: Histogram,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            inner: Mutex::new(Inner {
                requests: 0,
                batches: 0,
                macs: 0,
                latency_us: Histogram::new(vec![
                    10.0, 20.0, 50.0, 100.0, 200.0, 500.0, 1_000.0, 2_000.0,
                    5_000.0, 10_000.0, 50_000.0, 200_000.0,
                ]),
                batch_sizes: Histogram::new(vec![
                    1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0,
                ]),
            }),
            started: Instant::now(),
        }
    }

    pub fn record_request(&self, latency_us: f64) {
        let mut g = self.inner.lock().unwrap();
        g.requests += 1;
        g.latency_us.record(latency_us);
    }

    pub fn record_batch(&self, size: usize, macs: u64) {
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        g.macs += macs;
        g.batch_sizes.record(size as f64);
    }

    pub fn requests(&self) -> u64 {
        self.inner.lock().unwrap().requests
    }

    /// Requests per second since startup.
    pub fn throughput_rps(&self) -> f64 {
        let secs = self.started.elapsed().as_secs_f64().max(1e-9);
        self.requests() as f64 / secs
    }

    pub fn summary(&self) -> String {
        let g = self.inner.lock().unwrap();
        let secs = self.started.elapsed().as_secs_f64().max(1e-9);
        format!(
            "requests={} batches={} macs={} rps={:.1} mac/s={:.3e}\n\
             latency_us: {}\n\
             batch_size: {}",
            g.requests,
            g.batches,
            g.macs,
            g.requests as f64 / secs,
            g.macs as f64 / secs,
            g.latency_us.summary(),
            g.batch_sizes.summary()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_request(100.0);
        m.record_request(200.0);
        m.record_batch(2, 32768);
        assert_eq!(m.requests(), 2);
        let s = m.summary();
        assert!(s.contains("requests=2"));
        assert!(s.contains("macs=32768"));
    }

    #[test]
    fn throughput_positive_after_requests() {
        let m = Metrics::new();
        m.record_request(1.0);
        assert!(m.throughput_rps() > 0.0);
    }
}

//! Serving metrics (DESIGN.md S11): throughput counters + latency
//! histogram, shared by the server threads behind a mutex (coarse-grained
//! is fine — the hot path is the macro computation, not metric updates).
//!
//! Readers consume one [`MetricsSnapshot`] — a consistent view taken
//! under a single lock acquisition — instead of locking around ad-hoc
//! getter reads. The fabric backend (DESIGN.md S15) additionally feeds
//! NoC hop/packet counters and the tile-utilization gauge.

use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::Histogram;

/// Aggregated serving metrics.
pub struct Metrics {
    inner: Mutex<Inner>,
    started: Instant,
}

struct Inner {
    requests: u64,
    batches: u64,
    macs: u64,
    latency_us: Histogram,
    batch_sizes: Histogram,
    // --- event-driven input occupancy (S17) ---
    active_rows: u64,
    row_slots: u64,
    // --- modeled compute energy (S18: per-timestep stream serving) ---
    energy_fj: f64,
    // --- fabric backend only (S15) ---
    noc_packets: u64,
    noc_hops: u64,
    tiles_used: u64,
    tiles_total: u64,
    // --- reliability runtime (S19) ---
    flips_injected: u64,
    flips_detected: u64,
    flips_repaired: u64,
    scrubs: u64,
    scrub_energy_fj: f64,
    scrub_busy_ns: f64,
    sim_time_ns: f64,
}

/// One consistent view of every serving counter.
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    /// MAC operations executed (2 OPs each).
    pub macs: u64,
    pub uptime_s: f64,
    /// Requests per second since startup.
    pub rps: f64,
    /// MACs per second since startup.
    pub macs_per_s: f64,
    pub latency_mean_us: f64,
    pub latency_p50_us: f64,
    pub latency_p95_us: f64,
    pub latency_p99_us: f64,
    pub mean_batch: f64,
    /// Input rows that carried a spike pair, across all served requests
    /// (DESIGN.md S17: the event-driven occupancy of the traffic).
    pub active_rows: u64,
    /// Input row slots offered (`Σ batch × in_dim`; for the stream
    /// backend, macro row slots across all stages).
    pub row_slots: u64,
    /// Modeled compute energy of all served work (fJ; 0 unless the
    /// backend reports it — the stream server does, per timestep).
    pub energy_fj: f64,
    /// Spike packets routed on the fabric NoC (0 for non-fabric backends).
    pub noc_packets: u64,
    /// Total hops those packets travelled.
    pub noc_hops: u64,
    /// Fabric tiles carrying a weight shard (gauge; 0 off-fabric).
    pub tiles_used: u64,
    /// Fabric mesh size (gauge; 0 off-fabric).
    pub tiles_total: u64,
    /// Cells changed by injected retention drift (S19; 0 without a
    /// fault plan).
    pub flips_injected: u64,
    /// Cells found disagreeing with golden during scrub passes.
    pub flips_detected: u64,
    /// Cells restored to golden by scrub rewrites.
    pub flips_repaired: u64,
    /// Scrub passes completed.
    pub scrubs: u64,
    /// SOT write energy spent scrubbing (fJ; also folded into
    /// `energy_fj` so the serving ledger sees it).
    pub scrub_energy_fj: f64,
    /// Simulated array time occupied by scrubbing (ns).
    pub scrub_busy_ns: f64,
    /// Simulated uptime advanced by drift injection (ns).
    pub sim_time_ns: f64,
}

impl MetricsSnapshot {
    /// Fraction of served input rows that were active (0 before any
    /// traffic) — silent rows cost the macro nothing, so this is the
    /// knob the event-list engine's win scales with.
    pub fn input_density(&self) -> f64 {
        if self.row_slots == 0 {
            0.0
        } else {
            self.active_rows as f64 / self.row_slots as f64
        }
    }

    /// Fraction of fabric tiles carrying a weight shard (0 off-fabric).
    pub fn tile_utilization(&self) -> f64 {
        if self.tiles_total == 0 {
            0.0
        } else {
            self.tiles_used as f64 / self.tiles_total as f64
        }
    }

    /// Mean hops per routed spike packet.
    pub fn hops_per_packet(&self) -> f64 {
        if self.noc_packets == 0 {
            0.0
        } else {
            self.noc_hops as f64 / self.noc_packets as f64
        }
    }

    /// Fraction of simulated uptime spent scrubbing, clamped to [0, 1]
    /// (an aggressive wall-clock scrubber can overlap serving, so the
    /// raw ratio may exceed 1; 0 before any drift is injected).
    pub fn scrub_duty_cycle(&self) -> f64 {
        if self.sim_time_ns <= 0.0 {
            0.0
        } else {
            (self.scrub_busy_ns / self.sim_time_ns).min(1.0)
        }
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            inner: Mutex::new(Inner {
                requests: 0,
                batches: 0,
                macs: 0,
                latency_us: Histogram::new(vec![
                    10.0, 20.0, 50.0, 100.0, 200.0, 500.0, 1_000.0, 2_000.0,
                    5_000.0, 10_000.0, 50_000.0, 200_000.0,
                ]),
                batch_sizes: Histogram::new(vec![
                    1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0,
                ]),
                active_rows: 0,
                row_slots: 0,
                energy_fj: 0.0,
                noc_packets: 0,
                noc_hops: 0,
                tiles_used: 0,
                tiles_total: 0,
                flips_injected: 0,
                flips_detected: 0,
                flips_repaired: 0,
                scrubs: 0,
                scrub_energy_fj: 0.0,
                scrub_busy_ns: 0.0,
                sim_time_ns: 0.0,
            }),
            started: Instant::now(),
        }
    }

    pub fn record_request(&self, latency_us: f64) {
        let mut g = self.inner.lock().unwrap();
        g.requests += 1;
        g.latency_us.record(latency_us);
    }

    pub fn record_batch(&self, size: usize, macs: u64) {
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        g.macs += macs;
        g.batch_sizes.record(size as f64);
    }

    /// Account one batch's input occupancy (DESIGN.md S17): `active`
    /// rows carried spikes out of `slots` offered.
    pub fn record_activity(&self, active: u64, slots: u64) {
        let mut g = self.inner.lock().unwrap();
        g.active_rows += active;
        g.row_slots += slots;
    }

    /// Account modeled compute energy for served work (fJ, monotonic).
    /// The stream backend calls this per timestep (DESIGN.md S18).
    pub fn record_energy(&self, fj: f64) {
        let mut g = self.inner.lock().unwrap();
        g.energy_fj += fj;
    }

    /// Convenience: input density of all served traffic so far (one
    /// lock, via snapshot). Returns 0.0 — never NaN, never a panic —
    /// on a fresh server with no traffic (`row_slots == 0`).
    pub fn input_density(&self) -> f64 {
        self.snapshot().input_density()
    }

    /// Account routed fabric traffic (counters, monotonic).
    pub fn record_noc(&self, packets: u64, hops: u64) {
        let mut g = self.inner.lock().unwrap();
        g.noc_packets += packets;
        g.noc_hops += hops;
    }

    /// Set the fabric placement gauge (shard-carrying tiles / mesh size).
    pub fn set_tile_usage(&self, used: u64, total: u64) {
        let mut g = self.inner.lock().unwrap();
        g.tiles_used = used;
        g.tiles_total = total;
    }

    /// Account one drift-injection round (S19): `flips` cells changed
    /// while the simulated clock advanced by `dt_ns`.
    pub fn record_fault_injection(&self, flips: u64, dt_ns: f64) {
        let mut g = self.inner.lock().unwrap();
        g.flips_injected += flips;
        g.sim_time_ns += dt_ns;
    }

    /// Account one scrub pass (S19): mismatches found, cells restored,
    /// write energy spent, and simulated array time occupied. The
    /// energy also lands in the serving ledger (`energy_fj`), so scrub
    /// cost is visible wherever compute energy is.
    pub fn record_scrub(
        &self,
        detected: u64,
        repaired: u64,
        energy_fj: f64,
        busy_ns: f64,
    ) {
        let mut g = self.inner.lock().unwrap();
        g.scrubs += 1;
        g.flips_detected += detected;
        g.flips_repaired += repaired;
        g.scrub_energy_fj += energy_fj;
        g.scrub_busy_ns += busy_ns;
        g.energy_fj += energy_fj;
    }

    /// Derive the snapshot from an already-held guard — the one source
    /// of every rate/quantile, shared by `snapshot()` and `summary()`.
    fn snapshot_of(&self, g: &Inner) -> MetricsSnapshot {
        let secs = self.started.elapsed().as_secs_f64().max(1e-9);
        MetricsSnapshot {
            requests: g.requests,
            batches: g.batches,
            macs: g.macs,
            uptime_s: secs,
            rps: g.requests as f64 / secs,
            macs_per_s: g.macs as f64 / secs,
            latency_mean_us: g.latency_us.mean(),
            latency_p50_us: g.latency_us.quantile(0.50),
            latency_p95_us: g.latency_us.quantile(0.95),
            latency_p99_us: g.latency_us.quantile(0.99),
            mean_batch: g.batch_sizes.mean(),
            active_rows: g.active_rows,
            row_slots: g.row_slots,
            energy_fj: g.energy_fj,
            noc_packets: g.noc_packets,
            noc_hops: g.noc_hops,
            tiles_used: g.tiles_used,
            tiles_total: g.tiles_total,
            flips_injected: g.flips_injected,
            flips_detected: g.flips_detected,
            flips_repaired: g.flips_repaired,
            scrubs: g.scrubs,
            scrub_energy_fj: g.scrub_energy_fj,
            scrub_busy_ns: g.scrub_busy_ns,
            sim_time_ns: g.sim_time_ns,
        }
    }

    /// Take one consistent snapshot (single lock acquisition).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        self.snapshot_of(&g)
    }

    /// Convenience: request count (one lock, via snapshot).
    pub fn requests(&self) -> u64 {
        self.snapshot().requests
    }

    /// Requests per second since startup.
    pub fn throughput_rps(&self) -> f64 {
        self.snapshot().rps
    }

    pub fn summary(&self) -> String {
        let g = self.inner.lock().unwrap();
        let s = self.snapshot_of(&g); // same guard: one consistent view
        let mut out = format!(
            "requests={} batches={} macs={} rps={:.1} mac/s={:.3e}\n\
             latency_us: {}\n\
             batch_size: {}",
            s.requests,
            s.batches,
            s.macs,
            s.rps,
            s.macs_per_s,
            g.latency_us.summary(),
            g.batch_sizes.summary()
        );
        if s.row_slots > 0 {
            out.push_str(&format!(
                "\nactivity: active_rows={} / {} slots ({:.1} % dense)",
                s.active_rows,
                s.row_slots,
                s.input_density() * 100.0
            ));
        }
        if s.energy_fj > 0.0 {
            out.push_str(&format!(
                "\nenergy: {:.1} pJ modeled ({:.2} pJ/request)",
                s.energy_fj / 1e3,
                s.energy_fj / 1e3 / s.requests.max(1) as f64
            ));
        }
        if s.tiles_total > 0 || s.noc_packets > 0 {
            out.push_str(&format!(
                "\nnoc: packets={} hops={} tiles={}/{} ({:.0} % utilized)",
                s.noc_packets,
                s.noc_hops,
                s.tiles_used,
                s.tiles_total,
                s.tile_utilization() * 100.0
            ));
        }
        if s.flips_injected > 0 || s.scrubs > 0 {
            out.push_str(&format!(
                "\nreliability: flips injected={} detected={} repaired={} \
                 scrubs={} duty={:.1} % scrub_energy={:.1} pJ",
                s.flips_injected,
                s.flips_detected,
                s.flips_repaired,
                s.scrubs,
                s.scrub_duty_cycle() * 100.0,
                s.scrub_energy_fj / 1e3
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_request(100.0);
        m.record_request(200.0);
        m.record_batch(2, 32768);
        assert_eq!(m.requests(), 2);
        let s = m.summary();
        assert!(s.contains("requests=2"));
        assert!(s.contains("macs=32768"));
        assert!(!s.contains("noc:"), "no fabric line off-fabric");
    }

    #[test]
    fn throughput_positive_after_requests() {
        let m = Metrics::new();
        m.record_request(1.0);
        assert!(m.throughput_rps() > 0.0);
    }

    #[test]
    fn snapshot_is_one_consistent_view() {
        let m = Metrics::new();
        for lat in [50.0, 150.0, 900.0] {
            m.record_request(lat);
        }
        m.record_batch(3, 3 * 16384);
        let s = m.snapshot();
        assert_eq!(s.requests, 3);
        assert_eq!(s.batches, 1);
        assert_eq!(s.macs, 3 * 16384);
        assert!(s.rps > 0.0 && s.macs_per_s > 0.0);
        assert!(s.latency_mean_us > 0.0);
        // Histogram upper-edge convention: p50 lands on a bucket bound.
        assert!(s.latency_p50_us >= 50.0);
        assert!(s.latency_p99_us >= s.latency_p50_us);
        assert!((s.mean_batch - 3.0).abs() < 1e-12);
        assert_eq!(s.noc_packets, 0);
        assert_eq!(s.tile_utilization(), 0.0);
    }

    #[test]
    fn activity_counters_and_density() {
        let m = Metrics::new();
        assert_eq!(m.snapshot().input_density(), 0.0);
        m.record_activity(13, 128);
        m.record_activity(0, 128);
        let s = m.snapshot();
        assert_eq!(s.active_rows, 13);
        assert_eq!(s.row_slots, 256);
        assert!((s.input_density() - 13.0 / 256.0).abs() < 1e-12);
        assert!((m.input_density() - 13.0 / 256.0).abs() < 1e-12);
        assert!(m.summary().contains("active_rows=13 / 256"));
    }

    #[test]
    fn fresh_server_input_density_is_zero_not_nan() {
        // The S18 satellite fix: a fresh server (no traffic, zero row
        // slots) must report density 0.0 — finite, no NaN, no panic —
        // through both the snapshot and the Metrics convenience.
        let m = Metrics::new();
        let d = m.input_density();
        assert_eq!(d, 0.0);
        assert!(d.is_finite());
        assert_eq!(m.snapshot().input_density(), 0.0);
        assert_eq!(MetricsSnapshot::default().input_density(), 0.0);
        // Zero-slot activity records keep it well-defined too.
        m.record_activity(0, 0);
        assert_eq!(m.input_density(), 0.0);
    }

    #[test]
    fn energy_accumulates_and_shows_in_summary() {
        let m = Metrics::new();
        assert_eq!(m.snapshot().energy_fj, 0.0);
        assert!(!m.summary().contains("energy:"), "no line before traffic");
        m.record_energy(1500.0);
        m.record_energy(500.0);
        m.record_request(10.0);
        let s = m.snapshot();
        assert_eq!(s.energy_fj, 2000.0);
        assert!(m.summary().contains("energy: 2.0 pJ modeled"));
    }

    #[test]
    fn reliability_counters_accumulate_and_show() {
        let m = Metrics::new();
        assert_eq!(m.snapshot().scrub_duty_cycle(), 0.0);
        assert!(!m.summary().contains("reliability:"));
        m.record_fault_injection(12, 1e6);
        m.record_scrub(12, 12, 5_000.0, 2e5);
        m.record_fault_injection(3, 1e6);
        m.record_scrub(3, 3, 1_000.0, 2e5);
        let s = m.snapshot();
        assert_eq!(s.flips_injected, 15);
        assert_eq!(s.flips_detected, 15);
        assert_eq!(s.flips_repaired, 15);
        assert_eq!(s.scrubs, 2);
        assert_eq!(s.scrub_energy_fj, 6_000.0);
        assert!((s.scrub_duty_cycle() - 0.2).abs() < 1e-12);
        // Scrub energy is folded into the serving ledger.
        assert_eq!(s.energy_fj, 6_000.0);
        assert!(m.summary().contains(
            "reliability: flips injected=15 detected=15 repaired=15"
        ));
    }

    #[test]
    fn scrub_duty_cycle_clamps_at_one() {
        let m = Metrics::new();
        m.record_fault_injection(0, 10.0);
        m.record_scrub(0, 0, 0.0, 100.0);
        assert_eq!(m.snapshot().scrub_duty_cycle(), 1.0);
    }

    #[test]
    fn fabric_counters_and_gauges() {
        let m = Metrics::new();
        m.record_noc(10, 35);
        m.record_noc(5, 10);
        m.set_tile_usage(3, 4);
        let s = m.snapshot();
        assert_eq!(s.noc_packets, 15);
        assert_eq!(s.noc_hops, 45);
        assert_eq!(s.tiles_used, 3);
        assert!((s.tile_utilization() - 0.75).abs() < 1e-12);
        assert!((s.hops_per_packet() - 3.0).abs() < 1e-12);
        assert!(m.summary().contains("noc: packets=15 hops=45 tiles=3/4"));
    }
}
